//! Quickstart: build a small 3D Poisson problem, run all three triple
//! product algorithms, verify they agree, and compare memory/time.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use galerkin_ptap::dist::World;
use galerkin_ptap::gen::{Grid3, ModelProblem};
use galerkin_ptap::mem::MemTracker;
use galerkin_ptap::ptap::{Ptap, ALL_ALGOS};
use galerkin_ptap::util::fmt_secs;

fn main() {
    let np = 4;
    let coarse = Grid3::cube(16);
    let fine = coarse.refine();
    println!(
        "quickstart: C = PᵀAP on a {}³ fine grid ({} unknowns), {} simulated ranks\n",
        fine.nx,
        fine.len(),
        np
    );

    let world = World::new(np);
    // Each rank builds its slice of A (7-point Laplacian) and P (trilinear
    // interpolation), then runs the three algorithms.
    let per_rank = world.run(|comm| {
        let mp = ModelProblem::build(coarse, comm.rank(), comm.size());
        let mut out = Vec::new();
        let mut c_ref = None;
        for algo in ALL_ALGOS {
            let tracker = MemTracker::new();
            let mut op = Ptap::symbolic(algo, &comm, &mp.a, &mp.p, &tracker);
            op.numeric(&comm, &mp.a, &mp.p);
            let c = op.extract_c();
            // all three algorithms must produce the identical coarse operator
            let g = c.gather_global(&comm);
            match &c_ref {
                None => c_ref = Some(g),
                Some(r) => {
                    let diff = r.max_abs_diff(&g);
                    assert!(diff < 1e-10, "{} disagrees by {diff}", algo.name());
                }
            }
            out.push((algo, tracker.peak_total(), op.stats));
        }
        out
    });

    println!(
        "{:<12} {:>12} {:>12} {:>12}",
        "algorithm", "peak mem", "symbolic", "numeric"
    );
    println!("{}", "-".repeat(52));
    for k in 0..ALL_ALGOS.len() {
        let algo = per_rank[0][k].0;
        let mem = per_rank.iter().map(|r| r[k].1).max().unwrap();
        let tsym = per_rank
            .iter()
            .map(|r| r[k].2.time_sym_modeled())
            .fold(0.0f64, f64::max);
        let tnum = per_rank
            .iter()
            .map(|r| r[k].2.time_num_modeled())
            .fold(0.0f64, f64::max);
        println!(
            "{:<12} {:>9.2} MB {:>12} {:>12}",
            algo.name(),
            mem as f64 / 1048576.0,
            fmt_secs(tsym),
            fmt_secs(tnum),
        );
    }
    println!("\nall three algorithms produced the identical coarse operator ✓");
}
