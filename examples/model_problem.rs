//! The paper's model problem (§4.1) at example scale: a structured-grid
//! two-level Galerkin product swept over rank counts, printing the
//! Table 1/2 analog rows.
//!
//! ```bash
//! cargo run --release --example model_problem
//! ```

use galerkin_ptap::coordinator::{
    model_problem_tables, run_model_problem, write_results, ModelProblemConfig,
};
use galerkin_ptap::gen::Grid3;
use galerkin_ptap::ptap::ALL_ALGOS;

fn main() {
    let coarse = Grid3::cube(20);
    let fine = coarse.refine();
    println!(
        "model problem: coarse {}³ → fine {}³ = {} unknowns; 1 symbolic + 11 numeric products\n",
        coarse.nx,
        fine.nx,
        fine.len()
    );
    let mut rows = Vec::new();
    for np in [2, 4, 8] {
        for algo in ALL_ALGOS {
            rows.push(run_model_problem(ModelProblemConfig {
                coarse,
                np,
                algo,
                numeric_repeats: 11,
            }));
            println!("  np={np} {} done", algo.name());
        }
    }
    let (main, storage) = model_problem_tables(&rows);
    println!("\n{}", main.render());
    println!("{}", storage.render());
    write_results(&main, "example_model_problem");

    // the paper's headline: all-at-once uses a fraction of two-step's memory
    let aao: Vec<_> = rows.iter().filter(|r| r.algo.name() == "allatonce").collect();
    let two: Vec<_> = rows.iter().filter(|r| r.algo.name() == "two-step").collect();
    for (a, t) in aao.iter().zip(&two) {
        println!(
            "np={:<3} two-step/all-at-once memory ratio: {:.1}x",
            a.np,
            t.mem_product as f64 / a.mem_product as f64
        );
    }
}
