//! END-TO-END driver (DESIGN.md §5): build a multilevel Galerkin hierarchy
//! for a ~0.9M-unknown 3D Poisson problem with the all-at-once triple
//! products, solve with MG-preconditioned CG, and log the residual curve.
//! Verifies the hierarchy built with all-at-once products is identical to
//! the two-step-built one (coarse operators agree to round-off).
//!
//! ```bash
//! cargo run --release --example mg_solve            # full size (~0.9M)
//! cargo run --release --example mg_solve -- small   # CI size  (~0.2M)
//! ```

use std::time::Instant;

use galerkin_ptap::dist::{CsrOperator, DistSpmv, DistVec, World};
use galerkin_ptap::gen::{grid_laplacian, Grid3};
use galerkin_ptap::mem::{Cat, MemTracker};
use galerkin_ptap::mg::{
    build_hierarchy, geometric_chain, pcg, Coarsening, HierarchyConfig, MgOpts, MgPreconditioner,
};
use galerkin_ptap::ptap::Algo;
use galerkin_ptap::util::table::Table;

fn main() {
    let small = std::env::args().any(|a| a == "small");
    // coarsest 7³ -> 13³ -> 25³ -> 49³ -> fine 97³ ≈ 0.91M unknowns
    // (5 levels; the small coarsest keeps the redundant dense solve cheap)
    let (coarsest, levels, np) = if small { (7, 3, 2) } else { (7, 5, 4) };
    let grids = geometric_chain(Grid3::cube(coarsest), levels);
    let n = grids[0].len();
    println!(
        "end-to-end MG-CG: fine {}³ = {} unknowns, {} levels, {} simulated ranks",
        grids[0].nx, n, levels, np
    );

    let world = World::new(np);
    let grids_ref = &grids;
    let wall = Instant::now();
    let results = world.run(move |comm| {
        let tracker = MemTracker::new();
        let a0 = grid_laplacian(grids_ref[0], comm.rank(), comm.size());
        tracker.alloc(Cat::MatA, a0.bytes());

        // hierarchy via all-at-once products
        let t0 = Instant::now();
        let h = build_hierarchy(
            &comm,
            a0.clone(),
            &Coarsening::Geometric { grids: grids_ref.clone() },
            HierarchyConfig {
                algo: Algo::AllAtOnce,
                cache: false,
                numeric_repeats: 1,
                eq_limit: None,
                retain: false,
            },
            &tracker,
        );
        let setup_aao = t0.elapsed().as_secs_f64();

        // cross-check: the two-step products must build the *same* coarse
        // operators (cheap check on the coarsest level)
        let h2 = build_hierarchy(
            &comm,
            a0.clone(),
            &Coarsening::Geometric { grids: grids_ref.clone() },
            HierarchyConfig {
                algo: Algo::TwoStep,
                cache: false,
                numeric_repeats: 1,
                eq_limit: None,
                retain: false,
            },
            &tracker,
        );
        let c1 = h.levels.last().unwrap().a.csr().gather_global(&comm);
        let c2 = h2.levels.last().unwrap().a.csr().gather_global(&comm);
        let hierarchy_diff = c1.max_abs_diff(&c2);
        drop(h2);

        let spmv = DistSpmv::new(&comm, &a0);
        let mut pc = MgPreconditioner::new(&comm, h, MgOpts::default());
        let layout = a0.row_layout.clone();
        // manufactured solution: x* with known pattern, b = A x*
        let xstar = DistVec::from_fn(layout.clone(), comm.rank(), |g| ((g % 100) as f64) / 100.0);
        let mut b = DistVec::zeros(layout.clone(), comm.rank());
        spmv.apply(&comm, &a0, &xstar, &mut b);
        let mut x = DistVec::zeros(layout, comm.rank());
        let t0 = Instant::now();
        let op = CsrOperator::new(&a0, &spmv);
        let res = pcg(&comm, &op, &b, &mut x, Some(&mut pc), 1e-8, 100);
        let solve_secs = t0.elapsed().as_secs_f64();
        // error vs manufactured solution
        let mut err = x.clone();
        err.axpy(-1.0, &xstar);
        let err_norm = err.norm2(&comm) / xstar.norm2(&comm);
        (
            res,
            setup_aao,
            solve_secs,
            hierarchy_diff,
            err_norm,
            tracker.peak_total(),
        )
    });

    let (res, setup, solve_secs, hdiff, err, peak) = &results[0];
    println!("hierarchy(all-at-once) vs hierarchy(two-step): max coarse diff = {hdiff:.2e} ✓");
    println!(
        "setup {:.2}s | solve {:.2}s ({} iters, converged={}) | wall {:.2}s | peak {:.0} MB/rank",
        setup,
        solve_secs,
        res.iterations,
        res.converged,
        wall.elapsed().as_secs_f64(),
        *peak as f64 / 1048576.0
    );
    println!("relative error vs manufactured solution: {err:.2e}");
    println!("\nresidual curve:");
    let mut t = Table::new(vec!["iter", "residual", "rate"]);
    for (k, r) in res.residuals.iter().enumerate() {
        let rate = if k == 0 { "-".to_string() } else {
            format!("{:.3}", r / res.residuals[k - 1])
        };
        t.row(vec![k.to_string(), format!("{r:.6e}"), rate]);
    }
    println!("{}", t.render());
    let _ = t.write_tsv(std::path::Path::new("results/mg_solve_residuals.tsv"));
    assert!(res.converged, "end-to-end solve must converge");
    assert!(*hdiff < 1e-9, "hierarchies must agree");
    assert!(*err < 1e-6, "solution error too large: {err}");
    println!("end-to-end OK -> results/mg_solve_residuals.tsv");
}
