//! The neutron-transport-like block workload (§4.2 analog): a multigroup
//! block operator coarsened with the block all-at-once product, with the
//! numeric hot path running through the compiled Pallas kernel (PJRT).
//!
//! ```bash
//! make artifacts && cargo run --release --example neutron_transport
//! ```

use std::time::Instant;

use galerkin_ptap::dist::World;
use galerkin_ptap::gen::{neutron_block_interp, neutron_block_operator, Grid3, NeutronConfig};
use galerkin_ptap::mem::MemTracker;
use galerkin_ptap::ptap::block::block_ptap;
use galerkin_ptap::runtime::{BlockBackend, KernelRuntime};

fn main() {
    let grid = Grid3::cube(10);
    let groups = 8;
    let np = 2;
    println!(
        "neutron analog: {}³ vertices × {} groups = {} unknowns, {} ranks",
        grid.nx,
        groups,
        grid.len() * groups,
        np
    );
    let dir = KernelRuntime::find_dir().expect("run `make artifacts` first");

    let world = World::new(np);
    let dir_ref = &dir;
    let rows = world.run(move |comm| {
        // one PJRT client per rank, as one per process under real MPI
        let rt = KernelRuntime::load_filtered(dir_ref, |m| {
            m.entry == "block_ptap" && m.block == groups
        })
        .expect("artifacts");
        let cfg = NeutronConfig { grid, groups, seed: 99 };
        let a = neutron_block_operator(cfg, comm.rank(), comm.size());
        let p = neutron_block_interp(grid, groups, comm.rank(), comm.size());

        let tracker = MemTracker::new();
        let t0 = Instant::now();
        let native = block_ptap(&comm, &a, &p, BlockBackend::Native, &tracker);
        let t_native = t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let pjrt = block_ptap(&comm, &a, &p, BlockBackend::Pjrt(&rt), &tracker);
        let t_pjrt = t0.elapsed().as_secs_f64();

        let diff = {
            let gn = native.c.to_scalar().gather_global(&comm);
            let gp = pjrt.c.to_scalar().gather_global(&comm);
            gn.max_abs_diff(&gp)
        };
        (
            comm.rank(),
            native.triples,
            pjrt.flushes,
            t_native,
            t_pjrt,
            diff,
            pjrt.c.nnz_blocks_local(),
        )
    });
    println!(
        "\n{:<5} {:>10} {:>8} {:>12} {:>12} {:>12}",
        "rank", "triples", "chunks", "native", "pjrt", "|Δ|max"
    );
    for (rank, triples, flushes, tn, tp, diff, nnzb) in rows {
        println!(
            "{:<5} {:>10} {:>8} {:>10.1}ms {:>10.1}ms {:>12.2e}   ({} C-blocks)",
            rank,
            triples,
            flushes,
            tn * 1e3,
            tp * 1e3,
            diff,
            nnzb
        );
        assert!(diff < 1e-3, "PJRT kernel must match the native path");
    }
    println!("\ncoarse block operator identical across backends ✓ (f32 kernel round-off)");
}
