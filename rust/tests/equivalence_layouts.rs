//! Algorithm equivalence on irregular `Layout::from_counts` partitions —
//! including an empty rank and a rank whose `P` offd block is empty — and
//! a pipeline chunk-size sweep: every `GPTAP_PIPELINE_CHUNK` setting
//! (1 = post every row, huge = end-staged/bulk) must produce the
//! bit-identical `C` and identical measured byte totals.

use std::sync::Mutex;

use galerkin_ptap::dist::{DistCsr, DistCsrBuilder, Layout, World};
use galerkin_ptap::mat::Csr;
use galerkin_ptap::mem::MemTracker;
use galerkin_ptap::ptap::{ptap_once, seq_ptap_reference, Algo, ALL_ALGOS};
use galerkin_ptap::util::prng::Rng;

/// `GPTAP_PIPELINE_CHUNK` is process-global state read by the pipelines;
/// `std::env::set_var` racing a concurrent `env::var` is UB on glibc.
/// Every test in this binary takes this lock so the chunk sweep never
/// overlaps another test's env reads.
static ENV_LOCK: Mutex<()> = Mutex::new(());

const N_FINE: usize = 40;

/// A: `N_FINE × N_FINE` (row and column space both partitioned by `rl`),
/// ~5 nnz/row, globally deterministic (the same matrix under any
/// partition).
fn build_a(rank: usize, rl: &Layout) -> DistCsr {
    let ncols = rl.global_size();
    let mut b = DistCsrBuilder::new(rank, rl.clone(), rl.clone());
    for gi in rl.range(rank) {
        let mut rng = Rng::new(900 + gi as u64 * 7919);
        let mut cols: Vec<u64> = (0..5).map(|_| rng.below(ncols) as u64).collect();
        cols.sort_unstable();
        cols.dedup();
        let entries: Vec<(u64, f64)> =
            cols.iter().map(|&c| (c, rng.range_f64(-1.0, 1.0))).collect();
        b.push_row(&entries);
    }
    b.finish()
}

/// P: `N_FINE × m`, ~2 nnz/row; rows owned by `local_only_rank` reference
/// only that rank's own coarse columns, so its offd block is empty.
fn build_p(rank: usize, rl: &Layout, cl: &Layout, local_only_rank: usize) -> DistCsr {
    let mut b = DistCsrBuilder::new(rank, rl.clone(), cl.clone());
    for gi in rl.range(rank) {
        let mut rng = Rng::new(7000 + gi as u64 * 104729);
        let range = if rank == local_only_rank {
            cl.range(local_only_rank)
        } else {
            0..cl.global_size()
        };
        assert!(!range.is_empty(), "local-only rank must own coarse columns");
        let lo = range.start as u64;
        let n = range.end - range.start;
        let mut cols: Vec<u64> = (0..2).map(|_| lo + rng.below(n) as u64).collect();
        cols.sort_unstable();
        cols.dedup();
        let entries: Vec<(u64, f64)> =
            cols.iter().map(|&c| (c, rng.range_f64(-1.0, 1.0))).collect();
        b.push_row(&entries);
    }
    b.finish()
}

struct Cell {
    row_counts: Vec<usize>,
    coarse_counts: Vec<usize>,
    local_only_rank: usize,
}

/// The partitions under test, all via `Layout::from_counts`:
/// - np = 1: trivial single-rank baseline;
/// - np = 2: rank 0 owns *no fine rows* (empty rank — its P offd is
///   trivially empty) while owning most coarse columns, so rank 1
///   computes everything and ships rank 0 its C block;
/// - np = 4: rank 0 owns no fine rows, rank 1 owns no coarse columns,
///   and rank 2 is the local-only rank (nonzero rows, empty P offd).
fn cells() -> Vec<Cell> {
    vec![
        Cell { row_counts: vec![N_FINE], coarse_counts: vec![12], local_only_rank: 0 },
        Cell {
            row_counts: vec![0, N_FINE],
            coarse_counts: vec![8, 4],
            local_only_rank: 0,
        },
        Cell {
            row_counts: vec![0, 18, 4, 18],
            coarse_counts: vec![6, 0, 4, 2],
            local_only_rank: 2,
        },
    ]
}

/// Run one algorithm on one partition; every rank returns the gathered
/// global C (plus A and P for the sequential reference).
fn run_cell(cell: &Cell, algo: Algo) -> Vec<(Csr, Csr, Csr, u64, u64)> {
    let np = cell.row_counts.len();
    let rl = Layout::from_counts(&cell.row_counts);
    let cl = Layout::from_counts(&cell.coarse_counts);
    let w = World::new(np);
    w.run(|comm| {
        let a = build_a(comm.rank(), &rl);
        let p = build_p(comm.rank(), &rl, &cl, cell.local_only_rank);
        if comm.rank() == cell.local_only_rank {
            assert_eq!(p.offd.nnz(), 0, "local-only rank must have an empty P offd");
        }
        let tracker = MemTracker::new();
        let (c, stats) = ptap_once(algo, &comm, &a, &p, &tracker);
        c.validate().unwrap();
        (
            c.gather_global(&comm),
            a.gather_global(&comm),
            p.gather_global(&comm),
            stats.sym_bytes,
            stats.num_bytes,
        )
    })
}

#[test]
fn algorithms_agree_on_irregular_partitions() {
    let _env = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    for cell in cells() {
        let np = cell.row_counts.len();
        let aao = run_cell(&cell, Algo::AllAtOnce);
        let merged = run_cell(&cell, Algo::Merged);
        let two = run_cell(&cell, Algo::TwoStep);
        let want = seq_ptap_reference(&aao[0].1, &aao[0].2);
        for rank in 0..np {
            // every rank assembles the same global C as rank 0
            assert_eq!(aao[rank].0, aao[0].0, "np={np} rank {rank} inconsistent");
            // all-at-once and merged perform identical per-slot float
            // sequences: bit-identical C
            assert_eq!(aao[rank].0, merged[rank].0, "np={np} rank {rank} aao vs merged");
            // two-step accumulates through the dense apa scratch; same
            // per-slot order, compared to ulp-level tolerance
            let d2 = two[rank].0.max_abs_diff(&aao[rank].0);
            assert!(d2 < 1e-12, "np={np} rank {rank} two-step diff {d2}");
            let dr = aao[rank].0.max_abs_diff(&want);
            assert!(dr < 1e-10, "np={np} rank {rank} vs reference diff {dr}");
        }
    }
}

#[test]
fn pipeline_chunk_sweep_is_bit_identical_to_bulk() {
    // chunk = 1 posts every staged row immediately (maximal pipelining);
    // a huge chunk degenerates to end-staged sends, i.e. exactly the
    // bulk-synchronous schedule.  C bits and byte totals must not move.
    let _env = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let cells = cells();
    let cell = &cells[2];
    for algo in ALL_ALGOS {
        std::env::set_var("GPTAP_PIPELINE_CHUNK", "1000000000");
        let bulk = run_cell(cell, algo);
        for chunk in ["1", "3", "64"] {
            std::env::set_var("GPTAP_PIPELINE_CHUNK", chunk);
            let piped = run_cell(cell, algo);
            for rank in 0..cell.row_counts.len() {
                assert_eq!(
                    piped[rank].0, bulk[rank].0,
                    "{:?} chunk {chunk} rank {rank}: C bits moved",
                    algo
                );
                assert_eq!(
                    (piped[rank].3, piped[rank].4),
                    (bulk[rank].3, bulk[rank].4),
                    "{:?} chunk {chunk} rank {rank}: byte totals moved",
                    algo
                );
            }
        }
    }
    std::env::remove_var("GPTAP_PIPELINE_CHUNK");
}
