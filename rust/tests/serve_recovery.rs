//! Self-healing serve: a malformed request inside a batch fails only its
//! own ticket (the guarded flush contains the panic, shape-votes the
//! culprit out, and redispatches the survivors bitwise intact); a
//! poisoned cache entry is rebuilt transparently on the next checkout,
//! bitwise identical to a fresh server; and an over-budget burst is shed
//! with [`galerkin_ptap::session::Overloaded`] while the requests that
//! were admitted before the shed still flush and complete healthy.

use std::time::Duration;

use galerkin_ptap::dist::{CsrOperator, DistSpmv, DistVec, World};
use galerkin_ptap::gen::{grid_laplacian, Grid3};
use galerkin_ptap::mem::MemTracker;
use galerkin_ptap::mg::{geometric_chain, pcg, Coarsening, HierarchyConfig, MgOpts};
use galerkin_ptap::obs;
use galerkin_ptap::obs::health::Verdict;
use galerkin_ptap::session::{RequestQueue, SessionCache};

const NP: usize = 2;
const RTOL: f64 = 1e-8;
const MAX_ITERS: usize = 40;

#[test]
fn wrong_grid_rhs_fails_only_its_ticket() {
    World::new(NP).run(|c| {
        obs::metrics::rank_begin(c.rank());
        let grids = geometric_chain(Grid3::cube(3), 3);
        let coarsening = Coarsening::Geometric { grids: grids.clone() };
        let a = grid_laplacian(grids[0], c.rank(), c.size());
        let layout = a.row_layout.clone();
        let tracker = MemTracker::new();
        let spmv = DistSpmv::new(&c, &a);
        let op = CsrOperator::new(&a, &spmv);
        let rhs = |s: usize| {
            DistVec::from_fn(layout.clone(), c.rank(), |g| {
                ((g as f64) * 0.21 + s as f64).sin()
            })
        };
        // a client assembled its RHS on the wrong grid: the layout has
        // the coarse level's size, so `DistMultiVec::from_columns`
        // panics on every rank before any message is sent
        let a_coarse = grid_laplacian(grids[1], c.rank(), c.size());
        let bad = DistVec::from_fn(a_coarse.row_layout.clone(), c.rank(), |g| (g as f64).cos());

        let mut cache = SessionCache::new();
        let (r, hit) = cache.checkout(
            &c,
            &a,
            &coarsening,
            HierarchyConfig::default(),
            MgOpts::default(),
            &tracker,
        );
        assert!(!hit);
        let mut q = RequestQueue::new(3, Duration::from_secs(3600));
        let t0 = q.submit(rhs(0));
        let t_bad = q.submit(bad);
        let t1 = q.submit(rhs(1));
        let done = q.flush_guarded(&c, &op, Some(r.pc()), RTOL, MAX_ITERS, &tracker);
        assert_eq!(done.len(), 3);

        // only the malformed ticket failed: zero solution on its own
        // layout, empty history, never reached the solver
        let d_bad = done.iter().find(|d| d.ticket == t_bad).unwrap();
        assert_eq!(
            d_bad.verdict, Verdict::Failed,
            "shape vote must flag the bad ticket"
        );
        assert!(!d_bad.result.converged);
        assert!(d_bad.result.residuals.is_empty());
        assert!(d_bad.x.vals.iter().all(|&v| v == 0.0));

        // the batch-mates redispatched and are bitwise what a fresh
        // server would have produced for each alone
        let mut fresh = SessionCache::new();
        let (rf, _) = fresh.checkout(
            &c,
            &a,
            &coarsening,
            HierarchyConfig::default(),
            MgOpts::default(),
            &tracker,
        );
        for (t, s) in [(t0, 0), (t1, 1)] {
            let d = done.iter().find(|d| d.ticket == t).unwrap();
            assert_eq!(d.verdict, Verdict::Healthy);
            assert!(d.result.converged);
            let mut x_solo = DistVec::zeros(layout.clone(), c.rank());
            let res_solo = pcg(&c, &op, &rhs(s), &mut x_solo, Some(rf.pc()), RTOL, MAX_ITERS);
            assert_eq!(
                d.x.vals, x_solo.vals,
                "survivor contaminated by its malformed batch-mate"
            );
            assert_eq!(d.result.residuals, res_solo.residuals);
        }

        // exactly one failure in the live metrics
        let snap = obs::metrics::rank_take();
        let failed = snap
            .entries
            .iter()
            .find(|e| e.sub == "session" && e.name == "request.failed")
            .expect("request.failed counter registered");
        assert_eq!(failed.value, 1, "exactly one ticket failed");
    });
}

#[test]
fn poisoned_entry_rebuilds_bitwise_identical_to_fresh() {
    World::new(NP).run(|c| {
        obs::metrics::rank_begin(c.rank());
        let grids = geometric_chain(Grid3::cube(3), 3);
        let coarsening = Coarsening::Geometric { grids: grids.clone() };
        let a = grid_laplacian(grids[0], c.rank(), c.size());
        let layout = a.row_layout.clone();
        let tracker = MemTracker::new();
        let spmv = DistSpmv::new(&c, &a);
        let op = CsrOperator::new(&a, &spmv);
        let rhs = |s: usize| {
            DistVec::from_fn(layout.clone(), c.rank(), |g| {
                ((g as f64) * 0.17 + s as f64).cos()
            })
        };

        let mut cache = SessionCache::new();
        {
            let (r, hit) = cache.checkout(
                &c,
                &a,
                &coarsening,
                HierarchyConfig::default(),
                MgOpts::default(),
                &tracker,
            );
            assert!(!hit);
            let mut x = DistVec::zeros(layout.clone(), c.rank());
            let res = pcg(&c, &op, &rhs(0), &mut x, Some(r.pc()), RTOL, MAX_ITERS);
            assert!(res.converged);
        }

        // a dispatch against this hierarchy panicked: evict it as
        // untrustworthy and demand a recovery rebuild
        let key = SessionCache::key(&c, &a, HierarchyConfig::default());
        cache.poison(key);
        assert!(cache.is_poisoned(&key));
        assert_eq!(cache.entry_count(), 0, "poisoned entry must be dropped now");

        let (done, hit2);
        {
            let (r2, h2) = cache.checkout(
                &c,
                &a,
                &coarsening,
                HierarchyConfig::default(),
                MgOpts::default(),
                &tracker,
            );
            hit2 = h2;
            let mut q = RequestQueue::new(2, Duration::from_secs(3600));
            q.submit(rhs(1));
            q.submit(rhs(2));
            done = q.flush_guarded(&c, &op, Some(r2.pc()), RTOL, MAX_ITERS, &tracker);
        }
        assert!(!hit2, "a poisoned key must miss");
        assert!(
            !cache.is_poisoned(&key),
            "rebuild must clear the poison mark"
        );
        assert_eq!(cache.rebuilds, 1, "the miss was a recovery rebuild");
        assert_eq!(cache.entry_count(), 1);

        // the rebuilt server is bitwise a fresh one
        let mut fresh = SessionCache::new();
        let (rf, _) = fresh.checkout(
            &c,
            &a,
            &coarsening,
            HierarchyConfig::default(),
            MgOpts::default(),
            &tracker,
        );
        let mut qf = RequestQueue::new(2, Duration::from_secs(3600));
        qf.submit(rhs(1));
        qf.submit(rhs(2));
        let fresh_done = qf.flush_guarded(&c, &op, Some(rf.pc()), RTOL, MAX_ITERS, &tracker);
        assert_eq!(done.len(), 2);
        for (d, f) in done.iter().zip(&fresh_done) {
            assert_eq!(d.verdict, Verdict::Healthy);
            assert!(d.result.converged);
            assert_eq!(
                d.x.vals, f.x.vals,
                "rebuilt hierarchy drifted from a fresh build"
            );
            assert_eq!(d.result.residuals, f.result.residuals);
            assert_eq!(d.result.iterations, f.result.iterations);
        }

        let snap = obs::metrics::rank_take();
        let rebuilds = snap
            .entries
            .iter()
            .find(|e| e.sub == "session" && e.name == "rebuilds")
            .expect("rebuilds counter registered");
        assert_eq!(rebuilds.value, 1);
    });
}

#[test]
fn over_budget_burst_sheds_while_admitted_requests_complete() {
    World::new(NP).run(|c| {
        obs::metrics::rank_begin(c.rank());
        let grids = geometric_chain(Grid3::cube(3), 3);
        let coarsening = Coarsening::Geometric { grids: grids.clone() };
        let a = grid_laplacian(grids[0], c.rank(), c.size());
        let layout = a.row_layout.clone();
        let tracker = MemTracker::new();
        let spmv = DistSpmv::new(&c, &a);
        let op = CsrOperator::new(&a, &spmv);
        let rhs = |s: usize| {
            DistVec::from_fn(layout.clone(), c.rank(), |g| {
                ((g as f64) * 0.13 + s as f64).sin()
            })
        };

        let mut cache = SessionCache::new();
        let (r, _) = cache.checkout(
            &c,
            &a,
            &coarsening,
            HierarchyConfig::default(),
            MgOpts::default(),
            &tracker,
        );
        let mut q = RequestQueue::new(4, Duration::from_secs(3600));

        // two requests fit under a generous budget (0 = unlimited)
        let t0 = q
            .try_submit(&c, rhs(0), &tracker, 0, None)
            .expect("first request admitted");
        let t1 = q
            .try_submit(&c, rhs(1), &tracker, 0, None)
            .expect("second request admitted");
        assert_eq!(q.len(), 2);

        // the burst continues against a 1-byte budget: the projection
        // (current usage + 2x the queued and new columns) must breach it
        // and the request is shed, consuming no ticket
        let over = q
            .try_submit(&c, rhs(2), &tracker, 1, None)
            .expect_err("a 1-byte budget must shed the request");
        assert_eq!(over.budget_bytes, 1);
        assert!(
            over.projected_bytes > over.budget_bytes,
            "shed verdict must carry the breaching projection"
        );
        assert_eq!(q.len(), 2, "a shed request must not be queued");

        // the earlier tickets are unaffected: they flush and complete
        // healthy, bitwise what a fresh server would have produced
        let done = q.flush_guarded(&c, &op, Some(r.pc()), RTOL, MAX_ITERS, &tracker);
        assert_eq!(done.len(), 2);
        let mut fresh = SessionCache::new();
        let (rf, _) = fresh.checkout(
            &c,
            &a,
            &coarsening,
            HierarchyConfig::default(),
            MgOpts::default(),
            &tracker,
        );
        for (t, s) in [(t0, 0), (t1, 1)] {
            let d = done.iter().find(|d| d.ticket == t).unwrap();
            assert_eq!(d.verdict, Verdict::Healthy);
            assert!(d.result.converged);
            let mut x_solo = DistVec::zeros(layout.clone(), c.rank());
            let res_solo = pcg(&c, &op, &rhs(s), &mut x_solo, Some(rf.pc()), RTOL, MAX_ITERS);
            assert_eq!(d.x.vals, x_solo.vals);
            assert_eq!(d.result.residuals, res_solo.residuals);
        }

        let snap = obs::metrics::rank_take();
        let shed = snap
            .entries
            .iter()
            .find(|e| e.sub == "session" && e.name == "queue.shed")
            .expect("queue.shed counter registered");
        assert_eq!(shed.value, 1, "exactly one request shed");
    });
}
