//! Integration tests for the PJRT runtime: artifact loading, kernel
//! execution vs the native f64 oracle, padding semantics, and the block
//! triple product end to end on the compiled path.
//!
//! These tests require `make artifacts`; they are skipped (with a stderr
//! note) when no artifact directory exists so `cargo test` stays green in
//! a fresh checkout.

use galerkin_ptap::dist::World;
use galerkin_ptap::gen::{neutron_block_interp, neutron_block_operator, Grid3, NeutronConfig};
use galerkin_ptap::mat::dense::block_triple_product_add;
use galerkin_ptap::mem::MemTracker;
use galerkin_ptap::ptap::block::block_ptap;
use galerkin_ptap::runtime::{BlockBackend, KernelRuntime, TripleBatcher};
use galerkin_ptap::util::prng::Rng;

fn runtime_or_skip() -> Option<KernelRuntime> {
    match KernelRuntime::load_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e}");
            None
        }
    }
}

#[test]
fn artifacts_enumerate_expected_variants() {
    let Some(rt) = runtime_or_skip() else { return };
    for b in [4usize, 8, 16] {
        assert!(rt.has("block_ptap", b), "missing block_ptap b={b}");
        assert!(rt.has("block_spmv", b), "missing block_spmv b={b}");
        assert_eq!(rt.batch_of("block_ptap", b), Some(256));
    }
}

#[test]
fn kernel_matches_f64_oracle_per_block() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(31337);
    for &b in &[4usize, 8, 16] {
        let n = rt.batch_of("block_ptap", b).unwrap();
        let bb = b * b;
        let mk = |rng: &mut Rng, len: usize| -> Vec<f64> {
            (0..len).map(|_| rng.normal()).collect()
        };
        let pl = mk(&mut rng, n * bb);
        let a = mk(&mut rng, n * bb);
        let pr = mk(&mut rng, n * bb);
        let to32 = |v: &[f64]| v.iter().map(|&x| x as f32).collect::<Vec<f32>>();
        let got = rt
            .run_block_ptap(b, &to32(&pl), &to32(&a), &to32(&pr))
            .expect("kernel run");
        for k in 0..n {
            let mut want = vec![0.0f64; bb];
            block_triple_product_add(
                b,
                &pl[k * bb..(k + 1) * bb],
                &a[k * bb..(k + 1) * bb],
                &pr[k * bb..(k + 1) * bb],
                &mut want,
            );
            for (g, w) in got[k * bb..(k + 1) * bb].iter().zip(&want) {
                // f32 kernel vs f64 oracle: b^2-term dot products
                let tol = 1e-3 * (1.0 + w.abs());
                assert!(
                    ((*g as f64) - w).abs() < tol,
                    "b={b} block {k}: {} vs {}",
                    g,
                    w
                );
            }
        }
    }
}

#[test]
fn spmv_kernel_matches_oracle() {
    let Some(rt) = runtime_or_skip() else { return };
    let b = 8usize;
    let n = rt.batch_of("block_spmv", b).unwrap();
    let mut rng = Rng::new(5);
    let a: Vec<f32> = (0..n * b * b).map(|_| rng.normal() as f32).collect();
    let x: Vec<f32> = (0..n * b).map(|_| rng.normal() as f32).collect();
    let y = rt.run_block_spmv(b, &a, &x).unwrap();
    for k in 0..n {
        for i in 0..b {
            let mut want = 0.0f64;
            for j in 0..b {
                want += a[k * b * b + i * b + j] as f64 * x[k * b + j] as f64;
            }
            assert!((y[k * b + i] as f64 - want).abs() < 1e-3 * (1.0 + want.abs()));
        }
    }
}

#[test]
fn batcher_pjrt_path_handles_padding_and_multiple_chunks() {
    let Some(rt) = runtime_or_skip() else { return };
    let b = 4usize;
    let mut rng = Rng::new(9);
    let mut batcher = TripleBatcher::new(BlockBackend::Pjrt(&rt), b);
    let mk = |rng: &mut Rng| (0..b * b).map(|_| rng.normal()).collect::<Vec<f64>>();
    let total = 300; // > one 256 chunk, tail forces padding
    let mut inputs = Vec::new();
    let mut results: Vec<Option<Vec<f64>>> = vec![None; total];
    {
        let mut sink = |tag: u64, blk: &[f64]| {
            results[tag as usize] = Some(blk.to_vec());
        };
        for tag in 0..total {
            let (pl, a, pr) = (mk(&mut rng), mk(&mut rng), mk(&mut rng));
            inputs.push((pl.clone(), a.clone(), pr.clone()));
            batcher.push(&pl, &a, &pr, tag as u64, &mut sink);
        }
        batcher.flush(&mut sink);
    }
    assert_eq!(batcher.flushes, 2);
    for (k, r) in results.iter().enumerate() {
        let r = r.as_ref().expect("missing result");
        let (pl, a, pr) = &inputs[k];
        let mut want = vec![0.0f64; b * b];
        block_triple_product_add(b, pl, a, pr, &mut want);
        for (g, w) in r.iter().zip(&want) {
            assert!((g - w).abs() < 1e-3 * (1.0 + w.abs()), "block {k}");
        }
    }
}

#[test]
fn block_ptap_pjrt_equals_native_distributed() {
    let Some(_) = runtime_or_skip() else { return };
    let dir = KernelRuntime::find_dir().unwrap();
    let grid = Grid3::cube(5);
    let groups = 4usize;
    let world = World::new(3);
    let dir_ref = &dir;
    world.run(move |comm| {
        let rt = KernelRuntime::load_filtered(dir_ref, |m| {
            m.entry == "block_ptap" && m.block == groups
        })
        .unwrap();
        let cfg = NeutronConfig { grid, groups, seed: 3 };
        let a = neutron_block_operator(cfg, comm.rank(), comm.size());
        let p = neutron_block_interp(grid, groups, comm.rank(), comm.size());
        let tracker = MemTracker::new();
        let native = block_ptap(&comm, &a, &p, BlockBackend::Native, &tracker);
        let pjrt = block_ptap(&comm, &a, &p, BlockBackend::Pjrt(&rt), &tracker);
        assert_eq!(native.triples, pjrt.triples);
        let gn = native.c.to_scalar().gather_global(&comm);
        let gp = pjrt.c.to_scalar().gather_global(&comm);
        let diff = gn.max_abs_diff(&gp);
        assert!(diff < 1e-3, "diff {diff}");
    });
}

#[test]
fn jacobi_kernel_matches_oracle_and_smooths() {
    let Some(rt) = runtime_or_skip() else { return };
    let b = 8usize;
    let n = rt.batch_of("block_spmv", b).unwrap();
    let mut rng = Rng::new(12);
    // SPD-ish diagonal blocks and their inverses
    let mut dinv = vec![0.0f32; n * b * b];
    let mut ablk = vec![0.0f64; n * b * b];
    for k in 0..n {
        let raw: Vec<f64> = (0..b * b).map(|_| rng.normal()).collect();
        let mut spd = vec![0.0f64; b * b];
        for i in 0..b {
            for j in 0..b {
                let mut acc = 0.0;
                for l in 0..b {
                    acc += raw[i * b + l] * raw[j * b + l];
                }
                spd[i * b + j] = acc + if i == j { 4.0 } else { 0.0 };
            }
        }
        let inv = galerkin_ptap::mat::block_invert(b, &spd).unwrap();
        for (t, &v) in inv.iter().enumerate() {
            dinv[k * b * b + t] = v as f32;
        }
        ablk[k * b * b..(k + 1) * b * b].copy_from_slice(&spd);
    }
    let xstar: Vec<f64> = (0..n * b).map(|_| rng.normal()).collect();
    // rhs = A xstar (block-diagonal system)
    let mut rhs = vec![0.0f64; n * b];
    for k in 0..n {
        galerkin_ptap::mat::block_matvec_add(
            b,
            &ablk[k * b * b..(k + 1) * b * b],
            &xstar[k * b..(k + 1) * b],
            &mut rhs[k * b..(k + 1) * b],
        );
    }
    // iterate x <- x + w dinv (rhs - A x) through the compiled kernel
    let mut x = vec![0.0f32; n * b];
    let omega = 0.9f32;
    for _ in 0..30 {
        let mut r = vec![0.0f64; n * b];
        for k in 0..n {
            let mut ax = vec![0.0f64; b];
            galerkin_ptap::mat::block_matvec_add(
                b,
                &ablk[k * b * b..(k + 1) * b * b],
                &x[k * b..(k + 1) * b].iter().map(|&v| v as f64).collect::<Vec<_>>(),
                &mut ax,
            );
            for i in 0..b {
                r[k * b + i] = rhs[k * b + i] - ax[i];
            }
        }
        let r32: Vec<f32> = r.iter().map(|&v| v as f32).collect();
        x = rt.run_block_jacobi(b, &dinv, &r32, &x, omega).unwrap();
    }
    // error must be tiny: with exact block inverses, omega-damped Jacobi
    // on a block-diagonal system contracts geometrically
    let mut err = 0.0f64;
    for i in 0..n * b {
        err = err.max((x[i] as f64 - xstar[i]).abs());
    }
    assert!(err < 1e-3, "block-Jacobi kernel failed to converge: err {err}");
}
