//! The metrics registry is observation only: arming it around a full
//! MG-PCG solve must leave the residual histories, the comm engine's
//! message accounting (counts, bytes, size-class histogram) and the
//! memory tracker's peaks bitwise identical to a disarmed run.  The comm
//! snapshot is captured BEFORE the collective merge round — the snapshot
//! allgather itself sends messages and must never leak into the
//! comparison.

use galerkin_ptap::dist::{CommStats, CsrOperator, DistSpmv, DistVec, World};
use galerkin_ptap::gen::{grid_laplacian, Grid3};
use galerkin_ptap::mem::MemTracker;
use galerkin_ptap::mg::{
    build_hierarchy, geometric_chain, pcg, Coarsening, HierarchyConfig, MgOpts, MgPreconditioner,
};
use galerkin_ptap::obs;

const NP: usize = 4;

fn run(metrics: bool) -> Vec<(Vec<f64>, CommStats, u64, Option<obs::metrics::MetricsSnapshot>)> {
    World::new(NP).run(move |c| {
        if metrics {
            obs::metrics::rank_begin(c.rank());
        }
        let grids = geometric_chain(Grid3::cube(3), 3);
        let tracker = MemTracker::new();
        let a0 = grid_laplacian(grids[0], c.rank(), c.size());
        let layout = a0.row_layout.clone();
        let h = build_hierarchy(
            &c,
            a0.clone(),
            &Coarsening::Geometric { grids: grids.clone() },
            HierarchyConfig::default(),
            &tracker,
        );
        let spmv = DistSpmv::new(&c, &a0);
        let op = CsrOperator::new(&a0, &spmv);
        let mut pc = MgPreconditioner::new(&c, h, MgOpts::default());
        let b = DistVec::from_fn(layout.clone(), c.rank(), |g| ((g * 13 % 7) as f64) - 3.0);
        let mut x = DistVec::zeros(layout, c.rank());
        let res = pcg(&c, &op, &b, &mut x, Some(&mut pc), 1e-8, 60);
        assert!(res.converged);
        // capture comm accounting BEFORE disarming: rank_take is local,
        // but any merge collective after this point would add traffic
        let stats = c.stats_global();
        let snap = if metrics { Some(obs::metrics::rank_take()) } else { None };
        (res.residuals, stats, tracker.peak_total(), snap)
    })
}

#[test]
fn armed_metrics_leave_numerics_and_accounting_bitwise_identical() {
    let off = run(false);
    let on = run(true);
    for (rank, (o, n)) in off.iter().zip(&on).enumerate() {
        assert_eq!(o.0.len(), n.0.len(), "rank {rank}: iteration counts differ");
        for (i, (a, b)) in o.0.iter().zip(&n.0).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "rank {rank} residual {i}: {a} vs {b}"
            );
        }
        assert_eq!(
            (o.1.msgs, o.1.bytes),
            (n.1.msgs, n.1.bytes),
            "rank {rank}: metrics must not change message accounting"
        );
        assert_eq!(
            o.1.hist, n.1.hist,
            "rank {rank}: metrics must not change the size-class histogram"
        );
        assert_eq!(
            o.1.close_waits, n.1.close_waits,
            "rank {rank}: metrics must not add or drop epoch barriers"
        );
        assert_eq!(o.2, n.2, "rank {rank}: metrics must not change tracker peaks");
        // the armed run did register real series across subsystems
        let snap = n.3.as_ref().expect("armed run returns a snapshot");
        assert!(!snap.entries.is_empty(), "rank {rank}: armed run registered nothing");
        for (sub, name) in
            [("mg", "cycles"), ("solve", "pcg.iters"), ("comm", "msgs.exchange")]
        {
            assert!(
                snap.entries.iter().any(|e| e.sub == sub && e.name == name),
                "rank {rank}: expected series {sub}/{name} in {:?}",
                snap.entries.iter().map(|e| format!("{}/{}", e.sub, e.name)).collect::<Vec<_>>()
            );
        }
        // span-fed stage histograms registered without tracing armed
        assert!(
            snap.entries.iter().any(|e| e.sub == "mg" && e.name == "smooth.pre"),
            "rank {rank}: cycle-stage spans must feed metrics histograms"
        );
    }
    // the disarmed run must hand back nothing
    assert!(off.iter().all(|r| r.3.is_none()));
}

/// Every rank folds the allgathered snapshots in rank order, so the
/// merged JSONL snapshot line is identical on every rank and passes the
/// self-contained schema checker.
#[test]
fn merged_snapshot_renders_identical_valid_jsonl_on_every_rank() {
    let lines = World::new(NP).run(|c| {
        obs::metrics::rank_begin(c.rank());
        obs::metrics::add(obs::Subsys::Session, "requests", (c.rank() + 1) as u64);
        obs::metrics::observe(obs::Subsys::Mg, "work_us", 10 * (c.rank() as u64 + 1));
        let snap = obs::metrics::rank_take();
        let merged = obs::metrics::merge_global(&c, &snap);
        assert_eq!(merged.ranks, NP);
        merged.jsonl_line(1, 123)
    });
    for w in lines.windows(2) {
        assert_eq!(w[0], w[1], "merged snapshot must not depend on the rank");
    }
    let check = obs::metrics::validate_stats_jsonl(&lines[0]).expect("schema-valid line");
    assert_eq!(check.lines, 1);
    assert!(check.metrics >= 2, "both series must survive the merge");
    assert!(lines[0].contains("\"requests\""));
    assert!(lines[0].contains("\"work_us\""));
}
