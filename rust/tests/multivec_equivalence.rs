//! Blocked K-wide MG-PCG ≡ K sequential scalar solves (DESIGN.md §11).
//!
//! Every K-wide kernel accumulates each column independently in the same
//! ascending-global-column fold order the scalar path uses, and the
//! coarsest direct solve routes both widths through the shared batched
//! back-substitution — so column `j` of a blocked solve must be *bitwise*
//! identical to the `j`-th single-RHS solve: same solution bits, same
//! residual history, same iteration count.  These tests pin that
//! equivalence for all three triple-product algorithms, with and without
//! coarse-level telescoping, across rank counts, on a partition with an
//! empty rank, and for the degenerate K = 1 batch.

use galerkin_ptap::dist::{
    CsrOperator, DistCsr, DistCsrBuilder, DistMultiVec, DistSpmv, DistVec, Layout, World,
};
use galerkin_ptap::gen::{grid_laplacian, Grid3};
use galerkin_ptap::mem::MemTracker;
use galerkin_ptap::mg::{
    build_hierarchy, geometric_chain, pcg, pcg_multi, AggregateOpts, Coarsening, HierarchyConfig,
    MgOpts, MgPreconditioner, SolveResult,
};
use galerkin_ptap::ptap::{Algo, ALL_ALGOS};

/// Distinct deterministic right-hand side per request slot `s`.
fn rhs(layout: &Layout, rank: usize, s: usize) -> DistVec {
    DistVec::from_fn(layout.clone(), rank, move |g| {
        (((g * 13 + s * 29 + 7) % 41) as f64 - 20.0) / 20.0
    })
}

struct Outcome {
    xs: Vec<Vec<u64>>,
    results: Vec<SolveResult>,
}

/// Solve the K slot right-hand sides one at a time (scalar path) and as
/// one blocked dispatch, against the same operator and preconditioner.
fn solve_both(
    comm: &galerkin_ptap::dist::Comm,
    op: &CsrOperator<'_>,
    pc: &mut MgPreconditioner,
    layout: &Layout,
    kk: usize,
) -> (Outcome, Outcome) {
    let mut seq = Outcome { xs: Vec::new(), results: Vec::new() };
    for s in 0..kk {
        let b = rhs(layout, comm.rank(), s);
        let mut x = DistVec::zeros(layout.clone(), comm.rank());
        let res = pcg(comm, op, &b, &mut x, Some(&mut *pc), 1e-10, 120);
        seq.xs.push(x.vals.iter().map(|v| v.to_bits()).collect());
        seq.results.push(res);
    }
    let cols: Vec<DistVec> = (0..kk).map(|s| rhs(layout, comm.rank(), s)).collect();
    let refs: Vec<&DistVec> = cols.iter().collect();
    let b = DistMultiVec::from_columns(&refs);
    let mut x = DistMultiVec::zeros(layout.clone(), comm.rank(), kk);
    let results = pcg_multi(comm, op, &b, &mut x, Some(pc), 1e-10, 120);
    let xs = (0..kk)
        .map(|j| x.column(j).vals.iter().map(|v| v.to_bits()).collect())
        .collect();
    (seq, Outcome { xs, results })
}

fn assert_column_bitwise(tag: &str, seq: &Outcome, blocked: &Outcome) {
    assert_eq!(seq.xs.len(), blocked.xs.len(), "{tag}: batch width diverged");
    for (s, (u, v)) in seq.xs.iter().zip(blocked.xs.iter()).enumerate() {
        assert_eq!(u, v, "{tag}: column {s} solution bits diverged from the scalar solve");
    }
    for (s, (u, v)) in seq.results.iter().zip(blocked.results.iter()).enumerate() {
        assert_eq!(
            u.residuals.len(),
            v.residuals.len(),
            "{tag}: column {s} residual history length diverged"
        );
        for (k, (a, b)) in u.residuals.iter().zip(v.residuals.iter()).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{tag}: column {s} residual {k} differs (scalar {a:e} vs blocked {b:e})"
            );
        }
        assert_eq!(u.iterations, v.iterations, "{tag}: column {s} iteration counts diverged");
        assert_eq!(u.converged, v.converged, "{tag}: column {s} convergence flags diverged");
        assert!(u.converged, "{tag}: column {s} scalar baseline must converge");
    }
}

/// Geometric-chain scenario: build, solve both ways, compare bitwise.
fn run_geometric(algo: Algo, eq_limit: Option<usize>, np: usize, levels: usize, kk: usize) {
    let tag = format!("{}/eq={eq_limit:?}/np={np}/k={kk}", algo.name());
    let grids = geometric_chain(Grid3::cube(3), levels);
    let world = World::new(np);
    world.run(|comm| {
        let tracker = MemTracker::new();
        let a0 = grid_laplacian(grids[0], comm.rank(), comm.size());
        let cfg = HierarchyConfig { algo, eq_limit, ..HierarchyConfig::default() };
        let h = build_hierarchy(
            &comm,
            a0.clone(),
            &Coarsening::Geometric { grids: grids.clone() },
            cfg,
            &tracker,
        );
        let spmv = DistSpmv::new(&comm, &a0);
        let op = CsrOperator::new(&a0, &spmv);
        let mut pc = MgPreconditioner::new(&comm, h, MgOpts::default());
        let layout = a0.row_layout.clone();
        let (seq, blocked) = solve_both(&comm, &op, &mut pc, &layout, kk);
        assert_column_bitwise(&tag, &seq, &blocked);
    });
}

#[test]
fn blocked_solve_matches_sequential_for_all_algorithms_and_telescoping() {
    // 3³→9³ chain on 4 ranks; eq_limit 16 telescopes the 27-row coarsest
    // level onto fewer ranks, so both coarse-solve paths are covered
    for algo in ALL_ALGOS {
        for eq_limit in [None, Some(16)] {
            run_geometric(algo, eq_limit, 4, 3, 3);
        }
    }
}

#[test]
fn blocked_solve_matches_across_rank_counts() {
    for np in [1, 2, 4] {
        run_geometric(Algo::AllAtOnce, None, np, 2, 3);
    }
}

#[test]
fn k1_blocked_solve_degenerates_to_scalar() {
    run_geometric(Algo::AllAtOnce, None, 2, 3, 1);
}

/// 1D Laplacian stiffened to strict diagonal dominance, assembled on an
/// arbitrary `Layout::from_counts` partition (SPD for any layout).
fn line_laplacian(rank: usize, rl: &Layout) -> DistCsr {
    let n = rl.global_size();
    let mut b = DistCsrBuilder::new(rank, rl.clone(), rl.clone());
    for gi in rl.range(rank) {
        let mut entries: Vec<(u64, f64)> = Vec::new();
        if gi > 0 {
            entries.push((gi as u64 - 1, -1.0));
        }
        entries.push((gi as u64, 2.25));
        if gi + 1 < n {
            entries.push((gi as u64 + 1, -1.0));
        }
        b.push_row(&entries);
    }
    b.finish()
}

#[test]
fn blocked_solve_matches_on_empty_rank_layout() {
    // rank 0 owns no rows at all: the K-wide halo exchange, smoothers and
    // telescope gather must all tolerate zero-length local blocks
    let rl = Layout::from_counts(&[0, 40, 24]);
    let world = World::new(3);
    world.run(|comm| {
        let tracker = MemTracker::new();
        let a0 = line_laplacian(comm.rank(), &rl);
        if comm.rank() == 0 {
            assert_eq!(a0.diag.nrows(), 0, "rank 0 must be the empty rank");
        }
        let h = build_hierarchy(
            &comm,
            a0.clone(),
            &Coarsening::Aggregation {
                opts: AggregateOpts::default(),
                min_rows: 8,
                max_levels: 4,
            },
            HierarchyConfig { eq_limit: Some(16), ..HierarchyConfig::default() },
            &tracker,
        );
        assert!(h.n_levels() >= 2, "aggregation must coarsen the line");
        let spmv = DistSpmv::new(&comm, &a0);
        let op = CsrOperator::new(&a0, &spmv);
        let mut pc = MgPreconditioner::new(&comm, h, MgOpts::default());
        let (seq, blocked) = solve_both(&comm, &op, &mut pc, &rl, 3);
        assert_column_bitwise("empty-rank", &seq, &blocked);
    });
}
