//! Matrix-free fine level ≡ assembled fine level (DESIGN.md §10).
//!
//! The stencil operator stores its entries in ascending linearized-delta
//! order, which coincides with ascending global column order — the same
//! fold order `DistSpmv` uses — so an MG-PCG solve whose level 0 is a
//! [`StencilOperator`] must produce a *bitwise* identical residual
//! history to one whose level 0 is the assembled `DistCsr`.  These tests
//! pin that equivalence for the 7-point grid Laplacian and the
//! backward-Euler heat operator, with and without coarse-level
//! telescoping, and check the matrix-free build actually shrinks level-0
//! operator storage to the stencil footprint.

use galerkin_ptap::dist::{CsrOperator, DistOperator, DistSpmv, DistVec, World};
use galerkin_ptap::gen::{grid_laplacian, heat_operator, Grid3, StencilOperator};
use galerkin_ptap::mem::{Cat, MemTracker};
use galerkin_ptap::mg::{
    build_hierarchy, build_hierarchy_matrix_free, geometric_chain, pcg, Coarsening,
    HierarchyConfig, MgOpts, MgPreconditioner, OpHandle,
};

struct SolveOutcome {
    residuals: Vec<f64>,
    iterations: usize,
    converged: bool,
    /// Global fine-operator storage (CSR tables + SpMV plan, or stencil
    /// coefficients + footprint halo plan).
    op_bytes: u64,
    /// Tracked bytes alive after the hierarchy build (max rank) — the
    /// scratch `A₀` assembly must already be freed here.
    cur_bytes: u64,
    halo_reuses: u64,
}

/// Build the geometric hierarchy (assembled or matrix-free fine level),
/// run MG-PCG against the matching external fine operator, and report
/// the residual history plus the storage evidence.
fn mg_solve(
    scenario: &str,
    mf: bool,
    coarse: Grid3,
    levels: usize,
    np: usize,
    eq_limit: Option<usize>,
) -> SolveOutcome {
    let dt = 0.05;
    let world = World::new(np);
    let grids = geometric_chain(coarse, levels);
    let mut per_rank = world.run(|comm| {
        let (rank, size) = (comm.rank(), comm.size());
        let fine = grids[0];
        let tracker = MemTracker::new();
        let coarsening = Coarsening::Geometric { grids: grids.clone() };
        let cfg = HierarchyConfig { eq_limit, ..HierarchyConfig::default() };
        // external fine operator for pcg (the hierarchy holds its own
        // level-0 copy either way)
        let mut sten = None;
        let mut assembled = None;
        let h = if mf {
            let s0 = match scenario {
                "grid" => StencilOperator::laplacian(&comm, fine),
                _ => StencilOperator::heat(&comm, fine, dt),
            };
            tracker.alloc(Cat::MatA, DistOperator::bytes(&s0));
            sten = Some(match scenario {
                "grid" => StencilOperator::laplacian(&comm, fine),
                _ => StencilOperator::heat(&comm, fine, dt),
            });
            build_hierarchy_matrix_free(&comm, s0, &coarsening, cfg, &tracker)
        } else {
            let a0 = match scenario {
                "grid" => grid_laplacian(fine, rank, size),
                _ => heat_operator(fine, rank, size, dt),
            };
            tracker.alloc(Cat::MatA, a0.bytes());
            let h = build_hierarchy(&comm, a0.clone(), &coarsening, cfg, &tracker);
            let spmv = DistSpmv::new(&comm, &a0);
            assembled = Some((a0, spmv));
            h
        };
        let op: OpHandle<'_> = match (&sten, &assembled) {
            (Some(s), _) => OpHandle::Stencil(s),
            (_, Some((a, spmv))) => OpHandle::Csr(CsrOperator::new(a, spmv)),
            _ => unreachable!(),
        };
        let layout = op.row_layout().clone();
        let local_op_bytes = match &assembled {
            Some((a, spmv)) => a.bytes() + spmv.bytes(),
            None => DistOperator::bytes(sten.as_ref().unwrap()),
        };
        let op_bytes = comm.allreduce_sum_u64(local_op_bytes);
        let mut pc = MgPreconditioner::new(&comm, h, MgOpts::default());
        let b = DistVec::from_fn(layout.clone(), rank, |g| ((g % 23) as f64 - 11.0) / 11.0);
        let mut x = DistVec::zeros(layout, rank);
        let res = pcg(&comm, &op, &b, &mut x, Some(&mut pc), 1e-10, 80);
        let halo_reuses = comm.allreduce_sum_u64(op.halo_reuses() + pc.halo_reuses());
        (
            res.residuals,
            res.iterations,
            res.converged,
            op_bytes,
            tracker.current_total(),
            halo_reuses,
        )
    });
    let cur_bytes = per_rank.iter().map(|r| r.4).max().unwrap();
    let (residuals, iterations, converged, op_bytes, _, halo_reuses) = per_rank.remove(0);
    SolveOutcome { residuals, iterations, converged, op_bytes, cur_bytes, halo_reuses }
}

fn assert_bitwise(tag: &str, csr: &SolveOutcome, mf: &SolveOutcome) {
    assert_eq!(
        csr.residuals.len(),
        mf.residuals.len(),
        "{tag}: residual history length diverged (csr {} vs mf {})",
        csr.residuals.len(),
        mf.residuals.len()
    );
    for (k, (u, v)) in csr.residuals.iter().zip(mf.residuals.iter()).enumerate() {
        assert_eq!(
            u.to_bits(),
            v.to_bits(),
            "{tag}: residual {k} differs between csr ({u:e}) and mf ({v:e})"
        );
    }
    assert_eq!(csr.iterations, mf.iterations, "{tag}: iteration counts diverged");
    assert_eq!(csr.converged, mf.converged, "{tag}: convergence flags diverged");
}

fn assert_memory_savings(tag: &str, csr: &SolveOutcome, mf: &SolveOutcome) {
    // stencil storage is O(coefficients + halo plan), not O(nnz): demand
    // a wide margin, not a few stray bytes
    assert!(
        mf.op_bytes * 4 < csr.op_bytes,
        "{tag}: matrix-free fine operator should be >4x smaller \
         (mf {} bytes vs csr {} bytes)",
        mf.op_bytes,
        csr.op_bytes
    );
    assert!(
        mf.cur_bytes < csr.cur_bytes,
        "{tag}: tracked bytes after build should drop without a level-0 CSR \
         (mf {} vs csr {})",
        mf.cur_bytes,
        csr.cur_bytes
    );
    assert!(mf.halo_reuses > 0, "{tag}: persistent halo buffers never reused");
}

#[test]
fn grid_matrix_free_solve_is_bit_identical() {
    let coarse = Grid3::cube(3);
    let csr = mg_solve("grid", false, coarse, 3, 4, None);
    let mf = mg_solve("grid", true, coarse, 3, 4, None);
    assert!(csr.converged, "grid: baseline solve must converge");
    assert_bitwise("grid", &csr, &mf);
    assert_memory_savings("grid", &csr, &mf);
}

#[test]
fn heat_matrix_free_solve_is_bit_identical() {
    let coarse = Grid3::cube(3);
    let csr = mg_solve("heat", false, coarse, 3, 4, None);
    let mf = mg_solve("heat", true, coarse, 3, 4, None);
    assert!(csr.converged, "heat: baseline solve must converge");
    assert_bitwise("heat", &csr, &mf);
    assert_memory_savings("heat", &csr, &mf);
}

#[test]
fn matrix_free_solve_is_bit_identical_under_telescoping() {
    // coarsest 3³ = 27 rows < 16 × 4 ranks → telescopes onto 2 ranks;
    // the matrix-free fine level must not perturb the agglomerated path
    let coarse = Grid3::cube(3);
    for scenario in ["grid", "heat"] {
        let csr = mg_solve(scenario, false, coarse, 3, 4, Some(16));
        let mf = mg_solve(scenario, true, coarse, 3, 4, Some(16));
        assert_bitwise(&format!("{scenario}+eq16"), &csr, &mf);
        assert_memory_savings(&format!("{scenario}+eq16"), &csr, &mf);
    }
}

#[test]
fn matrix_free_matches_across_rank_counts() {
    // the mf/csr equivalence must hold on every np, and each np's own
    // history is deterministic — but histories may differ *across* np
    let coarse = Grid3::cube(3);
    for np in [1, 2, 4] {
        let csr = mg_solve("grid", false, coarse, 2, np, None);
        let mf = mg_solve("grid", true, coarse, 2, np, None);
        assert_bitwise(&format!("grid np={np}"), &csr, &mf);
    }
}
