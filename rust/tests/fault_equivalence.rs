//! Bitwise fault tolerance: with a deterministic fault plan armed, the
//! reliable transport (DESIGN.md §14) must recover such that every
//! pipeline output is bitwise identical to the fault-free run — the
//! triple-product operators of all three algorithms, the MG-PCG residual
//! history and solution, and the *logical* message counts (retransmits,
//! duplicates, NACKs and ACKs are protocol frames and must never leak
//! into `CommStats`).  An empty plan must be pure overhead: bitwise
//! transparent with zero recovery traffic.

use std::time::Duration;

use galerkin_ptap::dist::{CsrOperator, DistSpmv, DistVec, FaultPlan, ReliabilityStats, World};
use galerkin_ptap::gen::{grid_laplacian, Grid3};
use galerkin_ptap::mem::MemTracker;
use galerkin_ptap::mg::{
    aggregate_interp, build_hierarchy, geometric_chain, pcg, AggregateOpts, Coarsening,
    HierarchyConfig, MgOpts, MgPreconditioner,
};
use galerkin_ptap::ptap::{Ptap, ALL_ALGOS};

const RTOL: f64 = 1e-8;
const MAX_ITERS: usize = 60;

fn fnv(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h ^= u64::from(b);
        *h = h.wrapping_mul(0x100_0000_01b3);
    }
}

fn hash_u32s(h: &mut u64, v: &[u32]) {
    for &x in v {
        fnv(h, &x.to_le_bytes());
    }
}

fn hash_f64s(h: &mut u64, v: &[f64]) {
    for &x in v {
        fnv(h, &x.to_bits().to_le_bytes());
    }
}

struct Run {
    /// One fingerprint per rank: C = PᵀAP for all three algorithms plus
    /// the MG-PCG residual history and solution bits.
    fp: Vec<u64>,
    msgs: u64,
    bytes: u64,
    rel: ReliabilityStats,
}

/// The full pipeline under `plan`: three triple products (each algorithm
/// has its own communication schedule, so together they exercise every
/// tag class), then a geometric hierarchy build and an MG-PCG solve.
fn pipeline(np: usize, plan: Option<FaultPlan>) -> Run {
    let world = World::new(np)
        .with_fault_plan(plan)
        .with_comm_timeout(Duration::from_secs(120));
    let per_rank = world.run(|comm| {
        let tracker = MemTracker::new();
        let grids = geometric_chain(Grid3::cube(3), 3);
        let a0 = grid_laplacian(grids[0], comm.rank(), comm.size());
        let p = aggregate_interp(&comm, &a0, AggregateOpts::default());
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for &algo in &ALL_ALGOS {
            let mut op = Ptap::symbolic(algo, &comm, &a0, &p, &tracker);
            op.numeric(&comm, &a0, &p);
            let c = op.extract_c();
            for m in [&c.diag, &c.offd] {
                hash_u32s(&mut h, &m.rowptr);
                hash_u32s(&mut h, &m.cols);
                hash_f64s(&mut h, &m.vals);
            }
            fnv(&mut h, &(c.garray.len() as u64).to_le_bytes());
        }
        let hier = build_hierarchy(
            &comm,
            a0.clone(),
            &Coarsening::Geometric { grids: grids.clone() },
            HierarchyConfig::default(),
            &tracker,
        );
        let spmv = DistSpmv::new(&comm, &a0);
        let op = CsrOperator::new(&a0, &spmv);
        let mut pc = MgPreconditioner::new(&comm, hier, MgOpts::default());
        let layout = a0.row_layout.clone();
        let b = DistVec::from_fn(layout.clone(), comm.rank(), |g| {
            (((g * 13) % 17) as f64 - 8.0) / 8.0
        });
        let mut x = DistVec::zeros(layout, comm.rank());
        let res = pcg(&comm, &op, &b, &mut x, Some(&mut pc), RTOL, MAX_ITERS);
        assert!(res.converged, "smoke problem must converge");
        hash_f64s(&mut h, &res.residuals);
        hash_f64s(&mut h, &x.vals);
        fnv(&mut h, &(res.iterations as u64).to_le_bytes());
        let stats = comm.stats_global();
        (h, stats.msgs, stats.bytes, comm.reliability())
    });
    let mut rel = ReliabilityStats::default();
    for r in &per_rank {
        rel.merge(r.3);
    }
    Run {
        fp: per_rank.iter().map(|r| r.0).collect(),
        msgs: per_rank.iter().map(|r| r.1).sum(),
        bytes: per_rank.iter().map(|r| r.2).sum(),
        rel,
    }
}

/// The four recoverable fault kinds the issue names, at probabilities
/// high enough that every (plan, np) pair injects faults on the pinned
/// seeds (decisions are deterministic, so this is checked, not hoped).
fn plans() -> Vec<(&'static str, String)> {
    vec![
        ("drop", "seed=101;tag=*,drop=0.15".to_string()),
        ("corrupt", "seed=102;tag=*,corrupt=0.15".to_string()),
        ("delay+reorder", "seed=103;tag=*,delay=0.3,hold=3".to_string()),
        ("duplicate", "seed=104;tag=*,dup=0.2".to_string()),
    ]
}

fn check_recovers_bitwise(np: usize) {
    let clean = pipeline(np, None);
    assert_eq!(clean.rel.faults_injected, 0, "clean run must not inject");
    assert_eq!(clean.rel.retransmits, 0, "clean run must not retransmit");
    for (name, spec) in plans() {
        let plan = FaultPlan::parse(&spec).expect(name);
        let run = pipeline(np, Some(plan));
        assert!(
            run.rel.faults_injected > 0,
            "plan {name:?} np={np} injected nothing — the test is vacuous"
        );
        assert_eq!(
            run.fp, clean.fp,
            "plan {name:?} np={np}: recovered numerics drifted from the fault-free run"
        );
        assert_eq!(
            (run.msgs, run.bytes), (clean.msgs, clean.bytes),
            "plan {name:?} np={np}: protocol frames leaked into the logical CommStats"
        );
        assert_eq!(
            run.rel.timeouts, 0,
            "plan {name:?} np={np}: a recoverable fault hit the deadline path"
        );
    }
}

#[test]
fn faulted_runs_recover_bitwise_np2() {
    check_recovers_bitwise(2);
}

#[test]
fn faulted_runs_recover_bitwise_np4() {
    check_recovers_bitwise(4);
}

#[test]
fn empty_plan_is_transparent_with_zero_recovery_traffic() {
    let clean = pipeline(2, None);
    let armed = pipeline(2, Some(FaultPlan::empty(99)));
    assert_eq!(armed.fp, clean.fp, "armed transport perturbed the numerics");
    assert_eq!((armed.msgs, armed.bytes), (clean.msgs, clean.bytes));
    assert_eq!(armed.rel.faults_injected, 0);
    assert_eq!(armed.rel.retransmits, 0, "empty plan must never retransmit");
    assert_eq!(armed.rel.corrupt_frames, 0);
    assert_eq!(armed.rel.nack_roundtrips, 0);
    assert_eq!(armed.rel.dup_suppressed, 0);
    assert_eq!(armed.rel.timeouts, 0);
}
