//! Coarse-level rank agglomeration equivalence: a telescoped hierarchy
//! must produce bit-identical coarse operators and solver residual
//! history to the full-communicator build, while paying fewer messages
//! on the telescoped levels.
//!
//! Bitwise equality is a real guarantee here, not luck: the model
//! problem's arithmetic is dyadic-exact (integer Laplacian, power-of-two
//! interpolation weights), `DistSpmv` folds rows in global column order
//! (partition-invariant), and the coarsest direct solve assembles the
//! gathered operator and right-hand side in global order on every rank.

use galerkin_ptap::dist::{CsrOperator, DistSpmv, DistVec, World};
use galerkin_ptap::gen::{grid_laplacian, Grid3};
use galerkin_ptap::mat::Csr;
use galerkin_ptap::mem::MemTracker;
use galerkin_ptap::mg::{
    build_hierarchy, geometric_chain, pcg, Coarsening, HierarchyConfig, MgOpts,
    MgPreconditioner,
};
use galerkin_ptap::ptap::Algo;

/// Build a geometric hierarchy + MG-CG solve on `np` ranks; returns
/// rank 0's view: residual bits, the gathered coarsest operator, the
/// active-rank counts, and per-level build messages.
fn run_case(
    np: usize,
    levels: usize,
    algo: Algo,
    eq_limit: Option<usize>,
    omega: Option<f64>,
) -> (Vec<u64>, Csr, Vec<usize>, Vec<u64>) {
    let grids = geometric_chain(Grid3::cube(3), levels);
    let w = World::new(np);
    let mut out = w.run(|comm| {
        let tracker = MemTracker::new();
        let a0 = grid_laplacian(grids[0], comm.rank(), comm.size());
        let h = build_hierarchy(
            &comm,
            a0.clone(),
            &Coarsening::Geometric { grids: grids.clone() },
            HierarchyConfig { algo, cache: false, numeric_repeats: 1, eq_limit, retain: false },
            &tracker,
        );
        let active = h.active_ranks.clone();
        let level_msgs: Vec<u64> = h.level_comm.iter().map(|c| c.msgs).collect();
        // gather the coarsest operator inside its own communicator scope
        // (only ranks that hold it participate; rank 0 always does)
        let coarsest = if h.levels.last().unwrap().p.is_none() {
            let ccomm = h
                .levels
                .iter()
                .filter_map(|l| l.telescope.as_ref())
                .fold(None, |acc, tel| tel.subcomm.clone().or(acc))
                .unwrap_or_else(|| comm.clone());
            Some(h.levels.last().unwrap().a.csr().gather_global(&ccomm))
        } else {
            None
        };
        let spmv = DistSpmv::new(&comm, &a0);
        let mut pc = MgPreconditioner::new(&comm, h, MgOpts { omega, ..MgOpts::default() });
        let layout = a0.row_layout.clone();
        let b = DistVec::from_fn(layout.clone(), comm.rank(), |g| ((g % 13) as f64) - 6.0);
        let mut x = DistVec::zeros(layout, comm.rank());
        let op = CsrOperator::new(&a0, &spmv);
        let res = pcg(&comm, &op, &b, &mut x, Some(&mut pc), 1e-10, 40);
        let bits: Vec<u64> = res.residuals.iter().map(|r| r.to_bits()).collect();
        (bits, coarsest, active, level_msgs)
    });
    let (bits, coarsest, active, level_msgs) = out.remove(0);
    (bits, coarsest.expect("rank 0 must hold the coarsest level"), active, level_msgs)
}

#[test]
fn single_boundary_telescope_is_bit_identical() {
    // 3 levels: 729 / 125 / 27 rows on 4 ranks.  eq_limit 64 telescopes
    // level 1's product onto 2 ranks (125 < 64×4, ⌈125/64⌉ = 2), so the
    // coarsest level lives on the subcommunicator; everything the solver
    // touches above the boundary is identical, and the coarse work is
    // partition-invariant — bits must not move.
    for algo in [Algo::AllAtOnce, Algo::Merged, Algo::TwoStep] {
        let (bits0, coarse0, active0, msgs0) = run_case(4, 3, algo, None, None);
        let (bits1, coarse1, active1, msgs1) = run_case(4, 3, algo, Some(64), None);
        assert_eq!(active0, vec![4, 4, 4], "{algo:?} baseline active ranks");
        assert_eq!(active1, vec![4, 4, 2], "{algo:?} telescoped active ranks");
        assert_eq!(coarse0, coarse1, "{algo:?}: coarse operator bits moved");
        assert_eq!(bits0, bits1, "{algo:?}: residual history bits moved");
        // the telescoped level build pays fewer messages than the
        // all-ranks build of the same level
        assert!(
            msgs1[1] < msgs0[1],
            "{algo:?}: telescoped level msgs {} !< full msgs {}",
            msgs1[1],
            msgs0[1]
        );
    }
}

#[test]
fn gather_to_root_telescope_is_bit_identical() {
    // eq_limit 200 collapses level 1 (125 rows) onto a single rank —
    // the k = 1 gather-to-root case; zero remote messages below the
    // boundary.
    let (bits0, coarse0, _, msgs0) = run_case(4, 3, Algo::AllAtOnce, None, None);
    let (bits1, coarse1, active1, msgs1) = run_case(4, 3, Algo::AllAtOnce, Some(200), None);
    assert_eq!(active1, vec![4, 4, 1]);
    assert_eq!(coarse0, coarse1, "coarse operator bits moved");
    assert_eq!(bits0, bits1, "residual history bits moved");
    assert_eq!(msgs1[1], 0, "a single active rank sends no PtAP messages");
    assert!(msgs0[1] > 0);
}

#[test]
fn nested_telescope_matches_to_rounding_with_fixed_omega() {
    // 4 levels: 729 / 125 / 27 / 8 rows.  eq_limit 64 telescopes twice
    // (level 1 → 2 ranks, level 2 → 1 rank).  Level 2 now smooths and
    // *restricts* on a different partition than the baseline: the
    // sorted-merge SpMV and fixed ω keep the sweeps bit-identical, and
    // the dyadic-exact operators keep both PtAP products bitwise equal —
    // but restriction's scatter accumulates in partition-dependent order
    // (see mg::transfer docs), so the solve trajectories agree to
    // rounding, not bits.
    let omega = Some(0.75);
    let (bits0, coarse0, active0, _) = run_case(4, 4, Algo::AllAtOnce, None, omega);
    let (bits1, coarse1, active1, _) = run_case(4, 4, Algo::AllAtOnce, Some(64), omega);
    assert_eq!(active0, vec![4, 4, 4, 4]);
    assert_eq!(active1, vec![4, 4, 2, 1]);
    assert_eq!(coarse0, coarse1, "coarse operator bits moved");
    let r0: Vec<f64> = bits0.iter().map(|&b| f64::from_bits(b)).collect();
    let r1: Vec<f64> = bits1.iter().map(|&b| f64::from_bits(b)).collect();
    assert!(
        (r0.len() as i64 - r1.len() as i64).abs() <= 1,
        "iteration counts diverged: {} vs {}",
        r0.len(),
        r1.len()
    );
    for (i, (a, b)) in r0.iter().zip(&r1).enumerate() {
        let scale = a.abs().max(b.abs()).max(f64::MIN_POSITIVE);
        assert!(
            (a - b).abs() <= 1e-7 * scale,
            "iter {i}: residuals diverged beyond rounding: {a} vs {b}"
        );
    }
    // both converge to the same tolerance
    assert!(r0.last().unwrap() / r0[0] < 1e-10);
    assert!(r1.last().unwrap() / r1[0] < 1e-10);
}

#[test]
fn aggregation_hierarchy_telescopes_and_converges() {
    // Algebraic coarsening produces irregular `from_counts` coarse
    // layouts (zero-row ranks included); telescoping them must build and
    // solve without deadlock, with active ranks non-increasing.  The
    // eq_limit is derived from a baseline build so a telescopable level
    // is guaranteed regardless of the aggregation rate.
    use galerkin_ptap::mg::AggregateOpts;
    let np = 4;
    let coarsening = Coarsening::Aggregation {
        opts: AggregateOpts::default(),
        min_rows: 8,
        max_levels: 10,
    };
    let build = |eq_limit: Option<usize>| {
        let w = World::new(np);
        let mut out = w.run(|comm| {
            let tracker = MemTracker::new();
            let a0 = grid_laplacian(Grid3::cube(8), comm.rank(), comm.size());
            let cfg = HierarchyConfig {
                algo: Algo::AllAtOnce,
                cache: false,
                numeric_repeats: 1,
                eq_limit,
                retain: false,
            };
            let h = build_hierarchy(&comm, a0, &coarsening, cfg, &tracker);
            (
                h.active_ranks.clone(),
                h.op_stats.iter().map(|s| s.rows).collect::<Vec<u64>>(),
            )
        });
        out.remove(0)
    };
    let (base_active, base_rows) = build(None);
    assert!(base_active.iter().all(|&a| a == np));
    assert!(base_rows.len() >= 3, "need a multi-level hierarchy: {base_rows:?}");
    // the last level built through a PtAP qualifies when eq_limit equals
    // its fine rows (k = 1 there, possibly earlier elsewhere)
    let eq = base_rows[base_rows.len() - 2] as usize;
    let (active, rows) = build(Some(eq));
    assert_eq!(active.len(), rows.len());
    assert_eq!(active[0], np);
    for w in active.windows(2) {
        assert!(w[1] <= w[0], "active ranks must not grow: {active:?}");
    }
    assert!(
        *active.last().unwrap() < np,
        "a level with {eq} rows at eq_limit {eq} must telescope: {active:?}"
    );
}

#[test]
fn full_collapse_neutron_solve_converges() {
    // A huge eq_limit collapses the hierarchy onto one rank right below
    // the finest level — the extreme telescope — and the end-to-end
    // GMRES solve must still converge on irregular aggregation layouts.
    use galerkin_ptap::coordinator::{run_neutron, NeutronConfigExp};
    let r = run_neutron(NeutronConfigExp {
        grid: Grid3::cube(6),
        groups: 4,
        np: 4,
        algo: Algo::AllAtOnce,
        cache: false,
        max_levels: 8,
        solve_iters: 40,
        eq_limit: Some(10_000),
    });
    assert!(r.n_levels >= 3);
    assert_eq!(r.active_ranks.len(), r.n_levels);
    assert_eq!(r.active_ranks[0], 4);
    assert!(
        r.active_ranks[1..].iter().all(|&a| a == 1),
        "everything under the finest level collapses to rank 0: {:?}",
        r.active_ranks
    );
    let r0 = r.residuals.first().copied().unwrap();
    let rl = r.residuals.last().copied().unwrap();
    assert!(rl < 1e-6 * r0, "telescoped solve stalled: {r0} -> {rl}");
}
