//! Integration tests over the whole PtAP stack at paper-shaped scale:
//! correctness across algorithms and rank counts, the memory-ratio claims,
//! scaling behaviour, and the simulated Table-3 OOM row.

use galerkin_ptap::dist::World;
use galerkin_ptap::gen::{Grid3, ModelProblem};
use galerkin_ptap::mem::MemTracker;
use galerkin_ptap::ptap::{ptap_once, seq_ptap_reference, Algo, Ptap, ALL_ALGOS};

/// All algorithms × rank counts produce the sequential reference on the
/// model problem.
#[test]
fn model_problem_all_algos_match_reference() {
    let coarse = Grid3::cube(5);
    let mut reference: Option<galerkin_ptap::mat::Csr> = None;
    for np in [1, 2, 3, 4] {
        for algo in ALL_ALGOS {
            let world = World::new(np);
            let got = world
                .run(|comm| {
                    let mp = ModelProblem::build(coarse, comm.rank(), comm.size());
                    let tracker = MemTracker::new();
                    let (c, _) = ptap_once(algo, &comm, &mp.a, &mp.p, &tracker);
                    let cg = c.gather_global(&comm);
                    let (ag, pg) = (mp.a.gather_global(&comm), mp.p.gather_global(&comm));
                    (cg, ag, pg)
                })
                .remove(0);
            let want = reference.get_or_insert_with(|| seq_ptap_reference(&got.1, &got.2));
            let diff = got.0.max_abs_diff(want);
            assert!(diff < 1e-10, "np={np} {}: diff {diff}", algo.name());
        }
    }
}

/// Galerkin invariant: PᵀAP of a symmetric A is symmetric.
#[test]
fn coarse_operator_is_symmetric() {
    let world = World::new(3);
    world.run(|comm| {
        let mp = ModelProblem::build(Grid3::cube(6), comm.rank(), comm.size());
        let tracker = MemTracker::new();
        let (c, _) = ptap_once(Algo::AllAtOnce, &comm, &mp.a, &mp.p, &tracker);
        let g = c.gather_global(&comm);
        assert!(g.max_abs_diff(&g.transpose()) < 1e-11);
    });
}

/// The paper's memory claim at integration scale: two-step needs several
/// times the all-at-once product memory, and the gap does NOT shrink with
/// more ranks (Tables 1–4).
#[test]
fn memory_ratio_matches_paper_shape() {
    let coarse = Grid3::cube(16);
    let mut ratios = Vec::new();
    for np in [2, 4] {
        let world = World::new(np);
        let peaks = world.run(|comm| {
            let mp = ModelProblem::build(coarse, comm.rank(), comm.size());
            let mut out = Vec::new();
            for algo in [Algo::AllAtOnce, Algo::TwoStep] {
                let tracker = MemTracker::new();
                tracker.alloc(galerkin_ptap::mem::Cat::MatA, mp.a.bytes());
                tracker.alloc(galerkin_ptap::mem::Cat::MatP, mp.p.bytes());
                tracker.reset_peaks();
                let mut op = Ptap::symbolic(algo, &comm, &mp.a, &mp.p, &tracker);
                // the paper's protocol: repeated numeric products with the
                // context retained
                for _ in 0..3 {
                    op.numeric(&comm, &mp.a, &mp.p);
                }
                out.push(tracker.peak_total() - mp.a.bytes() - mp.p.bytes());
            }
            out
        });
        let aao = peaks.iter().map(|p| p[0]).max().unwrap();
        let two = peaks.iter().map(|p| p[1]).max().unwrap();
        let ratio = two as f64 / aao as f64;
        // the paper sees 8-10x at billion-scale; at this testbed scale the
        // structural gap is ~3x and grows with problem size (next assert)
        assert!(ratio > 2.5, "np={np}: ratio only {ratio:.2}");
        ratios.push(ratio);
    }
    // ratio roughly stable across rank counts (structure-determined)
    assert!((ratios[0] - ratios[1]).abs() < 0.5 * ratios[0]);
}

/// The two-step/all-at-once memory ratio grows with problem size toward
/// the paper's asymptotic regime (C̃+Pᵀ dominate every fixed overhead).
#[test]
fn memory_ratio_grows_with_problem_size() {
    let ratio_for = |m: usize| -> f64 {
        let world = World::new(2);
        let peaks = world.run(|comm| {
            let mp = ModelProblem::build(Grid3::cube(m), comm.rank(), comm.size());
            let mut out = Vec::new();
            for algo in [Algo::AllAtOnce, Algo::TwoStep] {
                let tracker = MemTracker::new();
                let mut op = Ptap::symbolic(algo, &comm, &mp.a, &mp.p, &tracker);
                op.numeric(&comm, &mp.a, &mp.p);
                out.push(tracker.peak_total());
            }
            out
        });
        let aao = peaks.iter().map(|p| p[0]).max().unwrap();
        let two = peaks.iter().map(|p| p[1]).max().unwrap();
        two as f64 / aao as f64
    };
    let small = ratio_for(8);
    let large = ratio_for(18);
    assert!(large > small, "ratio must grow: {small:.2} -> {large:.2}");
}

/// Per-rank product memory shrinks as ranks are added (the paper's
/// "perfectly scalable in the memory usage").
#[test]
fn memory_scales_down_with_ranks() {
    let coarse = Grid3::cube(20);
    let mut mems = Vec::new();
    for np in [1, 2, 4] {
        let world = World::new(np);
        let peak = world
            .run(|comm| {
                let mp = ModelProblem::build(coarse, comm.rank(), comm.size());
                let tracker = MemTracker::new();
                let mut op = Ptap::symbolic(Algo::AllAtOnce, &comm, &mp.a, &mp.p, &tracker);
                op.numeric(&comm, &mp.a, &mp.p);
                tracker.peak_total()
            })
            .into_iter()
            .max()
            .unwrap();
        mems.push(peak);
    }
    // doubling ranks should cut per-rank memory substantially (fixed
    // per-rank overheads — scratch, plans — temper the ideal 2x)
    assert!(mems[0] as f64 > 1.6 * mems[1] as f64, "{mems:?}");
    assert!(mems[1] as f64 > 1.35 * mems[2] as f64, "{mems:?}");
}

/// The Table 3 "two-step could not run at np=8192" row, simulated with a
/// per-rank memory budget: at the small rank count the two-step method
/// exceeds a budget the all-at-once algorithm fits in; at a larger rank
/// count both fit.
#[test]
fn two_step_exceeds_budget_where_all_at_once_fits() {
    let coarse = Grid3::cube(12);
    let run = |np: usize, algo: Algo| -> u64 {
        let world = World::new(np);
        world
            .run(|comm| {
                let mp = ModelProblem::build(coarse, comm.rank(), comm.size());
                let tracker = MemTracker::new();
                let mut op = Ptap::symbolic(algo, &comm, &mp.a, &mp.p, &tracker);
                op.numeric(&comm, &mp.a, &mp.p);
                tracker.peak_total() + mp.a.bytes() + mp.p.bytes()
            })
            .into_iter()
            .max()
            .unwrap()
    };
    let aao_small = run(2, Algo::AllAtOnce);
    let two_small = run(2, Algo::TwoStep);
    let two_large = run(8, Algo::TwoStep);
    // pick the budget between: aao fits, two-step doesn't (at np=2)
    let budget = (aao_small + two_small) / 2;
    assert!(aao_small <= budget, "all-at-once must fit the node budget");
    assert!(two_small > budget, "two-step must exceed it at low np");
    assert!(two_large <= budget, "two-step must fit once ranks are added");
}

/// Numeric re-products must not change C (the 1 symbolic + 11 numeric
/// protocol) and must not grow memory.
#[test]
fn repeated_numeric_is_stable() {
    let world = World::new(4);
    world.run(|comm| {
        let mp = ModelProblem::build(Grid3::cube(6), comm.rank(), comm.size());
        for algo in ALL_ALGOS {
            let tracker = MemTracker::new();
            let mut op = Ptap::symbolic(algo, &comm, &mp.a, &mp.p, &tracker);
            op.numeric(&comm, &mp.a, &mp.p);
            let c1 = op.extract_c().gather_global(&comm);
            let peak1 = tracker.peak_total();
            for _ in 0..10 {
                op.numeric(&comm, &mp.a, &mp.p);
            }
            let c11 = op.extract_c().gather_global(&comm);
            assert_eq!(c1, c11, "{}: numeric rerun changed C", algo.name());
            let peak11 = tracker.peak_total();
            assert!(
                peak11 as f64 <= peak1 as f64 * 1.05,
                "{}: memory grew across reruns {peak1} -> {peak11}",
                algo.name()
            );
        }
    });
}

/// Symbolic preallocation is exact: the numeric phase fills every slot.
#[test]
fn preallocation_is_exact_on_model_problem() {
    let world = World::new(3);
    world.run(|comm| {
        let mp = ModelProblem::build(Grid3::cube(6), comm.rank(), comm.size());
        for algo in ALL_ALGOS {
            let tracker = MemTracker::new();
            let mut op = Ptap::symbolic(algo, &comm, &mp.a, &mp.p, &tracker);
            op.numeric(&comm, &mp.a, &mp.p);
            let fill_d = op.c.diag.fill_ratio();
            let fill_o = op.c.offd.fill_ratio();
            assert!(
                fill_d > 0.999,
                "{}: diag fill {fill_d} (symbolic overcounted)",
                algo.name()
            );
            // offd can legitimately be empty on a 1-rank run
            if op.c.offd.capacity() > 0 {
                assert!(fill_o > 0.999, "{}: offd fill {fill_o}", algo.name());
            }
        }
    });
}

/// Non-cubic grids and rank counts that do not divide the rows.
#[test]
fn irregular_shapes_and_rank_counts() {
    let coarse = Grid3 { nx: 4, ny: 3, nz: 5 };
    for np in [3, 5, 7] {
        let world = World::new(np);
        let ok = world.run(|comm| {
            let fine = coarse.refine();
            let a = galerkin_ptap::gen::grid_laplacian(fine, comm.rank(), comm.size());
            let p = galerkin_ptap::gen::trilinear_interp(coarse, comm.rank(), comm.size());
            let tracker = MemTracker::new();
            let (c, _) = ptap_once(Algo::Merged, &comm, &a, &p, &tracker);
            c.validate().is_ok()
        });
        assert!(ok.iter().all(|&x| x), "np={np}");
    }
}
