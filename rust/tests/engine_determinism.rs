//! Determinism stress for the nonblocking comm engine: interleaved
//! `isend` / `try_recv_any` / `drain` schedules with PRNG-chosen chunk
//! sizes must fold to results bit-identical to the bulk-synchronous
//! `exchange` shim.  The engine's guarantee under test: payloads are
//! released in canonical order (source rank major, send order within a
//! source) no matter how sends and receives interleave, so a float
//! accumulation folded "as messages arrive" reproduces the bulk fold
//! exactly.

use galerkin_ptap::dist::{tag, World};
use galerkin_ptap::util::bytebuf::{ByteReader, ByteWriter};
use galerkin_ptap::util::prng::Rng;

const NP: usize = 4;
const ROWS: usize = 32;
const RECORDS: usize = 400;

/// Deterministic per-rank contribution stream: (dest, local row, value).
fn contributions(rank: usize) -> Vec<(usize, u32, f64)> {
    let mut rng = Rng::new(0xC0FFEE + rank as u64 * 7919);
    (0..RECORDS)
        .map(|_| {
            let dest = rng.below(NP);
            let row = rng.below(ROWS) as u32;
            let val = rng.range_f64(-1.0, 1.0);
            (dest, row, val)
        })
        .collect()
}

/// Order-sensitive fold: float `+=` per record, in payload order.
fn fold(acc: &mut [f64], payload: &[u8]) {
    let mut r = ByteReader::new(payload);
    while !r.done() {
        let row = r.u32() as usize;
        let val = r.f64();
        acc[row] += val;
    }
}

#[test]
fn random_chunked_pipeline_matches_bulk_exchange() {
    // Bulk-synchronous reference: one payload per destination, folded in
    // the exchange's source-rank order.
    let bulk = World::new(NP).run(|c| {
        let mut writers: Vec<ByteWriter> = (0..NP).map(|_| ByteWriter::new()).collect();
        for (dest, row, val) in contributions(c.rank()) {
            writers[dest].u32(row);
            writers[dest].f64(val);
        }
        let sends: Vec<(usize, Vec<u8>)> = writers
            .into_iter()
            .enumerate()
            .filter(|(_, w)| !w.is_empty())
            .map(|(d, w)| (d, w.into_bytes()))
            .collect();
        let mut acc = vec![0.0f64; ROWS];
        for (_src, payload) in c.exchange(sends) {
            fold(&mut acc, &payload);
        }
        acc
    });

    // Engine schedules: PRNG-sized chunks posted as they fill, releases
    // folded eagerly mid-stream, a collective thrown into the open epoch,
    // the drain folding the rest.  Several seeds = several interleavings.
    for seed in [1u64, 2, 3] {
        let engine = World::new(NP).run(|c| {
            let mut rng = Rng::new(seed * 1000 + c.rank() as u64);
            let mut acc = vec![0.0f64; ROWS];
            let mut writers: Vec<ByteWriter> = (0..NP).map(|_| ByteWriter::new()).collect();
            let mut staged = [0usize; NP];
            let mut chunk = 1 + rng.below(7);
            for (dest, row, val) in contributions(c.rank()) {
                writers[dest].u32(row);
                writers[dest].f64(val);
                staged[dest] += 1;
                if staged[dest] >= chunk {
                    let w = std::mem::take(&mut writers[dest]);
                    c.isend(dest, tag::PTAP_NUM, w.into_bytes());
                    staged[dest] = 0;
                    chunk = 1 + rng.below(7);
                }
                if rng.below(5) == 0 {
                    for (_src, payload) in c.try_recv_any(tag::PTAP_NUM) {
                        fold(&mut acc, &payload);
                    }
                }
            }
            for (dest, w) in writers.into_iter().enumerate() {
                if !w.is_empty() {
                    c.isend(dest, tag::PTAP_NUM, w.into_bytes());
                }
            }
            // a collective inside the open epoch must not disturb it
            assert_eq!(c.allreduce_sum_u64(1), NP as u64);
            for (_src, payload) in c.drain(tag::PTAP_NUM) {
                fold(&mut acc, &payload);
            }
            acc
        });
        for (rank, (got, want)) in engine.iter().zip(&bulk).enumerate() {
            for (row, (g, w)) in got.iter().zip(want).enumerate() {
                assert_eq!(
                    g.to_bits(),
                    w.to_bits(),
                    "seed {seed} rank {rank} row {row}: {g} vs {w}"
                );
            }
        }
    }
}

/// Tracing is observation only: the same chunked engine schedule with a
/// per-rank recorder armed must fold bit-identically to the untraced run
/// (the send-stamp frame extension and flight recording change no
/// delivery order and no payload bytes).
#[test]
fn traced_schedule_folds_bitwise_identical_to_untraced() {
    let run = |traced: bool| {
        World::new(NP).run(move |c| {
            if traced {
                galerkin_ptap::obs::rank_begin(c.rank());
            }
            let mut rng = Rng::new(42 + c.rank() as u64);
            let mut acc = vec![0.0f64; ROWS];
            let mut writers: Vec<ByteWriter> = (0..NP).map(|_| ByteWriter::new()).collect();
            let mut staged = [0usize; NP];
            let mut chunk = 1 + rng.below(7);
            for (dest, row, val) in contributions(c.rank()) {
                writers[dest].u32(row);
                writers[dest].f64(val);
                staged[dest] += 1;
                if staged[dest] >= chunk {
                    let w = std::mem::take(&mut writers[dest]);
                    c.isend(dest, tag::PTAP_NUM, w.into_bytes());
                    staged[dest] = 0;
                    chunk = 1 + rng.below(7);
                }
                if rng.below(5) == 0 {
                    for (_src, payload) in c.try_recv_any(tag::PTAP_NUM) {
                        fold(&mut acc, &payload);
                    }
                }
            }
            for (dest, w) in writers.into_iter().enumerate() {
                if !w.is_empty() {
                    c.isend(dest, tag::PTAP_NUM, w.into_bytes());
                }
            }
            for (_src, payload) in c.drain(tag::PTAP_NUM) {
                fold(&mut acc, &payload);
            }
            let stats = c.stats_global();
            let buf = if traced {
                Some(galerkin_ptap::obs::rank_take())
            } else {
                None
            };
            (acc, stats, buf)
        })
    };
    let untraced = run(false);
    let traced = run(true);
    for (rank, ((got, ts, buf), (want, us, _))) in traced.iter().zip(&untraced).enumerate() {
        for (row, (g, w)) in got.iter().zip(want).enumerate() {
            assert_eq!(g.to_bits(), w.to_bits(), "rank {rank} row {row}: {g} vs {w}");
        }
        assert_eq!(
            (ts.msgs, ts.bytes),
            (us.msgs, us.bytes),
            "rank {rank}: tracing must not change message accounting"
        );
        let buf = buf.as_ref().unwrap();
        assert!(
            buf.events.iter().any(|e| matches!(e, galerkin_ptap::obs::Ev::Flight { .. })),
            "rank {rank}: traced run must record message flights"
        );
        assert!(ts.flight_msgs > 0, "rank {rank}: stamped frames must be observed");
        assert_eq!(us.flight_msgs, 0, "untraced senders must leave a zero stamp");
    }
}
