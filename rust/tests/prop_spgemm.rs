//! Property tests for the row-wise SpGEMM: randomized shapes, densities
//! and rank counts against a sequential oracle (our hand-rolled
//! quickcheck — proptest is unavailable offline).

use galerkin_ptap::dist::{RowGatherPlan, World};
use galerkin_ptap::gen::random_dist_csr;
use galerkin_ptap::mat::{Csr, CsrBuilder};
use galerkin_ptap::spgemm::{ApProduct, RowScratch, RowView, StampedAccumulator};
use galerkin_ptap::util::prng::Rng;

fn seq_matmul(a: &Csr, b: &Csr) -> Csr {
    let mut out = CsrBuilder::new(b.ncols);
    let mut acc: std::collections::BTreeMap<u32, f64> = Default::default();
    for i in 0..a.nrows {
        acc.clear();
        let (ac, av) = a.row(i);
        for (&k, &aval) in ac.iter().zip(av) {
            let (bc, bv) = b.row(k as usize);
            for (&j, &bval) in bc.iter().zip(bv) {
                *acc.entry(j).or_insert(0.0) += aval * bval;
            }
        }
        let cols: Vec<u32> = acc.keys().copied().collect();
        let vals: Vec<f64> = acc.values().copied().collect();
        out.push_row(&cols, &vals);
    }
    out.finish()
}

/// Randomized sweep: 30 configurations of (n, m, density, np).
#[test]
fn random_ap_products_match_oracle() {
    let mut rng = Rng::new(0xC0FFEE);
    for case in 0..30 {
        let n = 10 + rng.below(60);
        let m = 4 + rng.below(40);
        let nnz_a = 1 + rng.below(7);
        let nnz_p = 1 + rng.below(4);
        let np = 1 + rng.below(5);
        let seed_a = rng.next_u64();
        let seed_p = rng.next_u64();
        let world = World::new(np);
        let (got_rows, ag, pg) = world
            .run(|comm| {
                let a = random_dist_csr(comm.rank(), comm.size(), n, n, nnz_a, seed_a);
                let p = random_dist_csr(comm.rank(), comm.size(), n, m, nnz_p, seed_p);
                let plan = RowGatherPlan::build(&comm, &p.row_layout, &a.garray);
                let pr = plan.gather_csr(&comm, &p);
                let v = RowView::new(&a, &p, &pr);
                let mut scratch = RowScratch::default();
                let mut acc = StampedAccumulator::new(p.global_ncols());
                let mut ap = ApProduct::symbolic(v, &mut scratch);
                ap.numeric(v, &mut acc);
                // exact preallocation is an invariant, not a coincidence
                assert!((ap.mat.fill_ratio() - 1.0).abs() < 1e-12);
                let rbeg = a.row_begin();
                let mat = ap.mat.clone().finish();
                let rows: Vec<(usize, Vec<(u32, f64)>)> = (0..mat.nrows)
                    .map(|i| {
                        let (c, vv) = mat.row(i);
                        (rbeg + i, c.iter().zip(vv).map(|(&x, &y)| (x, y)).collect())
                    })
                    .collect();
                (rows, a.gather_global(&comm), p.gather_global(&comm))
            })
            .into_iter()
            .fold((vec![Vec::new(); n], None, None), |(mut acc, _, _), (rows, ag, pg)| {
                for (gi, row) in rows {
                    acc[gi] = row;
                }
                (acc, Some(ag), Some(pg))
            });
        let want = seq_matmul(&ag.unwrap(), &pg.unwrap());
        for i in 0..n {
            let (wc, wv) = want.row(i);
            assert_eq!(got_rows[i].len(), wc.len(), "case {case} row {i}");
            for (k, (&c, &v)) in wc.iter().zip(wv).enumerate() {
                assert_eq!(got_rows[i][k].0, c, "case {case} row {i}");
                assert!((got_rows[i][k].1 - v).abs() < 1e-10, "case {case} row {i}");
            }
        }
    }
}

/// Identity propagation: A * I == A for any partitioning.
#[test]
fn multiplying_by_identity_preserves() {
    let mut rng = Rng::new(77);
    for _ in 0..10 {
        let n = 8 + rng.below(40);
        let np = 1 + rng.below(4);
        let seed = rng.next_u64();
        let world = World::new(np);
        world.run(|comm| {
            let a = random_dist_csr(comm.rank(), comm.size(), n, n, 4, seed);
            // identity as a distributed matrix
            let layout = a.row_layout.clone();
            let mut b =
                galerkin_ptap::dist::DistCsrBuilder::new(comm.rank(), layout.clone(), layout);
            for gi in a.row_layout.range(comm.rank()) {
                b.push_row(&[(gi as u64, 1.0)]);
            }
            let eye = b.finish();
            let plan = RowGatherPlan::build(&comm, &eye.row_layout, &a.garray);
            let pr = plan.gather_csr(&comm, &eye);
            let v = RowView::new(&a, &eye, &pr);
            let mut scratch = RowScratch::default();
            let mut acc = StampedAccumulator::new(eye.global_ncols());
            let mut ap = ApProduct::symbolic(v, &mut scratch);
            ap.numeric(v, &mut acc);
            let got = ap.mat.clone().finish();
            let want = a.gather_global(&comm);
            // compare local slice
            let rbeg = a.row_begin();
            for i in 0..a.local_nrows() {
                let (gc, gv) = got.row(i);
                let (wc, wv) = want.row(rbeg + i);
                assert_eq!(gc, wc);
                assert_eq!(gv, wv);
            }
        });
    }
}

/// Linearity: (αA)·P == α(A·P).
#[test]
fn scaling_a_scales_product() {
    let world = World::new(3);
    world.run(|comm| {
        let n = 36;
        let a1 = random_dist_csr(comm.rank(), comm.size(), n, n, 5, 42);
        let mut a2 = a1.clone();
        for v in a2.diag.vals.iter_mut().chain(a2.offd.vals.iter_mut()) {
            *v *= 2.5;
        }
        let p = random_dist_csr(comm.rank(), comm.size(), n, 12, 2, 43);
        let product = |a: &galerkin_ptap::dist::DistCsr,
                       comm: &galerkin_ptap::dist::Comm|
         -> Csr {
            let plan = RowGatherPlan::build(comm, &p.row_layout, &a.garray);
            let pr = plan.gather_csr(comm, &p);
            let v = RowView::new(a, &p, &pr);
            let mut scratch = RowScratch::default();
            let mut acc = StampedAccumulator::new(p.global_ncols());
            let mut ap = ApProduct::symbolic(v, &mut scratch);
            ap.numeric(v, &mut acc);
            ap.mat.clone().finish()
        };
        let c1 = product(&a1, &comm);
        let c2 = product(&a2, &comm);
        for i in 0..c1.nrows {
            let (k1, v1) = c1.row(i);
            let (k2, v2) = c2.row(i);
            assert_eq!(k1, k2);
            for (a, b) in v1.iter().zip(v2) {
                assert!((a * 2.5 - b).abs() < 1e-11);
            }
        }
    });
}
