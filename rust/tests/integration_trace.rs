//! End-to-end tracing integration: a 2-rank MG-PCG solve with per-rank
//! recorders armed must merge into a schema-valid Chrome trace (balanced
//! spans per rank/subsystem, message flights, memory counter samples),
//! and tracing must be observation-only — the traced solve's residual
//! history, message accounting, and tracker bytes are identical to the
//! untraced run's.

use galerkin_ptap::dist::{CommStats, CsrOperator, DistSpmv, DistVec, World};
use galerkin_ptap::gen::{grid_laplacian, Grid3};
use galerkin_ptap::mem::MemTracker;
use galerkin_ptap::mg::{
    build_hierarchy, geometric_chain, pcg, Coarsening, HierarchyConfig, MgOpts, MgPreconditioner,
};
use galerkin_ptap::obs;

const NP: usize = 2;

/// Per-rank outcome: residual history, rank-global comm stats, peak
/// tracker bytes, and (when traced) the rank's event buffer.
type RankOutcome = (Vec<f64>, CommStats, u64, Option<obs::TraceBuffer>);

/// One MG-PCG solve on a 3-level geometric chain, on every rank.
fn solve_once(traced: bool) -> Vec<RankOutcome> {
    World::new(NP).run(move |c| {
        if traced {
            obs::rank_begin(c.rank());
        }
        let tracker = MemTracker::new();
        let grids = geometric_chain(Grid3::cube(3), 3);
        let a0 = grid_laplacian(grids[0], c.rank(), c.size());
        let layout = a0.row_layout.clone();
        let h = build_hierarchy(
            &c,
            a0.clone(),
            &Coarsening::Geometric { grids },
            HierarchyConfig::default(),
            &tracker,
        );
        let spmv = DistSpmv::new(&c, &a0);
        let op = CsrOperator::new(&a0, &spmv);
        let mut pc = MgPreconditioner::new(&c, h, MgOpts::default());
        let b = DistVec::from_fn(layout.clone(), c.rank(), |g| ((g % 11) as f64) - 5.0);
        let mut x = DistVec::zeros(layout, c.rank());
        let res = pcg(&c, &op, &b, &mut x, Some(&mut pc), 1e-8, 60);
        assert!(res.converged, "trace-test solve must converge");
        let buf = if traced { Some(obs::rank_take()) } else { None };
        (res.residuals, c.stats_global(), tracker.peak_total(), buf)
    })
}

#[test]
fn traced_solve_produces_valid_chrome_trace() {
    let ranks = solve_once(true);
    let bufs: Vec<obs::TraceBuffer> =
        ranks.iter().map(|r| r.3.clone().expect("traced rank must yield a buffer")).collect();
    assert_eq!(bufs.len(), NP);
    for (rank, buf) in bufs.iter().enumerate() {
        assert_eq!(buf.rank, rank);
        assert_eq!(buf.dropped, 0, "smoke-scale solve must fit the ring");
        // every SpanBegin has a matching SpanEnd, LIFO per subsystem
        let mut stacks: std::collections::HashMap<u32, Vec<&'static str>> =
            std::collections::HashMap::new();
        for ev in &buf.events {
            match *ev {
                obs::Ev::Begin { sub, name, .. } => stacks.entry(sub.tid()).or_default().push(name),
                obs::Ev::End { sub, name, .. } => {
                    let open = stacks.get_mut(&sub.tid()).and_then(Vec::pop);
                    assert_eq!(open, Some(name), "rank {rank}: unbalanced span {name}");
                }
                _ => {}
            }
        }
        for (tid, stack) in &stacks {
            assert!(stack.is_empty(), "rank {rank} tid {tid}: spans left open: {stack:?}");
        }
        // the solve must have produced per-level cycle spans, flights,
        // and memory counter samples on every rank
        let evs = &buf.events;
        assert!(
            evs.iter().any(|e| matches!(e, obs::Ev::Begin { name: "level", .. })),
            "rank {rank}: no V-cycle level spans"
        );
        assert!(
            evs.iter().any(|e| matches!(e, obs::Ev::Begin { name: "symbolic", .. })),
            "rank {rank}: no PtAP symbolic span"
        );
        assert!(
            evs.iter().any(|e| matches!(e, obs::Ev::Flight { .. })),
            "rank {rank}: no message flights"
        );
        assert!(
            evs.iter().any(|e| matches!(e, obs::Ev::Counter { .. })),
            "rank {rank}: no memory counter samples"
        );
    }
    // the merged artifact must validate as a Chrome trace
    let text = obs::chrome::render_chrome_trace(&bufs);
    let summary = obs::chrome::validate_chrome_trace(&text).expect("merged trace must validate");
    assert_eq!(summary.ranks, NP);
    assert!(summary.spans > 0 && summary.flights > 0 && summary.counters > 0, "{summary:?}");
}

#[test]
fn tracing_is_observation_only() {
    let untraced = solve_once(false);
    let traced = solve_once(true);
    for (rank, (t, u)) in traced.iter().zip(&untraced).enumerate() {
        assert_eq!(
            t.0, u.0,
            "rank {rank}: residual history must be bitwise identical with tracing on"
        );
        assert_eq!(
            (t.1.msgs, t.1.bytes),
            (u.1.msgs, u.1.bytes),
            "rank {rank}: tracing must not change message accounting"
        );
        assert_eq!(t.2, u.2, "rank {rank}: tracing must not change tracker bytes");
        assert!(u.3.is_none(), "untraced run must not allocate a buffer");
    }
}
