//! Integration tests for the multigrid stack built on the triple products:
//! hierarchy construction (geometric + algebraic), V-cycle solves, and the
//! neutron-analog experiment plumbing.

use galerkin_ptap::coordinator::{run_neutron, NeutronConfigExp};
use galerkin_ptap::dist::{CsrOperator, DistSpmv, DistVec, World};
use galerkin_ptap::gen::{grid_laplacian, Grid3};
use galerkin_ptap::mem::MemTracker;
use galerkin_ptap::mg::{
    build_hierarchy, geometric_chain, pcg, AggregateOpts, Coarsening, CycleType, Hierarchy,
    HierarchyConfig, MgOpts, MgPreconditioner,
};
use galerkin_ptap::ptap::{Algo, ALL_ALGOS};

fn build_geo(comm: &galerkin_ptap::dist::Comm, grids: &[Grid3], algo: Algo) -> Hierarchy {
    let a0 = grid_laplacian(grids[0], comm.rank(), comm.size());
    let tracker = MemTracker::new();
    build_hierarchy(
        comm,
        a0,
        &Coarsening::Geometric { grids: grids.to_vec() },
        HierarchyConfig { algo, cache: false, numeric_repeats: 1, eq_limit: None, retain: false },
        &tracker,
    )
}

/// MG-PCG converges at mesh-independent-ish iteration counts for every
/// triple-product algorithm and several rank counts.
#[test]
fn mg_pcg_converges_for_all_algos_and_ranks() {
    for np in [1, 2, 4] {
        for algo in ALL_ALGOS {
            let world = World::new(np);
            world.run(|comm| {
                let grids = geometric_chain(Grid3::cube(4), 3);
                let h = build_geo(&comm, &grids, algo);
                let a = h.levels[0].a.csr().clone();
                let spmv = DistSpmv::new(&comm, &a);
                let mut pc = MgPreconditioner::new(&comm, h, MgOpts::default());
                let layout = a.row_layout.clone();
                let b = DistVec::from_fn(layout.clone(), comm.rank(), |g| {
                    ((g * 31 % 11) as f64) - 5.0
                });
                let mut x = DistVec::zeros(layout, comm.rank());
                let op = CsrOperator::new(&a, &spmv);
                let res = pcg(&comm, &op, &b, &mut x, Some(&mut pc), 1e-8, 40);
                assert!(res.converged, "np={np} {}", algo.name());
                assert!(
                    res.iterations <= 16,
                    "np={np} {}: {} iterations",
                    algo.name(),
                    res.iterations
                );
            });
        }
    }
}

/// Deeper grids should not blow up the iteration count (h-independence,
/// the property Galerkin coarsening exists to provide).
#[test]
fn iteration_count_stays_bounded_with_depth() {
    let world = World::new(2);
    world.run(|comm| {
        let mut iters = Vec::new();
        for levels in [2usize, 3, 4] {
            let grids = geometric_chain(Grid3::cube(3), levels);
            let h = build_geo(&comm, &grids, Algo::AllAtOnce);
            let a = h.levels[0].a.csr().clone();
            let spmv = DistSpmv::new(&comm, &a);
            let mut pc = MgPreconditioner::new(&comm, h, MgOpts::default());
            let layout = a.row_layout.clone();
            let b = DistVec::from_fn(layout.clone(), comm.rank(), |_| 1.0);
            let mut x = DistVec::zeros(layout, comm.rank());
            let op = CsrOperator::new(&a, &spmv);
            let res = pcg(&comm, &op, &b, &mut x, Some(&mut pc), 1e-8, 60);
            assert!(res.converged, "levels={levels}");
            iters.push(res.iterations);
        }
        // deepest grid (17^3) should still converge in O(10) iterations
        assert!(*iters.last().unwrap() <= 20, "{iters:?}");
    });
}

/// The algebraic (aggregation) hierarchy also supports the solver.
#[test]
fn amg_hierarchy_preconditions() {
    let world = World::new(2);
    world.run(|comm| {
        let a0 = grid_laplacian(Grid3::cube(12), comm.rank(), comm.size());
        let a = a0.clone();
        let tracker = MemTracker::new();
        let h = build_hierarchy(
            &comm,
            a0,
            &Coarsening::Aggregation {
                opts: AggregateOpts::default(),
                min_rows: 20,
                max_levels: 6,
            },
            HierarchyConfig::default(),
            &tracker,
        );
        assert!(h.n_levels() >= 2);
        let spmv = DistSpmv::new(&comm, &a);
        let mut pc = MgPreconditioner::new(&comm, h, MgOpts::default());
        let layout = a.row_layout.clone();
        let b = DistVec::from_fn(layout.clone(), comm.rank(), |_| 1.0);
        let mut x = DistVec::zeros(layout, comm.rank());
        let op = CsrOperator::new(&a, &spmv);
        let res = pcg(&comm, &op, &b, &mut x, Some(&mut pc), 1e-8, 60);
        assert!(res.converged);
        // must beat unpreconditioned CG on iteration count
        let mut x2 = DistVec::zeros(a.row_layout.clone(), comm.rank());
        let plain = pcg(&comm, &op, &b, &mut x2, None, 1e-8, 200);
        // on a 12³ grid plain CG needs noticeably more iterations
        assert!(
            res.iterations < plain.iterations,
            "AMG {} vs plain {}",
            res.iterations,
            plain.iterations
        );
    });
}

/// Hierarchy statistics have the Table 5/6 shape: rows strictly decrease,
/// interpolation dims chain, nnz positive everywhere.
#[test]
fn level_stats_shape() {
    let r = run_neutron(NeutronConfigExp {
        grid: Grid3::cube(6),
        groups: 4,
        np: 2,
        algo: Algo::AllAtOnce,
        cache: false,
        max_levels: 12,
        solve_iters: 3,
        eq_limit: None,
    });
    assert!(r.n_levels >= 3);
    assert_eq!(r.op_stats.len(), r.n_levels);
    assert_eq!(r.interp_stats.len(), r.n_levels - 1);
    for w in r.op_stats.windows(2) {
        assert!(w[1].rows < w[0].rows);
        assert!(w[1].nnz > 0);
    }
    for (k, is) in r.interp_stats.iter().enumerate() {
        assert_eq!(is.rows, r.op_stats[k].rows, "interp {k} rows");
        assert_eq!(is.cols, r.op_stats[k + 1].rows, "interp {k} cols");
    }
}

/// Cached vs non-cached hierarchy setup: caching must cost extra retained
/// memory, and both must produce the same operators (Table 7 vs 8).
#[test]
fn caching_costs_memory_not_correctness() {
    let mk = |cache: bool| {
        run_neutron(NeutronConfigExp {
            grid: Grid3::cube(6),
            groups: 4,
            np: 2,
            algo: Algo::AllAtOnce,
            cache,
            max_levels: 8,
            solve_iters: 3,
            eq_limit: None,
        })
    };
    let free = mk(false);
    let cached = mk(true);
    assert!(cached.mem_product > free.mem_product, "caching must retain more");
    assert_eq!(free.n_levels, cached.n_levels);
    for (a, b) in free.op_stats.iter().zip(&cached.op_stats) {
        assert_eq!(a.rows, b.rows);
        assert_eq!(a.nnz, b.nnz);
    }
}

/// W-cycles must converge at least as fast as V-cycles (per iteration).
#[test]
fn w_cycle_converges_no_slower_than_v() {
    let world = World::new(2);
    world.run(|comm| {
        let grids = geometric_chain(Grid3::cube(3), 4);
        let mut iters = Vec::new();
        for cycle in [CycleType::V, CycleType::W] {
            let h = build_geo(&comm, &grids, Algo::AllAtOnce);
            let a = h.levels[0].a.csr().clone();
            let spmv = DistSpmv::new(&comm, &a);
            let mut pc =
                MgPreconditioner::new(&comm, h, MgOpts { cycle, ..Default::default() });
            let layout = a.row_layout.clone();
            let b = DistVec::from_fn(layout.clone(), comm.rank(), |g| (g as f64).sin());
            let mut x = DistVec::zeros(layout, comm.rank());
            let op = CsrOperator::new(&a, &spmv);
            let res = pcg(&comm, &op, &b, &mut x, Some(&mut pc), 1e-8, 60);
            assert!(res.converged, "{cycle:?}");
            iters.push(res.iterations);
        }
        assert!(iters[1] <= iters[0], "W {} vs V {}", iters[1], iters[0]);
    });
}

/// GMRES with the MG preconditioner solves the nonsymmetric neutron
/// operator (the paper's actual solver configuration).
#[test]
fn mg_gmres_on_neutron_operator() {
    use galerkin_ptap::gen::{neutron_block_operator, NeutronConfig};
    use galerkin_ptap::mg::gmres;
    let world = World::new(2);
    world.run(|comm| {
        let cfg = NeutronConfig { grid: Grid3::cube(5), groups: 4, seed: 17 };
        let a = neutron_block_operator(cfg, comm.rank(), comm.size()).to_scalar();
        let tracker = MemTracker::new();
        let h = build_hierarchy(
            &comm,
            a.clone(),
            &Coarsening::Aggregation {
                opts: AggregateOpts { threshold: 0.25, smooth_omega: 0.0 },
                min_rows: 30,
                max_levels: 8,
            },
            HierarchyConfig::default(),
            &tracker,
        );
        let spmv = DistSpmv::new(&comm, &a);
        let mut pc = MgPreconditioner::new(&comm, h, MgOpts::default());
        let layout = a.row_layout.clone();
        let b = DistVec::from_fn(layout.clone(), comm.rank(), |_| 1.0);
        let mut x = DistVec::zeros(layout, comm.rank());
        let op = CsrOperator::new(&a, &spmv);
        let res = gmres(&comm, &op, &b, &mut x, Some(&mut pc), 30, 1e-8, 100);
        assert!(res.converged, "MG-GMRES stalled on the transport operator");
    });
}

/// Every smoother kind supports the V-cycle; Chebyshev(2) should need no
/// more outer iterations than point-Jacobi.
#[test]
fn all_smoothers_drive_mg() {
    use galerkin_ptap::mg::SmootherKind;
    let world = World::new(2);
    world.run(|comm| {
        let grids = geometric_chain(Grid3::cube(3), 3);
        let mut iters = Vec::new();
        for sm in [
            SmootherKind::Jacobi,
            SmootherKind::Chebyshev(2),
            SmootherKind::HybridSor,
        ] {
            let h = build_geo(&comm, &grids, Algo::AllAtOnce);
            let a = h.levels[0].a.csr().clone();
            let spmv = DistSpmv::new(&comm, &a);
            let mut pc = MgPreconditioner::new(
                &comm,
                h,
                MgOpts { smoother: sm, ..Default::default() },
            );
            let layout = a.row_layout.clone();
            let b = DistVec::from_fn(layout.clone(), comm.rank(), |g| ((g % 13) as f64) - 6.0);
            let mut x = DistVec::zeros(layout, comm.rank());
            let op = CsrOperator::new(&a, &spmv);
            let res = pcg(&comm, &op, &b, &mut x, Some(&mut pc), 1e-8, 40);
            assert!(res.converged, "{sm:?}");
            iters.push((sm, res.iterations));
        }
        let jac = iters[0].1;
        let cheb = iters[1].1;
        assert!(cheb <= jac, "chebyshev {cheb} vs jacobi {jac}");
    });
}
