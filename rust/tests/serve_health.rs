//! Graceful degradation: a divergent request inside a batch is flagged by
//! the health watchdog and reported as a per-ticket error, while the
//! server-side session state (retained hierarchy, request queue) stays
//! clean — the batch-mate and every subsequent request are bitwise what a
//! fresh server would have produced.
//!
//! The poisoned right-hand side uses finite entries around 1e300: the
//! norm's sum of squares overflows to +inf, so the initial residual is
//! non-finite and [`galerkin_ptap::obs::health::residual_verdict`] must
//! return `Diverging` regardless of what the NaN arithmetic does to the
//! rest of that column's history.

use std::time::Duration;

use galerkin_ptap::dist::{CsrOperator, DistSpmv, DistVec, World};
use galerkin_ptap::gen::{grid_laplacian, Grid3};
use galerkin_ptap::mem::MemTracker;
use galerkin_ptap::mg::{geometric_chain, pcg, Coarsening, HierarchyConfig, MgOpts};
use galerkin_ptap::obs;
use galerkin_ptap::obs::health::Verdict;
use galerkin_ptap::session::{RequestQueue, SessionCache};

const NP: usize = 2;
const RTOL: f64 = 1e-8;
const MAX_ITERS: usize = 40;

#[test]
fn divergent_ticket_fails_cleanly_and_session_stays_bitwise_fresh() {
    World::new(NP).run(|c| {
        obs::metrics::rank_begin(c.rank());
        let grids = geometric_chain(Grid3::cube(3), 3);
        let coarsening = Coarsening::Geometric { grids: grids.clone() };
        let a = grid_laplacian(grids[0], c.rank(), c.size());
        let layout = a.row_layout.clone();
        let tracker = MemTracker::new();
        let spmv = DistSpmv::new(&c, &a);
        let op = CsrOperator::new(&a, &spmv);
        let rhs = |s: usize| {
            DistVec::from_fn(layout.clone(), c.rank(), |g| {
                ((g as f64) * 0.21 + s as f64).sin()
            })
        };
        // finite entries whose squared sum overflows: a client sent
        // garbage scaling, not literal NaNs
        let bad = DistVec::from_fn(layout.clone(), c.rank(), |g| {
            (((g as f64) * 0.21).sin() + 1.5) * 1e300
        });

        // the server under test: one retained hierarchy, capacity-2 queue
        let mut cache = SessionCache::new();
        let (r, hit) = cache.checkout(
            &c,
            &a,
            &coarsening,
            HierarchyConfig::default(),
            MgOpts::default(),
            &tracker,
        );
        assert!(!hit);
        let mut q = RequestQueue::new(2, Duration::from_secs(3600));
        let t_good = q.submit(rhs(0));
        let t_bad = q.submit(bad);
        let done = q.flush(&c, &op, Some(r.pc()), RTOL, MAX_ITERS, &tracker);
        assert_eq!(done.len(), 2);

        // the watchdog flags the poisoned ticket; it errors cleanly
        // (verdict on the QueuedSolve), the server keeps running
        let d_bad = done.iter().find(|d| d.ticket == t_bad).unwrap();
        assert_eq!(d_bad.verdict, Verdict::Diverging, "watchdog must flag the bad ticket");
        assert!(!d_bad.result.converged);
        assert!(
            d_bad.result.residuals.iter().any(|v| !v.is_finite()),
            "poisoned column must show a non-finite residual"
        );

        // a reference server that never saw the poisoned request
        let mut fresh_cache = SessionCache::new();
        let (rf, _) = fresh_cache.checkout(
            &c,
            &a,
            &coarsening,
            HierarchyConfig::default(),
            MgOpts::default(),
            &tracker,
        );

        // the batch-mate is untouched: bitwise the solve a fresh server
        // would have produced for it alone
        let d_good = done.iter().find(|d| d.ticket == t_good).unwrap();
        assert_eq!(d_good.verdict, Verdict::Healthy);
        assert!(d_good.result.converged);
        let mut x_solo = DistVec::zeros(layout.clone(), c.rank());
        let res_solo = pcg(&c, &op, &rhs(0), &mut x_solo, Some(rf.pc()), RTOL, MAX_ITERS);
        assert!(res_solo.converged);
        assert_eq!(
            d_good.x.vals, x_solo.vals,
            "good column contaminated by its divergent batch-mate"
        );
        assert_eq!(d_good.result.residuals, res_solo.residuals);
        assert_eq!(d_good.result.iterations, res_solo.iterations);

        // the session keeps serving: the next batch through the SAME
        // retained hierarchy and queue is bitwise the fresh server's
        let t2 = [q.submit(rhs(1)), q.submit(rhs(2))];
        assert!(q.should_flush());
        let done2 = q.flush(&c, &op, Some(r.pc()), RTOL, MAX_ITERS, &tracker);
        let mut qf = RequestQueue::new(2, Duration::from_secs(3600));
        let tf = [qf.submit(rhs(1)), qf.submit(rhs(2))];
        let fresh2 = qf.flush(&c, &op, Some(rf.pc()), RTOL, MAX_ITERS, &tracker);
        assert_eq!(done2.len(), 2);
        for ((d, f), (td, tfk)) in done2.iter().zip(&fresh2).zip(t2.iter().zip(&tf)) {
            assert_eq!((d.ticket, f.ticket), (*td, *tfk));
            assert_eq!(d.verdict, Verdict::Healthy);
            assert!(d.result.converged);
            assert_eq!(
                d.x.vals, f.x.vals,
                "session state poisoned by the earlier divergent ticket"
            );
            assert_eq!(d.result.residuals, f.result.residuals);
        }

        // the failure was counted exactly once in the live metrics
        let snap = obs::metrics::rank_take();
        let failed = snap
            .entries
            .iter()
            .find(|e| e.sub == "session" && e.name == "request.failed")
            .expect("request.failed counter registered");
        assert_eq!(failed.value, 1, "exactly one ticket diverged");
    });
}
