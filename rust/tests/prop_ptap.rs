//! Property tests for the triple-product algorithms: randomized matrices,
//! partitions and rank counts; every algorithm must agree with the
//! sequential reference and with each other, with exact preallocation and
//! balanced memory accounting.

use galerkin_ptap::dist::World;
use galerkin_ptap::gen::random_dist_csr;
use galerkin_ptap::mem::MemTracker;
use galerkin_ptap::ptap::{ptap_once, seq_ptap_reference, Ptap, ALL_ALGOS};
use galerkin_ptap::util::prng::Rng;

/// 20 random (n, m, density, np) configurations × 3 algorithms.
#[test]
fn random_triple_products_match_reference() {
    let mut rng = Rng::new(0xBEEF);
    for case in 0..20 {
        let n = 12 + rng.below(50);
        let m = 3 + rng.below(25);
        let nnz_a = 1 + rng.below(8);
        let nnz_p = 1 + rng.below(4);
        let np = 1 + rng.below(5);
        let seed_a = rng.next_u64();
        let seed_p = rng.next_u64();
        let world = World::new(np);
        let per_rank = world.run(|comm| {
            let a = random_dist_csr(comm.rank(), comm.size(), n, n, nnz_a, seed_a);
            let p = random_dist_csr(comm.rank(), comm.size(), n, m, nnz_p, seed_p);
            let tracker = MemTracker::new();
            let cs: Vec<_> = ALL_ALGOS
                .iter()
                .map(|&algo| ptap_once(algo, &comm, &a, &p, &tracker).0.gather_global(&comm))
                .collect();
            assert_eq!(tracker.current_total(), 0, "tracker must balance");
            (cs, a.gather_global(&comm), p.gather_global(&comm))
        });
        let (cs, ag, pg) = &per_rank[0];
        let want = seq_ptap_reference(ag, pg);
        for (c, algo) in cs.iter().zip(ALL_ALGOS) {
            let diff = c.max_abs_diff(&want);
            assert!(
                diff < 1e-9,
                "case {case} np={np} {}: diff {diff}",
                algo.name()
            );
        }
    }
}

/// Values-change-pattern-stays: re-running numeric with modified values
/// reproduces the triple product of the *new* values (MAT_REUSE protocol).
#[test]
fn numeric_follows_value_updates() {
    let world = World::new(3);
    world.run(|comm| {
        let n = 40;
        let a = random_dist_csr(comm.rank(), comm.size(), n, n, 5, 1000);
        let p = random_dist_csr(comm.rank(), comm.size(), n, 10, 2, 2000);
        for algo in ALL_ALGOS {
            let tracker = MemTracker::new();
            let mut op = Ptap::symbolic(algo, &comm, &a, &p, &tracker);
            op.numeric(&comm, &a, &p);
            // perturb A's values (same pattern), rerun numeric
            let mut a2 = a.clone();
            for v in a2.diag.vals.iter_mut().chain(a2.offd.vals.iter_mut()) {
                *v = -*v * 3.0;
            }
            op.numeric(&comm, &a2, &p);
            let got = op.extract_c().gather_global(&comm);
            let want = seq_ptap_reference(&a2.gather_global(&comm), &p.gather_global(&comm));
            let diff = got.max_abs_diff(&want);
            assert!(diff < 1e-9, "{}: diff {diff}", algo.name());
        }
    });
}

/// Empty / degenerate inputs must not crash any algorithm.
#[test]
fn degenerate_inputs() {
    for np in [1, 2, 3] {
        let world = World::new(np);
        world.run(|comm| {
            // zero-size P columns (coarse space of 1)
            let n = 9;
            let a = random_dist_csr(comm.rank(), comm.size(), n, n, 3, 5);
            let p = random_dist_csr(comm.rank(), comm.size(), n, 1, 1, 6);
            let tracker = MemTracker::new();
            for algo in ALL_ALGOS {
                let (c, _) = ptap_once(algo, &comm, &a, &p, &tracker);
                assert_eq!(c.global_nrows(), 1);
                c.validate().unwrap();
            }
            // completely empty A
            let layout = a.row_layout.clone();
            let mut b = galerkin_ptap::dist::DistCsrBuilder::new(
                comm.rank(),
                layout.clone(),
                layout,
            );
            for _ in a.row_layout.range(comm.rank()) {
                b.push_row(&[]);
            }
            let empty = b.finish();
            for algo in ALL_ALGOS {
                let (c, _) = ptap_once(algo, &comm, &empty, &p, &tracker);
                assert_eq!(c.nnz_global(&comm), 0, "{}", algo.name());
            }
        });
    }
}

/// The product is independent of the rank count (bitwise pattern, values
/// to round-off).
#[test]
fn rank_count_invariance() {
    let run = |np: usize| {
        let world = World::new(np);
        world
            .run(|comm| {
                let a = random_dist_csr(comm.rank(), comm.size(), 45, 45, 6, 777);
                let p = random_dist_csr(comm.rank(), comm.size(), 45, 15, 3, 888);
                let tracker = MemTracker::new();
                ptap_once(galerkin_ptap::ptap::Algo::Merged, &comm, &a, &p, &tracker)
                    .0
                    .gather_global(&comm)
            })
            .remove(0)
    };
    let c1 = run(1);
    for np in [2, 4, 5] {
        let c = run(np);
        // same pattern
        assert_eq!(c1.rowptr, c.rowptr, "np={np}");
        assert_eq!(c1.cols, c.cols, "np={np}");
        // values to accumulation round-off
        assert!(c1.max_abs_diff(&c) < 1e-11, "np={np}");
    }
}
