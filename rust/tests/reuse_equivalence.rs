//! Hierarchy-wide numeric refresh equivalence (`MAT_REUSE_MATRIX`
//! analog): after the fine operator's *values* change, a refreshed
//! hierarchy must be bit-identical — every level's coarse operator and
//! the solver's residual history — to a from-scratch rebuild with the
//! new values, across all three PtAP algorithms, with and without
//! telescoping (including the k = 1 full-collapse layout), while sending
//! strictly fewer bytes and running no symbolic phase at all.
//!
//! Bitwise equality is exact, not approximate: the heat operator
//! `A(dt) = M + dt·K` uses dyadic `dt`, the trilinear interpolation has
//! power-of-two weights, and every distributed fold is partition- and
//! history-invariant, so the refreshed numeric pass reproduces the
//! rebuilt one to the last bit.

use galerkin_ptap::dist::{Comm, CsrOperator, DistSpmv, DistVec, World};
use galerkin_ptap::gen::{heat_operator, Grid3};
use galerkin_ptap::mat::Csr;
use galerkin_ptap::mem::MemTracker;
use galerkin_ptap::mg::{
    build_hierarchy, geometric_chain, pcg, Coarsening, Hierarchy, HierarchyConfig, MgOpts,
    MgPreconditioner,
};
use galerkin_ptap::ptap::{Algo, ALL_ALGOS};
use galerkin_ptap::reuse::HierarchyRefresher;

/// Gather every level's operator on its own communicator scope (walking
/// telescope boundaries exactly like the preconditioner does).
fn gather_levels(h: &Hierarchy, comm: &Comm) -> Vec<Csr> {
    let mut out = Vec::new();
    let mut cur = comm.clone();
    for lvl in &h.levels {
        out.push(lvl.a.csr().gather_global(&cur));
        if let Some(tel) = &lvl.telescope {
            match &tel.subcomm {
                Some(sc) => cur = sc.clone(),
                None => break,
            }
        }
    }
    out
}

/// Solve `A x = b` by MG-PCG and return the residual history bits.
fn solve_bits(
    comm: &Comm,
    a: &galerkin_ptap::dist::DistCsr,
    pc: &mut MgPreconditioner,
) -> Vec<u64> {
    let spmv = DistSpmv::new(comm, a);
    let layout = a.row_layout.clone();
    let b = DistVec::from_fn(layout.clone(), comm.rank(), |g| ((g % 13) as f64) - 6.0);
    let mut x = DistVec::zeros(layout, comm.rank());
    let op = CsrOperator::new(a, &spmv);
    let res = pcg(comm, &op, &b, &mut x, Some(pc), 1e-10, 40);
    res.residuals.iter().map(|r| r.to_bits()).collect()
}

/// Refresh path: build on `dts[0]`, refresh through `dts[1..]`, then
/// gather operators + solve with the final values.  Returns rank 0's
/// (ops, residual bits, last-refresh global bytes, symbolic-phase delta
/// evidence, per-refresh tracker bytes).
#[allow(clippy::type_complexity)]
fn refreshed_case(
    np: usize,
    levels: usize,
    algo: Algo,
    eq_limit: Option<usize>,
    dts: &[f64],
) -> (Vec<Csr>, Vec<u64>, u64, (u64, u64, f64), Vec<u64>) {
    let grids = geometric_chain(Grid3::cube(3), levels);
    let fine = grids[0];
    let w = World::new(np);
    let mut out = w.run(|comm| {
        let tracker = MemTracker::new();
        let a0 = heat_operator(fine, comm.rank(), comm.size(), dts[0]);
        let h = build_hierarchy(
            &comm,
            a0,
            &Coarsening::Geometric { grids: grids.clone() },
            HierarchyConfig {
                algo,
                cache: false,
                numeric_repeats: 1,
                eq_limit,
                retain: true,
            },
            &tracker,
        );
        let mut rf = HierarchyRefresher::new(&comm, h, MgOpts::default(), &tracker);
        let mut a_new = None;
        for &dt in &dts[1..] {
            let a = heat_operator(fine, comm.rank(), comm.size(), dt);
            rf.refresh(&comm, &a);
            a_new = Some(a);
        }
        let a_new = a_new.expect("at least one refresh");
        let ops = gather_levels(rf.hierarchy(), &comm);
        let bits = solve_bits(&comm, &a_new, rf.pc());
        let last = rf.refreshes.last().unwrap();
        let mem: Vec<u64> = rf.refreshes.iter().map(|r| r.mem_current).collect();
        (
            ops,
            bits,
            last.comm.bytes,
            (last.ptap.sym_msgs, last.ptap.sym_bytes, last.ptap.time_sym),
            mem,
        )
    });
    out.remove(0)
}

/// Rebuild path: one-shot build directly on the final values.  Returns
/// rank 0's (ops, residual bits, global build+setup bytes).
fn rebuilt_case(
    np: usize,
    levels: usize,
    algo: Algo,
    eq_limit: Option<usize>,
    dt: f64,
) -> (Vec<Csr>, Vec<u64>, u64) {
    let grids = geometric_chain(Grid3::cube(3), levels);
    let fine = grids[0];
    let w = World::new(np);
    let mut out = w.run(|comm| {
        let tracker = MemTracker::new();
        let a0 = heat_operator(fine, comm.rank(), comm.size(), dt);
        let before = comm.stats_global();
        let h = build_hierarchy(
            &comm,
            a0.clone(),
            &Coarsening::Geometric { grids: grids.clone() },
            HierarchyConfig {
                algo,
                cache: false,
                numeric_repeats: 1,
                eq_limit,
                retain: false,
            },
            &tracker,
        );
        let mut pc = MgPreconditioner::new(&comm, h, MgOpts::default());
        let build_bytes = comm.stats_global().since(before).bytes;
        let ops = gather_levels(&pc.hierarchy, &comm);
        let bits = solve_bits(&comm, &a0, &mut pc);
        (ops, bits, build_bytes)
    });
    out.remove(0)
}

fn check_case(np: usize, levels: usize, algo: Algo, eq_limit: Option<usize>) {
    let dts = [0.25f64, 0.125];
    let (ops_r, bits_r, refresh_bytes, (sym_msgs, sym_bytes, sym_time), _mem) =
        refreshed_case(np, levels, algo, eq_limit, &dts);
    let (ops_b, bits_b, build_bytes) = rebuilt_case(np, levels, algo, eq_limit, dts[1]);
    assert_eq!(
        ops_r.len(),
        ops_b.len(),
        "{algo:?} eq={eq_limit:?}: level counts diverged"
    );
    for (lvl, (r, b)) in ops_r.iter().zip(&ops_b).enumerate() {
        assert_eq!(r, b, "{algo:?} eq={eq_limit:?}: level {lvl} operator bits moved");
    }
    assert_eq!(bits_r, bits_b, "{algo:?} eq={eq_limit:?}: residual history bits moved");
    // no symbolic phase: zero symbolic traffic and zero symbolic time
    assert_eq!(sym_msgs, 0, "{algo:?}: refresh ran a symbolic phase");
    assert_eq!(sym_bytes, 0, "{algo:?}: refresh sent symbolic bytes");
    assert_eq!(sym_time, 0.0, "{algo:?}: refresh spent symbolic time");
    // strictly fewer bytes than a rebuild with the same values (np > 1:
    // on one rank neither path sends anything)
    if np > 1 {
        assert!(
            refresh_bytes < build_bytes,
            "{algo:?} eq={eq_limit:?}: refresh bytes {refresh_bytes} !< build bytes {build_bytes}"
        );
    }
}

#[test]
fn refresh_matches_rebuild_all_algorithms() {
    for algo in ALL_ALGOS {
        check_case(4, 3, algo, None);
    }
}

#[test]
fn refresh_matches_rebuild_telescoped() {
    // eq_limit 64 telescopes the 125-row level onto 2 of 4 ranks: the
    // refresh must replay the boundary's value-only redistribution over
    // the retained fine plan, then run numeric inside the subcomm
    for algo in ALL_ALGOS {
        check_case(4, 3, algo, Some(64));
    }
}

#[test]
fn refresh_matches_rebuild_full_collapse() {
    // eq_limit 200 collapses everything below the finest level onto one
    // rank (k = 1): idle ranks' refreshes end at the boundary, the root
    // re-runs every coarse product locally
    for algo in ALL_ALGOS {
        check_case(4, 3, algo, Some(200));
    }
}

#[test]
fn repeated_refreshes_hold_memory_flat() {
    // refreshing must not leak: everything is preallocated once, so the
    // tracker's current bytes are identical after every refresh
    let dts = [0.25f64, 0.125, 0.5, 0.0625];
    let (_, _, _, _, mem) = refreshed_case(2, 3, Algo::AllAtOnce, None, &dts);
    assert_eq!(mem.len(), 3);
    assert!(
        mem.windows(2).all(|w| w[0] == w[1]),
        "tracker bytes drifted across refreshes: {mem:?}"
    );
}

#[test]
fn timedep_driver_refresh_beats_rebuild_traffic() {
    use galerkin_ptap::coordinator::{run_timedep, TimedepConfig, TimedepResult, TimedepWorkload};
    let mk = |refresh: bool| {
        run_timedep(TimedepConfig {
            workload: TimedepWorkload::Heat { coarse: Grid3::cube(3), levels: 3 },
            np: 4,
            algo: Algo::AllAtOnce,
            steps: 4,
            dt0: 0.125,
            ramp: 0.5,
            eq_limit: None,
            refresh,
        })
    };
    let r = mk(true);
    let b = mk(false);
    assert_eq!(r.step_iters.len(), 4);
    assert!(r.final_rel_residual < 1e-7, "heat stepping stalled: {}", r.final_rel_residual);
    assert!(b.final_rel_residual < 1e-7);
    // every refresh moves strictly fewer bytes than the rebuild baseline
    for (i, (rb, bb)) in r.update_bytes.iter().zip(&b.update_bytes).enumerate() {
        assert!(rb < bb, "step {i}: refresh bytes {rb} !< rebuild bytes {bb}");
    }
    // and the per-refresh numeric cost sits below the one-off symbolic
    // build — the acceptance bar the bench artifact records
    let num_mean = TimedepResult::mean(&r.update_ptap_num);
    assert!(
        num_mean < r.build_time_sym.max(f64::MIN_POSITIVE) + r.build_time_num,
        "refresh numeric {num_mean} not below build cost {} + {}",
        r.build_time_sym,
        r.build_time_num
    );
}

#[test]
fn timedep_neutron_lagged_converges_with_refresh() {
    use galerkin_ptap::coordinator::{run_timedep, TimedepConfig, TimedepWorkload};
    let r = run_timedep(TimedepConfig {
        workload: TimedepWorkload::NeutronLagged {
            grid: Grid3::cube(5),
            groups: 3,
            max_levels: 6,
        },
        np: 2,
        algo: Algo::Merged,
        steps: 3,
        dt0: 0.5,
        ramp: 1.0,
        eq_limit: None,
        refresh: true,
    });
    assert_eq!(r.step_iters.len(), 3);
    assert!(r.n_levels >= 2);
    assert!(
        r.final_rel_residual < 1e-6,
        "lagged neutron iteration stalled: {}",
        r.final_rel_residual
    );
}
