//! Smoothers: damped point-Jacobi, Chebyshev polynomial smoothing, and
//! hybrid (processor-block) SOR — the standard multigrid relaxation menu
//! (PETSc's sor/chebyshev/jacobi).  A power-iteration eigenvalue
//! estimator picks damping and Chebyshev bounds automatically.
//!
//! Partition invariance (what telescoped levels rely on): Jacobi and
//! Chebyshev sweeps are elementwise over a [`DistSpmv`] product that
//! folds each row in global column order, so with a *fixed* ω/bounds a
//! sweep's bits do not depend on how the rows are distributed — a level
//! smoothed on a sub-communicator reproduces the full-communicator
//! sweep exactly.  Two caveats: [`chebyshev_bounds`] reduces partial
//! sums in rank order (auto-tuned ω is partition-*dependent*), and
//! [`HybridSorSmoother`] is local-block Gauss-Seidel by construction —
//! its sweep changes with the partition on purpose.

use crate::dist::vec::DistSpmv;
use crate::dist::{Comm, DistCsr, DistVec};

/// Which relaxation the V-cycle uses per level.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SmootherKind {
    Jacobi,
    /// Chebyshev polynomial of the given degree over the Jacobi iteration.
    Chebyshev(usize),
    /// Hybrid SOR: Gauss-Seidel on the local diag block, Jacobi across
    /// ranks (PETSc's default parallel SOR).
    HybridSor,
}

/// Damped Jacobi: `x += ω D⁻¹ (b − A x)`.
#[derive(Debug)]
pub struct JacobiSmoother {
    /// Inverse diagonal of A (local slice).
    pub(crate) dinv: Vec<f64>,
    pub omega: f64,
}

impl JacobiSmoother {
    pub fn new(a: &DistCsr, omega: f64) -> Self {
        let n = a.local_nrows();
        let mut dinv = vec![1.0; n];
        for i in 0..n {
            let (cols, vals) = a.diag.row(i);
            if let Some((_, &v)) = cols.iter().zip(vals).find(|&(&c, _)| c as usize == i) {
                if v != 0.0 {
                    dinv[i] = 1.0 / v;
                }
            }
        }
        JacobiSmoother { dinv, omega }
    }

    pub fn bytes(&self) -> u64 {
        (self.dinv.len() * 8) as u64
    }

    /// One smoothing sweep; `r` and `ax` are caller-provided work vectors.
    pub fn sweep(
        &self,
        comm: &Comm,
        a: &DistCsr,
        spmv: &DistSpmv,
        b: &DistVec,
        x: &mut DistVec,
        work: &mut DistVec,
    ) {
        spmv.apply(comm, a, x, work); // work = A x
        for i in 0..x.vals.len() {
            x.vals[i] += self.omega * self.dinv[i] * (b.vals[i] - work.vals[i]);
        }
    }
}

/// Estimate the largest eigenvalue of `D⁻¹A` by power iteration
/// (collective).  Returns (λ_max estimate, suggested Jacobi ω = 4/(3λ)).
pub fn chebyshev_bounds(
    comm: &Comm,
    a: &DistCsr,
    spmv: &DistSpmv,
    iters: usize,
) -> (f64, f64) {
    let sm = JacobiSmoother::new(a, 1.0);
    let mut v = DistVec::from_fn(a.row_layout.clone(), a.rank, |g| {
        // deterministic pseudo-random start
        ((g as f64 * 0.7390851) % 1.0) - 0.5
    });
    let mut av = DistVec::zeros(a.row_layout.clone(), a.rank);
    let mut lambda = 1.0;
    for _ in 0..iters {
        let n = v.norm2(comm);
        if n == 0.0 {
            break;
        }
        v.scale(1.0 / n);
        spmv.apply(comm, a, &v, &mut av);
        for i in 0..av.vals.len() {
            av.vals[i] *= sm.dinv[i];
        }
        lambda = v.dot(comm, &av);
        std::mem::swap(&mut v, &mut av);
    }
    (lambda, 4.0 / (3.0 * lambda.max(1e-12)))
}

/// Chebyshev polynomial smoother over D⁻¹A with spectrum bounds
/// [lmax/alpha, lmax] (textbook 3-term recurrence).
#[derive(Debug)]
pub struct ChebyshevSmoother {
    dinv: Vec<f64>,
    pub degree: usize,
    pub lmin: f64,
    pub lmax: f64,
}

impl ChebyshevSmoother {
    /// Collective: estimates λ_max(D⁻¹A) by power iteration and targets
    /// the upper part of the spectrum [λ/α, 1.1λ] (α = 4, the usual MG
    /// smoothing choice).
    pub fn new(comm: &Comm, a: &DistCsr, spmv: &DistSpmv, degree: usize) -> Self {
        let (lmax_est, _) = chebyshev_bounds(comm, a, spmv, 12);
        let lmax = 1.1 * lmax_est;
        let lmin = lmax / 4.0;
        let base = JacobiSmoother::new(a, 1.0);
        ChebyshevSmoother { dinv: base.dinv, degree, lmin, lmax }
    }

    pub fn bytes(&self) -> u64 {
        (self.dinv.len() * 8) as u64
    }

    /// One smoothing application: x updated by a degree-k Chebyshev
    /// polynomial in D⁻¹A applied to the residual.
    pub fn sweep(
        &self,
        comm: &Comm,
        a: &DistCsr,
        spmv: &DistSpmv,
        b: &DistVec,
        x: &mut DistVec,
        work: &mut DistVec,
    ) {
        let theta = 0.5 * (self.lmax + self.lmin);
        let delta = 0.5 * (self.lmax - self.lmin);
        // r = D^-1 (b - A x)
        let n = x.vals.len();
        let mut r = DistVec::zeros(x.layout.clone(), x.rank);
        spmv.apply(comm, a, x, work);
        for i in 0..n {
            r.vals[i] = self.dinv[i] * (b.vals[i] - work.vals[i]);
        }
        // d = r / theta ; x += d
        let mut d = r.clone();
        d.scale(1.0 / theta);
        for i in 0..n {
            x.vals[i] += d.vals[i];
        }
        // ρ₀ = δ/θ; ρ_k = (2θ/δ − ρ_{k-1})⁻¹  (Adams et al. 2003 recurrence)
        let mut rho = delta / theta;
        for _ in 1..self.degree {
            // r = D^-1 (b - A x)
            spmv.apply(comm, a, x, work);
            for i in 0..n {
                r.vals[i] = self.dinv[i] * (b.vals[i] - work.vals[i]);
            }
            let rho_new = 1.0 / (2.0 * theta / delta - rho);
            let c1 = rho_new * rho;
            let c2 = 2.0 * rho_new / delta;
            for i in 0..n {
                d.vals[i] = c1 * d.vals[i] + c2 * r.vals[i];
                x.vals[i] += d.vals[i];
            }
            rho = rho_new;
        }
    }
}

/// Hybrid SSOR: symmetric (forward + backward) Gauss-Seidel within the
/// rank's diag block; offd contributions use the halo from the start of
/// the sweep (block Jacobi across ranks) — PETSc
/// `SOR_LOCAL_SYMMETRIC_SWEEP`.  The symmetric sweep keeps the V-cycle a
/// valid CG preconditioner.
#[derive(Debug)]
pub struct HybridSorSmoother {
    /// 1 / a_ii per local row.
    dinv: Vec<f64>,
    pub omega: f64,
}

impl HybridSorSmoother {
    pub fn new(a: &DistCsr, omega: f64) -> Self {
        let base = JacobiSmoother::new(a, omega);
        HybridSorSmoother { dinv: base.dinv, omega }
    }

    pub fn bytes(&self) -> u64 {
        (self.dinv.len() * 8) as u64
    }

    #[inline]
    fn relax_row(&self, a: &DistCsr, halo: &[f64], b: &DistVec, x: &mut DistVec, i: usize) {
        let mut acc = b.vals[i];
        let (dc, dv) = a.diag.row(i);
        for (&c, &v) in dc.iter().zip(dv) {
            if c as usize != i {
                acc -= v * x.vals[c as usize];
            }
        }
        let (oc, ov) = a.offd.row(i);
        for (&c, &v) in oc.iter().zip(ov) {
            acc -= v * halo[c as usize];
        }
        let xi_new = self.dinv[i] * acc;
        x.vals[i] += self.omega * (xi_new - x.vals[i]);
    }

    /// One symmetric local sweep (collective: gathers the halo once).
    pub fn sweep(
        &self,
        comm: &Comm,
        a: &DistCsr,
        spmv: &DistSpmv,
        b: &DistVec,
        x: &mut DistVec,
    ) {
        let halo = spmv.gather_halo(comm, x);
        for i in 0..a.local_nrows() {
            self.relax_row(a, &halo, b, x, i);
        }
        for i in (0..a.local_nrows()).rev() {
            self.relax_row(a, &halo, b, x, i);
        }
    }

    /// Forward-only sweep (exposed for the sequential-GS equivalence test
    /// and for nonsymmetric outer solvers).
    pub fn sweep_forward(
        &self,
        comm: &Comm,
        a: &DistCsr,
        spmv: &DistSpmv,
        b: &DistVec,
        x: &mut DistVec,
    ) {
        let halo = spmv.gather_halo(comm, x);
        for i in 0..a.local_nrows() {
            self.relax_row(a, &halo, b, x, i);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::World;
    use crate::gen::{grid_laplacian, Grid3};

    #[test]
    fn jacobi_reduces_residual_on_laplacian() {
        let w = World::new(2);
        w.run(|c| {
            let a = grid_laplacian(Grid3::cube(5), c.rank(), c.size());
            let spmv = DistSpmv::new(&c, &a);
            let sm = JacobiSmoother::new(&a, 0.66);
            let b = DistVec::from_fn(a.row_layout.clone(), c.rank(), |_| 1.0);
            let mut x = DistVec::zeros(a.row_layout.clone(), c.rank());
            let mut work = DistVec::zeros(a.row_layout.clone(), c.rank());
            let res = |x: &DistVec, work: &mut DistVec, c: &Comm| {
                spmv.apply(c, &a, x, work);
                let mut r = b.clone();
                r.axpy(-1.0, work);
                r.norm2(c)
            };
            let r0 = res(&x, &mut work, &c);
            for _ in 0..20 {
                sm.sweep(&c, &a, &spmv, &b, &mut x, &mut work);
            }
            let r1 = res(&x, &mut work, &c);
            assert!(r1 < 0.5 * r0, "residual {r0} -> {r1}");
        });
    }

    #[test]
    fn power_iteration_bounds_dinva_spectrum() {
        let w = World::new(2);
        w.run(|c| {
            let a = grid_laplacian(Grid3::cube(6), c.rank(), c.size());
            let spmv = DistSpmv::new(&c, &a);
            let (lmax, omega) = chebyshev_bounds(&c, &a, &spmv, 20);
            // D^-1 A for the 7-pt Laplacian has spectrum in (0, 2)
            assert!(lmax > 1.0 && lmax < 2.01, "lambda {lmax}");
            assert!(omega > 0.6 && omega < 1.4, "omega {omega}");
        });
    }

    fn residual_after<F>(np: usize, sweeps: usize, relax: F) -> f64
    where
        F: Fn(&Comm, &DistCsr, &DistSpmv, &DistVec, &mut DistVec, &mut DistVec)
            + Send
            + Sync
            + Copy,
    {
        let w = World::new(np);
        let r = w.run(move |c| {
            let a = grid_laplacian(Grid3::cube(6), c.rank(), c.size());
            let spmv = DistSpmv::new(&c, &a);
            let b = DistVec::from_fn(a.row_layout.clone(), c.rank(), |g| ((g % 5) as f64) - 2.0);
            let mut x = DistVec::zeros(a.row_layout.clone(), c.rank());
            let mut work = DistVec::zeros(a.row_layout.clone(), c.rank());
            for _ in 0..sweeps {
                relax(&c, &a, &spmv, &b, &mut x, &mut work);
            }
            spmv.apply(&c, &a, &x, &mut work);
            let mut res = b.clone();
            res.axpy(-1.0, &work);
            res.norm2(&c)
        });
        r[0]
    }

    /// Chebyshev is a *smoother*: it must damp high-frequency error
    /// faster per matvec than Jacobi (it deliberately ignores the smooth
    /// components the coarse grid handles).
    #[test]
    fn chebyshev_damps_high_frequency_error_faster() {
        let err_after = |cheb: bool| -> f64 {
            let w = World::new(2);
            let r = w.run(move |c| {
                let a = grid_laplacian(Grid3::cube(6), c.rank(), c.size());
                let spmv = DistSpmv::new(&c, &a);
                let b = DistVec::zeros(a.row_layout.clone(), c.rank());
                // high-frequency initial error: alternating signs
                let mut x = DistVec::from_fn(a.row_layout.clone(), c.rank(), |g| {
                    if g % 2 == 0 { 1.0 } else { -1.0 }
                });
                let mut work = DistVec::zeros(a.row_layout.clone(), c.rank());
                if cheb {
                    let sm = ChebyshevSmoother::new(&c, &a, &spmv, 3);
                    sm.sweep(&c, &a, &spmv, &b, &mut x, &mut work); // 3 matvecs
                } else {
                    let sm = JacobiSmoother::new(&a, 0.66);
                    for _ in 0..3 {
                        sm.sweep(&c, &a, &spmv, &b, &mut x, &mut work);
                    }
                }
                x.norm2(&c) // exact solution is 0, so ||x|| is the error
            });
            r[0]
        };
        let cheb = err_after(true);
        let jac = err_after(false);
        assert!(
            cheb < 0.8 * jac,
            "chebyshev error {cheb} vs jacobi {jac} (3 matvecs each)"
        );
    }

    #[test]
    fn hybrid_sor_reduces_residual() {
        let sor = residual_after(2, 10, |c, a, spmv, b, x, _work| {
            let sm = HybridSorSmoother::new(a, 1.0);
            sm.sweep(c, a, spmv, b, x);
        });
        let nothing = residual_after(2, 0, |_c, _a, _spmv, _b, _x, _w| {});
        assert!(sor < 0.2 * nothing, "SOR {sor} vs initial {nothing}");
    }

    #[test]
    fn sor_matches_sequential_gs_on_one_rank() {
        // np=1: hybrid SOR == plain Gauss-Seidel; verify against a manual
        // GS sweep
        let w = World::new(1);
        w.run(|c| {
            let a = grid_laplacian(Grid3::cube(3), c.rank(), c.size());
            let spmv = DistSpmv::new(&c, &a);
            let b = DistVec::from_fn(a.row_layout.clone(), c.rank(), |g| g as f64);
            let mut x = DistVec::zeros(a.row_layout.clone(), c.rank());
            let sm = HybridSorSmoother::new(&a, 1.0);
            sm.sweep_forward(&c, &a, &spmv, &b, &mut x);
            // manual forward GS
            let g = a.gather_global(&c);
            let mut y = vec![0.0; g.nrows];
            for i in 0..g.nrows {
                let (cols, vals) = g.row(i);
                let mut acc = b.vals[i];
                let mut diag = 1.0;
                for (&cc, &vv) in cols.iter().zip(vals) {
                    if cc as usize == i {
                        diag = vv;
                    } else {
                        acc -= vv * y[cc as usize];
                    }
                }
                y[i] = acc / diag;
            }
            for i in 0..g.nrows {
                assert!((x.vals[i] - y[i]).abs() < 1e-12, "row {i}");
            }
        });
    }
}
