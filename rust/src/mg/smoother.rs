//! Smoothers: damped point-Jacobi, Chebyshev polynomial smoothing, and
//! hybrid (processor-block) SOR — the standard multigrid relaxation menu
//! (PETSc's sor/chebyshev/jacobi).  A power-iteration eigenvalue
//! estimator picks damping and Chebyshev bounds automatically.
//!
//! Every smoother relaxes a [`DistOperator`] — the assembled
//! [`crate::dist::CsrOperator`] view or the matrix-free
//! [`crate::gen::StencilOperator`] — and because both implementations
//! fold rows in ascending global column order, a sweep's bits do not
//! depend on which one backs the level.
//!
//! Partition invariance (what telescoped levels rely on): Jacobi and
//! Chebyshev sweeps are elementwise over an operator product that
//! folds each row in global column order, so with a *fixed* ω/bounds a
//! sweep's bits do not depend on how the rows are distributed — a level
//! smoothed on a sub-communicator reproduces the full-communicator
//! sweep exactly.  Two caveats: [`chebyshev_bounds`] reduces partial
//! sums in rank order (auto-tuned ω is partition-*dependent*), and
//! [`HybridSorSmoother`] is local-block Gauss-Seidel by construction —
//! its sweep changes with the partition on purpose.

use crate::dist::{Comm, DistMultiVec, DistOperator, DistVec};

/// Which relaxation the V-cycle uses per level.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SmootherKind {
    Jacobi,
    /// Chebyshev polynomial of the given degree over the Jacobi iteration.
    Chebyshev(usize),
    /// Hybrid SOR: Gauss-Seidel on the local diag block, Jacobi across
    /// ranks (PETSc's default parallel SOR).
    HybridSor,
}

/// Invert the operator diagonal with the Jacobi fallback: rows with a
/// missing or zero diagonal relax with weight 1.
fn invert_diagonal(a: &dyn DistOperator) -> Vec<f64> {
    a.diagonal().into_iter().map(|d| if d != 0.0 { 1.0 / d } else { 1.0 }).collect()
}

/// Damped Jacobi: `x += ω D⁻¹ (b − A x)`.
#[derive(Debug)]
pub struct JacobiSmoother {
    /// Inverse diagonal of A (local slice).
    pub(crate) dinv: Vec<f64>,
    pub omega: f64,
}

impl JacobiSmoother {
    pub fn new(a: &dyn DistOperator, omega: f64) -> Self {
        JacobiSmoother { dinv: invert_diagonal(a), omega }
    }

    pub fn bytes(&self) -> u64 {
        (self.dinv.len() * 8) as u64
    }

    /// One smoothing sweep; `work` is a caller-provided work vector.
    pub fn sweep(
        &self,
        comm: &Comm,
        a: &dyn DistOperator,
        b: &DistVec,
        x: &mut DistVec,
        work: &mut DistVec,
    ) {
        a.apply(comm, x, work); // work = A x
        for i in 0..x.vals.len() {
            x.vals[i] += self.omega * self.dinv[i] * (b.vals[i] - work.vals[i]);
        }
    }

    /// Blocked sweep over K stacked systems: one K-wide matvec (a single
    /// halo epoch), then the same elementwise update per column — column
    /// `j` is bitwise the scalar [`JacobiSmoother::sweep`] of column `j`.
    pub fn sweep_multi(
        &self,
        comm: &Comm,
        a: &dyn DistOperator,
        b: &DistMultiVec,
        x: &mut DistMultiVec,
        work: &mut DistMultiVec,
    ) {
        let k = x.k;
        a.apply_multi(comm, x, work); // work = A X
        for i in 0..self.dinv.len() {
            let wd = self.omega * self.dinv[i];
            for j in 0..k {
                let t = i * k + j;
                x.vals[t] += wd * (b.vals[t] - work.vals[t]);
            }
        }
    }
}

/// Estimate the largest eigenvalue of `D⁻¹A` by power iteration
/// (collective).  Returns (λ_max estimate, suggested Jacobi ω = 4/(3λ)).
pub fn chebyshev_bounds(comm: &Comm, a: &dyn DistOperator, iters: usize) -> (f64, f64) {
    let dinv = invert_diagonal(a);
    let mut v = DistVec::from_fn(a.row_layout().clone(), a.rank(), |g| {
        // deterministic pseudo-random start
        ((g as f64 * 0.7390851) % 1.0) - 0.5
    });
    let mut av = DistVec::zeros(a.row_layout().clone(), a.rank());
    let mut lambda = 1.0;
    for _ in 0..iters {
        let n = v.norm2(comm);
        if n == 0.0 {
            break;
        }
        v.scale(1.0 / n);
        a.apply(comm, &v, &mut av);
        for i in 0..av.vals.len() {
            av.vals[i] *= dinv[i];
        }
        lambda = v.dot(comm, &av);
        std::mem::swap(&mut v, &mut av);
    }
    (lambda, 4.0 / (3.0 * lambda.max(1e-12)))
}

/// Chebyshev polynomial smoother over D⁻¹A with spectrum bounds
/// [lmax/alpha, lmax] (textbook 3-term recurrence).
#[derive(Debug)]
pub struct ChebyshevSmoother {
    dinv: Vec<f64>,
    pub degree: usize,
    pub lmin: f64,
    pub lmax: f64,
}

impl ChebyshevSmoother {
    /// Collective: estimates λ_max(D⁻¹A) by power iteration and targets
    /// the upper part of the spectrum [λ/α, 1.1λ] (α = 4, the usual MG
    /// smoothing choice).
    pub fn new(comm: &Comm, a: &dyn DistOperator, degree: usize) -> Self {
        let (lmax_est, _) = chebyshev_bounds(comm, a, 12);
        let lmax = 1.1 * lmax_est;
        let lmin = lmax / 4.0;
        ChebyshevSmoother { dinv: invert_diagonal(a), degree, lmin, lmax }
    }

    pub fn bytes(&self) -> u64 {
        (self.dinv.len() * 8) as u64
    }

    /// One smoothing application: x updated by a degree-k Chebyshev
    /// polynomial in D⁻¹A applied to the residual.
    pub fn sweep(
        &self,
        comm: &Comm,
        a: &dyn DistOperator,
        b: &DistVec,
        x: &mut DistVec,
        work: &mut DistVec,
    ) {
        let theta = 0.5 * (self.lmax + self.lmin);
        let delta = 0.5 * (self.lmax - self.lmin);
        // r = D^-1 (b - A x)
        let n = x.vals.len();
        let mut r = DistVec::zeros(x.layout.clone(), x.rank);
        a.apply(comm, x, work);
        for i in 0..n {
            r.vals[i] = self.dinv[i] * (b.vals[i] - work.vals[i]);
        }
        // d = r / theta ; x += d
        let mut d = r.clone();
        d.scale(1.0 / theta);
        for i in 0..n {
            x.vals[i] += d.vals[i];
        }
        // ρ₀ = δ/θ; ρ_k = (2θ/δ − ρ_{k-1})⁻¹  (Adams et al. 2003 recurrence)
        let mut rho = delta / theta;
        for _ in 1..self.degree {
            // r = D^-1 (b - A x)
            a.apply(comm, x, work);
            for i in 0..n {
                r.vals[i] = self.dinv[i] * (b.vals[i] - work.vals[i]);
            }
            let rho_new = 1.0 / (2.0 * theta / delta - rho);
            let c1 = rho_new * rho;
            let c2 = 2.0 * rho_new / delta;
            for i in 0..n {
                d.vals[i] = c1 * d.vals[i] + c2 * r.vals[i];
                x.vals[i] += d.vals[i];
            }
            rho = rho_new;
        }
    }

    /// Blocked Chebyshev over K stacked systems: each of the `degree`
    /// matvecs is one K-wide halo epoch; the 3-term recurrence runs per
    /// column with the exact scalar coefficient arithmetic, so column `j`
    /// is bitwise the scalar [`ChebyshevSmoother::sweep`] of column `j`.
    pub fn sweep_multi(
        &self,
        comm: &Comm,
        a: &dyn DistOperator,
        b: &DistMultiVec,
        x: &mut DistMultiVec,
        work: &mut DistMultiVec,
    ) {
        let theta = 0.5 * (self.lmax + self.lmin);
        let delta = 0.5 * (self.lmax - self.lmin);
        let k = x.k;
        let n = self.dinv.len();
        let mut r = DistMultiVec::zeros(x.layout.clone(), x.rank, k);
        a.apply_multi(comm, x, work);
        for i in 0..n {
            for j in 0..k {
                let t = i * k + j;
                r.vals[t] = self.dinv[i] * (b.vals[t] - work.vals[t]);
            }
        }
        // d = r / theta ; x += d  (same scale-then-add bits as scalar)
        let mut d = r.clone();
        let inv_theta = 1.0 / theta;
        for t in 0..n * k {
            d.vals[t] *= inv_theta;
            x.vals[t] += d.vals[t];
        }
        let mut rho = delta / theta;
        for _ in 1..self.degree {
            a.apply_multi(comm, x, work);
            for i in 0..n {
                for j in 0..k {
                    let t = i * k + j;
                    r.vals[t] = self.dinv[i] * (b.vals[t] - work.vals[t]);
                }
            }
            let rho_new = 1.0 / (2.0 * theta / delta - rho);
            let c1 = rho_new * rho;
            let c2 = 2.0 * rho_new / delta;
            for t in 0..n * k {
                d.vals[t] = c1 * d.vals[t] + c2 * r.vals[t];
                x.vals[t] += d.vals[t];
            }
            rho = rho_new;
        }
    }
}

/// Hybrid SSOR: symmetric (forward + backward) Gauss-Seidel within the
/// rank's diag block; offd contributions use the halo from the start of
/// the sweep (block Jacobi across ranks) — PETSc
/// `SOR_LOCAL_SYMMETRIC_SWEEP`.  The symmetric sweep keeps the V-cycle a
/// valid CG preconditioner.  The row relaxation itself lives in the
/// operator ([`DistOperator::sor_sweep`]), which owns the fold order.
#[derive(Debug)]
pub struct HybridSorSmoother {
    /// 1 / a_ii per local row.
    dinv: Vec<f64>,
    pub omega: f64,
}

impl HybridSorSmoother {
    pub fn new(a: &dyn DistOperator, omega: f64) -> Self {
        HybridSorSmoother { dinv: invert_diagonal(a), omega }
    }

    pub fn bytes(&self) -> u64 {
        (self.dinv.len() * 8) as u64
    }

    /// One symmetric local sweep (collective: gathers the halo once).
    pub fn sweep(&self, comm: &Comm, a: &dyn DistOperator, b: &DistVec, x: &mut DistVec) {
        a.sor_sweep(comm, &self.dinv, self.omega, b, x, true);
    }

    /// Blocked symmetric sweep over K stacked systems: one K-wide frozen
    /// halo for all columns ([`DistOperator::sor_sweep_multi`]).
    pub fn sweep_multi(
        &self,
        comm: &Comm,
        a: &dyn DistOperator,
        b: &DistMultiVec,
        x: &mut DistMultiVec,
    ) {
        a.sor_sweep_multi(comm, &self.dinv, self.omega, b, x, true);
    }

    /// Forward-only sweep (exposed for the sequential-GS equivalence test
    /// and for nonsymmetric outer solvers).
    pub fn sweep_forward(
        &self,
        comm: &Comm,
        a: &dyn DistOperator,
        b: &DistVec,
        x: &mut DistVec,
    ) {
        a.sor_sweep(comm, &self.dinv, self.omega, b, x, false);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{CsrOperator, DistCsr, DistSpmv, World};
    use crate::gen::{grid_laplacian, Grid3};

    #[test]
    fn jacobi_reduces_residual_on_laplacian() {
        let w = World::new(2);
        w.run(|c| {
            let a = grid_laplacian(Grid3::cube(5), c.rank(), c.size());
            let spmv = DistSpmv::new(&c, &a);
            let op = CsrOperator::new(&a, &spmv);
            let sm = JacobiSmoother::new(&op, 0.66);
            let b = DistVec::from_fn(a.row_layout.clone(), c.rank(), |_| 1.0);
            let mut x = DistVec::zeros(a.row_layout.clone(), c.rank());
            let mut work = DistVec::zeros(a.row_layout.clone(), c.rank());
            let res = |x: &DistVec, work: &mut DistVec, c: &Comm| {
                op.apply(c, x, work);
                let mut r = b.clone();
                r.axpy(-1.0, work);
                r.norm2(c)
            };
            let r0 = res(&x, &mut work, &c);
            for _ in 0..20 {
                sm.sweep(&c, &op, &b, &mut x, &mut work);
            }
            let r1 = res(&x, &mut work, &c);
            assert!(r1 < 0.5 * r0, "residual {r0} -> {r1}");
        });
    }

    #[test]
    fn power_iteration_bounds_dinva_spectrum() {
        let w = World::new(2);
        w.run(|c| {
            let a = grid_laplacian(Grid3::cube(6), c.rank(), c.size());
            let spmv = DistSpmv::new(&c, &a);
            let op = CsrOperator::new(&a, &spmv);
            let (lmax, omega) = chebyshev_bounds(&c, &op, 20);
            // D^-1 A for the 7-pt Laplacian has spectrum in (0, 2)
            assert!(lmax > 1.0 && lmax < 2.01, "lambda {lmax}");
            assert!(omega > 0.6 && omega < 1.4, "omega {omega}");
        });
    }

    fn residual_after<F>(np: usize, sweeps: usize, relax: F) -> f64
    where
        F: Fn(&Comm, &CsrOperator, &DistVec, &mut DistVec, &mut DistVec) + Send + Sync + Copy,
    {
        let w = World::new(np);
        let r = w.run(move |c| {
            let a = grid_laplacian(Grid3::cube(6), c.rank(), c.size());
            let spmv = DistSpmv::new(&c, &a);
            let op = CsrOperator::new(&a, &spmv);
            let b = DistVec::from_fn(a.row_layout.clone(), c.rank(), |g| ((g % 5) as f64) - 2.0);
            let mut x = DistVec::zeros(a.row_layout.clone(), c.rank());
            let mut work = DistVec::zeros(a.row_layout.clone(), c.rank());
            for _ in 0..sweeps {
                relax(&c, &op, &b, &mut x, &mut work);
            }
            op.apply(&c, &x, &mut work);
            let mut res = b.clone();
            res.axpy(-1.0, &work);
            res.norm2(&c)
        });
        r[0]
    }

    /// Chebyshev is a *smoother*: it must damp high-frequency error
    /// faster per matvec than Jacobi (it deliberately ignores the smooth
    /// components the coarse grid handles).
    #[test]
    fn chebyshev_damps_high_frequency_error_faster() {
        let err_after = |cheb: bool| -> f64 {
            let w = World::new(2);
            let r = w.run(move |c| {
                let a = grid_laplacian(Grid3::cube(6), c.rank(), c.size());
                let spmv = DistSpmv::new(&c, &a);
                let op = CsrOperator::new(&a, &spmv);
                let b = DistVec::zeros(a.row_layout.clone(), c.rank());
                // high-frequency initial error: alternating signs
                let mut x = DistVec::from_fn(a.row_layout.clone(), c.rank(), |g| {
                    if g % 2 == 0 { 1.0 } else { -1.0 }
                });
                let mut work = DistVec::zeros(a.row_layout.clone(), c.rank());
                if cheb {
                    let sm = ChebyshevSmoother::new(&c, &op, 3);
                    sm.sweep(&c, &op, &b, &mut x, &mut work); // 3 matvecs
                } else {
                    let sm = JacobiSmoother::new(&op, 0.66);
                    for _ in 0..3 {
                        sm.sweep(&c, &op, &b, &mut x, &mut work);
                    }
                }
                x.norm2(&c) // exact solution is 0, so ||x|| is the error
            });
            r[0]
        };
        let cheb = err_after(true);
        let jac = err_after(false);
        assert!(
            cheb < 0.8 * jac,
            "chebyshev error {cheb} vs jacobi {jac} (3 matvecs each)"
        );
    }

    #[test]
    fn hybrid_sor_reduces_residual() {
        let sor = residual_after(2, 10, |c, op, b, x, _work| {
            let sm = HybridSorSmoother::new(op, 1.0);
            sm.sweep(c, op, b, x);
        });
        let nothing = residual_after(2, 0, |_c, _op, _b, _x, _w| {});
        assert!(sor < 0.2 * nothing, "SOR {sor} vs initial {nothing}");
    }

    #[test]
    fn sor_matches_sequential_gs_on_one_rank() {
        // np=1: hybrid SOR == plain Gauss-Seidel; verify against a manual
        // GS sweep
        let w = World::new(1);
        w.run(|c| {
            let a = grid_laplacian(Grid3::cube(3), c.rank(), c.size());
            let spmv = DistSpmv::new(&c, &a);
            let op = CsrOperator::new(&a, &spmv);
            let b = DistVec::from_fn(a.row_layout.clone(), c.rank(), |g| g as f64);
            let mut x = DistVec::zeros(a.row_layout.clone(), c.rank());
            let sm = HybridSorSmoother::new(&op, 1.0);
            sm.sweep_forward(&c, &op, &b, &mut x);
            // manual forward GS
            let g = a.gather_global(&c);
            let mut y = vec![0.0; g.nrows];
            for i in 0..g.nrows {
                let (cols, vals) = g.row(i);
                let mut acc = b.vals[i];
                let mut diag = 1.0;
                for (&cc, &vv) in cols.iter().zip(vals) {
                    if cc as usize == i {
                        diag = vv;
                    } else {
                        acc -= vv * y[cc as usize];
                    }
                }
                y[i] = acc / diag;
            }
            for i in 0..g.nrows {
                assert!((x.vals[i] - y[i]).abs() < 1e-12, "row {i}");
            }
        });
    }

    /// Irregular layouts: a rank with zero rows and a rank whose offd is
    /// empty must survive every smoother (collective lockstep, no
    /// indexing slips).
    #[test]
    fn smoothers_survive_empty_rank_and_empty_offd() {
        use crate::dist::{DistCsrBuilder, Layout};
        // three ranks: [5, 0, 4] rows of a global tridiagonal
        let w = World::new(3);
        w.run(|c| {
            let layout = Layout::from_counts(&[5, 0, 4]);
            let n = layout.global_size();
            let mut bld = DistCsrBuilder::new(c.rank(), layout.clone(), layout.clone());
            let mut row: Vec<(u64, f64)> = Vec::new();
            for g in layout.range(c.rank()) {
                row.clear();
                if g > 0 {
                    row.push((g as u64 - 1, -1.0));
                }
                row.push((g as u64, 4.0));
                if g + 1 < n {
                    row.push((g as u64 + 1, -1.0));
                }
                bld.push_row(&row);
            }
            let a = bld.finish();
            let spmv = DistSpmv::new(&c, &a);
            let op = CsrOperator::new(&a, &spmv);
            let b = DistVec::from_fn(layout.clone(), c.rank(), |g| (g as f64) - 3.0);
            let mut work = DistVec::zeros(layout.clone(), c.rank());

            let mut x = DistVec::zeros(layout.clone(), c.rank());
            let cheb = ChebyshevSmoother::new(&c, &op, 3);
            for _ in 0..4 {
                cheb.sweep(&c, &op, &b, &mut x, &mut work);
            }
            let mut rv = b.clone();
            op.apply(&c, &x, &mut work);
            rv.axpy(-1.0, &work);
            let r_cheb = rv.norm2(&c);

            let mut x = DistVec::zeros(layout.clone(), c.rank());
            let sor = HybridSorSmoother::new(&op, 1.0);
            for _ in 0..4 {
                sor.sweep(&c, &op, &b, &mut x);
            }
            let mut rv = b.clone();
            op.apply(&c, &x, &mut work);
            rv.axpy(-1.0, &work);
            let r_sor = rv.norm2(&c);

            let r0 = b.norm2(&c);
            assert!(r_cheb < 0.5 * r0, "chebyshev {r_cheb} vs {r0}");
            assert!(r_sor < 0.5 * r0, "sor {r_sor} vs {r0}");
        });
    }

    /// Empty-offd rank: a block-diagonal matrix (no cross-rank coupling)
    /// exercises the n_needed == 0 halo path of every sweep.
    #[test]
    fn smoothers_on_block_diagonal_no_offd() {
        use crate::dist::{DistCsrBuilder, Layout};
        let w = World::new(2);
        w.run(|c| {
            let layout = Layout::new_equal(8, c.size());
            let mut bld = DistCsrBuilder::new(c.rank(), layout.clone(), layout.clone());
            let (lo, hi) = (layout.start(c.rank()), layout.end(c.rank()));
            let mut row: Vec<(u64, f64)> = Vec::new();
            for g in layout.range(c.rank()) {
                row.clear();
                if g > lo {
                    row.push((g as u64 - 1, -1.0));
                }
                row.push((g as u64, 3.0));
                if g + 1 < hi {
                    row.push((g as u64 + 1, -1.0));
                }
                bld.push_row(&row);
            }
            let a = bld.finish();
            assert_eq!(a.offd.nnz(), 0);
            let spmv = DistSpmv::new(&c, &a);
            let op = CsrOperator::new(&a, &spmv);
            let b = DistVec::from_fn(layout.clone(), c.rank(), |_| 1.0);
            let mut work = DistVec::zeros(layout.clone(), c.rank());
            let mut x = DistVec::zeros(layout.clone(), c.rank());
            let cheb = ChebyshevSmoother::new(&c, &op, 2);
            cheb.sweep(&c, &op, &b, &mut x, &mut work);
            let sor = HybridSorSmoother::new(&op, 1.2);
            sor.sweep(&c, &op, &b, &mut x);
            op.apply(&c, &x, &mut work);
            let mut rv = b.clone();
            rv.axpy(-1.0, &work);
            assert!(rv.norm2(&c) < b.norm2(&c), "sweeps must make progress");
        });
    }
}
