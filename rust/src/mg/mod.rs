//! Multigrid substrate: the system the paper's triple products live in.
//!
//! A Galerkin hierarchy is built by repeated `C = PᵀAP` (with any of the
//! three [`crate::ptap::Algo`]s), then used as a V-cycle preconditioner
//! for CG.  Coarsening is geometric (structured grids, the model problem)
//! or algebraic (greedy strength-based aggregation + optional Jacobi
//! prolongator smoothing — the neutron problem's twelve-level setup).

mod aggregate;
mod cycle;
mod gmres;
mod hierarchy;
mod smoother;
mod solver;
mod transfer;

pub use aggregate::{aggregate_interp, aggregate_interp_with_refresh, AggregateOpts, InterpRefresh};
pub use cycle::{CycleType, MgOpts, MgPreconditioner};
pub use hierarchy::{
    build_hierarchy, build_hierarchy_matrix_free, geometric_chain, Coarsening, Hierarchy,
    HierarchyConfig, InterpStats, Level, LevelOp, LevelStats, OpHandle,
};
pub use gmres::{gmres, gmres_multi};
pub use smoother::{
    chebyshev_bounds, ChebyshevSmoother, HybridSorSmoother, JacobiSmoother, SmootherKind,
};
pub use solver::{pcg, pcg_multi, richardson, SolveResult};
pub use transfer::Transfer;
