//! Galerkin hierarchy construction: repeated `C = PᵀAP` with a selectable
//! triple-product algorithm — the paper's actual use case ("eleven
//! interpolations and twelve operator matrices", Table 5/6), including the
//! cached-vs-freed intermediate-data protocols of Tables 7/8.
//!
//! Coarse-level rank agglomeration: with [`HierarchyConfig::eq_limit`]
//! set, a level whose global rows fall under `eq_limit × active_ranks` is
//! telescoped — its `A` and `P` are redistributed onto
//! `⌈rows / eq_limit⌉` active ranks via [`crate::agglomerate`], the
//! triple product runs entirely inside the sub-communicator, and every
//! coarser level lives there too (telescoping again if it shrinks
//! enough).  Idle ranks' hierarchies end at the boundary level; they
//! rejoin only at the boundary's vector scatter/gather during cycling.

use std::rc::Rc;

use crate::agglomerate::{choose_active_ranks, telescope_operators, Telescope};
use crate::dist::{Comm, CommStats, CsrOperator, DistCsr, DistOperator, DistSpmv, DistVec, Layout};
use crate::gen::{trilinear_interp, Grid3, StencilOperator};
use crate::mem::{Cat, MemTracker};
use crate::ptap::{Algo, Ptap, PtapStats};
use crate::reuse::RetainedLevel;

use super::aggregate::{aggregate_interp_with_refresh, AggregateOpts};

/// How interpolations are produced.
#[derive(Debug, Clone)]
pub enum Coarsening {
    /// Geometric chain of grids, coarsest first (model problem): level k
    /// interpolates from `grids[k+1]` onto `grids[k]`.
    Geometric { grids: Vec<Grid3> },
    /// Strength-based aggregation (neutron problem).
    Aggregation { opts: AggregateOpts, min_rows: usize, max_levels: usize },
}

/// Hierarchy build protocol knobs (the experiment axes of Tables 7/8).
#[derive(Debug, Clone, Copy)]
pub struct HierarchyConfig {
    pub algo: Algo,
    /// Keep each level's triple-product context (plans, auxiliaries)
    /// alive after the level is built — "caching intermediate data"
    /// (Table 8).  When false the context is dropped per level (Table 7).
    pub cache: bool,
    /// Numeric products per level (the paper re-runs numeric 1–11 times).
    pub numeric_repeats: usize,
    /// Rows-per-rank agglomeration knob (PETSc
    /// `-pc_gamg_process_eq_limit` analog): a level with fewer than
    /// `eq_limit × active_ranks` global rows telescopes onto
    /// `⌈rows / eq_limit⌉` ranks.  `None` disables agglomeration.
    pub eq_limit: Option<usize>,
    /// Retain everything a hierarchy-wide numeric refresh needs (the
    /// `MAT_REUSE_MATRIX` analog): each level's triple-product context
    /// *and* the telescoped `A`/`P` copies, collected into
    /// [`Hierarchy::retained`] for [`crate::reuse::HierarchyRefresher`].
    /// Supersedes `cache` (the ops live in `retained`, not `cached_ops`).
    pub retain: bool,
}

impl Default for HierarchyConfig {
    fn default() -> Self {
        HierarchyConfig {
            algo: Algo::AllAtOnce,
            cache: false,
            numeric_repeats: 1,
            eq_limit: None,
            retain: false,
        }
    }
}

/// Per-level operator statistics (Table 5 columns).
#[derive(Debug, Clone, Copy)]
pub struct LevelStats {
    pub rows: u64,
    pub nnz: u64,
    pub cols_min: u64,
    pub cols_max: u64,
    pub cols_avg: f64,
}

/// Per-level interpolation statistics (Table 6 columns).
#[derive(Debug, Clone, Copy)]
pub struct InterpStats {
    pub rows: u64,
    pub cols: u64,
    pub cols_min: u64,
    pub cols_max: u64,
}

/// How a level stores its operator: assembled tables, or the matrix-free
/// stencil form (level 0 of a structured-grid hierarchy — O(stencil)
/// memory instead of O(nnz)).
pub enum LevelOp {
    Csr(DistCsr),
    Stencil(StencilOperator),
}

impl LevelOp {
    /// The assembled tables; panics on a matrix-free level (callers that
    /// can face one must match instead).
    pub fn csr(&self) -> &DistCsr {
        match self {
            LevelOp::Csr(a) => a,
            LevelOp::Stencil(_) => panic!("level is matrix-free: no assembled CSR"),
        }
    }

    pub fn csr_mut(&mut self) -> &mut DistCsr {
        match self {
            LevelOp::Csr(a) => a,
            LevelOp::Stencil(_) => panic!("level is matrix-free: no assembled CSR"),
        }
    }

    pub fn is_matrix_free(&self) -> bool {
        matches!(self, LevelOp::Stencil(_))
    }

    pub fn row_layout(&self) -> &Layout {
        match self {
            LevelOp::Csr(a) => &a.row_layout,
            LevelOp::Stencil(s) => &s.layout,
        }
    }

    pub fn rank(&self) -> usize {
        match self {
            LevelOp::Csr(a) => a.rank,
            LevelOp::Stencil(s) => s.rank,
        }
    }

    pub fn local_nrows(&self) -> usize {
        self.row_layout().local_size(self.rank())
    }

    pub fn bytes(&self) -> u64 {
        match self {
            LevelOp::Csr(a) => a.bytes(),
            LevelOp::Stencil(s) => s.bytes(),
        }
    }

    pub fn nnz_global(&self, comm: &Comm) -> u64 {
        match self {
            LevelOp::Csr(a) => a.nnz_global(comm),
            LevelOp::Stencil(s) => s.nnz_global(comm),
        }
    }

    pub fn row_nnz_stats(&self, comm: &Comm) -> (u64, u64, f64) {
        match self {
            LevelOp::Csr(a) => a.row_nnz_stats(comm),
            LevelOp::Stencil(s) => s.row_nnz_stats(comm),
        }
    }

    /// The [`DistOperator`] view: a CSR level borrows its prebuilt
    /// [`DistSpmv`] plan (must be `Some`), a stencil level applies itself.
    pub fn operator<'a>(&'a self, spmv: Option<&'a DistSpmv>) -> OpHandle<'a> {
        match self {
            LevelOp::Csr(a) => {
                OpHandle::Csr(CsrOperator::new(a, spmv.expect("CSR level needs its DistSpmv")))
            }
            LevelOp::Stencil(s) => OpHandle::Stencil(s),
        }
    }
}

/// Borrowed [`DistOperator`] over a level (CSR view or stencil).
pub enum OpHandle<'a> {
    Csr(CsrOperator<'a>),
    Stencil(&'a StencilOperator),
}

impl DistOperator for OpHandle<'_> {
    fn rank(&self) -> usize {
        match self {
            OpHandle::Csr(o) => o.rank(),
            OpHandle::Stencil(s) => DistOperator::rank(*s),
        }
    }

    fn row_layout(&self) -> &Layout {
        match self {
            OpHandle::Csr(o) => o.row_layout(),
            OpHandle::Stencil(s) => DistOperator::row_layout(*s),
        }
    }

    fn apply(&self, comm: &Comm, x: &DistVec, y: &mut DistVec) {
        match self {
            OpHandle::Csr(o) => o.apply(comm, x, y),
            OpHandle::Stencil(s) => s.apply(comm, x, y),
        }
    }

    fn diagonal(&self) -> Vec<f64> {
        match self {
            OpHandle::Csr(o) => o.diagonal(),
            OpHandle::Stencil(s) => s.diagonal(),
        }
    }

    fn row_norms1(&self) -> Vec<f64> {
        match self {
            OpHandle::Csr(o) => o.row_norms1(),
            OpHandle::Stencil(s) => s.row_norms1(),
        }
    }

    fn row_nnz_stats(&self, comm: &Comm) -> (u64, u64, f64) {
        match self {
            OpHandle::Csr(o) => o.row_nnz_stats(comm),
            OpHandle::Stencil(s) => DistOperator::row_nnz_stats(*s, comm),
        }
    }

    fn nnz_global(&self, comm: &Comm) -> u64 {
        match self {
            OpHandle::Csr(o) => o.nnz_global(comm),
            OpHandle::Stencil(s) => DistOperator::nnz_global(*s, comm),
        }
    }

    fn bytes(&self) -> u64 {
        match self {
            OpHandle::Csr(o) => DistOperator::bytes(o),
            OpHandle::Stencil(s) => DistOperator::bytes(*s),
        }
    }

    fn sor_sweep(
        &self,
        comm: &Comm,
        dinv: &[f64],
        omega: f64,
        b: &DistVec,
        x: &mut DistVec,
        symmetric: bool,
    ) {
        match self {
            OpHandle::Csr(o) => o.sor_sweep(comm, dinv, omega, b, x, symmetric),
            OpHandle::Stencil(s) => s.sor_sweep(comm, dinv, omega, b, x, symmetric),
        }
    }

    fn halo_reuses(&self) -> u64 {
        match self {
            OpHandle::Csr(o) => o.halo_reuses(),
            OpHandle::Stencil(s) => DistOperator::halo_reuses(*s),
        }
    }
}

/// One level: its operator, the interpolation to the next coarser one,
/// and — when the next level was agglomerated — the telescope boundary
/// sitting below it.
pub struct Level {
    pub a: LevelOp,
    pub p: Option<DistCsr>,
    /// `Some` when the next-coarser level lives on a sub-communicator
    /// (shared with the preconditioner's level contexts).
    pub telescope: Option<Rc<Telescope>>,
}

/// The built hierarchy plus everything the experiments report.
///
/// With agglomeration on, the fields are *rank-local*: an idle rank's
/// `levels` (and per-level stats) end at its last telescope boundary.
/// Rank 0 is always in the active prefix, so it sees the full hierarchy.
pub struct Hierarchy {
    pub levels: Vec<Level>,
    pub op_stats: Vec<LevelStats>,
    pub interp_stats: Vec<InterpStats>,
    /// Summed triple-product stats across levels (this rank).
    pub ptap_stats: PtapStats,
    /// Retained triple-product contexts when `cache` is on.
    pub cached_ops: Vec<Ptap>,
    /// Ranks holding each level (world size until the first boundary,
    /// then the active counts).
    pub active_ranks: Vec<usize>,
    /// This rank's traffic during each coarse level's triple product and
    /// stats collectives (index l = the build of level l+1's operator) —
    /// the per-level α/β evidence the bench artifact diffs.
    pub level_comm: Vec<CommStats>,
    /// This rank's traffic spent redistributing operators across
    /// telescope boundaries (split + scatter epochs).
    pub redist_comm: CommStats,
    /// One entry per built triple product when
    /// [`HierarchyConfig::retain`] is set: the symbolic state a
    /// hierarchy-wide numeric refresh replays (empty otherwise).
    pub retained: Vec<RetainedLevel>,
}

impl Hierarchy {
    pub fn n_levels(&self) -> usize {
        self.levels.len()
    }

    /// Local storage bytes of all operators + interpolations.
    pub fn matrix_bytes(&self) -> u64 {
        self.levels
            .iter()
            .map(|l| l.a.bytes() + l.p.as_ref().map_or(0, |p| p.bytes()))
            .sum()
    }
}

fn op_stats(comm: &Comm, a: &DistCsr) -> LevelStats {
    let (cols_min, cols_max, cols_avg) = a.row_nnz_stats(comm);
    LevelStats {
        rows: comm.allreduce_sum_u64(a.local_nrows() as u64),
        nnz: a.nnz_global(comm),
        cols_min,
        cols_max,
        cols_avg,
    }
}

fn op_stats_level(comm: &Comm, a: &LevelOp) -> LevelStats {
    let (cols_min, cols_max, cols_avg) = a.row_nnz_stats(comm);
    LevelStats {
        rows: comm.allreduce_sum_u64(a.local_nrows() as u64),
        nnz: a.nnz_global(comm),
        cols_min,
        cols_max,
        cols_avg,
    }
}

fn interp_stats(comm: &Comm, p: &DistCsr) -> InterpStats {
    let (cols_min, cols_max, _) = p.row_nnz_stats(comm);
    InterpStats {
        rows: comm.allreduce_sum_u64(p.local_nrows() as u64),
        cols: p.global_ncols() as u64,
        cols_min,
        cols_max,
    }
}

/// Build the hierarchy (collective).  `a0` is the finest operator; its
/// storage is charged to the tracker as `MatA` by the caller.
///
/// With [`HierarchyConfig::eq_limit`] set, small levels telescope onto a
/// rank prefix before their triple product: the current communicator is
/// split, `A`/`P` are redistributed, the PtAP (and all coarser work)
/// runs inside the sub-communicator, and idle ranks return immediately
/// with a hierarchy that ends at the boundary level.
pub fn build_hierarchy(
    comm: &Comm,
    a0: DistCsr,
    coarsening: &Coarsening,
    cfg: HierarchyConfig,
    tracker: &MemTracker,
) -> Hierarchy {
    build_hierarchy_op(comm, LevelOp::Csr(a0), coarsening, cfg, tracker)
}

/// Build a hierarchy whose finest level is matrix-free (collective):
/// level 0 holds only the stencil coefficients and footprint halo plan.
/// When a coarser level must be built, `A₀` is assembled once into a
/// scratch charged to [`Cat::Aux`] and dropped right after the level-1
/// triple product — the tracker shows the level-0 CSR savings either way.
pub fn build_hierarchy_matrix_free(
    comm: &Comm,
    a0: StencilOperator,
    coarsening: &Coarsening,
    cfg: HierarchyConfig,
    tracker: &MemTracker,
) -> Hierarchy {
    build_hierarchy_op(comm, LevelOp::Stencil(a0), coarsening, cfg, tracker)
}

fn build_hierarchy_op(
    comm: &Comm,
    a0: LevelOp,
    coarsening: &Coarsening,
    cfg: HierarchyConfig,
    tracker: &MemTracker,
) -> Hierarchy {
    let _sp = crate::obs::span(crate::obs::Subsys::Mg, "build_hierarchy", 0);
    let mut cur = comm.clone();
    let mut levels: Vec<Level> = Vec::new();
    let mut op_stats_v = vec![op_stats_level(&cur, &a0)];
    let mut interp_stats_v = Vec::new();
    let mut active_ranks = vec![cur.size()];
    let mut level_comm: Vec<CommStats> = Vec::new();
    let mut redist_comm = CommStats::default();
    let mut total = PtapStats::default();
    let mut cached_ops = Vec::new();
    let mut retained: Vec<RetainedLevel> = Vec::new();

    let mut a = a0;
    let mut k = 0usize;
    loop {
        // decide whether to coarsen further (collective sequence is
        // identical to the historical per-variant checks)
        let will_coarsen = match coarsening {
            Coarsening::Geometric { grids } => {
                if k + 1 < grids.len() {
                    debug_assert_eq!(grids[k + 1].refine(), grids[k], "grid chain broken");
                    true
                } else {
                    false
                }
            }
            Coarsening::Aggregation { min_rows, max_levels, .. } => {
                let global_rows = cur.allreduce_sum_u64(a.local_nrows() as u64);
                global_rows > *min_rows as u64 && k + 1 < *max_levels
            }
        };
        if !will_coarsen {
            levels.push(Level { a, p: None, telescope: None });
            break;
        }
        // a matrix-free level assembles its tables once into a scratch
        // for everything the coarsening needs explicit CSR for (strength
        // graph, telescoping, the triple product); the scratch is dropped
        // as soon as the next level's operator exists
        let scratch: Option<DistCsr> = match &a {
            LevelOp::Stencil(s) => {
                let m = s.assemble();
                tracker.alloc(Cat::Aux, m.bytes());
                Some(m)
            }
            LevelOp::Csr(_) => None,
        };
        let scratch_bytes = scratch.as_ref().map_or(0, |m| m.bytes());
        let free_scratch = |sc: Option<DistCsr>| {
            if sc.is_some() {
                tracker.free(Cat::Aux, scratch_bytes);
            }
        };
        let a_csr: &DistCsr = match &scratch {
            Some(m) => m,
            None => a.csr(),
        };
        let (p, mut interp_refresh) = match coarsening {
            Coarsening::Geometric { grids } => {
                (trilinear_interp(grids[k + 1], cur.rank(), cur.size()), None)
            }
            Coarsening::Aggregation { opts, .. } => {
                let (p, ir) = aggregate_interp_with_refresh(&cur, a_csr, *opts, cfg.retain);
                (p, ir)
            }
        };
        tracker.alloc(Cat::MatP, p.bytes());
        interp_stats_v.push(interp_stats(&cur, &p));

        // agglomeration decision: this level's global rows vs the knob
        let n_rows = op_stats_v[k].rows as usize;
        let tel_k = cfg
            .eq_limit
            .map(|eq| choose_active_ranks(n_rows, cur.size(), eq))
            .filter(|&kact| kact < cur.size());

        if let Some(kact) = tel_k {
            // telescope A and P onto the active prefix; the triple
            // product (and everything coarser) runs inside the subcomm
            let before = cur.stats_global();
            let (tel, ops) = telescope_operators(&cur, a_csr, &p, kact);
            let delta = cur.stats_global().since(before);
            redist_comm.merge(delta);
            let telescoped_bytes = ops.as_ref().map_or(0, |(at, pt)| at.bytes() + pt.bytes());
            tracker.alloc(Cat::Comm, tel.bytes() + telescoped_bytes);
            let subcomm = tel.subcomm.clone();
            levels.push(Level { a, p: Some(p), telescope: Some(Rc::new(tel)) });
            active_ranks.push(kact);
            let (Some(sc), Some((a_t, p_t))) = (subcomm, ops) else {
                // idle rank: its hierarchy ends at the boundary level (a
                // retain-mode refresh still replays the boundary's
                // value-only redistribution — and the local P value
                // recompute — so mark the slot)
                free_scratch(scratch);
                if cfg.retain {
                    retained.push(RetainedLevel {
                        op: None,
                        tele_ops: None,
                        interp: interp_refresh.take(),
                    });
                }
                break;
            };
            free_scratch(scratch);
            let before = sc.stats_global();
            let mut op = Ptap::symbolic(cfg.algo, &sc, &a_t, &p_t, tracker);
            for _ in 0..cfg.numeric_repeats {
                op.numeric(&sc, &a_t, &p_t);
            }
            let c = op.extract_c();
            tracker.alloc(Cat::MatC, c.bytes());
            total = sum_stats(total, op.stats);
            op_stats_v.push(op_stats(&sc, &c));
            level_comm.push(sc.stats_global().since(before));
            if cfg.retain {
                // keep the op, the telescoped copies and their Comm
                // charge alive: the refresh resends values over the
                // retained fine plan and re-runs numeric in place
                retained.push(RetainedLevel {
                    op: Some(op),
                    tele_ops: Some((a_t, p_t)),
                    interp: interp_refresh.take(),
                });
            } else {
                if cfg.cache {
                    cached_ops.push(op);
                } else {
                    drop(op);
                }
                // the telescoped copies served the build; release them
                tracker.free(Cat::Comm, telescoped_bytes);
                drop((a_t, p_t));
            }
            cur = sc;
            a = LevelOp::Csr(c);
        } else {
            // the paper's protocol: one symbolic + `numeric_repeats`
            // numerics on the current communicator
            let before = cur.stats_global();
            let mut op = Ptap::symbolic(cfg.algo, &cur, a_csr, &p, tracker);
            for _ in 0..cfg.numeric_repeats {
                op.numeric(&cur, a_csr, &p);
            }
            let c = op.extract_c();
            free_scratch(scratch);
            tracker.alloc(Cat::MatC, c.bytes());
            total = sum_stats(total, op.stats);
            if cfg.retain {
                retained.push(RetainedLevel {
                    op: Some(op),
                    tele_ops: None,
                    interp: interp_refresh.take(),
                });
            } else if cfg.cache {
                cached_ops.push(op);
            } else {
                drop(op);
            }
            op_stats_v.push(op_stats(&cur, &c));
            level_comm.push(cur.stats_global().since(before));
            active_ranks.push(cur.size());
            levels.push(Level { a, p: Some(p), telescope: None });
            a = LevelOp::Csr(c);
        }
        k += 1;
    }

    Hierarchy {
        levels,
        op_stats: op_stats_v,
        interp_stats: interp_stats_v,
        ptap_stats: total,
        cached_ops,
        active_ranks,
        level_comm,
        redist_comm,
        retained,
    }
}

fn sum_stats(mut acc: PtapStats, s: PtapStats) -> PtapStats {
    acc.add(s);
    acc
}

/// Geometric grid chain: `levels` grids ending at `coarsest` (each finer
/// grid is the refinement of the next), finest first.
/// (exported for examples and benches)
pub fn geometric_chain(coarsest: Grid3, levels: usize) -> Vec<Grid3> {
    let mut grids = vec![coarsest];
    for _ in 1..levels {
        let f = grids.last().unwrap().refine();
        grids.push(f);
    }
    grids.reverse();
    grids
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::World;
    use crate::gen::{grid_laplacian, Grid3};

    #[test]
    fn geometric_chain_links() {
        let grids = geometric_chain(Grid3::cube(3), 3);
        assert_eq!(grids.len(), 3);
        assert_eq!(grids[2], Grid3::cube(3));
        assert_eq!(grids[1], Grid3::cube(5));
        assert_eq!(grids[0], Grid3::cube(9));
    }

    #[test]
    fn geometric_hierarchy_builds_and_coarsens() {
        let w = World::new(2);
        w.run(|c| {
            let grids = geometric_chain(Grid3::cube(3), 3);
            let a0 = grid_laplacian(grids[0], c.rank(), c.size());
            let tracker = MemTracker::new();
            tracker.alloc(Cat::MatA, a0.bytes());
            let h = build_hierarchy(
                &c,
                a0,
                &Coarsening::Geometric { grids: grids.clone() },
                HierarchyConfig::default(),
                &tracker,
            );
            assert_eq!(h.n_levels(), 3);
            assert_eq!(h.op_stats[0].rows, 9 * 9 * 9);
            assert_eq!(h.op_stats[1].rows, 5 * 5 * 5);
            assert_eq!(h.op_stats[2].rows, 27);
            // Galerkin operators stay symmetric for symmetric A and full-rank P
            let coarsest = h.levels[2].a.csr().gather_global(&c);
            assert!(coarsest.max_abs_diff(&coarsest.transpose()) < 1e-10);
        });
    }

    #[test]
    fn aggregation_hierarchy_reaches_min_rows() {
        let w = World::new(2);
        w.run(|c| {
            let a0 = grid_laplacian(Grid3::cube(8), c.rank(), c.size());
            let tracker = MemTracker::new();
            let h = build_hierarchy(
                &c,
                a0,
                &Coarsening::Aggregation {
                    opts: AggregateOpts::default(),
                    min_rows: 10,
                    max_levels: 10,
                },
                HierarchyConfig::default(),
                &tracker,
            );
            assert!(h.n_levels() >= 3, "only {} levels", h.n_levels());
            // rows strictly decrease
            for w2 in h.op_stats.windows(2) {
                assert!(w2[1].rows < w2[0].rows);
            }
        });
    }

    #[test]
    fn cache_retains_contexts_and_memory() {
        let w = World::new(2);
        w.run(|c| {
            let grids = geometric_chain(Grid3::cube(3), 2);
            let build = |cache: bool, c: &Comm| {
                let a0 = grid_laplacian(grids[0], c.rank(), c.size());
                let tracker = MemTracker::new();
                let h = build_hierarchy(
                    c,
                    a0,
                    &Coarsening::Geometric { grids: grids.clone() },
                    HierarchyConfig { cache, ..Default::default() },
                    &tracker,
                );
                (h.cached_ops.len(), tracker.current_total(), tracker.peak_total())
            };
            let (n_nc, cur_nc, _peak_nc) = build(false, &c);
            let (n_c, cur_c, _peak_c) = build(true, &c);
            assert_eq!(n_nc, 0);
            assert_eq!(n_c, 1);
            assert!(cur_c > cur_nc, "cached {} vs freed {}", cur_c, cur_nc);
        });
    }

    #[test]
    fn all_algorithms_build_identical_hierarchies() {
        let w = World::new(3);
        w.run(|c| {
            let grids = geometric_chain(Grid3::cube(3), 3);
            let mut coarsest: Vec<crate::mat::Csr> = Vec::new();
            for algo in crate::ptap::ALL_ALGOS {
                let a0 = grid_laplacian(grids[0], c.rank(), c.size());
                let tracker = MemTracker::new();
                let h = build_hierarchy(
                    &c,
                    a0,
                    &Coarsening::Geometric { grids: grids.clone() },
                    HierarchyConfig { algo, ..Default::default() },
                    &tracker,
                );
                coarsest.push(h.levels.last().unwrap().a.csr().gather_global(&c));
            }
            assert!(coarsest[0].max_abs_diff(&coarsest[1]) < 1e-10);
            assert!(coarsest[0].max_abs_diff(&coarsest[2]) < 1e-10);
        });
    }
}
