//! Restarted GMRES — the outer solver the paper's neutron runs use
//! (Saad & Schultz 1986), right-preconditioned by the V-cycle.
//!
//! The transport-like operator is nonsymmetric (upwinded streaming), so
//! CG's assumptions do not hold; GMRES(m) is the appropriate Krylov
//! method and what RattleSnake/PETSc run.

use crate::dist::{Comm, DistOperator, DistVec};

use super::cycle::MgPreconditioner;
use super::solver::SolveResult;

/// Right-preconditioned restarted GMRES(m): solve `A M⁻¹ (M x) = b`.
/// `pc = None` runs plain GMRES.
#[allow(clippy::too_many_arguments)]
pub fn gmres(
    comm: &Comm,
    a: &dyn DistOperator,
    b: &DistVec,
    x: &mut DistVec,
    mut pc: Option<&mut MgPreconditioner>,
    restart: usize,
    rtol: f64,
    max_iters: usize,
) -> SolveResult {
    let layout = a.row_layout().clone();
    let rank = comm.rank();
    let m = restart.max(1);

    let mut r = DistVec::zeros(layout.clone(), rank);
    let mut w = DistVec::zeros(layout.clone(), rank);
    let mut z = DistVec::zeros(layout.clone(), rank);

    // r = b - A x
    a.apply(comm, x, &mut w);
    r.vals.clone_from(&b.vals);
    for i in 0..r.vals.len() {
        r.vals[i] -= w.vals[i];
    }
    let r0 = r.norm2(comm);
    let mut residuals = vec![r0];
    if r0 == 0.0 {
        return SolveResult { iterations: 0, converged: true, residuals };
    }
    let target = rtol * r0;

    let mut total_iters = 0usize;
    'outer: loop {
        // Arnoldi basis (distributed vectors) + Hessenberg (replicated)
        let beta = r.norm2(comm);
        if beta <= target {
            return SolveResult { iterations: total_iters, converged: true, residuals };
        }
        let mut v: Vec<DistVec> = Vec::with_capacity(m + 1);
        let mut v0 = r.clone();
        v0.scale(1.0 / beta);
        v.push(v0);
        // Hessenberg in column-major (m+1) x m, plus Givens rotations
        let mut h = vec![0.0f64; (m + 1) * m];
        let mut cs = vec![0.0f64; m];
        let mut sn = vec![0.0f64; m];
        let mut g = vec![0.0f64; m + 1];
        g[0] = beta;
        let mut k_used = 0usize;

        for k in 0..m {
            // w = A M⁻¹ v_k
            match pc.as_deref_mut() {
                Some(p) => {
                    p.apply(comm, &v[k], &mut z);
                    a.apply(comm, &z, &mut w);
                }
                None => a.apply(comm, &v[k], &mut w),
            }
            // modified Gram-Schmidt
            for j in 0..=k {
                let hjk = w.dot(comm, &v[j]);
                h[j * m + k] = hjk;
                w.axpy(-hjk, &v[j]);
            }
            let hk1 = w.norm2(comm);
            h[(k + 1) * m + k] = hk1;
            // apply accumulated Givens rotations to column k
            for j in 0..k {
                let t = cs[j] * h[j * m + k] + sn[j] * h[(j + 1) * m + k];
                h[(j + 1) * m + k] = -sn[j] * h[j * m + k] + cs[j] * h[(j + 1) * m + k];
                h[j * m + k] = t;
            }
            // new rotation to annihilate h[k+1][k]
            let denom = (h[k * m + k] * h[k * m + k] + hk1 * hk1).sqrt();
            if denom == 0.0 {
                k_used = k;
                break;
            }
            cs[k] = h[k * m + k] / denom;
            sn[k] = hk1 / denom;
            h[k * m + k] = denom;
            g[k + 1] = -sn[k] * g[k];
            g[k] *= cs[k];
            total_iters += 1;
            k_used = k + 1;
            let res = g[k + 1].abs();
            residuals.push(res);
            if res <= target || total_iters >= max_iters {
                break;
            }
            if hk1 == 0.0 {
                break; // lucky breakdown
            }
            let mut vk1 = w.clone();
            vk1.scale(1.0 / hk1);
            v.push(vk1);
        }

        // back-substitute y from the k_used x k_used triangular system
        let kk = k_used;
        let mut y = vec![0.0f64; kk];
        for i in (0..kk).rev() {
            let mut s = g[i];
            for j in i + 1..kk {
                s -= h[i * m + j] * y[j];
            }
            y[i] = s / h[i * m + i];
        }
        // x += M⁻¹ (V y)
        let mut update = DistVec::zeros(layout.clone(), rank);
        for (j, &yj) in y.iter().enumerate() {
            update.axpy(yj, &v[j]);
        }
        match pc.as_deref_mut() {
            Some(p) => {
                p.apply(comm, &update, &mut z);
                for i in 0..x.vals.len() {
                    x.vals[i] += z.vals[i];
                }
            }
            None => {
                for i in 0..x.vals.len() {
                    x.vals[i] += update.vals[i];
                }
            }
        }
        // true residual for the restart
        a.apply(comm, x, &mut w);
        r.vals.clone_from(&b.vals);
        for i in 0..r.vals.len() {
            r.vals[i] -= w.vals[i];
        }
        let rn = r.norm2(comm);
        *residuals.last_mut().unwrap() = rn;
        if rn <= target {
            return SolveResult { iterations: total_iters, converged: true, residuals };
        }
        if total_iters >= max_iters {
            break 'outer;
        }
    }
    SolveResult { iterations: total_iters, converged: false, residuals }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{CsrOperator, DistSpmv, World};
    use crate::gen::{grid_laplacian, neutron_block_operator, Grid3, NeutronConfig};
    use crate::mem::MemTracker;
    use crate::mg::cycle::MgOpts;
    use crate::mg::hierarchy::{build_hierarchy, geometric_chain, Coarsening, HierarchyConfig};

    #[test]
    fn gmres_solves_spd_system() {
        let w = World::new(2);
        w.run(|c| {
            let a = grid_laplacian(Grid3::cube(4), c.rank(), c.size());
            let spmv = DistSpmv::new(&c, &a);
            let op = CsrOperator::new(&a, &spmv);
            let layout = a.row_layout.clone();
            let xs = DistVec::from_fn(layout.clone(), c.rank(), |g| ((g % 9) as f64) - 4.0);
            let mut b = DistVec::zeros(layout.clone(), c.rank());
            op.apply(&c, &xs, &mut b);
            let mut x = DistVec::zeros(layout, c.rank());
            let res = gmres(&c, &op, &b, &mut x, None, 30, 1e-10, 400);
            assert!(res.converged, "residuals: {:?}", res.residuals.last());
            let mut err = x.clone();
            err.axpy(-1.0, &xs);
            assert!(err.norm2(&c) < 1e-6);
        });
    }

    #[test]
    fn gmres_handles_nonsymmetric_transport_operator() {
        let w = World::new(2);
        w.run(|c| {
            let cfg = NeutronConfig { grid: Grid3::cube(4), groups: 4, seed: 11 };
            let ab = neutron_block_operator(cfg, c.rank(), c.size());
            let a = ab.to_scalar();
            let spmv = DistSpmv::new(&c, &a);
            let op = CsrOperator::new(&a, &spmv);
            let layout = a.row_layout.clone();
            let b = DistVec::from_fn(layout.clone(), c.rank(), |_| 1.0);
            let mut x = DistVec::zeros(layout, c.rank());
            let res = gmres(&c, &op, &b, &mut x, None, 30, 1e-8, 400);
            assert!(res.converged, "GMRES stalled on the transport operator");
        });
    }

    #[test]
    fn mg_preconditioned_gmres_beats_plain() {
        let w = World::new(2);
        w.run(|c| {
            let grids = geometric_chain(Grid3::cube(4), 3);
            let a0 = grid_laplacian(grids[0], c.rank(), c.size());
            let a = a0.clone();
            let tracker = MemTracker::new();
            let h = build_hierarchy(
                &c,
                a0,
                &Coarsening::Geometric { grids },
                HierarchyConfig::default(),
                &tracker,
            );
            let spmv = DistSpmv::new(&c, &a);
            let op = CsrOperator::new(&a, &spmv);
            let mut pc = MgPreconditioner::new(&c, h, MgOpts::default());
            let layout = a.row_layout.clone();
            let b = DistVec::from_fn(layout.clone(), c.rank(), |_| 1.0);
            let mut x1 = DistVec::zeros(layout.clone(), c.rank());
            let with_pc = gmres(&c, &op, &b, &mut x1, Some(&mut pc), 30, 1e-8, 300);
            let mut x2 = DistVec::zeros(layout, c.rank());
            let plain = gmres(&c, &op, &b, &mut x2, None, 30, 1e-8, 300);
            assert!(with_pc.converged);
            assert!(
                with_pc.iterations < plain.iterations,
                "MG-GMRES {} vs plain {}",
                with_pc.iterations,
                plain.iterations
            );
        });
    }

    #[test]
    fn restart_does_not_break_convergence() {
        let w = World::new(1);
        w.run(|c| {
            let a = grid_laplacian(Grid3::cube(4), c.rank(), c.size());
            let spmv = DistSpmv::new(&c, &a);
            let op = CsrOperator::new(&a, &spmv);
            let layout = a.row_layout.clone();
            let b = DistVec::from_fn(layout.clone(), c.rank(), |g| (g as f64).cos());
            // tiny restart forces many outer cycles
            let mut x = DistVec::zeros(layout, c.rank());
            let res = gmres(&c, &op, &b, &mut x, None, 5, 1e-8, 2000);
            assert!(res.converged, "GMRES(5) stalled");
        });
    }
}
