//! Restarted GMRES — the outer solver the paper's neutron runs use
//! (Saad & Schultz 1986), right-preconditioned by the V-cycle.
//!
//! The transport-like operator is nonsymmetric (upwinded streaming), so
//! CG's assumptions do not hold; GMRES(m) is the appropriate Krylov
//! method and what RattleSnake/PETSc run.

use crate::dist::{Comm, DistMultiVec, DistOperator, DistVec};

use super::cycle::MgPreconditioner;
use super::solver::SolveResult;

/// Right-preconditioned restarted GMRES(m): solve `A M⁻¹ (M x) = b`.
/// `pc = None` runs plain GMRES.
#[allow(clippy::too_many_arguments)]
pub fn gmres(
    comm: &Comm,
    a: &dyn DistOperator,
    b: &DistVec,
    x: &mut DistVec,
    mut pc: Option<&mut MgPreconditioner>,
    restart: usize,
    rtol: f64,
    max_iters: usize,
) -> SolveResult {
    let layout = a.row_layout().clone();
    let rank = comm.rank();
    let m = restart.max(1);

    let mut r = DistVec::zeros(layout.clone(), rank);
    let mut w = DistVec::zeros(layout.clone(), rank);
    let mut z = DistVec::zeros(layout.clone(), rank);

    // r = b - A x
    a.apply(comm, x, &mut w);
    r.vals.clone_from(&b.vals);
    for i in 0..r.vals.len() {
        r.vals[i] -= w.vals[i];
    }
    let r0 = r.norm2(comm);
    let mut residuals = vec![r0];
    if r0 == 0.0 {
        return SolveResult { iterations: 0, converged: true, residuals };
    }
    let target = rtol * r0;

    let mut total_iters = 0usize;
    'outer: loop {
        // Arnoldi basis (distributed vectors) + Hessenberg (replicated)
        let beta = r.norm2(comm);
        if beta <= target {
            return SolveResult { iterations: total_iters, converged: true, residuals };
        }
        let mut v: Vec<DistVec> = Vec::with_capacity(m + 1);
        let mut v0 = r.clone();
        v0.scale(1.0 / beta);
        v.push(v0);
        // Hessenberg in column-major (m+1) x m, plus Givens rotations
        let mut h = vec![0.0f64; (m + 1) * m];
        let mut cs = vec![0.0f64; m];
        let mut sn = vec![0.0f64; m];
        let mut g = vec![0.0f64; m + 1];
        g[0] = beta;
        let mut k_used = 0usize;

        for k in 0..m {
            // w = A M⁻¹ v_k
            match pc.as_deref_mut() {
                Some(p) => {
                    p.apply(comm, &v[k], &mut z);
                    a.apply(comm, &z, &mut w);
                }
                None => a.apply(comm, &v[k], &mut w),
            }
            // modified Gram-Schmidt
            for j in 0..=k {
                let hjk = w.dot(comm, &v[j]);
                h[j * m + k] = hjk;
                w.axpy(-hjk, &v[j]);
            }
            let hk1 = w.norm2(comm);
            h[(k + 1) * m + k] = hk1;
            // apply accumulated Givens rotations to column k
            for j in 0..k {
                let t = cs[j] * h[j * m + k] + sn[j] * h[(j + 1) * m + k];
                h[(j + 1) * m + k] = -sn[j] * h[j * m + k] + cs[j] * h[(j + 1) * m + k];
                h[j * m + k] = t;
            }
            // new rotation to annihilate h[k+1][k]
            let denom = (h[k * m + k] * h[k * m + k] + hk1 * hk1).sqrt();
            if denom == 0.0 {
                k_used = k;
                break;
            }
            cs[k] = h[k * m + k] / denom;
            sn[k] = hk1 / denom;
            h[k * m + k] = denom;
            g[k + 1] = -sn[k] * g[k];
            g[k] *= cs[k];
            total_iters += 1;
            k_used = k + 1;
            let res = g[k + 1].abs();
            residuals.push(res);
            if res <= target || total_iters >= max_iters {
                break;
            }
            if hk1 == 0.0 {
                break; // lucky breakdown
            }
            let mut vk1 = w.clone();
            vk1.scale(1.0 / hk1);
            v.push(vk1);
        }

        // back-substitute y from the k_used x k_used triangular system
        let kk = k_used;
        let mut y = vec![0.0f64; kk];
        for i in (0..kk).rev() {
            let mut s = g[i];
            for j in i + 1..kk {
                s -= h[i * m + j] * y[j];
            }
            y[i] = s / h[i * m + i];
        }
        // x += M⁻¹ (V y)
        let mut update = DistVec::zeros(layout.clone(), rank);
        for (j, &yj) in y.iter().enumerate() {
            update.axpy(yj, &v[j]);
        }
        match pc.as_deref_mut() {
            Some(p) => {
                p.apply(comm, &update, &mut z);
                for i in 0..x.vals.len() {
                    x.vals[i] += z.vals[i];
                }
            }
            None => {
                for i in 0..x.vals.len() {
                    x.vals[i] += update.vals[i];
                }
            }
        }
        // true residual for the restart
        a.apply(comm, x, &mut w);
        r.vals.clone_from(&b.vals);
        for i in 0..r.vals.len() {
            r.vals[i] -= w.vals[i];
        }
        let rn = r.norm2(comm);
        *residuals.last_mut().unwrap() = rn;
        if rn <= target {
            return SolveResult { iterations: total_iters, converged: true, residuals };
        }
        if total_iters >= max_iters {
            break 'outer;
        }
    }
    SolveResult { iterations: total_iters, converged: false, residuals }
}

/// Blocked restarted GMRES(m) over K stacked right-hand sides
/// (collective).  All K columns march through one shared Arnoldi
/// schedule: each step pays one K-wide preconditioner cycle, one K-wide
/// matvec, and K-element reductions for the Gram-Schmidt dots, so every
/// α term is amortized across the block.  Each column keeps its own
/// Hessenberg/Givens state and freezes independently (breakdown,
/// convergence, or iteration cap) — column `j`'s solution and residual
/// history are bitwise the scalar [`gmres`] on column `j`.
#[allow(clippy::too_many_arguments)]
pub fn gmres_multi(
    comm: &Comm,
    a: &dyn DistOperator,
    b: &DistMultiVec,
    x: &mut DistMultiVec,
    mut pc: Option<&mut MgPreconditioner>,
    restart: usize,
    rtol: f64,
    max_iters: usize,
) -> Vec<SolveResult> {
    let kk = b.k;
    let layout = a.row_layout().clone();
    let rank = comm.rank();
    let m = restart.max(1);

    let mut r = DistMultiVec::zeros(layout.clone(), rank, kk);
    let mut w = DistMultiVec::zeros(layout.clone(), rank, kk);
    let mut z = DistMultiVec::zeros(layout.clone(), rank, kk);

    // R = B - A X
    a.apply_multi(comm, x, &mut w);
    r.vals.clone_from(&b.vals);
    for (rv, wv) in r.vals.iter_mut().zip(&w.vals) {
        *rv -= wv;
    }
    let r0 = r.norm2_multi(comm);
    let mut hist: Vec<Vec<f64>> = r0.iter().map(|&v| vec![v]).collect();
    let mut done: Vec<bool> = r0.iter().map(|&v| v == 0.0).collect();
    let mut conv = done.clone();
    let mut iters = vec![0usize; kk];
    let target: Vec<f64> = r0.iter().map(|&v| rtol * v).collect();
    let n_local = r.local_len();

    while !done.iter().all(|&d| d) {
        // columns participating in this restart cycle
        let cycle_cols: Vec<bool> = done.iter().map(|&d| !d).collect();
        let beta = r.norm2_multi(comm);
        let mut any = false;
        for j in 0..kk {
            if cycle_cols[j] && beta[j] <= target[j] {
                done[j] = true;
                conv[j] = true;
            }
            any |= cycle_cols[j] && !done[j];
        }
        if !any {
            break;
        }
        // per-column Arnoldi state: arn[j] = still extending the basis
        let mut arn: Vec<bool> =
            (0..kk).map(|j| cycle_cols[j] && !done[j]).collect();
        let mut v: Vec<DistMultiVec> = Vec::with_capacity(m + 1);
        let mut v0 = DistMultiVec::zeros(layout.clone(), rank, kk);
        for j in 0..kk {
            if arn[j] {
                let s = 1.0 / beta[j];
                for i in 0..n_local {
                    v0.vals[i * kk + j] = r.vals[i * kk + j] * s;
                }
            }
        }
        v.push(v0);
        let mut h = vec![vec![0.0f64; (m + 1) * m]; kk];
        let mut cs = vec![vec![0.0f64; m]; kk];
        let mut sn = vec![vec![0.0f64; m]; kk];
        let mut g = vec![vec![0.0f64; m + 1]; kk];
        for j in 0..kk {
            g[j][0] = beta[j];
        }
        let mut kdim = vec![0usize; kk];

        for k in 0..m {
            if !arn.iter().any(|&f| f) {
                break;
            }
            // W = A M⁻¹ v_k
            match pc.as_deref_mut() {
                Some(p) => {
                    p.apply_multi(comm, &v[k], &mut z);
                    a.apply_multi(comm, &z, &mut w);
                }
                None => a.apply_multi(comm, &v[k], &mut w),
            }
            // modified Gram-Schmidt, one K-element reduction per basis
            // vector
            for i in 0..=k {
                let hjk = w.dot_multi(comm, &v[i]);
                let neg: Vec<f64> = hjk.iter().map(|&v_| -v_).collect();
                for j in 0..kk {
                    if arn[j] {
                        h[j][i * m + k] = hjk[j];
                    }
                }
                w.axpy_cols(&neg, &v[i], &arn);
            }
            let hk1 = w.norm2_multi(comm);
            for j in 0..kk {
                if !arn[j] {
                    continue;
                }
                let hj = &mut h[j];
                hj[(k + 1) * m + k] = hk1[j];
                for i in 0..k {
                    let t = cs[j][i] * hj[i * m + k] + sn[j][i] * hj[(i + 1) * m + k];
                    hj[(i + 1) * m + k] = -sn[j][i] * hj[i * m + k] + cs[j][i] * hj[(i + 1) * m + k];
                    hj[i * m + k] = t;
                }
                let denom = (hj[k * m + k] * hj[k * m + k] + hk1[j] * hk1[j]).sqrt();
                if denom == 0.0 {
                    kdim[j] = k;
                    arn[j] = false;
                    continue;
                }
                cs[j][k] = hj[k * m + k] / denom;
                sn[j][k] = hk1[j] / denom;
                hj[k * m + k] = denom;
                g[j][k + 1] = -sn[j][k] * g[j][k];
                g[j][k] *= cs[j][k];
                iters[j] += 1;
                kdim[j] = k + 1;
                let res = g[j][k + 1].abs();
                hist[j].push(res);
                if res <= target[j] || iters[j] >= max_iters || hk1[j] == 0.0 {
                    arn[j] = false;
                }
            }
            if arn.iter().any(|&f| f) {
                let mut vk1 = DistMultiVec::zeros(layout.clone(), rank, kk);
                for j in 0..kk {
                    if arn[j] {
                        let s = 1.0 / hk1[j];
                        for i in 0..n_local {
                            vk1.vals[i * kk + j] = w.vals[i * kk + j] * s;
                        }
                    }
                }
                v.push(vk1);
            }
        }

        // per-column back-substitution and update assembly (local)
        let mut update = DistMultiVec::zeros(layout.clone(), rank, kk);
        for j in 0..kk {
            if !cycle_cols[j] || done[j] {
                continue;
            }
            let kd = kdim[j];
            let mut y = vec![0.0f64; kd];
            for i in (0..kd).rev() {
                let mut s = g[j][i];
                for t in i + 1..kd {
                    s -= h[j][i * m + t] * y[t];
                }
                y[i] = s / h[j][i * m + i];
            }
            for (t, &yt) in y.iter().enumerate() {
                let vt = &v[t];
                for i in 0..n_local {
                    update.vals[i * kk + j] += yt * vt.vals[i * kk + j];
                }
            }
        }
        // X += M⁻¹ (V y), frozen columns untouched
        let ones = vec![1.0f64; kk];
        let mask: Vec<bool> = (0..kk).map(|j| cycle_cols[j] && !done[j]).collect();
        match pc.as_deref_mut() {
            Some(p) => {
                p.apply_multi(comm, &update, &mut z);
                x.axpy_cols(&ones, &z, &mask);
            }
            None => x.axpy_cols(&ones, &update, &mask),
        }
        // true residual for the restart
        a.apply_multi(comm, x, &mut w);
        r.vals.clone_from(&b.vals);
        for (rv, wv) in r.vals.iter_mut().zip(&w.vals) {
            *rv -= wv;
        }
        let rn = r.norm2_multi(comm);
        for j in 0..kk {
            if !mask[j] {
                continue;
            }
            *hist[j].last_mut().unwrap() = rn[j];
            if rn[j] <= target[j] {
                done[j] = true;
                conv[j] = true;
            } else if iters[j] >= max_iters {
                done[j] = true;
            }
        }
    }
    (0..kk)
        .map(|j| SolveResult {
            iterations: iters[j],
            converged: conv[j],
            residuals: std::mem::take(&mut hist[j]),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{CsrOperator, DistSpmv, World};
    use crate::gen::{grid_laplacian, neutron_block_operator, Grid3, NeutronConfig};
    use crate::mem::MemTracker;
    use crate::mg::cycle::MgOpts;
    use crate::mg::hierarchy::{build_hierarchy, geometric_chain, Coarsening, HierarchyConfig};

    #[test]
    fn gmres_solves_spd_system() {
        let w = World::new(2);
        w.run(|c| {
            let a = grid_laplacian(Grid3::cube(4), c.rank(), c.size());
            let spmv = DistSpmv::new(&c, &a);
            let op = CsrOperator::new(&a, &spmv);
            let layout = a.row_layout.clone();
            let xs = DistVec::from_fn(layout.clone(), c.rank(), |g| ((g % 9) as f64) - 4.0);
            let mut b = DistVec::zeros(layout.clone(), c.rank());
            op.apply(&c, &xs, &mut b);
            let mut x = DistVec::zeros(layout, c.rank());
            let res = gmres(&c, &op, &b, &mut x, None, 30, 1e-10, 400);
            assert!(res.converged, "residuals: {:?}", res.residuals.last());
            let mut err = x.clone();
            err.axpy(-1.0, &xs);
            assert!(err.norm2(&c) < 1e-6);
        });
    }

    #[test]
    fn gmres_handles_nonsymmetric_transport_operator() {
        let w = World::new(2);
        w.run(|c| {
            let cfg = NeutronConfig { grid: Grid3::cube(4), groups: 4, seed: 11 };
            let ab = neutron_block_operator(cfg, c.rank(), c.size());
            let a = ab.to_scalar();
            let spmv = DistSpmv::new(&c, &a);
            let op = CsrOperator::new(&a, &spmv);
            let layout = a.row_layout.clone();
            let b = DistVec::from_fn(layout.clone(), c.rank(), |_| 1.0);
            let mut x = DistVec::zeros(layout, c.rank());
            let res = gmres(&c, &op, &b, &mut x, None, 30, 1e-8, 400);
            assert!(res.converged, "GMRES stalled on the transport operator");
        });
    }

    #[test]
    fn mg_preconditioned_gmres_beats_plain() {
        let w = World::new(2);
        w.run(|c| {
            let grids = geometric_chain(Grid3::cube(4), 3);
            let a0 = grid_laplacian(grids[0], c.rank(), c.size());
            let a = a0.clone();
            let tracker = MemTracker::new();
            let h = build_hierarchy(
                &c,
                a0,
                &Coarsening::Geometric { grids },
                HierarchyConfig::default(),
                &tracker,
            );
            let spmv = DistSpmv::new(&c, &a);
            let op = CsrOperator::new(&a, &spmv);
            let mut pc = MgPreconditioner::new(&c, h, MgOpts::default());
            let layout = a.row_layout.clone();
            let b = DistVec::from_fn(layout.clone(), c.rank(), |_| 1.0);
            let mut x1 = DistVec::zeros(layout.clone(), c.rank());
            let with_pc = gmres(&c, &op, &b, &mut x1, Some(&mut pc), 30, 1e-8, 300);
            let mut x2 = DistVec::zeros(layout, c.rank());
            let plain = gmres(&c, &op, &b, &mut x2, None, 30, 1e-8, 300);
            assert!(with_pc.converged);
            assert!(
                with_pc.iterations < plain.iterations,
                "MG-GMRES {} vs plain {}",
                with_pc.iterations,
                plain.iterations
            );
        });
    }

    #[test]
    fn restart_does_not_break_convergence() {
        let w = World::new(1);
        w.run(|c| {
            let a = grid_laplacian(Grid3::cube(4), c.rank(), c.size());
            let spmv = DistSpmv::new(&c, &a);
            let op = CsrOperator::new(&a, &spmv);
            let layout = a.row_layout.clone();
            let b = DistVec::from_fn(layout.clone(), c.rank(), |g| (g as f64).cos());
            // tiny restart forces many outer cycles
            let mut x = DistVec::zeros(layout, c.rank());
            let res = gmres(&c, &op, &b, &mut x, None, 5, 1e-8, 2000);
            assert!(res.converged, "GMRES(5) stalled");
        });
    }
}
