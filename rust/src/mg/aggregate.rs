//! Greedy strength-based aggregation coarsening (smoothed-aggregation AMG
//! analog) — how the neutron problem's twelve-level hierarchy is built
//! algebraically (paper §4.2 / Kong et al. 2019b's subspace coarsening).

use crate::dist::{Comm, DistCsr, DistCsrBuilder, Layout, PrMat, RowGatherPlan};
use crate::spgemm::{RowScratch, RowView};

/// Aggregation options.
#[derive(Debug, Clone, Copy)]
pub struct AggregateOpts {
    /// Strength threshold: j is a strong neighbour of i when
    /// |a_ij| >= threshold * max_k |a_ik| (k != i).
    pub threshold: f64,
    /// Damped-Jacobi prolongator smoothing weight (0 = unsmoothed /
    /// tentative).  Smoothing widens P's rows across rank boundaries,
    /// giving the off-rank communication the paper's runs exercise.
    pub smooth_omega: f64,
}

impl Default for AggregateOpts {
    fn default() -> Self {
        AggregateOpts { threshold: 0.25, smooth_omega: 0.55 }
    }
}

/// Rank-local greedy aggregation over the diag-block graph.  Returns the
/// local aggregate id per local row and the number of local aggregates.
fn aggregate_local(a: &DistCsr, threshold: f64) -> (Vec<i64>, usize) {
    let n = a.local_nrows();
    let mut agg: Vec<i64> = vec![-1; n];
    let mut n_agg = 0usize;

    // strength masks from the diag block
    let strong = |i: usize| -> Vec<usize> {
        let (cols, vals) = a.diag.row(i);
        let mut maxabs = 0.0f64;
        for (&c, &v) in cols.iter().zip(vals) {
            if c as usize != i {
                maxabs = maxabs.max(v.abs());
            }
        }
        let thr = threshold * maxabs;
        cols.iter()
            .zip(vals)
            .filter(|&(&c, &v)| c as usize != i && v.abs() >= thr && thr > 0.0)
            .map(|(&c, _)| c as usize)
            .collect()
    };

    // Pass 1: roots whose strong neighbourhood is fully unaggregated
    for i in 0..n {
        if agg[i] >= 0 {
            continue;
        }
        let nbrs = strong(i);
        if nbrs.iter().any(|&j| agg[j] >= 0) {
            continue;
        }
        let id = n_agg as i64;
        n_agg += 1;
        agg[i] = id;
        for &j in &nbrs {
            agg[j] = id;
        }
    }
    // Pass 2: attach leftovers to a neighbouring aggregate (or make a
    // singleton).
    for i in 0..n {
        if agg[i] >= 0 {
            continue;
        }
        let nbrs = strong(i);
        if let Some(&j) = nbrs.iter().find(|&&j| agg[j] >= 0) {
            agg[i] = agg[j];
        } else {
            agg[i] = n_agg as i64;
            n_agg += 1;
        }
    }
    (agg, n_agg)
}

/// The damped-Jacobi smoothing operator S = I − ω D⁻¹ A (rows local,
/// pattern = A's pattern; built from A's *current* values).
fn build_smoother_matrix(a: &DistCsr, omega: f64) -> DistCsr {
    let mut s_b = DistCsrBuilder::new(a.rank, a.row_layout.clone(), a.row_layout.clone());
    let mut entries: Vec<(u64, f64)> = Vec::new();
    for i in 0..a.local_nrows() {
        let (dc, dv) = a.diag.row(i);
        let dii = dc
            .iter()
            .zip(dv)
            .find(|&(&c, _)| c as usize == i)
            .map(|(_, &v)| v)
            .unwrap_or(1.0);
        let w = omega / dii;
        entries.clear();
        for (&c, &v) in dc.iter().zip(dv) {
            let gcol = a.col_layout.start(a.rank) as u64 + c as u64;
            let sv = if c as usize == i { 1.0 - w * v } else { -w * v };
            entries.push((gcol, sv));
        }
        let (oc, ov) = a.offd.row(i);
        for (&c, &v) in oc.iter().zip(ov) {
            entries.push((a.garray[c as usize], -w * v));
        }
        entries.sort_unstable_by_key(|&(c, _)| c);
        s_b.push_row(&entries);
    }
    s_b.finish()
}

/// `P = S · tent` with the row-wise SpGEMM over already-gathered remote
/// tent rows (local — the traffic happened when `pr` was gathered).
fn smooth_product(s: &DistCsr, tent: &DistCsr, pr: &PrMat, coarse_layout: Layout) -> DistCsr {
    let v = RowView::new(s, tent, pr);
    let mut scratch = RowScratch::default();
    let mut p_b = DistCsrBuilder::new(s.rank, s.row_layout.clone(), coarse_layout);
    let mut entries: Vec<(u64, f64)> = Vec::new();
    for i in 0..s.local_nrows() {
        scratch.numeric_row(v, i);
        scratch.extract_numeric();
        entries.clear();
        for (&c, &val) in scratch.dcols.iter().zip(&scratch.dvals) {
            entries.push((c + v.cbeg, val));
        }
        for (&c, &val) in scratch.ocols.iter().zip(&scratch.ovals) {
            entries.push((c, val));
        }
        entries.sort_unstable_by_key(|&(c, _)| c);
        p_b.push_row(&entries);
    }
    p_b.finish()
}

/// Everything a value-only smoothed-aggregation `P` refresh needs, all of
/// it value-static: the tentative prolongator, the gathered remote tent
/// rows, and ω.  When `A`'s values change (same pattern), `S = I − ωD⁻¹A`
/// is rebuilt locally and `P = S·tent` recomputed with **zero traffic** —
/// the symbolic half (aggregation, gather plan, gathered rows) is reused.
#[derive(Debug)]
pub struct InterpRefresh {
    tent: DistCsr,
    pr: PrMat,
    omega: f64,
}

impl InterpRefresh {
    /// Recompute `p`'s values from `a`'s current values, in place (local,
    /// no communication).  `p` must be the operator this context was
    /// built with (same pattern).
    pub fn refresh_values(&self, a: &DistCsr, p: &mut DistCsr) {
        let s = build_smoother_matrix(a, self.omega);
        let p_new = smooth_product(&s, &self.tent, &self.pr, p.col_layout.clone());
        p.copy_values_from(&p_new);
    }

    /// Retained bytes (tent tables + gathered rows).
    pub fn bytes(&self) -> u64 {
        self.tent.bytes() + self.pr.bytes()
    }
}

/// Build the aggregation interpolation for `a` (collective).  Tentative
/// `P` has one unit entry per row (its aggregate); with
/// `smooth_omega > 0` the prolongator is smoothed:
/// `P = (I − ω D⁻¹ A) P_tent`, computed with the row-wise SpGEMM.
pub fn aggregate_interp(comm: &Comm, a: &DistCsr, opts: AggregateOpts) -> DistCsr {
    aggregate_interp_with_refresh(comm, a, opts, false).0
}

/// Like [`aggregate_interp`], additionally returning the value-only
/// refresh context when `retain` is set (and the prolongator is actually
/// smoothed — a tentative P is value-static and needs no refresh).
pub fn aggregate_interp_with_refresh(
    comm: &Comm,
    a: &DistCsr,
    opts: AggregateOpts,
    retain: bool,
) -> (DistCsr, Option<InterpRefresh>) {
    let (agg, n_agg) = aggregate_local(a, opts.threshold);
    // coarse layout from per-rank aggregate counts
    let counts_u64 = comm.all_u64(n_agg as u64);
    let counts: Vec<usize> = counts_u64.iter().map(|&c| c as usize).collect();
    let coarse_layout = Layout::from_counts(&counts);
    let coarse_start = coarse_layout.start(comm.rank()) as u64;

    // tentative prolongator (injection)
    let mut tent_b = DistCsrBuilder::new(comm.rank(), a.row_layout.clone(), coarse_layout.clone());
    for &g in agg.iter() {
        tent_b.push_row(&[(coarse_start + g as u64, 1.0)]);
    }
    let tent = tent_b.finish();
    if opts.smooth_omega == 0.0 {
        return (tent, None);
    }

    let s = build_smoother_matrix(a, opts.smooth_omega);

    // P = S * tent via the row-wise SpGEMM
    let plan = RowGatherPlan::build(comm, &tent.row_layout, &s.garray);
    let pr = plan.gather_csr(comm, &tent);
    let p = smooth_product(&s, &tent, &pr, coarse_layout);
    let refresh =
        if retain { Some(InterpRefresh { tent, pr, omega: opts.smooth_omega }) } else { None };
    (p, refresh)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::World;
    use crate::gen::{grid_laplacian, Grid3};

    #[test]
    fn aggregates_cover_and_shrink() {
        let w = World::new(2);
        w.run(|c| {
            let a = grid_laplacian(Grid3::cube(6), c.rank(), c.size());
            let (agg, n_agg) = aggregate_local(&a, 0.25);
            assert!(agg.iter().all(|&g| g >= 0 && (g as usize) < n_agg));
            // 3D Laplacian: aggregates should shrink by at least 3x
            assert!(n_agg * 3 <= a.local_nrows(), "{n_agg} vs {}", a.local_nrows());
        });
    }

    #[test]
    fn tentative_interp_partitions_unity() {
        let w = World::new(3);
        w.run(|c| {
            let a = grid_laplacian(Grid3::cube(5), c.rank(), c.size());
            let p = aggregate_interp(&c, &a, AggregateOpts { threshold: 0.25, smooth_omega: 0.0 });
            p.validate().unwrap();
            for i in 0..p.local_nrows() {
                let s: f64 = p.diag.row(i).1.iter().chain(p.offd.row(i).1.iter()).sum();
                assert!((s - 1.0).abs() < 1e-12);
            }
        });
    }

    #[test]
    fn smoothed_interp_wider_and_crosses_ranks() {
        let w = World::new(4);
        let any_offd = w.run(|c| {
            let a = grid_laplacian(Grid3::cube(6), c.rank(), c.size());
            let p = aggregate_interp(&c, &a, AggregateOpts::default());
            p.validate().unwrap();
            let tent =
                aggregate_interp(&c, &a, AggregateOpts { threshold: 0.25, smooth_omega: 0.0 });
            assert!(p.nnz_local() > tent.nnz_local(), "smoothing must widen P");
            p.offd.nnz() > 0
        });
        assert!(any_offd.iter().any(|&x| x), "smoothed P never crossed ranks");
    }

    #[test]
    fn smoothed_rows_preserve_constants() {
        // S = I - wD^-1 A applied to the unit partition: row sums of P equal
        // row sums of S*1 = 1 - wD^-1(A*1); for interior Laplacian rows
        // A*1 = 0, so sums stay 1 there.
        let w = World::new(1);
        w.run(|c| {
            let a = grid_laplacian(Grid3::cube(5), c.rank(), c.size());
            let p = aggregate_interp(&c, &a, AggregateOpts::default());
            let g = Grid3::cube(5);
            for i in 0..p.local_nrows() {
                let (x, y, z) = g.coords(i);
                let interior = x > 0 && x + 1 < 5 && y > 0 && y + 1 < 5 && z > 0 && z + 1 < 5;
                if interior {
                    let s: f64 =
                        p.diag.row(i).1.iter().chain(p.offd.row(i).1.iter()).sum();
                    assert!((s - 1.0).abs() < 1e-10, "row {i} sum {s}");
                }
            }
        });
    }
}
