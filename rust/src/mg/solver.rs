//! Outer Krylov solvers: preconditioned CG and Richardson iteration.
//! Both are written against [`DistOperator`], so a matrix-free fine
//! level drops in without touching the Krylov loop.

use crate::dist::{Comm, DistOperator, DistVec};

use super::cycle::MgPreconditioner;

/// Convergence record of a solve.
#[derive(Debug, Clone)]
pub struct SolveResult {
    pub iterations: usize,
    pub converged: bool,
    /// ‖r_k‖₂ per iteration (index 0 = initial residual).
    pub residuals: Vec<f64>,
}

/// Preconditioned conjugate gradients: solve `A x = b` to
/// `‖r‖ <= rtol * ‖r₀‖` (collective).  `pc = None` runs plain CG.
pub fn pcg(
    comm: &Comm,
    a: &dyn DistOperator,
    b: &DistVec,
    x: &mut DistVec,
    mut pc: Option<&mut MgPreconditioner>,
    rtol: f64,
    max_iters: usize,
) -> SolveResult {
    let layout = a.row_layout().clone();
    let rank = comm.rank();
    let mut r = DistVec::zeros(layout.clone(), rank);
    let mut z = DistVec::zeros(layout.clone(), rank);
    let mut q = DistVec::zeros(layout.clone(), rank);

    // r = b - A x
    a.apply(comm, x, &mut q);
    r.vals.clone_from(&b.vals);
    for i in 0..r.vals.len() {
        r.vals[i] -= q.vals[i];
    }
    let r0 = r.norm2(comm);
    let mut residuals = vec![r0];
    if r0 == 0.0 {
        return SolveResult { iterations: 0, converged: true, residuals };
    }

    let apply_pc = |pc: &mut Option<&mut MgPreconditioner>,
                    comm: &Comm,
                    r: &DistVec,
                    z: &mut DistVec| match pc {
        Some(m) => m.apply(comm, r, z),
        None => z.vals.clone_from(&r.vals),
    };

    apply_pc(&mut pc, comm, &r, &mut z);
    let mut p = z.clone();
    let mut rz = r.dot(comm, &z);
    for it in 1..=max_iters {
        a.apply(comm, &p, &mut q);
        let pq = p.dot(comm, &q);
        let alpha = rz / pq;
        x.axpy(alpha, &p);
        r.axpy(-alpha, &q);
        let rn = r.norm2(comm);
        residuals.push(rn);
        if rn <= rtol * r0 {
            return SolveResult { iterations: it, converged: true, residuals };
        }
        apply_pc(&mut pc, comm, &r, &mut z);
        let rz_new = r.dot(comm, &z);
        let beta = rz_new / rz;
        rz = rz_new;
        p.aypx(beta, &z);
    }
    SolveResult { iterations: max_iters, converged: false, residuals }
}

/// Richardson iteration `x += M⁻¹ (b − A x)` (stationary MG solve).
pub fn richardson(
    comm: &Comm,
    a: &dyn DistOperator,
    b: &DistVec,
    x: &mut DistVec,
    pc: &mut MgPreconditioner,
    rtol: f64,
    max_iters: usize,
) -> SolveResult {
    let layout = a.row_layout().clone();
    let rank = comm.rank();
    let mut r = DistVec::zeros(layout.clone(), rank);
    let mut z = DistVec::zeros(layout.clone(), rank);
    let mut ax = DistVec::zeros(layout, rank);
    a.apply(comm, x, &mut ax);
    r.vals.clone_from(&b.vals);
    for i in 0..r.vals.len() {
        r.vals[i] -= ax.vals[i];
    }
    let r0 = r.norm2(comm);
    let mut residuals = vec![r0];
    for it in 1..=max_iters {
        pc.apply(comm, &r, &mut z);
        x.axpy(1.0, &z);
        a.apply(comm, x, &mut ax);
        r.vals.clone_from(&b.vals);
        for i in 0..r.vals.len() {
            r.vals[i] -= ax.vals[i];
        }
        let rn = r.norm2(comm);
        residuals.push(rn);
        if rn <= rtol * r0 {
            return SolveResult { iterations: it, converged: true, residuals };
        }
    }
    SolveResult { iterations: max_iters, converged: false, residuals }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{CsrOperator, DistSpmv, World};
    use crate::gen::{grid_laplacian, Grid3};
    use crate::mem::MemTracker;
    use crate::mg::cycle::MgOpts;
    use crate::mg::hierarchy::{build_hierarchy, geometric_chain, Coarsening, HierarchyConfig};

    #[test]
    fn plain_cg_solves_small_laplacian() {
        let w = World::new(2);
        w.run(|c| {
            let a = grid_laplacian(Grid3::cube(4), c.rank(), c.size());
            let spmv = DistSpmv::new(&c, &a);
            let op = CsrOperator::new(&a, &spmv);
            let layout = a.row_layout.clone();
            let xs = DistVec::from_fn(layout.clone(), c.rank(), |g| (g as f64 * 0.37).sin());
            let mut b = DistVec::zeros(layout.clone(), c.rank());
            op.apply(&c, &xs, &mut b);
            let mut x = DistVec::zeros(layout, c.rank());
            let res = pcg(&c, &op, &b, &mut x, None, 1e-10, 500);
            assert!(res.converged, "CG stalled: {:?}", res.residuals.last());
            let mut err = x.clone();
            err.axpy(-1.0, &xs);
            assert!(err.norm2(&c) < 1e-6);
        });
    }

    #[test]
    fn mg_pcg_converges_in_few_iterations() {
        let w = World::new(2);
        w.run(|c| {
            let grids = geometric_chain(Grid3::cube(3), 3);
            let a0 = grid_laplacian(grids[0], c.rank(), c.size());
            let a = a0.clone();
            let layout = a.row_layout.clone();
            let tracker = MemTracker::new();
            let h = build_hierarchy(
                &c,
                a0,
                &Coarsening::Geometric { grids },
                HierarchyConfig::default(),
                &tracker,
            );
            let spmv = DistSpmv::new(&c, &a);
            let op = CsrOperator::new(&a, &spmv);
            let mut pc = MgPreconditioner::new(&c, h, MgOpts::default());
            let b = DistVec::from_fn(layout.clone(), c.rank(), |g| ((g * 13 % 7) as f64) - 3.0);
            let mut x = DistVec::zeros(layout, c.rank());
            let res = pcg(&c, &op, &b, &mut x, Some(&mut pc), 1e-8, 60);
            assert!(res.converged);
            assert!(
                res.iterations <= 15,
                "MG-CG took {} iterations",
                res.iterations
            );
            // monotone-ish decline
            assert!(res.residuals.last().unwrap() < &(1e-8 * res.residuals[0] + 1e-300));
        });
    }
}
