//! Outer Krylov solvers: preconditioned CG and Richardson iteration.
//! Both are written against [`DistOperator`], so a matrix-free fine
//! level drops in without touching the Krylov loop.

use crate::dist::{Comm, DistMultiVec, DistOperator, DistVec};

use super::cycle::MgPreconditioner;

/// Convergence record of a solve.
#[derive(Debug, Clone)]
pub struct SolveResult {
    pub iterations: usize,
    pub converged: bool,
    /// ‖r_k‖₂ per iteration (index 0 = initial residual).
    pub residuals: Vec<f64>,
}

/// Preconditioned conjugate gradients: solve `A x = b` to
/// `‖r‖ <= rtol * ‖r₀‖` (collective).  `pc = None` runs plain CG.
pub fn pcg(
    comm: &Comm,
    a: &dyn DistOperator,
    b: &DistVec,
    x: &mut DistVec,
    mut pc: Option<&mut MgPreconditioner>,
    rtol: f64,
    max_iters: usize,
) -> SolveResult {
    let layout = a.row_layout().clone();
    let rank = comm.rank();
    let mut r = DistVec::zeros(layout.clone(), rank);
    let mut z = DistVec::zeros(layout.clone(), rank);
    let mut q = DistVec::zeros(layout.clone(), rank);

    // r = b - A x
    a.apply(comm, x, &mut q);
    r.vals.clone_from(&b.vals);
    for i in 0..r.vals.len() {
        r.vals[i] -= q.vals[i];
    }
    let r0 = r.norm2(comm);
    let mut residuals = vec![r0];
    if r0 == 0.0 {
        return SolveResult { iterations: 0, converged: true, residuals };
    }

    let apply_pc = |pc: &mut Option<&mut MgPreconditioner>,
                    comm: &Comm,
                    r: &DistVec,
                    z: &mut DistVec| match pc {
        Some(m) => m.apply(comm, r, z),
        None => z.vals.clone_from(&r.vals),
    };

    apply_pc(&mut pc, comm, &r, &mut z);
    let mut p = z.clone();
    let mut rz = r.dot(comm, &z);
    for it in 1..=max_iters {
        a.apply(comm, &p, &mut q);
        let pq = p.dot(comm, &q);
        let alpha = rz / pq;
        x.axpy(alpha, &p);
        r.axpy(-alpha, &q);
        let rn = r.norm2(comm);
        residuals.push(rn);
        if rn <= rtol * r0 {
            crate::obs::metrics::observe(crate::obs::Subsys::Solve, "pcg.iters", it as u64);
            return SolveResult { iterations: it, converged: true, residuals };
        }
        apply_pc(&mut pc, comm, &r, &mut z);
        let rz_new = r.dot(comm, &z);
        let beta = rz_new / rz;
        rz = rz_new;
        p.aypx(beta, &z);
    }
    crate::obs::metrics::observe(crate::obs::Subsys::Solve, "pcg.iters", max_iters as u64);
    SolveResult { iterations: max_iters, converged: false, residuals }
}

/// Blocked preconditioned CG over K stacked right-hand sides
/// (collective).  One iteration pays one K-wide matvec, one K-wide
/// preconditioner cycle, and one K-element reduction per dot product —
/// every α term amortized across the block.  Columns converge
/// independently: a column whose residual passes the tolerance is frozen
/// (its `x`, `r`, and residual history stop updating) while the blocked
/// iteration continues for the rest, so column `j`'s solution and
/// residual history are bitwise the scalar [`pcg`] on column `j`.
pub fn pcg_multi(
    comm: &Comm,
    a: &dyn DistOperator,
    b: &DistMultiVec,
    x: &mut DistMultiVec,
    mut pc: Option<&mut MgPreconditioner>,
    rtol: f64,
    max_iters: usize,
) -> Vec<SolveResult> {
    let kk = b.k;
    let layout = a.row_layout().clone();
    let rank = comm.rank();
    let mut r = DistMultiVec::zeros(layout.clone(), rank, kk);
    let mut z = DistMultiVec::zeros(layout.clone(), rank, kk);
    let mut q = DistMultiVec::zeros(layout.clone(), rank, kk);

    // R = B - A X
    a.apply_multi(comm, x, &mut q);
    r.vals.clone_from(&b.vals);
    for (rv, qv) in r.vals.iter_mut().zip(&q.vals) {
        *rv -= qv;
    }
    let r0 = r.norm2_multi(comm);
    let mut residuals: Vec<Vec<f64>> = r0.iter().map(|&v| vec![v]).collect();
    // a column with a zero rhs is converged before the first iteration,
    // exactly like the scalar early return
    let mut active: Vec<bool> = r0.iter().map(|&v| v != 0.0).collect();
    let mut iterations = vec![0usize; kk];
    let mut converged: Vec<bool> = r0.iter().map(|&v| v == 0.0).collect();

    let apply_pc = |pc: &mut Option<&mut MgPreconditioner>,
                    comm: &Comm,
                    r: &DistMultiVec,
                    z: &mut DistMultiVec| match pc {
        Some(m) => m.apply_multi(comm, r, z),
        None => z.vals.clone_from(&r.vals),
    };

    if active.iter().any(|&f| f) {
        apply_pc(&mut pc, comm, &r, &mut z);
        let mut p = z.clone();
        let mut rz = r.dot_multi(comm, &z);
        for it in 1..=max_iters {
            a.apply_multi(comm, &p, &mut q);
            let pq = p.dot_multi(comm, &q);
            let alpha: Vec<f64> =
                rz.iter().zip(&pq).map(|(&rzj, &pqj)| rzj / pqj).collect();
            x.axpy_cols(&alpha, &p, &active);
            let neg_alpha: Vec<f64> = alpha.iter().map(|&v| -v).collect();
            r.axpy_cols(&neg_alpha, &q, &active);
            let rn = r.norm2_multi(comm);
            for j in 0..kk {
                if active[j] {
                    residuals[j].push(rn[j]);
                    iterations[j] = it;
                    if rn[j] <= rtol * r0[j] {
                        active[j] = false;
                        converged[j] = true;
                    }
                }
            }
            if !active.iter().any(|&f| f) {
                break;
            }
            apply_pc(&mut pc, comm, &r, &mut z);
            let rz_new = r.dot_multi(comm, &z);
            let beta: Vec<f64> =
                rz_new.iter().zip(&rz).map(|(&n, &o)| n / o).collect();
            rz = rz_new;
            p.aypx_cols(&beta, &z, &active);
        }
    }
    for &it in &iterations {
        crate::obs::metrics::observe(crate::obs::Subsys::Solve, "pcg.iters", it as u64);
    }
    (0..kk)
        .map(|j| SolveResult {
            iterations: iterations[j],
            converged: converged[j],
            residuals: std::mem::take(&mut residuals[j]),
        })
        .collect()
}

/// Richardson iteration `x += M⁻¹ (b − A x)` (stationary MG solve).
pub fn richardson(
    comm: &Comm,
    a: &dyn DistOperator,
    b: &DistVec,
    x: &mut DistVec,
    pc: &mut MgPreconditioner,
    rtol: f64,
    max_iters: usize,
) -> SolveResult {
    let layout = a.row_layout().clone();
    let rank = comm.rank();
    let mut r = DistVec::zeros(layout.clone(), rank);
    let mut z = DistVec::zeros(layout.clone(), rank);
    let mut ax = DistVec::zeros(layout, rank);
    a.apply(comm, x, &mut ax);
    r.vals.clone_from(&b.vals);
    for i in 0..r.vals.len() {
        r.vals[i] -= ax.vals[i];
    }
    let r0 = r.norm2(comm);
    let mut residuals = vec![r0];
    for it in 1..=max_iters {
        pc.apply(comm, &r, &mut z);
        x.axpy(1.0, &z);
        a.apply(comm, x, &mut ax);
        r.vals.clone_from(&b.vals);
        for i in 0..r.vals.len() {
            r.vals[i] -= ax.vals[i];
        }
        let rn = r.norm2(comm);
        residuals.push(rn);
        if rn <= rtol * r0 {
            return SolveResult { iterations: it, converged: true, residuals };
        }
    }
    SolveResult { iterations: max_iters, converged: false, residuals }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{CsrOperator, DistSpmv, World};
    use crate::gen::{grid_laplacian, Grid3};
    use crate::mem::MemTracker;
    use crate::mg::cycle::MgOpts;
    use crate::mg::hierarchy::{build_hierarchy, geometric_chain, Coarsening, HierarchyConfig};

    #[test]
    fn plain_cg_solves_small_laplacian() {
        let w = World::new(2);
        w.run(|c| {
            let a = grid_laplacian(Grid3::cube(4), c.rank(), c.size());
            let spmv = DistSpmv::new(&c, &a);
            let op = CsrOperator::new(&a, &spmv);
            let layout = a.row_layout.clone();
            let xs = DistVec::from_fn(layout.clone(), c.rank(), |g| (g as f64 * 0.37).sin());
            let mut b = DistVec::zeros(layout.clone(), c.rank());
            op.apply(&c, &xs, &mut b);
            let mut x = DistVec::zeros(layout, c.rank());
            let res = pcg(&c, &op, &b, &mut x, None, 1e-10, 500);
            assert!(res.converged, "CG stalled: {:?}", res.residuals.last());
            let mut err = x.clone();
            err.axpy(-1.0, &xs);
            assert!(err.norm2(&c) < 1e-6);
        });
    }

    #[test]
    fn mg_pcg_converges_in_few_iterations() {
        let w = World::new(2);
        w.run(|c| {
            let grids = geometric_chain(Grid3::cube(3), 3);
            let a0 = grid_laplacian(grids[0], c.rank(), c.size());
            let a = a0.clone();
            let layout = a.row_layout.clone();
            let tracker = MemTracker::new();
            let h = build_hierarchy(
                &c,
                a0,
                &Coarsening::Geometric { grids },
                HierarchyConfig::default(),
                &tracker,
            );
            let spmv = DistSpmv::new(&c, &a);
            let op = CsrOperator::new(&a, &spmv);
            let mut pc = MgPreconditioner::new(&c, h, MgOpts::default());
            let b = DistVec::from_fn(layout.clone(), c.rank(), |g| ((g * 13 % 7) as f64) - 3.0);
            let mut x = DistVec::zeros(layout, c.rank());
            let res = pcg(&c, &op, &b, &mut x, Some(&mut pc), 1e-8, 60);
            assert!(res.converged);
            assert!(
                res.iterations <= 15,
                "MG-CG took {} iterations",
                res.iterations
            );
            // monotone-ish decline
            assert!(res.residuals.last().unwrap() < &(1e-8 * res.residuals[0] + 1e-300));
        });
    }
}
