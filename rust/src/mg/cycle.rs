//! V-cycle preconditioner over a built hierarchy: Jacobi smoothing,
//! matrix-free transfers, redundant dense solve on the coarsest level.
//!
//! Telescoped hierarchies: every level context remembers the
//! communicator its operators live on.  At a telescope boundary the
//! restricted right-hand side is scattered into the sub-communicator
//! ([`crate::agglomerate::RedistPlan::scatter_vec`]), the coarse
//! correction (smoothing, deeper levels, the direct solve) runs on the
//! active ranks alone — idle ranks skip straight to the gather — and the
//! correction is scattered back out before prolongation.  The crossing
//! moves bytes but reorders no arithmetic, so a telescoped V-cycle whose
//! coarse work is partition-invariant (sorted-merge SpMV, fixed-ω
//! smoothing, the gathered direct solve) reproduces the full-communicator
//! cycle bit for bit.

use std::rc::Rc;

use crate::agglomerate::Telescope;
use crate::dist::{Comm, DistMultiVec, DistOperator, DistSpmv, DistVec};
use crate::mat::block_invert;
use crate::mem::{Cat, Charge, MemTracker};
use crate::runtime::{BlockBackend, SpmvBatcher};
use crate::util::bytebuf::{ByteReader, ByteWriter};

use super::hierarchy::{Hierarchy, LevelOp};
use super::smoother::{
    chebyshev_bounds, ChebyshevSmoother, HybridSorSmoother, JacobiSmoother, SmootherKind,
};
use super::transfer::Transfer;

/// Cycle shape: V (one coarse visit) or W (two coarse visits per level).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CycleType {
    V,
    W,
}

/// V/W-cycle options.
#[derive(Debug, Clone, Copy)]
pub struct MgOpts {
    pub pre_smooth: usize,
    pub post_smooth: usize,
    /// Fixed Jacobi damping; when None it is auto-tuned per level from a
    /// power-iteration bound on λ(D⁻¹A).
    pub omega: Option<f64>,
    /// Coarsest sizes up to this get the redundant dense direct solve.
    pub max_direct: usize,
    pub cycle: CycleType,
    pub smoother: SmootherKind,
}

impl Default for MgOpts {
    fn default() -> Self {
        MgOpts {
            pre_smooth: 1,
            post_smooth: 1,
            omega: None,
            max_direct: 4000,
            cycle: CycleType::V,
            smoother: SmootherKind::Jacobi,
        }
    }
}

/// Per-level relaxation dispatch.
enum Relax {
    Jacobi(JacobiSmoother),
    Chebyshev(ChebyshevSmoother),
    Sor(HybridSorSmoother),
}

impl Relax {
    fn sweep(
        &self,
        comm: &Comm,
        a: &dyn DistOperator,
        b: &DistVec,
        x: &mut DistVec,
        work: &mut DistVec,
    ) {
        match self {
            Relax::Jacobi(s) => s.sweep(comm, a, b, x, work),
            Relax::Chebyshev(s) => s.sweep(comm, a, b, x, work),
            Relax::Sor(s) => s.sweep(comm, a, b, x),
        }
    }

    fn sweep_multi(
        &self,
        comm: &Comm,
        a: &dyn DistOperator,
        b: &DistMultiVec,
        x: &mut DistMultiVec,
        work: &mut DistMultiVec,
    ) {
        match self {
            Relax::Jacobi(s) => s.sweep_multi(comm, a, b, x, work),
            Relax::Chebyshev(s) => s.sweep_multi(comm, a, b, x, work),
            Relax::Sor(s) => s.sweep_multi(comm, a, b, x),
        }
    }

    fn bytes(&self) -> u64 {
        match self {
            Relax::Jacobi(s) => s.bytes(),
            Relax::Chebyshev(s) => s.bytes(),
            Relax::Sor(s) => s.bytes(),
        }
    }
}

/// Coarse direct-solve back-substitution tile (rows × cols per batched
/// block multiply).  Fixed so the tiled fold — and therefore the solve's
/// bits — never depends on K, the partition, or the backend chunk size.
const COARSE_TILE: usize = 16;

/// `out[0..len][0..kk] = inv[start..start+len, :] · full` — the dense
/// redundant coarse solve's back-substitution, K columns at once, tiled
/// [`COARSE_TILE`]² through the [`SpmvBatcher`] so the blocked-kernel
/// seam ([`crate::runtime`]) sees batched launches.  Per row and column
/// the fold adds tile partials in ascending column-tile order, each tile
/// partial folded flat ascending — the same structure for every `kk`, so
/// column `j` of a K-wide call is bitwise the `kk = 1` call on column
/// `j`.
#[allow(clippy::too_many_arguments)]
fn coarse_backsub(
    batcher: &mut SpmvBatcher<'_>,
    inv: &[f64],
    n: usize,
    full: &[f64],
    kk: usize,
    start: usize,
    len: usize,
    out: &mut [f64],
) {
    let bsz = batcher.block_size();
    debug_assert_eq!(full.len(), n * kk);
    debug_assert_eq!(out.len(), len * kk);
    out.fill(0.0);
    let mut a_blk = vec![0.0f64; bsz * bsz];
    let mut x_blk = vec![0.0f64; bsz];
    let mut sink = |tag: u64, y: &[f64]| {
        let j = (tag >> 32) as usize;
        let i0 = (tag & 0xffff_ffff) as usize;
        for (r, &yr) in y.iter().enumerate() {
            let li = i0 + r;
            if li < len {
                out[li * kk + j] += yr;
            }
        }
    };
    for j in 0..kk {
        for i0 in (0..len).step_by(bsz) {
            let rows = bsz.min(len - i0);
            for j0 in (0..n).step_by(bsz) {
                let cols = bsz.min(n - j0);
                a_blk.fill(0.0);
                for r in 0..rows {
                    let gi = start + i0 + r;
                    a_blk[r * bsz..r * bsz + cols]
                        .copy_from_slice(&inv[gi * n + j0..gi * n + j0 + cols]);
                }
                x_blk.fill(0.0);
                for c in 0..cols {
                    x_blk[c] = full[(j0 + c) * kk + j];
                }
                let tag = ((j as u64) << 32) | i0 as u64;
                batcher.push(&a_blk, &x_blk, tag, &mut sink);
            }
        }
    }
    batcher.flush(&mut sink);
}

struct LevelCtx {
    /// The communicator this level's operators live on (the world until
    /// the first telescope boundary, then the active sub-communicator).
    comm: Comm,
    /// The boundary below this level, if one exists (shared with the
    /// hierarchy; Rc so the recursive cycle can hold it cheaply).
    telescope: Option<Rc<Telescope>>,
    /// Halo plan for an assembled level; `None` when the level is
    /// matrix-free (the stencil operator carries its own halo plan).
    spmv: Option<DistSpmv>,
    smoother: Relax,
    transfer: Option<Transfer>,
    // work vectors
    r: DistVec,
    e: DistVec,
    work: DistVec,
    // Cached coarse-space cycle scratch, alive between applications (the
    // ROADMAP "coarse-grid caching" allocation half): `Option` so the
    // recursive cycle can take a buffer out while it crosses the level.
    /// Restricted rhs / coarse correction in this level's coarse layout.
    bc: Option<DistVec>,
    ec: Option<DistVec>,
    /// Their sub-communicator-side twins at a telescope boundary
    /// (active ranks only).
    bc_sub: Option<DistVec>,
    ec_sub: Option<DistVec>,
    /// W-cycle second-visit scratch in *this* level's row layout.
    rc2: Option<DistVec>,
    ec2: Option<DistVec>,
    /// K-wide twins of every scratch vector above, lazily allocated by
    /// [`MgPreconditioner::ensure_multi_scratch`] the first time a
    /// blocked cycle runs (and reallocated when K changes).  `mk` is the
    /// K they were sized for (0 = unallocated).
    mk: usize,
    r_m: Option<DistMultiVec>,
    e_m: Option<DistMultiVec>,
    work_m: Option<DistMultiVec>,
    bc_m: Option<DistMultiVec>,
    ec_m: Option<DistMultiVec>,
    bc_sub_m: Option<DistMultiVec>,
    ec_sub_m: Option<DistMultiVec>,
    rc2_m: Option<DistMultiVec>,
    ec2_m: Option<DistMultiVec>,
}

impl LevelCtx {
    fn multi_bytes(&self) -> u64 {
        let opt = |v: &Option<DistMultiVec>| v.as_ref().map_or(0, |x| x.bytes());
        opt(&self.r_m)
            + opt(&self.e_m)
            + opt(&self.work_m)
            + opt(&self.bc_m)
            + opt(&self.ec_m)
            + opt(&self.bc_sub_m)
            + opt(&self.ec_sub_m)
            + opt(&self.rc2_m)
            + opt(&self.ec2_m)
    }
}

/// A ready-to-apply V-cycle preconditioner.
pub struct MgPreconditioner {
    pub hierarchy: Hierarchy,
    levels: Vec<LevelCtx>,
    /// Dense inverse of the gathered coarsest operator (redundant solve).
    coarse_inv: Option<Vec<f64>>,
    coarse_n: usize,
    /// Batcher for the coarse back-substitution (scalar and blocked paths
    /// share it, so its `mults`/`flushes` count every direct solve).
    coarse_batcher: Option<SpmvBatcher<'static>>,
    /// Charges the K-wide scratch twins to [`Cat::MultiVec`] when a
    /// tracker was attached via [`MgPreconditioner::track_multi_scratch`].
    tracker: Option<MemTracker>,
    multi_charge: Option<Charge>,
    pub opts: MgOpts,
}

impl MgPreconditioner {
    /// Collective setup: smoothers, transfer plans, coarse factorization.
    /// Each level's context is built on the communicator the level lives
    /// on; an idle rank's contexts end at its telescope boundary.
    pub fn new(comm: &Comm, hierarchy: Hierarchy, opts: MgOpts) -> Self {
        let mut levels: Vec<LevelCtx> = Vec::new();
        let mut cur = comm.clone();
        let nlev = hierarchy.levels.len();
        for (li, lvl) in hierarchy.levels.iter().enumerate() {
            let spmv = match &lvl.a {
                LevelOp::Csr(a) => Some(DistSpmv::new(&cur, a)),
                LevelOp::Stencil(_) => None,
            };
            let direct = li + 1 == nlev
                && lvl.p.is_none()
                && lvl.a.row_layout().global_size() <= opts.max_direct;
            let smoother = {
                let op = lvl.a.operator(spmv.as_ref());
                Self::build_relax(&cur, &op, &opts, direct)
            };
            let transfer = lvl.p.as_ref().map(|p| Transfer::new(&cur, p));
            let layout = lvl.a.row_layout().clone();
            // coarse-space scratch: kept alive between cycle applications
            let (bc, ec) = match &lvl.p {
                Some(p) => {
                    let cl = p.col_layout.clone();
                    (
                        Some(DistVec::zeros(cl.clone(), cur.rank())),
                        Some(DistVec::zeros(cl, cur.rank())),
                    )
                }
                None => (None, None),
            };
            let (bc_sub, ec_sub) = match &lvl.telescope {
                Some(tel) if tel.subcomm.is_some() => {
                    let sc = tel.subcomm.as_ref().unwrap();
                    let nl = tel.coarse.new_layout().clone();
                    (
                        Some(DistVec::zeros(nl.clone(), sc.rank())),
                        Some(DistVec::zeros(nl, sc.rank())),
                    )
                }
                _ => (None, None),
            };
            // second-visit scratch only exists for W cycles (V never
            // calls w_revisit; don't hold dead vectors per level)
            let (rc2, ec2) = if opts.cycle == CycleType::W && li > 0 {
                (
                    Some(DistVec::zeros(layout.clone(), cur.rank())),
                    Some(DistVec::zeros(layout.clone(), cur.rank())),
                )
            } else {
                (None, None)
            };
            levels.push(LevelCtx {
                comm: cur.clone(),
                telescope: lvl.telescope.clone(),
                spmv,
                smoother,
                transfer,
                r: DistVec::zeros(layout.clone(), cur.rank()),
                e: DistVec::zeros(layout.clone(), cur.rank()),
                work: DistVec::zeros(layout, cur.rank()),
                bc,
                ec,
                bc_sub,
                ec_sub,
                rc2,
                ec2,
                mk: 0,
                r_m: None,
                e_m: None,
                work_m: None,
                bc_m: None,
                ec_m: None,
                bc_sub_m: None,
                ec_sub_m: None,
                rc2_m: None,
                ec2_m: None,
            });
            if let Some(tel) = &lvl.telescope {
                match &tel.subcomm {
                    Some(sc) => cur = sc.clone(),
                    // idle rank: the boundary level is its last
                    None => break,
                }
            }
        }
        let (coarse_inv, coarse_n) =
            Self::build_coarse_inv(&levels, &hierarchy, opts.max_direct);
        MgPreconditioner {
            hierarchy,
            levels,
            coarse_inv,
            coarse_n,
            coarse_batcher: None,
            tracker: None,
            multi_charge: None,
            opts,
        }
    }

    /// Attach a memory tracker: the blocked cycle's K-wide scratch twins
    /// are charged to [`Cat::MultiVec`] from now on (and re-charged when
    /// K changes).
    pub fn track_multi_scratch(&mut self, tracker: &MemTracker) {
        self.tracker = Some(tracker.clone());
    }

    /// Cumulative (block multiplies, kernel launches) of the batched
    /// coarse back-substitution since construction.
    pub fn coarse_batch_stats(&self) -> (u64, u64) {
        self.coarse_batcher.as_ref().map_or((0, 0), |b| (b.mults, b.flushes))
    }

    /// One level's relaxation, built from the operator's current values.
    /// The true coarsest level under the direct-solve threshold never
    /// smooths: skip its power iteration (no coarse-level epochs wasted
    /// on an unused ω).
    fn build_relax(comm: &Comm, a: &dyn DistOperator, opts: &MgOpts, direct: bool) -> Relax {
        if direct {
            return Relax::Jacobi(JacobiSmoother::new(a, 1.0));
        }
        let omega = match opts.omega {
            Some(w) => w,
            None => chebyshev_bounds(comm, a, 10).1,
        };
        match opts.smoother {
            SmootherKind::Jacobi => Relax::Jacobi(JacobiSmoother::new(a, omega)),
            SmootherKind::Chebyshev(deg) => Relax::Chebyshev(ChebyshevSmoother::new(comm, a, deg)),
            SmootherKind::HybridSor => Relax::Sor(HybridSorSmoother::new(a, 1.0)),
        }
    }

    /// Coarsest-level redundant dense inverse, built only on ranks
    /// holding the true coarsest level (idle ranks' lists end at a
    /// boundary, whose level still has a `p`).  A matrix-free coarsest
    /// level (single-level hierarchy) falls back to heavy smoothing.
    fn build_coarse_inv(
        levels: &[LevelCtx],
        hierarchy: &Hierarchy,
        max_direct: usize,
    ) -> (Option<Vec<f64>>, usize) {
        let last = hierarchy.levels.last().unwrap();
        if last.p.is_some() {
            return (None, 0);
        }
        let ccomm = &levels.last().unwrap().comm;
        let n = last.a.row_layout().global_size();
        let LevelOp::Csr(last_a) = &last.a else {
            return (None, n);
        };
        if n > max_direct {
            return (None, n);
        }
        let g = last_a.gather_global(ccomm);
        let mut dense = vec![0.0; n * n];
        for i in 0..n {
            let (cols, vals) = g.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                dense[i * n + c as usize] = v;
            }
        }
        (Some(block_invert(n, &dense).expect("coarsest operator is singular")), n)
    }

    /// Numeric-only re-setup after the hierarchy's operator values were
    /// refreshed in place (collective, level order — the same collective
    /// sequence as [`MgPreconditioner::new`], so a refreshed
    /// preconditioner is bit-identical to a fresh one): rebuild each
    /// level's smoother (diagonal extraction and, when auto-tuned, the ω
    /// power iteration) and re-factorize the coarsest direct solve.
    /// Communication plans, transfers and cycle scratch are reused — no
    /// pattern work, no re-allocation.
    pub fn refresh_solver_state(&mut self) {
        let nlev = self.hierarchy.levels.len();
        for li in 0..self.levels.len() {
            let lvl = &self.hierarchy.levels[li];
            let ctx = &mut self.levels[li];
            let direct = li + 1 == nlev
                && lvl.p.is_none()
                && lvl.a.row_layout().global_size() <= self.opts.max_direct;
            let op = lvl.a.operator(ctx.spmv.as_ref());
            ctx.smoother = Self::build_relax(&ctx.comm, &op, &self.opts, direct);
        }
        let (ci, cn) = Self::build_coarse_inv(&self.levels, &self.hierarchy, self.opts.max_direct);
        self.coarse_inv = ci;
        self.coarse_n = cn;
    }

    /// Total bytes of solver state beyond the matrices (work vectors,
    /// cached cycle scratch, smoothers, coarse inverse).
    pub fn bytes(&self) -> u64 {
        let opt = |v: &Option<DistVec>| v.as_ref().map_or(0, |x| x.bytes());
        let per_level: u64 = self
            .levels
            .iter()
            .map(|l| {
                l.r.bytes()
                    + l.e.bytes()
                    + l.work.bytes()
                    + l.smoother.bytes()
                    + opt(&l.bc)
                    + opt(&l.ec)
                    + opt(&l.bc_sub)
                    + opt(&l.ec_sub)
                    + opt(&l.rc2)
                    + opt(&l.ec2)
                    + l.multi_bytes()
            })
            .sum();
        per_level
            + self.coarse_inv.as_ref().map_or(0, |m| (m.len() * 8) as u64)
            + self.coarse_batcher.as_ref().map_or(0, |b| b.bytes())
    }

    /// Total halo gathers that hit a warm persistent buffer instead of
    /// allocating, summed over every level's SpMV plan, every transfer's
    /// prolongation plan, and any matrix-free level's stencil halo.
    pub fn halo_reuses(&self) -> u64 {
        let mut total = 0u64;
        for (li, ctx) in self.levels.iter().enumerate() {
            if let Some(s) = &ctx.spmv {
                total += s.halo_reuses();
            }
            if let Some(t) = &ctx.transfer {
                total += t.halo_reuses();
            }
            if let LevelOp::Stencil(s) = &self.hierarchy.levels[li].a {
                total += DistOperator::halo_reuses(s);
            }
        }
        total
    }

    /// Apply one V-cycle: `x = M⁻¹ b` with zero initial guess (collective
    /// over the finest level's communicator — each deeper level uses the
    /// communicator recorded at setup, so telescoped levels involve
    /// active ranks only).
    pub fn apply(&mut self, comm: &Comm, b: &DistVec, x: &mut DistVec) {
        debug_assert_eq!(comm.size(), self.levels[0].comm.size());
        crate::obs::metrics::add(crate::obs::Subsys::Mg, "cycles", 1);
        x.fill(0.0);
        self.cycle(0, b, x);
    }

    /// Apply one V-cycle to K stacked right-hand sides: `X = M⁻¹ B` with
    /// zero initial guess.  Every level pays one K-wide halo/transfer/
    /// telescope epoch instead of K scalar ones, and column `j` of the
    /// result is bitwise [`MgPreconditioner::apply`] of column `j`.
    pub fn apply_multi(&mut self, comm: &Comm, b: &DistMultiVec, x: &mut DistMultiVec) {
        debug_assert_eq!(comm.size(), self.levels[0].comm.size());
        debug_assert_eq!(b.k, x.k);
        crate::obs::metrics::add(crate::obs::Subsys::Mg, "cycles", 1);
        self.ensure_multi_scratch(b.k);
        x.fill(0.0);
        self.cycle_multi(0, b, x);
    }

    /// Allocate (or re-size) the K-wide scratch twins on every level the
    /// rank participates in.  Idempotent per K; charged to
    /// [`Cat::MultiVec`] when a tracker is attached.
    fn ensure_multi_scratch(&mut self, kk: usize) {
        debug_assert!(kk > 0);
        if self.levels.first().is_some_and(|l| l.mk == kk) {
            return;
        }
        for ctx in &mut self.levels {
            let mz = |v: &DistVec| DistMultiVec::zeros(v.layout.clone(), v.rank, kk);
            ctx.r_m = Some(mz(&ctx.r));
            ctx.e_m = Some(mz(&ctx.e));
            ctx.work_m = Some(mz(&ctx.work));
            ctx.bc_m = ctx.bc.as_ref().map(&mz);
            ctx.ec_m = ctx.ec.as_ref().map(&mz);
            ctx.bc_sub_m = ctx.bc_sub.as_ref().map(&mz);
            ctx.ec_sub_m = ctx.ec_sub.as_ref().map(&mz);
            ctx.rc2_m = ctx.rc2.as_ref().map(&mz);
            ctx.ec2_m = ctx.ec2.as_ref().map(&mz);
            ctx.mk = kk;
        }
        if let Some(t) = &self.tracker {
            let total: u64 = self.levels.iter().map(|l| l.multi_bytes()).sum();
            match &mut self.multi_charge {
                Some(c) => c.resize(total),
                None => self.multi_charge = Some(Charge::new(t, Cat::MultiVec, total)),
            }
        }
    }

    /// The K-wide twin of [`MgPreconditioner::cycle`]: the same smoothing
    /// / residual / restrict / recurse / prolongate sequence with every
    /// collective replaced by its blocked counterpart.
    fn cycle_multi(&mut self, k: usize, b: &DistMultiVec, x: &mut DistMultiVec) {
        let _lvl_sp = crate::obs::span(crate::obs::Subsys::Mg, "level", k as u64);
        let comm = self.levels[k].comm.clone();
        let comm = &comm;
        let nlev = self.levels.len();
        if k + 1 == nlev && self.hierarchy.levels[k].p.is_none() {
            self.coarse_solve_multi(comm, k, b, x);
            return;
        }
        {
            let _sp = crate::obs::span(crate::obs::Subsys::Mg, "smooth.pre", k as u64);
            for _ in 0..self.opts.pre_smooth {
                let lvl = &mut self.levels[k];
                let a = &self.hierarchy.levels[k].a;
                let op = a.operator(lvl.spmv.as_ref());
                lvl.smoother.sweep_multi(comm, &op, b, x, lvl.work_m.as_mut().unwrap());
            }
        }
        // residual R = B - A X
        {
            let _sp = crate::obs::span(crate::obs::Subsys::Mg, "residual", k as u64);
            let lvl = &mut self.levels[k];
            let a = &self.hierarchy.levels[k].a;
            let op = a.operator(lvl.spmv.as_ref());
            op.apply_multi(comm, x, lvl.work_m.as_mut().unwrap());
        }
        {
            let lvl = &mut self.levels[k];
            let work = lvl.work_m.take().unwrap();
            let r = lvl.r_m.as_mut().unwrap();
            r.vals.clone_from(&b.vals);
            for (rv, wv) in r.vals.iter_mut().zip(&work.vals) {
                *rv -= wv;
            }
            lvl.work_m = Some(work);
        }
        let mut bc = self.levels[k].bc_m.take().expect("coarse rhs scratch in use");
        {
            let _sp = crate::obs::span(crate::obs::Subsys::Mg, "restrict", k as u64);
            let p = self.hierarchy.levels[k].p.as_ref().unwrap();
            let lvl = &self.levels[k];
            lvl.transfer.as_ref().unwrap().restrict_multi(
                comm,
                p,
                lvl.r_m.as_ref().unwrap(),
                &mut bc,
            );
        }
        let w_revisit = self.opts.cycle == CycleType::W
            && self.hierarchy.levels.get(k + 1).is_some_and(|l| l.p.is_some());
        let mut ec = self.levels[k].ec_m.take().expect("coarse correction scratch in use");
        if let Some(tel) = self.levels[k].telescope.clone() {
            let mut bc_sub = self.levels[k].bc_sub_m.take();
            {
                let _sp = crate::obs::span(crate::obs::Subsys::Mg, "redist.scatter", k as u64);
                tel.coarse.scatter_multi_into(comm, &bc, bc_sub.as_mut());
            }
            let ec_sub = match (&tel.subcomm, bc_sub.as_ref()) {
                (Some(_), Some(bc_s)) => {
                    let mut ec_sub =
                        self.levels[k].ec_sub_m.take().expect("subcomm scratch in use");
                    ec_sub.fill(0.0);
                    self.cycle_multi(k + 1, bc_s, &mut ec_sub);
                    if w_revisit {
                        self.w_revisit_multi(k, bc_s, &mut ec_sub);
                    }
                    Some(ec_sub)
                }
                _ => None,
            };
            {
                let _sp = crate::obs::span(crate::obs::Subsys::Mg, "redist.gather", k as u64);
                tel.coarse.gather_multi_into(comm, ec_sub.as_ref(), &mut ec);
            }
            self.levels[k].ec_sub_m = ec_sub;
            self.levels[k].bc_sub_m = bc_sub;
        } else {
            ec.fill(0.0);
            self.cycle_multi(k + 1, &bc, &mut ec);
            if w_revisit {
                self.w_revisit_multi(k, &bc, &mut ec);
            }
        }
        {
            let _sp = crate::obs::span(crate::obs::Subsys::Mg, "prolong", k as u64);
            let p = self.hierarchy.levels[k].p.as_ref().unwrap();
            let lvl = &mut self.levels[k];
            let e = lvl.e_m.as_mut().unwrap();
            e.fill(0.0);
            lvl.transfer.as_ref().unwrap().prolong_add_multi(comm, p, &ec, e);
        }
        self.levels[k].bc_m = Some(bc);
        self.levels[k].ec_m = Some(ec);
        {
            let e = self.levels[k].e_m.as_ref().unwrap();
            for (xv, ev) in x.vals.iter_mut().zip(&e.vals) {
                *xv += ev;
            }
        }
        {
            let _sp = crate::obs::span(crate::obs::Subsys::Mg, "smooth.post", k as u64);
            for _ in 0..self.opts.post_smooth {
                let lvl = &mut self.levels[k];
                let a = &self.hierarchy.levels[k].a;
                let op = a.operator(lvl.spmv.as_ref());
                lvl.smoother.sweep_multi(comm, &op, b, x, lvl.work_m.as_mut().unwrap());
            }
        }
    }

    /// K-wide W-cycle second visit (twin of
    /// [`MgPreconditioner::w_revisit`]).
    fn w_revisit_multi(&mut self, k: usize, bc: &DistMultiVec, ec: &mut DistMultiVec) {
        let comm = self.levels[k + 1].comm.clone();
        let mut rc2 = self.levels[k + 1].rc2_m.take().expect("W-cycle rhs scratch in use");
        {
            let ac = &self.hierarchy.levels[k + 1].a;
            let lvl = &mut self.levels[k + 1];
            let op = ac.operator(lvl.spmv.as_ref());
            op.apply_multi(&comm, ec, lvl.work_m.as_mut().unwrap());
        }
        {
            let work = self.levels[k + 1].work_m.as_ref().unwrap();
            rc2.vals.clone_from(&bc.vals);
            for (rv, wv) in rc2.vals.iter_mut().zip(&work.vals) {
                *rv -= wv;
            }
        }
        let mut ec2 =
            self.levels[k + 1].ec2_m.take().expect("W-cycle correction scratch in use");
        ec2.fill(0.0);
        self.cycle_multi(k + 1, &rc2, &mut ec2);
        for (ev, e2) in ec.vals.iter_mut().zip(&ec2.vals) {
            *ev += 1.0 * e2;
        }
        self.levels[k + 1].rc2_m = Some(rc2);
        self.levels[k + 1].ec2_m = Some(ec2);
    }

    /// Blocked coarsest solve: one allgather ships all K local slices,
    /// one retained factorization back-substitutes K columns through the
    /// batched block kernel.
    fn coarse_solve_multi(
        &mut self,
        comm: &Comm,
        k: usize,
        b: &DistMultiVec,
        x: &mut DistMultiVec,
    ) {
        let _sp = crate::obs::span(crate::obs::Subsys::Mg, "coarse_solve", k as u64);
        let kk = b.k;
        match &self.coarse_inv {
            Some(inv) => {
                let n = self.coarse_n;
                let mut w = ByteWriter::with_capacity(8 * b.vals.len());
                w.f64_slice(&b.vals);
                let all = comm.allgather_bytes(w.into_bytes());
                let mut full = Vec::with_capacity(n * kk);
                for payload in &all {
                    let mut r = ByteReader::new(payload);
                    while !r.done() {
                        full.push(r.f64());
                    }
                }
                debug_assert_eq!(full.len(), n * kk);
                let start = b.layout.start(comm.rank());
                let len = b.local_len();
                let batcher = self
                    .coarse_batcher
                    .get_or_insert_with(|| SpmvBatcher::new(BlockBackend::Native, COARSE_TILE));
                coarse_backsub(batcher, inv, n, &full, kk, start, len, &mut x.vals);
            }
            None => {
                // fall back to heavy smoothing
                for _ in 0..20 {
                    let lvl = &mut self.levels[k];
                    let a = &self.hierarchy.levels[k].a;
                    let op = a.operator(lvl.spmv.as_ref());
                    lvl.smoother.sweep_multi(comm, &op, b, x, lvl.work_m.as_mut().unwrap());
                }
            }
        }
    }

    fn cycle(&mut self, k: usize, b: &DistVec, x: &mut DistVec) {
        let _lvl_sp = crate::obs::span(crate::obs::Subsys::Mg, "level", k as u64);
        let comm = self.levels[k].comm.clone();
        let comm = &comm;
        let nlev = self.levels.len();
        if k + 1 == nlev && self.hierarchy.levels[k].p.is_none() {
            self.coarse_solve(comm, k, b, x);
            return;
        }
        // borrow juggling: split level k from level k+1 state
        {
            let _sp = crate::obs::span(crate::obs::Subsys::Mg, "smooth.pre", k as u64);
            for _ in 0..self.opts.pre_smooth {
                let lvl = &mut self.levels[k];
                let a = &self.hierarchy.levels[k].a;
                let op = a.operator(lvl.spmv.as_ref());
                lvl.smoother.sweep(comm, &op, b, x, &mut lvl.work);
            }
        }
        // residual r = b - A x
        {
            let _sp = crate::obs::span(crate::obs::Subsys::Mg, "residual", k as u64);
            let lvl = &mut self.levels[k];
            let a = &self.hierarchy.levels[k].a;
            let op = a.operator(lvl.spmv.as_ref());
            op.apply(comm, x, &mut lvl.work);
            lvl.r.vals.clone_from(&b.vals);
            for i in 0..lvl.r.vals.len() {
                lvl.r.vals[i] -= lvl.work.vals[i];
            }
        }
        // restrict to coarse rhs (cached coarse-layout scratch — taken
        // out for the crossing, put back after prolongation)
        let mut bc = self.levels[k].bc.take().expect("coarse rhs scratch in use");
        {
            let _sp = crate::obs::span(crate::obs::Subsys::Mg, "restrict", k as u64);
            let p = self.hierarchy.levels[k].p.as_ref().unwrap();
            let lvl = &self.levels[k];
            lvl.transfer.as_ref().unwrap().restrict(comm, p, &lvl.r, &mut bc);
        }
        // coarse correction — across the telescope boundary when one sits
        // below this level (W-cycle: recurse twice, re-restricting the
        // updated residual before the second visit)
        // W-cycle revisit gate: "level k+1 is not the coarsest".  Decided
        // from the level itself, NOT the rank-local level count — at a
        // telescope boundary, idle ranks' lists end early while they must
        // still join the second visit's redistribution epochs.
        let w_revisit = self.opts.cycle == CycleType::W
            && self.hierarchy.levels.get(k + 1).is_some_and(|l| l.p.is_some());
        let mut ec = self.levels[k].ec.take().expect("coarse correction scratch in use");
        if let Some(tel) = self.levels[k].telescope.clone() {
            // scatter the rhs into the subcomm; idle ranks skip straight
            // to the gather below
            let mut bc_sub = self.levels[k].bc_sub.take();
            {
                let _sp = crate::obs::span(crate::obs::Subsys::Mg, "redist.scatter", k as u64);
                tel.coarse.scatter_vec_into(comm, &bc, bc_sub.as_mut());
            }
            let ec_sub = match (&tel.subcomm, bc_sub.as_ref()) {
                (Some(_), Some(bc_s)) => {
                    let mut ec_sub =
                        self.levels[k].ec_sub.take().expect("subcomm scratch in use");
                    ec_sub.fill(0.0);
                    self.cycle(k + 1, bc_s, &mut ec_sub);
                    if w_revisit {
                        self.w_revisit(k, bc_s, &mut ec_sub);
                    }
                    Some(ec_sub)
                }
                _ => None,
            };
            {
                let _sp = crate::obs::span(crate::obs::Subsys::Mg, "redist.gather", k as u64);
                tel.coarse.gather_vec_into(comm, ec_sub.as_ref(), &mut ec);
            }
            self.levels[k].ec_sub = ec_sub;
            self.levels[k].bc_sub = bc_sub;
        } else {
            ec.fill(0.0);
            self.cycle(k + 1, &bc, &mut ec);
            if w_revisit {
                self.w_revisit(k, &bc, &mut ec);
            }
        }
        // prolongate and correct
        {
            let _sp = crate::obs::span(crate::obs::Subsys::Mg, "prolong", k as u64);
            let p = self.hierarchy.levels[k].p.as_ref().unwrap();
            let lvl = &mut self.levels[k];
            lvl.e.fill(0.0);
            lvl.transfer.as_ref().unwrap().prolong_add(comm, p, &ec, &mut lvl.e);
        }
        self.levels[k].bc = Some(bc);
        self.levels[k].ec = Some(ec);
        for i in 0..x.vals.len() {
            x.vals[i] += self.levels[k].e.vals[i];
        }
        {
            let _sp = crate::obs::span(crate::obs::Subsys::Mg, "smooth.post", k as u64);
            for _ in 0..self.opts.post_smooth {
                let lvl = &mut self.levels[k];
                let a = &self.hierarchy.levels[k].a;
                let op = a.operator(lvl.spmv.as_ref());
                lvl.smoother.sweep(comm, &op, b, x, &mut lvl.work);
            }
        }
    }

    /// W-cycle second coarse visit at level `k + 1`:
    /// `rc2 = bc - A_c ec; ec += cycle(rc2)` — `bc`/`ec` live in level
    /// `k + 1`'s layout (inside the subcomm when level `k` telescopes).
    fn w_revisit(&mut self, k: usize, bc: &DistVec, ec: &mut DistVec) {
        let comm = self.levels[k + 1].comm.clone();
        let mut rc2 = self.levels[k + 1].rc2.take().expect("W-cycle rhs scratch in use");
        {
            let ac = &self.hierarchy.levels[k + 1].a;
            let lvl = &mut self.levels[k + 1];
            let op = ac.operator(lvl.spmv.as_ref());
            op.apply(&comm, ec, &mut lvl.work);
            rc2.vals.clone_from(&bc.vals);
            for i in 0..rc2.vals.len() {
                rc2.vals[i] -= lvl.work.vals[i];
            }
        }
        let mut ec2 = self.levels[k + 1].ec2.take().expect("W-cycle correction scratch in use");
        ec2.fill(0.0);
        self.cycle(k + 1, &rc2, &mut ec2);
        ec.axpy(1.0, &ec2);
        self.levels[k + 1].rc2 = Some(rc2);
        self.levels[k + 1].ec2 = Some(ec2);
    }

    fn coarse_solve(&mut self, comm: &Comm, k: usize, b: &DistVec, x: &mut DistVec) {
        let _sp = crate::obs::span(crate::obs::Subsys::Mg, "coarse_solve", k as u64);
        match &self.coarse_inv {
            Some(inv) => {
                // gather full rhs on every rank, apply the dense inverse,
                // keep the local slice (PETSc "redundant" analog); the
                // back-substitution is tiled through the block-kernel
                // batcher — the same fold the K-wide solve uses, so the
                // scalar and blocked coarse solves agree bit for bit
                let n = self.coarse_n;
                let mut w = ByteWriter::with_capacity(8 * b.vals.len());
                w.f64_slice(&b.vals);
                let all = comm.allgather_bytes(w.into_bytes());
                let mut full = Vec::with_capacity(n);
                for payload in &all {
                    let mut r = ByteReader::new(payload);
                    while !r.done() {
                        full.push(r.f64());
                    }
                }
                debug_assert_eq!(full.len(), n);
                let start = b.layout.start(comm.rank());
                let len = x.vals.len();
                let batcher = self
                    .coarse_batcher
                    .get_or_insert_with(|| SpmvBatcher::new(BlockBackend::Native, COARSE_TILE));
                coarse_backsub(batcher, inv, n, &full, 1, start, len, &mut x.vals);
            }
            None => {
                // fall back to heavy smoothing
                for _ in 0..20 {
                    let lvl = &mut self.levels[k];
                    let a = &self.hierarchy.levels[k].a;
                    let op = a.operator(lvl.spmv.as_ref());
                    lvl.smoother.sweep(comm, &op, b, x, &mut lvl.work);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::World;
    use crate::gen::{grid_laplacian, Grid3};
    use crate::mem::MemTracker;
    use crate::mg::hierarchy::{build_hierarchy, geometric_chain, Coarsening, HierarchyConfig};

    #[test]
    fn vcycle_contracts_error() {
        let w = World::new(2);
        w.run(|c| {
            let grids = geometric_chain(Grid3::cube(3), 3);
            let a0 = grid_laplacian(grids[0], c.rank(), c.size());
            let layout = a0.row_layout.clone();
            let tracker = MemTracker::new();
            let h = build_hierarchy(
                &c,
                a0,
                &Coarsening::Geometric { grids },
                HierarchyConfig::default(),
                &tracker,
            );
            let a = h.levels[0].a.csr().clone();
            let spmv = DistSpmv::new(&c, &a);
            let mut pc = MgPreconditioner::new(&c, h, MgOpts::default());
            // b = A * ones
            let ones = DistVec::from_fn(layout.clone(), c.rank(), |_| 1.0);
            let mut b = DistVec::zeros(layout.clone(), c.rank());
            spmv.apply(&c, &a, &ones, &mut b);
            // iterate x <- x + M^-1 (b - A x)
            let mut x = DistVec::zeros(layout.clone(), c.rank());
            let mut r = b.clone();
            let r0 = r.norm2(&c);
            let mut z = DistVec::zeros(layout.clone(), c.rank());
            let mut ax = DistVec::zeros(layout, c.rank());
            for _ in 0..8 {
                pc.apply(&c, &r, &mut z);
                x.axpy(1.0, &z);
                spmv.apply(&c, &a, &x, &mut ax);
                r.vals.clone_from(&b.vals);
                for i in 0..r.vals.len() {
                    r.vals[i] -= ax.vals[i];
                }
            }
            let r8 = r.norm2(&c);
            // V(1,1) point-Jacobi on a 9³→5³→3³ chain contracts ≈0.3/iter
            assert!(
                r8 < 1e-3 * r0,
                "V-cycle iteration stalled: {r0} -> {r8}"
            );
        });
    }
}
