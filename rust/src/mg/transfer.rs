//! Grid-transfer operators applied matrix-free from the stored `P`:
//! prolongation `x_f += P x_c` (halo-gather of coarse values) and
//! restriction `r_c = Pᵀ r_f` (scatter + owner sends, the same
//! communication shape as the all-at-once product's remote loop).
//!
//! Partition invariance: prolongation folds each fine row in global
//! column order (like [`crate::dist::DistSpmv`]), so its bits do not
//! depend on the partition.  Restriction is a scatter — each coarse slot
//! accumulates local contributions (fine-row order) then remote ones
//! (source-rank order), so its rounding *is* partition-dependent; a
//! telescoped level whose restriction runs on the subcomm reproduces the
//! full-communicator bits only when the products are exact (e.g. the
//! model problem's power-of-two weights against exact values).

use std::cell::{Cell, RefCell};

use crate::dist::{Comm, DistCsr, DistMultiVec, DistVec, VecGatherPlan};
use crate::util::bytebuf::{ByteReader, ByteWriter};

/// Cached communication plans for one interpolation operator.
#[derive(Debug)]
pub struct Transfer {
    /// Coarse-value halo for prolongation (needed ids = P.garray).
    halo: VecGatherPlan,
    /// Owner of each P.garray entry (restriction sends).
    garray_owner: Vec<usize>,
    /// Per-fine-row offd split ([`DistCsr::offd_split`]), precomputed for
    /// prolongation's global-column-order fold.
    splits: Vec<u32>,
    /// Persistent prolongation halo buffer (warm after the first cycle).
    buf: RefCell<Vec<f64>>,
    /// K-wide twin of `buf` for blocked prolongation.
    buf_multi: RefCell<Vec<f64>>,
    reuses: Cell<u64>,
}

impl Transfer {
    /// Collective build.
    pub fn new(comm: &Comm, p: &DistCsr) -> Self {
        let halo = VecGatherPlan::build(comm, &p.col_layout, &p.garray);
        let garray_owner =
            p.garray.iter().map(|&g| p.col_layout.owner(g as usize)).collect();
        let splits = (0..p.local_nrows()).map(|i| p.offd_split(i) as u32).collect();
        Transfer {
            halo,
            garray_owner,
            splits,
            buf: RefCell::new(Vec::new()),
            buf_multi: RefCell::new(Vec::new()),
            reuses: Cell::new(0),
        }
    }

    /// Prolongation halo gathers served from the warm persistent buffer.
    pub fn halo_reuses(&self) -> u64 {
        self.reuses.get()
    }

    /// `x_f += P x_c` (collective).  Folds each row in ascending global
    /// column order, so the bits are partition-invariant.
    pub fn prolong_add(&self, comm: &Comm, p: &DistCsr, xc: &DistVec, xf: &mut DistVec) {
        let mut halo = self.buf.borrow_mut();
        if halo.capacity() >= self.halo.n_needed() && self.halo.n_needed() > 0 {
            self.reuses.set(self.reuses.get() + 1);
            crate::obs::metrics::add(crate::obs::Subsys::Comm, "halo.reuse", 1);
        }
        self.halo.gather_into(comm, &xc.vals, &mut halo);
        debug_assert_eq!(self.splits.len(), p.local_nrows());
        for i in 0..p.local_nrows() {
            let (dc, dv) = p.diag.row(i);
            let (oc, ov) = p.offd.row(i);
            let split = self.splits[i] as usize;
            let mut acc = 0.0;
            for k in 0..split {
                acc += ov[k] * halo[oc[k] as usize];
            }
            for (&c, &v) in dc.iter().zip(dv) {
                acc += v * xc.vals[c as usize];
            }
            for k in split..oc.len() {
                acc += ov[k] * halo[oc[k] as usize];
            }
            xf.vals[i] += acc;
        }
    }

    /// `X_f += P X_c` for K stacked columns (collective): one K-wide halo
    /// epoch, each column folded in the exact [`Transfer::prolong_add`]
    /// order so column `j` is bitwise the scalar prolongation of column
    /// `j`.
    pub fn prolong_add_multi(
        &self,
        comm: &Comm,
        p: &DistCsr,
        xc: &DistMultiVec,
        xf: &mut DistMultiVec,
    ) {
        let kk = xc.k;
        debug_assert_eq!(kk, xf.k);
        let mut halo = self.buf_multi.borrow_mut();
        if halo.capacity() >= self.halo.n_needed() * kk && self.halo.n_needed() > 0 {
            self.reuses.set(self.reuses.get() + 1);
            crate::obs::metrics::add(crate::obs::Subsys::Comm, "halo.reuse", 1);
        }
        self.halo.gather_multi_into(comm, &xc.vals, kk, &mut halo);
        debug_assert_eq!(self.splits.len(), p.local_nrows());
        let mut acc = vec![0.0f64; kk];
        for i in 0..p.local_nrows() {
            let (dc, dv) = p.diag.row(i);
            let (oc, ov) = p.offd.row(i);
            let split = self.splits[i] as usize;
            acc.fill(0.0);
            for t in 0..split {
                let base = oc[t] as usize * kk;
                let v = ov[t];
                for (j, aj) in acc.iter_mut().enumerate() {
                    *aj += v * halo[base + j];
                }
            }
            for (&c, &v) in dc.iter().zip(dv) {
                let base = c as usize * kk;
                for (j, aj) in acc.iter_mut().enumerate() {
                    *aj += v * xc.vals[base + j];
                }
            }
            for t in split..oc.len() {
                let base = oc[t] as usize * kk;
                let v = ov[t];
                for (j, aj) in acc.iter_mut().enumerate() {
                    *aj += v * halo[base + j];
                }
            }
            for (j, &aj) in acc.iter().enumerate() {
                xf.vals[i * kk + j] += aj;
            }
        }
    }

    /// `r_c = Pᵀ r_f` (collective).
    pub fn restrict(&self, comm: &Comm, p: &DistCsr, rf: &DistVec, rc: &mut DistVec) {
        rc.fill(0.0);
        // local scatter
        for i in 0..p.local_nrows() {
            let ri = rf.vals[i];
            if ri == 0.0 {
                continue;
            }
            let (dc, dv) = p.diag.row(i);
            for (&c, &v) in dc.iter().zip(dv) {
                rc.vals[c as usize] += v * ri;
            }
        }
        // off-rank contributions accumulated per garray slot
        let mut acc = vec![0.0f64; p.garray.len()];
        for i in 0..p.local_nrows() {
            let ri = rf.vals[i];
            if ri == 0.0 {
                continue;
            }
            let (oc, ov) = p.offd.row(i);
            for (&c, &v) in oc.iter().zip(ov) {
                acc[c as usize] += v * ri;
            }
        }
        // ship (gid, value) pairs to owners
        let np = comm.size();
        let mut writers: Vec<Option<ByteWriter>> = (0..np).map(|_| None).collect();
        for (t, &val) in acc.iter().enumerate() {
            if val == 0.0 {
                continue;
            }
            let owner = self.garray_owner[t];
            let w = writers[owner].get_or_insert_with(ByteWriter::new);
            w.u64(p.garray[t]);
            w.f64(val);
        }
        let sends: Vec<(usize, Vec<u8>)> = writers
            .into_iter()
            .enumerate()
            .filter_map(|(d, w)| w.map(|w| (d, w.into_bytes())))
            .collect();
        let recvd = comm.exchange(sends);
        let cbeg = p.col_layout.start(p.rank) as u64;
        for (_src, payload) in &recvd {
            let mut r = ByteReader::new(payload);
            while !r.done() {
                let gid = r.u64();
                let val = r.f64();
                rc.vals[(gid - cbeg) as usize] += val;
            }
        }
    }

    /// `R_c = Pᵀ R_f` for K stacked columns (collective): one exchange
    /// round shipping `(gid, K×f64)` tuples.  Per-column zero skips match
    /// the scalar [`Transfer::restrict`] exactly (contributions are added
    /// only where the scalar path would add them), so column `j` is
    /// bitwise the scalar restriction of column `j`.
    pub fn restrict_multi(
        &self,
        comm: &Comm,
        p: &DistCsr,
        rf: &DistMultiVec,
        rc: &mut DistMultiVec,
    ) {
        let kk = rf.k;
        debug_assert_eq!(kk, rc.k);
        rc.fill(0.0);
        // local scatter
        for i in 0..p.local_nrows() {
            let ri = &rf.vals[i * kk..(i + 1) * kk];
            if ri.iter().all(|&v| v == 0.0) {
                continue;
            }
            let (dc, dv) = p.diag.row(i);
            for (&c, &v) in dc.iter().zip(dv) {
                let base = c as usize * kk;
                for (j, &rij) in ri.iter().enumerate() {
                    if rij != 0.0 {
                        rc.vals[base + j] += v * rij;
                    }
                }
            }
        }
        // off-rank contributions accumulated per garray slot
        let mut acc = vec![0.0f64; p.garray.len() * kk];
        for i in 0..p.local_nrows() {
            let ri = &rf.vals[i * kk..(i + 1) * kk];
            if ri.iter().all(|&v| v == 0.0) {
                continue;
            }
            let (oc, ov) = p.offd.row(i);
            for (&c, &v) in oc.iter().zip(ov) {
                let base = c as usize * kk;
                for (j, &rij) in ri.iter().enumerate() {
                    if rij != 0.0 {
                        acc[base + j] += v * rij;
                    }
                }
            }
        }
        // ship (gid, K values) tuples to owners; slots all-zero across
        // every column are dropped like the scalar path drops zero slots
        let np = comm.size();
        let mut writers: Vec<Option<ByteWriter>> = (0..np).map(|_| None).collect();
        for t in 0..p.garray.len() {
            let row = &acc[t * kk..(t + 1) * kk];
            if row.iter().all(|&v| v == 0.0) {
                continue;
            }
            let owner = self.garray_owner[t];
            let w = writers[owner].get_or_insert_with(ByteWriter::new);
            w.u64(p.garray[t]);
            w.f64_slice(row);
        }
        let sends: Vec<(usize, Vec<u8>)> = writers
            .into_iter()
            .enumerate()
            .filter_map(|(d, w)| w.map(|w| (d, w.into_bytes())))
            .collect();
        let recvd = comm.exchange(sends);
        let cbeg = p.col_layout.start(p.rank) as u64;
        for (_src, payload) in &recvd {
            let mut r = ByteReader::new(payload);
            while !r.done() {
                let gid = r.u64();
                let base = (gid - cbeg) as usize * kk;
                for j in 0..kk {
                    let val = r.f64();
                    // a column the scalar path would have skipped (its
                    // slot accumulated to zero) must stay untouched
                    if val != 0.0 {
                        rc.vals[base + j] += val;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::World;
    use crate::gen::trilinear_interp;
    use crate::gen::Grid3;

    #[test]
    fn restrict_matches_explicit_transpose() {
        let coarse = Grid3::cube(3);
        let w = World::new(3);
        let pieces = w.run(|c| {
            let p = trilinear_interp(coarse, c.rank(), c.size());
            let t = Transfer::new(&c, &p);
            let rf = DistVec::from_fn(p.row_layout.clone(), c.rank(), |g| (g % 7) as f64 - 3.0);
            let mut rc = DistVec::zeros(p.col_layout.clone(), c.rank());
            t.restrict(&c, &p, &rf, &mut rc);
            let pg = p.gather_global(&c);
            (p.col_layout.start(c.rank()), rc.vals, pg)
        });
        // sequential reference: rc = P^T rf
        let pg = &pieces[0].2;
        let n = pg.nrows;
        let rf_full: Vec<f64> = (0..n).map(|g| (g % 7) as f64 - 3.0).collect();
        let mut want = vec![0.0; pg.ncols];
        pg.spmv_transpose_add(&rf_full, &mut want);
        for (start, vals, _) in &pieces {
            for (k, &v) in vals.iter().enumerate() {
                assert!(
                    (v - want[start + k]).abs() < 1e-12,
                    "coarse {}: {} vs {}",
                    start + k,
                    v,
                    want[start + k]
                );
            }
        }
    }

    #[test]
    fn prolong_matches_explicit_p() {
        let coarse = Grid3::cube(3);
        let w = World::new(4);
        let pieces = w.run(|c| {
            let p = trilinear_interp(coarse, c.rank(), c.size());
            let t = Transfer::new(&c, &p);
            let xc = DistVec::from_fn(p.col_layout.clone(), c.rank(), |g| g as f64);
            let mut xf = DistVec::zeros(p.row_layout.clone(), c.rank());
            t.prolong_add(&c, &p, &xc, &mut xf);
            let pg = p.gather_global(&c);
            (p.row_layout.start(c.rank()), xf.vals, pg)
        });
        let pg = &pieces[0].2;
        let xc_full: Vec<f64> = (0..pg.ncols).map(|g| g as f64).collect();
        let mut want = vec![0.0; pg.nrows];
        pg.spmv(&xc_full, &mut want);
        for (start, vals, _) in &pieces {
            for (k, &v) in vals.iter().enumerate() {
                assert!((v - want[start + k]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn prolong_restrict_adjoint_identity() {
        // <P xc, rf> == <xc, P^T rf> — the Galerkin adjoint relation
        let coarse = Grid3::cube(3);
        let w = World::new(2);
        w.run(|c| {
            let p = trilinear_interp(coarse, c.rank(), c.size());
            let t = Transfer::new(&c, &p);
            let xc = DistVec::from_fn(p.col_layout.clone(), c.rank(), |g| (g as f64).sin());
            let rf = DistVec::from_fn(p.row_layout.clone(), c.rank(), |g| (g as f64).cos());
            let mut pxc = DistVec::zeros(p.row_layout.clone(), c.rank());
            t.prolong_add(&c, &p, &xc, &mut pxc);
            let mut ptrf = DistVec::zeros(p.col_layout.clone(), c.rank());
            t.restrict(&c, &p, &rf, &mut ptrf);
            let lhs = pxc.dot(&c, &rf);
            let rhs = xc.dot(&c, &ptrf);
            assert!((lhs - rhs).abs() < 1e-9, "{lhs} vs {rhs}");
        });
    }
}
