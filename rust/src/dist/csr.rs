//! Distributed CSR matrix (PETSc MPIAIJ analog): each rank owns a
//! contiguous block of rows, stored as two sequential CSRs — `diag` (the
//! columns this rank owns, with *local* column ids) and `offd` (everything
//! else, with column ids compacted against the sorted global id table
//! `garray`).  This is exactly the layout the paper's algorithms (and
//! PETSc's `MatPtAP`) are written against.

use crate::mat::{Csr, CsrBuilder};
use crate::util::bytebuf::{ByteReader, ByteWriter};

use super::layout::Layout;
use super::world::Comm;

/// One rank's slice of a distributed sparse matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct DistCsr {
    pub rank: usize,
    pub row_layout: Layout,
    pub col_layout: Layout,
    /// Rows over this rank's own column range; columns are local ids
    /// (global id = `col_begin() + local`).
    pub diag: Csr,
    /// Rows over off-rank columns, compacted: column `c` means global
    /// column `garray[c]`.
    pub offd: Csr,
    /// Sorted global ids of the off-diagonal columns referenced here.
    pub garray: Vec<u64>,
}

impl DistCsr {
    /// Rows owned by this rank.
    pub fn local_nrows(&self) -> usize {
        self.diag.nrows
    }

    /// First global row owned by this rank.
    pub fn row_begin(&self) -> usize {
        self.row_layout.start(self.rank)
    }

    /// First global column owned by this rank.
    pub fn col_begin(&self) -> usize {
        self.col_layout.start(self.rank)
    }

    pub fn global_nrows(&self) -> usize {
        self.row_layout.global_size()
    }

    pub fn global_ncols(&self) -> usize {
        self.col_layout.global_size()
    }

    /// Local nonzeros (diag + offd).
    pub fn nnz_local(&self) -> usize {
        self.diag.nnz() + self.offd.nnz()
    }

    /// Global nonzeros (collective).
    pub fn nnz_global(&self, comm: &Comm) -> u64 {
        comm.allreduce_sum_u64(self.nnz_local() as u64)
    }

    /// Heap bytes of this rank's slice (the tables' A/P/C storage).
    pub fn bytes(&self) -> u64 {
        self.diag.bytes() + self.offd.bytes() + (self.garray.len() * 8) as u64
    }

    /// Global (min, max, avg) nonzeros per row (collective) — the paper's
    /// Table 5/6 `cols` columns.
    pub fn row_nnz_stats(&self, comm: &Comm) -> (u64, u64, f64) {
        let mut lmin = u64::MAX;
        let mut lmax = 0u64;
        let mut lsum = 0u64;
        for i in 0..self.local_nrows() {
            let n = (self.diag.row_len(i) + self.offd.row_len(i)) as u64;
            lmin = lmin.min(n);
            lmax = lmax.max(n);
            lsum += n;
        }
        let mins = comm.all_u64(lmin);
        let maxs = comm.all_u64(lmax);
        let sums = comm.all_u64(lsum);
        let gmin = mins.into_iter().min().unwrap();
        let gmax = maxs.into_iter().max().unwrap();
        let gsum: u64 = sums.into_iter().sum();
        let rows = self.global_nrows();
        let avg = if rows == 0 { 0.0 } else { gsum as f64 / rows as f64 };
        (if gmin == u64::MAX { 0 } else { gmin }, gmax, avg)
    }

    /// Index within row `i`'s offd entries where the global column ids
    /// pass this rank's diag range — the single definition of the split
    /// every ascending-global-column fold uses (offd below the diag
    /// range, then diag, then offd above; see [`DistCsr::row_global`]).
    /// `garray` ascends with the compacted ids, so this is a binary
    /// search.
    #[inline]
    pub fn offd_split(&self, i: usize) -> usize {
        let cbeg = self.col_begin() as u64;
        self.offd.row_cols(i).partition_point(|&c| self.garray[c as usize] < cbeg)
    }

    /// Row `i` with *global* column ids, sorted ascending, appended into
    /// the provided buffers (cleared first).
    pub fn row_global(&self, i: usize, cols: &mut Vec<u64>, vals: &mut Vec<f64>) {
        cols.clear();
        vals.clear();
        let cbeg = self.col_begin() as u64;
        let (oc, ov) = self.offd.row(i);
        let (dc, dv) = self.diag.row(i);
        let split = self.offd_split(i);
        for k in 0..split {
            cols.push(self.garray[oc[k] as usize]);
            vals.push(ov[k]);
        }
        for (&c, &v) in dc.iter().zip(dv) {
            cols.push(cbeg + c as u64);
            vals.push(v);
        }
        for k in split..oc.len() {
            cols.push(self.garray[oc[k] as usize]);
            vals.push(ov[k]);
        }
    }

    /// Overwrite row `i`'s values from `vals`, given in [`DistCsr::row_global`]
    /// order (ascending global column) — the redistribution refresh's
    /// wire order.  The pattern must be unchanged.
    pub fn set_row_global_vals(&mut self, i: usize, vals: &[f64]) {
        let or = self.offd.rowptr[i] as usize..self.offd.rowptr[i + 1] as usize;
        let dr = self.diag.rowptr[i] as usize..self.diag.rowptr[i + 1] as usize;
        debug_assert_eq!(vals.len(), or.len() + dr.len(), "pattern drift in value refresh");
        let split = self.offd_split(i);
        let mut k = 0usize;
        for j in 0..split {
            self.offd.vals[or.start + j] = vals[k];
            k += 1;
        }
        for j in dr {
            self.diag.vals[j] = vals[k];
            k += 1;
        }
        for j in split..or.len() {
            self.offd.vals[or.start + j] = vals[k];
            k += 1;
        }
    }

    /// Overwrite every value from `other`, which must have the identical
    /// distributed pattern (the `MAT_REUSE_MATRIX` value path: a numeric
    /// refresh replaces values without touching structure or layouts).
    pub fn copy_values_from(&mut self, other: &DistCsr) {
        debug_assert_eq!(self.row_layout, other.row_layout, "value copy across layouts");
        debug_assert_eq!(self.diag.cols, other.diag.cols, "diag pattern drift in value copy");
        debug_assert_eq!(self.offd.cols, other.offd.cols, "offd pattern drift in value copy");
        debug_assert_eq!(self.garray, other.garray, "garray drift in value copy");
        self.diag.vals.copy_from_slice(&other.diag.vals);
        self.offd.vals.copy_from_slice(&other.offd.vals);
    }

    /// Assemble the full global matrix on every rank (collective, tests
    /// and coarse direct solves only).  Every rank returns the identical
    /// sequential [`Csr`].
    pub fn gather_global(&self, comm: &Comm) -> Csr {
        assert!(self.global_ncols() < u32::MAX as usize, "global cols exceed u32");
        let mut w = ByteWriter::new();
        let mut cols: Vec<u64> = Vec::new();
        let mut vals: Vec<f64> = Vec::new();
        for i in 0..self.local_nrows() {
            self.row_global(i, &mut cols, &mut vals);
            w.u32(cols.len() as u32);
            w.u64_slice(&cols);
            w.f64_slice(&vals);
        }
        let all = comm.allgather_bytes(w.into_bytes());
        let mut b = CsrBuilder::with_capacity(
            self.global_ncols(),
            self.global_nrows(),
            self.nnz_local() * comm.size(),
        );
        let mut cols32: Vec<u32> = Vec::new();
        let mut v: Vec<f64> = Vec::new();
        for (r, payload) in all.iter().enumerate() {
            let mut reader = ByteReader::new(payload);
            for _ in 0..self.row_layout.local_size(r) {
                let n = reader.u32() as usize;
                cols32.clear();
                v.clear();
                for _ in 0..n {
                    cols32.push(reader.u64() as u32);
                }
                for _ in 0..n {
                    v.push(reader.f64());
                }
                b.push_row(&cols32, &v);
            }
            debug_assert!(reader.done(), "trailing bytes from rank {r}");
        }
        b.finish()
    }

    /// Check the distributed invariants (local CSRs valid, garray sorted,
    /// strictly off-rank, in range; shapes consistent with the layouts).
    pub fn validate(&self) -> Result<(), String> {
        self.diag.validate().map_err(|e| format!("diag: {e}"))?;
        self.offd.validate().map_err(|e| format!("offd: {e}"))?;
        let local_rows = self.row_layout.local_size(self.rank);
        if self.diag.nrows != local_rows || self.offd.nrows != local_rows {
            return Err(format!(
                "row count mismatch: diag {} offd {} layout {local_rows}",
                self.diag.nrows, self.offd.nrows
            ));
        }
        if self.diag.ncols != self.col_layout.local_size(self.rank) {
            return Err("diag ncols != owned column count".into());
        }
        if self.offd.ncols != self.garray.len() {
            return Err("offd ncols != garray length".into());
        }
        let cbeg = self.col_begin() as u64;
        let cend = self.col_layout.end(self.rank) as u64;
        let ncols = self.global_ncols() as u64;
        for w in self.garray.windows(2) {
            if w[0] >= w[1] {
                return Err("garray not strictly sorted".into());
            }
        }
        for &g in &self.garray {
            if g >= ncols {
                return Err(format!("garray entry {g} out of range"));
            }
            if g >= cbeg && g < cend {
                return Err(format!("garray entry {g} is locally owned"));
            }
        }
        Ok(())
    }
}

/// Row-by-row builder taking (global column, value) entries; splits into
/// diag/offd and compacts `garray` on [`DistCsrBuilder::finish`].
#[derive(Debug)]
pub struct DistCsrBuilder {
    rank: usize,
    row_layout: Layout,
    col_layout: Layout,
    rowptr: Vec<usize>,
    cols: Vec<u64>,
    vals: Vec<f64>,
}

impl DistCsrBuilder {
    pub fn new(rank: usize, row_layout: Layout, col_layout: Layout) -> DistCsrBuilder {
        DistCsrBuilder {
            rank,
            row_layout,
            col_layout,
            rowptr: vec![0],
            cols: Vec::new(),
            vals: Vec::new(),
        }
    }

    /// Append the next local row; `entries` are (global col, value) sorted
    /// by strictly ascending column.
    pub fn push_row(&mut self, entries: &[(u64, f64)]) {
        debug_assert!(
            entries.windows(2).all(|w| w[0].0 < w[1].0),
            "row entries must have strictly ascending columns"
        );
        for &(c, v) in entries {
            debug_assert!((c as usize) < self.col_layout.global_size(), "column {c} out of range");
            self.cols.push(c);
            self.vals.push(v);
        }
        self.rowptr.push(self.cols.len());
    }

    pub fn nrows(&self) -> usize {
        self.rowptr.len() - 1
    }

    pub fn finish(self) -> DistCsr {
        let nrows = self.rowptr.len() - 1;
        debug_assert_eq!(
            nrows,
            self.row_layout.local_size(self.rank),
            "pushed rows must match the layout's local count"
        );
        let cbeg = self.col_layout.start(self.rank) as u64;
        let cend = self.col_layout.end(self.rank) as u64;
        let mut garray: Vec<u64> = self
            .cols
            .iter()
            .copied()
            .filter(|&c| c < cbeg || c >= cend)
            .collect();
        garray.sort_unstable();
        garray.dedup();
        let nloc_cols = self.col_layout.local_size(self.rank);
        let offd_nnz = self
            .cols
            .iter()
            .filter(|&&c| c < cbeg || c >= cend)
            .count();
        let mut diag = CsrBuilder::with_capacity(nloc_cols, nrows, self.cols.len() - offd_nnz);
        let mut offd = CsrBuilder::with_capacity(garray.len(), nrows, offd_nnz);
        let mut dc: Vec<u32> = Vec::new();
        let mut dv: Vec<f64> = Vec::new();
        let mut oc: Vec<u32> = Vec::new();
        let mut ov: Vec<f64> = Vec::new();
        for i in 0..nrows {
            dc.clear();
            dv.clear();
            oc.clear();
            ov.clear();
            for k in self.rowptr[i]..self.rowptr[i + 1] {
                let (c, v) = (self.cols[k], self.vals[k]);
                if c >= cbeg && c < cend {
                    dc.push((c - cbeg) as u32);
                    dv.push(v);
                } else {
                    oc.push(garray.binary_search(&c).unwrap() as u32);
                    ov.push(v);
                }
            }
            diag.push_row(&dc, &dv);
            offd.push_row(&oc, &ov);
        }
        DistCsr {
            rank: self.rank,
            row_layout: self.row_layout,
            col_layout: self.col_layout,
            diag: diag.finish(),
            offd: offd.finish(),
            garray,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::World;

    /// rank-local helper: a 4x6 matrix over 2 ranks.
    fn sample(rank: usize) -> DistCsr {
        let rl = Layout::new_equal(4, 2);
        let cl = Layout::new_equal(6, 2);
        let mut b = DistCsrBuilder::new(rank, rl.clone(), cl);
        for gi in rl.range(rank) {
            // row gi: entries at (gi) and (gi + 3) mod 6, value = col + 10*gi
            let mut e = vec![
                (gi as u64, (gi * 10 + gi) as f64),
                (((gi + 3) % 6) as u64, ((gi + 3) % 6 + 10 * gi) as f64),
            ];
            e.sort_unstable_by_key(|&(c, _)| c);
            b.push_row(&e);
        }
        b.finish()
    }

    #[test]
    fn split_and_garray() {
        let d = sample(0);
        d.validate().unwrap();
        // rank 0 owns cols 0..3; rows 0,1 hit cols {0,3} and {1,4}
        assert_eq!(d.garray, vec![3, 4]);
        assert_eq!(d.diag.nnz(), 2);
        assert_eq!(d.offd.nnz(), 2);
        let d1 = sample(1);
        d1.validate().unwrap();
        // rank 1 owns cols 3..6; rows 2,3 hit cols {2,5} and {0,3}
        assert_eq!(d1.garray, vec![0, 2]);
    }

    #[test]
    fn row_global_is_sorted_merge() {
        let d = sample(1);
        let (mut c, mut v) = (Vec::new(), Vec::new());
        // local row 0 == global row 2: cols {2, 5}
        d.row_global(0, &mut c, &mut v);
        assert_eq!(c, vec![2, 5]);
        // local row 1 == global row 3: cols {0, 3}
        d.row_global(1, &mut c, &mut v);
        assert_eq!(c, vec![0, 3]);
        assert_eq!(v, vec![30.0, 33.0]);
    }

    #[test]
    fn gather_global_identical_on_all_ranks() {
        let w = World::new(2);
        let gs = w.run(|comm| sample(comm.rank()).gather_global(&comm));
        assert_eq!(gs[0], gs[1]);
        let g = &gs[0];
        g.validate().unwrap();
        assert_eq!(g.nrows, 4);
        assert_eq!(g.ncols, 6);
        assert_eq!(g.nnz(), 8);
        assert_eq!(g.row_cols(3), &[0, 3]);
    }

    #[test]
    fn validate_rejects_owned_garray_entry() {
        let mut d = sample(0);
        d.garray[0] = 1; // owned by rank 0
        assert!(d.validate().is_err());
    }

    #[test]
    fn empty_rows_and_ranks() {
        let rl = Layout::new_equal(3, 4); // rank 3 owns nothing
        let cl = Layout::new_equal(3, 4);
        let w = World::new(4);
        w.run(|comm| {
            let mut b = DistCsrBuilder::new(comm.rank(), rl.clone(), cl.clone());
            for _ in rl.range(comm.rank()) {
                b.push_row(&[]);
            }
            let d = b.finish();
            d.validate().unwrap();
            let g = d.gather_global(&comm);
            assert_eq!(g.nnz(), 0);
            assert_eq!(g.nrows, 3);
        });
    }

    #[test]
    fn nnz_and_row_stats() {
        let w = World::new(2);
        w.run(|comm| {
            let d = sample(comm.rank());
            assert_eq!(d.nnz_global(&comm), 8);
            let (mn, mx, avg) = d.row_nnz_stats(&comm);
            assert_eq!((mn, mx), (2, 2));
            assert!((avg - 2.0).abs() < 1e-12);
        });
    }
}
