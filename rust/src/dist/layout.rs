//! Contiguous row-block ownership (PETSc `PetscLayout` analog): rank `r`
//! owns the half-open global index range `[start(r), end(r))`.  Both row
//! and column spaces of every distributed matrix carry one of these; the
//! diag/offd split and every owner lookup in the gather plans derive from
//! it.

/// Contiguous partition of `0..global_size()` over `np` ranks.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Layout {
    /// `np + 1` cumulative boundaries; rank `r` owns `starts[r]..starts[r+1]`.
    starts: Vec<usize>,
}

impl Layout {
    /// PETSc-style near-equal split: the first `n % np` ranks own one
    /// extra index.
    pub fn new_equal(n: usize, np: usize) -> Layout {
        assert!(np >= 1, "need at least one rank");
        let base = n / np;
        let rem = n % np;
        let mut starts = Vec::with_capacity(np + 1);
        let mut s = 0usize;
        starts.push(0);
        for r in 0..np {
            s += base + usize::from(r < rem);
            starts.push(s);
        }
        Layout { starts }
    }

    /// Build from explicit per-rank counts (aggregation coarse layouts).
    pub fn from_counts(counts: &[usize]) -> Layout {
        assert!(!counts.is_empty(), "need at least one rank");
        let mut starts = Vec::with_capacity(counts.len() + 1);
        let mut s = 0usize;
        starts.push(0);
        for &c in counts {
            s += c;
            starts.push(s);
        }
        Layout { starts }
    }

    /// Number of ranks this layout partitions over.
    pub fn np(&self) -> usize {
        self.starts.len() - 1
    }

    /// Total number of global indices.
    pub fn global_size(&self) -> usize {
        *self.starts.last().unwrap()
    }

    /// First global index owned by `rank`.
    pub fn start(&self, rank: usize) -> usize {
        self.starts[rank]
    }

    /// One past the last global index owned by `rank`.
    pub fn end(&self, rank: usize) -> usize {
        self.starts[rank + 1]
    }

    /// Number of indices owned by `rank`.
    pub fn local_size(&self, rank: usize) -> usize {
        self.starts[rank + 1] - self.starts[rank]
    }

    /// The global index range owned by `rank` (iterable).
    pub fn range(&self, rank: usize) -> std::ops::Range<usize> {
        self.starts[rank]..self.starts[rank + 1]
    }

    /// The rank owning global index `g`.
    pub fn owner(&self, g: usize) -> usize {
        debug_assert!(g < self.global_size(), "index {g} out of layout");
        // starts is sorted; the owner is the last boundary <= g.
        self.starts.partition_point(|&s| s <= g) - 1
    }

    /// The same partition with every boundary scaled by `b` (block layout
    /// -> scalar layout of a block matrix).
    pub fn scaled(&self, b: usize) -> Layout {
        Layout { starts: self.starts.iter().map(|&s| s * b).collect() }
    }

    /// Heap bytes (for memory accounting).
    pub fn bytes(&self) -> u64 {
        (self.starts.len() * std::mem::size_of::<usize>()) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equal_split_covers_all_indices() {
        let l = Layout::new_equal(10, 3);
        assert_eq!(l.global_size(), 10);
        assert_eq!(l.local_size(0), 4); // 10 % 3 = 1 extra on rank 0
        assert_eq!(l.local_size(1), 3);
        assert_eq!(l.local_size(2), 3);
        assert_eq!(l.range(1), 4..7);
        let total: usize = (0..3).map(|r| l.local_size(r)).sum();
        assert_eq!(total, 10);
    }

    #[test]
    fn owner_matches_ranges() {
        let l = Layout::new_equal(11, 4);
        for r in 0..4 {
            for g in l.range(r) {
                assert_eq!(l.owner(g), r, "index {g}");
            }
        }
    }

    #[test]
    fn from_counts_allows_empty_ranks() {
        let l = Layout::from_counts(&[3, 0, 2]);
        assert_eq!(l.global_size(), 5);
        assert_eq!(l.local_size(1), 0);
        assert_eq!(l.owner(3), 2);
        assert_eq!(l.range(1), 3..3);
    }

    #[test]
    fn more_ranks_than_rows() {
        let l = Layout::new_equal(2, 5);
        assert_eq!(l.local_size(0), 1);
        assert_eq!(l.local_size(1), 1);
        for r in 2..5 {
            assert_eq!(l.local_size(r), 0);
        }
        assert_eq!(l.owner(1), 1);
    }

    #[test]
    fn scaled_multiplies_boundaries() {
        let l = Layout::new_equal(5, 2);
        let s = l.scaled(3);
        assert_eq!(s.global_size(), 15);
        assert_eq!(s.start(1), l.start(1) * 3);
        assert_eq!(s.local_size(0), l.local_size(0) * 3);
    }
}
