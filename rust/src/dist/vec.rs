//! Distributed vectors and the halo-exchange SpMV built on them.

use std::cell::{Cell, Ref, RefCell};

use super::csr::DistCsr;
use super::gather::VecGatherPlan;
use super::layout::Layout;
use super::world::Comm;

/// One rank's contiguous slice of a global vector.
#[derive(Debug, Clone)]
pub struct DistVec {
    pub layout: Layout,
    pub rank: usize,
    /// Local entries; `vals[i]` is global entry `layout.start(rank) + i`.
    pub vals: Vec<f64>,
}

impl DistVec {
    pub fn zeros(layout: Layout, rank: usize) -> DistVec {
        let n = layout.local_size(rank);
        DistVec { layout, rank, vals: vec![0.0; n] }
    }

    /// Build from a function of the *global* index — every rank computes
    /// its slice of the same global vector, independent of the rank count.
    pub fn from_fn(layout: Layout, rank: usize, f: impl Fn(usize) -> f64) -> DistVec {
        let vals = layout.range(rank).map(f).collect();
        DistVec { layout, rank, vals }
    }

    pub fn local_len(&self) -> usize {
        self.vals.len()
    }

    pub fn global_len(&self) -> usize {
        self.layout.global_size()
    }

    pub fn bytes(&self) -> u64 {
        (self.vals.len() * 8) as u64
    }

    pub fn fill(&mut self, v: f64) {
        self.vals.fill(v);
    }

    pub fn scale(&mut self, s: f64) {
        for v in &mut self.vals {
            *v *= s;
        }
    }

    /// `self += alpha * x`.
    pub fn axpy(&mut self, alpha: f64, x: &DistVec) {
        debug_assert_eq!(self.vals.len(), x.vals.len());
        for (a, &b) in self.vals.iter_mut().zip(&x.vals) {
            *a += alpha * b;
        }
    }

    /// `self = beta * self + x`.
    pub fn aypx(&mut self, beta: f64, x: &DistVec) {
        debug_assert_eq!(self.vals.len(), x.vals.len());
        for (a, &b) in self.vals.iter_mut().zip(&x.vals) {
            *a = beta * *a + b;
        }
    }

    /// Global dot product (collective; bit-identical on every rank).
    pub fn dot(&self, comm: &Comm, other: &DistVec) -> f64 {
        debug_assert_eq!(self.vals.len(), other.vals.len());
        let local: f64 = self.vals.iter().zip(&other.vals).map(|(&a, &b)| a * b).sum();
        comm.allreduce_sum_f64(local)
    }

    /// Global 2-norm (collective).
    pub fn norm2(&self, comm: &Comm) -> f64 {
        self.dot(comm, self).sqrt()
    }
}

/// One rank's contiguous slice of K global vectors, stored row-major:
/// `vals[i*k + j]` is column `j` of local row `i`.  The K-wide layout is
/// what the blocked halo exchange ships per index, so K simultaneous
/// right-hand sides share every per-message α across the solve.
///
/// Every column-wise operation folds rows in the exact order the scalar
/// [`DistVec`] path does, so column `j` of any blocked kernel is
/// *bitwise* the scalar result.
#[derive(Debug, Clone)]
pub struct DistMultiVec {
    pub layout: Layout,
    pub rank: usize,
    /// Number of columns (simultaneous right-hand sides).
    pub k: usize,
    /// Row-major local entries, `local_len() * k` long.
    pub vals: Vec<f64>,
}

impl DistMultiVec {
    pub fn zeros(layout: Layout, rank: usize, k: usize) -> DistMultiVec {
        assert!(k >= 1, "multivector needs at least one column");
        let n = layout.local_size(rank);
        DistMultiVec { layout, rank, k, vals: vec![0.0; n * k] }
    }

    /// Stack K single vectors (identical layouts) into one multivector.
    ///
    /// Hard-asserts (release builds included) that every column shares
    /// the first column's layout: a mismatched column would silently
    /// corrupt the interleaved block, and panicking *here* — before any
    /// communication — lets the session layer catch the unwind without
    /// desynchronizing the SPMD collective schedule.
    pub fn from_columns(cols: &[&DistVec]) -> DistMultiVec {
        assert!(!cols.is_empty(), "multivector needs at least one column");
        let k = cols.len();
        let layout = cols[0].layout.clone();
        let rank = cols[0].rank;
        let n = cols[0].vals.len();
        let mut vals = vec![0.0; n * k];
        for (j, c) in cols.iter().enumerate() {
            assert_eq!(c.vals.len(), n, "column {j} does not share the batch layout");
            assert!(
                c.layout == layout,
                "column {j} does not share the batch layout"
            );
            for i in 0..n {
                vals[i * k + j] = c.vals[i];
            }
        }
        DistMultiVec { layout, rank, k, vals }
    }

    /// Extract column `j` as a standalone vector.
    pub fn column(&self, j: usize) -> DistVec {
        debug_assert!(j < self.k);
        let n = self.local_len();
        let vals = (0..n).map(|i| self.vals[i * self.k + j]).collect();
        DistVec { layout: self.layout.clone(), rank: self.rank, vals }
    }

    /// Overwrite column `j` from a single vector (same layout).
    pub fn set_column(&mut self, j: usize, x: &DistVec) {
        debug_assert!(j < self.k);
        debug_assert_eq!(x.vals.len(), self.local_len());
        for (i, &v) in x.vals.iter().enumerate() {
            self.vals[i * self.k + j] = v;
        }
    }

    pub fn local_len(&self) -> usize {
        self.layout.local_size(self.rank)
    }

    pub fn bytes(&self) -> u64 {
        (self.vals.len() * 8) as u64
    }

    pub fn fill(&mut self, v: f64) {
        self.vals.fill(v);
    }

    /// `self[:, j] += alpha[j] * x[:, j]` for every column with
    /// `active[j]` — frozen (converged) columns keep their bits.
    pub fn axpy_cols(&mut self, alpha: &[f64], x: &DistMultiVec, active: &[bool]) {
        let k = self.k;
        debug_assert_eq!(alpha.len(), k);
        debug_assert_eq!(active.len(), k);
        debug_assert_eq!(self.vals.len(), x.vals.len());
        for i in 0..self.local_len() {
            for j in 0..k {
                if active[j] {
                    self.vals[i * k + j] += alpha[j] * x.vals[i * k + j];
                }
            }
        }
    }

    /// `self[:, j] = beta[j] * self[:, j] + x[:, j]` for active columns.
    pub fn aypx_cols(&mut self, beta: &[f64], x: &DistMultiVec, active: &[bool]) {
        let k = self.k;
        debug_assert_eq!(beta.len(), k);
        debug_assert_eq!(active.len(), k);
        debug_assert_eq!(self.vals.len(), x.vals.len());
        for i in 0..self.local_len() {
            for j in 0..k {
                if active[j] {
                    let s = &mut self.vals[i * k + j];
                    *s = beta[j] * *s + x.vals[i * k + j];
                }
            }
        }
    }

    /// Per-column global dot products in **one** allreduce (collective).
    /// Each column's local sum folds rows in the scalar [`DistVec::dot`]
    /// order and the reduction combines in rank order, so element `j` is
    /// bit-identical to `self.column(j).dot(comm, &other.column(j))`.
    pub fn dot_multi(&self, comm: &Comm, other: &DistMultiVec) -> Vec<f64> {
        let k = self.k;
        debug_assert_eq!(other.k, k);
        debug_assert_eq!(self.vals.len(), other.vals.len());
        let mut local = vec![0.0f64; k];
        for i in 0..self.local_len() {
            for (j, acc) in local.iter_mut().enumerate() {
                *acc += self.vals[i * k + j] * other.vals[i * k + j];
            }
        }
        comm.allreduce_sum_f64_multi(&local)
    }

    /// Per-column global 2-norms in one allreduce (collective).
    pub fn norm2_multi(&self, comm: &Comm) -> Vec<f64> {
        self.dot_multi(comm, self).into_iter().map(f64::sqrt).collect()
    }
}

/// Halo-exchange sparse matrix-vector product: the plan for `A.garray` is
/// built once and reused every application (PETSc `MatMult` scatter).
#[derive(Debug)]
pub struct DistSpmv {
    halo: VecGatherPlan,
    /// Per-local-row offd split ([`DistCsr::offd_split`]) — pattern-static,
    /// precomputed so the global-column-order fold costs no search per
    /// application.
    splits: Vec<u32>,
    /// Persistent halo buffer: sized on first gather, reused (no
    /// allocation) on every later application.
    buf: RefCell<Vec<f64>>,
    /// Persistent K-wide halo buffer for blocked applications.
    buf_multi: RefCell<Vec<f64>>,
    /// How many gathers hit the warm buffer instead of allocating.
    reuses: Cell<u64>,
}

impl DistSpmv {
    /// Collective: build the halo plan for `a`'s off-diagonal columns.
    pub fn new(comm: &Comm, a: &DistCsr) -> DistSpmv {
        DistSpmv {
            halo: VecGatherPlan::build(comm, &a.col_layout, &a.garray),
            splits: (0..a.local_nrows()).map(|i| a.offd_split(i) as u32).collect(),
            buf: RefCell::new(Vec::new()),
            buf_multi: RefCell::new(Vec::new()),
            reuses: Cell::new(0),
        }
    }

    /// Fetch the halo entries of `x` named by `a.garray` (collective).
    /// The returned borrow views the persistent buffer — drop it before
    /// the next gather.
    pub fn gather_halo(&self, comm: &Comm, x: &DistVec) -> Ref<'_, [f64]> {
        {
            let mut buf = self.buf.borrow_mut();
            if buf.capacity() >= self.halo.n_needed() && self.halo.n_needed() > 0 {
                self.reuses.set(self.reuses.get() + 1);
                crate::obs::metrics::add(crate::obs::Subsys::Comm, "halo.reuse", 1);
            }
            self.halo.gather_into(comm, &x.vals, &mut buf);
        }
        Ref::map(self.buf.borrow(), |v| v.as_slice())
    }

    /// Halo gathers that reused the warm persistent buffer (saved
    /// allocations since construction).
    pub fn halo_reuses(&self) -> u64 {
        self.reuses.get()
    }

    /// Blocked halo fetch: the K-wide halo of `x` in one epoch
    /// (collective; warm persistent K-wide buffer).  Slot `c` of the
    /// scalar halo becomes `halo[c*k..(c+1)*k]`.
    pub fn gather_halo_multi(&self, comm: &Comm, x: &DistMultiVec) -> Ref<'_, [f64]> {
        let k = x.k;
        {
            let mut buf = self.buf_multi.borrow_mut();
            if buf.capacity() >= self.halo.n_needed() * k && self.halo.n_needed() > 0 {
                self.reuses.set(self.reuses.get() + 1);
                crate::obs::metrics::add(crate::obs::Subsys::Comm, "halo.reuse", 1);
            }
            self.halo.gather_multi_into(comm, &x.vals, k, &mut buf);
        }
        Ref::map(self.buf_multi.borrow(), |v| v.as_slice())
    }

    /// `y = A x` (collective).  Each row folds in ascending *global*
    /// column order (offd below the diag range, diag, offd above —
    /// `garray` ascends with the compacted ids), so the accumulation
    /// bits are independent of how the rows are partitioned: a
    /// telescoped level and the full-communicator level produce
    /// bit-identical products.
    pub fn apply(&self, comm: &Comm, a: &DistCsr, x: &DistVec, y: &mut DistVec) {
        debug_assert_eq!(x.vals.len(), a.diag.ncols);
        debug_assert_eq!(y.vals.len(), a.local_nrows());
        let halo = self.gather_halo(comm, x);
        debug_assert_eq!(self.splits.len(), a.local_nrows());
        for i in 0..a.local_nrows() {
            let mut acc = 0.0;
            let (dc, dv) = a.diag.row(i);
            let (oc, ov) = a.offd.row(i);
            let split = self.splits[i] as usize;
            for k in 0..split {
                acc += ov[k] * halo[oc[k] as usize];
            }
            for (&c, &v) in dc.iter().zip(dv) {
                acc += v * x.vals[c as usize];
            }
            for k in split..oc.len() {
                acc += ov[k] * halo[oc[k] as usize];
            }
            y.vals[i] = acc;
        }
    }

    /// `Y = A X` for a K-wide multivector (collective): **one** blocked
    /// halo epoch serves all K columns, and each column folds rows in the
    /// exact ascending-global-column order of [`DistSpmv::apply`], so
    /// column `j` of `Y` is bitwise the scalar product of column `j`.
    pub fn apply_multi(&self, comm: &Comm, a: &DistCsr, x: &DistMultiVec, y: &mut DistMultiVec) {
        let k = x.k;
        debug_assert_eq!(y.k, k);
        debug_assert_eq!(x.vals.len(), a.diag.ncols * k);
        debug_assert_eq!(y.vals.len(), a.local_nrows() * k);
        let halo = self.gather_halo_multi(comm, x);
        debug_assert_eq!(self.splits.len(), a.local_nrows());
        for i in 0..a.local_nrows() {
            let (dc, dv) = a.diag.row(i);
            let (oc, ov) = a.offd.row(i);
            let split = self.splits[i] as usize;
            let yi = &mut y.vals[i * k..(i + 1) * k];
            yi.fill(0.0);
            for t in 0..split {
                let c = oc[t] as usize;
                for (j, acc) in yi.iter_mut().enumerate() {
                    *acc += ov[t] * halo[c * k + j];
                }
            }
            for (&c, &v) in dc.iter().zip(dv) {
                let c = c as usize;
                for (j, acc) in yi.iter_mut().enumerate() {
                    *acc += v * x.vals[c * k + j];
                }
            }
            for t in split..oc.len() {
                let c = oc[t] as usize;
                for (j, acc) in yi.iter_mut().enumerate() {
                    *acc += ov[t] * halo[c * k + j];
                }
            }
        }
    }

    pub fn bytes(&self) -> u64 {
        self.halo.bytes()
            + (self.splits.len() * 4) as u64
            + ((self.buf.borrow().capacity() + self.buf_multi.borrow().capacity()) * 8) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::World;
    use crate::gen::{grid_laplacian, Grid3};

    #[test]
    fn spmv_matches_sequential() {
        for np in [1, 2, 3] {
            let w = World::new(np);
            let pieces = w.run(|comm| {
                let a = grid_laplacian(Grid3::cube(4), comm.rank(), comm.size());
                let spmv = DistSpmv::new(&comm, &a);
                let x = DistVec::from_fn(a.row_layout.clone(), comm.rank(), |g| {
                    (g as f64 * 0.3).sin()
                });
                let mut y = DistVec::zeros(a.row_layout.clone(), comm.rank());
                spmv.apply(&comm, &a, &x, &mut y);
                (a.row_begin(), y.vals, a.gather_global(&comm))
            });
            let g = &pieces[0].2;
            let xf: Vec<f64> = (0..g.ncols).map(|i| (i as f64 * 0.3).sin()).collect();
            let mut want = vec![0.0; g.nrows];
            g.spmv(&xf, &mut want);
            for (start, vals, _) in &pieces {
                for (k, &v) in vals.iter().enumerate() {
                    assert!((v - want[start + k]).abs() < 1e-12, "np={np} row {}", start + k);
                }
            }
        }
    }

    #[test]
    fn dot_and_norm_are_rank_invariant() {
        let run = |np: usize| -> (f64, f64) {
            let w = World::new(np);
            w.run(|comm| {
                let l = Layout::new_equal(37, comm.size());
                let x = DistVec::from_fn(l.clone(), comm.rank(), |g| g as f64 - 18.0);
                let y = DistVec::from_fn(l, comm.rank(), |g| 1.0 / (1.0 + g as f64));
                (x.dot(&comm, &y), x.norm2(&comm))
            })
            .remove(0)
        };
        let (d1, n1) = run(1);
        for np in [2, 4] {
            let (d, n) = run(np);
            assert!((d - d1).abs() < 1e-9, "np={np}");
            assert!((n - n1).abs() < 1e-9, "np={np}");
        }
    }

    #[test]
    fn blas1_ops() {
        let l = Layout::new_equal(5, 1);
        let mut x = DistVec::from_fn(l.clone(), 0, |g| g as f64);
        let y = DistVec::from_fn(l, 0, |_| 2.0);
        x.axpy(0.5, &y); // x = g + 1
        assert_eq!(x.vals, vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        x.aypx(2.0, &y); // x = 2x + 2
        assert_eq!(x.vals, vec![4.0, 6.0, 8.0, 10.0, 12.0]);
        x.scale(0.5);
        assert_eq!(x.vals[0], 2.0);
        x.fill(0.0);
        assert!(x.vals.iter().all(|&v| v == 0.0));
    }
}
