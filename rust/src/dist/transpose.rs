//! Explicit distributed transpose (PETSc `MatTranspose` analog) — the
//! general-`R` path of [`crate::ptap::rap`] and the baseline's `Pᵀ` when a
//! whole-matrix transpose is wanted rather than the local-block transposes
//! the two-step product keeps.

use crate::util::bytebuf::{ByteReader, ByteWriter};

use super::csr::{DistCsr, DistCsrBuilder};
use super::world::Comm;

/// Compute `Aᵀ`, distributed over `A.col_layout × A.row_layout`
/// (collective).  Every local entry `(i, j)` is shipped to the owner of
/// global row `j` in the transpose; receivers sort and assemble.
pub fn transpose_dist(comm: &Comm, a: &DistCsr) -> DistCsr {
    let np = comm.size();
    let rbeg = a.row_begin() as u64;
    let cbeg = a.col_begin() as u64;
    // bucket (t_row = a_col, t_col = a_row, v) triples by owner of t_row
    let mut writers: Vec<Option<ByteWriter>> = (0..np).map(|_| None).collect();
    let mut push = |owner: usize, trow: u64, tcol: u64, v: f64| {
        let w = writers[owner].get_or_insert_with(ByteWriter::new);
        w.u64(trow);
        w.u64(tcol);
        w.f64(v);
    };
    for i in 0..a.local_nrows() {
        let gi = rbeg + i as u64;
        let (dc, dv) = a.diag.row(i);
        for (&c, &v) in dc.iter().zip(dv) {
            let gc = cbeg + c as u64;
            push(a.col_layout.owner(gc as usize), gc, gi, v);
        }
        let (oc, ov) = a.offd.row(i);
        for (&c, &v) in oc.iter().zip(ov) {
            let gc = a.garray[c as usize];
            push(a.col_layout.owner(gc as usize), gc, gi, v);
        }
    }
    let sends: Vec<(usize, Vec<u8>)> = writers
        .into_iter()
        .enumerate()
        .filter_map(|(d, w)| w.map(|w| (d, w.into_bytes())))
        .collect();
    let recvd = comm.exchange(sends);

    let mut triples: Vec<(u64, u64, f64)> = Vec::new();
    for (_src, payload) in &recvd {
        let mut r = ByteReader::new(payload);
        while !r.done() {
            let trow = r.u64();
            let tcol = r.u64();
            let v = r.f64();
            triples.push((trow, tcol, v));
        }
    }
    // entries of A are unique, so (trow, tcol) keys are unique
    triples.sort_unstable_by_key(|&(r, c, _)| (r, c));

    let row_layout = a.col_layout.clone();
    let col_layout = a.row_layout.clone();
    let mut b = DistCsrBuilder::new(comm.rank(), row_layout.clone(), col_layout);
    let mut entries: Vec<(u64, f64)> = Vec::new();
    let mut k = 0usize;
    for gr in row_layout.range(comm.rank()) {
        entries.clear();
        while k < triples.len() && triples[k].0 == gr as u64 {
            entries.push((triples[k].1, triples[k].2));
            k += 1;
        }
        b.push_row(&entries);
    }
    debug_assert_eq!(k, triples.len(), "received transpose entries for unowned rows");
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::World;
    use crate::gen::random_dist_csr;

    #[test]
    fn matches_sequential_transpose() {
        for np in [1, 2, 4] {
            let w = World::new(np);
            w.run(|comm| {
                let a = random_dist_csr(comm.rank(), comm.size(), 17, 9, 3, 123);
                let t = transpose_dist(&comm, &a);
                t.validate().unwrap();
                assert_eq!(t.global_nrows(), 9);
                assert_eq!(t.global_ncols(), 17);
                let gt = t.gather_global(&comm);
                let ga = a.gather_global(&comm);
                assert_eq!(gt, ga.transpose(), "np={np}");
            });
        }
    }

    #[test]
    fn empty_matrix_transposes_to_empty() {
        let w = World::new(2);
        w.run(|comm| {
            use crate::dist::{DistCsrBuilder, Layout};
            let rl = Layout::new_equal(6, comm.size());
            let cl = Layout::new_equal(4, comm.size());
            let mut b = DistCsrBuilder::new(comm.rank(), rl.clone(), cl);
            for _ in rl.range(comm.rank()) {
                b.push_row(&[]);
            }
            let a = b.finish();
            let t = transpose_dist(&comm, &a);
            t.validate().unwrap();
            assert_eq!(t.nnz_global(&comm), 0);
        });
    }
}
