//! One-shot row/value gathers (PETSc `VecScatter` / `MatGetSubMatrix`
//! analogs).  A plan is built once per operator from the sorted list of
//! needed global ids (always a `garray`): owners are looked up, requests
//! exchanged, and both sides remember their half of the pattern.  After
//! that, gathering is a single sparse exchange — the paper's "one-shot
//! communication to get the remote rows of P" (Alg. 2/7/9 line 2), and its
//! numeric refresh (Alg. 4 line 3) reuses the same plan.

use crate::util::bytebuf::{ByteReader, ByteWriter};
use crate::util::timer::thread_cpu_time;

use super::bcsr::DistBcsr;
use super::csr::DistCsr;
use super::layout::Layout;
use super::world::{pipeline_chunk_rows, tag, Comm};

/// Measured traffic and overlap window of one pipelined gather refresh
/// ([`RowGatherPlan::update_values_csr`]): the serve payloads are posted
/// in `GPTAP_PIPELINE_CHUNK`-row chunks as they serialize, so the early
/// chunks are in flight while the later rows are still being packed.
/// `overlap` is the busy seconds between the first posted chunk and the
/// epoch close — creditable against the α-β model exactly like the
/// triple products' scatter windows.
#[derive(Debug, Default, Clone, Copy)]
pub struct GatherWindow {
    pub msgs: u64,
    pub bytes: u64,
    pub overlap: f64,
}

/// Plan traffic rides the nonblocking engine on its own tag: one bulk
/// epoch per gather.  Delivery order (source rank, then send order) is
/// identical to the old collective, so `zip_runs` alignment is unchanged.
fn sendrecv(comm: &Comm, sends: Vec<(usize, Vec<u8>)>) -> Vec<(usize, Vec<u8>)> {
    comm.exchange_on(tag::GATHER, sends)
}

/// Owner/serve pattern shared by the row and vector gather plans.
#[derive(Debug)]
struct GatherMap {
    /// Number of gathered ids (positions `0..n_needed`).
    n_needed: usize,
    /// (owner rank, contiguous position range) runs, ascending by owner.
    runs: Vec<(usize, std::ops::Range<usize>)>,
    /// (destination rank, owned local indices to send), ascending by rank.
    serve: Vec<(usize, Vec<u32>)>,
}

impl GatherMap {
    /// Collective: route requests for `needed` (strictly ascending global
    /// ids) to their owners under `layout`.
    fn build(comm: &Comm, layout: &Layout, needed: &[u64]) -> GatherMap {
        debug_assert!(needed.windows(2).all(|w| w[0] < w[1]), "needed ids must be sorted");
        let mut runs = Vec::new();
        let mut sends = Vec::new();
        let mut k = 0usize;
        while k < needed.len() {
            let owner = layout.owner(needed[k] as usize);
            let owner_end = layout.end(owner) as u64;
            let mut e = k + 1;
            while e < needed.len() && needed[e] < owner_end {
                e += 1;
            }
            let mut w = ByteWriter::with_capacity(8 * (e - k));
            w.u64_slice(&needed[k..e]);
            sends.push((owner, w.into_bytes()));
            runs.push((owner, k..e));
            k = e;
        }
        let recvd = sendrecv(comm, sends);
        let my_start = layout.start(comm.rank()) as u64;
        let my_len = layout.local_size(comm.rank());
        let serve = recvd
            .into_iter()
            .map(|(src, payload)| {
                let mut r = ByteReader::new(&payload);
                let mut ids = Vec::with_capacity(payload.len() / 8);
                while !r.done() {
                    let g = r.u64();
                    debug_assert!(
                        g >= my_start && g < my_start + my_len as u64,
                        "request for unowned id {g}"
                    );
                    ids.push((g - my_start) as u32);
                }
                (src, ids)
            })
            .collect();
        GatherMap { n_needed: needed.len(), runs, serve }
    }

    fn bytes(&self) -> u64 {
        let serve: usize = self.serve.iter().map(|(_, v)| 16 + v.len() * 4).sum();
        (serve + self.runs.len() * 24 + 24) as u64
    }

    /// Pair each run with its received payload (both ascend by rank).
    fn zip_runs<'a>(
        &'a self,
        recvd: &'a [(usize, Vec<u8>)],
    ) -> impl Iterator<Item = (&'a (usize, std::ops::Range<usize>), &'a [u8])> {
        debug_assert_eq!(recvd.len(), self.runs.len());
        self.runs.iter().zip(recvd.iter()).map(|(run, (src, payload))| {
            debug_assert_eq!(*src, run.0, "response/run misalignment");
            (run, payload.as_slice())
        })
    }
}

/// Gathered remote rows of a scalar matrix, in the order of the driving
/// `garray`; columns are *global* ids.
#[derive(Debug, Clone)]
pub struct PrMat {
    /// 32-bit row pointers (PetscInt width, matching [`crate::mat::Csr`]).
    rowptr: Vec<u32>,
    cols: Vec<u64>,
    vals: Vec<f64>,
}

impl PrMat {
    pub fn nrows(&self) -> usize {
        self.rowptr.len() - 1
    }

    #[inline]
    pub fn row(&self, k: usize) -> (&[u64], &[f64]) {
        let (a, b) = (self.rowptr[k] as usize, self.rowptr[k + 1] as usize);
        (&self.cols[a..b], &self.vals[a..b])
    }

    #[inline]
    pub fn row_cols(&self, k: usize) -> &[u64] {
        &self.cols[self.rowptr[k] as usize..self.rowptr[k + 1] as usize]
    }

    pub fn nnz(&self) -> usize {
        self.cols.len()
    }

    pub fn bytes(&self) -> u64 {
        (self.rowptr.len() * 4 + (self.cols.len() + self.vals.len()) * 8) as u64
    }
}

/// Gathered remote block rows, in `garray` order; block columns are
/// *global* block ids.
#[derive(Debug, Clone)]
pub struct PrBlocks {
    pub b: usize,
    rowptr: Vec<u32>,
    pub gcols: Vec<u64>,
    vals: Vec<f64>,
}

impl PrBlocks {
    pub fn nrows(&self) -> usize {
        self.rowptr.len() - 1
    }

    /// Block index range of gathered row `k`.
    #[inline]
    pub fn row_range(&self, k: usize) -> std::ops::Range<usize> {
        self.rowptr[k] as usize..self.rowptr[k + 1] as usize
    }

    /// Global block columns of gathered row `k`.
    #[inline]
    pub fn row_cols(&self, k: usize) -> &[u64] {
        &self.gcols[self.rowptr[k] as usize..self.rowptr[k + 1] as usize]
    }

    /// Dense block at block index `idx`.
    #[inline]
    pub fn block(&self, idx: usize) -> &[f64] {
        let s = self.b * self.b;
        &self.vals[idx * s..(idx + 1) * s]
    }

    pub fn bytes(&self) -> u64 {
        (self.rowptr.len() * 4 + (self.gcols.len() + self.vals.len()) * 8) as u64
    }
}

/// Plan for gathering whole remote *rows* of a distributed matrix.
#[derive(Debug)]
pub struct RowGatherPlan {
    map: GatherMap,
}

impl RowGatherPlan {
    /// Collective: plan the gather of the rows named by `needed` (sorted
    /// global ids — a `garray`) under the target matrix's `rows` layout.
    pub fn build(comm: &Comm, rows: &Layout, needed: &[u64]) -> RowGatherPlan {
        RowGatherPlan { map: GatherMap::build(comm, rows, needed) }
    }

    pub fn n_rows(&self) -> usize {
        self.map.n_needed
    }

    pub fn bytes(&self) -> u64 {
        self.map.bytes()
    }

    /// Collective: gather pattern + values of the planned rows of `p`.
    pub fn gather_csr(&self, comm: &Comm, p: &DistCsr) -> PrMat {
        self.gather_csr_inner(comm, p, true)
    }

    /// Collective: gather the pattern only (symbolic phase); values are
    /// zero until [`RowGatherPlan::update_values_csr`] refreshes them.
    pub fn gather_pattern_csr(&self, comm: &Comm, p: &DistCsr) -> PrMat {
        self.gather_csr_inner(comm, p, false)
    }

    fn gather_csr_inner(&self, comm: &Comm, p: &DistCsr, with_values: bool) -> PrMat {
        let mut cbuf: Vec<u64> = Vec::new();
        let mut vbuf: Vec<f64> = Vec::new();
        let mut sends = Vec::with_capacity(self.map.serve.len());
        for (dest, rows) in &self.map.serve {
            let mut w = ByteWriter::new();
            for &li in rows {
                p.row_global(li as usize, &mut cbuf, &mut vbuf);
                w.u32(cbuf.len() as u32);
                w.u64_slice(&cbuf);
                if with_values {
                    w.f64_slice(&vbuf);
                }
            }
            sends.push((*dest, w.into_bytes()));
        }
        let recvd = sendrecv(comm, sends);
        let mut rowptr: Vec<u32> = Vec::with_capacity(self.map.n_needed + 1);
        rowptr.push(0);
        let mut cols: Vec<u64> = Vec::new();
        let mut vals: Vec<f64> = Vec::new();
        for ((_, range), payload) in self.map.zip_runs(&recvd) {
            let mut r = ByteReader::new(payload);
            for _ in range.clone() {
                let n = r.u32() as usize;
                for _ in 0..n {
                    cols.push(r.u64());
                }
                if with_values {
                    for _ in 0..n {
                        vals.push(r.f64());
                    }
                }
                rowptr.push(cols.len() as u32);
            }
            debug_assert!(r.done());
        }
        debug_assert_eq!(rowptr.len(), self.map.n_needed + 1);
        if !with_values {
            vals = vec![0.0; cols.len()];
        }
        PrMat { rowptr, cols, vals }
    }

    /// Collective: refresh `pr`'s values from the current values of `p`
    /// without touching the pattern (Alg. 4 line 3 — the numeric-phase
    /// sparse communication).  Pipelined: each destination's payload is
    /// posted in `GPTAP_PIPELINE_CHUNK`-row chunks the moment a chunk is
    /// serialized, so serving overlaps the flight of earlier chunks;
    /// chunk boundaries never split a row and the engine's canonical
    /// release order makes the reassembled values byte-identical to the
    /// bulk path.
    pub fn update_values_csr(&self, comm: &Comm, p: &DistCsr, pr: &mut PrMat) -> GatherWindow {
        let chunk_rows = pipeline_chunk_rows();
        let mut win = GatherWindow::default();
        let mut first_post: Option<f64> = None;
        let mut cbuf: Vec<u64> = Vec::new();
        let mut vbuf: Vec<f64> = Vec::new();
        for (dest, rows) in &self.map.serve {
            let mut w = ByteWriter::new();
            let mut staged = 0usize;
            let post =
                |w: &mut ByteWriter, win: &mut GatherWindow, first: &mut Option<f64>| {
                    let payload = std::mem::take(w).into_bytes();
                    win.msgs += 1;
                    win.bytes += payload.len() as u64;
                    if first.is_none() {
                        *first = Some(thread_cpu_time());
                    }
                    comm.isend(*dest, tag::GATHER, payload);
                };
            for &li in rows {
                p.row_global(li as usize, &mut cbuf, &mut vbuf);
                w.f64_slice(&vbuf);
                staged += 1;
                if staged == chunk_rows {
                    post(&mut w, &mut win, &mut first_post);
                    staged = 0;
                }
            }
            if staged > 0 {
                post(&mut w, &mut win, &mut first_post);
            }
        }
        let recvd = comm.drain(tag::GATHER);
        if let Some(t0) = first_post {
            win.overlap = thread_cpu_time() - t0;
        }
        // Reassemble: concatenate a source's chunks (canonical order =
        // send order) back into its one-bulk-payload equivalent.
        let mut merged: Vec<(usize, Vec<u8>)> = Vec::new();
        for (src, payload) in recvd {
            match merged.last_mut() {
                Some((s, buf)) if *s == src => buf.extend_from_slice(&payload),
                _ => merged.push((src, payload)),
            }
        }
        debug_assert_eq!(pr.nrows(), self.map.n_needed);
        for ((_, range), payload) in self.map.zip_runs(&merged) {
            let mut r = ByteReader::new(payload);
            for t in range.clone() {
                for k in pr.rowptr[t] as usize..pr.rowptr[t + 1] as usize {
                    pr.vals[k] = r.f64();
                }
            }
            debug_assert!(r.done(), "pattern drift between symbolic and numeric");
        }
        win
    }

    /// Collective: gather the planned block rows of `p`.
    pub fn gather_bcsr(&self, comm: &Comm, p: &DistBcsr) -> PrBlocks {
        let b = p.b;
        let bb = b * b;
        let cbeg = p.col_begin() as u64;
        // serialize one block row with global ids in sorted merge order
        let write_row = |w: &mut ByteWriter, i: usize| {
            let oc = p.offd.row_cols(i);
            let dc = p.diag.row_cols(i);
            w.u32((oc.len() + dc.len()) as u32);
            let split = oc.partition_point(|&c| p.garray[c as usize] < cbeg);
            let orange = p.offd.row_range(i);
            let drange = p.diag.row_range(i);
            for k in 0..split {
                w.u64(p.garray[oc[k] as usize]);
            }
            for &c in dc {
                w.u64(cbeg + c as u64);
            }
            for k in split..oc.len() {
                w.u64(p.garray[oc[k] as usize]);
            }
            for k in 0..split {
                w.f64_slice(p.offd.block(orange.start + k));
            }
            for k in drange {
                w.f64_slice(p.diag.block(k));
            }
            for k in split..oc.len() {
                w.f64_slice(p.offd.block(orange.start + k));
            }
        };
        let mut sends = Vec::with_capacity(self.map.serve.len());
        for (dest, rows) in &self.map.serve {
            let mut w = ByteWriter::new();
            for &li in rows {
                write_row(&mut w, li as usize);
            }
            sends.push((*dest, w.into_bytes()));
        }
        let recvd = sendrecv(comm, sends);
        let mut rowptr: Vec<u32> = Vec::with_capacity(self.map.n_needed + 1);
        rowptr.push(0);
        let mut gcols: Vec<u64> = Vec::new();
        let mut vals: Vec<f64> = Vec::new();
        for ((_, range), payload) in self.map.zip_runs(&recvd) {
            let mut r = ByteReader::new(payload);
            for _ in range.clone() {
                let n = r.u32() as usize;
                for _ in 0..n {
                    gcols.push(r.u64());
                }
                for _ in 0..n * bb {
                    vals.push(r.f64());
                }
                rowptr.push(gcols.len() as u32);
            }
            debug_assert!(r.done());
        }
        PrBlocks { b, rowptr, gcols, vals }
    }
}

/// Plan for gathering remote *entries* of a distributed vector (the halo
/// used by SpMV, smoothers and the matrix-free transfers).
#[derive(Debug)]
pub struct VecGatherPlan {
    map: GatherMap,
}

impl VecGatherPlan {
    /// Collective: plan the gather of the entries named by `needed`
    /// (sorted global ids) under the vector's `layout`.
    pub fn build(comm: &Comm, layout: &Layout, needed: &[u64]) -> VecGatherPlan {
        VecGatherPlan { map: GatherMap::build(comm, layout, needed) }
    }

    pub fn n_needed(&self) -> usize {
        self.map.n_needed
    }

    pub fn bytes(&self) -> u64 {
        self.map.bytes() + (self.map.n_needed * 8) as u64
    }

    /// Collective: fetch the needed entries from `local` slices; the
    /// result is indexed like the driving `garray`.
    pub fn gather(&self, comm: &Comm, local: &[f64]) -> Vec<f64> {
        let mut out = Vec::new();
        self.gather_into(comm, local, &mut out);
        out
    }

    /// Collective: like [`VecGatherPlan::gather`] but fills a
    /// caller-provided buffer, so a plan applied every sweep (SpMV halos,
    /// transfer halos, the matrix-free stencil halo) allocates once over
    /// the solver lifetime instead of once per application.
    pub fn gather_into(&self, comm: &Comm, local: &[f64], out: &mut Vec<f64>) {
        let mut sends = Vec::with_capacity(self.map.serve.len());
        for (dest, ids) in &self.map.serve {
            let mut w = ByteWriter::with_capacity(ids.len() * 8);
            for &li in ids {
                w.f64(local[li as usize]);
            }
            sends.push((*dest, w.into_bytes()));
        }
        let recvd = sendrecv(comm, sends);
        out.clear();
        out.resize(self.map.n_needed, 0.0);
        for ((_, range), payload) in self.map.zip_runs(&recvd) {
            let mut r = ByteReader::new(payload);
            for slot in &mut out[range.clone()] {
                *slot = r.f64();
            }
            debug_assert!(r.done());
        }
    }

    /// Collective: blocked halo exchange — gather `k` values per planned
    /// id out of a row-major K-wide multivector (`local[li*k..(li+1)*k]`
    /// per owned index) in **one** epoch on the same wire format, so K
    /// simultaneous right-hand sides pay the per-message α once.  The
    /// output is indexed like the driving `garray`, `k` values per slot
    /// (`out[slot*k + j]` is column `j`); column `j`'s values are exactly
    /// what a scalar [`VecGatherPlan::gather_into`] of that column would
    /// deliver.
    pub fn gather_multi_into(&self, comm: &Comm, local: &[f64], k: usize, out: &mut Vec<f64>) {
        debug_assert!(k >= 1);
        let mut sends = Vec::with_capacity(self.map.serve.len());
        for (dest, ids) in &self.map.serve {
            let mut w = ByteWriter::with_capacity(ids.len() * k * 8);
            for &li in ids {
                let li = li as usize;
                w.f64_slice(&local[li * k..(li + 1) * k]);
            }
            sends.push((*dest, w.into_bytes()));
        }
        let recvd = sendrecv(comm, sends);
        out.clear();
        out.resize(self.map.n_needed * k, 0.0);
        for ((_, range), payload) in self.map.zip_runs(&recvd) {
            let mut r = ByteReader::new(payload);
            for slot in &mut out[range.start * k..range.end * k] {
                *slot = r.f64();
            }
            debug_assert!(r.done());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{DistCsrBuilder, World};

    /// P: 8x4 over np ranks, row gi has entries at cols {gi % 4} and
    /// {(gi + 1) % 4} with values 10*gi + col.
    fn p_matrix(rank: usize, np: usize) -> DistCsr {
        let rl = Layout::new_equal(8, np);
        let cl = Layout::new_equal(4, np);
        let mut b = DistCsrBuilder::new(rank, rl.clone(), cl);
        for gi in rl.range(rank) {
            let mut cols = vec![(gi % 4) as u64, ((gi + 1) % 4) as u64];
            cols.sort_unstable();
            cols.dedup();
            let entries: Vec<(u64, f64)> =
                cols.iter().map(|&c| (c, (10 * gi) as f64 + c as f64)).collect();
            b.push_row(&entries);
        }
        b.finish()
    }

    #[test]
    fn gather_rows_matches_local_content() {
        let w = World::new(3);
        w.run(|comm| {
            let p = p_matrix(comm.rank(), comm.size());
            // every rank asks for rows it does NOT own
            let needed: Vec<u64> = (0..8u64)
                .filter(|&g| p.row_layout.owner(g as usize) != comm.rank())
                .collect();
            let plan = RowGatherPlan::build(&comm, &p.row_layout, &needed);
            let pr = plan.gather_csr(&comm, &p);
            assert_eq!(pr.nrows(), needed.len());
            for (k, &g) in needed.iter().enumerate() {
                let (cols, vals) = pr.row(k);
                let gi = g as usize;
                let mut want: Vec<u64> = vec![(gi % 4) as u64, ((gi + 1) % 4) as u64];
                want.sort_unstable();
                want.dedup();
                assert_eq!(cols, &want[..], "row {g}");
                for (&c, &v) in cols.iter().zip(vals) {
                    assert_eq!(v, (10 * gi) as f64 + c as f64);
                }
            }
        });
    }

    #[test]
    fn pattern_then_update_equals_full_gather() {
        let w = World::new(2);
        w.run(|comm| {
            let p = p_matrix(comm.rank(), comm.size());
            let needed: Vec<u64> = (0..8u64)
                .filter(|&g| p.row_layout.owner(g as usize) != comm.rank())
                .collect();
            let plan = RowGatherPlan::build(&comm, &p.row_layout, &needed);
            let mut pr = plan.gather_pattern_csr(&comm, &p);
            // pattern present, values zero
            assert!(pr.nnz() > 0);
            assert!(pr.vals.iter().all(|&v| v == 0.0));
            plan.update_values_csr(&comm, &p, &mut pr);
            let full = plan.gather_csr(&comm, &p);
            assert_eq!(pr.rowptr, full.rowptr);
            assert_eq!(pr.cols, full.cols);
            assert_eq!(pr.vals, full.vals);
        });
    }

    #[test]
    fn empty_needed_is_fine() {
        let w = World::new(2);
        w.run(|comm| {
            let p = p_matrix(comm.rank(), comm.size());
            let plan = RowGatherPlan::build(&comm, &p.row_layout, &[]);
            let pr = plan.gather_csr(&comm, &p);
            assert_eq!(pr.nrows(), 0);
            assert_eq!(pr.nnz(), 0);
        });
    }

    #[test]
    fn vector_halo_gather() {
        let w = World::new(3);
        w.run(|comm| {
            let layout = Layout::new_equal(10, comm.size());
            let local: Vec<f64> =
                layout.range(comm.rank()).map(|g| (g * g) as f64).collect();
            let needed: Vec<u64> = (0..10u64)
                .filter(|&g| layout.owner(g as usize) != comm.rank() && g % 2 == 0)
                .collect();
            let plan = VecGatherPlan::build(&comm, &layout, &needed);
            let halo = plan.gather(&comm, &local);
            assert_eq!(halo.len(), needed.len());
            for (k, &g) in needed.iter().enumerate() {
                assert_eq!(halo[k], (g * g) as f64, "id {g}");
            }
        });
    }
}
