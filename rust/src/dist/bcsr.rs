//! Distributed block-CSR matrix (PETSc MPIBAIJ analog): the diag/offd
//! split of [`super::DistCsr`] over dense `b×b` blocks.  Layouts are in
//! *block* units; [`DistBcsr::to_scalar`] expands to the scalar layout for
//! cross-checking the block path against the scalar algorithms.

use std::cell::{Cell, Ref, RefCell};

use crate::mat::{Bcsr, BcsrBuilder};
use crate::runtime::SpmvBatcher;

use super::csr::{DistCsr, DistCsrBuilder};
use super::gather::VecGatherPlan;
use super::layout::Layout;
use super::vec::DistVec;
use super::world::Comm;

/// One rank's slice of a distributed block sparse matrix.
#[derive(Debug, Clone)]
pub struct DistBcsr {
    pub rank: usize,
    /// Block size.
    pub b: usize,
    /// Block-row layout.
    pub row_layout: Layout,
    /// Block-column layout.
    pub col_layout: Layout,
    pub diag: Bcsr,
    pub offd: Bcsr,
    /// Sorted global *block* column ids of the offd part.
    pub garray: Vec<u64>,
}

impl DistBcsr {
    /// Block rows owned by this rank.
    pub fn local_nrows(&self) -> usize {
        self.diag.nrows
    }

    /// First global block row owned by this rank.
    pub fn row_begin(&self) -> usize {
        self.row_layout.start(self.rank)
    }

    /// First global block column owned by this rank.
    pub fn col_begin(&self) -> usize {
        self.col_layout.start(self.rank)
    }

    pub fn global_nrows(&self) -> usize {
        self.row_layout.global_size()
    }

    pub fn global_ncols(&self) -> usize {
        self.col_layout.global_size()
    }

    /// Local nonzero blocks (diag + offd).
    pub fn nnz_blocks_local(&self) -> usize {
        self.diag.nnz_blocks() + self.offd.nnz_blocks()
    }

    /// Heap bytes of this rank's slice.
    pub fn bytes(&self) -> u64 {
        self.diag.bytes() + self.offd.bytes() + (self.garray.len() * 8) as u64
    }

    /// Expand into the scalar distributed CSR over the scaled layouts
    /// (explicit zeros inside blocks are dropped, so the pattern matches
    /// what a scalar assembly of the same operator would produce).
    pub fn to_scalar(&self) -> DistCsr {
        let b = self.b;
        let mut builder = DistCsrBuilder::new(
            self.rank,
            self.row_layout.scaled(b),
            self.col_layout.scaled(b),
        );
        let cbeg = self.col_begin() as u64;
        let mut entries: Vec<(u64, f64)> = Vec::new();
        for i in 0..self.local_nrows() {
            for r in 0..b {
                entries.clear();
                for idx in self.diag.row_range(i) {
                    let gc = cbeg + self.diag.cols[idx] as u64;
                    let blk = self.diag.block(idx);
                    for j in 0..b {
                        let v = blk[r * b + j];
                        if v != 0.0 {
                            entries.push((gc * b as u64 + j as u64, v));
                        }
                    }
                }
                for idx in self.offd.row_range(i) {
                    let gc = self.garray[self.offd.cols[idx] as usize];
                    let blk = self.offd.block(idx);
                    for j in 0..b {
                        let v = blk[r * b + j];
                        if v != 0.0 {
                            entries.push((gc * b as u64 + j as u64, v));
                        }
                    }
                }
                entries.sort_unstable_by_key(|&(c, _)| c);
                builder.push_row(&entries);
            }
        }
        builder.finish()
    }

    /// Check the distributed block invariants.
    pub fn validate(&self) -> Result<(), String> {
        self.diag.validate().map_err(|e| format!("diag: {e}"))?;
        self.offd.validate().map_err(|e| format!("offd: {e}"))?;
        if self.diag.b != self.b || self.offd.b != self.b {
            return Err("block size mismatch".into());
        }
        let local_rows = self.row_layout.local_size(self.rank);
        if self.diag.nrows != local_rows || self.offd.nrows != local_rows {
            return Err("block row count mismatch with layout".into());
        }
        if self.diag.ncols != self.col_layout.local_size(self.rank) {
            return Err("diag ncols != owned block columns".into());
        }
        if self.offd.ncols != self.garray.len() {
            return Err("offd ncols != garray length".into());
        }
        let cbeg = self.col_begin() as u64;
        let cend = self.col_layout.end(self.rank) as u64;
        let ncols = self.global_ncols() as u64;
        for w in self.garray.windows(2) {
            if w[0] >= w[1] {
                return Err("garray not strictly sorted".into());
            }
        }
        for &g in &self.garray {
            if g >= ncols {
                return Err(format!("garray entry {g} out of range"));
            }
            if g >= cbeg && g < cend {
                return Err(format!("garray entry {g} is locally owned"));
            }
        }
        Ok(())
    }
}

/// Block SpMV engine for [`DistBcsr`]: a scalar-unit halo plan expanded
/// from the block `garray` (each needed block contributes its `b`
/// consecutive scalar ids) plus a persistent halo buffer.  The numeric
/// work itself runs through a [`SpmvBatcher`], so block multiplies
/// execute as batched kernel launches (native tiles or the compiled
/// `block_spmv` artifact) instead of one scalar loop per block.
pub struct DistBSpmv {
    plan: VecGatherPlan,
    buf: RefCell<Vec<f64>>,
    reuses: Cell<u64>,
}

impl DistBSpmv {
    /// Build the halo plan (collective).  `x`/`y` live in the scalar
    /// layouts `col_layout.scaled(b)` / `row_layout.scaled(b)`.
    pub fn new(comm: &Comm, a: &DistBcsr) -> DistBSpmv {
        let b = a.b as u64;
        let mut ids: Vec<u64> = Vec::with_capacity(a.garray.len() * a.b);
        for &g in &a.garray {
            for j in 0..b {
                ids.push(g * b + j);
            }
        }
        let plan = VecGatherPlan::build(comm, &a.col_layout.scaled(a.b), &ids);
        DistBSpmv { plan, buf: RefCell::new(Vec::new()), reuses: Cell::new(0) }
    }

    /// Halo gathers that reused the persistent buffer's capacity.
    pub fn halo_reuses(&self) -> u64 {
        self.reuses.get()
    }

    pub fn bytes(&self) -> u64 {
        self.plan.bytes() + (self.buf.borrow().capacity() * 8) as u64
    }

    fn gather_halo(&self, comm: &Comm, x: &DistVec) -> Ref<'_, [f64]> {
        {
            let mut buf = self.buf.borrow_mut();
            let n = self.plan.n_needed();
            if buf.capacity() >= n && n > 0 {
                self.reuses.set(self.reuses.get() + 1);
                crate::obs::metrics::add(crate::obs::Subsys::Comm, "halo.reuse", 1);
            }
            self.plan.gather_into(comm, &x.vals, &mut buf);
        }
        Ref::map(self.buf.borrow(), |v| v.as_slice())
    }

    /// `y = A x` (collective): gather the scalar halo once, then stream
    /// every block multiply through the batcher.  Block products
    /// accumulate in flush order — deterministic for a fixed partition,
    /// but not bit-identical to the scalar [`super::DistSpmv`] fold.
    pub fn apply(
        &self,
        comm: &Comm,
        a: &DistBcsr,
        batcher: &mut SpmvBatcher<'_>,
        x: &DistVec,
        y: &mut DistVec,
    ) {
        let b = a.b;
        debug_assert_eq!(batcher.block_size(), b);
        debug_assert_eq!(x.vals.len(), a.col_layout.local_size(a.rank) * b);
        debug_assert_eq!(y.vals.len(), a.local_nrows() * b);
        let halo = self.gather_halo(comm, x);
        y.fill(0.0);
        let yv = &mut y.vals;
        let mut sink = |tag: u64, blk: &[f64]| {
            let off = tag as usize * b;
            for (r, &v) in blk.iter().enumerate() {
                yv[off + r] += v;
            }
        };
        for i in 0..a.local_nrows() {
            for idx in a.diag.row_range(i) {
                let bc = a.diag.cols[idx] as usize;
                batcher.push(a.diag.block(idx), &x.vals[bc * b..(bc + 1) * b], i as u64, &mut sink);
            }
            for idx in a.offd.row_range(i) {
                let oc = a.offd.cols[idx] as usize;
                batcher.push(a.offd.block(idx), &halo[oc * b..(oc + 1) * b], i as u64, &mut sink);
            }
        }
        batcher.flush(&mut sink);
    }
}

/// Row-by-row builder over (global block column, `b*b` block) entries.
#[derive(Debug)]
pub struct DistBcsrBuilder {
    rank: usize,
    b: usize,
    row_layout: Layout,
    col_layout: Layout,
    rowptr: Vec<usize>,
    cols: Vec<u64>,
    vals: Vec<f64>,
}

impl DistBcsrBuilder {
    pub fn new(rank: usize, b: usize, row_layout: Layout, col_layout: Layout) -> DistBcsrBuilder {
        assert!(b >= 1);
        DistBcsrBuilder {
            rank,
            b,
            row_layout,
            col_layout,
            rowptr: vec![0],
            cols: Vec::new(),
            vals: Vec::new(),
        }
    }

    /// Append the next local block row: strictly ascending global block
    /// columns with their blocks concatenated (`blocks.len() == cols.len()
    /// * b * b`).
    pub fn push_row(&mut self, cols: &[u64], blocks: &[f64]) {
        debug_assert_eq!(blocks.len(), cols.len() * self.b * self.b);
        debug_assert!(cols.windows(2).all(|w| w[0] < w[1]));
        self.cols.extend_from_slice(cols);
        self.vals.extend_from_slice(blocks);
        self.rowptr.push(self.cols.len());
    }

    pub fn finish(self) -> DistBcsr {
        let nrows = self.rowptr.len() - 1;
        debug_assert_eq!(nrows, self.row_layout.local_size(self.rank));
        let b = self.b;
        let bb = b * b;
        let cbeg = self.col_layout.start(self.rank) as u64;
        let cend = self.col_layout.end(self.rank) as u64;
        let mut garray: Vec<u64> = self
            .cols
            .iter()
            .copied()
            .filter(|&c| c < cbeg || c >= cend)
            .collect();
        garray.sort_unstable();
        garray.dedup();
        let mut diag = BcsrBuilder::new(self.col_layout.local_size(self.rank), b);
        let mut offd = BcsrBuilder::new(garray.len(), b);
        let mut dc: Vec<u32> = Vec::new();
        let mut dv: Vec<f64> = Vec::new();
        let mut oc: Vec<u32> = Vec::new();
        let mut ov: Vec<f64> = Vec::new();
        for i in 0..nrows {
            dc.clear();
            dv.clear();
            oc.clear();
            ov.clear();
            for k in self.rowptr[i]..self.rowptr[i + 1] {
                let c = self.cols[k];
                let blk = &self.vals[k * bb..(k + 1) * bb];
                if c >= cbeg && c < cend {
                    dc.push((c - cbeg) as u32);
                    dv.extend_from_slice(blk);
                } else {
                    oc.push(garray.binary_search(&c).unwrap() as u32);
                    ov.extend_from_slice(blk);
                }
            }
            diag.push_row(&dc, &dv);
            offd.push_row(&oc, &ov);
        }
        DistBcsr {
            rank: self.rank,
            b,
            row_layout: self.row_layout,
            col_layout: self.col_layout,
            diag: diag.finish(),
            offd: offd.finish(),
            garray,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::World;

    fn sample(rank: usize, np: usize) -> DistBcsr {
        // 4 block rows/cols of 2x2 blocks; row i hits cols i and (i+2)%4
        let b = 2usize;
        let l = Layout::new_equal(4, np);
        let mut bld = DistBcsrBuilder::new(rank, b, l.clone(), l.clone());
        for gi in l.range(rank) {
            let mut cols = vec![gi as u64, ((gi + 2) % 4) as u64];
            cols.sort_unstable();
            let mut blocks = Vec::new();
            for &c in &cols {
                // block value encodes (row, col): entry (r,j) = 100*gi + 10*c + r*2 + j
                for r in 0..b {
                    for j in 0..b {
                        blocks.push((100 * gi + 10 * c as usize + r * 2 + j) as f64);
                    }
                }
            }
            bld.push_row(&cols, &blocks);
        }
        bld.finish()
    }

    #[test]
    fn split_blocks_and_validate() {
        let d = sample(0, 2);
        d.validate().unwrap();
        assert_eq!(d.garray, vec![2, 3]);
        assert_eq!(d.diag.nnz_blocks(), 2);
        assert_eq!(d.offd.nnz_blocks(), 2);
    }

    #[test]
    fn batched_block_spmv_matches_scalar_spmv() {
        use crate::runtime::{BlockBackend, SpmvBatcher};

        let w = World::new(3);
        let reused = w.run(|c| {
            let a = sample(c.rank(), c.size());
            let s = a.to_scalar();
            let spmv = super::super::vec::DistSpmv::new(&c, &s);
            let layout = s.col_layout.clone();
            let x = DistVec::from_fn(layout.clone(), c.rank(), |g| 0.5 * g as f64 - 1.0);
            let mut y_ref = DistVec::zeros(s.row_layout.clone(), c.rank());
            spmv.apply(&c, &s, &x, &mut y_ref);

            let bspmv = DistBSpmv::new(&c, &a);
            let mut batcher = SpmvBatcher::new(BlockBackend::Native, a.b);
            let mut y = DistVec::zeros(s.row_layout.clone(), c.rank());
            bspmv.apply(&c, &a, &mut batcher, &x, &mut y);
            assert!(batcher.mults > 0);
            for (u, v) in y.vals.iter().zip(&y_ref.vals) {
                assert!((u - v).abs() <= 1e-12 * v.abs().max(1.0), "{u} vs {v}");
            }
            // a second application must reuse the warm halo buffer
            bspmv.apply(&c, &a, &mut batcher, &x, &mut y);
            bspmv.halo_reuses()
        });
        assert!(reused.iter().all(|&r| r >= 1), "halo buffer never reused: {reused:?}");
    }

    #[test]
    fn to_scalar_matches_single_rank_expansion() {
        let w = World::new(3);
        let gs = w.run(|c| sample(c.rank(), c.size()).to_scalar().gather_global(&c));
        let seq = sample(0, 1).to_scalar().gather_global_np1();
        for g in &gs {
            assert_eq!(g, &seq);
        }
    }

    impl DistCsr {
        /// np=1 shortcut used by the test above (no communicator needed).
        fn gather_global_np1(&self) -> crate::mat::Csr {
            assert_eq!(self.row_layout.np(), 1);
            let mut b = crate::mat::CsrBuilder::new(self.global_ncols());
            let (mut cols, mut vals) = (Vec::new(), Vec::new());
            let mut c32: Vec<u32> = Vec::new();
            for i in 0..self.local_nrows() {
                self.row_global(i, &mut cols, &mut vals);
                c32.clear();
                c32.extend(cols.iter().map(|&c| c as u32));
                b.push_row(&c32, &vals);
            }
            b.finish()
        }
    }
}
