//! Simulated MPI: a thread-per-rank world with a nonblocking,
//! tag-addressed communication engine underneath deterministic
//! collectives — plus sub-communicators ([`Comm::split`]) that scope
//! ranks, tags, epochs and traffic accounting to a subset of the world.
//!
//! [`World::run`] spawns one OS thread per rank and hands each a [`Comm`].
//! Communication runs over a full mesh of FIFO channels — one per ordered
//! rank pair.  Every frame on the wire carries a one-byte kind:
//!
//! - **collective** frames belong to the barrier-style collectives
//!   (`allgather_bytes`, `all_u64`, `allreduce_sum_*`), which still move
//!   exactly one frame per pair per call;
//! - **data** frames carry an epoch's point-to-point payloads for one
//!   `tag` ([`Comm::isend`] posts them immediately and returns), plus a
//!   sender-side microsecond stamp (zero when tracing is off) that lets
//!   the receiver measure true in-flight time per message;
//! - **close** frames are the epoch sentinels: a rank's promise that it
//!   will send no more data for that tag this epoch ([`Comm::drain`]
//!   posts one to every rank, then blocks until it has one from every
//!   rank).
//!
//! A per-source inbox demultiplexes the shared FIFO: frames that arrive
//! "early" (an engine payload while a peer is inside a collective, or
//! vice versa) are buffered per (source, tag) and consumed by whichever
//! call they belong to, so the SPMD call discipline never deadlocks and
//! never sees another epoch's traffic.
//!
//! Determinism: payloads are *released* to the consumer in source-rank
//! order — [`Comm::try_recv_any`] hands out the longest prefix of the
//! canonical order (all of rank 0's payloads in send order, then rank
//! 1's, ...) that has already arrived and closed, and [`Comm::drain`]
//! blocks for the rest — so interleaving sends with receives cannot
//! reorder anything relative to the bulk-synchronous [`Comm::exchange`]
//! shim, and repeated runs of a world reproduce byte-identical messages.
//! Reductions combine in rank order, so every rank computes bit-identical
//! global values.
//!
//! ## Sub-communicators
//!
//! [`Comm::split`] is the `MPI_Comm_split` analog: a collective that
//! partitions the calling communicator by `color` and returns each rank
//! its color group as a new [`Comm`].  The child shares the parent's
//! channel mesh but
//!
//! - **scopes ranks**: `rank()`/`size()` are relative to the group, and
//!   every collective/engine call addresses group members only;
//! - **scopes epochs**: `drain` posts close sentinels to members only, so
//!   ranks outside the group never enter (or hold up) the close barrier;
//! - **scopes tags**: every user tag is offset by the child's `tag_base`
//!   on the wire, so concurrent epochs on the same logical tag in
//!   different communicators cannot cross.  Bases are allocated from a
//!   per-endpoint monotonic counter, agreed across the parent's members
//!   at each split (max over members, then everyone bumps past it):
//!   any two communicators sharing *any* rank — including the rank's
//!   self-loopback channel — were both allocated through that rank's
//!   counter and therefore got distinct bases.  Communicators sharing
//!   no rank may reuse a base, but they share no channel either;
//! - **scopes stats**: [`Comm::stats`] counts only traffic sent through
//!   this communicator (shared by its clones); [`Comm::stats_global`]
//!   keeps the rank-wide total across all communicators.
//!
//! ## Reliability (wire format v3)
//!
//! Every data frame carries a per-(destination, wire-tag) sequence
//! number and an FNV-1a checksum (zero = unchecked), and every close
//! sentinel carries the epoch's exclusive end sequence.  The receiver
//! reassembles each (source, wire-tag) stream strictly in sequence
//! order — out-of-order arrivals wait in a side buffer, duplicates are
//! suppressed by their sequence number — so the canonical release order
//! (and therefore every consumer's bits) survives loss, reordering and
//! duplication.  When a [`super::fault::FaultPlan`] is armed, senders
//! keep retransmit copies of unacknowledged frames, receivers NACK
//! gaps and corrupt frames ([`FRAME_NACK`]), and the epoch close
//! barrier completes only once every member has acknowledged the
//! epoch ([`FRAME_ACK`]) — with the plan absent none of that machinery
//! runs and the transport keeps its original blocking path.  All
//! blocking waits carry a deadline (`GPTAP_COMM_TIMEOUT_MS`,
//! [`World::with_comm_timeout`]) that turns a permanent loss into a
//! diagnostic [`CommError`] naming the missing (src, tag, seq) instead
//! of a hung process.

use super::fault::{FaultPlan, FaultState, SendFate};
use crate::obs;
use std::cell::{Cell, RefCell};
use std::collections::{BTreeMap, HashMap, HashSet, VecDeque};
use std::rc::Rc;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::time::{Duration, Instant};

/// α (per-message latency) of the α-β communication model, seconds.
/// Tuned to a commodity cluster interconnect (DESIGN.md §7).
pub const COMM_ALPHA_SECS: f64 = 2.0e-6;

/// β (per-byte) of the α-β communication model, seconds/byte (~2 GB/s).
pub const COMM_BETA_SECS_PER_BYTE: f64 = 5.0e-10;

/// Reserved engine tags.  A tag names one logical stream of epochs; all
/// ranks must open and close epochs on a tag in the same global order
/// (the usual SPMD discipline), and a consumer must close its epoch
/// (`drain`) before any other consumer opens one on the same tag.
pub mod tag {
    /// The bulk-synchronous [`super::Comm::exchange`] compatibility shim.
    pub const EXCHANGE: u32 = 0;
    /// Gather-plan request/response traffic (`dist::gather`).
    pub const GATHER: u32 = 1;
    /// Triple-product symbolic-phase scatter (`ptap`).
    pub const PTAP_SYM: u32 = 2;
    /// Triple-product numeric-phase scatter (`ptap`).
    pub const PTAP_NUM: u32 = 3;
    /// Layout redistribution traffic (`agglomerate`).
    pub const REDIST: u32 = 4;

    /// Live-metrics counter names (msgs, bytes) for a tag class — static
    /// so the registry hooks stay allocation-free per update.
    pub fn metric_names(tag: u32) -> (&'static str, &'static str) {
        match tag {
            EXCHANGE => ("msgs.exchange", "bytes.exchange"),
            GATHER => ("msgs.gather", "bytes.gather"),
            PTAP_SYM => ("msgs.ptap_sym", "bytes.ptap_sym"),
            PTAP_NUM => ("msgs.ptap_num", "bytes.ptap_num"),
            REDIST => ("msgs.redist", "bytes.redist"),
            _ => ("msgs.other", "bytes.other"),
        }
    }
}

/// Tag-space stride between communicators: user tags must stay below
/// this; each [`Comm::split`] child gets its own `tag_base` multiple.
const TAG_STRIDE: u32 = 256;

/// Default staged rows per pipelined chunk; `GPTAP_PIPELINE_CHUNK`
/// overrides (any positive integer — 1 posts every row immediately, a
/// huge value degenerates to end-staging).
pub const DEFAULT_PIPELINE_CHUNK: usize = 64;

/// Rows per pipelined chunk.  Read per pipeline (not cached) so tests can
/// sweep chunk sizes within one process.
pub fn pipeline_chunk_rows() -> usize {
    std::env::var("GPTAP_PIPELINE_CHUNK")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&c| c > 0)
        .unwrap_or(DEFAULT_PIPELINE_CHUNK)
}

const FRAME_COLL: u8 = 0;
const FRAME_DATA: u8 = 1;
const FRAME_CLOSE: u8 = 2;
/// Receiver → sender: retransmit (wire_tag, seq).  Sent for checksum
/// failures and for gaps revealed by a close sentinel.
const FRAME_NACK: u8 = 3;
/// Receiver → sender: the epoch ending at `end_seq` on `wire_tag` is
/// fully received and released — the sender may drop retransmit copies
/// and complete its close barrier.  Only sent when a fault plan is
/// armed; the fault-free path completes on close sentinels alone.
const FRAME_ACK: u8 = 4;

/// v3 data-frame header: kind, wire tag, sequence number, checksum,
/// send stamp.  Payload follows.
const DATA_HDR: usize = 1 + 4 + 4 + 8 + 8;

/// Environment override (milliseconds) for every blocking transport
/// wait — drains, close barriers, collectives.
pub const ENV_COMM_TIMEOUT_MS: &str = "GPTAP_COMM_TIMEOUT_MS";

/// Default blocking-wait deadline.  Generous: it exists to convert a
/// permanently lost frame into a diagnostic, not to police slow ranks.
pub const DEFAULT_COMM_TIMEOUT: Duration = Duration::from_secs(60);

fn comm_timeout_from_env() -> Duration {
    std::env::var(ENV_COMM_TIMEOUT_MS)
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .filter(|&ms| ms > 0)
        .map(Duration::from_millis)
        .unwrap_or(DEFAULT_COMM_TIMEOUT)
}

/// FNV-1a 64 over a payload, mapped away from the zero sentinel
/// (`cksum == 0` on the wire means "unchecked" — the fault-free path
/// skips hashing entirely, mirroring the zero send stamp).
fn checksum(payload: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in payload {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    if h == 0 {
        1
    } else {
        h
    }
}

/// One frame the receiver is still waiting for when a deadline fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MissingFrame {
    /// Sender's world rank.
    pub src: usize,
    /// User tag (class) of the epoch.
    pub tag: u32,
    /// Sequence number of the missing frame.
    pub seq: u32,
}

/// A blocking transport wait ran past its deadline.  Carries everything
/// needed to diagnose the hang: which frames never arrived (by source,
/// tag and sequence number), which members never closed the epoch, and
/// — under an armed fault plan — which members never acknowledged it.
#[derive(Debug, Clone)]
pub struct CommError {
    /// User tag of the epoch that timed out.
    pub tag: u32,
    /// The deadline that fired, in milliseconds.
    pub timeout_ms: u64,
    /// Data frames known missing (a close sentinel revealed the gap).
    pub missing: Vec<MissingFrame>,
    /// Members (world ranks) whose close sentinel never arrived.
    pub missing_closes: Vec<usize>,
    /// Members (world ranks) whose epoch ACK never arrived (armed only).
    pub missing_acks: Vec<usize>,
}

impl std::fmt::Display for CommError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "comm timeout after {}ms on tag {}:", self.timeout_ms, self.tag)?;
        if self.missing.is_empty() && self.missing_closes.is_empty() && self.missing_acks.is_empty()
        {
            write!(f, " no missing frame identified (peer stalled?)")?;
        }
        for m in &self.missing {
            write!(f, " [missing src={} tag={} seq={}]", m.src, m.tag, m.seq)?;
        }
        if !self.missing_closes.is_empty() {
            write!(f, " [no close from world ranks {:?}]", self.missing_closes)?;
        }
        if !self.missing_acks.is_empty() {
            write!(f, " [no ack from world ranks {:?}]", self.missing_acks)?;
        }
        Ok(())
    }
}

impl std::error::Error for CommError {}

/// Rank-wide reliability-layer counters: what the transport detected
/// and recovered (receiver side) plus what the local fault plan
/// injected (sender side).  All zero on a clean, fault-free run.
#[derive(Debug, Default, Clone, Copy)]
pub struct ReliabilityStats {
    /// Frames re-sent from the retransmit buffer on a peer's NACK.
    pub retransmits: u64,
    /// Frames rejected by the checksum (each one also sent a NACK).
    pub corrupt_frames: u64,
    /// NACKs this rank sent (checksum failures + gap requests).
    pub nack_roundtrips: u64,
    /// Duplicate frames suppressed by sequence number.
    pub dup_suppressed: u64,
    /// Blocking waits that hit their deadline.
    pub timeouts: u64,
    /// Faults the local plan injected into this rank's sends.
    pub faults_injected: u64,
}

impl ReliabilityStats {
    /// Accumulate another rank's counters (chaos-harness reduction).
    pub fn merge(&mut self, o: ReliabilityStats) {
        self.retransmits += o.retransmits;
        self.corrupt_frames += o.corrupt_frames;
        self.nack_roundtrips += o.nack_roundtrips;
        self.dup_suppressed += o.dup_suppressed;
        self.timeouts += o.timeouts;
        self.faults_injected += o.faults_injected;
    }
}

/// Number of logarithmic message-size buckets in [`CommStats::hist`].
pub const SIZE_BUCKETS: usize = 8;

/// Upper edge (exclusive, bytes) of each size bucket; the last bucket is
/// unbounded.
pub const SIZE_BUCKET_EDGES: [u64; SIZE_BUCKETS - 1] =
    [64, 256, 1024, 4096, 16384, 65536, 262144];

fn size_bucket(bytes: u64) -> usize {
    SIZE_BUCKET_EDGES.iter().position(|&e| bytes < e).unwrap_or(SIZE_BUCKETS - 1)
}

/// Representative payload size of bucket `b` (geometric midpoint of its
/// edges), used by the calibrated α model.
fn bucket_rep_bytes(b: usize) -> f64 {
    let lo = if b == 0 { 1 } else { SIZE_BUCKET_EDGES[b - 1] };
    let hi = if b + 1 == SIZE_BUCKETS { 4 * lo } else { SIZE_BUCKET_EDGES[b] };
    ((lo * hi) as f64).sqrt()
}

/// Number of logarithmic in-flight latency buckets in
/// [`CommStats::flight_hist`].
pub const LAT_BUCKETS: usize = 8;

/// Upper edge (exclusive, microseconds) of each latency bucket; the last
/// bucket is unbounded.
pub const LAT_BUCKET_EDGES_US: [u64; LAT_BUCKETS - 1] = [1, 5, 10, 50, 100, 500, 1000];

fn lat_bucket(us: u64) -> usize {
    LAT_BUCKET_EDGES_US.iter().position(|&e| us < e).unwrap_or(LAT_BUCKETS - 1)
}

/// Snapshot of one rank's cumulative send-side traffic.
#[derive(Debug, Default, Clone, Copy)]
pub struct CommStats {
    /// Point-to-point messages sent to other ranks.
    pub msgs: u64,
    /// Payload bytes sent to other ranks.
    pub bytes: u64,
    /// Message counts by payload-size bucket ([`SIZE_BUCKET_EDGES`]) —
    /// the measured chunk-size distribution the calibrated α model reads.
    pub hist: [u64; SIZE_BUCKETS],
    /// Messages whose in-flight time was observed (the sender stamped a
    /// send time into the frame — i.e. the sender was tracing).  Recorded
    /// receiver-side, rank-wide only: scoped [`Comm::stats`] snapshots
    /// report zero here; read them from [`Comm::stats_global`].
    pub flight_msgs: u64,
    /// Total observed in-flight microseconds (send stamp → delivery).
    pub flight_us: u64,
    /// Observed in-flight times by latency bucket
    /// ([`LAT_BUCKET_EDGES_US`]).
    pub flight_hist: [u64; LAT_BUCKETS],
    /// Epoch close barriers this rank has completed ([`Comm::drain`]).
    pub close_waits: u64,
    /// Microseconds spent blocked in those close barriers — idle wait
    /// that would otherwise masquerade as communication time.
    pub close_wait_us: u64,
    /// Close-barrier waits by latency bucket ([`LAT_BUCKET_EDGES_US`]).
    /// Rank-wide like the flight histogram: subcommunicator barriers
    /// (telescoping splits) land here too, so the histogram totals match
    /// `close_waits` through [`Comm::stats_global`] no matter how many
    /// nested splits drained epochs.
    pub close_wait_hist: [u64; LAT_BUCKETS],
}

impl CommStats {
    /// The α-β model applied to this rank's traffic (fixed per-message α).
    pub fn modeled_secs(&self) -> f64 {
        self.msgs as f64 * COMM_ALPHA_SECS + self.bytes as f64 * COMM_BETA_SECS_PER_BYTE
    }

    /// The α term under the *calibrated* per-message credit: a pipelined
    /// chunk posted back-to-back behind another is spaced by its own
    /// serialization time, so a message of size `s` adds only
    /// `min(α, s·β)` of latency — small chunks (the engine's pipelined
    /// trains) amortize α, bulk messages still pay it in full.  Derived
    /// from the measured size histogram rather than the single constant.
    pub fn alpha_secs_calibrated(&self) -> f64 {
        self.hist
            .iter()
            .enumerate()
            .map(|(b, &n)| {
                n as f64 * COMM_ALPHA_SECS.min(bucket_rep_bytes(b) * COMM_BETA_SECS_PER_BYTE)
            })
            .sum()
    }

    /// The α-β model with the calibrated per-message α credit.
    pub fn modeled_secs_calibrated(&self) -> f64 {
        self.alpha_secs_calibrated() + self.bytes as f64 * COMM_BETA_SECS_PER_BYTE
    }

    /// Mean observed in-flight seconds per stamped message (0 when no
    /// message carried a stamp, i.e. the run was untraced).
    pub fn mean_flight_secs(&self) -> f64 {
        if self.flight_msgs == 0 {
            0.0
        } else {
            self.flight_us as f64 / self.flight_msgs as f64 * 1e-6
        }
    }

    /// Seconds spent blocked in epoch close barriers.
    pub fn close_wait_secs(&self) -> f64 {
        self.close_wait_us as f64 * 1e-6
    }

    /// Traffic since `earlier` (same counters, monotone).
    pub fn since(&self, earlier: CommStats) -> CommStats {
        let mut hist = [0u64; SIZE_BUCKETS];
        for (h, (a, b)) in hist.iter_mut().zip(self.hist.iter().zip(earlier.hist)) {
            *h = a - b;
        }
        let mut flight_hist = [0u64; LAT_BUCKETS];
        for (h, (a, b)) in
            flight_hist.iter_mut().zip(self.flight_hist.iter().zip(earlier.flight_hist))
        {
            *h = a - b;
        }
        let mut close_wait_hist = [0u64; LAT_BUCKETS];
        for (h, (a, b)) in
            close_wait_hist.iter_mut().zip(self.close_wait_hist.iter().zip(earlier.close_wait_hist))
        {
            *h = a - b;
        }
        CommStats {
            msgs: self.msgs - earlier.msgs,
            bytes: self.bytes - earlier.bytes,
            hist,
            flight_msgs: self.flight_msgs - earlier.flight_msgs,
            flight_us: self.flight_us - earlier.flight_us,
            flight_hist,
            close_waits: self.close_waits - earlier.close_waits,
            close_wait_us: self.close_wait_us - earlier.close_wait_us,
            close_wait_hist,
        }
    }

    /// Accumulate another snapshot's counters into this one.
    pub fn merge(&mut self, other: CommStats) {
        self.msgs += other.msgs;
        self.bytes += other.bytes;
        for (h, o) in self.hist.iter_mut().zip(other.hist) {
            *h += o;
        }
        self.flight_msgs += other.flight_msgs;
        self.flight_us += other.flight_us;
        for (h, o) in self.flight_hist.iter_mut().zip(other.flight_hist) {
            *h += o;
        }
        self.close_waits += other.close_waits;
        self.close_wait_us += other.close_wait_us;
        for (h, o) in self.close_wait_hist.iter_mut().zip(other.close_wait_hist) {
            *h += o;
        }
    }
}

/// One buffered engine frame: a payload, or the epoch-close sentinel.
enum EngineFrame {
    Data(Vec<u8>),
    Close,
}

/// One (source, wire-tag) receive stream: the in-order release queue the
/// consumer pops, plus the sequence-reassembly state that feeds it.
#[derive(Default)]
struct TagStream {
    /// Frames released to the consumer, in canonical order; `Close`
    /// entries delimit epochs.
    queue: VecDeque<EngineFrame>,
    /// Next sequence number the release queue needs (monotonic across
    /// epochs — sequence numbers never reset).
    next_seq: u32,
    /// Out-of-order arrivals parked until the gap before them fills.
    ooo: BTreeMap<u32, Vec<u8>>,
    /// Close sentinels (exclusive end sequence) whose epochs are not
    /// complete yet, in arrival order.
    pending_end: VecDeque<u32>,
}

impl TagStream {
    /// Release every frame (and close) that is now in sequence.  Returns
    /// the end sequences of epochs completed by this advance — each one
    /// owes the sender an ACK when the reliability protocol is armed.
    fn advance(&mut self) -> Vec<u32> {
        let mut completed = Vec::new();
        loop {
            if let Some(&end) = self.pending_end.front() {
                if self.next_seq >= end {
                    self.pending_end.pop_front();
                    self.queue.push_back(EngineFrame::Close);
                    completed.push(end);
                    continue;
                }
            }
            if let Some(p) = self.ooo.remove(&self.next_seq) {
                self.queue.push_back(EngineFrame::Data(p));
                self.next_seq += 1;
                continue;
            }
            break;
        }
        completed
    }

    /// Sequence numbers the oldest pending epoch is still missing.
    fn gaps(&self) -> Vec<u32> {
        let Some(&end) = self.pending_end.front() else { return Vec::new() };
        (self.next_seq..end).filter(|s| !self.ooo.contains_key(s)).collect()
    }
}

/// Demultiplexed arrivals from one source rank.
#[derive(Default)]
struct SourceInbox {
    /// Collective frames, in arrival (= send) order.
    coll: VecDeque<Vec<u8>>,
    /// Engine streams per wire tag.
    tags: HashMap<u32, TagStream>,
}

/// One rank's physical end of the channel mesh, shared by every
/// communicator ([`Comm`]) this rank holds.
struct Endpoint {
    world_rank: usize,
    world_np: usize,
    /// `tx[d]` sends one frame to world rank `d` (index `world_rank`
    /// loops back).
    tx: Vec<Sender<Vec<u8>>>,
    /// `rx[s]` receives frames sent by world rank `s`.
    rx: Vec<Receiver<Vec<u8>>>,
    /// Rank-wide send-side totals across all communicators.
    total_msgs: Cell<u64>,
    total_bytes: Cell<u64>,
    total_hist: Cell<[u64; SIZE_BUCKETS]>,
    /// Rank-wide receive-side in-flight accounting (stamped frames only).
    total_flight_msgs: Cell<u64>,
    total_flight_us: Cell<u64>,
    total_flight_hist: Cell<[u64; LAT_BUCKETS]>,
    /// Rank-wide epoch close-barrier accounting.
    total_close_waits: Cell<u64>,
    total_close_wait_us: Cell<u64>,
    total_close_wait_hist: Cell<[u64; LAT_BUCKETS]>,
    /// Next free wire-tag base for communicators created through this
    /// rank (monotonic; every split involving this rank bumps it).
    next_tag_base: Cell<u32>,
    /// Early arrivals, demultiplexed per world source.
    inbox: RefCell<Vec<SourceInbox>>,
    /// Per-wire-tag release cursor: the next *member index* (within the
    /// communicator owning that tag) whose current-epoch payloads have
    /// not been fully released yet (absent = 0).
    cursor: RefCell<HashMap<u32, usize>>,
    /// Next data sequence number per wire tag, indexed by destination
    /// world rank (monotonic across epochs).
    send_seq: RefCell<HashMap<u32, Vec<u32>>>,
    /// Retransmit copies of in-flight frames per wire tag, indexed by
    /// destination world rank (armed fault plan only; cleared when the
    /// destination ACKs the epoch).
    unacked: RefCell<HashMap<u32, Vec<BTreeMap<u32, Vec<u8>>>>>,
    /// Epoch ACKs received per wire tag: the world ranks whose current
    /// epoch acknowledgment has arrived (armed only).
    acks: RefCell<HashMap<u32, HashSet<usize>>>,
    /// Installed fault plan runtime (`None` = fault-free fast path).
    fault: Option<FaultState>,
    /// Deadline applied to every blocking transport wait.
    timeout: Duration,
    // Reliability counters (rank-wide, see [`ReliabilityStats`]).
    n_retransmits: Cell<u64>,
    n_corrupt: Cell<u64>,
    n_nacks: Cell<u64>,
    n_dup_suppressed: Cell<u64>,
    n_timeouts: Cell<u64>,
}

impl Endpoint {
    fn send_raw(&self, wdest: usize, f: Vec<u8>) {
        self.tx[wdest].send(f).expect("peer rank terminated early");
    }

    /// Ask `src` to retransmit (wire, seq).
    fn send_nack(&self, src: usize, wire: u32, seq: u32) {
        self.n_nacks.set(self.n_nacks.get() + 1);
        if obs::metrics::enabled() {
            obs::metrics::add(obs::Subsys::Comm, "nack_roundtrips", 1);
        }
        let mut f = Vec::with_capacity(9);
        f.push(FRAME_NACK);
        f.extend_from_slice(&wire.to_le_bytes());
        f.extend_from_slice(&seq.to_le_bytes());
        self.send_raw(src, f);
    }

    /// Confirm to `src` that its epoch ending at `end_seq` is complete.
    fn send_ack(&self, src: usize, wire: u32, end_seq: u32) {
        let mut f = Vec::with_capacity(9);
        f.push(FRAME_ACK);
        f.extend_from_slice(&wire.to_le_bytes());
        f.extend_from_slice(&end_seq.to_le_bytes());
        self.send_raw(src, f);
    }

    /// Transmit one built data frame to `wdest`, applying the armed
    /// fault plan's verdict (and keeping a retransmit copy) when one is
    /// installed.  `tag_class` is the user tag the plan rules match on.
    fn post_data(&self, wdest: usize, wire: u32, seq: u32, frame: Vec<u8>, tag_class: u32) {
        let Some(fs) = &self.fault else {
            self.send_raw(wdest, frame);
            return;
        };
        // Age the destination's delay limbo first so a parked frame's
        // hold counts *other* sends, then decide this frame's fate.
        for parked in fs.tick(wdest) {
            self.send_raw(wdest, parked);
        }
        let d = fs.decide(tag_class);
        if d.stall_ms > 0 {
            std::thread::sleep(Duration::from_millis(d.stall_ms));
        }
        if d.fate != SendFate::Blackhole {
            let mut un = self.unacked.borrow_mut();
            un.entry(wire).or_insert_with(|| vec![BTreeMap::new(); self.world_np])[wdest]
                .insert(seq, frame.clone());
        }
        match d.fate {
            SendFate::Deliver => self.send_raw(wdest, frame),
            SendFate::Duplicate => {
                self.send_raw(wdest, frame.clone());
                self.send_raw(wdest, frame);
            }
            SendFate::Corrupt => {
                let mut f = frame;
                if f.len() > DATA_HDR {
                    // flip one payload bit, deterministically by seq
                    let i = DATA_HDR + seq as usize % (f.len() - DATA_HDR);
                    f[i] ^= 1 << (seq % 8);
                } else {
                    // empty payload: corrupt the checksum field instead
                    f[9] ^= 1;
                }
                self.send_raw(wdest, f);
            }
            SendFate::Drop | SendFate::Blackhole => {}
            SendFate::Delay { hold } => fs.park(wdest, frame, hold),
        }
    }

    /// Route an arrived frame into the per-source inbox.  Data frames
    /// are verified (checksum), deduplicated and reassembled in
    /// sequence order before anything reaches a release queue, so the
    /// canonical order — and every consumer's bits — survives loss,
    /// reordering, duplication and corruption.
    fn deliver(&self, src: usize, frame: Vec<u8>) {
        match frame[0] {
            FRAME_COLL => {
                self.inbox.borrow_mut()[src].coll.push_back(frame[1..].to_vec());
            }
            FRAME_DATA => self.deliver_data(src, frame),
            FRAME_CLOSE => {
                let t = u32::from_le_bytes(frame[1..5].try_into().unwrap());
                let end = u32::from_le_bytes(frame[5..9].try_into().unwrap());
                let armed = self.fault.is_some();
                let (gaps, completed) = {
                    let mut inbox = self.inbox.borrow_mut();
                    let st = inbox[src].tags.entry(t).or_default();
                    st.pending_end.push_back(end);
                    let gaps = if armed { st.gaps() } else { Vec::new() };
                    (gaps, st.advance())
                };
                // NACK the gaps the sentinel just revealed; ACK epochs
                // this close completed (usually the one it announced).
                for seq in gaps {
                    self.send_nack(src, t, seq);
                }
                if armed {
                    for end in completed {
                        self.send_ack(src, t, end);
                    }
                }
            }
            FRAME_NACK => {
                let t = u32::from_le_bytes(frame[1..5].try_into().unwrap());
                let seq = u32::from_le_bytes(frame[5..9].try_into().unwrap());
                let copy = self
                    .unacked
                    .borrow()
                    .get(&t)
                    .and_then(|per_dest| per_dest[src].get(&seq))
                    .cloned();
                match copy {
                    Some(f) => {
                        self.n_retransmits.set(self.n_retransmits.get() + 1);
                        if obs::metrics::enabled() {
                            obs::metrics::add(obs::Subsys::Comm, "retransmits", 1);
                        }
                        self.send_raw(src, f);
                    }
                    None => {
                        // Blackholed (no retransmit copy) or already
                        // ACK-cleared.  The former is unrecoverable and
                        // will surface as the peer's CommError.
                        crate::log_warn!(
                            "unserviceable NACK from world rank {src}: wire tag {t} seq {seq}"
                        );
                    }
                }
            }
            FRAME_ACK => {
                let t = u32::from_le_bytes(frame[1..5].try_into().unwrap());
                let end = u32::from_le_bytes(frame[5..9].try_into().unwrap());
                self.acks.borrow_mut().entry(t).or_default().insert(src);
                if let Some(per_dest) = self.unacked.borrow_mut().get_mut(&t) {
                    per_dest[src].retain(|&s, _| s >= end);
                }
            }
            k => unreachable!("bad frame kind {k}"),
        }
    }

    fn deliver_data(&self, src: usize, frame: Vec<u8>) {
        let t = u32::from_le_bytes(frame[1..5].try_into().unwrap());
        let seq = u32::from_le_bytes(frame[5..9].try_into().unwrap());
        let cksum = u64::from_le_bytes(frame[9..17].try_into().unwrap());
        let send_us = u64::from_le_bytes(frame[17..25].try_into().unwrap());
        let completed = {
            let mut inbox = self.inbox.borrow_mut();
            let st = inbox[src].tags.entry(t).or_default();
            // Duplicate suppression: already released or already parked.
            if seq < st.next_seq || st.ooo.contains_key(&seq) {
                self.n_dup_suppressed.set(self.n_dup_suppressed.get() + 1);
                if obs::metrics::enabled() {
                    obs::metrics::add(obs::Subsys::Comm, "dup_suppressed", 1);
                }
                return;
            }
            // Verify before accepting; a corrupt frame is discarded and
            // NACKed so the sender's intact copy replaces it.  cksum 0
            // means the sender ran unchecked (fault-free fast path).
            if cksum != 0 && checksum(&frame[DATA_HDR..]) != cksum {
                self.n_corrupt.set(self.n_corrupt.get() + 1);
                if obs::metrics::enabled() {
                    obs::metrics::add(obs::Subsys::Comm, "corrupt_frames", 1);
                }
                drop(inbox);
                self.send_nack(src, t, seq);
                return;
            }
            // Self-loopback frames are uncounted in CommStats, so their
            // flights are skipped here too.  Only accepted frames count.
            if send_us != 0 && src != self.world_rank {
                let recv_us = obs::now_us();
                let us = recv_us.saturating_sub(send_us);
                self.total_flight_msgs.set(self.total_flight_msgs.get() + 1);
                self.total_flight_us.set(self.total_flight_us.get() + us);
                let mut fh = self.total_flight_hist.get();
                fh[lat_bucket(us)] += 1;
                self.total_flight_hist.set(fh);
                obs::flight(src as u32, t, (frame.len() - DATA_HDR) as u64, send_us, recv_us);
                obs::metrics::observe(obs::Subsys::Comm, "flight_us", us);
            }
            let payload = frame[DATA_HDR..].to_vec();
            if seq == st.next_seq && st.ooo.is_empty() && st.pending_end.is_empty() {
                // in-order fast path: the fault-free transport lives here
                st.queue.push_back(EngineFrame::Data(payload));
                st.next_seq += 1;
                return;
            }
            st.ooo.insert(seq, payload);
            st.advance()
        };
        if self.fault.is_some() {
            for end in completed {
                self.send_ack(src, t, end);
            }
        }
    }

    /// Next collective frame from world rank `src`, demuxing engine
    /// frames aside.  The blocking wait carries the transport deadline:
    /// a peer that never sends (lost to a fault, or wedged) surfaces as
    /// a diagnostic panic instead of a hung process.
    fn recv_collective(&self, src: usize) -> Vec<u8> {
        let deadline = Instant::now() + self.timeout;
        loop {
            let buffered = self.inbox.borrow_mut()[src].coll.pop_front();
            if let Some(f) = buffered {
                return f;
            }
            let wait = deadline.saturating_duration_since(Instant::now());
            match self.rx[src].recv_timeout(wait) {
                Ok(frame) => self.deliver(src, frame),
                Err(RecvTimeoutError::Timeout) => {
                    self.n_timeouts.set(self.n_timeouts.get() + 1);
                    obs::metrics::add(obs::Subsys::Comm, "timeouts", 1);
                    panic!(
                        "comm timeout after {}ms: no collective frame from world rank {src}",
                        self.timeout.as_millis()
                    );
                }
                Err(RecvTimeoutError::Disconnected) => panic!("peer rank panicked"),
            }
        }
    }
}

/// Membership of one communicator: the world ranks it spans, this rank's
/// index among them, the wire-tag offset, and the scoped traffic stats
/// (shared by clones of the same communicator).
struct Group {
    /// World ranks of the members, strictly ascending.
    members: Vec<usize>,
    /// This rank's index within `members` — its rank in this communicator.
    my: usize,
    /// Added to every user tag on the wire (epoch scoping).
    tag_base: u32,
    /// Send-side traffic through this communicator.
    msgs: Cell<u64>,
    bytes: Cell<u64>,
    hist: Cell<[u64; SIZE_BUCKETS]>,
}

/// One rank's endpoint of a (sub-)communicator.  Cheap to clone: clones
/// share the channel mesh and the communicator's scoped stats.
#[derive(Clone)]
pub struct Comm {
    ep: Rc<Endpoint>,
    group: Rc<Group>,
}

impl Comm {
    /// Build the world communicator for one rank (called on its thread).
    fn root(
        world_rank: usize,
        world_np: usize,
        tx: Vec<Sender<Vec<u8>>>,
        rx: Vec<Receiver<Vec<u8>>>,
        fault_plan: Option<FaultPlan>,
        timeout: Duration,
    ) -> Comm {
        Comm {
            ep: Rc::new(Endpoint {
                world_rank,
                world_np,
                tx,
                rx,
                total_msgs: Cell::new(0),
                total_bytes: Cell::new(0),
                total_hist: Cell::new([0; SIZE_BUCKETS]),
                total_flight_msgs: Cell::new(0),
                total_flight_us: Cell::new(0),
                total_flight_hist: Cell::new([0; LAT_BUCKETS]),
                total_close_waits: Cell::new(0),
                total_close_wait_us: Cell::new(0),
                total_close_wait_hist: Cell::new([0; LAT_BUCKETS]),
                next_tag_base: Cell::new(TAG_STRIDE),
                inbox: RefCell::new((0..world_np).map(|_| SourceInbox::default()).collect()),
                cursor: RefCell::new(HashMap::new()),
                send_seq: RefCell::new(HashMap::new()),
                unacked: RefCell::new(HashMap::new()),
                acks: RefCell::new(HashMap::new()),
                fault: fault_plan.map(|p| FaultState::new(p, world_rank)),
                timeout,
                n_retransmits: Cell::new(0),
                n_corrupt: Cell::new(0),
                n_nacks: Cell::new(0),
                n_dup_suppressed: Cell::new(0),
                n_timeouts: Cell::new(0),
            }),
            group: Rc::new(Group {
                members: (0..world_np).collect(),
                my: world_rank,
                tag_base: 0,
                msgs: Cell::new(0),
                bytes: Cell::new(0),
                hist: Cell::new([0; SIZE_BUCKETS]),
            }),
        }
    }

    /// This rank's id within this communicator, `0..size()`.
    pub fn rank(&self) -> usize {
        self.group.my
    }

    /// Number of ranks in this communicator.
    pub fn size(&self) -> usize {
        self.group.members.len()
    }

    /// World rank behind member index `r` of this communicator.
    pub fn world_rank_of(&self, r: usize) -> usize {
        self.group.members[r]
    }

    /// Cumulative send-side traffic through *this* communicator (payload
    /// bytes; engine framing and close sentinels are protocol overhead
    /// and uncounted, exactly as the one-frame-per-pair barrier was).
    /// Scoped: a sub-communicator counts only its own epochs and
    /// collectives — see [`Comm::stats_global`] for the rank-wide total.
    pub fn stats(&self) -> CommStats {
        // In-flight and close-barrier accounting is rank-wide (receiver
        // side cannot cheaply attribute a wire tag to a communicator), so
        // scoped snapshots carry zeros there — see [`Comm::stats_global`].
        CommStats {
            msgs: self.group.msgs.get(),
            bytes: self.group.bytes.get(),
            hist: self.group.hist.get(),
            ..CommStats::default()
        }
    }

    /// Rank-wide send-side totals across every communicator this rank
    /// holds (world + all sub-communicators), plus the receive-side
    /// in-flight and close-barrier accounting.
    pub fn stats_global(&self) -> CommStats {
        CommStats {
            msgs: self.ep.total_msgs.get(),
            bytes: self.ep.total_bytes.get(),
            hist: self.ep.total_hist.get(),
            flight_msgs: self.ep.total_flight_msgs.get(),
            flight_us: self.ep.total_flight_us.get(),
            flight_hist: self.ep.total_flight_hist.get(),
            close_waits: self.ep.total_close_waits.get(),
            close_wait_us: self.ep.total_close_wait_us.get(),
            close_wait_hist: self.ep.total_close_wait_hist.get(),
        }
    }

    /// Count `msgs` sent messages of `msg_bytes` payload bytes each.
    fn count_send(&self, msgs: u64, msg_bytes: u64) {
        let bytes = msgs * msg_bytes;
        self.group.msgs.set(self.group.msgs.get() + msgs);
        self.group.bytes.set(self.group.bytes.get() + bytes);
        self.ep.total_msgs.set(self.ep.total_msgs.get() + msgs);
        self.ep.total_bytes.set(self.ep.total_bytes.get() + bytes);
        if msgs > 0 {
            let b = size_bucket(msg_bytes);
            let mut gh = self.group.hist.get();
            gh[b] += msgs;
            self.group.hist.set(gh);
            let mut th = self.ep.total_hist.get();
            th[b] += msgs;
            self.ep.total_hist.set(th);
        }
    }

    /// The wire tag carrying user `tag` for this communicator.
    fn wire_tag(&self, tag: u32) -> u32 {
        debug_assert!(tag < TAG_STRIDE, "user tag {tag} exceeds the communicator tag space");
        self.group.tag_base + tag
    }

    /// Split this communicator by `color` (collective — the
    /// `MPI_Comm_split` analog): members that passed the same color form
    /// a new communicator, ordered by their rank here.  The child scopes
    /// ranks, tags, epochs and stats to its members; ranks outside a
    /// child never participate in its collectives or epoch close
    /// barriers.
    pub fn split(&self, color: usize) -> Comm {
        let colors = self.all_u64(color as u64);
        let members: Vec<usize> = colors
            .iter()
            .enumerate()
            .filter(|&(_, &c)| c == color as u64)
            .map(|(i, _)| self.group.members[i])
            .collect();
        let my = members
            .binary_search(&self.ep.world_rank)
            .expect("split: caller missing from its own color group");
        // Agree on the children's wire-tag base: the max of the members'
        // next free bases, which everyone then bumps past.  Allocating
        // through each member's endpoint counter makes the base unique
        // among all communicators sharing any rank (self-loopback
        // channel included); sibling color groups share one base but are
        // disjoint rank sets, so they share no channel at all.
        let bases = self.all_u64(self.ep.next_tag_base.get() as u64);
        let tag_base = bases.into_iter().max().unwrap() as u32;
        self.ep.next_tag_base.set(tag_base + TAG_STRIDE);
        Comm {
            ep: Rc::clone(&self.ep),
            group: Rc::new(Group {
                members,
                my,
                tag_base,
                msgs: Cell::new(0),
                bytes: Cell::new(0),
                hist: Cell::new([0; SIZE_BUCKETS]),
            }),
        }
    }

    /// One collective round: every member sends exactly one frame to
    /// every member (self included) and receives one frame from every
    /// member, in member order.
    fn round(&self, frames: Vec<Vec<u8>>) -> Vec<Vec<u8>> {
        debug_assert_eq!(frames.len(), self.size());
        for (d, frame) in frames.into_iter().enumerate() {
            let mut f = Vec::with_capacity(1 + frame.len());
            f.push(FRAME_COLL);
            f.extend_from_slice(&frame);
            self.ep.tx[self.group.members[d]].send(f).expect("peer rank terminated early");
        }
        self.group.members.iter().map(|&s| self.ep.recv_collective(s)).collect()
    }

    /// Post `payload` to member `dest` under `tag` and return immediately
    /// (the nonblocking send).  Payloads are delivered in send order per
    /// (source, tag) pair; `dest == rank()` loops back.
    ///
    /// Wire format v3: after the tag the frame carries a per-(dest,
    /// wire-tag) sequence number, an FNV-1a checksum of the payload
    /// (zero when no fault plan is armed — hashing is skipped), and the
    /// 8-byte send stamp (zero when tracing is off).  Framing bytes are
    /// protocol overhead and never counted in [`CommStats`]; neither
    /// are retransmits, duplicates, NACKs or ACKs — the stats count
    /// *logical* sends, so a faulted run's traffic accounting is
    /// bitwise a clean run's.
    pub fn isend(&self, dest: usize, tag: u32, payload: Vec<u8>) {
        let wdest = self.group.members[dest];
        if wdest != self.ep.world_rank {
            self.count_send(1, payload.len() as u64);
            if obs::metrics::enabled() {
                let (msgs_name, bytes_name) = tag::metric_names(tag);
                obs::metrics::add(obs::Subsys::Comm, msgs_name, 1);
                obs::metrics::add(obs::Subsys::Comm, bytes_name, payload.len() as u64);
            }
        }
        let wire = self.wire_tag(tag);
        let seq = {
            let mut m = self.ep.send_seq.borrow_mut();
            let per_dest = m.entry(wire).or_insert_with(|| vec![0u32; self.ep.world_np]);
            let s = per_dest[wdest];
            per_dest[wdest] += 1;
            s
        };
        let cksum = if self.ep.fault.is_some() { checksum(&payload) } else { 0 };
        // Stamp whenever either observer is armed: the tracer records the
        // flight event, the metrics registry feeds its latency histogram.
        let send_us =
            if obs::enabled() || obs::metrics::enabled() { obs::now_us() } else { 0 };
        let mut f = Vec::with_capacity(DATA_HDR + payload.len());
        f.push(FRAME_DATA);
        f.extend_from_slice(&wire.to_le_bytes());
        f.extend_from_slice(&seq.to_le_bytes());
        f.extend_from_slice(&cksum.to_le_bytes());
        f.extend_from_slice(&send_us.to_le_bytes());
        f.extend_from_slice(&payload);
        self.ep.post_data(wdest, wire, seq, f, tag);
    }

    fn send_close(&self, dest: usize, tag: u32) {
        let wire = self.wire_tag(tag);
        let wdest = self.group.members[dest];
        // The close announces the epoch's exclusive end sequence: the
        // receiver learns exactly which frames it is still owed.
        let end_seq = self
            .ep
            .send_seq
            .borrow()
            .get(&wire)
            .map(|per_dest| per_dest[wdest])
            .unwrap_or(0);
        let mut f = Vec::with_capacity(9);
        f.push(FRAME_CLOSE);
        f.extend_from_slice(&wire.to_le_bytes());
        f.extend_from_slice(&end_seq.to_le_bytes());
        self.ep.send_raw(wdest, f);
        // Flush this destination's delay limbo *after* the sentinel:
        // the genuine past-the-close reorder the delay rule produces.
        if let Some(fs) = &self.ep.fault {
            for parked in fs.flush_parked(wdest) {
                self.ep.send_raw(wdest, parked);
            }
        }
    }

    /// Release loop shared by [`Comm::try_recv_any`] and [`Comm::drain`]:
    /// walk member sources in rank order from the tag's cursor, handing
    /// out data frames until the epoch closes (every member's `Close`
    /// consumed) or — nonblocking — until the cursor source has nothing
    /// buffered.  Returns whether the epoch fully closed (and resets the
    /// cursor); the blocking walk returns [`CommError`] when its
    /// deadline fires.  Released source ids are member indices.
    fn release_into(
        &self,
        tag: u32,
        deadline: Option<Instant>,
        out: &mut Vec<(usize, Vec<u8>)>,
    ) -> Result<bool, CommError> {
        let wire = self.wire_tag(tag);
        let np = self.size();
        let mut cur = self.ep.cursor.borrow_mut().remove(&wire).unwrap_or(0);
        'sources: while cur < np {
            let wsrc = self.group.members[cur];
            loop {
                let next = self.ep.inbox.borrow_mut()[wsrc]
                    .tags
                    .get_mut(&wire)
                    .and_then(|st| st.queue.pop_front());
                match next {
                    Some(EngineFrame::Data(p)) => {
                        out.push((cur, p));
                        continue;
                    }
                    Some(EngineFrame::Close) => {
                        cur += 1;
                        continue 'sources;
                    }
                    None => {}
                }
                match deadline {
                    Some(d) => {
                        let wait = d.saturating_duration_since(Instant::now());
                        match self.ep.rx[wsrc].recv_timeout(wait) {
                            Ok(frame) => self.ep.deliver(wsrc, frame),
                            Err(RecvTimeoutError::Timeout) => {
                                self.ep.cursor.borrow_mut().insert(wire, cur);
                                return Err(self.timeout_report(tag));
                            }
                            Err(RecvTimeoutError::Disconnected) => panic!("peer rank panicked"),
                        }
                    }
                    None => match self.ep.rx[wsrc].try_recv() {
                        Ok(frame) => self.ep.deliver(wsrc, frame),
                        Err(TryRecvError::Empty) => break 'sources,
                        Err(TryRecvError::Disconnected) => panic!("peer rank panicked"),
                    },
                }
            }
        }
        if cur >= np {
            Ok(true)
        } else {
            self.ep.cursor.borrow_mut().insert(wire, cur);
            Ok(false)
        }
    }

    /// Build the deadline diagnostic for `tag`: every frame, close and
    /// (armed) ACK this rank is still owed, dumped to the log and the
    /// observers before being returned as a [`CommError`].
    fn timeout_report(&self, tag: u32) -> CommError {
        let wire = self.wire_tag(tag);
        let mut missing = Vec::new();
        let mut missing_closes = Vec::new();
        let inbox = self.ep.inbox.borrow();
        for &wsrc in &self.group.members {
            match inbox[wsrc].tags.get(&wire) {
                Some(st) => {
                    for seq in st.gaps() {
                        missing.push(MissingFrame { src: wsrc, tag, seq });
                    }
                    // A close is "arrived" if it awaits missing data
                    // (pending) or sits released-but-unconsumed in the
                    // queue; only a truly absent sentinel is reported.
                    let close_here = !st.pending_end.is_empty()
                        || st.queue.iter().any(|f| matches!(f, EngineFrame::Close));
                    if !close_here {
                        missing_closes.push(wsrc);
                    }
                }
                None => missing_closes.push(wsrc),
            }
        }
        drop(inbox);
        // A source with a buffered close was already consumed by the
        // release walk; prune the closes list down to sources the cursor
        // has not passed yet.
        let cur = self.ep.cursor.borrow().get(&wire).copied().unwrap_or(self.size());
        let passed: HashSet<usize> =
            self.group.members.iter().take(cur).copied().collect();
        missing_closes.retain(|s| !passed.contains(s));
        let missing_acks = if self.ep.fault.is_some() {
            let acks = self.ep.acks.borrow();
            let got = acks.get(&wire);
            self.group
                .members
                .iter()
                .copied()
                .filter(|m| !got.is_some_and(|g| g.contains(m)))
                .collect()
        } else {
            Vec::new()
        };
        let err = CommError {
            tag,
            timeout_ms: self.ep.timeout.as_millis() as u64,
            missing,
            missing_closes,
            missing_acks,
        };
        self.ep.n_timeouts.set(self.ep.n_timeouts.get() + 1);
        obs::metrics::add(obs::Subsys::Comm, "timeouts", 1);
        obs::instant(obs::Subsys::Comm, "comm.timeout", tag as u64);
        crate::log_error!("{err}");
        err
    }

    /// Nonblocking receive: whatever prefix of this epoch's canonical
    /// delivery order (source-rank major, send order within a source) has
    /// already arrived.  A source's payloads are only released once every
    /// lower-ranked source has closed its epoch — that restriction is
    /// what makes interleaved send/receive schedules bit-deterministic.
    pub fn try_recv_any(&self, tag: u32) -> Vec<(usize, Vec<u8>)> {
        let mut out = Vec::new();
        self.release_into(tag, None, &mut out)
            .expect("nonblocking release cannot time out");
        out
    }

    /// Close this rank's epoch on `tag` (collective over the tag): post
    /// the close sentinel to every member, then block until every
    /// member's sentinel has arrived, returning all not-yet-released
    /// payloads in canonical order.  After `drain` the tag is ready for a
    /// new epoch.  Ranks outside this communicator are not involved —
    /// the close barrier spans members only.
    pub fn drain(&self, tag: u32) -> Vec<(usize, Vec<u8>)> {
        match self.drain_checked(tag) {
            Ok(out) => out,
            Err(e) => panic!("{e}"),
        }
    }

    /// [`Comm::drain`] with the deadline surfaced: a permanent hang (lost
    /// frame that no retransmit can recover, missing close, missing ACK)
    /// returns a diagnostic [`CommError`] naming every missing
    /// `(src, tag, seq)` instead of blocking forever.  The deadline is
    /// `GPTAP_COMM_TIMEOUT_MS` (or [`World::with_comm_timeout`]).
    pub fn drain_checked(&self, tag: u32) -> Result<Vec<(usize, Vec<u8>)>, CommError> {
        for d in 0..self.size() {
            self.send_close(d, tag);
        }
        // The blocking release below is the epoch close barrier: time it
        // so barrier idle stops masquerading as communication time.  Two
        // clock reads per *epoch* (not per message), so it stays on even
        // when tracing is off.  The span guard is inert unless the tracer
        // or the metrics registry is armed (one TLS read), in which case
        // it records the barrier and/or feeds the "close_barrier"
        // histogram.
        let sp = obs::span(obs::Subsys::Comm, "close_barrier", tag as u64);
        let t0 = std::time::Instant::now();
        let deadline = t0 + self.ep.timeout;
        let mut out = Vec::new();
        let res = if self.ep.fault.is_some() {
            self.drain_reliable(tag, deadline, &mut out)
        } else {
            self.release_into(tag, Some(deadline), &mut out).map(|closed| {
                debug_assert!(closed, "blocking release must close the epoch");
            })
        };
        let us = t0.elapsed().as_micros() as u64;
        drop(sp);
        self.ep.total_close_waits.set(self.ep.total_close_waits.get() + 1);
        self.ep.total_close_wait_us.set(self.ep.total_close_wait_us.get() + us);
        let mut ch = self.ep.total_close_wait_hist.get();
        ch[lat_bucket(us)] += 1;
        self.ep.total_close_wait_hist.set(ch);
        res.map(|_| out)
    }

    /// Armed (fault-plan active) close barrier.  The unarmed barrier can
    /// block on the cursor source because FIFO channels guarantee its
    /// close will arrive; under faults a lower-ranked source may be
    /// waiting on a NACK retransmit *from us*, so blocking on one channel
    /// would deadlock.  Instead: poll every member channel round-robin,
    /// deliver whatever arrives, release in canonical order, and finish
    /// only once the epoch is closed **and** every member has ACKed our
    /// own stream — leaving earlier would orphan a peer's NACK for a
    /// frame only our retransmit buffer can supply.  Known gaps are
    /// re-NACKed while idling as cheap insurance (duplicate suppression
    /// makes repeats harmless); a gap with no retransmit copy anywhere
    /// (blackhole) runs into the deadline and surfaces as [`CommError`].
    fn drain_reliable(
        &self,
        tag: u32,
        deadline: Instant,
        out: &mut Vec<(usize, Vec<u8>)>,
    ) -> Result<(), CommError> {
        let wire = self.wire_tag(tag);
        let mut closed = false;
        let mut idle_rounds: u64 = 0;
        loop {
            // Service every member channel: NACKs, ACKs and retransmits
            // can arrive from any rank at any point in the barrier.
            let mut progress = false;
            for &wsrc in &self.group.members {
                loop {
                    match self.ep.rx[wsrc].try_recv() {
                        Ok(frame) => {
                            self.ep.deliver(wsrc, frame);
                            progress = true;
                        }
                        Err(TryRecvError::Empty) => break,
                        Err(TryRecvError::Disconnected) => panic!("peer rank panicked"),
                    }
                }
            }
            if !closed {
                let before = out.len();
                closed = self.release_into(tag, None, out)?;
                progress |= closed || out.len() > before;
            }
            if closed {
                let acked = {
                    let acks = self.ep.acks.borrow();
                    acks.get(&wire)
                        .is_some_and(|g| self.group.members.iter().all(|m| g.contains(m)))
                };
                if acked {
                    self.ep.acks.borrow_mut().remove(&wire);
                    if let Some(per) = self.ep.unacked.borrow_mut().get_mut(&wire) {
                        for buf in per.iter_mut() {
                            buf.clear();
                        }
                    }
                    return Ok(());
                }
            }
            if progress {
                idle_rounds = 0;
                continue;
            }
            idle_rounds += 1;
            if Instant::now() >= deadline {
                return Err(self.timeout_report(tag));
            }
            // Periodically re-request known gaps while idle.  Protocol
            // frames are never faulted, so one NACK round normally
            // suffices; this is cheap insurance against a NACK sent
            // before the sender buffered the copy, and duplicate
            // suppression makes repeats harmless.
            if idle_rounds % 64 == 0 {
                let mut renacks = Vec::new();
                {
                    let inbox = self.ep.inbox.borrow();
                    for &wsrc in &self.group.members {
                        if let Some(st) = inbox[wsrc].tags.get(&wire) {
                            for seq in st.gaps() {
                                renacks.push((wsrc, seq));
                            }
                        }
                    }
                }
                for (wsrc, seq) in renacks {
                    self.ep.send_nack(wsrc, wire, seq);
                }
            }
            if idle_rounds > 256 {
                std::thread::sleep(Duration::from_micros(50));
            } else {
                std::thread::yield_now();
            }
        }
    }

    /// Reliability-layer counters for this communicator's endpoint
    /// (shared across sub-communicators on the same rank): retransmits
    /// served, corrupt frames rejected, NACKs sent, duplicates
    /// suppressed, deadline hits, and total faults injected by an armed
    /// plan.  All zero on a clean run with an empty plan.
    pub fn reliability(&self) -> ReliabilityStats {
        ReliabilityStats {
            retransmits: self.ep.n_retransmits.get(),
            corrupt_frames: self.ep.n_corrupt.get(),
            nack_roundtrips: self.ep.n_nacks.get(),
            dup_suppressed: self.ep.n_dup_suppressed.get(),
            timeouts: self.ep.n_timeouts.get(),
            faults_injected: self.ep.fault.as_ref().map(|f| f.counts().total()).unwrap_or(0),
        }
    }

    /// Bulk epoch on an explicit tag: one `isend` per payload plus one
    /// `drain` — a one-epoch, zero-overlap use of the engine with the
    /// canonical delivery order (source rank, then send order within a
    /// source).  Every rank must call it collectively per epoch; empty
    /// `sends` are fine.
    pub fn exchange_on(&self, tag: u32, sends: Vec<(usize, Vec<u8>)>) -> Vec<(usize, Vec<u8>)> {
        for (dest, payload) in sends {
            self.isend(dest, tag, payload);
        }
        self.drain(tag)
    }

    /// Sparse all-to-all: deliver each `(dest, payload)` pair and return
    /// the `(source, payload)` pairs addressed to this rank, ordered by
    /// source rank (then send order within a source).  Every rank must
    /// call this the same number of times; empty `sends` are fine.
    ///
    /// Compatibility shim over [`Comm::exchange_on`] with identical
    /// delivery order and identical measured traffic to the historical
    /// bulk-synchronous collective.
    pub fn exchange(&self, sends: Vec<(usize, Vec<u8>)>) -> Vec<(usize, Vec<u8>)> {
        self.exchange_on(tag::EXCHANGE, sends)
    }

    /// Allgather of raw byte payloads (collective): returns one payload
    /// per member, indexed by member rank.
    pub fn allgather_bytes(&self, payload: Vec<u8>) -> Vec<Vec<u8>> {
        let others = self.size() as u64 - 1;
        self.count_send(others, payload.len() as u64);
        let frames: Vec<Vec<u8>> = (0..self.size()).map(|_| payload.clone()).collect();
        self.round(frames)
    }

    /// Allgather of one `u64` per rank (collective), indexed by rank.
    pub fn all_u64(&self, v: u64) -> Vec<u64> {
        let others = self.size() as u64 - 1;
        self.count_send(others, 8);
        let frames: Vec<Vec<u8>> = (0..self.size()).map(|_| v.to_le_bytes().to_vec()).collect();
        self.round(frames)
            .into_iter()
            .map(|f| u64::from_le_bytes(f[0..8].try_into().unwrap()))
            .collect()
    }

    /// Global sum of one `u64` per rank (collective).
    pub fn allreduce_sum_u64(&self, v: u64) -> u64 {
        self.all_u64(v).into_iter().sum()
    }

    /// Global sum of one `f64` per rank (collective).  Combines in rank
    /// order, so every rank computes the bit-identical result.
    pub fn allreduce_sum_f64(&self, v: f64) -> f64 {
        let others = self.size() as u64 - 1;
        self.count_send(others, 8);
        let frames: Vec<Vec<u8>> = (0..self.size()).map(|_| v.to_le_bytes().to_vec()).collect();
        self.round(frames)
            .into_iter()
            .map(|f| f64::from_le_bytes(f[0..8].try_into().unwrap()))
            .sum()
    }

    /// Global element-wise sum of `v.len()` `f64`s per rank (collective)
    /// in **one** message round: K partial sums ride a single payload, so
    /// a blocked solve pays one α per reduction instead of K.  Each
    /// element combines in rank order, so element `j` is bit-identical to
    /// a scalar [`Comm::allreduce_sum_f64`] of the ranks' `v[j]`s.
    pub fn allreduce_sum_f64_multi(&self, v: &[f64]) -> Vec<f64> {
        let others = self.size() as u64 - 1;
        self.count_send(others, (v.len() * 8) as u64);
        let mut payload = Vec::with_capacity(v.len() * 8);
        for x in v {
            payload.extend_from_slice(&x.to_le_bytes());
        }
        let frames: Vec<Vec<u8>> = (0..self.size()).map(|_| payload.clone()).collect();
        let mut out = vec![0.0f64; v.len()];
        for f in self.round(frames) {
            debug_assert_eq!(f.len(), v.len() * 8);
            for (j, slot) in out.iter_mut().enumerate() {
                *slot += f64::from_le_bytes(f[j * 8..j * 8 + 8].try_into().unwrap());
            }
        }
        out
    }
}

/// A set of `np` simulated ranks.
pub struct World {
    np: usize,
    fault_plan: Option<FaultPlan>,
    timeout: Duration,
}

impl World {
    /// A world with the ambient reliability configuration: the fault
    /// plan from `GPTAP_FAULT` (if set) and the comm deadline from
    /// `GPTAP_COMM_TIMEOUT_MS` (default 60 s).
    pub fn new(np: usize) -> World {
        assert!(np >= 1, "world needs at least one rank");
        World { np, fault_plan: FaultPlan::from_env(), timeout: comm_timeout_from_env() }
    }

    /// Override the fault plan (`None` disarms the reliability layer
    /// entirely, env notwithstanding).
    pub fn with_fault_plan(mut self, plan: Option<FaultPlan>) -> World {
        self.fault_plan = plan;
        self
    }

    /// Override the comm deadline used by `drain`/close barriers and
    /// collective receives.
    pub fn with_comm_timeout(mut self, timeout: Duration) -> World {
        self.timeout = timeout;
        self
    }

    pub fn size(&self) -> usize {
        self.np
    }

    /// Run `f` once per rank on its own thread and return the per-rank
    /// results ordered by rank.  Scoped threads: `f` may borrow from the
    /// caller.  A panic in any rank propagates (preferring the original
    /// panic over the "peer died" cascades it triggers in other ranks).
    pub fn run<T, F>(&self, f: F) -> Vec<T>
    where
        F: Fn(Comm) -> T + Send + Sync,
        T: Send,
    {
        let np = self.np;
        // full channel mesh: pair (s, d) has its own FIFO
        let mut txs: Vec<Vec<Option<Sender<Vec<u8>>>>> =
            (0..np).map(|_| (0..np).map(|_| None).collect()).collect();
        let mut rxs: Vec<Vec<Option<Receiver<Vec<u8>>>>> =
            (0..np).map(|_| (0..np).map(|_| None).collect()).collect();
        for (s, row) in txs.iter_mut().enumerate() {
            for (d, slot) in row.iter_mut().enumerate() {
                let (tx, rx) = channel();
                *slot = Some(tx);
                rxs[d][s] = Some(rx);
            }
        }
        // the Comm itself is single-threaded (Rc innards): ship the raw
        // channel halves to each thread and build the Comm there
        let parts: Vec<(usize, Vec<Sender<Vec<u8>>>, Vec<Receiver<Vec<u8>>>)> = txs
            .into_iter()
            .zip(rxs)
            .enumerate()
            .map(|(rank, (tx_row, rx_col))| {
                (
                    rank,
                    tx_row.into_iter().map(|t| t.unwrap()).collect(),
                    rx_col.into_iter().map(|r| r.unwrap()).collect(),
                )
            })
            .collect();

        let f_ref = &f;
        let plan_ref = &self.fault_plan;
        let timeout = self.timeout;
        let joined: Vec<std::thread::Result<T>> = std::thread::scope(|scope| {
            let handles: Vec<_> = parts
                .into_iter()
                .map(|(rank, tx, rx)| {
                    scope.spawn(move || {
                        crate::util::log::set_rank(rank);
                        f_ref(Comm::root(rank, np, tx, rx, plan_ref.clone(), timeout))
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join()).collect()
        });
        // prefer the original panic over "peer rank ..." cascades
        if joined.iter().any(|r| r.is_err()) {
            let is_cascade = |p: &(dyn std::any::Any + Send)| -> bool {
                let msg = p
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| p.downcast_ref::<String>().cloned())
                    .unwrap_or_default();
                msg.contains("peer rank")
            };
            let mut cascade = None;
            for r in joined {
                if let Err(p) = r {
                    if !is_cascade(p.as_ref()) {
                        std::panic::resume_unwind(p);
                    }
                    cascade.get_or_insert(p);
                }
            }
            std::panic::resume_unwind(cascade.unwrap());
        }
        joined.into_iter().map(|r| r.unwrap()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_ordered_by_rank() {
        let w = World::new(4);
        let out = w.run(|c| (c.rank(), c.size()));
        assert_eq!(out, vec![(0, 4), (1, 4), (2, 4), (3, 4)]);
    }

    #[test]
    fn exchange_routes_and_orders_by_source() {
        let w = World::new(3);
        let all = w.run(|c| {
            // every rank sends its id to every *other* rank
            let sends: Vec<(usize, Vec<u8>)> = (0..c.size())
                .filter(|&d| d != c.rank())
                .map(|d| (d, vec![c.rank() as u8]))
                .collect();
            c.exchange(sends)
        });
        for (me, inbox) in all.iter().enumerate() {
            let srcs: Vec<usize> = inbox.iter().map(|&(s, _)| s).collect();
            let want: Vec<usize> = (0..3).filter(|&s| s != me).collect();
            assert_eq!(srcs, want);
            for (s, p) in inbox {
                assert_eq!(p, &vec![*s as u8]);
            }
        }
    }

    #[test]
    fn exchange_supports_empty_and_multiple_payloads() {
        let w = World::new(2);
        let all = w.run(|c| {
            if c.rank() == 0 {
                c.exchange(vec![(1, vec![1]), (1, vec![2, 3])])
            } else {
                c.exchange(Vec::new())
            }
        });
        assert!(all[0].is_empty());
        assert_eq!(all[1], vec![(0, vec![1]), (0, vec![2, 3])]);
    }

    #[test]
    fn collectives_compose_over_many_rounds() {
        let w = World::new(3);
        let sums = w.run(|c| {
            let mut acc = 0u64;
            for round in 0..50u64 {
                acc += c.allreduce_sum_u64(round + c.rank() as u64);
            }
            acc
        });
        assert!(sums.iter().all(|&s| s == sums[0]));
    }

    #[test]
    fn allgather_indexed_by_rank() {
        let w = World::new(3);
        let all = w.run(|c| c.allgather_bytes(vec![c.rank() as u8 * 10]));
        for per_rank in all {
            assert_eq!(per_rank, vec![vec![0], vec![10], vec![20]]);
        }
    }

    #[test]
    fn reduce_f64_is_identical_on_all_ranks() {
        let w = World::new(4);
        let vals = w.run(|c| c.allreduce_sum_f64(0.1 * (c.rank() as f64 + 1.0)));
        assert!(vals.iter().all(|v| v.to_bits() == vals[0].to_bits()));
    }

    #[test]
    fn stats_count_remote_traffic_only() {
        let w = World::new(2);
        let stats = w.run(|c| {
            let _ = c.exchange(vec![(c.rank(), vec![9; 100]), ((c.rank() + 1) % 2, vec![7; 8])]);
            c.stats()
        });
        for s in stats {
            assert_eq!(s.msgs, 1);
            assert_eq!(s.bytes, 8);
        }
    }

    #[test]
    fn single_rank_world_loops_back() {
        let w = World::new(1);
        let out = w.run(|c| {
            let r = c.exchange(vec![(0, vec![42])]);
            assert_eq!(r, vec![(0, vec![42])]);
            assert_eq!(c.all_u64(7), vec![7]);
            c.allreduce_sum_u64(3)
        });
        assert_eq!(out, vec![3]);
    }

    #[test]
    fn isend_drain_matches_exchange_order() {
        let w = World::new(4);
        let all = w.run(|c| {
            // two payloads to every rank (self included), posted early
            for d in 0..c.size() {
                c.isend(d, tag::PTAP_NUM, vec![c.rank() as u8, 0]);
                c.isend(d, tag::PTAP_NUM, vec![c.rank() as u8, 1]);
            }
            c.drain(tag::PTAP_NUM)
        });
        for inbox in all {
            let want: Vec<(usize, Vec<u8>)> = (0..4)
                .flat_map(|s| [(s, vec![s as u8, 0]), (s, vec![s as u8, 1])])
                .collect();
            assert_eq!(inbox, want);
        }
    }

    #[test]
    fn epochs_reuse_a_tag() {
        let w = World::new(3);
        let all = w.run(|c| {
            let mut epochs = Vec::new();
            for e in 0..4u8 {
                let next = (c.rank() + 1) % c.size();
                c.isend(next, tag::GATHER, vec![e, c.rank() as u8]);
                epochs.push(c.drain(tag::GATHER));
            }
            epochs
        });
        for (me, epochs) in all.iter().enumerate() {
            let prev = (me + 3 - 1) % 3;
            for (e, inbox) in epochs.iter().enumerate() {
                assert_eq!(inbox, &vec![(prev, vec![e as u8, prev as u8])]);
            }
        }
    }

    #[test]
    fn try_recv_then_drain_release_canonical_prefix_and_rest() {
        let w = World::new(3);
        let all = w.run(|c| {
            for d in 0..c.size() {
                c.isend(d, tag::PTAP_SYM, vec![c.rank() as u8]);
            }
            // poll a few times mid-"compute"; releases are a prefix of the
            // canonical order, the drain returns the rest
            let mut got = Vec::new();
            for _ in 0..10 {
                got.extend(c.try_recv_any(tag::PTAP_SYM));
            }
            got.extend(c.drain(tag::PTAP_SYM));
            got
        });
        for inbox in all {
            let want: Vec<(usize, Vec<u8>)> = (0..3).map(|s| (s, vec![s as u8])).collect();
            assert_eq!(inbox, want);
        }
    }

    #[test]
    fn engine_traffic_interleaves_with_collectives() {
        let w = World::new(3);
        let all = w.run(|c| {
            // post engine payloads, run collectives on top of the open
            // epoch, then close it — the inbox must demux both streams
            for d in 0..c.size() {
                c.isend(d, tag::PTAP_NUM, vec![7; c.rank() + 1]);
            }
            let total = c.allreduce_sum_u64(c.rank() as u64 + 1);
            let gathered = c.all_u64(10 + c.rank() as u64);
            let drained = c.drain(tag::PTAP_NUM);
            (total, gathered, drained)
        });
        for (total, gathered, drained) in all {
            assert_eq!(total, 6);
            assert_eq!(gathered, vec![10, 11, 12]);
            let want: Vec<(usize, Vec<u8>)> = (0..3).map(|s| (s, vec![7; s + 1])).collect();
            assert_eq!(drained, want);
        }
    }

    #[test]
    fn isend_counts_remote_payload_bytes_only() {
        let w = World::new(2);
        let stats = w.run(|c| {
            c.isend(c.rank(), tag::PTAP_NUM, vec![1; 64]); // self: uncounted
            c.isend((c.rank() + 1) % 2, tag::PTAP_NUM, vec![2; 10]);
            let _ = c.drain(tag::PTAP_NUM); // close sentinels: uncounted
            c.stats()
        });
        for s in stats {
            assert_eq!(s.msgs, 1);
            assert_eq!(s.bytes, 10);
        }
    }

    #[test]
    fn size_histogram_tracks_chunk_distribution() {
        let w = World::new(2);
        let stats = w.run(|c| {
            let peer = 1 - c.rank();
            c.isend(peer, tag::PTAP_NUM, vec![0; 10]); // bucket 0 (<64)
            c.isend(peer, tag::PTAP_NUM, vec![0; 10]);
            c.isend(peer, tag::PTAP_NUM, vec![0; 100_000]); // bucket 6 (<256K)
            let _ = c.drain(tag::PTAP_NUM);
            c.stats()
        });
        for s in stats {
            assert_eq!(s.msgs, 3);
            assert_eq!(s.hist[0], 2);
            assert_eq!(s.hist[6], 1);
            assert_eq!(s.hist.iter().sum::<u64>(), s.msgs);
            // calibrated α: the two tiny chunks amortize their latency, so
            // the calibrated term sits strictly below fixed α·msgs while
            // the bulk message still pays (nearly) full α
            let fixed_alpha = s.msgs as f64 * COMM_ALPHA_SECS;
            let cal = s.alpha_secs_calibrated();
            assert!(cal < fixed_alpha, "calibrated {cal} !< fixed {fixed_alpha}");
            assert!(cal > 0.9 * COMM_ALPHA_SECS, "bulk message must keep its α: {cal}");
        }
    }

    #[test]
    fn close_barrier_waits_are_accounted() {
        let w = World::new(2);
        let stats = w.run(|c| {
            let _ = c.drain(tag::PTAP_NUM);
            let _ = c.drain(tag::PTAP_SYM);
            c.stats_global()
        });
        for s in stats {
            assert_eq!(s.close_waits, 2, "one close barrier per drained epoch");
            // untraced frames carry no stamp: no flights observed
            assert_eq!(s.flight_msgs, 0);
            assert_eq!(s.flight_us, 0);
        }
    }

    #[test]
    fn stamped_frames_record_in_flight_time() {
        let w = World::new(2);
        let out = w.run(|c| {
            crate::obs::rank_begin(c.rank());
            let peer = 1 - c.rank();
            c.isend(peer, tag::PTAP_NUM, vec![5; 32]);
            c.isend(c.rank(), tag::PTAP_NUM, vec![6; 32]); // self: no flight
            let got = c.drain(tag::PTAP_NUM);
            let stats = c.stats_global();
            let buf = crate::obs::rank_take();
            (got.len(), stats, buf)
        });
        for (ngot, s, buf) in out {
            assert_eq!(ngot, 2);
            assert_eq!(s.flight_msgs, 1, "only the stamped remote frame counts");
            assert_eq!(s.flight_hist.iter().sum::<u64>(), 1);
            let flights = buf
                .events
                .iter()
                .filter(|e| matches!(e, crate::obs::Ev::Flight { .. }))
                .count();
            assert_eq!(flights, 1, "receiver records one flight event");
            let barriers = buf
                .events
                .iter()
                .filter(|e| {
                    matches!(e, crate::obs::Ev::Begin { name: "close_barrier", .. })
                })
                .count();
            assert_eq!(barriers, 1, "the drain records its close-barrier span");
        }
    }

    #[test]
    fn split_scopes_ranks_and_collectives() {
        let w = World::new(5);
        let out = w.run(|c| {
            // colors: {0,1,2} and {3,4}
            let color = usize::from(c.rank() >= 3);
            let sub = c.split(color);
            let sum = sub.allreduce_sum_u64(c.rank() as u64);
            (sub.rank(), sub.size(), sum)
        });
        assert_eq!(out[0], (0, 3, 3)); // 0+1+2
        assert_eq!(out[1], (1, 3, 3));
        assert_eq!(out[2], (2, 3, 3));
        assert_eq!(out[3], (0, 2, 7)); // 3+4
        assert_eq!(out[4], (1, 2, 7));
    }

    #[test]
    fn split_scopes_epochs_to_members_only() {
        // the active group runs several engine epochs while the idle
        // ranks never touch the tag — the close barrier spans members
        // only, so this would deadlock if idle ranks were required
        let w = World::new(4);
        let out = w.run(|c| {
            let active = c.rank() < 2;
            let sub = c.split(usize::from(!active));
            let mut got = Vec::new();
            if active {
                for e in 0..3u8 {
                    let peer = 1 - sub.rank();
                    sub.isend(peer, tag::GATHER, vec![e, sub.rank() as u8]);
                    got.extend(sub.drain(tag::GATHER));
                }
            }
            // everyone rejoins a world collective afterwards
            let total = c.allreduce_sum_u64(1);
            (got, total)
        });
        for (me, (got, total)) in out.iter().enumerate() {
            assert_eq!(*total, 4);
            if me < 2 {
                let peer = 1 - me;
                let want: Vec<(usize, Vec<u8>)> =
                    (0..3u8).map(|e| (peer, vec![e, peer as u8])).collect();
                assert_eq!(got, &want);
            } else {
                assert!(got.is_empty());
            }
        }
    }

    #[test]
    fn split_tags_do_not_cross_communicators() {
        // parent and child post on the same user tag concurrently; the
        // tag_base offset keeps the epochs apart
        let w = World::new(2);
        let out = w.run(|c| {
            let sub = c.split(0); // same members, new tag scope
            c.isend(1 - c.rank(), tag::GATHER, vec![1]);
            sub.isend(1 - sub.rank(), tag::GATHER, vec![2]);
            let parent = c.drain(tag::GATHER);
            let child = sub.drain(tag::GATHER);
            (parent, child)
        });
        for (me, (parent, child)) in out.iter().enumerate() {
            assert_eq!(parent, &vec![(1 - me, vec![1])]);
            assert_eq!(child, &vec![(1 - me, vec![2])]);
        }
    }

    #[test]
    fn split_stats_are_scoped_and_totals_global() {
        let w = World::new(4);
        let out = w.run(|c| {
            let sub = c.split(usize::from(c.rank() >= 2));
            let pre = c.stats().msgs;
            let _ = sub.exchange(vec![(1 - sub.rank(), vec![0; 16])]);
            (c.stats().msgs - pre, sub.stats(), c.stats_global())
        });
        for (parent_delta, sub_stats, global) in out {
            assert_eq!(parent_delta, 0, "subcomm traffic must not count in the parent scope");
            assert_eq!(sub_stats.msgs, 1);
            assert_eq!(sub_stats.bytes, 16);
            assert!(global.msgs >= sub_stats.msgs, "global totals include subcomm traffic");
        }
    }

    #[test]
    fn nested_split_scopes_compose() {
        let w = World::new(4);
        let out = w.run(|c| {
            let half = c.split(usize::from(c.rank() >= 2)); // {0,1} {2,3}
            let solo = half.split(half.rank()); // singletons
            let r = solo.exchange(vec![(0, vec![c.rank() as u8])]);
            (half.size(), solo.size(), r)
        });
        for (me, (hs, ss, r)) in out.iter().enumerate() {
            assert_eq!(*hs, 2);
            assert_eq!(*ss, 1);
            assert_eq!(r, &vec![(0, vec![me as u8])]);
        }
    }

    /// Telescoping regression (2 split boundaries): close-wait and flight
    /// histograms recorded under subcommunicators keep aggregating
    /// rank-wide through `stats_global()`, with totals matching the
    /// scalar counters; scoped `stats()` snapshots still carry zeros.
    #[test]
    fn telescoped_close_wait_and_flight_hists_aggregate_globally() {
        let w = World::new(4);
        let out = w.run(|c| {
            obs::rank_begin(c.rank()); // stamp frames so flights are observed
            let _ = c.exchange(vec![((c.rank() + 1) % c.size(), vec![1u8; 64])]);
            let half = c.split(usize::from(c.rank() >= 2)); // boundary 1: {0,1} {2,3}
            let _ = half.exchange(vec![(1 - half.rank(), vec![2u8; 256])]);
            let solo = half.split(half.rank()); // boundary 2: singletons
            let _ = solo.drain(tag::EXCHANGE);
            let _ = obs::rank_take();
            (c.stats(), half.stats(), c.stats_global())
        });
        for (scoped, half_scoped, global) in out {
            // Scoped snapshots carry no rank-wide barrier/flight fields.
            assert_eq!(scoped.close_waits + half_scoped.close_waits, 0);
            assert_eq!(scoped.close_wait_hist.iter().sum::<u64>(), 0);
            assert_eq!(half_scoped.close_wait_hist.iter().sum::<u64>(), 0);
            // Global totals fold every boundary: world exchange + half
            // exchange + singleton drain = 3 close barriers.
            assert_eq!(global.close_waits, 3);
            assert_eq!(
                global.close_wait_hist.iter().sum::<u64>(),
                global.close_waits,
                "every close barrier lands in exactly one latency bucket"
            );
            // One stamped world frame + one stamped subcomm frame arrived
            // at each rank; both flights land in the global histogram.
            assert_eq!(global.flight_msgs, 2);
            assert_eq!(global.flight_hist.iter().sum::<u64>(), global.flight_msgs);
            // The histograms ride through since() and merge().
            let delta = global.since(CommStats::default());
            assert_eq!(delta.close_wait_hist, global.close_wait_hist);
            let mut acc = CommStats::default();
            acc.merge(global);
            acc.merge(global);
            assert_eq!(acc.close_wait_hist.iter().sum::<u64>(), 2 * global.close_waits);
        }
    }

    /// Multi-epoch all-to-all under an optional fault plan: every rank's
    /// released (source, payload) stream plus its reliability counters.
    fn chaotic_exchange(
        np: usize,
        plan: Option<FaultPlan>,
    ) -> (Vec<Vec<(usize, Vec<u8>)>>, Vec<ReliabilityStats>) {
        let w = World::new(np)
            .with_fault_plan(plan)
            .with_comm_timeout(Duration::from_secs(20));
        let out = w.run(|c| {
            let mut got = Vec::new();
            for epoch in 0..3u8 {
                for d in 0..c.size() {
                    for k in 0..4u8 {
                        c.isend(d, tag::PTAP_NUM, vec![c.rank() as u8, epoch, k, 0xAB]);
                    }
                }
                got.extend(c.drain(tag::PTAP_NUM));
            }
            (got, c.reliability())
        });
        out.into_iter().unzip()
    }

    /// The reliability tentpole in one assertion: under drop, corruption,
    /// delay/reorder, duplication and a transient stall, every rank
    /// releases the byte-identical stream a fault-free run releases.
    #[test]
    fn reliable_transport_is_bitwise_under_every_fault_kind() {
        let (clean, base) = chaotic_exchange(3, None);
        for s in &base {
            assert_eq!(s.retransmits + s.nack_roundtrips + s.dup_suppressed, 0);
            assert_eq!(s.faults_injected, 0);
        }
        for spec in [
            "seed=11;tag=*,drop=0.4",
            "seed=12;tag=*,corrupt=0.4",
            "seed=13;tag=*,delay=0.5,hold=2",
            "seed=14;tag=*,dup=0.5",
            "seed=15;rank=1,tag=*,stall_ms=1,nth=2",
            "seed=16;tag=*,drop=0.2;tag=*,corrupt=0.2;tag=*,dup=0.2;tag=*,delay=0.3,hold=3",
        ] {
            let plan = FaultPlan::parse(spec).unwrap();
            let (got, stats) = chaotic_exchange(3, Some(plan));
            assert_eq!(got, clean, "delivered bits changed under fault plan '{spec}'");
            let injected: u64 = stats.iter().map(|s| s.faults_injected).sum();
            assert!(injected > 0, "plan '{spec}' never fired at these probabilities");
            assert_eq!(
                stats.iter().map(|s| s.timeouts).sum::<u64>(),
                0,
                "recoverable faults must not hit the deadline"
            );
        }
    }

    #[test]
    fn recovery_counters_attribute_the_fault_kind() {
        let specs: [(&str, fn(&ReliabilityStats) -> u64); 3] = [
            ("seed=21;tag=*,drop=0.5", |s| s.retransmits),
            ("seed=22;tag=*,corrupt=0.5", |s| s.corrupt_frames),
            ("seed=23;tag=*,dup=0.5", |s| s.dup_suppressed),
        ];
        for (spec, counter) in specs {
            let plan = FaultPlan::parse(spec).unwrap();
            let (_, stats) = chaotic_exchange(2, Some(plan));
            let hits: u64 = stats.iter().map(counter).sum();
            assert!(hits > 0, "plan '{spec}' should trip its recovery counter");
        }
    }

    /// An empty plan arms the protocol (checksums, ACK barriers) but
    /// injects nothing: all recovery counters must stay zero.
    #[test]
    fn empty_plan_arms_cleanly_with_zero_recovery_counters() {
        let (clean, _) = chaotic_exchange(3, None);
        let (got, stats) = chaotic_exchange(3, Some(FaultPlan::empty(99)));
        assert_eq!(got, clean);
        for s in stats {
            assert_eq!(s.retransmits, 0);
            assert_eq!(s.corrupt_frames, 0);
            assert_eq!(s.nack_roundtrips, 0);
            assert_eq!(s.dup_suppressed, 0);
            assert_eq!(s.timeouts, 0);
            assert_eq!(s.faults_injected, 0);
        }
    }

    /// Satellite regression: a blackholed (dropped, never retransmitted)
    /// frame must surface as a diagnostic `CommError` naming the missing
    /// (src, tag, seq) on the receiver — and a missing ACK on the sender
    /// — instead of hanging the drain forever.
    #[test]
    fn blackhole_times_out_with_named_missing_frame() {
        let plan = FaultPlan::parse("seed=5;rank=0,tag=*,blackhole=1.0").unwrap();
        let w = World::new(2)
            .with_fault_plan(Some(plan))
            .with_comm_timeout(Duration::from_millis(250));
        let outcomes = w.run(|c| {
            if c.rank() == 0 {
                c.isend(1, tag::PTAP_SYM, vec![0xEE; 16]);
            }
            let res = c.drain_checked(tag::PTAP_SYM);
            (c.rank(), res)
        });
        for (rank, res) in outcomes {
            let err = res.expect_err("the blackholed frame is unrecoverable");
            assert_eq!(err.tag, tag::PTAP_SYM);
            if rank == 1 {
                assert_eq!(
                    err.missing,
                    vec![MissingFrame { src: 0, tag: tag::PTAP_SYM, seq: 0 }],
                    "receiver must name the exact missing frame"
                );
                let text = err.to_string();
                assert!(text.contains("src=0") && text.contains("seq=0"), "got: {text}");
            } else {
                assert!(
                    err.missing_acks.contains(&1),
                    "sender must report the peer that never ACKed: {err}"
                );
            }
        }
    }

    /// The deadline also covers the fault-free blocking path: a plain
    /// drain with a peer that never closes must return, not hang.
    #[test]
    fn unarmed_drain_deadline_reports_missing_close() {
        let w = World::new(2).with_comm_timeout(Duration::from_millis(200));
        let outcomes = w.run(|c| {
            if c.rank() == 0 {
                // Rank 0 never opens/closes the epoch; rank 1 drains.
                // Park in a collective afterwards so the world stays up
                // while rank 1 waits out its deadline.
                let _ = c.all_u64(0);
                (c.rank(), None)
            } else {
                let res = c.drain_checked(tag::REDIST);
                let _ = c.all_u64(0);
                (c.rank(), Some(res))
            }
        });
        for (rank, res) in outcomes {
            if rank == 1 {
                let err = res.unwrap().expect_err("no close can ever arrive");
                assert!(
                    err.missing_closes.contains(&0),
                    "must name the member whose close is missing: {err}"
                );
            }
        }
    }
}
