//! Simulated MPI: a thread-per-rank world with a nonblocking,
//! tag-addressed communication engine underneath deterministic
//! collectives.
//!
//! [`World::run`] spawns one OS thread per rank and hands each a [`Comm`].
//! Communication runs over a full mesh of FIFO channels — one per ordered
//! rank pair.  Every frame on the wire carries a one-byte kind:
//!
//! - **collective** frames belong to the barrier-style collectives
//!   (`allgather_bytes`, `all_u64`, `allreduce_sum_*`), which still move
//!   exactly one frame per pair per call;
//! - **data** frames carry an epoch's point-to-point payloads for one
//!   `tag` ([`Comm::isend`] posts them immediately and returns);
//! - **close** frames are the epoch sentinels: a rank's promise that it
//!   will send no more data for that tag this epoch ([`Comm::drain`]
//!   posts one to every rank, then blocks until it has one from every
//!   rank).
//!
//! A per-source inbox demultiplexes the shared FIFO: frames that arrive
//! "early" (an engine payload while a peer is inside a collective, or
//! vice versa) are buffered per (source, tag) and consumed by whichever
//! call they belong to, so the SPMD call discipline never deadlocks and
//! never sees another epoch's traffic.
//!
//! Determinism: payloads are *released* to the consumer in source-rank
//! order — [`Comm::try_recv_any`] hands out the longest prefix of the
//! canonical order (all of rank 0's payloads in send order, then rank
//! 1's, ...) that has already arrived and closed, and [`Comm::drain`]
//! blocks for the rest — so interleaving sends with receives cannot
//! reorder anything relative to the bulk-synchronous [`Comm::exchange`]
//! shim, and repeated runs of a world reproduce byte-identical messages.
//! Reductions combine in rank order, so every rank computes bit-identical
//! global values.

use std::cell::{Cell, RefCell};
use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};

/// α (per-message latency) of the α-β communication model, seconds.
/// Tuned to a commodity cluster interconnect (DESIGN.md §7).
pub const COMM_ALPHA_SECS: f64 = 2.0e-6;

/// β (per-byte) of the α-β communication model, seconds/byte (~2 GB/s).
pub const COMM_BETA_SECS_PER_BYTE: f64 = 5.0e-10;

/// Reserved engine tags.  A tag names one logical stream of epochs; all
/// ranks must open and close epochs on a tag in the same global order
/// (the usual SPMD discipline), and a consumer must close its epoch
/// (`drain`) before any other consumer opens one on the same tag.
pub mod tag {
    /// The bulk-synchronous [`super::Comm::exchange`] compatibility shim.
    pub const EXCHANGE: u32 = 0;
    /// Gather-plan request/response traffic (`dist::gather`).
    pub const GATHER: u32 = 1;
    /// Triple-product symbolic-phase scatter (`ptap`).
    pub const PTAP_SYM: u32 = 2;
    /// Triple-product numeric-phase scatter (`ptap`).
    pub const PTAP_NUM: u32 = 3;
}

const FRAME_COLL: u8 = 0;
const FRAME_DATA: u8 = 1;
const FRAME_CLOSE: u8 = 2;

/// Snapshot of one rank's cumulative send-side traffic.
#[derive(Debug, Default, Clone, Copy)]
pub struct CommStats {
    /// Point-to-point messages sent to other ranks.
    pub msgs: u64,
    /// Payload bytes sent to other ranks.
    pub bytes: u64,
}

impl CommStats {
    /// The α-β model applied to this rank's traffic.
    pub fn modeled_secs(&self) -> f64 {
        self.msgs as f64 * COMM_ALPHA_SECS + self.bytes as f64 * COMM_BETA_SECS_PER_BYTE
    }
}

/// One buffered engine frame: a payload, or the epoch-close sentinel.
enum EngineFrame {
    Data(Vec<u8>),
    Close,
}

/// Demultiplexed arrivals from one source rank.
#[derive(Default)]
struct SourceInbox {
    /// Collective frames, in arrival (= send) order.
    coll: VecDeque<Vec<u8>>,
    /// Engine frames per tag, in arrival order; `Close` entries delimit
    /// epochs.
    tags: HashMap<u32, VecDeque<EngineFrame>>,
}

/// One rank's endpoint of the simulated communicator.
pub struct Comm {
    rank: usize,
    np: usize,
    /// `tx[d]` sends one frame to rank `d` (index `rank` loops back).
    tx: Vec<Sender<Vec<u8>>>,
    /// `rx[s]` receives frames sent by rank `s`.
    rx: Vec<Receiver<Vec<u8>>>,
    sent_msgs: Cell<u64>,
    sent_bytes: Cell<u64>,
    /// Early arrivals, demultiplexed per source.
    inbox: RefCell<Vec<SourceInbox>>,
    /// Per-tag release cursor: the next source rank whose current-epoch
    /// payloads have not been fully released yet (absent = 0).
    cursor: RefCell<HashMap<u32, usize>>,
}

impl Comm {
    /// This rank's id, `0..size()`.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Number of ranks in the world.
    pub fn size(&self) -> usize {
        self.np
    }

    /// Cumulative send-side traffic of this rank (payload bytes; engine
    /// framing and close sentinels are protocol overhead and uncounted,
    /// exactly as the one-frame-per-pair barrier was).
    pub fn stats(&self) -> CommStats {
        CommStats { msgs: self.sent_msgs.get(), bytes: self.sent_bytes.get() }
    }

    /// Route an arrived frame into the per-source inbox.
    fn deliver(&self, src: usize, frame: Vec<u8>) {
        let mut inbox = self.inbox.borrow_mut();
        let slot = &mut inbox[src];
        match frame[0] {
            FRAME_COLL => slot.coll.push_back(frame[1..].to_vec()),
            FRAME_DATA => {
                let t = u32::from_le_bytes(frame[1..5].try_into().unwrap());
                slot.tags.entry(t).or_default().push_back(EngineFrame::Data(frame[5..].to_vec()));
            }
            FRAME_CLOSE => {
                let t = u32::from_le_bytes(frame[1..5].try_into().unwrap());
                slot.tags.entry(t).or_default().push_back(EngineFrame::Close);
            }
            k => unreachable!("bad frame kind {k}"),
        }
    }

    /// Next collective frame from `src`, demuxing engine frames aside.
    fn recv_collective(&self, src: usize) -> Vec<u8> {
        loop {
            let buffered = self.inbox.borrow_mut()[src].coll.pop_front();
            if let Some(f) = buffered {
                return f;
            }
            let frame = self.rx[src].recv().expect("peer rank panicked");
            self.deliver(src, frame);
        }
    }

    /// One collective round: every rank sends exactly one frame to every
    /// rank (self included) and receives one frame from every rank.
    fn round(&self, frames: Vec<Vec<u8>>) -> Vec<Vec<u8>> {
        debug_assert_eq!(frames.len(), self.np);
        for (d, frame) in frames.into_iter().enumerate() {
            let mut f = Vec::with_capacity(1 + frame.len());
            f.push(FRAME_COLL);
            f.extend_from_slice(&frame);
            self.tx[d].send(f).expect("peer rank terminated early");
        }
        (0..self.np).map(|s| self.recv_collective(s)).collect()
    }

    /// Post `payload` to `dest` under `tag` and return immediately (the
    /// nonblocking send).  Payloads are delivered in send order per
    /// (source, tag) pair; `dest == rank()` loops back.
    pub fn isend(&self, dest: usize, tag: u32, payload: Vec<u8>) {
        if dest != self.rank {
            self.sent_msgs.set(self.sent_msgs.get() + 1);
            self.sent_bytes.set(self.sent_bytes.get() + payload.len() as u64);
        }
        let mut f = Vec::with_capacity(5 + payload.len());
        f.push(FRAME_DATA);
        f.extend_from_slice(&tag.to_le_bytes());
        f.extend_from_slice(&payload);
        self.tx[dest].send(f).expect("peer rank terminated early");
    }

    fn send_close(&self, dest: usize, tag: u32) {
        let mut f = Vec::with_capacity(5);
        f.push(FRAME_CLOSE);
        f.extend_from_slice(&tag.to_le_bytes());
        self.tx[dest].send(f).expect("peer rank terminated early");
    }

    /// Release loop shared by [`Comm::try_recv_any`] and [`Comm::drain`]:
    /// walk sources in rank order from the tag's cursor, handing out data
    /// frames until the epoch closes (every source's `Close` consumed) or
    /// — nonblocking — until the cursor source has nothing buffered.
    /// Returns whether the epoch fully closed (and resets the cursor).
    fn release_into(&self, tag: u32, blocking: bool, out: &mut Vec<(usize, Vec<u8>)>) -> bool {
        let mut cur = self.cursor.borrow_mut().remove(&tag).unwrap_or(0);
        'sources: while cur < self.np {
            loop {
                let next = self.inbox.borrow_mut()[cur]
                    .tags
                    .get_mut(&tag)
                    .and_then(|q| q.pop_front());
                match next {
                    Some(EngineFrame::Data(p)) => {
                        out.push((cur, p));
                        continue;
                    }
                    Some(EngineFrame::Close) => {
                        cur += 1;
                        continue 'sources;
                    }
                    None => {}
                }
                if blocking {
                    let frame = self.rx[cur].recv().expect("peer rank panicked");
                    self.deliver(cur, frame);
                } else {
                    match self.rx[cur].try_recv() {
                        Ok(frame) => self.deliver(cur, frame),
                        Err(TryRecvError::Empty) => break 'sources,
                        Err(TryRecvError::Disconnected) => panic!("peer rank panicked"),
                    }
                }
            }
        }
        if cur >= self.np {
            true
        } else {
            self.cursor.borrow_mut().insert(tag, cur);
            false
        }
    }

    /// Nonblocking receive: whatever prefix of this epoch's canonical
    /// delivery order (source-rank major, send order within a source) has
    /// already arrived.  A source's payloads are only released once every
    /// lower-ranked source has closed its epoch — that restriction is
    /// what makes interleaved send/receive schedules bit-deterministic.
    pub fn try_recv_any(&self, tag: u32) -> Vec<(usize, Vec<u8>)> {
        let mut out = Vec::new();
        self.release_into(tag, false, &mut out);
        out
    }

    /// Close this rank's epoch on `tag` (collective over the tag): post
    /// the close sentinel to every rank, then block until every rank's
    /// sentinel has arrived, returning all not-yet-released payloads in
    /// canonical order.  After `drain` the tag is ready for a new epoch.
    pub fn drain(&self, tag: u32) -> Vec<(usize, Vec<u8>)> {
        for d in 0..self.np {
            self.send_close(d, tag);
        }
        let mut out = Vec::new();
        let closed = self.release_into(tag, true, &mut out);
        debug_assert!(closed, "blocking release must close the epoch");
        out
    }

    /// Bulk epoch on an explicit tag: one `isend` per payload plus one
    /// `drain` — a one-epoch, zero-overlap use of the engine with the
    /// canonical delivery order (source rank, then send order within a
    /// source).  Every rank must call it collectively per epoch; empty
    /// `sends` are fine.
    pub fn exchange_on(&self, tag: u32, sends: Vec<(usize, Vec<u8>)>) -> Vec<(usize, Vec<u8>)> {
        for (dest, payload) in sends {
            self.isend(dest, tag, payload);
        }
        self.drain(tag)
    }

    /// Sparse all-to-all: deliver each `(dest, payload)` pair and return
    /// the `(source, payload)` pairs addressed to this rank, ordered by
    /// source rank (then send order within a source).  Every rank must
    /// call this the same number of times; empty `sends` are fine.
    ///
    /// Compatibility shim over [`Comm::exchange_on`] with identical
    /// delivery order and identical measured traffic to the historical
    /// bulk-synchronous collective.
    pub fn exchange(&self, sends: Vec<(usize, Vec<u8>)>) -> Vec<(usize, Vec<u8>)> {
        self.exchange_on(tag::EXCHANGE, sends)
    }

    /// Allgather of raw byte payloads (collective): returns one payload
    /// per rank, indexed by rank.
    pub fn allgather_bytes(&self, payload: Vec<u8>) -> Vec<Vec<u8>> {
        self.sent_msgs.set(self.sent_msgs.get() + (self.np as u64 - 1));
        self.sent_bytes
            .set(self.sent_bytes.get() + (self.np as u64 - 1) * payload.len() as u64);
        let frames: Vec<Vec<u8>> = (0..self.np).map(|_| payload.clone()).collect();
        self.round(frames)
    }

    /// Allgather of one `u64` per rank (collective), indexed by rank.
    pub fn all_u64(&self, v: u64) -> Vec<u64> {
        self.sent_msgs.set(self.sent_msgs.get() + (self.np as u64 - 1));
        self.sent_bytes.set(self.sent_bytes.get() + (self.np as u64 - 1) * 8);
        let frames: Vec<Vec<u8>> = (0..self.np).map(|_| v.to_le_bytes().to_vec()).collect();
        self.round(frames)
            .into_iter()
            .map(|f| u64::from_le_bytes(f[0..8].try_into().unwrap()))
            .collect()
    }

    /// Global sum of one `u64` per rank (collective).
    pub fn allreduce_sum_u64(&self, v: u64) -> u64 {
        self.all_u64(v).into_iter().sum()
    }

    /// Global sum of one `f64` per rank (collective).  Combines in rank
    /// order, so every rank computes the bit-identical result.
    pub fn allreduce_sum_f64(&self, v: f64) -> f64 {
        self.sent_msgs.set(self.sent_msgs.get() + (self.np as u64 - 1));
        self.sent_bytes.set(self.sent_bytes.get() + (self.np as u64 - 1) * 8);
        let frames: Vec<Vec<u8>> = (0..self.np).map(|_| v.to_le_bytes().to_vec()).collect();
        self.round(frames)
            .into_iter()
            .map(|f| f64::from_le_bytes(f[0..8].try_into().unwrap()))
            .sum()
    }
}

/// A set of `np` simulated ranks.
pub struct World {
    np: usize,
}

impl World {
    pub fn new(np: usize) -> World {
        assert!(np >= 1, "world needs at least one rank");
        World { np }
    }

    pub fn size(&self) -> usize {
        self.np
    }

    /// Run `f` once per rank on its own thread and return the per-rank
    /// results ordered by rank.  Scoped threads: `f` may borrow from the
    /// caller.  A panic in any rank propagates (preferring the original
    /// panic over the "peer died" cascades it triggers in other ranks).
    pub fn run<T, F>(&self, f: F) -> Vec<T>
    where
        F: Fn(Comm) -> T + Send + Sync,
        T: Send,
    {
        let np = self.np;
        // full channel mesh: pair (s, d) has its own FIFO
        let mut txs: Vec<Vec<Option<Sender<Vec<u8>>>>> =
            (0..np).map(|_| (0..np).map(|_| None).collect()).collect();
        let mut rxs: Vec<Vec<Option<Receiver<Vec<u8>>>>> =
            (0..np).map(|_| (0..np).map(|_| None).collect()).collect();
        for (s, row) in txs.iter_mut().enumerate() {
            for (d, slot) in row.iter_mut().enumerate() {
                let (tx, rx) = channel();
                *slot = Some(tx);
                rxs[d][s] = Some(rx);
            }
        }
        let comms: Vec<Comm> = txs
            .into_iter()
            .zip(rxs)
            .enumerate()
            .map(|(rank, (tx_row, rx_col))| Comm {
                rank,
                np,
                tx: tx_row.into_iter().map(|t| t.unwrap()).collect(),
                rx: rx_col.into_iter().map(|r| r.unwrap()).collect(),
                sent_msgs: Cell::new(0),
                sent_bytes: Cell::new(0),
                inbox: RefCell::new((0..np).map(|_| SourceInbox::default()).collect()),
                cursor: RefCell::new(HashMap::new()),
            })
            .collect();

        let f_ref = &f;
        let joined: Vec<std::thread::Result<T>> = std::thread::scope(|scope| {
            let handles: Vec<_> = comms
                .into_iter()
                .map(|comm| scope.spawn(move || f_ref(comm)))
                .collect();
            handles.into_iter().map(|h| h.join()).collect()
        });
        // prefer the original panic over "peer rank ..." cascades
        if joined.iter().any(|r| r.is_err()) {
            let is_cascade = |p: &(dyn std::any::Any + Send)| -> bool {
                let msg = p
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| p.downcast_ref::<String>().cloned())
                    .unwrap_or_default();
                msg.contains("peer rank")
            };
            let mut cascade = None;
            for r in joined {
                if let Err(p) = r {
                    if !is_cascade(p.as_ref()) {
                        std::panic::resume_unwind(p);
                    }
                    cascade.get_or_insert(p);
                }
            }
            std::panic::resume_unwind(cascade.unwrap());
        }
        joined.into_iter().map(|r| r.unwrap()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_ordered_by_rank() {
        let w = World::new(4);
        let out = w.run(|c| (c.rank(), c.size()));
        assert_eq!(out, vec![(0, 4), (1, 4), (2, 4), (3, 4)]);
    }

    #[test]
    fn exchange_routes_and_orders_by_source() {
        let w = World::new(3);
        let all = w.run(|c| {
            // every rank sends its id to every *other* rank
            let sends: Vec<(usize, Vec<u8>)> = (0..c.size())
                .filter(|&d| d != c.rank())
                .map(|d| (d, vec![c.rank() as u8]))
                .collect();
            c.exchange(sends)
        });
        for (me, inbox) in all.iter().enumerate() {
            let srcs: Vec<usize> = inbox.iter().map(|&(s, _)| s).collect();
            let want: Vec<usize> = (0..3).filter(|&s| s != me).collect();
            assert_eq!(srcs, want);
            for (s, p) in inbox {
                assert_eq!(p, &vec![*s as u8]);
            }
        }
    }

    #[test]
    fn exchange_supports_empty_and_multiple_payloads() {
        let w = World::new(2);
        let all = w.run(|c| {
            if c.rank() == 0 {
                c.exchange(vec![(1, vec![1]), (1, vec![2, 3])])
            } else {
                c.exchange(Vec::new())
            }
        });
        assert!(all[0].is_empty());
        assert_eq!(all[1], vec![(0, vec![1]), (0, vec![2, 3])]);
    }

    #[test]
    fn collectives_compose_over_many_rounds() {
        let w = World::new(3);
        let sums = w.run(|c| {
            let mut acc = 0u64;
            for round in 0..50u64 {
                acc += c.allreduce_sum_u64(round + c.rank() as u64);
            }
            acc
        });
        assert!(sums.iter().all(|&s| s == sums[0]));
    }

    #[test]
    fn allgather_indexed_by_rank() {
        let w = World::new(3);
        let all = w.run(|c| c.allgather_bytes(vec![c.rank() as u8 * 10]));
        for per_rank in all {
            assert_eq!(per_rank, vec![vec![0], vec![10], vec![20]]);
        }
    }

    #[test]
    fn reduce_f64_is_identical_on_all_ranks() {
        let w = World::new(4);
        let vals = w.run(|c| c.allreduce_sum_f64(0.1 * (c.rank() as f64 + 1.0)));
        assert!(vals.iter().all(|v| v.to_bits() == vals[0].to_bits()));
    }

    #[test]
    fn stats_count_remote_traffic_only() {
        let w = World::new(2);
        let stats = w.run(|c| {
            let _ = c.exchange(vec![(c.rank(), vec![9; 100]), ((c.rank() + 1) % 2, vec![7; 8])]);
            c.stats()
        });
        for s in stats {
            assert_eq!(s.msgs, 1);
            assert_eq!(s.bytes, 8);
        }
    }

    #[test]
    fn single_rank_world_loops_back() {
        let w = World::new(1);
        let out = w.run(|c| {
            let r = c.exchange(vec![(0, vec![42])]);
            assert_eq!(r, vec![(0, vec![42])]);
            assert_eq!(c.all_u64(7), vec![7]);
            c.allreduce_sum_u64(3)
        });
        assert_eq!(out, vec![3]);
    }

    #[test]
    fn isend_drain_matches_exchange_order() {
        let w = World::new(4);
        let all = w.run(|c| {
            // two payloads to every rank (self included), posted early
            for d in 0..c.size() {
                c.isend(d, tag::PTAP_NUM, vec![c.rank() as u8, 0]);
                c.isend(d, tag::PTAP_NUM, vec![c.rank() as u8, 1]);
            }
            c.drain(tag::PTAP_NUM)
        });
        for inbox in all {
            let want: Vec<(usize, Vec<u8>)> = (0..4)
                .flat_map(|s| [(s, vec![s as u8, 0]), (s, vec![s as u8, 1])])
                .collect();
            assert_eq!(inbox, want);
        }
    }

    #[test]
    fn epochs_reuse_a_tag() {
        let w = World::new(3);
        let all = w.run(|c| {
            let mut epochs = Vec::new();
            for e in 0..4u8 {
                let next = (c.rank() + 1) % c.size();
                c.isend(next, tag::GATHER, vec![e, c.rank() as u8]);
                epochs.push(c.drain(tag::GATHER));
            }
            epochs
        });
        for (me, epochs) in all.iter().enumerate() {
            let prev = (me + 3 - 1) % 3;
            for (e, inbox) in epochs.iter().enumerate() {
                assert_eq!(inbox, &vec![(prev, vec![e as u8, prev as u8])]);
            }
        }
    }

    #[test]
    fn try_recv_then_drain_release_canonical_prefix_and_rest() {
        let w = World::new(3);
        let all = w.run(|c| {
            for d in 0..c.size() {
                c.isend(d, tag::PTAP_SYM, vec![c.rank() as u8]);
            }
            // poll a few times mid-"compute"; releases are a prefix of the
            // canonical order, the drain returns the rest
            let mut got = Vec::new();
            for _ in 0..10 {
                got.extend(c.try_recv_any(tag::PTAP_SYM));
            }
            got.extend(c.drain(tag::PTAP_SYM));
            got
        });
        for inbox in all {
            let want: Vec<(usize, Vec<u8>)> = (0..3).map(|s| (s, vec![s as u8])).collect();
            assert_eq!(inbox, want);
        }
    }

    #[test]
    fn engine_traffic_interleaves_with_collectives() {
        let w = World::new(3);
        let all = w.run(|c| {
            // post engine payloads, run collectives on top of the open
            // epoch, then close it — the inbox must demux both streams
            for d in 0..c.size() {
                c.isend(d, tag::PTAP_NUM, vec![7; c.rank() + 1]);
            }
            let total = c.allreduce_sum_u64(c.rank() as u64 + 1);
            let gathered = c.all_u64(10 + c.rank() as u64);
            let drained = c.drain(tag::PTAP_NUM);
            (total, gathered, drained)
        });
        for (total, gathered, drained) in all {
            assert_eq!(total, 6);
            assert_eq!(gathered, vec![10, 11, 12]);
            let want: Vec<(usize, Vec<u8>)> = (0..3).map(|s| (s, vec![7; s + 1])).collect();
            assert_eq!(drained, want);
        }
    }

    #[test]
    fn isend_counts_remote_payload_bytes_only() {
        let w = World::new(2);
        let stats = w.run(|c| {
            c.isend(c.rank(), tag::PTAP_NUM, vec![1; 64]); // self: uncounted
            c.isend((c.rank() + 1) % 2, tag::PTAP_NUM, vec![2; 10]);
            let _ = c.drain(tag::PTAP_NUM); // close sentinels: uncounted
            c.stats()
        });
        for s in stats {
            assert_eq!(s.msgs, 1);
            assert_eq!(s.bytes, 10);
        }
    }
}
