//! Simulated MPI: a thread-per-rank world with a nonblocking,
//! tag-addressed communication engine underneath deterministic
//! collectives — plus sub-communicators ([`Comm::split`]) that scope
//! ranks, tags, epochs and traffic accounting to a subset of the world.
//!
//! [`World::run`] spawns one OS thread per rank and hands each a [`Comm`].
//! Communication runs over a full mesh of FIFO channels — one per ordered
//! rank pair.  Every frame on the wire carries a one-byte kind:
//!
//! - **collective** frames belong to the barrier-style collectives
//!   (`allgather_bytes`, `all_u64`, `allreduce_sum_*`), which still move
//!   exactly one frame per pair per call;
//! - **data** frames carry an epoch's point-to-point payloads for one
//!   `tag` ([`Comm::isend`] posts them immediately and returns), plus a
//!   sender-side microsecond stamp (zero when tracing is off) that lets
//!   the receiver measure true in-flight time per message;
//! - **close** frames are the epoch sentinels: a rank's promise that it
//!   will send no more data for that tag this epoch ([`Comm::drain`]
//!   posts one to every rank, then blocks until it has one from every
//!   rank).
//!
//! A per-source inbox demultiplexes the shared FIFO: frames that arrive
//! "early" (an engine payload while a peer is inside a collective, or
//! vice versa) are buffered per (source, tag) and consumed by whichever
//! call they belong to, so the SPMD call discipline never deadlocks and
//! never sees another epoch's traffic.
//!
//! Determinism: payloads are *released* to the consumer in source-rank
//! order — [`Comm::try_recv_any`] hands out the longest prefix of the
//! canonical order (all of rank 0's payloads in send order, then rank
//! 1's, ...) that has already arrived and closed, and [`Comm::drain`]
//! blocks for the rest — so interleaving sends with receives cannot
//! reorder anything relative to the bulk-synchronous [`Comm::exchange`]
//! shim, and repeated runs of a world reproduce byte-identical messages.
//! Reductions combine in rank order, so every rank computes bit-identical
//! global values.
//!
//! ## Sub-communicators
//!
//! [`Comm::split`] is the `MPI_Comm_split` analog: a collective that
//! partitions the calling communicator by `color` and returns each rank
//! its color group as a new [`Comm`].  The child shares the parent's
//! channel mesh but
//!
//! - **scopes ranks**: `rank()`/`size()` are relative to the group, and
//!   every collective/engine call addresses group members only;
//! - **scopes epochs**: `drain` posts close sentinels to members only, so
//!   ranks outside the group never enter (or hold up) the close barrier;
//! - **scopes tags**: every user tag is offset by the child's `tag_base`
//!   on the wire, so concurrent epochs on the same logical tag in
//!   different communicators cannot cross.  Bases are allocated from a
//!   per-endpoint monotonic counter, agreed across the parent's members
//!   at each split (max over members, then everyone bumps past it):
//!   any two communicators sharing *any* rank — including the rank's
//!   self-loopback channel — were both allocated through that rank's
//!   counter and therefore got distinct bases.  Communicators sharing
//!   no rank may reuse a base, but they share no channel either;
//! - **scopes stats**: [`Comm::stats`] counts only traffic sent through
//!   this communicator (shared by its clones); [`Comm::stats_global`]
//!   keeps the rank-wide total across all communicators.

use crate::obs;
use std::cell::{Cell, RefCell};
use std::collections::{HashMap, VecDeque};
use std::rc::Rc;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};

/// α (per-message latency) of the α-β communication model, seconds.
/// Tuned to a commodity cluster interconnect (DESIGN.md §7).
pub const COMM_ALPHA_SECS: f64 = 2.0e-6;

/// β (per-byte) of the α-β communication model, seconds/byte (~2 GB/s).
pub const COMM_BETA_SECS_PER_BYTE: f64 = 5.0e-10;

/// Reserved engine tags.  A tag names one logical stream of epochs; all
/// ranks must open and close epochs on a tag in the same global order
/// (the usual SPMD discipline), and a consumer must close its epoch
/// (`drain`) before any other consumer opens one on the same tag.
pub mod tag {
    /// The bulk-synchronous [`super::Comm::exchange`] compatibility shim.
    pub const EXCHANGE: u32 = 0;
    /// Gather-plan request/response traffic (`dist::gather`).
    pub const GATHER: u32 = 1;
    /// Triple-product symbolic-phase scatter (`ptap`).
    pub const PTAP_SYM: u32 = 2;
    /// Triple-product numeric-phase scatter (`ptap`).
    pub const PTAP_NUM: u32 = 3;
    /// Layout redistribution traffic (`agglomerate`).
    pub const REDIST: u32 = 4;

    /// Live-metrics counter names (msgs, bytes) for a tag class — static
    /// so the registry hooks stay allocation-free per update.
    pub fn metric_names(tag: u32) -> (&'static str, &'static str) {
        match tag {
            EXCHANGE => ("msgs.exchange", "bytes.exchange"),
            GATHER => ("msgs.gather", "bytes.gather"),
            PTAP_SYM => ("msgs.ptap_sym", "bytes.ptap_sym"),
            PTAP_NUM => ("msgs.ptap_num", "bytes.ptap_num"),
            REDIST => ("msgs.redist", "bytes.redist"),
            _ => ("msgs.other", "bytes.other"),
        }
    }
}

/// Tag-space stride between communicators: user tags must stay below
/// this; each [`Comm::split`] child gets its own `tag_base` multiple.
const TAG_STRIDE: u32 = 256;

/// Default staged rows per pipelined chunk; `GPTAP_PIPELINE_CHUNK`
/// overrides (any positive integer — 1 posts every row immediately, a
/// huge value degenerates to end-staging).
pub const DEFAULT_PIPELINE_CHUNK: usize = 64;

/// Rows per pipelined chunk.  Read per pipeline (not cached) so tests can
/// sweep chunk sizes within one process.
pub fn pipeline_chunk_rows() -> usize {
    std::env::var("GPTAP_PIPELINE_CHUNK")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&c| c > 0)
        .unwrap_or(DEFAULT_PIPELINE_CHUNK)
}

const FRAME_COLL: u8 = 0;
const FRAME_DATA: u8 = 1;
const FRAME_CLOSE: u8 = 2;

/// Number of logarithmic message-size buckets in [`CommStats::hist`].
pub const SIZE_BUCKETS: usize = 8;

/// Upper edge (exclusive, bytes) of each size bucket; the last bucket is
/// unbounded.
pub const SIZE_BUCKET_EDGES: [u64; SIZE_BUCKETS - 1] =
    [64, 256, 1024, 4096, 16384, 65536, 262144];

fn size_bucket(bytes: u64) -> usize {
    SIZE_BUCKET_EDGES.iter().position(|&e| bytes < e).unwrap_or(SIZE_BUCKETS - 1)
}

/// Representative payload size of bucket `b` (geometric midpoint of its
/// edges), used by the calibrated α model.
fn bucket_rep_bytes(b: usize) -> f64 {
    let lo = if b == 0 { 1 } else { SIZE_BUCKET_EDGES[b - 1] };
    let hi = if b + 1 == SIZE_BUCKETS { 4 * lo } else { SIZE_BUCKET_EDGES[b] };
    ((lo * hi) as f64).sqrt()
}

/// Number of logarithmic in-flight latency buckets in
/// [`CommStats::flight_hist`].
pub const LAT_BUCKETS: usize = 8;

/// Upper edge (exclusive, microseconds) of each latency bucket; the last
/// bucket is unbounded.
pub const LAT_BUCKET_EDGES_US: [u64; LAT_BUCKETS - 1] = [1, 5, 10, 50, 100, 500, 1000];

fn lat_bucket(us: u64) -> usize {
    LAT_BUCKET_EDGES_US.iter().position(|&e| us < e).unwrap_or(LAT_BUCKETS - 1)
}

/// Snapshot of one rank's cumulative send-side traffic.
#[derive(Debug, Default, Clone, Copy)]
pub struct CommStats {
    /// Point-to-point messages sent to other ranks.
    pub msgs: u64,
    /// Payload bytes sent to other ranks.
    pub bytes: u64,
    /// Message counts by payload-size bucket ([`SIZE_BUCKET_EDGES`]) —
    /// the measured chunk-size distribution the calibrated α model reads.
    pub hist: [u64; SIZE_BUCKETS],
    /// Messages whose in-flight time was observed (the sender stamped a
    /// send time into the frame — i.e. the sender was tracing).  Recorded
    /// receiver-side, rank-wide only: scoped [`Comm::stats`] snapshots
    /// report zero here; read them from [`Comm::stats_global`].
    pub flight_msgs: u64,
    /// Total observed in-flight microseconds (send stamp → delivery).
    pub flight_us: u64,
    /// Observed in-flight times by latency bucket
    /// ([`LAT_BUCKET_EDGES_US`]).
    pub flight_hist: [u64; LAT_BUCKETS],
    /// Epoch close barriers this rank has completed ([`Comm::drain`]).
    pub close_waits: u64,
    /// Microseconds spent blocked in those close barriers — idle wait
    /// that would otherwise masquerade as communication time.
    pub close_wait_us: u64,
    /// Close-barrier waits by latency bucket ([`LAT_BUCKET_EDGES_US`]).
    /// Rank-wide like the flight histogram: subcommunicator barriers
    /// (telescoping splits) land here too, so the histogram totals match
    /// `close_waits` through [`Comm::stats_global`] no matter how many
    /// nested splits drained epochs.
    pub close_wait_hist: [u64; LAT_BUCKETS],
}

impl CommStats {
    /// The α-β model applied to this rank's traffic (fixed per-message α).
    pub fn modeled_secs(&self) -> f64 {
        self.msgs as f64 * COMM_ALPHA_SECS + self.bytes as f64 * COMM_BETA_SECS_PER_BYTE
    }

    /// The α term under the *calibrated* per-message credit: a pipelined
    /// chunk posted back-to-back behind another is spaced by its own
    /// serialization time, so a message of size `s` adds only
    /// `min(α, s·β)` of latency — small chunks (the engine's pipelined
    /// trains) amortize α, bulk messages still pay it in full.  Derived
    /// from the measured size histogram rather than the single constant.
    pub fn alpha_secs_calibrated(&self) -> f64 {
        self.hist
            .iter()
            .enumerate()
            .map(|(b, &n)| {
                n as f64 * COMM_ALPHA_SECS.min(bucket_rep_bytes(b) * COMM_BETA_SECS_PER_BYTE)
            })
            .sum()
    }

    /// The α-β model with the calibrated per-message α credit.
    pub fn modeled_secs_calibrated(&self) -> f64 {
        self.alpha_secs_calibrated() + self.bytes as f64 * COMM_BETA_SECS_PER_BYTE
    }

    /// Mean observed in-flight seconds per stamped message (0 when no
    /// message carried a stamp, i.e. the run was untraced).
    pub fn mean_flight_secs(&self) -> f64 {
        if self.flight_msgs == 0 {
            0.0
        } else {
            self.flight_us as f64 / self.flight_msgs as f64 * 1e-6
        }
    }

    /// Seconds spent blocked in epoch close barriers.
    pub fn close_wait_secs(&self) -> f64 {
        self.close_wait_us as f64 * 1e-6
    }

    /// Traffic since `earlier` (same counters, monotone).
    pub fn since(&self, earlier: CommStats) -> CommStats {
        let mut hist = [0u64; SIZE_BUCKETS];
        for (h, (a, b)) in hist.iter_mut().zip(self.hist.iter().zip(earlier.hist)) {
            *h = a - b;
        }
        let mut flight_hist = [0u64; LAT_BUCKETS];
        for (h, (a, b)) in
            flight_hist.iter_mut().zip(self.flight_hist.iter().zip(earlier.flight_hist))
        {
            *h = a - b;
        }
        let mut close_wait_hist = [0u64; LAT_BUCKETS];
        for (h, (a, b)) in
            close_wait_hist.iter_mut().zip(self.close_wait_hist.iter().zip(earlier.close_wait_hist))
        {
            *h = a - b;
        }
        CommStats {
            msgs: self.msgs - earlier.msgs,
            bytes: self.bytes - earlier.bytes,
            hist,
            flight_msgs: self.flight_msgs - earlier.flight_msgs,
            flight_us: self.flight_us - earlier.flight_us,
            flight_hist,
            close_waits: self.close_waits - earlier.close_waits,
            close_wait_us: self.close_wait_us - earlier.close_wait_us,
            close_wait_hist,
        }
    }

    /// Accumulate another snapshot's counters into this one.
    pub fn merge(&mut self, other: CommStats) {
        self.msgs += other.msgs;
        self.bytes += other.bytes;
        for (h, o) in self.hist.iter_mut().zip(other.hist) {
            *h += o;
        }
        self.flight_msgs += other.flight_msgs;
        self.flight_us += other.flight_us;
        for (h, o) in self.flight_hist.iter_mut().zip(other.flight_hist) {
            *h += o;
        }
        self.close_waits += other.close_waits;
        self.close_wait_us += other.close_wait_us;
        for (h, o) in self.close_wait_hist.iter_mut().zip(other.close_wait_hist) {
            *h += o;
        }
    }
}

/// One buffered engine frame: a payload, or the epoch-close sentinel.
enum EngineFrame {
    Data(Vec<u8>),
    Close,
}

/// Demultiplexed arrivals from one source rank.
#[derive(Default)]
struct SourceInbox {
    /// Collective frames, in arrival (= send) order.
    coll: VecDeque<Vec<u8>>,
    /// Engine frames per wire tag, in arrival order; `Close` entries
    /// delimit epochs.
    tags: HashMap<u32, VecDeque<EngineFrame>>,
}

/// One rank's physical end of the channel mesh, shared by every
/// communicator ([`Comm`]) this rank holds.
struct Endpoint {
    world_rank: usize,
    world_np: usize,
    /// `tx[d]` sends one frame to world rank `d` (index `world_rank`
    /// loops back).
    tx: Vec<Sender<Vec<u8>>>,
    /// `rx[s]` receives frames sent by world rank `s`.
    rx: Vec<Receiver<Vec<u8>>>,
    /// Rank-wide send-side totals across all communicators.
    total_msgs: Cell<u64>,
    total_bytes: Cell<u64>,
    total_hist: Cell<[u64; SIZE_BUCKETS]>,
    /// Rank-wide receive-side in-flight accounting (stamped frames only).
    total_flight_msgs: Cell<u64>,
    total_flight_us: Cell<u64>,
    total_flight_hist: Cell<[u64; LAT_BUCKETS]>,
    /// Rank-wide epoch close-barrier accounting.
    total_close_waits: Cell<u64>,
    total_close_wait_us: Cell<u64>,
    total_close_wait_hist: Cell<[u64; LAT_BUCKETS]>,
    /// Next free wire-tag base for communicators created through this
    /// rank (monotonic; every split involving this rank bumps it).
    next_tag_base: Cell<u32>,
    /// Early arrivals, demultiplexed per world source.
    inbox: RefCell<Vec<SourceInbox>>,
    /// Per-wire-tag release cursor: the next *member index* (within the
    /// communicator owning that tag) whose current-epoch payloads have
    /// not been fully released yet (absent = 0).
    cursor: RefCell<HashMap<u32, usize>>,
}

impl Endpoint {
    /// Route an arrived frame into the per-source inbox.  Data frames
    /// carry the sender's microsecond stamp after the tag (zero when the
    /// sender was not tracing); delivery is the receive end of the
    /// in-flight span, so the stamp is consumed here.
    fn deliver(&self, src: usize, frame: Vec<u8>) {
        let mut inbox = self.inbox.borrow_mut();
        let slot = &mut inbox[src];
        match frame[0] {
            FRAME_COLL => slot.coll.push_back(frame[1..].to_vec()),
            FRAME_DATA => {
                let t = u32::from_le_bytes(frame[1..5].try_into().unwrap());
                let send_us = u64::from_le_bytes(frame[5..13].try_into().unwrap());
                // Self-loopback frames are uncounted in CommStats, so
                // their flights are skipped here too.
                if send_us != 0 && src != self.world_rank {
                    let recv_us = obs::now_us();
                    let us = recv_us.saturating_sub(send_us);
                    self.total_flight_msgs.set(self.total_flight_msgs.get() + 1);
                    self.total_flight_us.set(self.total_flight_us.get() + us);
                    let mut fh = self.total_flight_hist.get();
                    fh[lat_bucket(us)] += 1;
                    self.total_flight_hist.set(fh);
                    obs::flight(src as u32, t, (frame.len() - 13) as u64, send_us, recv_us);
                    obs::metrics::observe(obs::Subsys::Comm, "flight_us", us);
                }
                slot.tags.entry(t).or_default().push_back(EngineFrame::Data(frame[13..].to_vec()));
            }
            FRAME_CLOSE => {
                let t = u32::from_le_bytes(frame[1..5].try_into().unwrap());
                slot.tags.entry(t).or_default().push_back(EngineFrame::Close);
            }
            k => unreachable!("bad frame kind {k}"),
        }
    }

    /// Next collective frame from world rank `src`, demuxing engine
    /// frames aside.
    fn recv_collective(&self, src: usize) -> Vec<u8> {
        loop {
            let buffered = self.inbox.borrow_mut()[src].coll.pop_front();
            if let Some(f) = buffered {
                return f;
            }
            let frame = self.rx[src].recv().expect("peer rank panicked");
            self.deliver(src, frame);
        }
    }
}

/// Membership of one communicator: the world ranks it spans, this rank's
/// index among them, the wire-tag offset, and the scoped traffic stats
/// (shared by clones of the same communicator).
struct Group {
    /// World ranks of the members, strictly ascending.
    members: Vec<usize>,
    /// This rank's index within `members` — its rank in this communicator.
    my: usize,
    /// Added to every user tag on the wire (epoch scoping).
    tag_base: u32,
    /// Send-side traffic through this communicator.
    msgs: Cell<u64>,
    bytes: Cell<u64>,
    hist: Cell<[u64; SIZE_BUCKETS]>,
}

/// One rank's endpoint of a (sub-)communicator.  Cheap to clone: clones
/// share the channel mesh and the communicator's scoped stats.
#[derive(Clone)]
pub struct Comm {
    ep: Rc<Endpoint>,
    group: Rc<Group>,
}

impl Comm {
    /// Build the world communicator for one rank (called on its thread).
    fn root(
        world_rank: usize,
        world_np: usize,
        tx: Vec<Sender<Vec<u8>>>,
        rx: Vec<Receiver<Vec<u8>>>,
    ) -> Comm {
        Comm {
            ep: Rc::new(Endpoint {
                world_rank,
                world_np,
                tx,
                rx,
                total_msgs: Cell::new(0),
                total_bytes: Cell::new(0),
                total_hist: Cell::new([0; SIZE_BUCKETS]),
                total_flight_msgs: Cell::new(0),
                total_flight_us: Cell::new(0),
                total_flight_hist: Cell::new([0; LAT_BUCKETS]),
                total_close_waits: Cell::new(0),
                total_close_wait_us: Cell::new(0),
                total_close_wait_hist: Cell::new([0; LAT_BUCKETS]),
                next_tag_base: Cell::new(TAG_STRIDE),
                inbox: RefCell::new((0..world_np).map(|_| SourceInbox::default()).collect()),
                cursor: RefCell::new(HashMap::new()),
            }),
            group: Rc::new(Group {
                members: (0..world_np).collect(),
                my: world_rank,
                tag_base: 0,
                msgs: Cell::new(0),
                bytes: Cell::new(0),
                hist: Cell::new([0; SIZE_BUCKETS]),
            }),
        }
    }

    /// This rank's id within this communicator, `0..size()`.
    pub fn rank(&self) -> usize {
        self.group.my
    }

    /// Number of ranks in this communicator.
    pub fn size(&self) -> usize {
        self.group.members.len()
    }

    /// World rank behind member index `r` of this communicator.
    pub fn world_rank_of(&self, r: usize) -> usize {
        self.group.members[r]
    }

    /// Cumulative send-side traffic through *this* communicator (payload
    /// bytes; engine framing and close sentinels are protocol overhead
    /// and uncounted, exactly as the one-frame-per-pair barrier was).
    /// Scoped: a sub-communicator counts only its own epochs and
    /// collectives — see [`Comm::stats_global`] for the rank-wide total.
    pub fn stats(&self) -> CommStats {
        // In-flight and close-barrier accounting is rank-wide (receiver
        // side cannot cheaply attribute a wire tag to a communicator), so
        // scoped snapshots carry zeros there — see [`Comm::stats_global`].
        CommStats {
            msgs: self.group.msgs.get(),
            bytes: self.group.bytes.get(),
            hist: self.group.hist.get(),
            ..CommStats::default()
        }
    }

    /// Rank-wide send-side totals across every communicator this rank
    /// holds (world + all sub-communicators), plus the receive-side
    /// in-flight and close-barrier accounting.
    pub fn stats_global(&self) -> CommStats {
        CommStats {
            msgs: self.ep.total_msgs.get(),
            bytes: self.ep.total_bytes.get(),
            hist: self.ep.total_hist.get(),
            flight_msgs: self.ep.total_flight_msgs.get(),
            flight_us: self.ep.total_flight_us.get(),
            flight_hist: self.ep.total_flight_hist.get(),
            close_waits: self.ep.total_close_waits.get(),
            close_wait_us: self.ep.total_close_wait_us.get(),
            close_wait_hist: self.ep.total_close_wait_hist.get(),
        }
    }

    /// Count `msgs` sent messages of `msg_bytes` payload bytes each.
    fn count_send(&self, msgs: u64, msg_bytes: u64) {
        let bytes = msgs * msg_bytes;
        self.group.msgs.set(self.group.msgs.get() + msgs);
        self.group.bytes.set(self.group.bytes.get() + bytes);
        self.ep.total_msgs.set(self.ep.total_msgs.get() + msgs);
        self.ep.total_bytes.set(self.ep.total_bytes.get() + bytes);
        if msgs > 0 {
            let b = size_bucket(msg_bytes);
            let mut gh = self.group.hist.get();
            gh[b] += msgs;
            self.group.hist.set(gh);
            let mut th = self.ep.total_hist.get();
            th[b] += msgs;
            self.ep.total_hist.set(th);
        }
    }

    /// The wire tag carrying user `tag` for this communicator.
    fn wire_tag(&self, tag: u32) -> u32 {
        debug_assert!(tag < TAG_STRIDE, "user tag {tag} exceeds the communicator tag space");
        self.group.tag_base + tag
    }

    /// Split this communicator by `color` (collective — the
    /// `MPI_Comm_split` analog): members that passed the same color form
    /// a new communicator, ordered by their rank here.  The child scopes
    /// ranks, tags, epochs and stats to its members; ranks outside a
    /// child never participate in its collectives or epoch close
    /// barriers.
    pub fn split(&self, color: usize) -> Comm {
        let colors = self.all_u64(color as u64);
        let members: Vec<usize> = colors
            .iter()
            .enumerate()
            .filter(|&(_, &c)| c == color as u64)
            .map(|(i, _)| self.group.members[i])
            .collect();
        let my = members
            .binary_search(&self.ep.world_rank)
            .expect("split: caller missing from its own color group");
        // Agree on the children's wire-tag base: the max of the members'
        // next free bases, which everyone then bumps past.  Allocating
        // through each member's endpoint counter makes the base unique
        // among all communicators sharing any rank (self-loopback
        // channel included); sibling color groups share one base but are
        // disjoint rank sets, so they share no channel at all.
        let bases = self.all_u64(self.ep.next_tag_base.get() as u64);
        let tag_base = bases.into_iter().max().unwrap() as u32;
        self.ep.next_tag_base.set(tag_base + TAG_STRIDE);
        Comm {
            ep: Rc::clone(&self.ep),
            group: Rc::new(Group {
                members,
                my,
                tag_base,
                msgs: Cell::new(0),
                bytes: Cell::new(0),
                hist: Cell::new([0; SIZE_BUCKETS]),
            }),
        }
    }

    /// One collective round: every member sends exactly one frame to
    /// every member (self included) and receives one frame from every
    /// member, in member order.
    fn round(&self, frames: Vec<Vec<u8>>) -> Vec<Vec<u8>> {
        debug_assert_eq!(frames.len(), self.size());
        for (d, frame) in frames.into_iter().enumerate() {
            let mut f = Vec::with_capacity(1 + frame.len());
            f.push(FRAME_COLL);
            f.extend_from_slice(&frame);
            self.ep.tx[self.group.members[d]].send(f).expect("peer rank terminated early");
        }
        self.group.members.iter().map(|&s| self.ep.recv_collective(s)).collect()
    }

    /// Post `payload` to member `dest` under `tag` and return immediately
    /// (the nonblocking send).  Payloads are delivered in send order per
    /// (source, tag) pair; `dest == rank()` loops back.
    ///
    /// The frame reserves 8 bytes for a send stamp (microseconds since
    /// the shared trace origin) after the tag; it is zero when tracing is
    /// off, so both ends agree on the layout unconditionally.  Framing
    /// bytes — kind, tag, and stamp — remain protocol overhead and are
    /// never counted in [`CommStats`].
    pub fn isend(&self, dest: usize, tag: u32, payload: Vec<u8>) {
        let wdest = self.group.members[dest];
        if wdest != self.ep.world_rank {
            self.count_send(1, payload.len() as u64);
            if obs::metrics::enabled() {
                let (msgs_name, bytes_name) = tag::metric_names(tag);
                obs::metrics::add(obs::Subsys::Comm, msgs_name, 1);
                obs::metrics::add(obs::Subsys::Comm, bytes_name, payload.len() as u64);
            }
        }
        let wire = self.wire_tag(tag);
        // Stamp whenever either observer is armed: the tracer records the
        // flight event, the metrics registry feeds its latency histogram.
        // The stamp is framing overhead, never counted in [`CommStats`].
        let send_us =
            if obs::enabled() || obs::metrics::enabled() { obs::now_us() } else { 0 };
        let mut f = Vec::with_capacity(13 + payload.len());
        f.push(FRAME_DATA);
        f.extend_from_slice(&wire.to_le_bytes());
        f.extend_from_slice(&send_us.to_le_bytes());
        f.extend_from_slice(&payload);
        self.ep.tx[wdest].send(f).expect("peer rank terminated early");
    }

    fn send_close(&self, dest: usize, tag: u32) {
        let wire = self.wire_tag(tag);
        let mut f = Vec::with_capacity(5);
        f.push(FRAME_CLOSE);
        f.extend_from_slice(&wire.to_le_bytes());
        self.ep.tx[self.group.members[dest]].send(f).expect("peer rank terminated early");
    }

    /// Release loop shared by [`Comm::try_recv_any`] and [`Comm::drain`]:
    /// walk member sources in rank order from the tag's cursor, handing
    /// out data frames until the epoch closes (every member's `Close`
    /// consumed) or — nonblocking — until the cursor source has nothing
    /// buffered.  Returns whether the epoch fully closed (and resets the
    /// cursor).  Released source ids are member indices.
    fn release_into(&self, tag: u32, blocking: bool, out: &mut Vec<(usize, Vec<u8>)>) -> bool {
        let wire = self.wire_tag(tag);
        let np = self.size();
        let mut cur = self.ep.cursor.borrow_mut().remove(&wire).unwrap_or(0);
        'sources: while cur < np {
            let wsrc = self.group.members[cur];
            loop {
                let next = self.ep.inbox.borrow_mut()[wsrc]
                    .tags
                    .get_mut(&wire)
                    .and_then(|q| q.pop_front());
                match next {
                    Some(EngineFrame::Data(p)) => {
                        out.push((cur, p));
                        continue;
                    }
                    Some(EngineFrame::Close) => {
                        cur += 1;
                        continue 'sources;
                    }
                    None => {}
                }
                if blocking {
                    let frame = self.ep.rx[wsrc].recv().expect("peer rank panicked");
                    self.ep.deliver(wsrc, frame);
                } else {
                    match self.ep.rx[wsrc].try_recv() {
                        Ok(frame) => self.ep.deliver(wsrc, frame),
                        Err(TryRecvError::Empty) => break 'sources,
                        Err(TryRecvError::Disconnected) => panic!("peer rank panicked"),
                    }
                }
            }
        }
        if cur >= np {
            true
        } else {
            self.ep.cursor.borrow_mut().insert(wire, cur);
            false
        }
    }

    /// Nonblocking receive: whatever prefix of this epoch's canonical
    /// delivery order (source-rank major, send order within a source) has
    /// already arrived.  A source's payloads are only released once every
    /// lower-ranked source has closed its epoch — that restriction is
    /// what makes interleaved send/receive schedules bit-deterministic.
    pub fn try_recv_any(&self, tag: u32) -> Vec<(usize, Vec<u8>)> {
        let mut out = Vec::new();
        self.release_into(tag, false, &mut out);
        out
    }

    /// Close this rank's epoch on `tag` (collective over the tag): post
    /// the close sentinel to every member, then block until every
    /// member's sentinel has arrived, returning all not-yet-released
    /// payloads in canonical order.  After `drain` the tag is ready for a
    /// new epoch.  Ranks outside this communicator are not involved —
    /// the close barrier spans members only.
    pub fn drain(&self, tag: u32) -> Vec<(usize, Vec<u8>)> {
        for d in 0..self.size() {
            self.send_close(d, tag);
        }
        // The blocking release below is the epoch close barrier: time it
        // so barrier idle stops masquerading as communication time.  Two
        // clock reads per *epoch* (not per message), so it stays on even
        // when tracing is off.  The span guard is inert unless the tracer
        // or the metrics registry is armed (one TLS read), in which case
        // it records the barrier and/or feeds the "close_barrier"
        // histogram.
        let sp = obs::span(obs::Subsys::Comm, "close_barrier", tag as u64);
        let t0 = std::time::Instant::now();
        let mut out = Vec::new();
        let closed = self.release_into(tag, true, &mut out);
        let us = t0.elapsed().as_micros() as u64;
        drop(sp);
        self.ep.total_close_waits.set(self.ep.total_close_waits.get() + 1);
        self.ep.total_close_wait_us.set(self.ep.total_close_wait_us.get() + us);
        let mut ch = self.ep.total_close_wait_hist.get();
        ch[lat_bucket(us)] += 1;
        self.ep.total_close_wait_hist.set(ch);
        debug_assert!(closed, "blocking release must close the epoch");
        out
    }

    /// Bulk epoch on an explicit tag: one `isend` per payload plus one
    /// `drain` — a one-epoch, zero-overlap use of the engine with the
    /// canonical delivery order (source rank, then send order within a
    /// source).  Every rank must call it collectively per epoch; empty
    /// `sends` are fine.
    pub fn exchange_on(&self, tag: u32, sends: Vec<(usize, Vec<u8>)>) -> Vec<(usize, Vec<u8>)> {
        for (dest, payload) in sends {
            self.isend(dest, tag, payload);
        }
        self.drain(tag)
    }

    /// Sparse all-to-all: deliver each `(dest, payload)` pair and return
    /// the `(source, payload)` pairs addressed to this rank, ordered by
    /// source rank (then send order within a source).  Every rank must
    /// call this the same number of times; empty `sends` are fine.
    ///
    /// Compatibility shim over [`Comm::exchange_on`] with identical
    /// delivery order and identical measured traffic to the historical
    /// bulk-synchronous collective.
    pub fn exchange(&self, sends: Vec<(usize, Vec<u8>)>) -> Vec<(usize, Vec<u8>)> {
        self.exchange_on(tag::EXCHANGE, sends)
    }

    /// Allgather of raw byte payloads (collective): returns one payload
    /// per member, indexed by member rank.
    pub fn allgather_bytes(&self, payload: Vec<u8>) -> Vec<Vec<u8>> {
        let others = self.size() as u64 - 1;
        self.count_send(others, payload.len() as u64);
        let frames: Vec<Vec<u8>> = (0..self.size()).map(|_| payload.clone()).collect();
        self.round(frames)
    }

    /// Allgather of one `u64` per rank (collective), indexed by rank.
    pub fn all_u64(&self, v: u64) -> Vec<u64> {
        let others = self.size() as u64 - 1;
        self.count_send(others, 8);
        let frames: Vec<Vec<u8>> = (0..self.size()).map(|_| v.to_le_bytes().to_vec()).collect();
        self.round(frames)
            .into_iter()
            .map(|f| u64::from_le_bytes(f[0..8].try_into().unwrap()))
            .collect()
    }

    /// Global sum of one `u64` per rank (collective).
    pub fn allreduce_sum_u64(&self, v: u64) -> u64 {
        self.all_u64(v).into_iter().sum()
    }

    /// Global sum of one `f64` per rank (collective).  Combines in rank
    /// order, so every rank computes the bit-identical result.
    pub fn allreduce_sum_f64(&self, v: f64) -> f64 {
        let others = self.size() as u64 - 1;
        self.count_send(others, 8);
        let frames: Vec<Vec<u8>> = (0..self.size()).map(|_| v.to_le_bytes().to_vec()).collect();
        self.round(frames)
            .into_iter()
            .map(|f| f64::from_le_bytes(f[0..8].try_into().unwrap()))
            .sum()
    }

    /// Global element-wise sum of `v.len()` `f64`s per rank (collective)
    /// in **one** message round: K partial sums ride a single payload, so
    /// a blocked solve pays one α per reduction instead of K.  Each
    /// element combines in rank order, so element `j` is bit-identical to
    /// a scalar [`Comm::allreduce_sum_f64`] of the ranks' `v[j]`s.
    pub fn allreduce_sum_f64_multi(&self, v: &[f64]) -> Vec<f64> {
        let others = self.size() as u64 - 1;
        self.count_send(others, (v.len() * 8) as u64);
        let mut payload = Vec::with_capacity(v.len() * 8);
        for x in v {
            payload.extend_from_slice(&x.to_le_bytes());
        }
        let frames: Vec<Vec<u8>> = (0..self.size()).map(|_| payload.clone()).collect();
        let mut out = vec![0.0f64; v.len()];
        for f in self.round(frames) {
            debug_assert_eq!(f.len(), v.len() * 8);
            for (j, slot) in out.iter_mut().enumerate() {
                *slot += f64::from_le_bytes(f[j * 8..j * 8 + 8].try_into().unwrap());
            }
        }
        out
    }
}

/// A set of `np` simulated ranks.
pub struct World {
    np: usize,
}

impl World {
    pub fn new(np: usize) -> World {
        assert!(np >= 1, "world needs at least one rank");
        World { np }
    }

    pub fn size(&self) -> usize {
        self.np
    }

    /// Run `f` once per rank on its own thread and return the per-rank
    /// results ordered by rank.  Scoped threads: `f` may borrow from the
    /// caller.  A panic in any rank propagates (preferring the original
    /// panic over the "peer died" cascades it triggers in other ranks).
    pub fn run<T, F>(&self, f: F) -> Vec<T>
    where
        F: Fn(Comm) -> T + Send + Sync,
        T: Send,
    {
        let np = self.np;
        // full channel mesh: pair (s, d) has its own FIFO
        let mut txs: Vec<Vec<Option<Sender<Vec<u8>>>>> =
            (0..np).map(|_| (0..np).map(|_| None).collect()).collect();
        let mut rxs: Vec<Vec<Option<Receiver<Vec<u8>>>>> =
            (0..np).map(|_| (0..np).map(|_| None).collect()).collect();
        for (s, row) in txs.iter_mut().enumerate() {
            for (d, slot) in row.iter_mut().enumerate() {
                let (tx, rx) = channel();
                *slot = Some(tx);
                rxs[d][s] = Some(rx);
            }
        }
        // the Comm itself is single-threaded (Rc innards): ship the raw
        // channel halves to each thread and build the Comm there
        let parts: Vec<(usize, Vec<Sender<Vec<u8>>>, Vec<Receiver<Vec<u8>>>)> = txs
            .into_iter()
            .zip(rxs)
            .enumerate()
            .map(|(rank, (tx_row, rx_col))| {
                (
                    rank,
                    tx_row.into_iter().map(|t| t.unwrap()).collect(),
                    rx_col.into_iter().map(|r| r.unwrap()).collect(),
                )
            })
            .collect();

        let f_ref = &f;
        let joined: Vec<std::thread::Result<T>> = std::thread::scope(|scope| {
            let handles: Vec<_> = parts
                .into_iter()
                .map(|(rank, tx, rx)| {
                    scope.spawn(move || {
                        crate::util::log::set_rank(rank);
                        f_ref(Comm::root(rank, np, tx, rx))
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join()).collect()
        });
        // prefer the original panic over "peer rank ..." cascades
        if joined.iter().any(|r| r.is_err()) {
            let is_cascade = |p: &(dyn std::any::Any + Send)| -> bool {
                let msg = p
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| p.downcast_ref::<String>().cloned())
                    .unwrap_or_default();
                msg.contains("peer rank")
            };
            let mut cascade = None;
            for r in joined {
                if let Err(p) = r {
                    if !is_cascade(p.as_ref()) {
                        std::panic::resume_unwind(p);
                    }
                    cascade.get_or_insert(p);
                }
            }
            std::panic::resume_unwind(cascade.unwrap());
        }
        joined.into_iter().map(|r| r.unwrap()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_ordered_by_rank() {
        let w = World::new(4);
        let out = w.run(|c| (c.rank(), c.size()));
        assert_eq!(out, vec![(0, 4), (1, 4), (2, 4), (3, 4)]);
    }

    #[test]
    fn exchange_routes_and_orders_by_source() {
        let w = World::new(3);
        let all = w.run(|c| {
            // every rank sends its id to every *other* rank
            let sends: Vec<(usize, Vec<u8>)> = (0..c.size())
                .filter(|&d| d != c.rank())
                .map(|d| (d, vec![c.rank() as u8]))
                .collect();
            c.exchange(sends)
        });
        for (me, inbox) in all.iter().enumerate() {
            let srcs: Vec<usize> = inbox.iter().map(|&(s, _)| s).collect();
            let want: Vec<usize> = (0..3).filter(|&s| s != me).collect();
            assert_eq!(srcs, want);
            for (s, p) in inbox {
                assert_eq!(p, &vec![*s as u8]);
            }
        }
    }

    #[test]
    fn exchange_supports_empty_and_multiple_payloads() {
        let w = World::new(2);
        let all = w.run(|c| {
            if c.rank() == 0 {
                c.exchange(vec![(1, vec![1]), (1, vec![2, 3])])
            } else {
                c.exchange(Vec::new())
            }
        });
        assert!(all[0].is_empty());
        assert_eq!(all[1], vec![(0, vec![1]), (0, vec![2, 3])]);
    }

    #[test]
    fn collectives_compose_over_many_rounds() {
        let w = World::new(3);
        let sums = w.run(|c| {
            let mut acc = 0u64;
            for round in 0..50u64 {
                acc += c.allreduce_sum_u64(round + c.rank() as u64);
            }
            acc
        });
        assert!(sums.iter().all(|&s| s == sums[0]));
    }

    #[test]
    fn allgather_indexed_by_rank() {
        let w = World::new(3);
        let all = w.run(|c| c.allgather_bytes(vec![c.rank() as u8 * 10]));
        for per_rank in all {
            assert_eq!(per_rank, vec![vec![0], vec![10], vec![20]]);
        }
    }

    #[test]
    fn reduce_f64_is_identical_on_all_ranks() {
        let w = World::new(4);
        let vals = w.run(|c| c.allreduce_sum_f64(0.1 * (c.rank() as f64 + 1.0)));
        assert!(vals.iter().all(|v| v.to_bits() == vals[0].to_bits()));
    }

    #[test]
    fn stats_count_remote_traffic_only() {
        let w = World::new(2);
        let stats = w.run(|c| {
            let _ = c.exchange(vec![(c.rank(), vec![9; 100]), ((c.rank() + 1) % 2, vec![7; 8])]);
            c.stats()
        });
        for s in stats {
            assert_eq!(s.msgs, 1);
            assert_eq!(s.bytes, 8);
        }
    }

    #[test]
    fn single_rank_world_loops_back() {
        let w = World::new(1);
        let out = w.run(|c| {
            let r = c.exchange(vec![(0, vec![42])]);
            assert_eq!(r, vec![(0, vec![42])]);
            assert_eq!(c.all_u64(7), vec![7]);
            c.allreduce_sum_u64(3)
        });
        assert_eq!(out, vec![3]);
    }

    #[test]
    fn isend_drain_matches_exchange_order() {
        let w = World::new(4);
        let all = w.run(|c| {
            // two payloads to every rank (self included), posted early
            for d in 0..c.size() {
                c.isend(d, tag::PTAP_NUM, vec![c.rank() as u8, 0]);
                c.isend(d, tag::PTAP_NUM, vec![c.rank() as u8, 1]);
            }
            c.drain(tag::PTAP_NUM)
        });
        for inbox in all {
            let want: Vec<(usize, Vec<u8>)> = (0..4)
                .flat_map(|s| [(s, vec![s as u8, 0]), (s, vec![s as u8, 1])])
                .collect();
            assert_eq!(inbox, want);
        }
    }

    #[test]
    fn epochs_reuse_a_tag() {
        let w = World::new(3);
        let all = w.run(|c| {
            let mut epochs = Vec::new();
            for e in 0..4u8 {
                let next = (c.rank() + 1) % c.size();
                c.isend(next, tag::GATHER, vec![e, c.rank() as u8]);
                epochs.push(c.drain(tag::GATHER));
            }
            epochs
        });
        for (me, epochs) in all.iter().enumerate() {
            let prev = (me + 3 - 1) % 3;
            for (e, inbox) in epochs.iter().enumerate() {
                assert_eq!(inbox, &vec![(prev, vec![e as u8, prev as u8])]);
            }
        }
    }

    #[test]
    fn try_recv_then_drain_release_canonical_prefix_and_rest() {
        let w = World::new(3);
        let all = w.run(|c| {
            for d in 0..c.size() {
                c.isend(d, tag::PTAP_SYM, vec![c.rank() as u8]);
            }
            // poll a few times mid-"compute"; releases are a prefix of the
            // canonical order, the drain returns the rest
            let mut got = Vec::new();
            for _ in 0..10 {
                got.extend(c.try_recv_any(tag::PTAP_SYM));
            }
            got.extend(c.drain(tag::PTAP_SYM));
            got
        });
        for inbox in all {
            let want: Vec<(usize, Vec<u8>)> = (0..3).map(|s| (s, vec![s as u8])).collect();
            assert_eq!(inbox, want);
        }
    }

    #[test]
    fn engine_traffic_interleaves_with_collectives() {
        let w = World::new(3);
        let all = w.run(|c| {
            // post engine payloads, run collectives on top of the open
            // epoch, then close it — the inbox must demux both streams
            for d in 0..c.size() {
                c.isend(d, tag::PTAP_NUM, vec![7; c.rank() + 1]);
            }
            let total = c.allreduce_sum_u64(c.rank() as u64 + 1);
            let gathered = c.all_u64(10 + c.rank() as u64);
            let drained = c.drain(tag::PTAP_NUM);
            (total, gathered, drained)
        });
        for (total, gathered, drained) in all {
            assert_eq!(total, 6);
            assert_eq!(gathered, vec![10, 11, 12]);
            let want: Vec<(usize, Vec<u8>)> = (0..3).map(|s| (s, vec![7; s + 1])).collect();
            assert_eq!(drained, want);
        }
    }

    #[test]
    fn isend_counts_remote_payload_bytes_only() {
        let w = World::new(2);
        let stats = w.run(|c| {
            c.isend(c.rank(), tag::PTAP_NUM, vec![1; 64]); // self: uncounted
            c.isend((c.rank() + 1) % 2, tag::PTAP_NUM, vec![2; 10]);
            let _ = c.drain(tag::PTAP_NUM); // close sentinels: uncounted
            c.stats()
        });
        for s in stats {
            assert_eq!(s.msgs, 1);
            assert_eq!(s.bytes, 10);
        }
    }

    #[test]
    fn size_histogram_tracks_chunk_distribution() {
        let w = World::new(2);
        let stats = w.run(|c| {
            let peer = 1 - c.rank();
            c.isend(peer, tag::PTAP_NUM, vec![0; 10]); // bucket 0 (<64)
            c.isend(peer, tag::PTAP_NUM, vec![0; 10]);
            c.isend(peer, tag::PTAP_NUM, vec![0; 100_000]); // bucket 6 (<256K)
            let _ = c.drain(tag::PTAP_NUM);
            c.stats()
        });
        for s in stats {
            assert_eq!(s.msgs, 3);
            assert_eq!(s.hist[0], 2);
            assert_eq!(s.hist[6], 1);
            assert_eq!(s.hist.iter().sum::<u64>(), s.msgs);
            // calibrated α: the two tiny chunks amortize their latency, so
            // the calibrated term sits strictly below fixed α·msgs while
            // the bulk message still pays (nearly) full α
            let fixed_alpha = s.msgs as f64 * COMM_ALPHA_SECS;
            let cal = s.alpha_secs_calibrated();
            assert!(cal < fixed_alpha, "calibrated {cal} !< fixed {fixed_alpha}");
            assert!(cal > 0.9 * COMM_ALPHA_SECS, "bulk message must keep its α: {cal}");
        }
    }

    #[test]
    fn close_barrier_waits_are_accounted() {
        let w = World::new(2);
        let stats = w.run(|c| {
            let _ = c.drain(tag::PTAP_NUM);
            let _ = c.drain(tag::PTAP_SYM);
            c.stats_global()
        });
        for s in stats {
            assert_eq!(s.close_waits, 2, "one close barrier per drained epoch");
            // untraced frames carry no stamp: no flights observed
            assert_eq!(s.flight_msgs, 0);
            assert_eq!(s.flight_us, 0);
        }
    }

    #[test]
    fn stamped_frames_record_in_flight_time() {
        let w = World::new(2);
        let out = w.run(|c| {
            crate::obs::rank_begin(c.rank());
            let peer = 1 - c.rank();
            c.isend(peer, tag::PTAP_NUM, vec![5; 32]);
            c.isend(c.rank(), tag::PTAP_NUM, vec![6; 32]); // self: no flight
            let got = c.drain(tag::PTAP_NUM);
            let stats = c.stats_global();
            let buf = crate::obs::rank_take();
            (got.len(), stats, buf)
        });
        for (ngot, s, buf) in out {
            assert_eq!(ngot, 2);
            assert_eq!(s.flight_msgs, 1, "only the stamped remote frame counts");
            assert_eq!(s.flight_hist.iter().sum::<u64>(), 1);
            let flights = buf
                .events
                .iter()
                .filter(|e| matches!(e, crate::obs::Ev::Flight { .. }))
                .count();
            assert_eq!(flights, 1, "receiver records one flight event");
            let barriers = buf
                .events
                .iter()
                .filter(|e| {
                    matches!(e, crate::obs::Ev::Begin { name: "close_barrier", .. })
                })
                .count();
            assert_eq!(barriers, 1, "the drain records its close-barrier span");
        }
    }

    #[test]
    fn split_scopes_ranks_and_collectives() {
        let w = World::new(5);
        let out = w.run(|c| {
            // colors: {0,1,2} and {3,4}
            let color = usize::from(c.rank() >= 3);
            let sub = c.split(color);
            let sum = sub.allreduce_sum_u64(c.rank() as u64);
            (sub.rank(), sub.size(), sum)
        });
        assert_eq!(out[0], (0, 3, 3)); // 0+1+2
        assert_eq!(out[1], (1, 3, 3));
        assert_eq!(out[2], (2, 3, 3));
        assert_eq!(out[3], (0, 2, 7)); // 3+4
        assert_eq!(out[4], (1, 2, 7));
    }

    #[test]
    fn split_scopes_epochs_to_members_only() {
        // the active group runs several engine epochs while the idle
        // ranks never touch the tag — the close barrier spans members
        // only, so this would deadlock if idle ranks were required
        let w = World::new(4);
        let out = w.run(|c| {
            let active = c.rank() < 2;
            let sub = c.split(usize::from(!active));
            let mut got = Vec::new();
            if active {
                for e in 0..3u8 {
                    let peer = 1 - sub.rank();
                    sub.isend(peer, tag::GATHER, vec![e, sub.rank() as u8]);
                    got.extend(sub.drain(tag::GATHER));
                }
            }
            // everyone rejoins a world collective afterwards
            let total = c.allreduce_sum_u64(1);
            (got, total)
        });
        for (me, (got, total)) in out.iter().enumerate() {
            assert_eq!(*total, 4);
            if me < 2 {
                let peer = 1 - me;
                let want: Vec<(usize, Vec<u8>)> =
                    (0..3u8).map(|e| (peer, vec![e, peer as u8])).collect();
                assert_eq!(got, &want);
            } else {
                assert!(got.is_empty());
            }
        }
    }

    #[test]
    fn split_tags_do_not_cross_communicators() {
        // parent and child post on the same user tag concurrently; the
        // tag_base offset keeps the epochs apart
        let w = World::new(2);
        let out = w.run(|c| {
            let sub = c.split(0); // same members, new tag scope
            c.isend(1 - c.rank(), tag::GATHER, vec![1]);
            sub.isend(1 - sub.rank(), tag::GATHER, vec![2]);
            let parent = c.drain(tag::GATHER);
            let child = sub.drain(tag::GATHER);
            (parent, child)
        });
        for (me, (parent, child)) in out.iter().enumerate() {
            assert_eq!(parent, &vec![(1 - me, vec![1])]);
            assert_eq!(child, &vec![(1 - me, vec![2])]);
        }
    }

    #[test]
    fn split_stats_are_scoped_and_totals_global() {
        let w = World::new(4);
        let out = w.run(|c| {
            let sub = c.split(usize::from(c.rank() >= 2));
            let pre = c.stats().msgs;
            let _ = sub.exchange(vec![(1 - sub.rank(), vec![0; 16])]);
            (c.stats().msgs - pre, sub.stats(), c.stats_global())
        });
        for (parent_delta, sub_stats, global) in out {
            assert_eq!(parent_delta, 0, "subcomm traffic must not count in the parent scope");
            assert_eq!(sub_stats.msgs, 1);
            assert_eq!(sub_stats.bytes, 16);
            assert!(global.msgs >= sub_stats.msgs, "global totals include subcomm traffic");
        }
    }

    #[test]
    fn nested_split_scopes_compose() {
        let w = World::new(4);
        let out = w.run(|c| {
            let half = c.split(usize::from(c.rank() >= 2)); // {0,1} {2,3}
            let solo = half.split(half.rank()); // singletons
            let r = solo.exchange(vec![(0, vec![c.rank() as u8])]);
            (half.size(), solo.size(), r)
        });
        for (me, (hs, ss, r)) in out.iter().enumerate() {
            assert_eq!(*hs, 2);
            assert_eq!(*ss, 1);
            assert_eq!(r, &vec![(0, vec![me as u8])]);
        }
    }

    /// Telescoping regression (2 split boundaries): close-wait and flight
    /// histograms recorded under subcommunicators keep aggregating
    /// rank-wide through `stats_global()`, with totals matching the
    /// scalar counters; scoped `stats()` snapshots still carry zeros.
    #[test]
    fn telescoped_close_wait_and_flight_hists_aggregate_globally() {
        let w = World::new(4);
        let out = w.run(|c| {
            obs::rank_begin(c.rank()); // stamp frames so flights are observed
            let _ = c.exchange(vec![((c.rank() + 1) % c.size(), vec![1u8; 64])]);
            let half = c.split(usize::from(c.rank() >= 2)); // boundary 1: {0,1} {2,3}
            let _ = half.exchange(vec![(1 - half.rank(), vec![2u8; 256])]);
            let solo = half.split(half.rank()); // boundary 2: singletons
            let _ = solo.drain(tag::EXCHANGE);
            let _ = obs::rank_take();
            (c.stats(), half.stats(), c.stats_global())
        });
        for (scoped, half_scoped, global) in out {
            // Scoped snapshots carry no rank-wide barrier/flight fields.
            assert_eq!(scoped.close_waits + half_scoped.close_waits, 0);
            assert_eq!(scoped.close_wait_hist.iter().sum::<u64>(), 0);
            assert_eq!(half_scoped.close_wait_hist.iter().sum::<u64>(), 0);
            // Global totals fold every boundary: world exchange + half
            // exchange + singleton drain = 3 close barriers.
            assert_eq!(global.close_waits, 3);
            assert_eq!(
                global.close_wait_hist.iter().sum::<u64>(),
                global.close_waits,
                "every close barrier lands in exactly one latency bucket"
            );
            // One stamped world frame + one stamped subcomm frame arrived
            // at each rank; both flights land in the global histogram.
            assert_eq!(global.flight_msgs, 2);
            assert_eq!(global.flight_hist.iter().sum::<u64>(), global.flight_msgs);
            // The histograms ride through since() and merge().
            let delta = global.since(CommStats::default());
            assert_eq!(delta.close_wait_hist, global.close_wait_hist);
            let mut acc = CommStats::default();
            acc.merge(global);
            acc.merge(global);
            assert_eq!(acc.close_wait_hist.iter().sum::<u64>(), 2 * global.close_waits);
        }
    }
}
