//! Simulated MPI: a thread-per-rank world with deterministic collectives.
//!
//! [`World::run`] spawns one OS thread per rank and hands each a [`Comm`].
//! Communication runs over a full mesh of FIFO channels — one per ordered
//! rank pair — and every collective moves **exactly one frame per pair**,
//! so collectives stay aligned without barriers and a panicking rank
//! cascades cleanly (peers observe a disconnected channel) instead of
//! deadlocking the test suite.
//!
//! Determinism: received payloads are always ordered by source rank and
//! reductions combine in rank order, so every rank computes bit-identical
//! global values and repeated runs of a world reproduce byte-identical
//! messages.

use std::cell::Cell;
use std::sync::mpsc::{channel, Receiver, Sender};

/// α (per-message latency) of the α-β communication model, seconds.
/// Tuned to a commodity cluster interconnect (DESIGN.md §7).
pub const COMM_ALPHA_SECS: f64 = 2.0e-6;

/// β (per-byte) of the α-β communication model, seconds/byte (~2 GB/s).
pub const COMM_BETA_SECS_PER_BYTE: f64 = 5.0e-10;

/// Snapshot of one rank's cumulative send-side traffic.
#[derive(Debug, Default, Clone, Copy)]
pub struct CommStats {
    /// Point-to-point messages sent to other ranks.
    pub msgs: u64,
    /// Payload bytes sent to other ranks.
    pub bytes: u64,
}

impl CommStats {
    /// The α-β model applied to this rank's traffic.
    pub fn modeled_secs(&self) -> f64 {
        self.msgs as f64 * COMM_ALPHA_SECS + self.bytes as f64 * COMM_BETA_SECS_PER_BYTE
    }
}

/// One rank's endpoint of the simulated communicator.
pub struct Comm {
    rank: usize,
    np: usize,
    /// `tx[d]` sends one frame to rank `d` (index `rank` loops back).
    tx: Vec<Sender<Vec<u8>>>,
    /// `rx[s]` receives frames sent by rank `s`.
    rx: Vec<Receiver<Vec<u8>>>,
    sent_msgs: Cell<u64>,
    sent_bytes: Cell<u64>,
}

impl Comm {
    /// This rank's id, `0..size()`.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Number of ranks in the world.
    pub fn size(&self) -> usize {
        self.np
    }

    /// Cumulative send-side traffic of this rank.
    pub fn stats(&self) -> CommStats {
        CommStats { msgs: self.sent_msgs.get(), bytes: self.sent_bytes.get() }
    }

    /// One collective round: every rank sends exactly one frame to every
    /// rank (self included) and receives one frame from every rank.
    fn round(&self, frames: Vec<Vec<u8>>) -> Vec<Vec<u8>> {
        debug_assert_eq!(frames.len(), self.np);
        for (d, frame) in frames.into_iter().enumerate() {
            self.tx[d].send(frame).expect("peer rank terminated early");
        }
        (0..self.np)
            .map(|s| self.rx[s].recv().expect("peer rank panicked"))
            .collect()
    }

    /// Sparse all-to-all (collective): deliver each `(dest, payload)` pair
    /// and return the `(source, payload)` pairs addressed to this rank,
    /// ordered by source rank (then send order within a source).  Every
    /// rank must call this the same number of times; empty `sends` are
    /// fine.
    pub fn exchange(&self, sends: Vec<(usize, Vec<u8>)>) -> Vec<(usize, Vec<u8>)> {
        // frame per destination: [count u32, (len u32, bytes)*]
        let mut buckets: Vec<Vec<Vec<u8>>> = (0..self.np).map(|_| Vec::new()).collect();
        for (dest, payload) in sends {
            if dest != self.rank {
                self.sent_msgs.set(self.sent_msgs.get() + 1);
                self.sent_bytes.set(self.sent_bytes.get() + payload.len() as u64);
            }
            buckets[dest].push(payload);
        }
        let frames: Vec<Vec<u8>> = buckets
            .into_iter()
            .map(|payloads| {
                let total: usize = payloads.iter().map(|p| p.len() + 4).sum();
                let mut f = Vec::with_capacity(4 + total);
                f.extend_from_slice(&(payloads.len() as u32).to_le_bytes());
                for p in &payloads {
                    f.extend_from_slice(&(p.len() as u32).to_le_bytes());
                    f.extend_from_slice(p);
                }
                f
            })
            .collect();
        let recvd = self.round(frames);
        let mut out = Vec::new();
        for (src, frame) in recvd.into_iter().enumerate() {
            let count = u32::from_le_bytes(frame[0..4].try_into().unwrap()) as usize;
            let mut pos = 4usize;
            for _ in 0..count {
                let len = u32::from_le_bytes(frame[pos..pos + 4].try_into().unwrap()) as usize;
                pos += 4;
                out.push((src, frame[pos..pos + len].to_vec()));
                pos += len;
            }
        }
        out
    }

    /// Allgather of raw byte payloads (collective): returns one payload
    /// per rank, indexed by rank.
    pub fn allgather_bytes(&self, payload: Vec<u8>) -> Vec<Vec<u8>> {
        self.sent_msgs.set(self.sent_msgs.get() + (self.np as u64 - 1));
        self.sent_bytes
            .set(self.sent_bytes.get() + (self.np as u64 - 1) * payload.len() as u64);
        let frames: Vec<Vec<u8>> = (0..self.np).map(|_| payload.clone()).collect();
        self.round(frames)
    }

    /// Allgather of one `u64` per rank (collective), indexed by rank.
    pub fn all_u64(&self, v: u64) -> Vec<u64> {
        self.sent_msgs.set(self.sent_msgs.get() + (self.np as u64 - 1));
        self.sent_bytes.set(self.sent_bytes.get() + (self.np as u64 - 1) * 8);
        let frames: Vec<Vec<u8>> = (0..self.np).map(|_| v.to_le_bytes().to_vec()).collect();
        self.round(frames)
            .into_iter()
            .map(|f| u64::from_le_bytes(f[0..8].try_into().unwrap()))
            .collect()
    }

    /// Global sum of one `u64` per rank (collective).
    pub fn allreduce_sum_u64(&self, v: u64) -> u64 {
        self.all_u64(v).into_iter().sum()
    }

    /// Global sum of one `f64` per rank (collective).  Combines in rank
    /// order, so every rank computes the bit-identical result.
    pub fn allreduce_sum_f64(&self, v: f64) -> f64 {
        self.sent_msgs.set(self.sent_msgs.get() + (self.np as u64 - 1));
        self.sent_bytes.set(self.sent_bytes.get() + (self.np as u64 - 1) * 8);
        let frames: Vec<Vec<u8>> = (0..self.np).map(|_| v.to_le_bytes().to_vec()).collect();
        self.round(frames)
            .into_iter()
            .map(|f| f64::from_le_bytes(f[0..8].try_into().unwrap()))
            .sum()
    }
}

/// A set of `np` simulated ranks.
pub struct World {
    np: usize,
}

impl World {
    pub fn new(np: usize) -> World {
        assert!(np >= 1, "world needs at least one rank");
        World { np }
    }

    pub fn size(&self) -> usize {
        self.np
    }

    /// Run `f` once per rank on its own thread and return the per-rank
    /// results ordered by rank.  Scoped threads: `f` may borrow from the
    /// caller.  A panic in any rank propagates (preferring the original
    /// panic over the "peer died" cascades it triggers in other ranks).
    pub fn run<T, F>(&self, f: F) -> Vec<T>
    where
        F: Fn(Comm) -> T + Send + Sync,
        T: Send,
    {
        let np = self.np;
        // full channel mesh: pair (s, d) has its own FIFO
        let mut txs: Vec<Vec<Option<Sender<Vec<u8>>>>> =
            (0..np).map(|_| (0..np).map(|_| None).collect()).collect();
        let mut rxs: Vec<Vec<Option<Receiver<Vec<u8>>>>> =
            (0..np).map(|_| (0..np).map(|_| None).collect()).collect();
        for (s, row) in txs.iter_mut().enumerate() {
            for (d, slot) in row.iter_mut().enumerate() {
                let (tx, rx) = channel();
                *slot = Some(tx);
                rxs[d][s] = Some(rx);
            }
        }
        let comms: Vec<Comm> = txs
            .into_iter()
            .zip(rxs)
            .enumerate()
            .map(|(rank, (tx_row, rx_col))| Comm {
                rank,
                np,
                tx: tx_row.into_iter().map(|t| t.unwrap()).collect(),
                rx: rx_col.into_iter().map(|r| r.unwrap()).collect(),
                sent_msgs: Cell::new(0),
                sent_bytes: Cell::new(0),
            })
            .collect();

        let f_ref = &f;
        let joined: Vec<std::thread::Result<T>> = std::thread::scope(|scope| {
            let handles: Vec<_> = comms
                .into_iter()
                .map(|comm| scope.spawn(move || f_ref(comm)))
                .collect();
            handles.into_iter().map(|h| h.join()).collect()
        });
        // prefer the original panic over "peer rank ..." cascades
        if joined.iter().any(|r| r.is_err()) {
            let is_cascade = |p: &(dyn std::any::Any + Send)| -> bool {
                let msg = p
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| p.downcast_ref::<String>().cloned())
                    .unwrap_or_default();
                msg.contains("peer rank")
            };
            let mut cascade = None;
            for r in joined {
                if let Err(p) = r {
                    if !is_cascade(p.as_ref()) {
                        std::panic::resume_unwind(p);
                    }
                    cascade.get_or_insert(p);
                }
            }
            std::panic::resume_unwind(cascade.unwrap());
        }
        joined.into_iter().map(|r| r.unwrap()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_ordered_by_rank() {
        let w = World::new(4);
        let out = w.run(|c| (c.rank(), c.size()));
        assert_eq!(out, vec![(0, 4), (1, 4), (2, 4), (3, 4)]);
    }

    #[test]
    fn exchange_routes_and_orders_by_source() {
        let w = World::new(3);
        let all = w.run(|c| {
            // every rank sends its id to every *other* rank
            let sends: Vec<(usize, Vec<u8>)> = (0..c.size())
                .filter(|&d| d != c.rank())
                .map(|d| (d, vec![c.rank() as u8]))
                .collect();
            c.exchange(sends)
        });
        for (me, inbox) in all.iter().enumerate() {
            let srcs: Vec<usize> = inbox.iter().map(|&(s, _)| s).collect();
            let want: Vec<usize> = (0..3).filter(|&s| s != me).collect();
            assert_eq!(srcs, want);
            for (s, p) in inbox {
                assert_eq!(p, &vec![*s as u8]);
            }
        }
    }

    #[test]
    fn exchange_supports_empty_and_multiple_payloads() {
        let w = World::new(2);
        let all = w.run(|c| {
            if c.rank() == 0 {
                c.exchange(vec![(1, vec![1]), (1, vec![2, 3])])
            } else {
                c.exchange(Vec::new())
            }
        });
        assert!(all[0].is_empty());
        assert_eq!(all[1], vec![(0, vec![1]), (0, vec![2, 3])]);
    }

    #[test]
    fn collectives_compose_over_many_rounds() {
        let w = World::new(3);
        let sums = w.run(|c| {
            let mut acc = 0u64;
            for round in 0..50u64 {
                acc += c.allreduce_sum_u64(round + c.rank() as u64);
            }
            acc
        });
        assert!(sums.iter().all(|&s| s == sums[0]));
    }

    #[test]
    fn allgather_indexed_by_rank() {
        let w = World::new(3);
        let all = w.run(|c| c.allgather_bytes(vec![c.rank() as u8 * 10]));
        for per_rank in all {
            assert_eq!(per_rank, vec![vec![0], vec![10], vec![20]]);
        }
    }

    #[test]
    fn reduce_f64_is_identical_on_all_ranks() {
        let w = World::new(4);
        let vals = w.run(|c| c.allreduce_sum_f64(0.1 * (c.rank() as f64 + 1.0)));
        assert!(vals.iter().all(|v| v.to_bits() == vals[0].to_bits()));
    }

    #[test]
    fn stats_count_remote_traffic_only() {
        let w = World::new(2);
        let stats = w.run(|c| {
            let _ = c.exchange(vec![(c.rank(), vec![9; 100]), ((c.rank() + 1) % 2, vec![7; 8])]);
            c.stats()
        });
        for s in stats {
            assert_eq!(s.msgs, 1);
            assert_eq!(s.bytes, 8);
        }
    }

    #[test]
    fn single_rank_world_loops_back() {
        let w = World::new(1);
        let out = w.run(|c| {
            let r = c.exchange(vec![(0, vec![42])]);
            assert_eq!(r, vec![(0, vec![42])]);
            assert_eq!(c.all_u64(7), vec![7]);
            c.allreduce_sum_u64(3)
        });
        assert_eq!(out, vec![3]);
    }
}
