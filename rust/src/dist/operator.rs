//! The distributed-operator abstraction: what the smoothers, Krylov
//! solvers, and the V-cycle's level-0 hot loop actually need from "a
//! matrix".  Two implementations exist — [`CsrOperator`] viewing an
//! assembled [`DistCsr`] + [`DistSpmv`] pair, and the matrix-free
//! [`crate::gen::StencilOperator`] that evaluates the generators'
//! stencils directly — and both fold rows in ascending *global* column
//! order, so swapping one for the other changes no bits anywhere in the
//! solve.

use super::csr::DistCsr;
use super::vec::{DistMultiVec, DistSpmv, DistVec};
use super::world::Comm;
use crate::dist::Layout;

/// A distributed linear operator with square row/column ownership (the
/// level-operator shape): apply, diagonal/row-norm extraction for the
/// smoothers, processor-block SOR relaxation, and the memory/nnz
/// accounting the reports read.
///
/// Contract shared by all implementations:
/// - `apply` folds each row in ascending global column order, so the
///   product's bits are partition-invariant;
/// - `sor_sweep` relaxes the local block in row order (forward, then
///   backward when `symmetric`) against a halo frozen at sweep start,
///   subtracting owned-column entries in ascending global order and then
///   off-rank entries in ascending global order — the
///   [`DistCsr`] diag-then-offd order;
/// - the collective counters (`row_nnz_stats`, `nnz_global`) issue the
///   same collective sequence in every implementation, so mixed
///   CSR/matrix-free ranks would stay in lockstep.
pub trait DistOperator {
    fn rank(&self) -> usize;
    fn row_layout(&self) -> &Layout;
    /// Owned rows on this rank.
    fn local_nrows(&self) -> usize {
        self.row_layout().local_size(self.rank())
    }
    fn global_nrows(&self) -> usize {
        self.row_layout().global_size()
    }
    /// `y = A x` (collective).
    fn apply(&self, comm: &Comm, x: &DistVec, y: &mut DistVec);
    /// Local diagonal entries `a_ii` (0.0 where the row has no diagonal
    /// entry); the smoothers own the invert-with-fallback policy.
    fn diagonal(&self) -> Vec<f64>;
    /// Local 1-norms of the rows (diag + offd entries).
    fn row_norms1(&self) -> Vec<f64>;
    /// Global (min, max, avg) nonzeros per row (collective).
    fn row_nnz_stats(&self, comm: &Comm) -> (u64, u64, f64);
    /// Global nonzero count (collective).
    fn nnz_global(&self, comm: &Comm) -> u64;
    /// Heap bytes this rank holds for the operator.
    fn bytes(&self) -> u64;
    /// Hybrid (processor-block) SOR relaxation: Gauss-Seidel over the
    /// local rows with `x[i] += omega*(dinv[i]*acc - x[i])`, halo frozen
    /// at sweep start (collective: one halo gather).
    fn sor_sweep(
        &self,
        comm: &Comm,
        dinv: &[f64],
        omega: f64,
        b: &DistVec,
        x: &mut DistVec,
        symmetric: bool,
    );
    /// Halo gathers served from a warm persistent buffer since build.
    fn halo_reuses(&self) -> u64;

    /// `Y = A X` for K stacked right-hand sides (collective).  Column `j`
    /// of `Y` must be bitwise the scalar `apply` of column `j`.  The
    /// default loops columns (K separate halo epochs); implementations
    /// override it with a blocked kernel that pays one epoch for all K.
    fn apply_multi(&self, comm: &Comm, x: &DistMultiVec, y: &mut DistMultiVec) {
        debug_assert_eq!(x.k, y.k);
        for j in 0..x.k {
            let xj = x.column(j);
            let mut yj = y.column(j);
            self.apply(comm, &xj, &mut yj);
            y.set_column(j, &yj);
        }
    }

    /// Blocked hybrid SOR: relax all K columns against one frozen K-wide
    /// halo.  Column `j` must be bitwise the scalar `sor_sweep` of column
    /// `j`.  Default loops columns; overrides pay one halo epoch.
    #[allow(clippy::too_many_arguments)]
    fn sor_sweep_multi(
        &self,
        comm: &Comm,
        dinv: &[f64],
        omega: f64,
        b: &DistMultiVec,
        x: &mut DistMultiVec,
        symmetric: bool,
    ) {
        debug_assert_eq!(x.k, b.k);
        for j in 0..x.k {
            let bj = b.column(j);
            let mut xj = x.column(j);
            self.sor_sweep(comm, dinv, omega, &bj, &mut xj, symmetric);
            x.set_column(j, &xj);
        }
    }
}

/// [`DistOperator`] view over an assembled matrix: borrows the
/// [`DistCsr`] tables and the prebuilt [`DistSpmv`] halo plan.
pub struct CsrOperator<'a> {
    pub a: &'a DistCsr,
    pub spmv: &'a DistSpmv,
}

impl<'a> CsrOperator<'a> {
    pub fn new(a: &'a DistCsr, spmv: &'a DistSpmv) -> Self {
        CsrOperator { a, spmv }
    }

    #[inline]
    fn relax_row(&self, halo: &[f64], dinv: &[f64], omega: f64, b: &DistVec, x: &mut DistVec, i: usize) {
        let a = self.a;
        let mut acc = b.vals[i];
        let (dc, dv) = a.diag.row(i);
        for (&c, &v) in dc.iter().zip(dv) {
            if c as usize != i {
                acc -= v * x.vals[c as usize];
            }
        }
        let (oc, ov) = a.offd.row(i);
        for (&c, &v) in oc.iter().zip(ov) {
            acc -= v * halo[c as usize];
        }
        x.vals[i] += omega * (dinv[i] * acc - x.vals[i]);
    }

    /// K-wide relaxation of row `i`: each column runs the exact
    /// [`CsrOperator::relax_row`] subtraction order against the K-wide
    /// frozen halo, so column bits match the scalar sweep.
    #[inline]
    #[allow(clippy::too_many_arguments)]
    fn relax_row_multi(
        &self,
        halo: &[f64],
        dinv: &[f64],
        omega: f64,
        b: &DistMultiVec,
        x: &mut DistMultiVec,
        acc: &mut [f64],
        i: usize,
    ) {
        let a = self.a;
        let k = x.k;
        acc.copy_from_slice(&b.vals[i * k..(i + 1) * k]);
        let (dc, dv) = a.diag.row(i);
        for (&c, &v) in dc.iter().zip(dv) {
            let c = c as usize;
            if c != i {
                for (j, aj) in acc.iter_mut().enumerate() {
                    *aj -= v * x.vals[c * k + j];
                }
            }
        }
        let (oc, ov) = a.offd.row(i);
        for (&c, &v) in oc.iter().zip(ov) {
            let c = c as usize;
            for (j, aj) in acc.iter_mut().enumerate() {
                *aj -= v * halo[c * k + j];
            }
        }
        for (j, &aj) in acc.iter().enumerate() {
            let xi = &mut x.vals[i * k + j];
            *xi += omega * (dinv[i] * aj - *xi);
        }
    }
}

impl DistOperator for CsrOperator<'_> {
    fn rank(&self) -> usize {
        self.a.rank
    }

    fn row_layout(&self) -> &Layout {
        &self.a.row_layout
    }

    fn apply(&self, comm: &Comm, x: &DistVec, y: &mut DistVec) {
        let _sp = crate::obs::span(crate::obs::Subsys::Solve, "spmv", self.a.local_nrows() as u64);
        self.spmv.apply(comm, self.a, x, y);
    }

    fn diagonal(&self) -> Vec<f64> {
        let n = self.a.local_nrows();
        let mut d = vec![0.0; n];
        for (i, di) in d.iter_mut().enumerate() {
            let (cols, vals) = self.a.diag.row(i);
            if let Some((_, &v)) = cols.iter().zip(vals).find(|&(&c, _)| c as usize == i) {
                *di = v;
            }
        }
        d
    }

    fn row_norms1(&self) -> Vec<f64> {
        let n = self.a.local_nrows();
        let mut norms = vec![0.0; n];
        for (i, ni) in norms.iter_mut().enumerate() {
            let (_, dv) = self.a.diag.row(i);
            let (_, ov) = self.a.offd.row(i);
            *ni = dv.iter().chain(ov).map(|v| v.abs()).sum();
        }
        norms
    }

    fn row_nnz_stats(&self, comm: &Comm) -> (u64, u64, f64) {
        self.a.row_nnz_stats(comm)
    }

    fn nnz_global(&self, comm: &Comm) -> u64 {
        self.a.nnz_global(comm)
    }

    fn bytes(&self) -> u64 {
        self.a.bytes()
    }

    fn sor_sweep(
        &self,
        comm: &Comm,
        dinv: &[f64],
        omega: f64,
        b: &DistVec,
        x: &mut DistVec,
        symmetric: bool,
    ) {
        let halo = self.spmv.gather_halo(comm, x);
        for i in 0..self.a.local_nrows() {
            self.relax_row(&halo, dinv, omega, b, x, i);
        }
        if symmetric {
            for i in (0..self.a.local_nrows()).rev() {
                self.relax_row(&halo, dinv, omega, b, x, i);
            }
        }
    }

    fn halo_reuses(&self) -> u64 {
        self.spmv.halo_reuses()
    }

    fn apply_multi(&self, comm: &Comm, x: &DistMultiVec, y: &mut DistMultiVec) {
        let _sp = crate::obs::span(crate::obs::Subsys::Solve, "spmv.multi", x.k as u64);
        self.spmv.apply_multi(comm, self.a, x, y);
    }

    fn sor_sweep_multi(
        &self,
        comm: &Comm,
        dinv: &[f64],
        omega: f64,
        b: &DistMultiVec,
        x: &mut DistMultiVec,
        symmetric: bool,
    ) {
        let halo = self.spmv.gather_halo_multi(comm, x);
        let mut acc = vec![0.0; x.k];
        for i in 0..self.a.local_nrows() {
            self.relax_row_multi(&halo, dinv, omega, b, x, &mut acc, i);
        }
        if symmetric {
            for i in (0..self.a.local_nrows()).rev() {
                self.relax_row_multi(&halo, dinv, omega, b, x, &mut acc, i);
            }
        }
    }
}
