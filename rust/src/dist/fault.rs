//! Deterministic fault injection for the simulated transport.
//!
//! A [`FaultPlan`] is a seeded list of per-rank, per-tag-class rules —
//! drop, blackhole (drop without a retransmit copy), bit-flip
//! corruption, duplication, delay (reorder), and a transient rank stall.
//! The plan is installed into the [`super::world::World`] (CLI
//! `--fault-plan` or the `GPTAP_FAULT` env) and consulted on the send
//! side of every data frame, behind a zero-cost-when-absent
//! `Option` check: with no plan the transport takes its original path.
//!
//! Decisions are drawn from a per-rank xoshiro stream seeded from
//! `(plan.seed, world_rank)`, so a given (plan, world size, program)
//! triple injects the exact same faults on every run — chaos results are
//! reproducible, and the reliability layer's recovery can be asserted
//! bitwise against a fault-free run.
//!
//! ## Plan grammar
//!
//! Semicolon-separated items; each item is `seed=N` or one rule of
//! comma-separated `key=value` pairs:
//!
//! ```text
//! seed=7;rank=*,tag=*,drop=0.05;rank=1,tag=gather,corrupt=0.02
//! tag=ptap_num,delay=0.2,hold=3
//! rank=2,tag=*,stall_ms=5,nth=10
//! ```
//!
//! - `rank=<r|*>` — world rank whose *sends* the rule matches (default `*`);
//! - `tag=<class|*>` — user tag class (`exchange`, `gather`, `ptap_sym`,
//!   `ptap_num`, `redist`, or a number; default `*`);
//! - exactly one action: `drop=p`, `blackhole=p`, `corrupt=p`, `dup=p`,
//!   `delay=p` (with optional `hold=k` sends, default 3), or
//!   `stall_ms=m` (with optional `nth=n`, default 1: sleep `m` ms once,
//!   at the rule's n-th matching send).
//!
//! Collective frames are never faulted: the reliability protocol covers
//! the epoch engine, and faulting barrier frames would only test the
//! timeout path, which has its own hook.

use crate::util::prng::Rng;
use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::fmt;

/// Environment variable holding a fault-plan spec for every [`super::World`].
pub const ENV_FAULT: &str = "GPTAP_FAULT";

/// What the plan does to one matching data frame.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultAction {
    /// Don't transmit; the retransmit buffer recovers it on NACK.
    Drop { p: f64 },
    /// Don't transmit AND don't keep a retransmit copy: a permanent
    /// loss, which the receiver's deadline turns into a `CommError`.
    Blackhole { p: f64 },
    /// Flip one payload bit in the transmitted copy (the retransmit
    /// copy stays intact, so the NACK round recovers the true bytes).
    Corrupt { p: f64 },
    /// Transmit the frame twice (duplicate suppression eats the echo).
    Duplicate { p: f64 },
    /// Park the frame and release it after `hold` later sends to the
    /// same destination (or at epoch close) — genuine reordering.
    Delay { p: f64, hold: u32 },
    /// Sleep `ms` milliseconds once, at this rule's `nth` matching send:
    /// a transient rank stall.
    Stall { ms: u64, nth: u64 },
}

/// One plan rule: scope (sender rank, user tag class) plus an action.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultRule {
    /// Sender world rank the rule applies to (`None` = every rank).
    pub rank: Option<usize>,
    /// User tag class the rule applies to (`None` = every class).
    pub tag: Option<u32>,
    pub action: FaultAction,
}

impl FaultRule {
    fn matches(&self, rank: usize, tag_class: u32) -> bool {
        self.rank.is_none_or(|r| r == rank) && self.tag.is_none_or(|t| t == tag_class)
    }
}

/// A seeded, deterministic fault schedule for one world.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    pub rules: Vec<FaultRule>,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan { seed: 0x5eed, rules: Vec::new() }
    }
}

fn parse_prob(key: &str, v: &str) -> Result<f64, String> {
    let p: f64 =
        v.parse().map_err(|_| format!("fault plan: bad probability '{v}' for '{key}'"))?;
    if !(0.0..=1.0).contains(&p) {
        return Err(format!("fault plan: probability '{key}={p}' outside [0, 1]"));
    }
    Ok(p)
}

fn parse_tag_class(v: &str) -> Result<u32, String> {
    use super::world::tag;
    Ok(match v {
        "exchange" => tag::EXCHANGE,
        "gather" => tag::GATHER,
        "ptap_sym" => tag::PTAP_SYM,
        "ptap_num" => tag::PTAP_NUM,
        "redist" => tag::REDIST,
        _ => v.parse().map_err(|_| format!("fault plan: unknown tag class '{v}'"))?,
    })
}

fn tag_class_name(t: u32) -> String {
    use super::world::tag;
    match t {
        tag::EXCHANGE => "exchange".into(),
        tag::GATHER => "gather".into(),
        tag::PTAP_SYM => "ptap_sym".into(),
        tag::PTAP_NUM => "ptap_num".into(),
        tag::REDIST => "redist".into(),
        other => other.to_string(),
    }
}

impl FaultPlan {
    /// A plan with a seed and no rules: arms the reliability layer
    /// (checksums, ACK barriers) without injecting any fault — what the
    /// overhead bench and the zero-retransmit assertions run under.
    pub fn empty(seed: u64) -> FaultPlan {
        FaultPlan { seed, rules: Vec::new() }
    }

    /// Parse the plan grammar (module docs).  Errors name the offending
    /// key so a bad `--fault-plan` fails fast and legibly.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        for item in spec.split(';').map(str::trim).filter(|s| !s.is_empty()) {
            if let Some(v) = item.strip_prefix("seed=") {
                plan.seed = v
                    .trim()
                    .parse()
                    .map_err(|_| format!("fault plan: bad seed '{v}'"))?;
                continue;
            }
            let mut rank = None;
            let mut tag = None;
            let mut action: Option<FaultAction> = None;
            let mut hold: Option<u32> = None;
            let mut nth: Option<u64> = None;
            let mut set_action = |a: FaultAction| -> Result<(), String> {
                if action.is_some() {
                    return Err(format!("fault plan: rule '{item}' has two actions"));
                }
                action = Some(a);
                Ok(())
            };
            for pair in item.split(',').map(str::trim).filter(|s| !s.is_empty()) {
                let (key, v) = pair
                    .split_once('=')
                    .ok_or_else(|| format!("fault plan: expected key=value, got '{pair}'"))?;
                let (key, v) = (key.trim(), v.trim());
                match key {
                    "rank" => {
                        rank = if v == "*" {
                            None
                        } else {
                            Some(
                                v.parse::<usize>()
                                    .map_err(|_| format!("fault plan: bad rank '{v}'"))?,
                            )
                        }
                    }
                    "tag" => {
                        tag = if v == "*" { None } else { Some(parse_tag_class(v)?) };
                    }
                    "drop" => set_action(FaultAction::Drop { p: parse_prob(key, v)? })?,
                    "blackhole" => {
                        set_action(FaultAction::Blackhole { p: parse_prob(key, v)? })?
                    }
                    "corrupt" => set_action(FaultAction::Corrupt { p: parse_prob(key, v)? })?,
                    "dup" => set_action(FaultAction::Duplicate { p: parse_prob(key, v)? })?,
                    "delay" => {
                        set_action(FaultAction::Delay { p: parse_prob(key, v)?, hold: 3 })?
                    }
                    "hold" => {
                        hold = Some(
                            v.parse().map_err(|_| format!("fault plan: bad hold '{v}'"))?,
                        )
                    }
                    "stall_ms" => set_action(FaultAction::Stall {
                        ms: v.parse().map_err(|_| format!("fault plan: bad stall_ms '{v}'"))?,
                        nth: 1,
                    })?,
                    "nth" => {
                        nth = Some(
                            v.parse().map_err(|_| format!("fault plan: bad nth '{v}'"))?,
                        )
                    }
                    other => return Err(format!("fault plan: unknown key '{other}'")),
                }
            }
            let mut action =
                action.ok_or_else(|| format!("fault plan: rule '{item}' has no action"))?;
            match (&mut action, hold, nth) {
                (FaultAction::Delay { hold: h, .. }, Some(k), _) => *h = k.max(1),
                (_, Some(_), _) => {
                    return Err("fault plan: 'hold' only applies to 'delay' rules".into())
                }
                (FaultAction::Stall { nth: n, .. }, _, Some(k)) => *n = k.max(1),
                (_, _, Some(_)) => {
                    return Err("fault plan: 'nth' only applies to 'stall_ms' rules".into())
                }
                _ => {}
            }
            plan.rules.push(FaultRule { rank, tag, action });
        }
        Ok(plan)
    }

    /// Plan from `GPTAP_FAULT`, if set.  An unparsable spec panics:
    /// silently running fault-free when chaos was requested would
    /// invalidate whatever the caller was soaking.
    pub fn from_env() -> Option<FaultPlan> {
        let spec = std::env::var(ENV_FAULT).ok()?;
        if spec.trim().is_empty() {
            return None;
        }
        match FaultPlan::parse(&spec) {
            Ok(p) => Some(p),
            Err(e) => panic!("{ENV_FAULT}: {e}"),
        }
    }
}

impl fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "seed={}", self.seed)?;
        for r in &self.rules {
            write!(f, ";rank=")?;
            match r.rank {
                Some(k) => write!(f, "{k}")?,
                None => write!(f, "*")?,
            }
            write!(f, ",tag=")?;
            match r.tag {
                Some(t) => write!(f, "{}", tag_class_name(t))?,
                None => write!(f, "*")?,
            }
            match r.action {
                FaultAction::Drop { p } => write!(f, ",drop={p}")?,
                FaultAction::Blackhole { p } => write!(f, ",blackhole={p}")?,
                FaultAction::Corrupt { p } => write!(f, ",corrupt={p}")?,
                FaultAction::Duplicate { p } => write!(f, ",dup={p}")?,
                FaultAction::Delay { p, hold } => write!(f, ",delay={p},hold={hold}")?,
                FaultAction::Stall { ms, nth } => write!(f, ",stall_ms={ms},nth={nth}")?,
            }
        }
        Ok(())
    }
}

/// What the transport should do with one outgoing data frame.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SendFate {
    Deliver,
    Drop,
    Blackhole,
    Corrupt,
    Duplicate,
    Delay { hold: u32 },
}

/// One send's verdict: a fate plus an optional stall (the stall applies
/// on top of whatever the fate is — a stalled rank still sends).
#[derive(Debug, Clone, Copy)]
pub struct FaultDecision {
    pub fate: SendFate,
    pub stall_ms: u64,
}

/// Cumulative faults this rank's plan has injected, by kind.
#[derive(Debug, Default, Clone, Copy)]
pub struct FaultCounts {
    pub drops: u64,
    pub blackholes: u64,
    pub corruptions: u64,
    pub duplicates: u64,
    pub delays: u64,
    pub stalls: u64,
}

impl FaultCounts {
    pub fn total(&self) -> u64 {
        self.drops + self.blackholes + self.corruptions + self.duplicates + self.delays
            + self.stalls
    }
}

/// A parked (delayed) frame: released after `after` more sends to its
/// destination, or when an epoch close flushes the destination's limbo.
struct Parked {
    frame: Vec<u8>,
    after: u32,
}

/// Per-rank runtime of a [`FaultPlan`]: the seeded decision stream, the
/// per-rule stall counters, the delay limbo, and the injected-fault
/// counters the chaos harness reports.
pub struct FaultState {
    plan: FaultPlan,
    rank: usize,
    rng: RefCell<Rng>,
    /// Matching-send count per rule (drives `stall nth`).
    rule_hits: Vec<Cell<u64>>,
    /// Delayed frames per destination world rank.
    limbo: RefCell<HashMap<usize, Vec<Parked>>>,
    drops: Cell<u64>,
    blackholes: Cell<u64>,
    corruptions: Cell<u64>,
    duplicates: Cell<u64>,
    delays: Cell<u64>,
    stalls: Cell<u64>,
}

impl FaultState {
    pub fn new(plan: FaultPlan, world_rank: usize) -> FaultState {
        // Decorrelate ranks without losing determinism: golden-ratio
        // stride on the world rank, folded into the plan seed.
        let seed = plan
            .seed
            .wrapping_add((world_rank as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let nrules = plan.rules.len();
        FaultState {
            plan,
            rank: world_rank,
            rng: RefCell::new(Rng::new(seed)),
            rule_hits: (0..nrules).map(|_| Cell::new(0)).collect(),
            limbo: RefCell::new(HashMap::new()),
            drops: Cell::new(0),
            blackholes: Cell::new(0),
            corruptions: Cell::new(0),
            duplicates: Cell::new(0),
            delays: Cell::new(0),
            stalls: Cell::new(0),
        }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Decide the fate of one outgoing data frame on user tag class
    /// `tag_class`.  Rules are evaluated in plan order; the first
    /// probabilistic rule that fires wins the fate (every matching rule
    /// still draws, so one rule's outcome never shifts another's
    /// stream).  Stalls stack on top of the fate.
    pub fn decide(&self, tag_class: u32) -> FaultDecision {
        let mut rng = self.rng.borrow_mut();
        let mut fate = SendFate::Deliver;
        let mut stall_ms = 0u64;
        for (i, rule) in self.plan.rules.iter().enumerate() {
            if !rule.matches(self.rank, tag_class) {
                continue;
            }
            let hits = &self.rule_hits[i];
            hits.set(hits.get() + 1);
            match rule.action {
                FaultAction::Stall { ms, nth } => {
                    if hits.get() == nth {
                        stall_ms += ms;
                        self.stalls.set(self.stalls.get() + 1);
                    }
                }
                FaultAction::Drop { p } => {
                    let hit = rng.chance(p);
                    if hit && fate == SendFate::Deliver {
                        fate = SendFate::Drop;
                        self.drops.set(self.drops.get() + 1);
                    }
                }
                FaultAction::Blackhole { p } => {
                    let hit = rng.chance(p);
                    if hit && fate == SendFate::Deliver {
                        fate = SendFate::Blackhole;
                        self.blackholes.set(self.blackholes.get() + 1);
                    }
                }
                FaultAction::Corrupt { p } => {
                    let hit = rng.chance(p);
                    if hit && fate == SendFate::Deliver {
                        fate = SendFate::Corrupt;
                        self.corruptions.set(self.corruptions.get() + 1);
                    }
                }
                FaultAction::Duplicate { p } => {
                    let hit = rng.chance(p);
                    if hit && fate == SendFate::Deliver {
                        fate = SendFate::Duplicate;
                        self.duplicates.set(self.duplicates.get() + 1);
                    }
                }
                FaultAction::Delay { p, hold } => {
                    let hit = rng.chance(p);
                    if hit && fate == SendFate::Deliver {
                        fate = SendFate::Delay { hold };
                        self.delays.set(self.delays.get() + 1);
                    }
                }
            }
        }
        FaultDecision { fate, stall_ms }
    }

    /// Park a delayed frame for `dest`.
    pub fn park(&self, dest: usize, frame: Vec<u8>, hold: u32) {
        self.limbo.borrow_mut().entry(dest).or_default().push(Parked { frame, after: hold });
    }

    /// One more send went to `dest`: age its parked frames and return the
    /// ones due for release, in park order.
    pub fn tick(&self, dest: usize) -> Vec<Vec<u8>> {
        let mut limbo = self.limbo.borrow_mut();
        let Some(q) = limbo.get_mut(&dest) else { return Vec::new() };
        for p in q.iter_mut() {
            p.after = p.after.saturating_sub(1);
        }
        let mut due = Vec::new();
        q.retain_mut(|p| {
            if p.after == 0 {
                due.push(std::mem::take(&mut p.frame));
                false
            } else {
                true
            }
        });
        due
    }

    /// Epoch close for `dest`: everything still parked is released now —
    /// after the close sentinel, which is the genuine reorder the delay
    /// rule exists to produce (the receiver's sequence numbers put it
    /// back).
    pub fn flush_parked(&self, dest: usize) -> Vec<Vec<u8>> {
        self.limbo
            .borrow_mut()
            .remove(&dest)
            .map(|q| q.into_iter().map(|p| p.frame).collect())
            .unwrap_or_default()
    }

    /// Injected-fault counters so far.
    pub fn counts(&self) -> FaultCounts {
        FaultCounts {
            drops: self.drops.get(),
            blackholes: self.blackholes.get(),
            corruptions: self.corruptions.get(),
            duplicates: self.duplicates.get(),
            delays: self.delays.get(),
            stalls: self.stalls.get(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::tag;

    #[test]
    fn grammar_round_trip() {
        let p = FaultPlan::parse(
            "seed=7; rank=*,tag=*,drop=0.05; rank=1,tag=gather,corrupt=0.02; \
             tag=ptap_num,delay=0.2,hold=5; rank=2,stall_ms=4,nth=10; tag=3,dup=0.1",
        )
        .unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.rules.len(), 5);
        assert_eq!(p.rules[0], FaultRule {
            rank: None,
            tag: None,
            action: FaultAction::Drop { p: 0.05 }
        });
        assert_eq!(p.rules[1], FaultRule {
            rank: Some(1),
            tag: Some(tag::GATHER),
            action: FaultAction::Corrupt { p: 0.02 }
        });
        assert_eq!(p.rules[2], FaultRule {
            rank: None,
            tag: Some(tag::PTAP_NUM),
            action: FaultAction::Delay { p: 0.2, hold: 5 }
        });
        assert_eq!(p.rules[3], FaultRule {
            rank: Some(2),
            tag: None,
            action: FaultAction::Stall { ms: 4, nth: 10 }
        });
        assert_eq!(p.rules[4], FaultRule {
            rank: None,
            tag: Some(tag::PTAP_NUM),
            action: FaultAction::Duplicate { p: 0.1 }
        });
        // Display re-parses to the same plan.
        let again = FaultPlan::parse(&p.to_string()).unwrap();
        assert_eq!(again, p);
    }

    #[test]
    fn grammar_rejects_garbage() {
        assert!(FaultPlan::parse("drop=2.0").is_err(), "probability above 1");
        assert!(FaultPlan::parse("rank=0,tag=*").is_err(), "rule without action");
        assert!(FaultPlan::parse("frobnicate=1").is_err(), "unknown key");
        assert!(FaultPlan::parse("tag=nonsense,drop=0.1").is_err(), "unknown tag class");
        assert!(FaultPlan::parse("drop=0.1,corrupt=0.1").is_err(), "two actions in one rule");
        assert!(FaultPlan::parse("drop=0.1,nth=3").is_err(), "nth without stall");
        assert!(FaultPlan::parse("corrupt=0.1,hold=3").is_err(), "hold without delay");
        assert!(FaultPlan::parse("seed=x").is_err(), "bad seed");
    }

    #[test]
    fn empty_spec_is_an_empty_plan() {
        let p = FaultPlan::parse("seed=9").unwrap();
        assert_eq!(p, FaultPlan::empty(9));
        assert!(p.rules.is_empty());
    }

    #[test]
    fn decisions_are_deterministic_per_rank_and_differ_across_ranks() {
        let plan = FaultPlan::parse("seed=11;tag=*,drop=0.3").unwrap();
        let run = |rank: usize| -> Vec<SendFate> {
            let fs = FaultState::new(plan.clone(), rank);
            (0..256).map(|_| fs.decide(tag::PTAP_NUM).fate).collect()
        };
        assert_eq!(run(0), run(0), "same (seed, rank) must replay identically");
        assert_ne!(run(0), run(1), "ranks must draw decorrelated streams");
        let drops = run(0).iter().filter(|f| **f == SendFate::Drop).count();
        assert!((20..=140).contains(&drops), "p=0.3 of 256 sends, got {drops} drops");
    }

    #[test]
    fn rule_scope_filters_rank_and_class() {
        let plan = FaultPlan::parse("seed=3;rank=1,tag=gather,drop=1.0").unwrap();
        let on_scope = FaultState::new(plan.clone(), 1);
        assert_eq!(on_scope.decide(tag::GATHER).fate, SendFate::Drop);
        assert_eq!(on_scope.decide(tag::PTAP_NUM).fate, SendFate::Deliver);
        let off_rank = FaultState::new(plan, 0);
        assert_eq!(off_rank.decide(tag::GATHER).fate, SendFate::Deliver);
        assert_eq!(off_rank.counts().total(), 0);
    }

    #[test]
    fn stall_fires_once_at_nth_matching_send() {
        let plan = FaultPlan::parse("seed=1;tag=*,stall_ms=7,nth=3").unwrap();
        let fs = FaultState::new(plan, 0);
        let stalls: Vec<u64> = (0..5).map(|_| fs.decide(tag::EXCHANGE).stall_ms).collect();
        assert_eq!(stalls, vec![0, 0, 7, 0, 0]);
        assert_eq!(fs.counts().stalls, 1);
    }

    #[test]
    fn limbo_ages_and_flushes() {
        let plan = FaultPlan::empty(1);
        let fs = FaultState::new(plan, 0);
        fs.park(2, vec![1], 2);
        fs.park(2, vec![2], 1);
        assert_eq!(fs.tick(2), vec![vec![2]], "hold=1 frame due after one send");
        assert_eq!(fs.tick(3), Vec::<Vec<u8>>::new(), "other destinations unaffected");
        assert_eq!(fs.tick(2), vec![vec![1]]);
        fs.park(2, vec![3], 10);
        assert_eq!(fs.flush_parked(2), vec![vec![3]], "close flushes regardless of hold");
        assert!(fs.flush_parked(2).is_empty());
    }
}
