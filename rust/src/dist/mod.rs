//! Distributed-memory substrate (simulated MPI, PETSc-shaped).
//!
//! The paper's algorithms are written against a PETSc-style layout:
//! contiguous per-rank row ownership ([`Layout`]), distributed matrices
//! split into an owned-column `diag` block and a compacted off-rank `offd`
//! block ([`DistCsr`], [`DistBcsr`]), one-shot gathers of remote `P` rows
//! ([`RowGatherPlan`] → [`PrMat`]/[`PrBlocks`]), and vector halos
//! ([`VecGatherPlan`], [`DistSpmv`]).  [`World`] runs `np` rank closures
//! on threads with real byte-level message passing ([`Comm`]): a
//! nonblocking tag-addressed engine ([`Comm::isend`] /
//! [`Comm::try_recv_any`] / [`Comm::drain`]) underneath the deterministic
//! collectives, so message counts and bytes are measured, not modeled —
//! the α-β model
//! ([`COMM_ALPHA_SECS`], [`COMM_BETA_SECS_PER_BYTE`]) is applied on top of
//! the measured traffic when simulated parallel times are reported
//! (DESIGN.md §7).

mod bcsr;
mod csr;
pub mod fault;
mod gather;
mod layout;
mod operator;
mod transpose;
pub mod vec;
mod world;

pub use bcsr::{DistBSpmv, DistBcsr, DistBcsrBuilder};
pub use csr::{DistCsr, DistCsrBuilder};
pub use fault::{FaultAction, FaultCounts, FaultPlan, FaultRule, ENV_FAULT};
pub use gather::{GatherWindow, PrBlocks, PrMat, RowGatherPlan, VecGatherPlan};
pub use layout::Layout;
pub use operator::{CsrOperator, DistOperator};
pub use transpose::transpose_dist;
pub use vec::{DistMultiVec, DistSpmv, DistVec};
pub use world::{
    pipeline_chunk_rows, tag, Comm, CommError, CommStats, MissingFrame, ReliabilityStats, World,
    COMM_ALPHA_SECS, COMM_BETA_SECS_PER_BYTE, DEFAULT_COMM_TIMEOUT, DEFAULT_PIPELINE_CHUNK,
    ENV_COMM_TIMEOUT_MS, SIZE_BUCKETS, SIZE_BUCKET_EDGES,
};
