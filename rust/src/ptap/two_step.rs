//! The traditional two-step triple product (paper Alg. 5–6):
//! `C̃ = A·P` (row-wise, materialized), then `C = Pᵀ·C̃` via explicit
//! local transpose of `P` and an owner-send of the off-rank rows.
//!
//! This is the memory-hungry baseline: `C̃` and `Pᵀ` are retained across
//! numeric re-products (PETSc keeps them in the `MatPtAP` context for
//! MAT_REUSE_MATRIX), which is exactly the overhead the all-at-once
//! algorithms eliminate.

use crate::dist::{tag, Comm, DistCsr, PrMat};
use crate::mat::Csr;
use crate::mem::{Cat, MemTracker};
use crate::spgemm::{ApProduct, RowScratch, RowView, StampedAccumulator};

use super::common::{
    exchange_tracked, for_each_num_row, for_each_sym_row, write_num_row, COutput, LocalSymTables,
    PtapStats, RemoteStageSym, ScatterPipeline,
};

/// Retained two-step state: the auxiliary matrices the paper charges.
#[derive(Debug)]
pub struct TwoStepState {
    /// C̃ = A·P with global columns (pattern fixed by symbolic).
    pub ap: ApProduct,
    /// Explicit transpose of P's diag block (rows = local coarse cols).
    pub ptd: Csr,
    /// Explicit transpose of P's offd block (rows = P.garray positions).
    pub pto: Csr,
    /// Dense stamped accumulator (PETSc `apa`): shared by the C̃ numeric
    /// fill and the second product's row accumulation — the two-step
    /// method's hash-free numeric path, retained in the context (and
    /// charged as part of its memory footprint).
    acc: StampedAccumulator,
    cbuf32: Vec<u32>,
    vbuf: Vec<f64>,
}

/// Alg. 5: symbolic phase.  Returns the retained state and preallocated C.
pub fn symbolic(
    comm: &Comm,
    a: &DistCsr,
    p: &DistCsr,
    pr: &PrMat,
    scratch: &mut RowScratch,
    stats: &mut PtapStats,
    tracker: &MemTracker,
) -> (TwoStepState, COutput) {
    let v = RowView::new(a, p, pr);
    // Line 2: C̃ = Alg.2(A_l, P_l) — symbolic with materialized pattern.
    let ap = ApProduct::symbolic(v, scratch);
    tracker.alloc(Cat::Aux, ap.bytes());
    // Line 3: explicit transpose of P_l (symbolic would be structure-only;
    // we build the full transpose once and refresh values each numeric
    // pass, which charges the same retained bytes).
    let ptd = p.diag.transpose();
    let pto = p.offd.transpose();
    tracker.alloc(Cat::Aux, ptd.bytes() + pto.bytes());

    // Line 4: symbolically compute C_s = P_oᵀ C̃ (rows -> remote owners).
    let mut cs = RemoteStageSym::new(p.garray.len());
    for t in 0..pto.nrows {
        if pto.row_len(t) == 0 {
            continue;
        }
        let set = cs.row_mut(t);
        for &iu in pto.row_cols(t) {
            for &c in ap.mat.row(iu as usize).0 {
                set.insert(c);
            }
        }
    }
    tracker.alloc(Cat::Hash, cs.bytes());
    // Line 5: send C_s to its owners.
    let sends = cs.serialize(&p.garray, &p.col_layout, comm.size());
    let send_bytes: u64 = sends.iter().map(|(_, b)| b.len() as u64).sum();
    tracker.alloc(Cat::Comm, send_bytes);
    let recvd = exchange_tracked(comm, sends, &mut stats.sym_msgs, &mut stats.sym_bytes);
    tracker.free(Cat::Hash, cs.bytes());
    drop(cs);
    let recv_bytes: u64 = recvd.iter().map(|(_, b)| b.len() as u64).sum();
    tracker.alloc(Cat::Comm, recv_bytes);

    // Line 6: symbolically compute C_l = P_dᵀ C̃.
    let cbeg = v.cbeg;
    let cend = v.cend;
    let mut clh = LocalSymTables::new(ptd.nrows);
    for i in 0..ptd.nrows {
        if ptd.row_len(i) == 0 {
            continue;
        }
        for &iu in ptd.row_cols(i) {
            let cols = ap.mat.row(iu as usize).0;
            let (d, o) = clh.row_mut(i);
            for &c in cols {
                let c = c as u64;
                if c >= cbeg && c < cend {
                    d.insert((c - cbeg) as u32);
                } else {
                    o.insert(c as u32);
                }
            }
        }
    }
    // Lines 7–8: receive C_r and merge.
    for (_src, payload) in &recvd {
        for_each_sym_row(payload, |grow, cols| {
            clh.insert_global((grow - cbeg) as usize, cols, cbeg, cend);
        });
    }
    tracker.alloc(Cat::Hash, clh.bytes());
    tracker.free(Cat::Comm, send_bytes + recv_bytes);
    let (nzd, nzo) = clh.counts();
    tracker.free(Cat::Hash, clh.bytes());
    drop(clh);
    let c = COutput::prealloc(p.rank, p.col_layout.clone(), &nzd, &nzo);
    tracker.alloc(Cat::MatC, c.bytes());
    let acc = StampedAccumulator::new(p.global_ncols());
    tracker.alloc(Cat::Aux, acc.bytes());
    (TwoStepState { ap, ptd, pto, acc, cbuf32: Vec::new(), vbuf: Vec::new() }, c)
}

/// Alg. 6: numeric phase (re-runnable; values of A/P may have changed).
pub fn numeric(
    state: &mut TwoStepState,
    comm: &Comm,
    a: &DistCsr,
    p: &DistCsr,
    pr: &PrMat,
    _scratch: &mut RowScratch,
    c: &mut COutput,
    stats: &mut PtapStats,
    tracker: &MemTracker,
) {
    let v = RowView::new(a, p, pr);
    // Line 2: numeric C̃ (pattern reused; dense stamped accumulation).
    state.ap.numeric(v, &mut state.acc);
    // Line 3: numeric transpose of P_l (values refresh).
    refresh_transpose_values(&p.diag, &mut state.ptd);
    refresh_transpose_values(&p.offd, &mut state.pto);
    c.zero_values();

    // Lines 4–5: numeric C_s = P_oᵀ C̃ — per remote target row, accumulate
    // densely and serialize straight into the pipeline, which posts every
    // full chunk while the loop keeps computing (garray ascending => rows
    // ascend within each destination, exactly as the bulk path sent them).
    let mut pipe = ScatterPipeline::new(comm.size(), tag::PTAP_NUM);
    let mut cbuf64: Vec<u64> = Vec::new();
    for t in 0..state.pto.nrows {
        if state.pto.row_len(t) == 0 {
            continue;
        }
        let (icols, ivals) = state.pto.row(t);
        for (&iu, &w) in icols.iter().zip(ivals) {
            let (cols, vals) = state.ap.mat.row(iu as usize);
            for (&cc, &vv) in cols.iter().zip(vals) {
                state.acc.add(cc, w * vv);
            }
        }
        state.acc.extract_sorted(&mut state.cbuf32, &mut state.vbuf);
        cbuf64.clear();
        cbuf64.extend(state.cbuf32.iter().map(|&cc| cc as u64));
        let grow = p.garray[t];
        let owner = p.col_layout.owner(grow as usize);
        write_num_row(pipe.writer(owner), grow, &cbuf64, &state.vbuf);
        pipe.row_done(comm, owner);
    }

    // Line 6: numeric C_l = P_dᵀ C̃ — accumulate one output row at a time,
    // releasing received chunks off the wire between pipeline chunks.
    let mut recvd: Vec<(usize, Vec<u8>)> = Vec::new();
    let poll_every = pipe.chunk_rows();
    for i in 0..state.ptd.nrows {
        if i % poll_every == 0 {
            recvd.extend(pipe.poll(comm));
        }
        if state.ptd.row_len(i) == 0 {
            continue;
        }
        let (icols, ivals) = state.ptd.row(i);
        for (&iu, &w) in icols.iter().zip(ivals) {
            let (cols, vals) = state.ap.mat.row(iu as usize);
            for (&cc, &vv) in cols.iter().zip(vals) {
                state.acc.add(cc, w * vv);
            }
        }
        state.acc.extract_sorted(&mut state.cbuf32, &mut state.vbuf);
        c.add_global_row(i, &state.cbuf32, &state.vbuf);
    }
    // Lines 7–8: epoch close, then C_l += C_r — folded after the local
    // loop, in canonical source order, so the slot update order (hence
    // the bits) matches the bulk-synchronous path.
    recvd.extend(pipe.finish(comm));
    // bulk-equivalent comm-buffer accounting across the fold window
    let recv_bytes: u64 = recvd.iter().map(|(_, b)| b.len() as u64).sum();
    let comm_bytes = pipe.bytes + recv_bytes;
    tracker.alloc(Cat::Comm, comm_bytes);
    let cbeg = v.cbeg;
    for (_src, payload) in &recvd {
        for_each_num_row(payload, |grow, cols, vals| {
            c.add_global_row((grow - cbeg) as usize, cols, vals);
        });
    }
    tracker.free(Cat::Comm, comm_bytes);
    stats.num_msgs += pipe.msgs;
    stats.num_bytes += pipe.bytes;
    stats.num_overlap += pipe.overlap;
    stats.num_calls += 1;
}

/// Refresh the values of a previously built transpose without touching its
/// structure (the "numeric transpose" of Alg. 6 line 3).
fn refresh_transpose_values(orig: &Csr, t: &mut Csr) {
    debug_assert_eq!(t.nrows, orig.ncols);
    let mut cursor: Vec<u32> = t.rowptr[..t.nrows].to_vec();
    for i in 0..orig.nrows {
        let (cols, vals) = orig.row(i);
        for (&c, &v) in cols.iter().zip(vals) {
            let p = cursor[c as usize] as usize;
            debug_assert_eq!(t.cols[p] as usize, i);
            t.vals[p] = v;
            cursor[c as usize] += 1;
        }
    }
}

/// Retained auxiliary bytes (C̃ + Pᵀ + dense accumulator) — what the
/// paper charges the two-step method for.
pub fn retained_aux_bytes(state: &TwoStepState) -> u64 {
    state.ap.bytes() + state.ptd.bytes() + state.pto.bytes() + state.acc.bytes()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mat::CsrBuilder;

    #[test]
    fn transpose_value_refresh_matches_rebuild() {
        let mut b = CsrBuilder::new(4);
        b.push_row(&[0, 2], &[1.0, 2.0]);
        b.push_row(&[1, 3], &[3.0, 4.0]);
        b.push_row(&[0, 1], &[5.0, 6.0]);
        let mut m = b.finish();
        let mut t = m.transpose();
        // change values, refresh
        for v in m.vals.iter_mut() {
            *v *= 10.0;
        }
        refresh_transpose_values(&m, &mut t);
        let want = m.transpose();
        assert_eq!(t, want);
    }
}
