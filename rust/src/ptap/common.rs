//! Shared machinery for the three triple-product algorithms: the
//! preallocated output `C`, remote-contribution staging, and stats.

use crate::dist::{Comm, DistCsr, Layout};
use crate::hash::{IntMap, Set32};
use crate::mat::PreallocCsr;
use crate::util::bytebuf::{ByteReader, ByteWriter};
use crate::util::timer::thread_cpu_time;

/// Per-phase communication + time accounting for one rank.
#[derive(Debug, Default, Clone, Copy)]
pub struct PtapStats {
    /// Busy CPU seconds in the symbolic phase (this rank).
    pub time_sym: f64,
    /// Busy CPU seconds accumulated over all numeric calls.
    pub time_num: f64,
    /// Number of numeric products performed.
    pub num_calls: u32,
    /// Messages/bytes sent during symbolic / numeric phases.
    pub sym_msgs: u64,
    pub sym_bytes: u64,
    pub num_msgs: u64,
    pub num_bytes: u64,
    /// Overlap windows: busy CPU seconds between the phase's first posted
    /// send and its epoch close — the span in which communication was in
    /// flight behind compute.  All-at-once earns a large window (remote
    /// loop posts, local loop computes), merged stages its sends to the
    /// end and earns ≈ 0 (the paper's §3 trade-off).
    pub sym_overlap: f64,
    pub num_overlap: f64,
}

/// The α-β comm model can be disabled with `GPTAP_COMM_MODEL=off`
/// (busy CPU time only) — DESIGN.md §7.
pub fn comm_model_enabled() -> bool {
    static ON: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *ON.get_or_init(|| std::env::var("GPTAP_COMM_MODEL").map_or(true, |v| v != "off"))
}

impl PtapStats {
    /// Field-wise accumulation (level sums, refresh totals).
    pub fn add(&mut self, s: PtapStats) {
        self.time_sym += s.time_sym;
        self.time_num += s.time_num;
        self.num_calls += s.num_calls;
        self.sym_msgs += s.sym_msgs;
        self.sym_bytes += s.sym_bytes;
        self.num_msgs += s.num_msgs;
        self.num_bytes += s.num_bytes;
        self.sym_overlap += s.sym_overlap;
        self.num_overlap += s.num_overlap;
    }

    /// Field-wise delta since `earlier` (counters are monotone).
    pub fn since(&self, earlier: PtapStats) -> PtapStats {
        PtapStats {
            time_sym: self.time_sym - earlier.time_sym,
            time_num: self.time_num - earlier.time_num,
            num_calls: self.num_calls - earlier.num_calls,
            sym_msgs: self.sym_msgs - earlier.sym_msgs,
            sym_bytes: self.sym_bytes - earlier.sym_bytes,
            num_msgs: self.num_msgs - earlier.num_msgs,
            num_bytes: self.num_bytes - earlier.num_bytes,
            sym_overlap: self.sym_overlap - earlier.sym_overlap,
            num_overlap: self.num_overlap - earlier.num_overlap,
        }
    }

    /// Total overlap window across both phases.
    pub fn overlap_total(&self) -> f64 {
        self.sym_overlap + self.num_overlap
    }

    /// Modeled symbolic time: busy time plus the α-β communication model,
    /// crediting the measured overlap window (communication hidden behind
    /// compute costs nothing up to the window's length).
    pub fn time_sym_modeled(&self) -> f64 {
        if !comm_model_enabled() {
            return self.time_sym;
        }
        let comm = self.sym_msgs as f64 * crate::dist::COMM_ALPHA_SECS
            + self.sym_bytes as f64 * crate::dist::COMM_BETA_SECS_PER_BYTE;
        self.time_sym + (comm - self.sym_overlap).max(0.0)
    }

    pub fn time_num_modeled(&self) -> f64 {
        if !comm_model_enabled() {
            return self.time_num;
        }
        let comm = self.num_msgs as f64 * crate::dist::COMM_ALPHA_SECS
            + self.num_bytes as f64 * crate::dist::COMM_BETA_SECS_PER_BYTE;
        self.time_num + (comm - self.num_overlap).max(0.0)
    }
}

/// The output matrix `C` under construction: exactly-preallocated diag
/// (local coarse columns) and offd (global columns, compacted on finish).
#[derive(Debug, Clone)]
pub struct COutput {
    pub rank: usize,
    /// C's row layout == C's col layout == P's column layout.
    pub layout: Layout,
    pub diag: PreallocCsr,
    pub offd: PreallocCsr,
}

impl COutput {
    /// Preallocate from the symbolic phase's exact per-row counts.
    pub fn prealloc(rank: usize, layout: Layout, nzd: &[u32], nzo: &[u32]) -> Self {
        let local = layout.local_size(rank);
        assert_eq!(nzd.len(), local);
        let global = layout.global_size();
        assert!(global < u32::MAX as usize);
        COutput {
            rank,
            layout: layout.clone(),
            diag: PreallocCsr::with_row_counts(local, nzd),
            offd: PreallocCsr::with_row_counts(global, nzo),
        }
    }

    pub fn col_begin(&self) -> u64 {
        self.layout.start(self.rank) as u64
    }

    pub fn col_end(&self) -> u64 {
        self.layout.end(self.rank) as u64
    }

    pub fn bytes(&self) -> u64 {
        self.diag.bytes() + self.offd.bytes()
    }

    pub fn zero_values(&mut self) {
        self.diag.zero_values();
        self.offd.zero_values();
    }

    /// Add `w *` (sorted local diag cols, vals) and (sorted global offd
    /// cols, vals) into local row `i`.
    pub fn add_split_scaled(
        &mut self,
        i: usize,
        dcols: &[u32],
        dvals: &[f64],
        ocols: &[u32],
        ovals: &[f64],
        w: f64,
    ) {
        if !dcols.is_empty() {
            self.diag.add_row_scaled(i, dcols, dvals, w);
        }
        if !ocols.is_empty() {
            self.offd.add_row_scaled(i, ocols, ovals, w);
        }
    }

    /// Add a received remote contribution: `cols` are sorted *global* ids,
    /// split into the contiguous diag range [cbeg, cend) and the offd
    /// remainder on either side.
    pub fn add_global_row(&mut self, i: usize, cols: &[u32], vals: &[f64]) {
        let cbeg = self.col_begin() as u32;
        let cend = self.col_end() as u32;
        let lo = cols.partition_point(|&c| c < cbeg);
        let hi = cols.partition_point(|&c| c < cend);
        if lo > 0 {
            self.offd.add_row(i, &cols[..lo], &vals[..lo]);
        }
        if hi > lo {
            // diag chunk: shift to local ids
            let local: Vec<u32> = cols[lo..hi].iter().map(|&c| c - cbeg).collect();
            self.diag.add_row(i, &local, &vals[lo..hi]);
        }
        if hi < cols.len() {
            self.offd.add_row(i, &cols[hi..], &vals[hi..]);
        }
    }

    /// Compact into a [`DistCsr`] (clones the current values).
    pub fn to_dist(&self) -> DistCsr {
        let diag = self.diag.clone().finish();
        let offd_global = self.offd.clone().finish();
        // compact offd columns into garray
        let mut garray: Vec<u64> = offd_global.cols.iter().map(|&c| c as u64).collect();
        garray.sort_unstable();
        garray.dedup();
        let mut offd = offd_global.clone();
        offd.ncols = garray.len();
        for c in offd.cols.iter_mut() {
            *c = garray.binary_search(&(*c as u64)).unwrap() as u32;
        }
        DistCsr {
            rank: self.rank,
            row_layout: self.layout.clone(),
            col_layout: self.layout.clone(),
            diag,
            offd,
            garray,
        }
    }
}

/// Serialize one symbolic contribution row — `[grow u64, n u32, cols
/// u64…]`, the wire format [`for_each_sym_row`] parses.  Every producer
/// (bulk serializers and pipelined writers alike) must go through this so
/// the format cannot drift per algorithm.
pub fn write_sym_row(w: &mut ByteWriter, grow: u64, cols: &[u64]) {
    w.u64(grow);
    w.u32(cols.len() as u32);
    w.u64_slice(cols);
}

/// Serialize one numeric contribution row — `[grow u64, n u32, cols
/// u64…, vals f64…]`, the wire format [`for_each_num_row`] parses.
pub fn write_num_row(w: &mut ByteWriter, grow: u64, cols: &[u64], vals: &[f64]) {
    w.u64(grow);
    w.u32(cols.len() as u32);
    w.u64_slice(cols);
    w.f64_slice(vals);
}

/// Staging for contributions to *remote* rows of C, keyed by P's offd
/// compacted column (P.garray position).  The symbolic side stages column
/// sets (`C_s^H`), the numeric side value maps (`C_s`).
#[derive(Debug, Default)]
pub struct RemoteStageSym {
    /// One set of global C columns per P.garray position (lazy).
    pub rows: Vec<Option<Set32>>,
}

impl RemoteStageSym {
    pub fn new(n: usize) -> Self {
        RemoteStageSym { rows: (0..n).map(|_| None).collect() }
    }

    #[inline]
    pub fn row_mut(&mut self, t: usize) -> &mut Set32 {
        self.rows[t].get_or_insert_with(Set32::default)
    }

    pub fn bytes(&self) -> u64 {
        self.rows.iter().flatten().map(|s| s.bytes()).sum::<u64>()
            + (self.rows.len() * std::mem::size_of::<Option<Set32>>()) as u64
    }

    /// Serialize per-owner messages: [grow u64, n u32, cols u64...]*.
    /// Columns are sent sorted (receivers add split chunks).
    pub fn serialize(&self, garray: &[u64], layout: &Layout, np: usize) -> Vec<(usize, Vec<u8>)> {
        let mut writers: Vec<Option<ByteWriter>> = (0..np).map(|_| None).collect();
        let mut buf: Vec<u64> = Vec::new();
        for (t, row) in self.rows.iter().enumerate() {
            let Some(set) = row else { continue };
            if set.is_empty() {
                continue;
            }
            let grow = garray[t];
            let owner = layout.owner(grow as usize);
            let w = writers[owner].get_or_insert_with(ByteWriter::new);
            set.collect_sorted_u64(&mut buf);
            write_sym_row(w, grow, &buf);
        }
        writers
            .into_iter()
            .enumerate()
            .filter_map(|(dest, w)| w.map(|w| (dest, w.into_bytes())))
            .collect()
    }
}

/// Numeric staging: value maps per P.garray position.
#[derive(Debug, Default)]
pub struct RemoteStageNum {
    pub rows: Vec<Option<IntMap>>,
}

impl RemoteStageNum {
    pub fn new(n: usize) -> Self {
        RemoteStageNum { rows: (0..n).map(|_| None).collect() }
    }

    #[inline]
    pub fn row_mut(&mut self, t: usize) -> &mut IntMap {
        self.rows[t].get_or_insert_with(IntMap::default)
    }

    pub fn bytes(&self) -> u64 {
        self.rows.iter().flatten().map(|m| m.bytes()).sum::<u64>()
            + (self.rows.len() * std::mem::size_of::<Option<IntMap>>()) as u64
    }

    /// Serialize per-owner messages: [grow u64, n u32, cols u64..., vals
    /// f64...]*, columns sorted.
    pub fn serialize(&mut self, garray: &[u64], layout: &Layout, np: usize) -> Vec<(usize, Vec<u8>)> {
        let mut writers: Vec<Option<ByteWriter>> = (0..np).map(|_| None).collect();
        let mut kbuf: Vec<u64> = Vec::new();
        let mut vbuf: Vec<f64> = Vec::new();
        for (t, row) in self.rows.iter_mut().enumerate() {
            let Some(map) = row else { continue };
            if map.is_empty() {
                continue;
            }
            let grow = garray[t];
            let owner = layout.owner(grow as usize);
            let w = writers[owner].get_or_insert_with(ByteWriter::new);
            map.collect_sorted(&mut kbuf, &mut vbuf);
            write_num_row(w, grow, &kbuf, &vbuf);
        }
        writers
            .into_iter()
            .enumerate()
            .filter_map(|(dest, w)| w.map(|w| (dest, w.into_bytes())))
            .collect()
    }
}

/// Exchange staged messages and record stats.  Returns received payloads.
pub fn exchange_tracked(
    comm: &Comm,
    sends: Vec<(usize, Vec<u8>)>,
    msgs: &mut u64,
    bytes: &mut u64,
) -> Vec<(usize, Vec<u8>)> {
    *msgs += sends.len() as u64;
    *bytes += sends.iter().map(|(_, p)| p.len() as u64).sum::<u64>();
    comm.exchange(sends)
}

// The pipeline chunk knob lives in `dist` now (the gather plans pipeline
// too); re-exported here for the algorithm modules.
pub use crate::dist::{pipeline_chunk_rows, DEFAULT_PIPELINE_CHUNK};

/// Pipelined scatter over the nonblocking engine: staged rows are
/// serialized into per-destination buffers and posted (`Comm::isend`) as
/// soon as a destination has a full chunk, so the payloads are in flight
/// while the caller keeps computing.  `poll` releases whatever the engine
/// can hand out deterministically mid-loop; `finish` flushes the open
/// buffers, closes the epoch and measures the overlap window.
///
/// Chunk boundaries never split a row and never reorder rows within a
/// destination, so the receiver sees exactly the bulk path's rows —
/// identical byte totals, deterministic content.
#[derive(Debug)]
pub struct ScatterPipeline {
    tag: u32,
    chunk_rows: usize,
    writers: Vec<Option<ByteWriter>>,
    rows_staged: Vec<usize>,
    first_isend_busy: Option<f64>,
    /// Trace timestamp of the first posted chunk (tracing runs only) —
    /// the overlap window becomes a visible span in the merged trace.
    first_isend_us: Option<u64>,
    /// Messages/payload bytes posted (chunks count as messages).
    pub msgs: u64,
    pub bytes: u64,
    /// Busy seconds between the first posted chunk and the epoch close
    /// (0 until `finish`, and 0 if nothing was sent).
    pub overlap: f64,
}

impl ScatterPipeline {
    pub fn new(np: usize, tag: u32) -> Self {
        ScatterPipeline {
            tag,
            chunk_rows: pipeline_chunk_rows(),
            writers: (0..np).map(|_| None).collect(),
            rows_staged: vec![0; np],
            first_isend_busy: None,
            first_isend_us: None,
            msgs: 0,
            bytes: 0,
            overlap: 0.0,
        }
    }

    /// Rows per chunk (also a sensible poll cadence for receive loops).
    pub fn chunk_rows(&self) -> usize {
        self.chunk_rows
    }

    /// The open serialization buffer for `dest` (serialize one row, then
    /// call [`ScatterPipeline::row_done`]).
    pub fn writer(&mut self, dest: usize) -> &mut ByteWriter {
        self.writers[dest].get_or_insert_with(ByteWriter::new)
    }

    /// Mark one staged row complete for `dest`; posts the buffer once a
    /// full chunk has accumulated.
    pub fn row_done(&mut self, comm: &Comm, dest: usize) {
        self.rows_staged[dest] += 1;
        if self.rows_staged[dest] >= self.chunk_rows {
            self.flush_dest(comm, dest);
        }
    }

    fn flush_dest(&mut self, comm: &Comm, dest: usize) {
        if let Some(w) = self.writers[dest].take() {
            if !w.is_empty() {
                let payload = w.into_bytes();
                self.msgs += 1;
                self.bytes += payload.len() as u64;
                if self.first_isend_busy.is_none() {
                    self.first_isend_busy = Some(thread_cpu_time());
                    if crate::obs::enabled() {
                        self.first_isend_us = Some(crate::obs::now_us());
                    }
                }
                comm.isend(dest, self.tag, payload);
            }
        }
        self.rows_staged[dest] = 0;
    }

    /// Nonblocking: whatever received payloads the engine can release in
    /// canonical (source-rank, send) order right now.
    pub fn poll(&mut self, comm: &Comm) -> Vec<(usize, Vec<u8>)> {
        comm.try_recv_any(self.tag)
    }

    /// Flush every open buffer, close the epoch, record the overlap
    /// window, and return the remaining payloads (canonical order).
    pub fn finish(&mut self, comm: &Comm) -> Vec<(usize, Vec<u8>)> {
        for dest in 0..self.writers.len() {
            self.flush_dest(comm, dest);
        }
        let recvd = comm.drain(self.tag);
        if let Some(t0) = self.first_isend_busy.take() {
            self.overlap = thread_cpu_time() - t0;
        }
        if let Some(us0) = self.first_isend_us.take() {
            let end = crate::obs::now_us();
            crate::obs::complete(crate::obs::Subsys::Ptap, "overlap", self.bytes, us0, end);
        }
        recvd
    }
}

/// End-staged engine send (the merged algorithm's side of the paper's §3
/// trade-off): post every already-serialized payload at once, close the
/// epoch, and record stats plus the — by construction ≈ 0 — overlap
/// window.  Delivery order and byte totals match the bulk shim exactly.
pub fn send_staged_tracked(
    comm: &Comm,
    tag: u32,
    sends: Vec<(usize, Vec<u8>)>,
    msgs: &mut u64,
    bytes: &mut u64,
    overlap: &mut f64,
) -> Vec<(usize, Vec<u8>)> {
    *msgs += sends.len() as u64;
    *bytes += sends.iter().map(|(_, p)| p.len() as u64).sum::<u64>();
    let sent_any = !sends.is_empty();
    let t0 = thread_cpu_time();
    let recvd = comm.exchange_on(tag, sends);
    if sent_any {
        *overlap += thread_cpu_time() - t0;
    }
    recvd
}

/// Iterate a received symbolic payload: (global row, sorted global cols).
pub fn for_each_sym_row(payload: &[u8], mut f: impl FnMut(u64, &[u64])) {
    let mut r = ByteReader::new(payload);
    let mut cols: Vec<u64> = Vec::new();
    while !r.done() {
        let grow = r.u64();
        let n = r.u32() as usize;
        cols.clear();
        for _ in 0..n {
            cols.push(r.u64());
        }
        f(grow, &cols);
    }
}

/// Iterate a received numeric payload: (global row, sorted global cols,
/// values).
pub fn for_each_num_row(payload: &[u8], mut f: impl FnMut(u64, &[u32], &[f64])) {
    let mut r = ByteReader::new(payload);
    let mut cols: Vec<u32> = Vec::new();
    let mut vals: Vec<f64> = Vec::new();
    while !r.done() {
        let grow = r.u64();
        let n = r.u32() as usize;
        cols.clear();
        vals.clear();
        for _ in 0..n {
            cols.push(r.u64() as u32);
        }
        for _ in 0..n {
            vals.push(r.f64());
        }
        f(grow, &cols, &vals);
    }
}

/// Per-local-row symbolic tables for the local part of C (`C_l^H`): one
/// diag set (local cols) + one offd set (global cols) per row, lazily
/// created (paper Alg. 7 line 15).
#[derive(Debug, Default)]
pub struct LocalSymTables {
    pub rows: Vec<Option<(Set32, Set32)>>,
}

impl LocalSymTables {
    pub fn new(nrows: usize) -> Self {
        LocalSymTables { rows: (0..nrows).map(|_| None).collect() }
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut (Set32, Set32) {
        self.rows[i].get_or_insert_with(|| (Set32::default(), Set32::default()))
    }

    pub fn bytes(&self) -> u64 {
        self.rows
            .iter()
            .flatten()
            .map(|(d, o)| d.bytes() + o.bytes())
            .sum::<u64>()
            + (self.rows.len() * std::mem::size_of::<Option<(Set32, Set32)>>()) as u64
    }

    /// Final per-row counts (nzd, nzo).
    pub fn counts(&self) -> (Vec<u32>, Vec<u32>) {
        let nzd = self
            .rows
            .iter()
            .map(|r| r.as_ref().map_or(0, |(d, _)| d.len() as u32))
            .collect();
        let nzo = self
            .rows
            .iter()
            .map(|r| r.as_ref().map_or(0, |(_, o)| o.len() as u32))
            .collect();
        (nzd, nzo)
    }

    /// Insert a sorted global-column row, classifying diag/offd.
    pub fn insert_global(&mut self, i: usize, cols: &[u64], cbeg: u64, cend: u64) {
        let (d, o) = self.row_mut(i);
        for &c in cols {
            if c >= cbeg && c < cend {
                d.insert((c - cbeg) as u32);
            } else {
                o.insert(c as u32);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coutput_prealloc_and_fill() {
        let layout = Layout::new_equal(8, 2);
        // rank 0 owns rows/cols 0..4
        let mut c = COutput::prealloc(0, layout, &[2, 1, 0, 1], &[1, 0, 0, 0]);
        c.add_split_scaled(0, &[0, 2], &[1.0, 2.0], &[6], &[0.5], 2.0);
        c.add_split_scaled(1, &[3], &[1.0], &[], &[], 1.0);
        c.add_split_scaled(3, &[1], &[4.0], &[], &[], 1.0);
        let d = c.to_dist();
        d.validate().unwrap();
        assert_eq!(d.diag.row(0).1, &[2.0, 4.0]);
        assert_eq!(d.garray, vec![6]);
        assert_eq!(d.offd.row(0).1, &[1.0]);
    }

    #[test]
    fn add_global_row_splits_ranges() {
        let layout = Layout::new_equal(9, 3);
        // rank 1 owns cols 3..6
        let mut c = COutput::prealloc(1, layout, &[2, 0, 0], &[2, 0, 0]);
        // sorted global cols straddling the local range
        c.add_global_row(0, &[1, 3, 5, 8], &[1.0, 3.0, 5.0, 8.0]);
        let d = c.to_dist();
        assert_eq!(d.diag.row(0).1, &[3.0, 5.0]);
        assert_eq!(d.garray, vec![1, 8]);
        assert_eq!(d.offd.row(0).1, &[1.0, 8.0]);
    }

    #[test]
    fn local_sym_tables_count() {
        let mut t = LocalSymTables::new(3);
        t.insert_global(0, &[2, 5, 7], 2, 6);
        t.insert_global(0, &[2, 9], 2, 6);
        let (nzd, nzo) = t.counts();
        assert_eq!(nzd, vec![2, 0, 0]); // cols 2,5 local
        assert_eq!(nzo, vec![2, 0, 0]); // cols 7,9 remote
    }

    #[test]
    fn sym_stage_serializes_sorted() {
        let layout = Layout::new_equal(10, 2);
        let garray = vec![7u64, 9u64];
        let mut st = RemoteStageSym::new(2);
        st.row_mut(0).insert(4);
        st.row_mut(0).insert(1);
        st.row_mut(1).insert(2);
        let msgs = st.serialize(&garray, &layout, 2);
        assert_eq!(msgs.len(), 1);
        assert_eq!(msgs[0].0, 1); // rows 7 and 9 owned by rank 1
        let mut seen = Vec::new();
        for_each_sym_row(&msgs[0].1, |grow, cols| seen.push((grow, cols.to_vec())));
        assert_eq!(seen, vec![(7, vec![1, 4]), (9, vec![2])]);
    }

    #[test]
    fn num_stage_round_trip() {
        let layout = Layout::new_equal(4, 2);
        let garray = vec![3u64];
        let mut st = RemoteStageNum::new(1);
        st.row_mut(0).add(2, 1.5);
        st.row_mut(0).add(0, -1.0);
        st.row_mut(0).add(2, 0.5);
        let msgs = st.serialize(&garray, &layout, 2);
        assert_eq!(msgs.len(), 1);
        let mut seen = Vec::new();
        for_each_num_row(&msgs[0].1, |g, c, v| seen.push((g, c.to_vec(), v.to_vec())));
        assert_eq!(seen, vec![(3, vec![0, 2], vec![-1.0, 2.0])]);
    }
}
