//! Galerkin triple products `C = PᵀAP` — the paper's contribution.
//!
//! Three algorithms behind one interface:
//!
//! | [`Algo`]       | paper      | auxiliaries retained            |
//! |----------------|------------|---------------------------------|
//! | `TwoStep`      | Alg. 5–6   | `C̃ = AP`, explicit `Pᵀ`        |
//! | `AllAtOnce`    | Alg. 7–8   | none (hash staging only)        |
//! | `Merged`       | Alg. 9–10  | none; fused single loop         |
//!
//! Protocol: [`Ptap::symbolic`] once (builds the gather plan, the exact
//! preallocation of `C`, and any retained auxiliaries), then
//! [`Ptap::numeric`] any number of times as the values of `A`/`P` change
//! (the paper runs 1 symbolic + 11 numeric).  Every phase measures its own
//! busy CPU time, message counts and bytes, plus the *overlap window* —
//! busy seconds between its first posted send and the epoch close on the
//! nonblocking engine (large for all-at-once, ≈ 0 for merged — the
//! paper's §3 trade-off made measurable) — and charges every byte it
//! holds to the rank's [`MemTracker`] — those numbers are the tables.

mod all_at_once;
pub mod block;
mod common;
mod merged;
pub mod rap;
mod two_step;

pub use common::{comm_model_enabled, COutput, PtapStats};
pub use rap::rap;

use crate::dist::{Comm, DistCsr, PrMat, RowGatherPlan};
use crate::mem::{Cat, MemTracker};
use crate::spgemm::RowScratch;
use crate::util::timer::BusyTimer;

/// Which triple-product algorithm to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algo {
    TwoStep,
    AllAtOnce,
    Merged,
}

pub const ALL_ALGOS: [Algo; 3] = [Algo::AllAtOnce, Algo::Merged, Algo::TwoStep];

impl Algo {
    /// Name as the paper's tables print it.
    pub fn name(self) -> &'static str {
        match self {
            Algo::TwoStep => "two-step",
            Algo::AllAtOnce => "allatonce",
            Algo::Merged => "merged",
        }
    }

    pub fn parse(s: &str) -> Option<Algo> {
        match s {
            "two-step" | "twostep" | "2step" => Some(Algo::TwoStep),
            "allatonce" | "all-at-once" | "aao" => Some(Algo::AllAtOnce),
            "merged" | "merged-allatonce" => Some(Algo::Merged),
            _ => None,
        }
    }
}

enum State {
    TwoStep(two_step::TwoStepState),
    AllAtOnce(all_at_once::AaoState),
    Merged(all_at_once::AaoState),
}

/// A triple-product operation in progress: symbolic state + preallocated C.
pub struct Ptap {
    pub algo: Algo,
    pub c: COutput,
    pub stats: PtapStats,
    plan: RowGatherPlan,
    pr: PrMat,
    scratch: RowScratch,
    state: State,
    tracker: MemTracker,
    /// Bytes this op has charged and must release on drop, per category.
    retained: Vec<(Cat, u64)>,
}

impl Ptap {
    /// Symbolic phase (collective): plan communication, compute C's exact
    /// pattern counts, preallocate C, build retained auxiliaries.
    pub fn symbolic(
        algo: Algo,
        comm: &Comm,
        a: &DistCsr,
        p: &DistCsr,
        tracker: &MemTracker,
    ) -> Ptap {
        let _sp = crate::obs::span(crate::obs::Subsys::Ptap, "symbolic", algo as u64);
        let mut stats = PtapStats::default();
        let mut timer = BusyTimer::new();
        timer.start();
        let pre = comm.stats();
        // Extract the remote rows P̃_r of P named by A's offd columns
        // (Alg. 2/7/9 line 2).  Pattern only; values come per numeric pass.
        let plan = RowGatherPlan::build(comm, &p.row_layout, &a.garray);
        let pr = plan.gather_pattern_csr(comm, p);
        tracker.alloc(Cat::Comm, plan.bytes() + pr.bytes());
        let mut retained = vec![(Cat::Comm, plan.bytes() + pr.bytes())];
        let mut scratch = RowScratch::default();

        let (state, c) = match algo {
            Algo::TwoStep => {
                let (st, c) =
                    two_step::symbolic(comm, a, p, &pr, &mut scratch, &mut stats, tracker);
                retained.push((Cat::Aux, two_step::retained_aux_bytes(&st)));
                (State::TwoStep(st), c)
            }
            Algo::AllAtOnce => {
                let (st, c) =
                    all_at_once::symbolic(comm, a, p, &pr, &mut scratch, &mut stats, tracker);
                (State::AllAtOnce(st), c)
            }
            Algo::Merged => {
                let (st, c) =
                    merged::symbolic(comm, a, p, &pr, &mut scratch, &mut stats, tracker);
                (State::Merged(st), c)
            }
        };
        retained.push((Cat::MatC, c.bytes()));
        // the reusable row accumulators stay alive for the numeric passes
        tracker.alloc(Cat::Hash, scratch.bytes());
        retained.push((Cat::Hash, scratch.bytes()));
        timer.stop();
        let post = comm.stats();
        stats.time_sym = timer.total();
        stats.sym_msgs += 0; // phase counters already tracked at exchange
        let _ = (pre, post);
        Ptap { algo, c, stats, plan, pr, scratch, state, tracker: tracker.clone(), retained }
    }

    /// Numeric phase (collective, re-runnable): refresh P̃_r values and
    /// fill C's values.
    pub fn numeric(&mut self, comm: &Comm, a: &DistCsr, p: &DistCsr) {
        let _sp = crate::obs::span(crate::obs::Subsys::Ptap, "numeric", self.algo as u64);
        let mut timer = BusyTimer::new();
        timer.start();
        // Alg. 4 line 3: update P̃_r with a sparse communication — served
        // in pipelined chunks, so the refresh's traffic and its overlap
        // window are measured and credited like the scatter phases'.
        let gw = {
            let _gw_sp = crate::obs::span(crate::obs::Subsys::Ptap, "gather_values", 0);
            self.plan.update_values_csr(comm, p, &mut self.pr)
        };
        self.stats.num_msgs += gw.msgs;
        self.stats.num_bytes += gw.bytes;
        self.stats.num_overlap += gw.overlap;
        match &mut self.state {
            State::TwoStep(st) => two_step::numeric(
                st,
                comm,
                a,
                p,
                &self.pr,
                &mut self.scratch,
                &mut self.c,
                &mut self.stats,
                &self.tracker,
            ),
            State::AllAtOnce(st) => all_at_once::numeric(
                st,
                comm,
                a,
                p,
                &self.pr,
                &mut self.scratch,
                &mut self.c,
                &mut self.stats,
                &self.tracker,
            ),
            State::Merged(st) => merged::numeric(
                st,
                comm,
                a,
                p,
                &self.pr,
                &mut self.scratch,
                &mut self.c,
                &mut self.stats,
                &self.tracker,
            ),
        }
        timer.stop();
        self.stats.time_num += timer.total();
    }

    /// Materialize C as a distributed matrix (clones current values).
    pub fn extract_c(&self) -> DistCsr {
        self.c.to_dist()
    }

    /// Bytes retained by this op while alive (plans, auxiliaries, C).
    pub fn retained_bytes(&self) -> u64 {
        self.retained.iter().map(|&(_, b)| b).sum()
    }
}

impl Drop for Ptap {
    fn drop(&mut self) {
        for &(cat, bytes) in &self.retained {
            self.tracker.free(cat, bytes);
        }
    }
}

/// Convenience: symbolic + one numeric, returning C and the stats.
pub fn ptap_once(
    algo: Algo,
    comm: &Comm,
    a: &DistCsr,
    p: &DistCsr,
    tracker: &MemTracker,
) -> (DistCsr, PtapStats) {
    let mut op = Ptap::symbolic(algo, comm, a, p, tracker);
    op.numeric(comm, a, p);
    (op.extract_c(), op.stats)
}

/// Sequential reference triple product (dense-accumulator SpGEMM twice) —
/// the correctness oracle for all three distributed algorithms.
pub fn seq_ptap_reference(a: &crate::mat::Csr, p: &crate::mat::Csr) -> crate::mat::Csr {
    use std::collections::BTreeMap;
    let seq_mm = |x: &crate::mat::Csr, y: &crate::mat::Csr| -> crate::mat::Csr {
        let mut b = crate::mat::CsrBuilder::new(y.ncols);
        let mut acc: BTreeMap<u32, f64> = BTreeMap::new();
        for i in 0..x.nrows {
            acc.clear();
            let (xc, xv) = x.row(i);
            for (&k, &xval) in xc.iter().zip(xv) {
                let (yc, yv) = y.row(k as usize);
                for (&j, &yval) in yc.iter().zip(yv) {
                    *acc.entry(j).or_insert(0.0) += xval * yval;
                }
            }
            let cols: Vec<u32> = acc.keys().copied().collect();
            let vals: Vec<f64> = acc.values().copied().collect();
            b.push_row(&cols, &vals);
        }
        b.finish()
    };
    let ap = seq_mm(a, p);
    let pt = p.transpose();
    seq_mm(&pt, &ap)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{DistCsrBuilder, Layout, World};
    use crate::util::prng::Rng;

    /// Random rank-local slice of a globally deterministic sparse matrix.
    pub(crate) fn random_dist(
        rank: usize,
        np: usize,
        nrows: usize,
        ncols: usize,
        row_nnz: usize,
        seed: u64,
    ) -> DistCsr {
        let rl = Layout::new_equal(nrows, np);
        let cl = Layout::new_equal(ncols, np);
        let mut b = DistCsrBuilder::new(rank, rl.clone(), cl);
        for gi in rl.range(rank) {
            let mut rng = Rng::new(seed.wrapping_add(gi as u64 * 7919));
            let mut cols: Vec<u64> = (0..row_nnz).map(|_| rng.below(ncols) as u64).collect();
            cols.sort_unstable();
            cols.dedup();
            let entries: Vec<(u64, f64)> =
                cols.iter().map(|&c| (c, rng.range_f64(-1.0, 1.0))).collect();
            b.push_row(&entries);
        }
        b.finish()
    }

    fn check_algo_matches_reference(algo: Algo, np: usize, n: usize, m: usize) {
        let w = World::new(np);
        let results = w.run(|comm| {
            let a = random_dist(comm.rank(), comm.size(), n, n, 5, 100);
            let p = random_dist(comm.rank(), comm.size(), n, m, 3, 200);
            let tracker = MemTracker::new();
            let (c, _stats) = ptap_once(algo, &comm, &a, &p, &tracker);
            c.validate().unwrap();
            let cg = c.gather_global(&comm);
            let ag = a.gather_global(&comm);
            let pg = p.gather_global(&comm);
            (cg, ag, pg)
        });
        let (cg, ag, pg) = &results[0];
        let want = seq_ptap_reference(ag, pg);
        let diff = cg.max_abs_diff(&want);
        assert!(diff < 1e-10, "{:?} np={np}: max diff {diff}", algo);
        // every rank must assemble the identical global C
        for (c_other, _, _) in &results[1..] {
            assert_eq!(cg, c_other);
        }
    }

    #[test]
    fn two_step_matches_reference() {
        for np in [1, 2, 4] {
            check_algo_matches_reference(Algo::TwoStep, np, 48, 16);
        }
    }

    #[test]
    fn all_at_once_matches_reference() {
        for np in [1, 2, 4] {
            check_algo_matches_reference(Algo::AllAtOnce, np, 48, 16);
        }
    }

    #[test]
    fn merged_matches_reference() {
        for np in [1, 2, 4] {
            check_algo_matches_reference(Algo::Merged, np, 48, 16);
        }
    }

    #[test]
    fn algorithms_agree_with_each_other() {
        let w = World::new(3);
        let cs = w.run(|comm| {
            let a = random_dist(comm.rank(), comm.size(), 60, 60, 6, 300);
            let p = random_dist(comm.rank(), comm.size(), 60, 20, 2, 400);
            let tracker = MemTracker::new();
            ALL_ALGOS
                .iter()
                .map(|&algo| ptap_once(algo, &comm, &a, &p, &tracker).0.gather_global(&comm))
                .collect::<Vec<_>>()
        });
        let aao = &cs[0][0];
        for per_rank in &cs {
            for c in per_rank {
                assert!(aao.max_abs_diff(c) < 1e-10);
            }
        }
    }

    #[test]
    fn numeric_rerun_reproduces_values() {
        let w = World::new(2);
        w.run(|comm| {
            let a = random_dist(comm.rank(), comm.size(), 40, 40, 4, 500);
            let p = random_dist(comm.rank(), comm.size(), 40, 12, 2, 600);
            let tracker = MemTracker::new();
            for algo in ALL_ALGOS {
                let mut op = Ptap::symbolic(algo, &comm, &a, &p, &tracker);
                op.numeric(&comm, &a, &p);
                let c1 = op.extract_c();
                for _ in 0..3 {
                    op.numeric(&comm, &a, &p);
                }
                let c2 = op.extract_c();
                assert!(c1.diag == c2.diag && c1.offd == c2.offd, "{:?} rerun", algo);
                assert_eq!(op.stats.num_calls, 4);
            }
        });
    }

    #[test]
    fn eviction_lowers_all_at_once_hash_peak() {
        // Rank 1 owns every coarse row, so all of rank 0's outer-product
        // contributions flow through the remote stage (its local tables
        // are empty).  All-at-once frees each staged row's hash map right
        // after its pipelined post — targets advance every two fine rows,
        // so at most one stage row is live — while merged end-stages the
        // full table.  Rank 0's hash peak must therefore drop.
        use crate::dist::{DistCsrBuilder, Layout};
        let w = World::new(2);
        let peaks = w.run(|comm| {
            let n = 40;
            let m = 20;
            let rl = Layout::new_equal(n, 2);
            let cl = Layout::from_counts(&[0, m]);
            let a = random_dist(comm.rank(), comm.size(), n, n, 8, 4242);
            let mut pb = DistCsrBuilder::new(comm.rank(), rl.clone(), cl.clone());
            for gi in rl.range(comm.rank()) {
                // each fine-row pair hits one coarse target, advancing so
                // rank 0's staged rows complete (and evict) throughout
                let local_i = gi - rl.start(comm.rank());
                pb.push_row(&[((local_i / 2) as u64, 1.0 + gi as f64)]);
            }
            let p = pb.finish();
            if comm.rank() == 0 {
                assert_eq!(p.diag.nnz(), 0, "rank 0's P must be all-remote");
            }
            let peak_hash = |algo: Algo| {
                let tracker = MemTracker::new();
                let (_c, _stats) = ptap_once(algo, &comm, &a, &p, &tracker);
                tracker.peak(crate::mem::Cat::Hash)
            };
            (peak_hash(Algo::AllAtOnce), peak_hash(Algo::Merged))
        });
        let (aao, merged) = peaks[0];
        assert!(
            aao < merged,
            "eviction must lower rank 0's staged hash peak: aao {aao} vs merged {merged}"
        );
    }

    #[test]
    fn tracker_balances_on_drop() {
        let w = World::new(2);
        w.run(|comm| {
            let a = random_dist(comm.rank(), comm.size(), 30, 30, 4, 700);
            let p = random_dist(comm.rank(), comm.size(), 30, 10, 2, 800);
            for algo in ALL_ALGOS {
                let tracker = MemTracker::new();
                {
                    let mut op = Ptap::symbolic(algo, &comm, &a, &p, &tracker);
                    op.numeric(&comm, &a, &p);
                    assert!(tracker.current_total() > 0);
                }
                assert_eq!(tracker.current_total(), 0, "{:?} leaked bytes", algo);
                assert!(tracker.peak_total() > 0);
            }
        });
    }

    #[test]
    fn two_step_retains_more_memory() {
        // the paper's core claim, at unit-test scale, on the structured
        // model problem (random matrices make C nearly dense, which is
        // not the regime the claim is about)
        let w = World::new(4);
        let peaks = w.run(|comm| {
            let mp = crate::gen::ModelProblem::build(
                crate::gen::Grid3::cube(8),
                comm.rank(),
                comm.size(),
            );
            let (a, p) = (mp.a, mp.p);
            ALL_ALGOS
                .iter()
                .map(|&algo| {
                    let tracker = MemTracker::new();
                    let mut op = Ptap::symbolic(algo, &comm, &a, &p, &tracker);
                    op.numeric(&comm, &a, &p);
                    tracker.peak_total()
                })
                .collect::<Vec<u64>>()
        });
        for p in peaks {
            let (aao, merged, two_step) = (p[0], p[1], p[2]);
            assert!(
                two_step as f64 > 1.5 * aao as f64,
                "two-step {} vs aao {}",
                two_step,
                aao
            );
            // aao evicts staged rows as their pipelined posts complete,
            // so its peak can sit below merged's end-staged peak — but
            // never meaningfully above it
            let ratio = aao as f64 / merged as f64;
            assert!((0.5..1.25).contains(&ratio), "aao {} merged {}", aao, merged);
        }
    }
}
