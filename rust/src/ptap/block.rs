//! Block-structured all-at-once triple product (MPIBAIJ analog) — the
//! path where the compiled Pallas kernel sits on the numeric hot path.
//!
//! For a block matrix the elementary numeric update is a dense `b×b`
//! triple product `C(i,j) += P(I,i)ᵀ · A(I,K) · P(K,j)` — exactly what the
//! `block_ptap` artifact batches through the MXU (see
//! python/compile/kernels/block_ptap.py).  The surrounding algorithm is
//! the merged all-at-once scheme: one pass over the fine block rows, local
//! targets land in the preallocated C, remote targets are staged per owner
//! and shipped once.

use std::collections::HashMap;

use crate::dist::{Comm, DistBcsr, Layout, PrBlocks, RowGatherPlan};
use crate::hash::IntSet;
use crate::mat::Bcsr;
use crate::mem::{Cat, MemTracker};
use crate::runtime::{BlockBackend, TripleBatcher};
use crate::util::bytebuf::{ByteReader, ByteWriter};
use crate::util::timer::BusyTimer;

use super::common::{exchange_tracked, write_sym_row, PtapStats};

/// Result of a block triple product.
pub struct BlockPtapResult {
    pub c: DistBcsr,
    pub stats: PtapStats,
    /// Elementary b×b triple products evaluated.
    pub triples: u64,
    /// Kernel invocations (chunks).
    pub flushes: u64,
}

/// Exactly-preallocated block output with fixed sorted patterns.
struct BlockCOutput {
    b: usize,
    rank: usize,
    layout: Layout,
    diag: Bcsr,
    /// offd with *global* block columns (compacted in `to_dist`).
    offd_rowptr: Vec<u32>,
    offd_gcols: Vec<u64>,
    offd_vals: Vec<f64>,
}

impl BlockCOutput {
    fn from_patterns(
        b: usize,
        rank: usize,
        layout: Layout,
        diag_rows: Vec<Vec<u32>>,
        offd_rows: Vec<Vec<u64>>,
    ) -> Self {
        let nloc = layout.local_size(rank);
        let bb = b * b;
        let mut diag_rowptr = vec![0u32];
        let mut diag_cols = Vec::new();
        for r in &diag_rows {
            diag_cols.extend_from_slice(r);
            diag_rowptr.push(diag_cols.len() as u32);
        }
        let diag_nnz = diag_cols.len();
        let mut offd_rowptr = vec![0u32];
        let mut offd_gcols = Vec::new();
        for r in &offd_rows {
            offd_gcols.extend_from_slice(r);
            offd_rowptr.push(offd_gcols.len() as u32);
        }
        let offd_nnz = offd_gcols.len();
        BlockCOutput {
            b,
            rank,
            layout,
            diag: Bcsr {
                b,
                nrows: nloc,
                ncols: nloc,
                rowptr: diag_rowptr,
                cols: diag_cols,
                vals: vec![0.0; diag_nnz * bb],
            },
            offd_rowptr,
            offd_gcols,
            offd_vals: vec![0.0; offd_nnz * bb],
        }
    }

    fn bytes(&self) -> u64 {
        self.diag.bytes()
            + (self.offd_rowptr.len() * 4 + self.offd_gcols.len() * 8 + self.offd_vals.len() * 8)
                as u64
    }

    /// Accumulate a block into local row `i`, global block column `gcol`.
    fn add_block(&mut self, i: usize, gcol: u64, blk: &[f64]) {
        let bb = self.b * self.b;
        let cbeg = self.layout.start(self.rank) as u64;
        let cend = self.layout.end(self.rank) as u64;
        if gcol >= cbeg && gcol < cend {
            let local = (gcol - cbeg) as u32;
            let r = self.diag.rowptr[i] as usize..self.diag.rowptr[i + 1] as usize;
            let pos = r.start
                + self.diag.cols[r.clone()]
                    .binary_search(&local)
                    .expect("block symbolic undercounted (diag)");
            for (o, &v) in self.diag.vals[pos * bb..(pos + 1) * bb].iter_mut().zip(blk) {
                *o += v;
            }
        } else {
            let r = self.offd_rowptr[i] as usize..self.offd_rowptr[i + 1] as usize;
            let pos = r.start
                + self.offd_gcols[r.clone()]
                    .binary_search(&gcol)
                    .expect("block symbolic undercounted (offd)");
            for (o, &v) in self.offd_vals[pos * bb..(pos + 1) * bb].iter_mut().zip(blk) {
                *o += v;
            }
        }
    }

    fn to_dist(self) -> DistBcsr {
        let mut garray: Vec<u64> = self.offd_gcols.clone();
        garray.sort_unstable();
        garray.dedup();
        let cols: Vec<u32> = self
            .offd_gcols
            .iter()
            .map(|g| garray.binary_search(g).unwrap() as u32)
            .collect();
        let offd = Bcsr {
            b: self.b,
            nrows: self.diag.nrows,
            ncols: garray.len(),
            rowptr: self.offd_rowptr,
            cols,
            vals: self.offd_vals,
        };
        DistBcsr {
            rank: self.rank,
            b: self.b,
            row_layout: self.layout.clone(),
            col_layout: self.layout,
            diag: self.diag,
            offd,
            garray,
        }
    }
}

/// Iterate the (global block col, block values) pairs of row `I` of P,
/// calling `f` for each — covering diag, offd, or a gathered remote row.
#[inline]
fn for_each_p_block<'a>(
    p: &'a DistBcsr,
    i: usize,
    mut f: impl FnMut(u64, &'a [f64]),
) {
    let cbeg = p.col_begin() as u64;
    for idx in p.diag.row_range(i) {
        f(cbeg + p.diag.cols[idx] as u64, p.diag.block(idx));
    }
    for idx in p.offd.row_range(i) {
        f(p.garray[p.offd.cols[idx] as usize], p.offd.block(idx));
    }
}

/// The block triple product `C = PᵀAP` (collective).
pub fn block_ptap(
    comm: &Comm,
    a: &DistBcsr,
    p: &DistBcsr,
    backend: BlockBackend<'_>,
    tracker: &MemTracker,
) -> BlockPtapResult {
    assert_eq!(a.b, p.b, "block sizes must match");
    let b = a.b;
    let bb = b * b;
    let mut stats = PtapStats::default();
    let mut timer = BusyTimer::new();
    timer.start();

    // remote block rows of P named by A's offd columns
    let plan = RowGatherPlan::build(comm, &p.row_layout, &a.garray);
    let prb: PrBlocks = plan.gather_bcsr(comm, p);
    tracker.alloc(Cat::Comm, plan.bytes() + prb.bytes());

    let cbeg = p.col_layout.start(p.rank) as u64;
    let cend = p.col_layout.end(p.rank) as u64;
    let nloc = a.local_nrows();

    // ---- symbolic: per-C-row block column sets ------------------------
    let nloc_coarse = p.col_layout.local_size(p.rank);
    let mut loc_sets: Vec<Option<(IntSet, IntSet)>> = (0..nloc_coarse).map(|_| None).collect();
    let mut rem_sets: Vec<Option<IntSet>> = (0..p.garray.len()).map(|_| None).collect();
    let mut row_cols = IntSet::default();
    let mut row_cols_buf: Vec<u64> = Vec::new();
    for i_fine in 0..nloc {
        // R = block cols of (AP)(I,:)
        row_cols.clear();
        for idx in a.diag.row_range(i_fine) {
            let k = a.diag.cols[idx] as usize;
            for_each_p_block(p, k, |gc, _| {
                row_cols.insert(gc);
            });
        }
        for idx in a.offd.row_range(i_fine) {
            let k = a.offd.cols[idx] as usize;
            for &gc in prb.row_cols(k) {
                row_cols.insert(gc);
            }
        }
        if row_cols.is_empty() {
            continue;
        }
        row_cols.collect_sorted(&mut row_cols_buf);
        // scatter to targets selected by P(I,:)
        for idx in p.diag.row_range(i_fine) {
            let i_coarse = p.diag.cols[idx] as usize;
            let (d, o) =
                loc_sets[i_coarse].get_or_insert_with(|| (IntSet::default(), IntSet::default()));
            for &gc in &row_cols_buf {
                if gc >= cbeg && gc < cend {
                    d.insert(gc - cbeg);
                } else {
                    o.insert(gc);
                }
            }
        }
        for idx in p.offd.row_range(i_fine) {
            let t = p.offd.cols[idx] as usize;
            let set = rem_sets[t].get_or_insert_with(IntSet::default);
            for &gc in &row_cols_buf {
                set.insert(gc);
            }
        }
    }
    // ship remote pattern rows to owners
    let np = comm.size();
    let mut writers: Vec<Option<ByteWriter>> = (0..np).map(|_| None).collect();
    for (t, set) in rem_sets.iter().enumerate() {
        let Some(set) = set else { continue };
        let grow = p.garray[t];
        let owner = p.col_layout.owner(grow as usize);
        let w = writers[owner].get_or_insert_with(ByteWriter::new);
        set.collect_sorted(&mut row_cols_buf);
        write_sym_row(w, grow, &row_cols_buf);
    }
    let sym_hash_bytes: u64 = loc_sets
        .iter()
        .flatten()
        .map(|(d, o)| d.bytes() + o.bytes())
        .chain(rem_sets.iter().flatten().map(|s| s.bytes()))
        .sum();
    tracker.alloc(Cat::Hash, sym_hash_bytes);
    let sends: Vec<(usize, Vec<u8>)> = writers
        .into_iter()
        .enumerate()
        .filter_map(|(d, w)| w.map(|w| (d, w.into_bytes())))
        .collect();
    let recvd = exchange_tracked(comm, sends, &mut stats.sym_msgs, &mut stats.sym_bytes);
    for (_src, payload) in &recvd {
        let mut r = ByteReader::new(payload);
        while !r.done() {
            let grow = r.u64();
            let n = r.u32() as usize;
            let i = (grow - cbeg) as usize;
            let (d, o) =
                loc_sets[i].get_or_insert_with(|| (IntSet::default(), IntSet::default()));
            for _ in 0..n {
                let gc = r.u64();
                if gc >= cbeg && gc < cend {
                    d.insert(gc - cbeg);
                } else {
                    o.insert(gc);
                }
            }
        }
    }
    drop(rem_sets);
    // materialize sorted patterns, free the sets
    let mut diag_rows: Vec<Vec<u32>> = Vec::with_capacity(nloc_coarse);
    let mut offd_rows: Vec<Vec<u64>> = Vec::with_capacity(nloc_coarse);
    for entry in loc_sets.iter() {
        match entry {
            Some((d, o)) => {
                d.collect_sorted(&mut row_cols_buf);
                diag_rows.push(row_cols_buf.iter().map(|&c| c as u32).collect());
                o.collect_sorted(&mut row_cols_buf);
                offd_rows.push(row_cols_buf.clone());
            }
            None => {
                diag_rows.push(Vec::new());
                offd_rows.push(Vec::new());
            }
        }
    }
    drop(loc_sets);
    tracker.free(Cat::Hash, sym_hash_bytes);
    let mut c = BlockCOutput::from_patterns(b, p.rank, p.col_layout.clone(), diag_rows, offd_rows);
    tracker.alloc(Cat::MatC, c.bytes());
    stats.time_sym = {
        timer.stop();
        let t = timer.total();
        timer = BusyTimer::new();
        timer.start();
        t
    };

    // ---- numeric: batched triple products ------------------------------
    // Targets table: tag -> (kind, row-or-garray-pos, global col)
    #[derive(Clone, Copy)]
    enum Target {
        Local { i: u32, gcol: u64 },
        Remote { t: u32, gcol: u64 },
    }
    let mut targets: Vec<Target> = Vec::new();
    let mut remote_acc: HashMap<(u32, u64), Vec<f64>> = HashMap::new();
    let mut batcher = TripleBatcher::new(backend, b);

    // two-phase drain: collect batcher outputs into (tag, block) pairs,
    // then apply — avoids borrowing `c`/`remote_acc` inside the sink.
    let mut drained: Vec<(u64, Vec<f64>)> = Vec::new();
    {
        let mut sink = |tag: u64, blk: &[f64]| drained.push((tag, blk.to_vec()));
        for i_fine in 0..nloc {
            // enumerate (K, A block) pairs of row I
            // and P(K,:) blocks; scatter against P(I,:) targets
            let p_targets_d = p.diag.row_range(i_fine);
            let p_targets_o = p.offd.row_range(i_fine);
            if p_targets_d.is_empty() && p_targets_o.is_empty() {
                continue;
            }
            let do_pair = |a_blk: &[f64], gc_j: u64, pr_blk: &[f64],
                               batcher: &mut TripleBatcher<'_>,
                               targets: &mut Vec<Target>,
                               sink: &mut dyn FnMut(u64, &[f64])| {
                for idx in p_targets_d.clone() {
                    let i_coarse = p.diag.cols[idx];
                    let pl_blk = p.diag.block(idx);
                    let tag = targets.len() as u64;
                    targets.push(Target::Local { i: i_coarse, gcol: gc_j });
                    batcher.push(pl_blk, a_blk, pr_blk, tag, sink);
                }
                for idx in p_targets_o.clone() {
                    let t = p.offd.cols[idx];
                    let pl_blk = p.offd.block(idx);
                    let tag = targets.len() as u64;
                    targets.push(Target::Remote { t, gcol: gc_j });
                    batcher.push(pl_blk, a_blk, pr_blk, tag, sink);
                }
            };
            for idx in a.diag.row_range(i_fine) {
                let k = a.diag.cols[idx] as usize;
                let a_blk = a.diag.block(idx);
                for_each_p_block(p, k, |gc, pr_blk| {
                    do_pair(a_blk, gc, pr_blk, &mut batcher, &mut targets, &mut sink);
                });
            }
            for idx in a.offd.row_range(i_fine) {
                let k = a.offd.cols[idx] as usize;
                let a_blk = a.offd.block(idx);
                for ridx in prb.row_range(k) {
                    let gc = prb.gcols[ridx];
                    let pr_blk = prb.block(ridx);
                    do_pair(a_blk, gc, pr_blk, &mut batcher, &mut targets, &mut sink);
                }
            }
        }
        batcher.flush(&mut sink);
    }
    tracker.alloc(Cat::Hash, batcher.bytes() + (targets.len() * 24) as u64);
    // apply drained results
    for (tag, blk) in &drained {
        match targets[*tag as usize] {
            Target::Local { i, gcol } => c.add_block(i as usize, gcol, blk),
            Target::Remote { t, gcol } => {
                let acc = remote_acc
                    .entry((t, gcol))
                    .or_insert_with(|| vec![0.0; bb]);
                for (o, &v) in acc.iter_mut().zip(blk) {
                    *o += v;
                }
            }
        }
    }
    tracker.free(Cat::Hash, batcher.bytes() + (targets.len() * 24) as u64);
    // ship remote numeric contributions
    let mut writers: Vec<Option<ByteWriter>> = (0..np).map(|_| None).collect();
    let mut keys: Vec<(u32, u64)> = remote_acc.keys().copied().collect();
    keys.sort_unstable();
    for (t, gcol) in keys {
        let grow = p.garray[t as usize];
        let owner = p.col_layout.owner(grow as usize);
        let w = writers[owner].get_or_insert_with(ByteWriter::new);
        w.u64(grow);
        w.u64(gcol);
        w.f64_slice(&remote_acc[&(t, gcol)]);
    }
    let sends: Vec<(usize, Vec<u8>)> = writers
        .into_iter()
        .enumerate()
        .filter_map(|(d, w)| w.map(|w| (d, w.into_bytes())))
        .collect();
    let recvd = exchange_tracked(comm, sends, &mut stats.num_msgs, &mut stats.num_bytes);
    for (_src, payload) in &recvd {
        let mut r = ByteReader::new(payload);
        let mut blk = vec![0.0f64; bb];
        while !r.done() {
            let grow = r.u64();
            let gcol = r.u64();
            for v in blk.iter_mut() {
                *v = r.f64();
            }
            c.add_block((grow - cbeg) as usize, gcol, &blk);
        }
    }
    timer.stop();
    stats.time_num = timer.total();
    stats.num_calls = 1;

    let c_bytes = c.bytes();
    let c = c.to_dist();
    tracker.free(Cat::MatC, c_bytes);
    tracker.alloc(Cat::MatC, c.bytes());
    tracker.free(Cat::Comm, plan.bytes() + prb.bytes());
    // caller owns C's charge now
    tracker.free(Cat::MatC, c.bytes());
    BlockPtapResult { c, stats, triples: batcher.triples, flushes: batcher.flushes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::World;
    use crate::gen::{neutron_block_interp, neutron_block_operator, Grid3, NeutronConfig};
    use crate::ptap::{ptap_once, Algo};

    #[test]
    fn block_ptap_matches_scalar_ptap() {
        let cfg = NeutronConfig { grid: Grid3::cube(4), groups: 3, seed: 7 };
        let w = World::new(3);
        w.run(|comm| {
            let a = neutron_block_operator(cfg, comm.rank(), comm.size());
            let p = neutron_block_interp(cfg.grid, cfg.groups, comm.rank(), comm.size());
            let tracker = MemTracker::new();
            let res = block_ptap(&comm, &a, &p, BlockBackend::Native, &tracker);
            res.c.validate().unwrap();
            assert!(res.triples > 0);
            // scalar oracle: expand and run the scalar all-at-once product
            let a_s = a.to_scalar();
            let p_s = p.to_scalar();
            let (c_s, _) = ptap_once(Algo::AllAtOnce, &comm, &a_s, &p_s, &tracker);
            let want = c_s.gather_global(&comm);
            let got = res.c.to_scalar().gather_global(&comm);
            // block result stores explicit zeros inside blocks; compare by
            // values
            let diff = got.max_abs_diff(&want);
            assert!(diff < 1e-10, "block vs scalar diff {diff}");
        });
    }

    #[test]
    fn block_ptap_tracker_balances() {
        let cfg = NeutronConfig { grid: Grid3::cube(3), groups: 2, seed: 9 };
        let w = World::new(2);
        w.run(|comm| {
            let a = neutron_block_operator(cfg, comm.rank(), comm.size());
            let p = neutron_block_interp(cfg.grid, cfg.groups, comm.rank(), comm.size());
            let tracker = MemTracker::new();
            let _res = block_ptap(&comm, &a, &p, BlockBackend::Native, &tracker);
            assert_eq!(tracker.current_total(), 0);
            assert!(tracker.peak_total() > 0);
        });
    }
}
