//! The merged all-at-once triple product (paper Alg. 9–10): identical to
//! all-at-once except the remote and local outer-product loops are fused,
//! so the row `R = (AP)(I,:)` is computed ONCE per fine row instead of
//! twice.  The trade-off: sends are staged until the single loop ends, so
//! there is (almost) no communication/compute overlap — "if the
//! communication in the first loop is expensive, we may prefer the
//! all-at-once" (paper §3).  The sends still ride the nonblocking engine
//! ([`send_staged_tracked`]), which *measures* that missing overlap: the
//! window between the first post and the epoch close is by construction
//! ≈ 0 here, versus the whole local loop for all-at-once.

use crate::dist::{tag, Comm, DistCsr, PrMat};
use crate::mem::{Cat, MemTracker};
use crate::spgemm::{RowScratch, RowView};

use super::all_at_once::AaoState;
use super::common::{
    for_each_num_row, for_each_sym_row, send_staged_tracked, COutput, LocalSymTables, PtapStats,
    RemoteStageNum, RemoteStageSym,
};

/// Alg. 9: symbolic phase (single fused loop).
pub fn symbolic(
    comm: &Comm,
    a: &DistCsr,
    p: &DistCsr,
    pr: &PrMat,
    scratch: &mut RowScratch,
    stats: &mut PtapStats,
    tracker: &MemTracker,
) -> (AaoState, COutput) {
    let v = RowView::new(a, p, pr);
    let cbeg = v.cbeg;
    let cend = v.cend;
    let nloc = a.local_nrows();

    let mut cs = RemoteStageSym::new(p.garray.len());
    let mut clh = LocalSymTables::new(p.diag.ncols);
    // Lines 6–15: one pass; R computed once, scattered to both stages.
    for i_fine in 0..nloc {
        let ocols = p.offd.row_cols(i_fine);
        let dcols = p.diag.row_cols(i_fine);
        if ocols.is_empty() && dcols.is_empty() {
            continue;
        }
        scratch.symbolic_row(v, i_fine);
        scratch.rd.collect_sorted(&mut scratch.dcols);
        scratch.ro.collect_sorted(&mut scratch.ocols);
        for &t in ocols {
            let set = cs.row_mut(t as usize);
            for &c in &scratch.dcols {
                set.insert((c + cbeg) as u32);
            }
            for &c in &scratch.ocols {
                set.insert(c as u32);
            }
        }
        for &i_coarse in dcols {
            let (d, o) = clh.row_mut(i_coarse as usize);
            for &c in &scratch.dcols {
                d.insert(c as u32);
            }
            for &c in &scratch.ocols {
                o.insert(c as u32);
            }
        }
    }
    tracker.alloc(Cat::Hash, cs.bytes());
    // Lines 16–19: send (end-staged — the fused loop traded the overlap
    // away), receive, merge.
    let sends = cs.serialize(&p.garray, &p.col_layout, comm.size());
    let send_bytes: u64 = sends.iter().map(|(_, b)| b.len() as u64).sum();
    tracker.alloc(Cat::Comm, send_bytes);
    let recvd = send_staged_tracked(
        comm,
        tag::PTAP_SYM,
        sends,
        &mut stats.sym_msgs,
        &mut stats.sym_bytes,
        &mut stats.sym_overlap,
    );
    tracker.free(Cat::Hash, cs.bytes());
    drop(cs);
    let recv_bytes: u64 = recvd.iter().map(|(_, b)| b.len() as u64).sum();
    tracker.alloc(Cat::Comm, recv_bytes);
    for (_src, payload) in &recvd {
        for_each_sym_row(payload, |grow, cols| {
            clh.insert_global((grow - cbeg) as usize, cols, cbeg, cend);
        });
    }
    tracker.alloc(Cat::Hash, clh.bytes());
    tracker.free(Cat::Comm, send_bytes + recv_bytes);
    // Lines 20–27: counts, free, preallocate.
    let (nzd, nzo) = clh.counts();
    tracker.free(Cat::Hash, clh.bytes());
    drop(clh);
    let c = COutput::prealloc(p.rank, p.col_layout.clone(), &nzd, &nzo);
    tracker.alloc(Cat::MatC, c.bytes());
    (AaoState::default(), c)
}

/// Alg. 10: numeric phase (single fused loop, re-runnable).
pub fn numeric(
    state: &mut AaoState,
    comm: &Comm,
    a: &DistCsr,
    p: &DistCsr,
    pr: &PrMat,
    scratch: &mut RowScratch,
    c: &mut COutput,
    stats: &mut PtapStats,
    tracker: &MemTracker,
) {
    let v = RowView::new(a, p, pr);
    let cbeg = v.cbeg;
    let nloc = a.local_nrows();
    c.zero_values();

    let mut csm = RemoteStageNum::new(p.garray.len());
    // Lines 4–13: fused loop.
    for i_fine in 0..nloc {
        let (ocols, ovals) = p.offd.row(i_fine);
        let (dcols, dvals) = p.diag.row(i_fine);
        if ocols.is_empty() && dcols.is_empty() {
            continue;
        }
        scratch.numeric_row(v, i_fine);
        scratch.extract_numeric();
        for (&t, &w) in ocols.iter().zip(ovals) {
            let map = csm.row_mut(t as usize);
            for (&cc, &vv) in scratch.dcols.iter().zip(&scratch.dvals) {
                map.add(cc + cbeg, w * vv);
            }
            for (&cc, &vv) in scratch.ocols.iter().zip(&scratch.ovals) {
                map.add(cc, w * vv);
            }
        }
        if !dcols.is_empty() {
            state.scatter_local(scratch, c, dcols, dvals);
        }
    }
    tracker.alloc(Cat::Hash, csm.bytes());
    // Lines 14–16: send (end-staged), receive, merge.
    let sends = csm.serialize(&p.garray, &p.col_layout, comm.size());
    let send_bytes: u64 = sends.iter().map(|(_, b)| b.len() as u64).sum();
    tracker.alloc(Cat::Comm, send_bytes);
    let recvd = send_staged_tracked(
        comm,
        tag::PTAP_NUM,
        sends,
        &mut stats.num_msgs,
        &mut stats.num_bytes,
        &mut stats.num_overlap,
    );
    tracker.free(Cat::Hash, csm.bytes());
    drop(csm);
    let recv_bytes: u64 = recvd.iter().map(|(_, b)| b.len() as u64).sum();
    tracker.alloc(Cat::Comm, recv_bytes);
    for (_src, payload) in &recvd {
        for_each_num_row(payload, |grow, cols, vals| {
            c.add_global_row((grow - cbeg) as usize, cols, vals);
        });
    }
    tracker.free(Cat::Comm, send_bytes + recv_bytes);
    stats.num_calls += 1;
}
