//! The all-at-once triple product (paper Alg. 7–8): form `C = PᵀAP` in one
//! pass over `A` and `P` — no auxiliary `C̃`, no explicit `Pᵀ`.
//!
//! Per fine row `I`, the row `R = (AP)(I,:)` is formed row-wise (Alg. 1/3)
//! in a reusable hash accumulator, then scattered as the outer product
//! `P(I,:) ⊗ R`: nonzeros of `P_o(I,:)` select *remote* target rows of `C`
//! (staged per P.garray position and shipped to their owners), nonzeros of
//! `P_d(I,:)` select *local* rows.  Two loops (remote first, then local)
//! let the communication overlap the local compute.

use crate::dist::{Comm, DistCsr, PrMat};
use crate::mem::{Cat, MemTracker};
use crate::spgemm::{RowScratch, RowView};

use super::common::{
    exchange_tracked, for_each_num_row, for_each_sym_row, COutput, LocalSymTables, PtapStats,
    RemoteStageNum, RemoteStageSym,
};

/// Reusable u32 conversion buffers for the numeric scatter.
#[derive(Debug, Default)]
pub struct AaoState {
    dcols32: Vec<u32>,
    ocols32: Vec<u32>,
}

impl AaoState {
    /// Scatter the extracted row `R` (in `scratch`) into the local rows of
    /// C selected by `P_d(I,:)` — the outer product `P_d(I,:) ⊗ R`.
    pub(crate) fn scatter_local(
        &mut self,
        scratch: &RowScratch,
        c: &mut COutput,
        dcols: &[u32],
        dvals: &[f64],
    ) {
        self.dcols32.clear();
        self.dcols32.extend(scratch.dcols.iter().map(|&c| c as u32));
        self.ocols32.clear();
        self.ocols32.extend(scratch.ocols.iter().map(|&c| c as u32));
        for (&i_coarse, &w) in dcols.iter().zip(dvals) {
            c.add_split_scaled(
                i_coarse as usize,
                &self.dcols32,
                &scratch.dvals,
                &self.ocols32,
                &scratch.ovals,
                w,
            );
        }
    }
}

/// Alg. 7: symbolic phase.
pub fn symbolic(
    comm: &Comm,
    a: &DistCsr,
    p: &DistCsr,
    pr: &PrMat,
    scratch: &mut RowScratch,
    stats: &mut PtapStats,
    tracker: &MemTracker,
) -> (AaoState, COutput) {
    let v = RowView::new(a, p, pr);
    let cbeg = v.cbeg;
    let cend = v.cend;
    let nloc = a.local_nrows();

    // First loop (lines 5–13): remote contributions C_s^H += P_o(I,:) ⊗ R.
    let mut cs = RemoteStageSym::new(p.garray.len());
    for i_fine in 0..nloc {
        let ocols = p.offd.row_cols(i_fine);
        if ocols.is_empty() {
            continue;
        }
        scratch.symbolic_row(v, i_fine);
        scratch.rd.collect_sorted(&mut scratch.dcols);
        scratch.ro.collect_sorted(&mut scratch.ocols);
        for &t in ocols {
            let set = cs.row_mut(t as usize);
            for &c in &scratch.dcols {
                set.insert((c + cbeg) as u32);
            }
            for &c in &scratch.ocols {
                set.insert(c as u32);
            }
        }
    }
    tracker.alloc(Cat::Hash, cs.bytes());
    // Line 14: send C_s^H to its owners.
    let sends = cs.serialize(&p.garray, &p.col_layout, comm.size());
    let send_bytes: u64 = sends.iter().map(|(_, b)| b.len() as u64).sum();
    tracker.alloc(Cat::Comm, send_bytes);
    let recvd = exchange_tracked(comm, sends, &mut stats.sym_msgs, &mut stats.sym_bytes);
    tracker.free(Cat::Hash, cs.bytes());
    drop(cs);
    let recv_bytes: u64 = recvd.iter().map(|(_, b)| b.len() as u64).sum();
    tracker.alloc(Cat::Comm, recv_bytes);

    // Second loop (lines 16–25): local contributions C_l^H += P_d(I,:) ⊗ R.
    let mut clh = LocalSymTables::new(p.diag.ncols);
    for i_fine in 0..nloc {
        let dcols = p.diag.row_cols(i_fine);
        if dcols.is_empty() {
            continue;
        }
        scratch.symbolic_row(v, i_fine);
        scratch.rd.collect_sorted(&mut scratch.dcols);
        scratch.ro.collect_sorted(&mut scratch.ocols);
        for &i_coarse in dcols {
            let (d, o) = clh.row_mut(i_coarse as usize);
            for &c in &scratch.dcols {
                d.insert(c as u32);
            }
            for &c in &scratch.ocols {
                o.insert(c as u32);
            }
        }
    }
    // Lines 26–27: receive C_r^H and merge.
    for (_src, payload) in &recvd {
        for_each_sym_row(payload, |grow, cols| {
            clh.insert_global((grow - cbeg) as usize, cols, cbeg, cend);
        });
    }
    tracker.alloc(Cat::Hash, clh.bytes());
    tracker.free(Cat::Comm, send_bytes + recv_bytes);
    // Lines 29–36: counts, free tables, preallocate C.
    let (nzd, nzo) = clh.counts();
    tracker.free(Cat::Hash, clh.bytes());
    drop(clh);
    let c = COutput::prealloc(p.rank, p.col_layout.clone(), &nzd, &nzo);
    tracker.alloc(Cat::MatC, c.bytes());
    (AaoState::default(), c)
}

/// Alg. 8: numeric phase (re-runnable).
pub fn numeric(
    state: &mut AaoState,
    comm: &Comm,
    a: &DistCsr,
    p: &DistCsr,
    pr: &PrMat,
    scratch: &mut RowScratch,
    c: &mut COutput,
    stats: &mut PtapStats,
    tracker: &MemTracker,
) {
    let v = RowView::new(a, p, pr);
    let cbeg = v.cbeg;
    let nloc = a.local_nrows();
    c.zero_values();

    // First loop (lines 4–12): remote contributions C_s += P_o(I,:) ⊗ R.
    let mut csm = RemoteStageNum::new(p.garray.len());
    for i_fine in 0..nloc {
        let (ocols, ovals) = p.offd.row(i_fine);
        if ocols.is_empty() {
            continue;
        }
        scratch.numeric_row(v, i_fine);
        scratch.extract_numeric();
        for (&t, &w) in ocols.iter().zip(ovals) {
            let map = csm.row_mut(t as usize);
            for (&cc, &vv) in scratch.dcols.iter().zip(&scratch.dvals) {
                map.add(cc + cbeg, w * vv);
            }
            for (&cc, &vv) in scratch.ocols.iter().zip(&scratch.ovals) {
                map.add(cc, w * vv);
            }
        }
    }
    tracker.alloc(Cat::Hash, csm.bytes());
    // Line 13: send C_s.
    let sends = csm.serialize(&p.garray, &p.col_layout, comm.size());
    let send_bytes: u64 = sends.iter().map(|(_, b)| b.len() as u64).sum();
    tracker.alloc(Cat::Comm, send_bytes);
    let recvd = exchange_tracked(comm, sends, &mut stats.num_msgs, &mut stats.num_bytes);
    tracker.free(Cat::Hash, csm.bytes());
    drop(csm);
    let recv_bytes: u64 = recvd.iter().map(|(_, b)| b.len() as u64).sum();
    tracker.alloc(Cat::Comm, recv_bytes);

    // Second loop (lines 15–23): local contributions straight into the
    // preallocated C.
    for i_fine in 0..nloc {
        let (dcols, dvals) = p.diag.row(i_fine);
        if dcols.is_empty() {
            continue;
        }
        scratch.numeric_row(v, i_fine);
        scratch.extract_numeric();
        state.scatter_local(scratch, c, dcols, dvals);
    }
    // Lines 24–25: receive C_r, C_l += C_r.
    for (_src, payload) in &recvd {
        for_each_num_row(payload, |grow, cols, vals| {
            c.add_global_row((grow - cbeg) as usize, cols, vals);
        });
    }
    tracker.free(Cat::Comm, send_bytes + recv_bytes);
    stats.num_calls += 1;
}
