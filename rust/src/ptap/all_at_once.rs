//! The all-at-once triple product (paper Alg. 7–8): form `C = PᵀAP` in one
//! pass over `A` and `P` — no auxiliary `C̃`, no explicit `Pᵀ`.
//!
//! Per fine row `I`, the row `R = (AP)(I,:)` is formed row-wise (Alg. 1/3)
//! in a reusable hash accumulator, then scattered as the outer product
//! `P(I,:) ⊗ R`: nonzeros of `P_o(I,:)` select *remote* target rows of `C`
//! (staged per P.garray position and shipped to their owners), nonzeros of
//! `P_d(I,:)` select *local* rows.  Two loops (remote first, then local)
//! let the communication overlap the local compute — and with the
//! nonblocking engine the overlap is real: each staged row is posted
//! ([`crate::dist::Comm::isend`]) the moment its *last* contributing fine
//! row has passed (the precomputed last-touch schedule), so chunks are in
//! flight throughout the remainder of the remote loop and the whole local
//! loop, and the epoch closes only after the local loop finishes.
//!
//! Determinism: received remote contributions are folded into `C` after
//! the local loop, in the engine's canonical source-rank order, so the
//! pipelined product is bit-identical to the bulk-synchronous one (each
//! source sends at most one contribution row per global C row, and
//! distinct target rows touch disjoint slots — only the cross-source and
//! local-vs-remote fold orders matter, and both are preserved).

use crate::dist::{tag, Comm, DistCsr, PrMat};
use crate::mem::{Cat, MemTracker};
use crate::spgemm::{RowScratch, RowView};

use super::common::{
    for_each_num_row, for_each_sym_row, write_num_row, write_sym_row, COutput, LocalSymTables,
    PtapStats, RemoteStageNum, RemoteStageSym, ScatterPipeline,
};

/// Reusable u32 conversion buffers for the numeric scatter, plus the
/// pipeline's send schedule (fixed by P's structure, computed once in the
/// symbolic phase and reused by every numeric call).
#[derive(Debug, Default)]
pub struct AaoState {
    dcols32: Vec<u32>,
    ocols32: Vec<u32>,
    /// rowptr over fine rows / P.garray positions whose staged C row
    /// completes at that row (its last off-diagonal touch).
    finish_ptr: Vec<u32>,
    finish_items: Vec<u32>,
}

impl AaoState {
    /// Scatter the extracted row `R` (in `scratch`) into the local rows of
    /// C selected by `P_d(I,:)` — the outer product `P_d(I,:) ⊗ R`.
    pub(crate) fn scatter_local(
        &mut self,
        scratch: &RowScratch,
        c: &mut COutput,
        dcols: &[u32],
        dvals: &[f64],
    ) {
        self.dcols32.clear();
        self.dcols32.extend(scratch.dcols.iter().map(|&c| c as u32));
        self.ocols32.clear();
        self.ocols32.extend(scratch.ocols.iter().map(|&c| c as u32));
        for (&i_coarse, &w) in dcols.iter().zip(dvals) {
            c.add_split_scaled(
                i_coarse as usize,
                &self.dcols32,
                &scratch.dvals,
                &self.ocols32,
                &scratch.ovals,
                w,
            );
        }
    }
}

/// The pipeline's send schedule: for each fine row, the P.garray positions
/// whose staged C row completes there (i.e. whose last off-diagonal touch
/// is that row).  Returned as a rowptr/items pair over `0..nloc`.
fn stage_finish_lists(p: &DistCsr, nloc: usize) -> (Vec<u32>, Vec<u32>) {
    let nt = p.garray.len();
    let mut last = vec![u32::MAX; nt];
    for i in 0..nloc {
        for &t in p.offd.row_cols(i) {
            last[t as usize] = i as u32;
        }
    }
    let mut ptr = vec![0u32; nloc + 1];
    for &l in &last {
        if l != u32::MAX {
            ptr[l as usize + 1] += 1;
        }
    }
    for i in 0..nloc {
        ptr[i + 1] += ptr[i];
    }
    let mut items = vec![0u32; *ptr.last().unwrap() as usize];
    let mut cursor = ptr.clone();
    for (t, &l) in last.iter().enumerate() {
        if l != u32::MAX {
            items[cursor[l as usize] as usize] = t as u32;
            cursor[l as usize] += 1;
        }
    }
    (ptr, items)
}

/// Alg. 7: symbolic phase.
pub fn symbolic(
    comm: &Comm,
    a: &DistCsr,
    p: &DistCsr,
    pr: &PrMat,
    scratch: &mut RowScratch,
    stats: &mut PtapStats,
    tracker: &MemTracker,
) -> (AaoState, COutput) {
    let v = RowView::new(a, p, pr);
    let cbeg = v.cbeg;
    let cend = v.cend;
    let nloc = a.local_nrows();

    // First loop (lines 5–13): remote contributions C_s^H += P_o(I,:) ⊗ R,
    // posting each staged row as soon as its last touch has passed — and
    // *evicting* it: the row's hash set is freed the moment the pipelined
    // send has serialized it, so the symbolic hash peak is the running
    // maximum of live stage rows, not the whole stage.  Growth and
    // eviction both flow through the tracker incrementally
    // (`MemTracker::update`), so the reported peak is that running max.
    let (finish_ptr, finish_items) = stage_finish_lists(p, nloc);
    let mut pipe = ScatterPipeline::new(comm.size(), tag::PTAP_SYM);
    let mut sorted: Vec<u64> = Vec::new();
    let mut cs = RemoteStageSym::new(p.garray.len());
    let slot_bytes = cs.bytes();
    tracker.alloc(Cat::Hash, slot_bytes);
    let mut row_bytes: Vec<u64> = vec![0; p.garray.len()];
    for i_fine in 0..nloc {
        let ocols = p.offd.row_cols(i_fine);
        if !ocols.is_empty() {
            scratch.symbolic_row(v, i_fine);
            scratch.rd.collect_sorted(&mut scratch.dcols);
            scratch.ro.collect_sorted(&mut scratch.ocols);
            for &t in ocols {
                let set = cs.row_mut(t as usize);
                for &c in &scratch.dcols {
                    set.insert((c + cbeg) as u32);
                }
                for &c in &scratch.ocols {
                    set.insert(c as u32);
                }
                let nb = set.bytes();
                tracker.update(Cat::Hash, row_bytes[t as usize], nb);
                row_bytes[t as usize] = nb;
            }
        }
        // Line 14, pipelined: ship every stage row that just completed,
        // freeing its set immediately after the post.
        for &t in &finish_items[finish_ptr[i_fine] as usize..finish_ptr[i_fine + 1] as usize] {
            let Some(set) = cs.rows[t as usize].take() else { continue };
            if !set.is_empty() {
                let grow = p.garray[t as usize];
                let owner = p.col_layout.owner(grow as usize);
                set.collect_sorted_u64(&mut sorted);
                write_sym_row(pipe.writer(owner), grow, &sorted);
                pipe.row_done(comm, owner);
            }
            tracker.free(Cat::Hash, row_bytes[t as usize]);
            row_bytes[t as usize] = 0;
        }
    }
    // every touched row has a last touch, so the stage is empty here —
    // only the slot array remains to release
    debug_assert!(row_bytes.iter().all(|&b| b == 0), "stage row escaped eviction");
    tracker.free(Cat::Hash, slot_bytes);
    drop(cs);

    // Second loop (lines 16–25): local contributions C_l^H += P_d(I,:) ⊗ R,
    // folding received remote rows between chunks (set union is
    // order-independent, so the eager merge cannot change the pattern).
    let mut clh = LocalSymTables::new(p.diag.ncols);
    let mut recv_bytes: u64 = 0;
    let poll_every = pipe.chunk_rows();
    for i_fine in 0..nloc {
        if i_fine % poll_every == 0 {
            for (_src, payload) in pipe.poll(comm) {
                recv_bytes += payload.len() as u64;
                for_each_sym_row(&payload, |grow, cols| {
                    clh.insert_global((grow - cbeg) as usize, cols, cbeg, cend);
                });
            }
        }
        let dcols = p.diag.row_cols(i_fine);
        if dcols.is_empty() {
            continue;
        }
        scratch.symbolic_row(v, i_fine);
        scratch.rd.collect_sorted(&mut scratch.dcols);
        scratch.ro.collect_sorted(&mut scratch.ocols);
        for &i_coarse in dcols {
            let (d, o) = clh.row_mut(i_coarse as usize);
            for &c in &scratch.dcols {
                d.insert(c as u32);
            }
            for &c in &scratch.ocols {
                o.insert(c as u32);
            }
        }
    }
    // Lines 26–27: epoch close — merge the stragglers.
    for (_src, payload) in pipe.finish(comm) {
        recv_bytes += payload.len() as u64;
        for_each_sym_row(&payload, |grow, cols| {
            clh.insert_global((grow - cbeg) as usize, cols, cbeg, cend);
        });
    }
    stats.sym_msgs += pipe.msgs;
    stats.sym_bytes += pipe.bytes;
    stats.sym_overlap += pipe.overlap;
    // Comm-buffer accounting: the stage was evicted row by row during
    // the remote loop, so only the send/receive buffers and the local
    // tables coexist here.
    tracker.alloc(Cat::Comm, pipe.bytes + recv_bytes);
    tracker.alloc(Cat::Hash, clh.bytes());
    tracker.free(Cat::Comm, pipe.bytes + recv_bytes);
    // Lines 29–36: counts, free tables, preallocate C.
    let (nzd, nzo) = clh.counts();
    tracker.free(Cat::Hash, clh.bytes());
    drop(clh);
    let c = COutput::prealloc(p.rank, p.col_layout.clone(), &nzd, &nzo);
    tracker.alloc(Cat::MatC, c.bytes());
    // retain the send schedule: every numeric call replays it
    let state =
        AaoState { dcols32: Vec::new(), ocols32: Vec::new(), finish_ptr, finish_items };
    (state, c)
}

/// Alg. 8: numeric phase (re-runnable).
pub fn numeric(
    state: &mut AaoState,
    comm: &Comm,
    a: &DistCsr,
    p: &DistCsr,
    pr: &PrMat,
    scratch: &mut RowScratch,
    c: &mut COutput,
    stats: &mut PtapStats,
    tracker: &MemTracker,
) {
    let v = RowView::new(a, p, pr);
    let cbeg = v.cbeg;
    let nloc = a.local_nrows();
    c.zero_values();

    // First loop (lines 4–12): remote contributions C_s += P_o(I,:) ⊗ R,
    // posted on stage-row completion (the symbolic phase's last-touch
    // schedule, retained in `state`) and evicted right after the post —
    // the numeric hash peak is the running max of live stage rows.
    let mut pipe = ScatterPipeline::new(comm.size(), tag::PTAP_NUM);
    let mut kbuf: Vec<u64> = Vec::new();
    let mut vbuf: Vec<f64> = Vec::new();
    let mut csm = RemoteStageNum::new(p.garray.len());
    let slot_bytes = csm.bytes();
    tracker.alloc(Cat::Hash, slot_bytes);
    let mut row_bytes: Vec<u64> = vec![0; p.garray.len()];
    for i_fine in 0..nloc {
        let (ocols, ovals) = p.offd.row(i_fine);
        if !ocols.is_empty() {
            scratch.numeric_row(v, i_fine);
            scratch.extract_numeric();
            for (&t, &w) in ocols.iter().zip(ovals) {
                let map = csm.row_mut(t as usize);
                for (&cc, &vv) in scratch.dcols.iter().zip(&scratch.dvals) {
                    map.add(cc + cbeg, w * vv);
                }
                for (&cc, &vv) in scratch.ocols.iter().zip(&scratch.ovals) {
                    map.add(cc, w * vv);
                }
                let nb = map.bytes();
                tracker.update(Cat::Hash, row_bytes[t as usize], nb);
                row_bytes[t as usize] = nb;
            }
        }
        // Line 13, pipelined: ship completed stage rows while the loop
        // keeps computing, freeing each row's map after its post.
        let finishing = &state.finish_items
            [state.finish_ptr[i_fine] as usize..state.finish_ptr[i_fine + 1] as usize];
        for &t in finishing {
            let Some(mut map) = csm.rows[t as usize].take() else { continue };
            if !map.is_empty() {
                let grow = p.garray[t as usize];
                let owner = p.col_layout.owner(grow as usize);
                map.collect_sorted(&mut kbuf, &mut vbuf);
                write_num_row(pipe.writer(owner), grow, &kbuf, &vbuf);
                pipe.row_done(comm, owner);
            }
            tracker.free(Cat::Hash, row_bytes[t as usize]);
            row_bytes[t as usize] = 0;
        }
    }
    debug_assert!(row_bytes.iter().all(|&b| b == 0), "stage row escaped eviction");
    tracker.free(Cat::Hash, slot_bytes);
    drop(csm);

    // Second loop (lines 15–23): local contributions straight into the
    // preallocated C.  Received chunks are *released* (taken off the
    // wire) between chunks, but folded only after the loop: a C row can
    // take both local and remote contributions, and the bulk path folds
    // all locals first — deferring keeps the slot update order, hence the
    // bits, identical.
    let mut recvd: Vec<(usize, Vec<u8>)> = Vec::new();
    let poll_every = pipe.chunk_rows();
    for i_fine in 0..nloc {
        if i_fine % poll_every == 0 {
            recvd.extend(pipe.poll(comm));
        }
        let (dcols, dvals) = p.diag.row(i_fine);
        if dcols.is_empty() {
            continue;
        }
        scratch.numeric_row(v, i_fine);
        scratch.extract_numeric();
        state.scatter_local(scratch, c, dcols, dvals);
    }
    // Lines 24–25: epoch close; C_l += C_r in canonical source order.
    recvd.extend(pipe.finish(comm));
    // Comm-buffer accounting: the stage was evicted row by row during
    // the remote loop, so only the send/receive buffers remain.
    let recv_bytes: u64 = recvd.iter().map(|(_, b)| b.len() as u64).sum();
    tracker.alloc(Cat::Comm, pipe.bytes + recv_bytes);
    for (_src, payload) in &recvd {
        for_each_num_row(payload, |grow, cols, vals| {
            c.add_global_row((grow - cbeg) as usize, cols, vals);
        });
    }
    tracker.free(Cat::Comm, pipe.bytes + recv_bytes);
    stats.num_msgs += pipe.msgs;
    stats.num_bytes += pipe.bytes;
    stats.num_overlap += pipe.overlap;
    stats.num_calls += 1;
}
