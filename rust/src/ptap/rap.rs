//! General triple product `C = R·A·P` with an explicit restriction `R`
//! (PETSc `MatMatMatMult` / `MatRARt` analog).
//!
//! The paper's all-at-once algorithms exploit `R = Pᵀ`; this module serves
//! the *general* case the paper's introduction cites (Schur-complement
//! style products, non-Galerkin restriction).  Implementation: the
//! row-wise SpGEMM twice — `C̃ = A·P` materialized per-rank, converted to
//! a distributed matrix, then `C = R·C̃` with a second remote-row gather
//! driven by `R`'s off-diagonal columns.

use crate::dist::{Comm, DistCsr, DistCsrBuilder, RowGatherPlan};
use crate::mem::{Cat, MemTracker};
use crate::spgemm::{ApProduct, RowScratch, RowView, StampedAccumulator};

/// Compute `C = R·A·P` (collective).
///
/// Layout requirements: `R.col_layout == A.row_layout`,
/// `A.col_layout == P.row_layout`.  The result is distributed over
/// `R.row_layout × P.col_layout`.
pub fn rap(
    comm: &Comm,
    r: &DistCsr,
    a: &DistCsr,
    p: &DistCsr,
    tracker: &MemTracker,
) -> DistCsr {
    assert_eq!(r.col_layout, a.row_layout, "R cols must match A rows");
    assert_eq!(a.col_layout, p.row_layout, "A cols must match P rows");

    // --- C̃ = A·P (row-wise, materialized) ---------------------------
    let plan = RowGatherPlan::build(comm, &p.row_layout, &a.garray);
    let pr = plan.gather_csr(comm, p);
    tracker.alloc(Cat::Comm, plan.bytes() + pr.bytes());
    let v = RowView::new(a, p, &pr);
    let mut scratch = RowScratch::default();
    let mut acc = StampedAccumulator::new(p.global_ncols());
    let mut ap = ApProduct::symbolic(v, &mut scratch);
    ap.numeric(v, &mut acc);
    tracker.alloc(Cat::Aux, ap.bytes() + acc.bytes());
    tracker.free(Cat::Comm, plan.bytes() + pr.bytes());
    drop((plan, pr));

    // convert C̃ to a distributed matrix over A.rows × P.cols
    let mut tb = DistCsrBuilder::new(comm.rank(), a.row_layout.clone(), p.col_layout.clone());
    let mut entries: Vec<(u64, f64)> = Vec::new();
    for i in 0..a.local_nrows() {
        let (cols, vals) = ap.mat.row(i);
        entries.clear();
        entries.extend(cols.iter().zip(vals).map(|(&c, &v)| (c as u64, v)));
        tb.push_row(&entries);
    }
    let ctilde = tb.finish();
    tracker.alloc(Cat::Aux, ctilde.bytes());
    let ap_bytes = ap.bytes() + acc.bytes();
    drop(ap);

    // --- C = R·C̃ (row-wise over local R rows) -----------------------
    let plan2 = RowGatherPlan::build(comm, &ctilde.row_layout, &r.garray);
    let cr = plan2.gather_csr(comm, &ctilde);
    tracker.alloc(Cat::Comm, plan2.bytes() + cr.bytes());
    let v2 = RowView::new(r, &ctilde, &cr);
    let mut acc2 = StampedAccumulator::new(p.global_ncols());
    let mut ob = DistCsrBuilder::new(comm.rank(), r.row_layout.clone(), p.col_layout.clone());
    let mut cols32: Vec<u32> = Vec::new();
    let mut vals: Vec<f64> = Vec::new();
    let cbeg2 = v2.cbeg as u32;
    for i in 0..r.local_nrows() {
        // accumulate Σ_k R(i,k) C̃(k,:) densely (global columns)
        {
            let (rc, rv) = r.diag.row(i);
            for (&k, &rval) in rc.iter().zip(rv) {
                let k = k as usize;
                let (tc, tv) = ctilde.diag.row(k);
                for (&j, &tval) in tc.iter().zip(tv) {
                    acc2.add(cbeg2 + j, rval * tval);
                }
                let (oc, ov) = ctilde.offd.row(k);
                for (&j, &tval) in oc.iter().zip(ov) {
                    acc2.add(ctilde.garray[j as usize] as u32, rval * tval);
                }
            }
            let (rc, rv) = r.offd.row(i);
            for (&k, &rval) in rc.iter().zip(rv) {
                let (gc, gv) = cr.row(k as usize);
                for (&gj, &tval) in gc.iter().zip(gv) {
                    acc2.add(gj as u32, rval * tval);
                }
            }
        }
        acc2.extract_sorted(&mut cols32, &mut vals);
        let entries: Vec<(u64, f64)> =
            cols32.iter().zip(&vals).map(|(&c, &v)| (c as u64, v)).collect();
        ob.push_row(&entries);
    }
    tracker.free(Cat::Comm, plan2.bytes() + cr.bytes());
    tracker.free(Cat::Aux, ap_bytes + ctilde.bytes());
    let c = ob.finish();
    tracker.alloc(Cat::MatC, c.bytes());
    tracker.free(Cat::MatC, c.bytes()); // caller owns the charge
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{transpose_dist, World};
    use crate::gen::{random_dist_csr, Grid3, ModelProblem};
    use crate::ptap::{ptap_once, Algo};

    /// R = Pᵀ (built by the general distributed transpose) must make
    /// rap() agree with the all-at-once PtAP.
    #[test]
    fn rap_with_transposed_p_equals_ptap() {
        for np in [1, 3] {
            let world = World::new(np);
            world.run(|comm| {
                let mp = ModelProblem::build(Grid3::cube(4), comm.rank(), comm.size());
                let tracker = MemTracker::new();
                let rt = transpose_dist(&comm, &mp.p);
                rt.validate().unwrap();
                let c_rap = rap(&comm, &rt, &mp.a, &mp.p, &tracker);
                c_rap.validate().unwrap();
                let (c_ptap, _) = ptap_once(Algo::AllAtOnce, &comm, &mp.a, &mp.p, &tracker);
                let g1 = c_rap.gather_global(&comm);
                let g2 = c_ptap.gather_global(&comm);
                let diff = g1.max_abs_diff(&g2);
                assert!(diff < 1e-10, "rap vs ptap diff {diff}");
            });
        }
    }

    /// Random rectangular R (not Pᵀ): compare against the sequential
    /// reference R·(A·P).
    #[test]
    fn general_rap_matches_sequential() {
        let world = World::new(2);
        world.run(|comm| {
            let n = 30;
            let m = 10;
            let k = 8; // R rows
            let a = random_dist_csr(comm.rank(), comm.size(), n, n, 4, 1);
            let p = random_dist_csr(comm.rank(), comm.size(), n, m, 2, 2);
            // R: k x n
            let r = random_dist_csr(comm.rank(), comm.size(), k, n, 5, 3);
            let tracker = MemTracker::new();
            let c = rap(&comm, &r, &a, &p, &tracker);
            let got = c.gather_global(&comm);
            // sequential reference
            let (rg, ag, pg) =
                (r.gather_global(&comm), a.gather_global(&comm), p.gather_global(&comm));
            let seq_mm = |x: &crate::mat::Csr, y: &crate::mat::Csr| {
                let mut b = crate::mat::CsrBuilder::new(y.ncols);
                let mut accm: std::collections::BTreeMap<u32, f64> = Default::default();
                for i in 0..x.nrows {
                    accm.clear();
                    let (xc, xv) = x.row(i);
                    for (&kk, &xval) in xc.iter().zip(xv) {
                        let (yc, yv) = y.row(kk as usize);
                        for (&j, &yval) in yc.iter().zip(yv) {
                            *accm.entry(j).or_insert(0.0) += xval * yval;
                        }
                    }
                    let cols: Vec<u32> = accm.keys().copied().collect();
                    let vals: Vec<f64> = accm.values().copied().collect();
                    b.push_row(&cols, &vals);
                }
                b.finish()
            };
            let want = seq_mm(&rg, &seq_mm(&ag, &pg));
            let diff = got.max_abs_diff(&want);
            assert!(diff < 1e-10, "diff {diff}");
        });
    }

    #[test]
    fn transpose_dist_round_trips() {
        let world = World::new(3);
        world.run(|comm| {
            let p = random_dist_csr(comm.rank(), comm.size(), 25, 9, 3, 7);
            let t = transpose_dist(&comm, &p);
            t.validate().unwrap();
            let tt = transpose_dist(&comm, &t);
            let g1 = p.gather_global(&comm);
            let g2 = tt.gather_global(&comm);
            assert_eq!(g1, g2);
            // and the transpose itself matches the sequential transpose
            let gt = t.gather_global(&comm);
            assert_eq!(gt, g1.transpose());
        });
    }
}
