//! Live telemetry: a per-rank, allocation-free registry of named counters,
//! gauges, and log₂-bucket rolling-window histograms with streaming
//! percentiles — the always-on counterpart of the trace recorder.
//!
//! Cost model mirrors the tracer exactly: every hook starts with the same
//! single thread-local activity-bitmask read (see `obs::active_bits`), so
//! a binary with telemetry compiled in but not armed pays one TLS load per
//! hook and nothing else.  When armed (`rank_begin`), updates touch a
//! pre-registered slot found through a `(lane, &'static str)` hash — the
//! only allocation is the slot itself on first use of a new name.
//!
//! Histograms never store samples: each observation lands in a log₂
//! bucket (lifetime totals) and in a fixed-size rolling window of bucket
//! indices, so p50/p95/p99 stream from cumulative bucket counts with no
//! post-hoc sort (`util::stats::bucket_percentile`).  Percentiles are
//! exact to bucket resolution (a factor of 2), which is what latency
//! monitoring needs; the bench cells keep their sample-exact percentiles.
//!
//! Cross-rank view: [`merge_global`] serialises each rank's snapshot and
//! runs a **single collective round** (`allgather_bytes`), then every rank
//! folds the per-rank snapshots deterministically (rank order) into a
//! [`MergedMetrics`] — per-rank min/max/mean/median for counters and
//! gauges (the median reuses the shared `util::stats::percentile`),
//! bucket-wise sums and streaming percentiles for histograms.  Note the
//! merge round itself sends messages, so observation-only comparisons must
//! capture comm stats *before* merging.

use std::cell::RefCell;
use std::collections::HashMap;

use super::{METRICS_BIT, Subsys};
use crate::dist::Comm;
use crate::util::bytebuf::{ByteReader, ByteWriter};
use crate::util::stats::{bucket_percentile, percentile};
use crate::util::table::Table;

/// Log₂ buckets: bucket `i` holds values in `[2^i, 2^{i+1})` (value 0
/// clamps into bucket 0); bucket 31 is open-ended.  Covers 1 µs .. ~35 min
/// for durations and 1 B .. 2 GiB for sizes.
pub const HIST_BUCKETS: usize = 32;

/// Rolling-window length per histogram (recent samples kept as bucket
/// indices, one byte each).
pub const WINDOW_CAP: usize = 512;

/// Bucket index for a value: `floor(log2(max(v,1)))`, clamped.
#[inline]
pub fn bucket_of(v: u64) -> usize {
    (63 - v.max(1).leading_zeros() as usize).min(HIST_BUCKETS - 1)
}

/// Representative value for bucket `i`: the geometric midpoint of
/// `[2^i, 2^{i+1})`.
pub fn bucket_rep(i: usize) -> f64 {
    2f64.powi(i as i32) * std::f64::consts::SQRT_2
}

/// Metric kind (wire-stable discriminants).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Counter = 0,
    Gauge = 1,
    Hist = 2,
}

impl Kind {
    pub fn name(self) -> &'static str {
        match self {
            Kind::Counter => "counter",
            Kind::Gauge => "gauge",
            Kind::Hist => "hist",
        }
    }

    fn from_u8(v: u8) -> Kind {
        match v {
            0 => Kind::Counter,
            1 => Kind::Gauge,
            _ => Kind::Hist,
        }
    }
}

struct Metric {
    sub: Subsys,
    name: &'static str,
    kind: Kind,
    /// Counter: running total.  Gauge: last sampled value.
    value: u64,
    /// Histogram lifetime observation count / value sum.
    count: u64,
    sum: u64,
    buckets: [u64; HIST_BUCKETS],
    /// Rolling window: ring of bucket indices plus per-bucket counts so
    /// eviction is O(1) and percentiles need no replay.
    win: Vec<u8>,
    win_head: usize,
    win_buckets: [u32; HIST_BUCKETS],
}

impl Metric {
    fn new(sub: Subsys, name: &'static str, kind: Kind) -> Metric {
        Metric {
            sub,
            name,
            kind,
            value: 0,
            count: 0,
            sum: 0,
            buckets: [0; HIST_BUCKETS],
            win: Vec::new(),
            win_head: 0,
            win_buckets: [0; HIST_BUCKETS],
        }
    }

    fn observe(&mut self, v: u64) {
        let b = bucket_of(v);
        self.count += 1;
        self.sum += v;
        self.buckets[b] += 1;
        if self.win.len() < WINDOW_CAP {
            if self.win.capacity() == 0 {
                self.win.reserve_exact(WINDOW_CAP);
            }
            self.win.push(b as u8);
        } else {
            let old = self.win[self.win_head] as usize;
            self.win_buckets[old] -= 1;
            self.win[self.win_head] = b as u8;
            self.win_head = (self.win_head + 1) % WINDOW_CAP;
        }
        self.win_buckets[b] += 1;
    }
}

struct Registry {
    rank: usize,
    metrics: Vec<Metric>,
    index: HashMap<(u32, &'static str), usize>,
}

impl Registry {
    fn new(rank: usize) -> Registry {
        Registry { rank, metrics: Vec::new(), index: HashMap::new() }
    }

    fn slot(&mut self, sub: Subsys, name: &'static str, kind: Kind) -> &mut Metric {
        let key = (sub.tid(), name);
        if let Some(&idx) = self.index.get(&key) {
            return &mut self.metrics[idx];
        }
        let idx = self.metrics.len();
        self.metrics.push(Metric::new(sub, name, kind));
        self.index.insert(key, idx);
        &mut self.metrics[idx]
    }

    fn snapshot(&self) -> MetricsSnapshot {
        let mut entries: Vec<EntrySnap> = self
            .metrics
            .iter()
            .map(|m| EntrySnap {
                sub: m.sub.name().to_string(),
                name: m.name.to_string(),
                kind: m.kind,
                value: m.value,
                count: m.count,
                sum: m.sum,
                buckets: if m.kind == Kind::Hist { m.buckets.to_vec() } else { Vec::new() },
                win_buckets: if m.kind == Kind::Hist {
                    m.win_buckets.iter().map(|&c| c as u64).collect()
                } else {
                    Vec::new()
                },
            })
            .collect();
        entries.sort_by(|a, b| (&a.sub, &a.name).cmp(&(&b.sub, &b.name)));
        MetricsSnapshot { rank: self.rank, entries }
    }
}

thread_local! {
    static REGISTRY: RefCell<Option<Registry>> = const { RefCell::new(None) };
}

/// Is the metrics registry armed on this rank thread?  Shares the single
/// activity-bitmask TLS read with the tracer.
#[inline]
pub fn enabled() -> bool {
    super::active_bits() & METRICS_BIT != 0
}

/// Arm the registry on the calling rank thread.  Pair with [`rank_take`].
pub fn rank_begin(rank: usize) {
    REGISTRY.with(|r| *r.borrow_mut() = Some(Registry::new(rank)));
    super::set_active_bit(METRICS_BIT, true);
}

/// Disarm and hand back this rank's final snapshot (empty if never armed).
pub fn rank_take() -> MetricsSnapshot {
    super::set_active_bit(METRICS_BIT, false);
    REGISTRY
        .with(|r| r.borrow_mut().take())
        .map(|reg| reg.snapshot())
        .unwrap_or_default()
}

/// Snapshot the live registry without disarming it (`serve --stats-every`
/// calls this at each snapshot round).
pub fn local_snapshot() -> Option<MetricsSnapshot> {
    REGISTRY.with(|r| r.borrow().as_ref().map(|reg| reg.snapshot()))
}

#[inline]
fn with_slot(sub: Subsys, name: &'static str, kind: Kind, f: impl FnOnce(&mut Metric)) {
    REGISTRY.with(|r| {
        if let Some(reg) = r.borrow_mut().as_mut() {
            f(reg.slot(sub, name, kind));
        }
    });
}

/// Increment a counter by `delta`.  One TLS read when disarmed.
#[inline]
pub fn add(sub: Subsys, name: &'static str, delta: u64) {
    if !enabled() {
        return;
    }
    with_slot(sub, name, Kind::Counter, |m| m.value += delta);
}

/// Sample a gauge (last value wins; merged min/max/mean are per rank).
#[inline]
pub fn gauge(sub: Subsys, name: &'static str, val: u64) {
    if !enabled() {
        return;
    }
    with_slot(sub, name, Kind::Gauge, |m| m.value = val);
}

/// Observe one sample into a histogram.
#[inline]
pub fn observe(sub: Subsys, name: &'static str, val: u64) {
    if !enabled() {
        return;
    }
    with_slot(sub, name, Kind::Hist, |m| m.observe(val));
}

/// Pre-register the transport-reliability and serve-recovery counters at
/// zero.  The transport and session layers only touch these series when
/// the corresponding event fires, so without this a clean run's snapshot
/// lines would silently lack them; registering them up front keeps the
/// JSONL schema stable whether or not anything went wrong.  No-op when
/// the registry is disarmed.
pub fn register_reliability_series() {
    for name in ["retransmits", "corrupt_frames", "nack_roundtrips", "dup_suppressed", "timeouts"]
    {
        add(Subsys::Comm, name, 0);
    }
    for name in ["rebuilds", "queue.shed", "request.cancelled", "request.failed"] {
        add(Subsys::Session, name, 0);
    }
}

/// Span drop hook: the caller (`obs::Span`) already checked the activity
/// bits, so go straight to the slot.
pub(crate) fn span_observed(sub: Subsys, name: &'static str, dur_us: u64) {
    with_slot(sub, name, Kind::Hist, |m| m.observe(dur_us));
}

/// One rank's serialisable registry snapshot.
#[derive(Debug, Clone, Default)]
pub struct MetricsSnapshot {
    pub rank: usize,
    pub entries: Vec<EntrySnap>,
}

#[derive(Debug, Clone)]
pub struct EntrySnap {
    pub sub: String,
    pub name: String,
    pub kind: Kind,
    pub value: u64,
    pub count: u64,
    pub sum: u64,
    pub buckets: Vec<u64>,
    pub win_buckets: Vec<u64>,
}

impl MetricsSnapshot {
    pub fn serialize(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.u32(self.rank as u32);
        w.u32(self.entries.len() as u32);
        for e in &self.entries {
            w.u32(e.sub.len() as u32);
            w.bytes(e.sub.as_bytes());
            w.u32(e.name.len() as u32);
            w.bytes(e.name.as_bytes());
            w.u8(e.kind as u8);
            w.u64(e.value);
            w.u64(e.count);
            w.u64(e.sum);
            w.u32(e.buckets.len() as u32);
            w.u64_slice(&e.buckets);
            w.u32(e.win_buckets.len() as u32);
            w.u64_slice(&e.win_buckets);
        }
        w.into_bytes()
    }

    pub fn deserialize(bytes: &[u8]) -> MetricsSnapshot {
        let mut r = ByteReader::new(bytes);
        let rank = r.u32() as usize;
        let n = r.u32() as usize;
        let mut entries = Vec::with_capacity(n);
        for _ in 0..n {
            let sl = r.u32() as usize;
            let sub = String::from_utf8(r.bytes(sl).to_vec()).unwrap();
            let nl = r.u32() as usize;
            let name = String::from_utf8(r.bytes(nl).to_vec()).unwrap();
            let kind = Kind::from_u8(r.u8());
            let value = r.u64();
            let count = r.u64();
            let sum = r.u64();
            let nb = r.u32() as usize;
            let buckets = (0..nb).map(|_| r.u64()).collect();
            let nw = r.u32() as usize;
            let win_buckets = (0..nw).map(|_| r.u64()).collect();
            entries.push(EntrySnap { sub, name, kind, value, count, sum, buckets, win_buckets });
        }
        MetricsSnapshot { rank, entries }
    }
}

/// One metric folded across ranks.
#[derive(Debug, Clone)]
pub struct MergedEntry {
    pub sub: String,
    pub name: String,
    pub kind: Kind,
    /// Per-rank primary value: counter/gauge value, histogram count.
    pub per_rank: Vec<u64>,
    /// Per-rank sum: equals `per_rank` for counters/gauges, the value sum
    /// for histograms (feeds the cross-rank imbalance indicator).
    pub per_rank_sum: Vec<u64>,
    pub count: u64,
    pub sum: u64,
    pub buckets: Vec<u64>,
    pub win_buckets: Vec<u64>,
}

impl MergedEntry {
    pub fn min(&self) -> u64 {
        self.per_rank.iter().copied().min().unwrap_or(0)
    }

    pub fn max(&self) -> u64 {
        self.per_rank.iter().copied().max().unwrap_or(0)
    }

    pub fn total(&self) -> u64 {
        self.per_rank.iter().sum()
    }

    pub fn mean(&self) -> f64 {
        if self.per_rank.is_empty() {
            0.0
        } else {
            self.total() as f64 / self.per_rank.len() as f64
        }
    }

    /// Cross-rank median of the per-rank values — this is where the
    /// shared nearest-rank `percentile` is reused by the snapshot path.
    pub fn median(&self) -> f64 {
        let vals: Vec<f64> = self.per_rank.iter().map(|&v| v as f64).collect();
        percentile(&vals, 50.0)
    }

    /// Streaming percentile over the merged rolling windows (histograms).
    pub fn p(&self, p: f64) -> f64 {
        bucket_percentile(&self.win_buckets, p, bucket_rep)
    }

    /// Mean sample value over the lifetime of the histogram.
    pub fn mean_sample(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// max/mean of the per-rank sums: 1.0 is perfectly balanced, 0 when
    /// nothing was recorded.
    pub fn imbalance(&self) -> f64 {
        let sums: Vec<f64> = self.per_rank_sum.iter().map(|&v| v as f64).collect();
        crate::obs::health::imbalance(&sums)
    }
}

/// All metrics folded across ranks, sorted by (lane, name).
#[derive(Debug, Clone, Default)]
pub struct MergedMetrics {
    pub ranks: usize,
    pub entries: Vec<MergedEntry>,
}

/// Deterministic fold of per-rank snapshots (rank order; entries sorted).
pub fn merge_snapshots(snaps: &[MetricsSnapshot]) -> MergedMetrics {
    let np = snaps.len();
    let mut entries: Vec<MergedEntry> = Vec::new();
    let mut index: HashMap<(String, String), usize> = HashMap::new();
    for snap in snaps {
        let r = snap.rank;
        for e in &snap.entries {
            let key = (e.sub.clone(), e.name.clone());
            let idx = *index.entry(key).or_insert_with(|| {
                entries.push(MergedEntry {
                    sub: e.sub.clone(),
                    name: e.name.clone(),
                    kind: e.kind,
                    per_rank: vec![0; np],
                    per_rank_sum: vec![0; np],
                    count: 0,
                    sum: 0,
                    buckets: vec![0; HIST_BUCKETS],
                    win_buckets: vec![0; HIST_BUCKETS],
                });
                entries.len() - 1
            });
            let me = &mut entries[idx];
            let (primary, rank_sum) = match e.kind {
                Kind::Hist => (e.count, e.sum),
                _ => (e.value, e.value),
            };
            if r < np {
                me.per_rank[r] = primary;
                me.per_rank_sum[r] = rank_sum;
            }
            me.count += e.count;
            me.sum += e.sum;
            for (i, &b) in e.buckets.iter().enumerate().take(HIST_BUCKETS) {
                me.buckets[i] += b;
            }
            for (i, &b) in e.win_buckets.iter().enumerate().take(HIST_BUCKETS) {
                me.win_buckets[i] += b;
            }
        }
    }
    entries.sort_by(|a, b| (&a.sub, &a.name).cmp(&(&b.sub, &b.name)));
    MergedMetrics { ranks: np, entries }
}

/// Merge every rank's snapshot with **one** collective round.  All ranks
/// must call this at the same point (SPMD); every rank gets the same
/// merged view.  The round itself sends messages — capture comm stats
/// before calling if you are comparing observation-only invariants.
pub fn merge_global(comm: &Comm, local: &MetricsSnapshot) -> MergedMetrics {
    let all = comm.allgather_bytes(local.serialize());
    let snaps: Vec<MetricsSnapshot> = all.iter().map(|b| MetricsSnapshot::deserialize(b)).collect();
    merge_snapshots(&snaps)
}

impl MergedMetrics {
    /// One schema-valid JSONL snapshot line (see DESIGN §13 for the
    /// schema; `stats-check` validates it).
    pub fn jsonl_line(&self, snapshot: u64, ts_us: u64) -> String {
        let mut s = format!(
            "{{\"snapshot\":{snapshot},\"ts_us\":{ts_us},\"ranks\":{},\"metrics\":[",
            self.ranks
        );
        for (i, e) in self.entries.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "{{\"sub\":\"{}\",\"name\":\"{}\",\"kind\":\"{}\"",
                e.sub,
                e.name,
                e.kind.name()
            ));
            match e.kind {
                Kind::Counter | Kind::Gauge => {
                    s.push_str(&format!(
                        ",\"sum\":{},\"min\":{},\"max\":{},\"mean\":{:.3}",
                        e.total(),
                        e.min(),
                        e.max(),
                        e.mean()
                    ));
                }
                Kind::Hist => {
                    s.push_str(&format!(
                        ",\"count\":{},\"sum\":{},\"mean\":{:.3},\"p50\":{:.3},\"p95\":{:.3},\"p99\":{:.3},\"imbalance\":{:.3}",
                        e.count,
                        e.sum,
                        e.mean_sample(),
                        e.p(50.0),
                        e.p(95.0),
                        e.p(99.0),
                        e.imbalance()
                    ));
                }
            }
            s.push('}');
        }
        s.push_str("]}");
        s
    }

    /// Human-readable exit report (printed by `serve` on shutdown).
    pub fn render_report(&self) -> String {
        let mut t = Table::new(vec![
            "subsys", "metric", "kind", "total", "mean", "p50", "p95", "p99", "imb",
        ]);
        for e in &self.entries {
            match e.kind {
                Kind::Counter | Kind::Gauge => t.row(vec![
                    e.sub.clone(),
                    e.name.clone(),
                    e.kind.name().to_string(),
                    format!("{}", e.total()),
                    format!("{:.1}", e.mean()),
                    "-".to_string(),
                    "-".to_string(),
                    "-".to_string(),
                    "-".to_string(),
                ]),
                Kind::Hist => t.row(vec![
                    e.sub.clone(),
                    e.name.clone(),
                    "hist".to_string(),
                    format!("{}", e.count),
                    format!("{:.1}", e.mean_sample()),
                    format!("{:.1}", e.p(50.0)),
                    format!("{:.1}", e.p(95.0)),
                    format!("{:.1}", e.p(99.0)),
                    format!("{:.2}", e.imbalance()),
                ]),
            }
        }
        t.render()
    }
}

/// Summary returned by the JSONL snapshot validator.
#[derive(Debug, Clone, Default)]
pub struct StatsCheck {
    pub lines: usize,
    pub metrics: usize,
}

fn field<'a>(
    obj: &'a [(String, super::chrome::json::Value)],
    key: &str,
) -> Option<&'a super::chrome::json::Value> {
    obj.iter().find(|(k, _)| k == key).map(|(_, v)| v)
}

/// Self-contained schema checker for `--stats-out` JSONL files: every
/// non-empty line must parse as one snapshot object with the envelope
/// fields and per-kind metric fields from DESIGN §13.
pub fn validate_stats_jsonl(text: &str) -> Result<StatsCheck, String> {
    use super::chrome::json;
    let mut check = StatsCheck::default();
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        let n = ln + 1;
        let v = json::parse(line).map_err(|e| format!("line {n}: {e}"))?;
        let obj = v.as_object().ok_or_else(|| format!("line {n}: not an object"))?;
        for key in ["snapshot", "ts_us", "ranks"] {
            field(obj, key)
                .and_then(|v| v.as_i64())
                .ok_or_else(|| format!("line {n}: missing numeric \"{key}\""))?;
        }
        let metrics = field(obj, "metrics")
            .and_then(|v| v.as_array())
            .ok_or_else(|| format!("line {n}: missing \"metrics\" array"))?;
        for m in metrics {
            let mo = m.as_object().ok_or_else(|| format!("line {n}: metric not an object"))?;
            for key in ["sub", "name"] {
                field(mo, key)
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| format!("line {n}: metric missing \"{key}\""))?;
            }
            let kind = field(mo, "kind")
                .and_then(|v| v.as_str())
                .ok_or_else(|| format!("line {n}: metric missing \"kind\""))?;
            let required: &[&str] = match kind {
                "counter" | "gauge" => &["sum", "min", "max", "mean"],
                "hist" => &["count", "sum", "mean", "p50", "p95", "p99", "imbalance"],
                other => return Err(format!("line {n}: unknown kind \"{other}\"")),
            };
            for key in required {
                field(mo, key)
                    .and_then(|v| v.as_f64())
                    .ok_or_else(|| format!("line {n}: {kind} missing numeric \"{key}\""))?;
            }
            check.metrics += 1;
        }
        check.lines += 1;
    }
    if check.lines == 0 {
        return Err("no snapshot lines".to_string());
    }
    Ok(check)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Disarmed hooks are inert: nothing registers, nothing allocates in
    /// TLS, and a later arm starts from an empty registry.
    #[test]
    fn disabled_hooks_are_inert() {
        assert!(!enabled());
        add(Subsys::Comm, "msgs.exchange", 3);
        gauge(Subsys::Mem, "A", 4096);
        observe(Subsys::Session, "queue.wait_us", 17);
        rank_begin(2);
        let snap = rank_take();
        assert_eq!(snap.rank, 2);
        assert!(snap.entries.is_empty());
        assert!(!enabled());
    }

    #[test]
    fn counters_gauges_hists_snapshot() {
        rank_begin(0);
        add(Subsys::Comm, "msgs.exchange", 2);
        add(Subsys::Comm, "msgs.exchange", 3);
        gauge(Subsys::Mem, "A", 100);
        gauge(Subsys::Mem, "A", 60);
        for v in [1u64, 2, 4, 1000] {
            observe(Subsys::Session, "queue.wait_us", v);
        }
        let snap = rank_take();
        assert_eq!(snap.entries.len(), 3);
        let ctr = snap.entries.iter().find(|e| e.name == "msgs.exchange").unwrap();
        assert_eq!((ctr.kind, ctr.value), (Kind::Counter, 5));
        let g = snap.entries.iter().find(|e| e.name == "A").unwrap();
        assert_eq!((g.kind, g.value), (Kind::Gauge, 60));
        let h = snap.entries.iter().find(|e| e.name == "queue.wait_us").unwrap();
        assert_eq!((h.kind, h.count, h.sum), (Kind::Hist, 4, 1007));
        assert_eq!(h.buckets.iter().sum::<u64>(), 4);
        assert_eq!(h.win_buckets.iter().sum::<u64>(), 4);
    }

    /// The rolling window evicts the oldest bucket index in O(1); the
    /// lifetime buckets keep everything.
    #[test]
    fn window_evicts_oldest() {
        rank_begin(0);
        for _ in 0..WINDOW_CAP {
            observe(Subsys::Solve, "lat", 1); // bucket 0
        }
        for _ in 0..10 {
            observe(Subsys::Solve, "lat", 1 << 20); // bucket 20
        }
        let snap = rank_take();
        let h = &snap.entries[0];
        assert_eq!(h.count as usize, WINDOW_CAP + 10);
        assert_eq!(h.win_buckets.iter().sum::<u64>() as usize, WINDOW_CAP);
        assert_eq!(h.win_buckets[0] as usize, WINDOW_CAP - 10);
        assert_eq!(h.win_buckets[20], 10);
        assert_eq!(h.buckets[0] as usize, WINDOW_CAP);
        assert_eq!(h.buckets[20], 10);
    }

    /// Span drops feed the metrics histograms even when tracing is off,
    /// and arming metrics does not arm the tracer.
    #[test]
    fn spans_feed_metrics_without_tracing() {
        rank_begin(0);
        assert!(!crate::obs::enabled());
        {
            let _sp = crate::obs::span(Subsys::Mg, "level", 0);
        }
        let snap = rank_take();
        let h = snap.entries.iter().find(|e| e.name == "level").unwrap();
        assert_eq!(h.kind, Kind::Hist);
        assert_eq!(h.count, 1);
    }

    #[test]
    fn snapshot_serialization_round_trips() {
        rank_begin(5);
        add(Subsys::Comm, "bytes.exchange", 1234);
        observe(Subsys::Ptap, "numeric", 99);
        let snap = rank_take();
        let back = MetricsSnapshot::deserialize(&snap.serialize());
        assert_eq!(back.rank, 5);
        assert_eq!(back.entries.len(), snap.entries.len());
        for (a, b) in snap.entries.iter().zip(&back.entries) {
            assert_eq!((&a.sub, &a.name, a.kind), (&b.sub, &b.name, b.kind));
            assert_eq!((a.value, a.count, a.sum), (b.value, b.count, b.sum));
            assert_eq!(a.buckets, b.buckets);
            assert_eq!(a.win_buckets, b.win_buckets);
        }
    }

    /// Merge two ranks' snapshots and validate the JSONL line against the
    /// self-contained schema checker.
    #[test]
    fn merge_and_jsonl_schema() {
        rank_begin(0);
        add(Subsys::Comm, "msgs.exchange", 10);
        observe(Subsys::Mg, "level", 8);
        let s0 = rank_take();
        rank_begin(1);
        add(Subsys::Comm, "msgs.exchange", 30);
        observe(Subsys::Mg, "level", 32);
        observe(Subsys::Mg, "level", 32);
        let s1 = rank_take();

        let merged = merge_snapshots(&[s0, s1]);
        assert_eq!(merged.ranks, 2);
        let ctr = merged.entries.iter().find(|e| e.name == "msgs.exchange").unwrap();
        assert_eq!(ctr.per_rank, vec![10, 30]);
        assert_eq!((ctr.total(), ctr.min(), ctr.max()), (40, 10, 30));
        assert_eq!(ctr.median(), 10.0); // nearest-rank of [10, 30] at p50
        let h = merged.entries.iter().find(|e| e.name == "level").unwrap();
        assert_eq!((h.count, h.sum), (3, 72));
        assert_eq!(h.per_rank, vec![1, 2]);
        assert!(h.p(50.0) > 0.0);

        let line = merged.jsonl_line(0, 123);
        let check = validate_stats_jsonl(&line).expect("schema-valid line");
        assert_eq!(check.lines, 1);
        assert_eq!(check.metrics, 2);

        // A corrupted line must fail.
        assert!(validate_stats_jsonl(&line.replace("\"p95\"", "\"oops\"")).is_err());
        assert!(validate_stats_jsonl("").is_err());
    }
}
