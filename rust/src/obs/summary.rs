//! Per-phase summary: modeled vs calibrated vs measured wall time.
//!
//! The α-β model (`CommStats::modeled_secs`) and its calibrated variant
//! (`Time_cal`, DESIGN.md §7) predict communication cost from message
//! counts and sizes; the measured column is real wall time (max over
//! ranks).  Printing the three side by side per phase is ROADMAP item
//! 6's convergence check: where the columns diverge is where the model
//! is missing a term (e.g. the close-barrier idle time the comm stats
//! now attribute separately).

use crate::util::fmt_secs;
use crate::util::table::Table;

/// One phase's worth of evidence.
#[derive(Debug, Clone)]
pub struct PhaseRow {
    pub phase: &'static str,
    /// α-β modeled communication seconds plus measured busy compute.
    pub modeled: f64,
    /// Same, with the calibrated per-message α (`Time_cal`).
    pub calibrated: f64,
    /// Real wall seconds, max over ranks.
    pub measured: f64,
    pub msgs: u64,
    pub bytes: u64,
}

/// Render the modeled/calibrated/measured table for a set of phases.
pub fn phase_table(rows: &[PhaseRow]) -> Table {
    let mut t = Table::new(vec!["Phase", "Modeled", "Calibrated", "Measured", "Msgs", "Bytes"]);
    for r in rows {
        t.row(vec![
            r.phase.to_string(),
            fmt_secs(r.modeled),
            fmt_secs(r.calibrated),
            fmt_secs(r.measured),
            r.msgs.to_string(),
            r.bytes.to_string(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_has_one_row_per_phase() {
        let rows = vec![
            PhaseRow {
                phase: "build",
                modeled: 1.5e-3,
                calibrated: 1.2e-3,
                measured: 2.0e-3,
                msgs: 10,
                bytes: 1024,
            },
            PhaseRow {
                phase: "solve",
                modeled: 4.0e-3,
                calibrated: 3.5e-3,
                measured: 5.0e-3,
                msgs: 40,
                bytes: 8192,
            },
        ];
        let t = phase_table(&rows);
        assert_eq!(t.n_rows(), 2);
        let s = t.render();
        assert!(s.contains("build") && s.contains("Calibrated"));
    }
}
