//! Rank-resolved tracing: spans, instants, counters, and message
//! in-flight timelines, exported as Chrome trace-event JSON.
//!
//! The recorder is **per rank**: every simulated rank is one OS thread
//! (see `dist::World`), so a thread-local ring buffer gives each rank its
//! own event stream with no locking and no signature changes anywhere in
//! the solver stack.  A run that wants a trace calls [`rank_begin`] at the
//! top of its rank closure and [`rank_take`] at the end; the leader merges
//! the returned [`TraceBuffer`]s with [`chrome::write_chrome_trace`].
//!
//! Cost model: when observation is disabled (the default), every hook in
//! the hot paths is a single thread-local activity-bitmask read — no clock
//! reads, no allocation, no branches beyond the flag test.  The same
//! bitmask arms the live metrics registry ([`metrics`]), so tracing and
//! metrics together still cost one TLS load when off.  When enabled, events
//! are fixed-size (`&'static str` names, integer args) and land in a
//! pre-allocated ring; overflow drops the *oldest* events and counts them
//! in [`TraceBuffer::dropped`] rather than reallocating.
//!
//! Timestamps are microseconds since a process-wide origin (a
//! `OnceLock<Instant>` shared by every rank thread), so merged timelines
//! from different ranks line up and a sender's stamp can be compared
//! against the receiver's clock to measure true in-flight time.

pub mod chrome;
pub mod health;
pub mod metrics;
pub mod profile;
pub mod summary;

use std::cell::{Cell, RefCell};
use std::sync::OnceLock;
use std::time::Instant;

/// Subsystem lane: becomes the Chrome trace `tid` (one row per subsystem
/// under each rank's `pid`) and the event `cat`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Subsys {
    /// Comm engine: epochs, close barriers, message flights.
    Comm,
    /// PtAP triple products: symbolic / numeric / overlap windows.
    Ptap,
    /// Multigrid cycle: per-level smooth / restrict / redist / coarse.
    Mg,
    /// Hierarchy refresh passes (`reuse::HierarchyRefresher`).
    Refresh,
    /// Batched block kernels (`runtime::SpmvBatcher` and friends).
    Batch,
    /// Session layer: request enqueue → flush → dispatch → completion.
    Session,
    /// Memory tracker per-`Cat` byte counters.
    Mem,
    /// Outer Krylov solve phases.
    Solve,
}

impl Subsys {
    pub fn name(self) -> &'static str {
        match self {
            Subsys::Comm => "comm",
            Subsys::Ptap => "ptap",
            Subsys::Mg => "mg",
            Subsys::Refresh => "refresh",
            Subsys::Batch => "batch",
            Subsys::Session => "session",
            Subsys::Mem => "mem",
            Subsys::Solve => "solve",
        }
    }

    /// Stable Chrome `tid` for this lane.
    pub fn tid(self) -> u32 {
        match self {
            Subsys::Comm => 1,
            Subsys::Ptap => 2,
            Subsys::Mg => 3,
            Subsys::Refresh => 4,
            Subsys::Batch => 5,
            Subsys::Session => 6,
            Subsys::Mem => 7,
            Subsys::Solve => 8,
        }
    }
}

/// One recorded event.  Fixed-size: names are `&'static str`, args are
/// integers (a level index, a ticket, a byte count) — nothing here
/// allocates after the ring itself.
#[derive(Debug, Clone, Copy)]
pub enum Ev {
    /// Span open (Chrome `ph:"B"`).
    Begin { ts_us: u64, sub: Subsys, name: &'static str, arg: u64 },
    /// Span close (Chrome `ph:"E"`); carries the lane so B/E pair up.
    End { ts_us: u64, sub: Subsys, name: &'static str },
    /// Point event (Chrome `ph:"i"`).
    Instant { ts_us: u64, sub: Subsys, name: &'static str, arg: u64 },
    /// Counter sample (Chrome `ph:"C"`), e.g. per-`Cat` bytes.
    Counter { ts_us: u64, sub: Subsys, name: &'static str, val: u64 },
    /// A message in flight: stamped by the sender, recorded by the
    /// receiver (Chrome `ph:"X"` on the receiver's comm lane).
    Flight { send_us: u64, recv_us: u64, src: u32, tag: u32, bytes: u64 },
    /// A complete span recorded after the fact (Chrome `ph:"X"`), e.g. a
    /// request's enqueue→completion lifetime.
    Complete { start_us: u64, end_us: u64, sub: Subsys, name: &'static str, arg: u64 },
}

/// One rank's finished event stream.
#[derive(Debug, Clone, Default)]
pub struct TraceBuffer {
    pub rank: usize,
    pub events: Vec<Ev>,
    /// Oldest events overwritten because the ring filled.
    pub dropped: u64,
}

struct Recorder {
    rank: usize,
    ring: Vec<Ev>,
    cap: usize,
    /// Next slot to overwrite once the ring has wrapped.
    head: usize,
    wrapped: bool,
    dropped: u64,
}

impl Recorder {
    fn push(&mut self, ev: Ev) {
        if self.ring.len() < self.cap {
            self.ring.push(ev);
        } else {
            self.ring[self.head] = ev;
            self.head = (self.head + 1) % self.cap;
            self.wrapped = true;
            self.dropped += 1;
        }
    }

    fn into_buffer(self) -> TraceBuffer {
        let mut events = self.ring;
        if self.wrapped {
            // Restore chronological order: oldest surviving event first.
            events.rotate_left(self.head);
        }
        TraceBuffer { rank: self.rank, events, dropped: self.dropped }
    }
}

/// Tracing armed on this thread (events land in the ring recorder).
pub(crate) const TRACE_BIT: u8 = 1;
/// Live metrics armed on this thread (see [`metrics`]).
pub(crate) const METRICS_BIT: u8 = 2;

thread_local! {
    /// Activity bitmask: one TLS read serves both the trace recorder and
    /// the metrics registry, so the fully-disabled hot path stays a
    /// single thread-local load even with two observers.
    static ACTIVE: Cell<u8> = const { Cell::new(0) };
    static RECORDER: RefCell<Option<Recorder>> = const { RefCell::new(None) };
}

#[inline]
pub(crate) fn active_bits() -> u8 {
    ACTIVE.with(|a| a.get())
}

#[inline]
pub(crate) fn set_active_bit(mask: u8, on: bool) {
    ACTIVE.with(|a| {
        let v = a.get();
        a.set(if on { v | mask } else { v & !mask });
    });
}

/// Process-wide time origin, initialised by the first rank that starts
/// tracing — shared across rank threads so merged timelines align.
static ORIGIN: OnceLock<Instant> = OnceLock::new();

/// Default ring capacity (events per rank); override with
/// `GPTAP_TRACE_CAP` when a run is long enough to wrap.
const DEFAULT_CAP: usize = 1 << 18;

fn ring_cap() -> usize {
    std::env::var("GPTAP_TRACE_CAP")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&c| c > 0)
        .unwrap_or(DEFAULT_CAP)
}

/// Is tracing active on this rank thread?  One TLS read — this is the
/// entire disabled-path cost of every hook.
#[inline]
pub fn enabled() -> bool {
    active_bits() & TRACE_BIT != 0
}

/// Microseconds since the shared origin.  Returns at least 1 so a zero
/// wire stamp can keep meaning "sender was not tracing".
pub fn now_us() -> u64 {
    let origin = *ORIGIN.get_or_init(Instant::now);
    (origin.elapsed().as_micros() as u64).max(1)
}

/// Start recording on the calling rank thread.  Call at the top of the
/// rank closure; pair with [`rank_take`] before the closure returns.
pub fn rank_begin(rank: usize) {
    rank_begin_with_cap(rank, ring_cap());
}

/// [`rank_begin`] with an explicit ring capacity (tests sweep small
/// rings without racing on the process environment).
pub fn rank_begin_with_cap(rank: usize, cap: usize) {
    ORIGIN.get_or_init(Instant::now);
    let cap = cap.max(1);
    RECORDER.with(|r| {
        *r.borrow_mut() = Some(Recorder {
            rank,
            ring: Vec::with_capacity(cap.min(4096)),
            cap,
            head: 0,
            wrapped: false,
            dropped: 0,
        });
    });
    set_active_bit(TRACE_BIT, true);
}

/// Stop recording and hand back this rank's events.  Returns an empty
/// buffer if [`rank_begin`] was never called on this thread.
pub fn rank_take() -> TraceBuffer {
    set_active_bit(TRACE_BIT, false);
    RECORDER
        .with(|r| r.borrow_mut().take())
        .map(Recorder::into_buffer)
        .unwrap_or_default()
}

fn record(ev: Ev) {
    RECORDER.with(|r| {
        if let Some(rec) = r.borrow_mut().as_mut() {
            rec.push(ev);
        }
    });
}

/// RAII span guard: records `Begin` on creation and `End` on drop.  Bind
/// it (`let _sp = obs::span(...)`) so the span covers the scope.
///
/// Spans serve two observers from the activity bits captured at open:
/// the trace recorder gets Begin/End events, and the metrics registry
/// gets the elapsed microseconds folded into a `(sub, name)` histogram.
#[must_use = "bind the span guard or the span closes immediately"]
pub struct Span {
    bits: u8,
    t0: u64,
    sub: Subsys,
    name: &'static str,
}

impl Drop for Span {
    fn drop(&mut self) {
        if self.bits == 0 {
            return;
        }
        let t1 = now_us();
        if self.bits & TRACE_BIT != 0 {
            record(Ev::End { ts_us: t1, sub: self.sub, name: self.name });
        }
        if self.bits & METRICS_BIT != 0 {
            metrics::span_observed(self.sub, self.name, t1.saturating_sub(self.t0));
        }
    }
}

/// Open a span on `sub` named `name` with one integer argument (level,
/// ticket, byte count, ... — whatever identifies the instance).
#[inline]
pub fn span(sub: Subsys, name: &'static str, arg: u64) -> Span {
    let bits = active_bits();
    if bits == 0 {
        return Span { bits, t0: 0, sub, name };
    }
    let t0 = now_us();
    if bits & TRACE_BIT != 0 {
        record(Ev::Begin { ts_us: t0, sub, name, arg });
    }
    Span { bits, t0, sub, name }
}

/// Record a point event.
#[inline]
pub fn instant(sub: Subsys, name: &'static str, arg: u64) {
    if enabled() {
        record(Ev::Instant { ts_us: now_us(), sub, name, arg });
    }
}

/// Sample a counter (rendered as a stacked chart in Perfetto).
#[inline]
pub fn counter(sub: Subsys, name: &'static str, val: u64) {
    if enabled() {
        record(Ev::Counter { ts_us: now_us(), sub, name, val });
    }
}

/// Record a message flight observed by the *receiver*: `send_us` is the
/// sender's wire stamp, `recv_us` the receiver's delivery time.
#[inline]
pub fn flight(src: u32, tag: u32, bytes: u64, send_us: u64, recv_us: u64) {
    if enabled() {
        record(Ev::Flight { send_us, recv_us, src, tag, bytes });
    }
}

/// Record a complete span after the fact (start and end already known).
#[inline]
pub fn complete(sub: Subsys, name: &'static str, arg: u64, start_us: u64, end_us: u64) {
    if enabled() {
        record(Ev::Complete { start_us, end_us, sub, name, arg });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Spans recorded through the RAII guard balance and nest per rank:
    /// every `Begin` has a matching `End` on the same lane, LIFO order.
    #[test]
    fn spans_nest_and_balance() {
        rank_begin(0);
        {
            let _outer = span(Subsys::Mg, "cycle", 0);
            {
                let _inner = span(Subsys::Mg, "smooth.pre", 1);
                instant(Subsys::Comm, "halo", 42);
            }
            let _sibling = span(Subsys::Ptap, "numeric", 2);
        }
        let buf = rank_take();
        assert_eq!(buf.dropped, 0);
        let mut stack: Vec<(&str, u32)> = Vec::new();
        let mut begins = 0;
        let mut ends = 0;
        for ev in &buf.events {
            match *ev {
                Ev::Begin { sub, name, .. } => {
                    begins += 1;
                    stack.push((name, sub.tid()));
                }
                Ev::End { sub, name, .. } => {
                    ends += 1;
                    let (top, tid) = stack.pop().expect("End without Begin");
                    assert_eq!((top, tid), (name, sub.tid()), "spans must close LIFO");
                }
                _ => {}
            }
        }
        assert_eq!(begins, 3);
        assert_eq!(ends, 3);
        assert!(stack.is_empty(), "unbalanced spans: {stack:?}");
    }

    /// With no recorder armed, hooks record nothing and allocate nothing.
    #[test]
    fn disabled_recorder_records_nothing() {
        assert!(!enabled());
        {
            let _sp = span(Subsys::Session, "dispatch", 7);
            instant(Subsys::Session, "enqueue", 1);
            counter(Subsys::Mem, "A", 1024);
            flight(0, 5, 100, 10, 20);
            complete(Subsys::Session, "request", 1, 10, 20);
        }
        // Arming afterwards must start from an empty ring: nothing leaked
        // from the disabled period.
        rank_begin(3);
        let buf = rank_take();
        assert_eq!(buf.rank, 3);
        assert!(buf.events.is_empty());
        assert_eq!(buf.dropped, 0);
    }

    /// The ring drops the *oldest* events and reports the count.
    #[test]
    fn ring_overflow_drops_oldest() {
        rank_begin_with_cap(1, 4);
        for i in 0..6 {
            instant(Subsys::Solve, "tick", i);
        }
        let buf = rank_take();
        assert_eq!(buf.events.len(), 4);
        assert_eq!(buf.dropped, 2);
        let args: Vec<u64> = buf
            .events
            .iter()
            .map(|e| match e {
                Ev::Instant { arg, .. } => *arg,
                _ => panic!("unexpected event"),
            })
            .collect();
        assert_eq!(args, vec![2, 3, 4, 5], "oldest events drop first");
    }
}
