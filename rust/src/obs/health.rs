//! Health watchdogs over live solver signals: convergence verdicts from
//! residual histories, memory-budget breach checks, and a cross-rank
//! imbalance indicator.  Everything here is *observation-only* — verdicts
//! are computed from data the solve already produced and never feed back
//! into the numerics.  The serve loop uses them for graceful degradation:
//! a diverging ticket is reported and dropped, the server keeps running.

/// Convergence verdict for one residual history.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    Healthy,
    /// Residuals stopped improving over the stagnation window.
    Stagnating,
    /// Residuals blew up (non-finite, or grew past the divergence factor).
    Diverging,
    /// The request never produced a history: its dispatch panicked (bad
    /// layout, poisoned state) and was isolated to this ticket.
    Failed,
    /// The request was cancelled before dispatch (per-request deadline
    /// expired while it waited in the queue).
    Cancelled,
}

impl Verdict {
    pub fn name(self) -> &'static str {
        match self {
            Verdict::Healthy => "healthy",
            Verdict::Stagnating => "stagnating",
            Verdict::Diverging => "diverging",
            Verdict::Failed => "failed",
            Verdict::Cancelled => "cancelled",
        }
    }
}

/// Transport health over a window of reliability counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommVerdict {
    /// No faults detected, nothing recovered.
    Clean,
    /// Faults were detected and fully recovered (retransmits, checksum
    /// rejects, duplicate suppression) — results are still bitwise, but
    /// the network is misbehaving.
    Degraded,
    /// At least one blocking wait hit its deadline: something was lost
    /// beyond recovery, and a `CommError` surfaced.
    Lossy,
}

impl CommVerdict {
    pub fn name(self) -> &'static str {
        match self {
            CommVerdict::Clean => "clean",
            CommVerdict::Degraded => "degraded",
            CommVerdict::Lossy => "lossy",
        }
    }
}

/// Classify the transport from its reliability counters
/// ([`crate::dist::ReliabilityStats`]): any deadline hit is `Lossy`, any
/// recovered fault is `Degraded`, otherwise `Clean`.
pub fn comm_verdict(
    retransmits: u64,
    corrupt_frames: u64,
    dup_suppressed: u64,
    timeouts: u64,
) -> CommVerdict {
    if timeouts > 0 {
        CommVerdict::Lossy
    } else if retransmits + corrupt_frames + dup_suppressed > 0 {
        CommVerdict::Degraded
    } else {
        CommVerdict::Clean
    }
}

/// Thresholds for [`residual_verdict`].  Defaults match DESIGN §13.
#[derive(Debug, Clone, Copy)]
pub struct HealthPolicy {
    /// Diverging when `r_n > divergence_factor * r_0` (or any non-finite).
    pub divergence_factor: f64,
    /// Look-back window (iterations) for stagnation.
    pub stagnation_window: usize,
    /// Stagnating when `r_n > stagnation_decay * r_{n-window}` — i.e. less
    /// than `1 - stagnation_decay` relative progress across the window.
    pub stagnation_decay: f64,
}

impl Default for HealthPolicy {
    fn default() -> Self {
        HealthPolicy { divergence_factor: 1e4, stagnation_window: 10, stagnation_decay: 0.99 }
    }
}

/// Classify a residual history (`residuals[0]` is the initial residual;
/// the solver appends one entry per iteration).  A converged history is
/// always healthy; histories too short for the stagnation window are
/// given the benefit of the doubt.
pub fn residual_verdict(residuals: &[f64], converged: bool, policy: &HealthPolicy) -> Verdict {
    if residuals.iter().any(|r| !r.is_finite()) {
        return Verdict::Diverging;
    }
    if converged || residuals.len() < 2 {
        return Verdict::Healthy;
    }
    let r0 = residuals[0];
    let rn = residuals[residuals.len() - 1];
    if r0 > 0.0 && rn > policy.divergence_factor * r0 {
        return Verdict::Diverging;
    }
    if residuals.len() > policy.stagnation_window {
        let back = residuals[residuals.len() - 1 - policy.stagnation_window];
        if back > 0.0 && rn > policy.stagnation_decay * back {
            return Verdict::Stagnating;
        }
    }
    Verdict::Healthy
}

/// Memory-budget breach: `Some(current_bytes)` when current tracked usage
/// exceeds `budget_bytes`.  The caller decides what to log or shed.
pub fn memory_breach(current_bytes: u64, budget_bytes: u64) -> Option<u64> {
    (budget_bytes > 0 && current_bytes > budget_bytes).then_some(current_bytes)
}

/// Cross-rank imbalance: `max / mean` of a per-rank load vector.  1.0 is
/// perfectly balanced; 0.0 when the vector is empty or all-zero.
pub fn imbalance(per_rank: &[f64]) -> f64 {
    if per_rank.is_empty() {
        return 0.0;
    }
    let max = per_rank.iter().cloned().fold(0.0f64, f64::max);
    let mean = per_rank.iter().sum::<f64>() / per_rank.len() as f64;
    if mean <= 0.0 {
        0.0
    } else {
        max / mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn converged_histories_are_healthy() {
        let pol = HealthPolicy::default();
        assert_eq!(residual_verdict(&[1.0, 0.1, 1e-9], true, &pol), Verdict::Healthy);
        assert_eq!(residual_verdict(&[1.0], false, &pol), Verdict::Healthy);
        assert_eq!(residual_verdict(&[], false, &pol), Verdict::Healthy);
    }

    #[test]
    fn non_finite_or_growth_diverges() {
        let pol = HealthPolicy::default();
        assert_eq!(residual_verdict(&[1.0, f64::NAN], false, &pol), Verdict::Diverging);
        assert_eq!(residual_verdict(&[1.0, f64::INFINITY], true, &pol), Verdict::Diverging);
        assert_eq!(residual_verdict(&[1.0, 2.0, 2e4], false, &pol), Verdict::Diverging);
    }

    #[test]
    fn flat_tail_stagnates() {
        let pol = HealthPolicy::default();
        // 2 decades of progress then flat for > window iterations.
        let mut hist = vec![1.0, 0.1, 0.01];
        hist.extend(vec![0.0099; 12]);
        assert_eq!(residual_verdict(&hist, false, &pol), Verdict::Stagnating);
        // Still making >1% progress per window: healthy.
        let improving: Vec<f64> = (0..20).map(|i| 0.8f64.powi(i)).collect();
        assert_eq!(residual_verdict(&improving, false, &pol), Verdict::Healthy);
    }

    #[test]
    fn memory_breach_threshold() {
        assert_eq!(memory_breach(100, 0), None); // no budget set
        assert_eq!(memory_breach(100, 200), None);
        assert_eq!(memory_breach(300, 200), Some(300));
    }

    #[test]
    fn imbalance_max_over_mean() {
        assert_eq!(imbalance(&[]), 0.0);
        assert_eq!(imbalance(&[0.0, 0.0]), 0.0);
        assert_eq!(imbalance(&[2.0, 2.0]), 1.0);
        assert_eq!(imbalance(&[3.0, 1.0]), 1.5);
    }

    #[test]
    fn comm_verdict_orders_loss_over_degradation() {
        assert_eq!(comm_verdict(0, 0, 0, 0), CommVerdict::Clean);
        assert_eq!(comm_verdict(3, 0, 0, 0), CommVerdict::Degraded);
        assert_eq!(comm_verdict(0, 1, 2, 0), CommVerdict::Degraded);
        assert_eq!(comm_verdict(5, 5, 5, 1), CommVerdict::Lossy, "timeouts dominate");
        assert_eq!(comm_verdict(0, 0, 0, 2), CommVerdict::Lossy);
    }
}
