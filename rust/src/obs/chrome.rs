//! Chrome trace-event JSON exporter and schema validator.
//!
//! Export maps the merged per-rank [`TraceBuffer`]s onto the Chrome
//! trace-event format (loadable in chrome://tracing and Perfetto):
//!
//! * `pid`  = rank (with a `process_name` metadata record per rank),
//! * `tid`  = subsystem lane ([`Subsys::tid`], named via `thread_name`
//!   metadata) — comm, ptap, mg, refresh, batch, session, mem, solve,
//! * `ph`   = `B`/`E` for spans, `i` for instants, `C` for counters,
//!   `X` for message flights and after-the-fact complete spans,
//! * `ts`   = microseconds since the shared process origin.
//!
//! The validator re-parses the emitted JSON with a small self-contained
//! parser (the bench-report scanner in `coordinator::report` cannot split
//! fields containing nested `args` objects) and checks the structural
//! schema CI relies on: a `traceEvents` array, required keys per phase
//! type, and B/E balance per `(pid, tid)` lane.

use super::{Ev, Subsys, TraceBuffer};
use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

const ALL_SUBSYS: [Subsys; 8] = [
    Subsys::Comm,
    Subsys::Ptap,
    Subsys::Mg,
    Subsys::Refresh,
    Subsys::Batch,
    Subsys::Session,
    Subsys::Mem,
    Subsys::Solve,
];

/// Render the merged buffers as a Chrome trace-event JSON string.
pub fn render_chrome_trace(bufs: &[TraceBuffer]) -> String {
    let mut out = String::from("{\"traceEvents\": [\n");
    let mut first = true;
    let push = |out: &mut String, first: &mut bool, line: String| {
        if !*first {
            out.push_str(",\n");
        }
        *first = false;
        out.push_str("  ");
        out.push_str(&line);
    };
    for buf in bufs {
        let pid = buf.rank;
        push(
            &mut out,
            &mut first,
            format!(
                "{{\"ph\": \"M\", \"pid\": {pid}, \"tid\": 0, \"name\": \"process_name\", \
                 \"args\": {{\"name\": \"rank {pid}\"}}}}"
            ),
        );
        for sub in ALL_SUBSYS {
            push(
                &mut out,
                &mut first,
                format!(
                    "{{\"ph\": \"M\", \"pid\": {pid}, \"tid\": {}, \"name\": \"thread_name\", \
                     \"args\": {{\"name\": \"{}\"}}}}",
                    sub.tid(),
                    sub.name()
                ),
            );
        }
        for ev in &buf.events {
            let line = match *ev {
                Ev::Begin { ts_us, sub, name, arg } => format!(
                    "{{\"ph\": \"B\", \"pid\": {pid}, \"tid\": {}, \"ts\": {ts_us}, \
                     \"name\": \"{name}\", \"cat\": \"{}\", \"args\": {{\"arg\": {arg}}}}}",
                    sub.tid(),
                    sub.name()
                ),
                Ev::End { ts_us, sub, name } => format!(
                    "{{\"ph\": \"E\", \"pid\": {pid}, \"tid\": {}, \"ts\": {ts_us}, \
                     \"name\": \"{name}\", \"cat\": \"{}\"}}",
                    sub.tid(),
                    sub.name()
                ),
                Ev::Instant { ts_us, sub, name, arg } => format!(
                    "{{\"ph\": \"i\", \"s\": \"t\", \"pid\": {pid}, \"tid\": {}, \
                     \"ts\": {ts_us}, \"name\": \"{name}\", \"cat\": \"{}\", \
                     \"args\": {{\"arg\": {arg}}}}}",
                    sub.tid(),
                    sub.name()
                ),
                Ev::Counter { ts_us, sub, name, val } => format!(
                    "{{\"ph\": \"C\", \"pid\": {pid}, \"tid\": {}, \"ts\": {ts_us}, \
                     \"name\": \"mem.{name}\", \"cat\": \"{}\", \"args\": {{\"bytes\": {val}}}}}",
                    sub.tid(),
                    sub.name()
                ),
                Ev::Flight { send_us, recv_us, src, tag, bytes } => format!(
                    "{{\"ph\": \"X\", \"pid\": {pid}, \"tid\": {}, \"ts\": {send_us}, \
                     \"dur\": {}, \"name\": \"msg\", \"cat\": \"comm\", \
                     \"args\": {{\"src\": {src}, \"tag\": {tag}, \"bytes\": {bytes}}}}}",
                    Subsys::Comm.tid(),
                    recv_us.saturating_sub(send_us)
                ),
                Ev::Complete { start_us, end_us, sub, name, arg } => format!(
                    "{{\"ph\": \"X\", \"pid\": {pid}, \"tid\": {}, \"ts\": {start_us}, \
                     \"dur\": {}, \"name\": \"{name}\", \"cat\": \"{}\", \
                     \"args\": {{\"arg\": {arg}}}}}",
                    sub.tid(),
                    end_us.saturating_sub(start_us),
                    sub.name()
                ),
            };
            push(&mut out, &mut first, line);
        }
        if buf.dropped > 0 {
            push(
                &mut out,
                &mut first,
                format!(
                    "{{\"ph\": \"i\", \"s\": \"p\", \"pid\": {pid}, \"tid\": 0, \"ts\": 0, \
                     \"name\": \"ring_dropped\", \"cat\": \"meta\", \
                     \"args\": {{\"arg\": {}}}}}",
                    buf.dropped
                ),
            );
        }
    }
    out.push_str("\n]}\n");
    out
}

/// Merge the per-rank buffers and write the Chrome trace JSON to `path`.
pub fn write_chrome_trace(bufs: &[TraceBuffer], path: &Path) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(render_chrome_trace(bufs).as_bytes())
}

/// What [`validate_chrome_trace`] found in a structurally valid trace.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TraceSummary {
    pub ranks: usize,
    pub spans: usize,
    pub instants: usize,
    pub counters: usize,
    pub flights: usize,
    pub completes: usize,
}

impl TraceSummary {
    pub fn render(&self) -> String {
        let mut s = String::new();
        let _ = write!(
            s,
            "{} rank(s): {} span pair(s), {} flight(s), {} counter sample(s), \
             {} instant(s), {} complete span(s)",
            self.ranks, self.spans, self.flights, self.counters, self.instants, self.completes
        );
        s
    }
}

/// Validate a Chrome trace-event JSON document: it must parse, carry a
/// `traceEvents` array of objects, every event must have the keys its
/// phase requires, and every `B` must close with an `E` on the same
/// `(pid, tid)` lane in LIFO order.
pub fn validate_chrome_trace(text: &str) -> Result<TraceSummary, String> {
    let doc = json::parse(text)?;
    let root = doc.as_object().ok_or("top level must be an object")?;
    let events = root
        .iter()
        .find(|(k, _)| k == "traceEvents")
        .map(|(_, v)| v)
        .ok_or("missing \"traceEvents\"")?
        .as_array()
        .ok_or("\"traceEvents\" must be an array")?;
    let mut sum = TraceSummary::default();
    let mut ranks = std::collections::BTreeSet::new();
    // (pid, tid) → stack of open span names
    let mut stacks: std::collections::HashMap<(i64, i64), Vec<String>> =
        std::collections::HashMap::new();
    for (i, ev) in events.iter().enumerate() {
        let obj = ev.as_object().ok_or_else(|| format!("event {i}: not an object"))?;
        let field = |k: &str| obj.iter().find(|(n, _)| n == k).map(|(_, v)| v);
        let ph = field("ph")
            .and_then(|v| v.as_str())
            .ok_or_else(|| format!("event {i}: missing \"ph\""))?
            .to_string();
        let pid = field("pid")
            .and_then(|v| v.as_i64())
            .ok_or_else(|| format!("event {i}: missing integer \"pid\""))?;
        if ph != "M" {
            ranks.insert(pid);
            field("ts")
                .and_then(|v| v.as_i64())
                .ok_or_else(|| format!("event {i}: missing integer \"ts\""))?;
        }
        let tid = field("tid")
            .and_then(|v| v.as_i64())
            .ok_or_else(|| format!("event {i}: missing integer \"tid\""))?;
        let name = field("name").and_then(|v| v.as_str()).map(str::to_string);
        match ph.as_str() {
            "B" => {
                let n = name.ok_or_else(|| format!("event {i}: B without \"name\""))?;
                stacks.entry((pid, tid)).or_default().push(n);
            }
            "E" => {
                let open = stacks.entry((pid, tid)).or_default().pop().ok_or_else(|| {
                    format!("event {i}: E on pid {pid} tid {tid} without open span")
                })?;
                if let Some(n) = name {
                    if n != open {
                        return Err(format!(
                            "event {i}: E \"{n}\" closes span \"{open}\" (pid {pid} tid {tid})"
                        ));
                    }
                }
                sum.spans += 1;
            }
            "X" => {
                field("dur")
                    .and_then(|v| v.as_i64())
                    .ok_or_else(|| format!("event {i}: X without integer \"dur\""))?;
                if name.as_deref() == Some("msg") {
                    sum.flights += 1;
                } else {
                    sum.completes += 1;
                }
            }
            "i" => sum.instants += 1,
            "C" => {
                name.ok_or_else(|| format!("event {i}: C without \"name\""))?;
                sum.counters += 1;
            }
            "M" => {}
            other => return Err(format!("event {i}: unknown phase {other:?}")),
        }
    }
    for ((pid, tid), stack) in &stacks {
        if !stack.is_empty() {
            return Err(format!(
                "unbalanced spans on pid {pid} tid {tid}: {:?} never closed",
                stack
            ));
        }
    }
    sum.ranks = ranks.len();
    Ok(sum)
}

/// Minimal recursive-descent JSON parser — just enough structure for the
/// trace validator, with proper handling of nested objects/arrays and
/// string escapes (which the flat bench-cell scanner cannot do).  Shared
/// crate-wide: the metrics JSONL checker and the `profile` subcommand
/// parse with it too.
pub(crate) mod json {
    #[derive(Debug, Clone, PartialEq)]
    pub enum Value {
        Null,
        Bool(bool),
        Num(f64),
        Str(String),
        Arr(Vec<Value>),
        Obj(Vec<(String, Value)>),
    }

    impl Value {
        pub fn as_object(&self) -> Option<&[(String, Value)]> {
            match self {
                Value::Obj(f) => Some(f),
                _ => None,
            }
        }
        pub fn as_array(&self) -> Option<&[Value]> {
            match self {
                Value::Arr(v) => Some(v),
                _ => None,
            }
        }
        pub fn as_str(&self) -> Option<&str> {
            match self {
                Value::Str(s) => Some(s),
                _ => None,
            }
        }
        pub fn as_i64(&self) -> Option<i64> {
            match self {
                Value::Num(n) => Some(*n as i64),
                _ => None,
            }
        }
        pub fn as_f64(&self) -> Option<f64> {
            match self {
                Value::Num(n) => Some(*n),
                _ => None,
            }
        }
    }

    pub fn parse(text: &str) -> Result<Value, String> {
        let b = text.as_bytes();
        let mut pos = 0;
        let v = value(b, &mut pos)?;
        skip_ws(b, &mut pos);
        if pos != b.len() {
            return Err(format!("trailing bytes at offset {pos}"));
        }
        Ok(v)
    }

    fn skip_ws(b: &[u8], pos: &mut usize) {
        while *pos < b.len() && b[*pos].is_ascii_whitespace() {
            *pos += 1;
        }
    }

    fn expect(b: &[u8], pos: &mut usize, ch: u8) -> Result<(), String> {
        skip_ws(b, pos);
        if *pos < b.len() && b[*pos] == ch {
            *pos += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at offset {}", ch as char, pos))
        }
    }

    fn value(b: &[u8], pos: &mut usize) -> Result<Value, String> {
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b'{') => object(b, pos),
            Some(b'[') => array(b, pos),
            Some(b'"') => Ok(Value::Str(string(b, pos)?)),
            Some(b't') => literal(b, pos, "true", Value::Bool(true)),
            Some(b'f') => literal(b, pos, "false", Value::Bool(false)),
            Some(b'n') => literal(b, pos, "null", Value::Null),
            Some(_) => number(b, pos),
            None => Err("unexpected end of input".into()),
        }
    }

    fn literal(b: &[u8], pos: &mut usize, lit: &str, v: Value) -> Result<Value, String> {
        if b[*pos..].starts_with(lit.as_bytes()) {
            *pos += lit.len();
            Ok(v)
        } else {
            Err(format!("bad literal at offset {pos}"))
        }
    }

    fn number(b: &[u8], pos: &mut usize) -> Result<Value, String> {
        let start = *pos;
        while *pos < b.len()
            && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            *pos += 1;
        }
        std::str::from_utf8(&b[start..*pos])
            .ok()
            .and_then(|s| s.parse().ok())
            .map(Value::Num)
            .ok_or_else(|| format!("bad number at offset {start}"))
    }

    fn string(b: &[u8], pos: &mut usize) -> Result<String, String> {
        expect(b, pos, b'"')?;
        let mut s = String::new();
        while *pos < b.len() {
            match b[*pos] {
                b'"' => {
                    *pos += 1;
                    return Ok(s);
                }
                b'\\' => {
                    *pos += 1;
                    let esc = *b.get(*pos).ok_or("unterminated escape")?;
                    s.push(match esc {
                        b'"' => '"',
                        b'\\' => '\\',
                        b'/' => '/',
                        b'n' => '\n',
                        b't' => '\t',
                        b'r' => '\r',
                        b'b' => '\u{8}',
                        b'f' => '\u{c}',
                        b'u' => {
                            let hex = b
                                .get(*pos + 1..*pos + 5)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .and_then(|h| u32::from_str_radix(h, 16).ok())
                                .ok_or("bad \\u escape")?;
                            *pos += 4;
                            char::from_u32(hex).unwrap_or('\u{fffd}')
                        }
                        other => return Err(format!("bad escape \\{}", other as char)),
                    });
                    *pos += 1;
                }
                c => {
                    // Multi-byte UTF-8 sequences pass through unmodified.
                    let len = match c {
                        0x00..=0x7f => 1,
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    let chunk = b.get(*pos..*pos + len).ok_or("truncated utf-8")?;
                    s.push_str(std::str::from_utf8(chunk).map_err(|e| e.to_string())?);
                    *pos += len;
                }
            }
        }
        Err("unterminated string".into())
    }

    fn array(b: &[u8], pos: &mut usize) -> Result<Value, String> {
        expect(b, pos, b'[')?;
        let mut items = Vec::new();
        skip_ws(b, pos);
        if b.get(*pos) == Some(&b']') {
            *pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(value(b, pos)?);
            skip_ws(b, pos);
            match b.get(*pos) {
                Some(b',') => *pos += 1,
                Some(b']') => {
                    *pos += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at offset {pos}")),
            }
        }
    }

    fn object(b: &[u8], pos: &mut usize) -> Result<Value, String> {
        expect(b, pos, b'{')?;
        let mut fields = Vec::new();
        skip_ws(b, pos);
        if b.get(*pos) == Some(&b'}') {
            *pos += 1;
            return Ok(Value::Obj(fields));
        }
        loop {
            skip_ws(b, pos);
            let key = string(b, pos)?;
            expect(b, pos, b':')?;
            fields.push((key, value(b, pos)?));
            skip_ws(b, pos);
            match b.get(*pos) {
                Some(b',') => *pos += 1,
                Some(b'}') => {
                    *pos += 1;
                    return Ok(Value::Obj(fields));
                }
                _ => return Err(format!("expected ',' or '}}' at offset {pos}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_buffer() -> TraceBuffer {
        TraceBuffer {
            rank: 0,
            events: vec![
                Ev::Begin { ts_us: 10, sub: Subsys::Mg, name: "cycle", arg: 0 },
                Ev::Counter { ts_us: 11, sub: Subsys::Mem, name: "A", val: 4096 },
                Ev::Flight { send_us: 12, recv_us: 19, src: 1, tag: 7, bytes: 80 },
                Ev::Instant { ts_us: 14, sub: Subsys::Session, name: "enqueue", arg: 3 },
                Ev::Complete {
                    start_us: 5,
                    end_us: 25,
                    sub: Subsys::Session,
                    name: "request",
                    arg: 3,
                },
                Ev::End { ts_us: 20, sub: Subsys::Mg, name: "cycle" },
            ],
            dropped: 0,
        }
    }

    #[test]
    fn rendered_trace_validates() {
        let text = render_chrome_trace(&[sample_buffer()]);
        let sum = validate_chrome_trace(&text).expect("valid trace");
        assert_eq!(
            sum,
            TraceSummary {
                ranks: 1,
                spans: 1,
                instants: 1,
                counters: 1,
                flights: 1,
                completes: 1
            }
        );
    }

    #[test]
    fn validator_rejects_unbalanced_spans() {
        let mut buf = sample_buffer();
        buf.events.pop(); // drop the End
        let text = render_chrome_trace(&[buf]);
        let err = validate_chrome_trace(&text).unwrap_err();
        assert!(err.contains("unbalanced"), "got: {err}");
    }

    #[test]
    fn validator_rejects_mismatched_close() {
        let mut buf = sample_buffer();
        buf.events[5] = Ev::End { ts_us: 20, sub: Subsys::Mg, name: "other" };
        let text = render_chrome_trace(&[buf]);
        let err = validate_chrome_trace(&text).unwrap_err();
        assert!(err.contains("closes span"), "got: {err}");
    }

    #[test]
    fn validator_rejects_non_json() {
        assert!(validate_chrome_trace("not json").is_err());
        assert!(validate_chrome_trace("{\"other\": []}").is_err());
    }

    #[test]
    fn json_parser_handles_nesting_and_escapes() {
        let text = "{\"a\": [1, {\"b\": \"x\\\"y\"}, [2, 3]], \"c\": -4.5e2}";
        let v = super::json::parse(text).expect("parse");
        let obj = v.as_object().unwrap();
        assert_eq!(obj.len(), 2);
        let arr = obj[0].1.as_array().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[1].as_object().unwrap()[0].1.as_str(), Some("x\"y"));
        assert_eq!(obj[1].1.as_i64(), Some(-450));
    }
}
