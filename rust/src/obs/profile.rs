//! Span-folded profiles: collapse Begin/End span events into a
//! hierarchical call tree (per-frame counts, total and self microseconds),
//! exportable as a flamegraph-compatible folded-stacks text and a top-k
//! table — so `solve --profile` and the `profile` subcommand answer
//! "where did the time go" without opening a Chrome trace.
//!
//! Two sources fold into the same tree: in-memory [`TraceBuffer`]s right
//! after a traced run, or a Chrome trace JSON written earlier (parsed with
//! the same minimal JSON parser the validator uses).  Stacks are tracked
//! per `(rank, lane)` — exactly the granularity at which spans are LIFO —
//! and every rank's tree hangs under a synthetic `r<rank>` root frame so
//! per-rank asymmetry stays visible in the flamegraph.

use std::collections::HashMap;

use super::chrome::json;
use super::{Ev, TraceBuffer};
use crate::util::table::Table;

/// One frame in the folded call tree.
#[derive(Debug, Clone)]
pub struct ProfileNode {
    /// Frame label: `lane.span` (e.g. `mg.smooth.pre`) or `r<rank>`.
    pub name: String,
    /// Completed spans folded into this frame.
    pub count: u64,
    /// Total microseconds (including children).
    pub total_us: u64,
    /// Microseconds attributed to direct children.
    pub child_us: u64,
    pub children: Vec<ProfileNode>,
}

impl ProfileNode {
    pub fn self_us(&self) -> u64 {
        self.total_us.saturating_sub(self.child_us)
    }
}

/// A folded profile: one synthetic root per rank.
#[derive(Debug, Clone, Default)]
pub struct Profile {
    pub roots: Vec<ProfileNode>,
    /// Begin events whose End never arrived (ring overflow, truncation).
    pub unmatched: u64,
}

/// Arena node used during folding (children indexed by label).
struct ArenaNode {
    name: String,
    count: u64,
    total_us: u64,
    child_us: u64,
    children: HashMap<String, usize>,
    order: Vec<usize>,
}

struct Folder {
    arena: Vec<ArenaNode>,
    /// Root arena index per rank (sorted at the end).
    roots: HashMap<u64, usize>,
    /// Open-span stack per (rank, lane): (arena index, begin ts).
    stacks: HashMap<(u64, u64), Vec<(usize, u64)>>,
    unmatched: u64,
}

impl Folder {
    fn new() -> Folder {
        Folder { arena: Vec::new(), roots: HashMap::new(), stacks: HashMap::new(), unmatched: 0 }
    }

    fn node(&mut self, name: &str) -> usize {
        self.arena.push(ArenaNode {
            name: name.to_string(),
            count: 0,
            total_us: 0,
            child_us: 0,
            children: HashMap::new(),
            order: Vec::new(),
        });
        self.arena.len() - 1
    }

    fn root_of(&mut self, rank: u64) -> usize {
        if let Some(&idx) = self.roots.get(&rank) {
            return idx;
        }
        let idx = self.node(&format!("r{rank}"));
        self.roots.insert(rank, idx);
        idx
    }

    fn child_of(&mut self, parent: usize, name: &str) -> usize {
        if let Some(&idx) = self.arena[parent].children.get(name) {
            return idx;
        }
        let idx = self.node(name);
        self.arena[parent].children.insert(name.to_string(), idx);
        self.arena[parent].order.push(idx);
        idx
    }

    fn begin(&mut self, rank: u64, lane: u64, label: &str, ts: u64) {
        let parent = match self.stacks.get(&(rank, lane)).and_then(|s| s.last()) {
            Some(&(idx, _)) => idx,
            None => self.root_of(rank),
        };
        let idx = self.child_of(parent, label);
        self.stacks.entry((rank, lane)).or_default().push((idx, ts));
    }

    fn end(&mut self, rank: u64, lane: u64, ts: u64) {
        let Some((idx, t0)) = self.stacks.get_mut(&(rank, lane)).and_then(|s| s.pop()) else {
            self.unmatched += 1;
            return;
        };
        let dur = ts.saturating_sub(t0);
        self.arena[idx].count += 1;
        self.arena[idx].total_us += dur;
        let parent = match self.stacks.get(&(rank, lane)).and_then(|s| s.last()) {
            Some(&(p, _)) => p,
            None => self.root_of(rank),
        };
        self.arena[parent].child_us += dur;
        // The rank root's total is the union of its children's time.
        if self.roots.get(&rank) == Some(&parent) {
            self.arena[parent].total_us += dur;
        }
    }

    fn finish(mut self) -> Profile {
        // Spans still open (End lost to ring overflow) count as unmatched.
        for (_, stack) in self.stacks.iter() {
            self.unmatched += stack.len() as u64;
        }
        let mut ranks: Vec<u64> = self.roots.keys().copied().collect();
        ranks.sort_unstable();
        let roots = ranks.iter().map(|r| build(&self.arena, self.roots[r])).collect();
        Profile { roots, unmatched: self.unmatched }
    }
}

fn build(arena: &[ArenaNode], idx: usize) -> ProfileNode {
    let n = &arena[idx];
    ProfileNode {
        name: n.name.clone(),
        count: n.count,
        total_us: n.total_us,
        child_us: n.child_us,
        children: n.order.iter().map(|&c| build(arena, c)).collect(),
    }
}

/// Fold in-memory per-rank trace buffers (the `solve --profile` path).
pub fn fold_buffers(bufs: &[TraceBuffer]) -> Profile {
    let mut f = Folder::new();
    for buf in bufs {
        let rank = buf.rank as u64;
        for ev in &buf.events {
            match *ev {
                Ev::Begin { ts_us, sub, name, .. } => {
                    let label = format!("{}.{name}", sub.name());
                    f.begin(rank, sub.tid() as u64, &label, ts_us);
                }
                Ev::End { ts_us, sub, .. } => f.end(rank, sub.tid() as u64, ts_us),
                _ => {}
            }
        }
    }
    f.finish()
}

/// Fold a Chrome trace JSON written by `--trace` (the `profile`
/// subcommand path).  Only `B`/`E` phases participate; `X`/`i`/`C`/`M`
/// events pass through untouched.
pub fn fold_chrome_text(text: &str) -> Result<Profile, String> {
    let v = json::parse(text)?;
    let events = v
        .as_object()
        .and_then(|o| o.iter().find(|(k, _)| k == "traceEvents"))
        .map(|(_, v)| v)
        .and_then(|v| v.as_array())
        .ok_or("missing \"traceEvents\" array")?;
    let mut f = Folder::new();
    for ev in events {
        let obj = ev.as_object().ok_or("event is not an object")?;
        let field = |key: &str| obj.iter().find(|(k, _)| k == key).map(|(_, v)| v);
        let ph = field("ph").and_then(|v| v.as_str()).ok_or("event missing \"ph\"")?;
        if ph != "B" && ph != "E" {
            continue;
        }
        let pid = field("pid").and_then(|v| v.as_i64()).ok_or("span missing \"pid\"")? as u64;
        let tid = field("tid").and_then(|v| v.as_i64()).ok_or("span missing \"tid\"")? as u64;
        let ts = field("ts").and_then(|v| v.as_i64()).ok_or("span missing \"ts\"")? as u64;
        if ph == "B" {
            let name = field("name").and_then(|v| v.as_str()).ok_or("span missing \"name\"")?;
            let cat = field("cat").and_then(|v| v.as_str()).unwrap_or("?");
            f.begin(pid, tid, &format!("{cat}.{name}"), ts);
        } else {
            f.end(pid, tid, ts);
        }
    }
    Ok(f.finish())
}

fn fold_lines(out: &mut String, node: &ProfileNode, prefix: &str) {
    let path = if prefix.is_empty() {
        node.name.clone()
    } else {
        format!("{prefix};{}", node.name)
    };
    if node.self_us() > 0 {
        out.push_str(&format!("{path} {}\n", node.self_us()));
    }
    for c in &node.children {
        fold_lines(out, c, &path);
    }
}

/// Flamegraph-compatible folded stacks: one `frame;frame;... self_us`
/// line per tree node with nonzero self time (feed to `flamegraph.pl` or
/// speedscope).
pub fn folded_stacks(p: &Profile) -> String {
    let mut out = String::new();
    for root in &p.roots {
        fold_lines(&mut out, root, "");
    }
    out
}

fn collect<'a>(node: &'a ProfileNode, depth: usize, rows: &mut Vec<(&'a ProfileNode, usize)>) {
    rows.push((node, depth));
    for c in &node.children {
        collect(c, depth + 1, rows);
    }
}

/// Top-k frames by self time across all ranks, as a rendered table.
pub fn top_table(p: &Profile, k: usize) -> Table {
    let mut rows: Vec<(&ProfileNode, usize)> = Vec::new();
    for root in &p.roots {
        for c in &root.children {
            collect(c, 0, &mut rows);
        }
    }
    rows.sort_by(|a, b| b.0.self_us().cmp(&a.0.self_us()));
    let mut t = Table::new(vec!["frame", "count", "self_ms", "total_ms"]);
    for (node, _) in rows.iter().take(k) {
        t.row(vec![
            node.name.clone(),
            format!("{}", node.count),
            format!("{:.3}", node.self_us() as f64 / 1e3),
            format!("{:.3}", node.total_us as f64 / 1e3),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{self, Subsys};

    fn sample_buffer() -> TraceBuffer {
        obs::rank_begin(0);
        {
            let _cycle = obs::span(Subsys::Mg, "cycle", 0);
            {
                let _sm = obs::span(Subsys::Mg, "smooth.pre", 0);
            }
            {
                let _sm = obs::span(Subsys::Mg, "smooth.pre", 0);
            }
            let _pt = obs::span(Subsys::Ptap, "numeric", 0);
        }
        obs::rank_take()
    }

    #[test]
    fn fold_builds_nested_tree_with_self_time() {
        let buf = sample_buffer();
        let p = fold_buffers(&[buf]);
        assert_eq!(p.unmatched, 0);
        assert_eq!(p.roots.len(), 1);
        let root = &p.roots[0];
        assert_eq!(root.name, "r0");
        // Two lanes: mg.cycle (with nested smooth.pre ×2) and ptap.numeric.
        let cycle = root.children.iter().find(|c| c.name == "mg.cycle").unwrap();
        assert_eq!(cycle.count, 1);
        let sm = cycle.children.iter().find(|c| c.name == "mg.smooth.pre").unwrap();
        assert_eq!(sm.count, 2);
        assert!(cycle.total_us >= cycle.child_us);
        assert!(root.children.iter().any(|c| c.name == "ptap.numeric"));
        // Root total is the union of its direct children.
        assert_eq!(root.total_us, root.child_us);
    }

    #[test]
    fn chrome_round_trip_matches_buffer_fold() {
        let buf = sample_buffer();
        let direct = fold_buffers(&[buf.clone()]);
        let text = crate::obs::chrome::render_chrome_trace(&[buf]);
        let via_json = fold_chrome_text(&text).expect("parse rendered trace");
        fn names(n: &ProfileNode) -> Vec<String> {
            let mut v = vec![format!("{}:{}", n.name, n.count)];
            for c in &n.children {
                v.extend(names(c));
            }
            v
        }
        assert_eq!(names(&direct.roots[0]), names(&via_json.roots[0]));
    }

    #[test]
    fn folded_stacks_and_top_table_render() {
        let p = fold_buffers(&[sample_buffer()]);
        let stacks = folded_stacks(&p);
        for line in stacks.lines() {
            let (path, n) = line.rsplit_once(' ').expect("folded line has a trailing count");
            assert!(n.parse::<u64>().is_ok(), "bad sample count in {line:?}");
            assert!(path.starts_with("r0"), "stack must start at the rank frame");
        }
        let table = top_table(&p, 10).render();
        assert!(table.contains("mg.smooth.pre"));
        assert!(table.contains("ptap.numeric"));
    }
}
