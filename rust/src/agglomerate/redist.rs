//! Layout-to-layout redistribution: move a contiguous row partition over
//! `np` ranks onto the equal split over the first `k` ranks (and back).
//!
//! Because both sides are contiguous partitions of the same global index
//! space, the schedule is pure interval intersection — each rank sends at
//! most a few contiguous global ranges, receivers reassemble them in
//! ascending source order, which *is* ascending global-row order.  The
//! wire format per matrix row is `[n u32, cols u64×n, vals f64×n]` with
//! globally-sorted columns ([`DistCsr::row_global`] order); the value
//! refresh resends `vals f64×n` alone over the identical schedule.

use std::ops::Range;

use crate::dist::{tag, Comm, DistCsr, DistCsrBuilder, DistMultiVec, DistVec, Layout};
use crate::util::bytebuf::{ByteReader, ByteWriter};

/// Active rank count for `n` global rows under an `eq_limit` rows-per-rank
/// knob (PETSc `-pc_gamg_process_eq_limit` analog): enough ranks that each
/// active rank owns roughly `eq_limit` rows, never more than `np`, never
/// fewer than one.
pub fn choose_active_ranks(n: usize, np: usize, eq_limit: usize) -> usize {
    assert!(eq_limit > 0, "eq_limit must be positive");
    if n == 0 {
        return 1;
    }
    n.div_ceil(eq_limit).clamp(1, np)
}

/// Precomputed redistribution schedule between an `old` layout over the
/// parent communicator's `np` ranks and the equal `new` layout over the
/// contiguous prefix of `k` active ranks.  Built once per telescoped
/// level (the one-shot symbolic plan); every scatter/gather/refresh
/// replays the same schedule.
#[derive(Debug, Clone)]
pub struct RedistPlan {
    old: Layout,
    new: Layout,
    k: usize,
    /// This rank's outgoing runs: (active destination, global range),
    /// ascending by destination (and hence by range).
    sends: Vec<(usize, Range<usize>)>,
    /// This rank's incoming runs under `new` (active ranks only):
    /// (parent source, global range), ascending by source.
    recvs: Vec<(usize, Range<usize>)>,
}

/// Intersection of two half-open ranges (possibly empty).
fn isect(a: &Range<usize>, b: &Range<usize>) -> Range<usize> {
    a.start.max(b.start)..a.end.min(b.end)
}

impl RedistPlan {
    /// Plan the redistribution of `old` onto `k` active ranks for the
    /// calling `rank` (pure layout arithmetic — no communication).
    pub fn new(old: &Layout, k: usize, rank: usize) -> RedistPlan {
        assert!((1..=old.np()).contains(&k), "active count {k} out of 1..={}", old.np());
        let new = Layout::new_equal(old.global_size(), k);
        let mine = old.range(rank);
        let mut sends = Vec::new();
        for d in 0..k {
            let r = isect(&mine, &new.range(d));
            if !r.is_empty() {
                sends.push((d, r));
            }
        }
        let mut recvs = Vec::new();
        if rank < k {
            let mine_new = new.range(rank);
            for s in 0..old.np() {
                let r = isect(&mine_new, &old.range(s));
                if !r.is_empty() {
                    recvs.push((s, r));
                }
            }
        }
        RedistPlan { old: old.clone(), new, k, sends, recvs }
    }

    /// Number of active ranks.
    pub fn active(&self) -> usize {
        self.k
    }

    /// The layout on the parent communicator.
    pub fn old_layout(&self) -> &Layout {
        &self.old
    }

    /// The layout on the active prefix (a `k`-rank layout).
    pub fn new_layout(&self) -> &Layout {
        &self.new
    }

    /// Heap bytes of the plan (schedules + layouts), for memory
    /// accounting.
    pub fn bytes(&self) -> u64 {
        self.old.bytes()
            + self.new.bytes()
            + ((self.sends.len() + self.recvs.len()) * 24) as u64
    }

    /// Scatter a distributed matrix onto the active ranks (collective
    /// over the *parent* communicator; `m.row_layout` must equal the
    /// plan's old layout).  Active ranks return the telescoped matrix
    /// under the new row layout and the given column layout; idle ranks
    /// return `None`.
    pub fn scatter_csr(&self, comm: &Comm, m: &DistCsr, col_layout: Layout) -> Option<DistCsr> {
        debug_assert_eq!(m.row_layout, self.old, "matrix layout does not match the plan");
        let rank = comm.rank();
        let my_start = self.old.start(rank);
        let mut cbuf: Vec<u64> = Vec::new();
        let mut vbuf: Vec<f64> = Vec::new();
        let mut sends = Vec::with_capacity(self.sends.len());
        for (dest, range) in &self.sends {
            let mut w = ByteWriter::new();
            for g in range.clone() {
                m.row_global(g - my_start, &mut cbuf, &mut vbuf);
                w.u32(cbuf.len() as u32);
                w.u64_slice(&cbuf);
                w.f64_slice(&vbuf);
            }
            sends.push((*dest, w.into_bytes()));
        }
        let recvd = comm.exchange_on(tag::REDIST, sends);
        if rank >= self.k {
            debug_assert!(recvd.is_empty(), "idle rank received redistributed rows");
            return None;
        }
        debug_assert_eq!(recvd.len(), self.recvs.len(), "recv runs out of step");
        let mut b = DistCsrBuilder::new(rank, self.new.clone(), col_layout);
        let mut entries: Vec<(u64, f64)> = Vec::new();
        for ((src, range), (psrc, payload)) in self.recvs.iter().zip(&recvd) {
            debug_assert_eq!(src, psrc, "recv run misalignment");
            let mut r = ByteReader::new(payload);
            for _ in range.clone() {
                let n = r.u32() as usize;
                entries.clear();
                for _ in 0..n {
                    entries.push((r.u64(), 0.0));
                }
                for e in entries.iter_mut() {
                    e.1 = r.f64();
                }
                b.push_row(&entries);
            }
            debug_assert!(r.done(), "trailing redistribution bytes from rank {src}");
        }
        Some(b.finish())
    }

    /// Refresh the values of an already-telescoped matrix from the
    /// current values of `m` without resending structure (collective over
    /// the parent communicator) — the numeric-refresh half of the
    /// one-shot plan.  `out` must be the matrix a prior
    /// [`RedistPlan::scatter_csr`] built (`Some` exactly on active ranks).
    pub fn refresh_csr(&self, comm: &Comm, m: &DistCsr, out: Option<&mut DistCsr>) {
        debug_assert_eq!(m.row_layout, self.old, "matrix layout does not match the plan");
        let rank = comm.rank();
        let my_start = self.old.start(rank);
        let mut cbuf: Vec<u64> = Vec::new();
        let mut vbuf: Vec<f64> = Vec::new();
        let mut sends = Vec::with_capacity(self.sends.len());
        for (dest, range) in &self.sends {
            let mut w = ByteWriter::new();
            for g in range.clone() {
                m.row_global(g - my_start, &mut cbuf, &mut vbuf);
                w.f64_slice(&vbuf);
            }
            sends.push((*dest, w.into_bytes()));
        }
        let recvd = comm.exchange_on(tag::REDIST, sends);
        let Some(out) = out else {
            debug_assert!(rank >= self.k && recvd.is_empty(), "active rank must pass its matrix");
            return;
        };
        debug_assert_eq!(out.row_layout, self.new, "out is not this plan's telescoped matrix");
        let new_start = self.new.start(rank);
        let mut vals: Vec<f64> = Vec::new();
        for ((src, range), (psrc, payload)) in self.recvs.iter().zip(&recvd) {
            debug_assert_eq!(src, psrc, "recv run misalignment");
            let mut r = ByteReader::new(payload);
            for g in range.clone() {
                let li = g - new_start;
                let n = out.diag.row_len(li) + out.offd.row_len(li);
                vals.clear();
                for _ in 0..n {
                    vals.push(r.f64());
                }
                out.set_row_global_vals(li, &vals);
            }
            debug_assert!(r.done(), "pattern drift in redistribution refresh");
        }
    }

    /// Scatter a vector in the old layout onto the active ranks
    /// (collective over the parent communicator).  Active ranks return
    /// their slice under the new layout; idle ranks return `None`.
    pub fn scatter_vec(&self, comm: &Comm, v: &DistVec) -> Option<DistVec> {
        let mut out =
            (comm.rank() < self.k).then(|| DistVec::zeros(self.new.clone(), comm.rank()));
        self.scatter_vec_into(comm, v, out.as_mut());
        out
    }

    /// [`RedistPlan::scatter_vec`] into a caller-owned buffer — the
    /// cycle's per-application boundary crossing without re-allocation.
    /// Active ranks pass `Some` of a new-layout vector; idle ranks `None`.
    pub fn scatter_vec_into(&self, comm: &Comm, v: &DistVec, out: Option<&mut DistVec>) {
        debug_assert_eq!(v.layout, self.old, "vector layout does not match the plan");
        let rank = comm.rank();
        let my_start = self.old.start(rank);
        let mut sends = Vec::with_capacity(self.sends.len());
        for (dest, range) in &self.sends {
            let mut w = ByteWriter::with_capacity(8 * range.len());
            w.f64_slice(&v.vals[range.start - my_start..range.end - my_start]);
            sends.push((*dest, w.into_bytes()));
        }
        let recvd = comm.exchange_on(tag::REDIST, sends);
        let Some(out) = out else {
            debug_assert!(rank >= self.k && recvd.is_empty(), "active rank must pass a buffer");
            return;
        };
        debug_assert_eq!(out.layout, self.new, "out buffer layout does not match the plan");
        let new_start = self.new.start(rank);
        for ((src, range), (psrc, payload)) in self.recvs.iter().zip(&recvd) {
            debug_assert_eq!(src, psrc, "recv run misalignment");
            let mut r = ByteReader::new(payload);
            for slot in &mut out.vals[range.start - new_start..range.end - new_start] {
                *slot = r.f64();
            }
            debug_assert!(r.done());
        }
    }

    /// K-wide [`RedistPlan::scatter_vec_into`]: scatter a row-major
    /// multivector across the telescope boundary in one epoch on the same
    /// interval schedule — each global range ships `len×k` values, so K
    /// blocked right-hand sides pay the boundary's α once.  Column `j` of
    /// the result is bitwise the scalar scatter of column `j`.
    pub fn scatter_multi_into(
        &self,
        comm: &Comm,
        v: &DistMultiVec,
        out: Option<&mut DistMultiVec>,
    ) {
        debug_assert_eq!(v.layout, self.old, "multivector layout does not match the plan");
        let rank = comm.rank();
        let k = v.k;
        let my_start = self.old.start(rank);
        let mut sends = Vec::with_capacity(self.sends.len());
        for (dest, range) in &self.sends {
            let mut w = ByteWriter::with_capacity(8 * range.len() * k);
            w.f64_slice(&v.vals[(range.start - my_start) * k..(range.end - my_start) * k]);
            sends.push((*dest, w.into_bytes()));
        }
        let recvd = comm.exchange_on(tag::REDIST, sends);
        let Some(out) = out else {
            debug_assert!(rank >= self.k && recvd.is_empty(), "active rank must pass a buffer");
            return;
        };
        debug_assert_eq!(out.layout, self.new, "out buffer layout does not match the plan");
        debug_assert_eq!(out.k, k, "column width changed across the boundary");
        let new_start = self.new.start(rank);
        for ((src, range), (psrc, payload)) in self.recvs.iter().zip(&recvd) {
            debug_assert_eq!(src, psrc, "recv run misalignment");
            let mut r = ByteReader::new(payload);
            for slot in &mut out.vals[(range.start - new_start) * k..(range.end - new_start) * k]
            {
                *slot = r.f64();
            }
            debug_assert!(r.done());
        }
    }

    /// K-wide [`RedistPlan::gather_vec_into`]: the reverse boundary
    /// crossing for a multivector, one epoch for all K columns.
    pub fn gather_multi_into(
        &self,
        comm: &Comm,
        v: Option<&DistMultiVec>,
        out: &mut DistMultiVec,
    ) {
        let rank = comm.rank();
        let k = out.k;
        let mut sends = Vec::with_capacity(self.recvs.len());
        if let Some(v) = v {
            debug_assert_eq!(v.layout, self.new, "multivector layout does not match the plan");
            debug_assert_eq!(v.k, k, "column width changed across the boundary");
            let new_start = self.new.start(rank);
            for (dest, range) in &self.recvs {
                let mut w = ByteWriter::with_capacity(8 * range.len() * k);
                w.f64_slice(&v.vals[(range.start - new_start) * k..(range.end - new_start) * k]);
                sends.push((*dest, w.into_bytes()));
            }
        } else {
            debug_assert!(rank >= self.k, "active rank must pass its slice");
        }
        let recvd = comm.exchange_on(tag::REDIST, sends);
        debug_assert_eq!(out.layout, self.old, "out buffer layout does not match the plan");
        let my_start = self.old.start(rank);
        out.fill(0.0);
        debug_assert_eq!(recvd.len(), self.sends.len(), "gather runs out of step");
        for ((src, range), (psrc, payload)) in self.sends.iter().zip(&recvd) {
            debug_assert_eq!(src, psrc, "gather run misalignment");
            let mut r = ByteReader::new(payload);
            for slot in &mut out.vals[(range.start - my_start) * k..(range.end - my_start) * k] {
                *slot = r.f64();
            }
            debug_assert!(r.done());
        }
    }

    /// Gather a vector from the active ranks back into the old layout
    /// (collective over the parent communicator — the reverse schedule of
    /// [`RedistPlan::scatter_vec`]).  Active ranks pass their slice;
    /// idle ranks pass `None`; every rank returns its old-layout slice.
    pub fn gather_vec(&self, comm: &Comm, v: Option<&DistVec>) -> DistVec {
        let mut out = DistVec::zeros(self.old.clone(), comm.rank());
        self.gather_vec_into(comm, v, &mut out);
        out
    }

    /// [`RedistPlan::gather_vec`] into a caller-owned old-layout buffer.
    pub fn gather_vec_into(&self, comm: &Comm, v: Option<&DistVec>, out: &mut DistVec) {
        let rank = comm.rank();
        let mut sends = Vec::with_capacity(self.recvs.len());
        if let Some(v) = v {
            debug_assert_eq!(v.layout, self.new, "vector layout does not match the plan");
            let new_start = self.new.start(rank);
            for (dest, range) in &self.recvs {
                let mut w = ByteWriter::with_capacity(8 * range.len());
                w.f64_slice(&v.vals[range.start - new_start..range.end - new_start]);
                sends.push((*dest, w.into_bytes()));
            }
        } else {
            debug_assert!(rank >= self.k, "active rank must pass its slice");
        }
        let recvd = comm.exchange_on(tag::REDIST, sends);
        debug_assert_eq!(out.layout, self.old, "out buffer layout does not match the plan");
        let my_start = self.old.start(rank);
        out.fill(0.0);
        debug_assert_eq!(recvd.len(), self.sends.len(), "gather runs out of step");
        for ((src, range), (psrc, payload)) in self.sends.iter().zip(&recvd) {
            debug_assert_eq!(src, psrc, "gather run misalignment");
            let mut r = ByteReader::new(payload);
            for slot in &mut out.vals[range.start - my_start..range.end - my_start] {
                *slot = r.f64();
            }
            debug_assert!(r.done());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::World;

    /// Deterministic dyadic-valued matrix over an arbitrary layout: sums
    /// and products stay exact in f64, so redistribution equality checks
    /// can be bitwise.
    fn dyadic_matrix(rank: usize, rl: &Layout, cl: &Layout) -> DistCsr {
        let n = cl.global_size() as u64;
        let mut b = DistCsrBuilder::new(rank, rl.clone(), cl.clone());
        for g in rl.range(rank) {
            let g = g as u64;
            let mut cols = vec![g % n, (g * 7 + 3) % n];
            cols.sort_unstable();
            cols.dedup();
            let entries: Vec<(u64, f64)> = cols
                .iter()
                .map(|&c| (c, ((g * 5 + c) % 16) as f64 / 4.0 - 2.0))
                .collect();
            b.push_row(&entries);
        }
        b.finish()
    }

    #[test]
    fn choose_active_ranks_respects_eq_limit() {
        assert_eq!(choose_active_ranks(1000, 8, 500), 2);
        assert_eq!(choose_active_ranks(27, 8, 64), 1);
        assert_eq!(choose_active_ranks(1_000_000, 8, 100), 8); // clamped
        assert_eq!(choose_active_ranks(0, 8, 100), 1);
        assert_eq!(choose_active_ranks(129, 8, 64), 3); // ceil
    }

    #[test]
    fn vec_scatter_gather_round_trips_with_zero_row_ranks() {
        // irregular old layout with zero-row ranks (aggregation coarse
        // layouts produce these)
        let old = Layout::from_counts(&[6, 0, 4, 2]);
        let w = World::new(4);
        w.run(|c| {
            let plan = RedistPlan::new(&old, 2, c.rank());
            let v = DistVec::from_fn(old.clone(), c.rank(), |g| g as f64 * 0.25);
            let sub = plan.scatter_vec(&c, &v);
            assert_eq!(sub.is_some(), c.rank() < 2);
            if let Some(sv) = &sub {
                assert_eq!(sv.local_len(), plan.new_layout().local_size(c.rank()));
                for (i, &x) in sv.vals.iter().enumerate() {
                    let g = plan.new_layout().start(c.rank()) + i;
                    assert_eq!(x, g as f64 * 0.25);
                }
            }
            let back = plan.gather_vec(&c, sub.as_ref());
            assert_eq!(back.vals, v.vals, "rank {} round trip", c.rank());
        });
    }

    #[test]
    fn csr_scatter_preserves_global_matrix_bitwise() {
        let old = Layout::from_counts(&[0, 5, 3, 4]);
        let cl = Layout::from_counts(&[4, 2, 0, 3]);
        let w = World::new(4);
        w.run(|c| {
            let m = dyadic_matrix(c.rank(), &old, &cl);
            let before = m.gather_global(&c);
            for k in [1, 2, 3] {
                let plan = RedistPlan::new(&old, k, c.rank());
                let cl_new = Layout::new_equal(cl.global_size(), k);
                let mt = plan.scatter_csr(&c, &m, cl_new);
                assert_eq!(mt.is_some(), c.rank() < k);
                // assemble the telescoped matrix on the active prefix and
                // compare bitwise — gather_global is partition-invariant
                if let Some(mt) = &mt {
                    mt.validate().unwrap();
                }
                let sub = c.split(usize::from(c.rank() >= k));
                if let Some(mt) = &mt {
                    let after = mt.gather_global(&sub);
                    assert_eq!(after, before, "k={k} bits moved");
                }
            }
        });
    }

    #[test]
    fn csr_refresh_updates_values_only() {
        let old = Layout::from_counts(&[3, 0, 5]);
        let cl = Layout::new_equal(6, 3);
        let w = World::new(3);
        w.run(|c| {
            let m = dyadic_matrix(c.rank(), &old, &cl);
            let plan = RedistPlan::new(&old, 2, c.rank());
            let cl_new = Layout::new_equal(cl.global_size(), 2);
            let mut mt = plan.scatter_csr(&c, &m, cl_new);
            // scale the source values, refresh, compare to a re-scatter
            let mut m2 = m.clone();
            for v in m2.diag.vals.iter_mut().chain(m2.offd.vals.iter_mut()) {
                *v *= 2.0;
            }
            plan.refresh_csr(&c, &m2, mt.as_mut());
            let fresh = plan.scatter_csr(&c, &m2, Layout::new_equal(cl.global_size(), 2));
            match (&mt, &fresh) {
                (Some(a), Some(b)) => assert_eq!(a, b, "refresh drifted from re-scatter"),
                (None, None) => {}
                _ => panic!("active/idle mismatch"),
            }
        });
    }

    #[test]
    fn single_rank_world_noop_telescope() {
        let old = Layout::new_equal(7, 1);
        let w = World::new(1);
        w.run(|c| {
            let plan = RedistPlan::new(&old, 1, c.rank());
            let v = DistVec::from_fn(old.clone(), 0, |g| g as f64);
            let sub = plan.scatter_vec(&c, &v).unwrap();
            assert_eq!(sub.vals, v.vals);
            let back = plan.gather_vec(&c, Some(&sub));
            assert_eq!(back.vals, v.vals);
            let m = dyadic_matrix(0, &old, &old);
            let mt = plan.scatter_csr(&c, &m, old.clone()).unwrap();
            assert_eq!(mt.gather_global(&c), m.gather_global(&c));
        });
    }

    #[test]
    fn gather_to_root_collects_everything() {
        let old = Layout::new_equal(10, 4);
        let w = World::new(4);
        w.run(|c| {
            let plan = RedistPlan::new(&old, 1, c.rank());
            let v = DistVec::from_fn(old.clone(), c.rank(), |g| (g * g) as f64);
            let sub = plan.scatter_vec(&c, &v);
            if c.rank() == 0 {
                let sv = sub.as_ref().unwrap();
                assert_eq!(sv.local_len(), 10);
                for (g, &x) in sv.vals.iter().enumerate() {
                    assert_eq!(x, (g * g) as f64);
                }
            } else {
                assert!(sub.is_none());
            }
            let back = plan.gather_vec(&c, sub.as_ref());
            assert_eq!(back.vals, v.vals);
        });
    }
}
