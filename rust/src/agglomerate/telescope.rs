//! One telescoped level: the sub-communicator, the coarse-space
//! redistribution plan the V-cycle crosses every iteration, and the
//! one-shot redistribution of a level's operators onto the active ranks.

use crate::dist::{Comm, DistCsr};

use super::redist::RedistPlan;

/// The scope boundary below a telescoped level, retained by the
/// hierarchy: restriction scatters coarse vectors *into* the subcomm
/// through `coarse`, the coarse correction runs on `subcomm`, and
/// prolongation gathers back out.
#[derive(Clone)]
pub struct Telescope {
    /// The active ranks' communicator (`None` on idle ranks, which skip
    /// everything between the boundary's scatter and gather).
    pub subcomm: Option<Comm>,
    /// Fine-space plan: parent row layout ↔ subcomm row layout — the
    /// schedule the operators moved through, retained so a numeric
    /// refresh ([`RedistPlan::refresh_csr`]) can resend values alone.
    pub fine: RedistPlan,
    /// Coarse-space plan: parent coarse layout ↔ subcomm coarse layout.
    pub coarse: RedistPlan,
    /// Number of active ranks.
    pub active: usize,
}

impl Telescope {
    /// Heap bytes of the retained plans (for memory accounting).
    pub fn bytes(&self) -> u64 {
        self.fine.bytes() + self.coarse.bytes()
    }
}

/// Telescope one level onto `k` active ranks (collective over `parent`):
/// split the communicator, redistribute the level operator `a`
/// (rows *and* columns onto the new fine layout) and the interpolation
/// `p` (rows onto the new fine layout, columns onto the new coarse
/// layout).  Active ranks get `Some((a, p))` telescoped plus the
/// subcommunicator inside the returned [`Telescope`]; idle ranks get
/// `None` for both and will never enter a sub-scope epoch.
pub fn telescope_operators(
    parent: &Comm,
    a: &DistCsr,
    p: &DistCsr,
    k: usize,
) -> (Telescope, Option<(DistCsr, DistCsr)>) {
    debug_assert!(k < parent.size(), "telescoping onto all ranks is a no-op");
    let rank = parent.rank();
    let fine = RedistPlan::new(&a.row_layout, k, rank);
    let coarse = RedistPlan::new(&p.col_layout, k, rank);
    let active = rank < k;
    // active ranks are color 0 so the sub-rank order matches the prefix
    let sub = parent.split(usize::from(!active));
    let a_t = fine.scatter_csr(parent, a, fine.new_layout().clone());
    let p_t = fine.scatter_csr(parent, p, coarse.new_layout().clone());
    let tel = Telescope { subcomm: active.then_some(sub), fine, coarse, active: k };
    let ops = match (a_t, p_t) {
        (Some(a_t), Some(p_t)) => Some((a_t, p_t)),
        (None, None) => None,
        _ => unreachable!("fine-plan activity must agree for A and P"),
    };
    (tel, ops)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{Layout, World};
    use crate::gen::{grid_laplacian, trilinear_interp, Grid3};

    #[test]
    fn telescoped_operators_match_originals_globally() {
        let coarse_grid = Grid3::cube(3);
        let w = World::new(4);
        w.run(|c| {
            let a = grid_laplacian(coarse_grid.refine(), c.rank(), c.size());
            let p = trilinear_interp(coarse_grid, c.rank(), c.size());
            let a_full = a.gather_global(&c);
            let p_full = p.gather_global(&c);
            let (tel, ops) = telescope_operators(&c, &a, &p, 2);
            assert_eq!(tel.active, 2);
            assert_eq!(ops.is_some(), c.rank() < 2);
            assert_eq!(tel.subcomm.is_some(), c.rank() < 2);
            if let (Some(sc), Some((a_t, p_t))) = (&tel.subcomm, &ops) {
                a_t.validate().unwrap();
                p_t.validate().unwrap();
                assert_eq!(sc.size(), 2);
                assert_eq!(a_t.gather_global(sc), a_full);
                assert_eq!(p_t.gather_global(sc), p_full);
                // P's coarse columns moved to the subcomm coarse layout
                assert_eq!(p_t.col_layout, Layout::new_equal(p.global_ncols(), 2));
            }
        });
    }
}
