//! Coarse-level rank agglomeration (the PETSc PCTelescope /
//! `-pc_gamg_process_eq_limit` analog).
//!
//! On the coarsest AMG levels most ranks own a handful of rows, yet every
//! communication epoch still pays an all-ranks close barrier and the full
//! α term of the model.  This subsystem telescopes such levels onto a
//! contiguous prefix of *active* ranks:
//!
//! - [`choose_active_ranks`] picks the active count `k` from an
//!   `eq_limit` rows-per-rank knob (a level telescopes when its global
//!   rows fall under `eq_limit × np`);
//! - [`RedistPlan`] maps a [`crate::dist::Layout`] over `np` ranks onto
//!   the equal split over the first `k` ranks and moves
//!   [`crate::dist::DistCsr`] / [`crate::dist::DistVec`] data both
//!   directions — one-shot symbolic scatters plus value-only numeric
//!   refreshes over the same schedule;
//! - [`telescope_operators`] splits the communicator
//!   ([`crate::dist::Comm::split`]) and redistributes a level's `A` and
//!   `P` onto the sub-communicator,
//!   so the triple product (and everything coarser) runs entirely inside
//!   it while idle ranks never enter an epoch's close barrier.
//!
//! Determinism: both layouts are contiguous partitions of the same
//! global index space, so redistribution is pure interval arithmetic —
//! rows move in ascending global order and land in ascending global
//! order (the engine releases sources rank-major), making the telescoped
//! operators bitwise-equal re-partitions of the originals.

mod redist;
mod telescope;

pub use redist::{choose_active_ranks, RedistPlan};
pub use telescope::{telescope_operators, Telescope};
