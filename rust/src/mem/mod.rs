//! Per-rank memory accounting — the source of every "Mem" column in the
//! reproduced tables.
//!
//! The paper reports "estimated memory usage per processor core" for the
//! triple products, separated from the storage of A, P and C (its Tables
//! 1–4, 7–8).  We account the same way: every substrate structure charges
//! its buffer bytes to a category when built and releases them when
//! dropped; the tracker keeps current and peak per category and overall.

use crate::obs;
use std::cell::RefCell;
use std::rc::Rc;

/// What a byte belongs to.  Categories mirror the paper's breakdown.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Cat {
    /// Fine operator A storage.
    MatA,
    /// Interpolation P storage.
    MatP,
    /// Output coarse operator C storage.
    MatC,
    /// Auxiliary matrices (the two-step method's C̃ = AP and explicit Pᵀ).
    Aux,
    /// Hash tables (row accumulators, C_s^H / C_l^H).
    Hash,
    /// Communication staging buffers (sends, receives, gathered P̃_r).
    Comm,
    /// K-wide multivector state: `DistMultiVec` RHS/solution blocks and
    /// the blocked cycle's K-wide scratch twins.
    MultiVec,
    /// Everything else (vectors, solver state, hierarchy bookkeeping).
    Other,
}

pub const ALL_CATS: [Cat; 8] = [
    Cat::MatA,
    Cat::MatP,
    Cat::MatC,
    Cat::Aux,
    Cat::Hash,
    Cat::Comm,
    Cat::MultiVec,
    Cat::Other,
];

impl Cat {
    pub fn name(self) -> &'static str {
        match self {
            Cat::MatA => "A",
            Cat::MatP => "P",
            Cat::MatC => "C",
            Cat::Aux => "aux",
            Cat::Hash => "hash",
            Cat::Comm => "comm",
            Cat::MultiVec => "multivec",
            Cat::Other => "other",
        }
    }

    fn idx(self) -> usize {
        match self {
            Cat::MatA => 0,
            Cat::MatP => 1,
            Cat::MatC => 2,
            Cat::Aux => 3,
            Cat::Hash => 4,
            Cat::Comm => 5,
            Cat::MultiVec => 6,
            Cat::Other => 7,
        }
    }

    /// Live-metrics gauge name for this category's peak bytes (the
    /// current bytes reuse [`Cat::name`]).
    fn peak_metric(self) -> &'static str {
        match self {
            Cat::MatA => "A.peak",
            Cat::MatP => "P.peak",
            Cat::MatC => "C.peak",
            Cat::Aux => "aux.peak",
            Cat::Hash => "hash.peak",
            Cat::Comm => "comm.peak",
            Cat::MultiVec => "multivec.peak",
            Cat::Other => "other.peak",
        }
    }
}

#[derive(Default, Debug, Clone)]
struct Inner {
    cur: [u64; 8],
    peak: [u64; 8],
    cur_total: u64,
    peak_total: u64,
}

/// Cheap clonable handle to a rank's memory tracker (single-threaded per
/// rank, hence `Rc<RefCell>`).
#[derive(Default, Debug, Clone)]
pub struct MemTracker {
    inner: Rc<RefCell<Inner>>,
}

impl MemTracker {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn alloc(&self, cat: Cat, bytes: u64) {
        let mut m = self.inner.borrow_mut();
        let i = cat.idx();
        m.cur[i] += bytes;
        m.cur_total += bytes;
        if m.cur[i] > m.peak[i] {
            m.peak[i] = m.cur[i];
        }
        if m.cur_total > m.peak_total {
            m.peak_total = m.cur_total;
        }
        // Trace the memory timeline: one counter sample per change turns
        // the per-Cat peaks into a visible bytes-over-time waterfall.
        // A single flag test when tracing is off; same for the live
        // gauges (current + peak per category).
        obs::counter(obs::Subsys::Mem, cat.name(), m.cur[i]);
        obs::metrics::gauge(obs::Subsys::Mem, cat.name(), m.cur[i]);
        obs::metrics::gauge(obs::Subsys::Mem, cat.peak_metric(), m.peak[i]);
    }

    pub fn free(&self, cat: Cat, bytes: u64) {
        let mut m = self.inner.borrow_mut();
        let i = cat.idx();
        debug_assert!(m.cur[i] >= bytes, "free underflow in {:?}", cat);
        m.cur[i] = m.cur[i].saturating_sub(bytes);
        m.cur_total = m.cur_total.saturating_sub(bytes);
        obs::counter(obs::Subsys::Mem, cat.name(), m.cur[i]);
        obs::metrics::gauge(obs::Subsys::Mem, cat.name(), m.cur[i]);
    }

    /// Re-charge already-allocated bytes from one category to another
    /// (e.g. hash-built structure becomes C storage).
    pub fn transfer(&self, from: Cat, to: Cat, bytes: u64) {
        self.free(from, bytes);
        self.alloc(to, bytes);
    }

    /// Track an incrementally-grown (or evicted) structure: charge or
    /// free the delta between its previously-reported size and its
    /// current one.  Feeding every growth step through this keeps the
    /// category peak equal to the true *running maximum* — the point of
    /// stage eviction, where rows are freed mid-phase and a bulk
    /// end-of-phase charge would overstate the peak.
    pub fn update(&self, cat: Cat, old_bytes: u64, new_bytes: u64) {
        if new_bytes >= old_bytes {
            self.alloc(cat, new_bytes - old_bytes);
        } else {
            self.free(cat, old_bytes - new_bytes);
        }
    }

    pub fn current(&self, cat: Cat) -> u64 {
        self.inner.borrow().cur[cat.idx()]
    }

    pub fn current_total(&self) -> u64 {
        self.inner.borrow().cur_total
    }

    pub fn peak(&self, cat: Cat) -> u64 {
        self.inner.borrow().peak[cat.idx()]
    }

    pub fn peak_total(&self) -> u64 {
        self.inner.borrow().peak_total
    }

    /// Reset peaks to the current levels (used between experiment phases so
    /// each op's peak is measured in isolation).
    pub fn reset_peaks(&self) {
        let mut m = self.inner.borrow_mut();
        let cur = m.cur;
        m.peak = cur;
        m.peak_total = m.cur_total;
    }

    /// Snapshot of (category, current, peak) triples.
    pub fn snapshot(&self) -> Vec<(Cat, u64, u64)> {
        let m = self.inner.borrow();
        ALL_CATS.iter().map(|&c| (c, m.cur[c.idx()], m.peak[c.idx()])).collect()
    }
}

/// RAII guard: charges on construction, frees on drop.
pub struct Charge {
    tracker: MemTracker,
    cat: Cat,
    bytes: u64,
}

impl Charge {
    pub fn new(tracker: &MemTracker, cat: Cat, bytes: u64) -> Self {
        tracker.alloc(cat, bytes);
        Charge { tracker: tracker.clone(), cat, bytes }
    }

    /// Adjust the charged size (e.g. a growing buffer).
    pub fn resize(&mut self, bytes: u64) {
        if bytes > self.bytes {
            self.tracker.alloc(self.cat, bytes - self.bytes);
        } else {
            self.tracker.free(self.cat, self.bytes - bytes);
        }
        self.bytes = bytes;
    }

    pub fn bytes(&self) -> u64 {
        self.bytes
    }
}

impl Drop for Charge {
    fn drop(&mut self) {
        self.tracker.free(self.cat, self.bytes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_tracks_high_water() {
        let t = MemTracker::new();
        t.alloc(Cat::Aux, 100);
        t.alloc(Cat::Aux, 50);
        t.free(Cat::Aux, 120);
        assert_eq!(t.current(Cat::Aux), 30);
        assert_eq!(t.peak(Cat::Aux), 150);
        assert_eq!(t.peak_total(), 150);
    }

    #[test]
    fn charge_raii() {
        let t = MemTracker::new();
        {
            let _c = Charge::new(&t, Cat::Hash, 64);
            assert_eq!(t.current(Cat::Hash), 64);
        }
        assert_eq!(t.current(Cat::Hash), 0);
        assert_eq!(t.peak(Cat::Hash), 64);
    }

    #[test]
    fn charge_resize() {
        let t = MemTracker::new();
        let mut c = Charge::new(&t, Cat::Comm, 10);
        c.resize(100);
        assert_eq!(t.current(Cat::Comm), 100);
        c.resize(40);
        assert_eq!(t.current(Cat::Comm), 40);
        drop(c);
        assert_eq!(t.current(Cat::Comm), 0);
        assert_eq!(t.peak(Cat::Comm), 100);
    }

    #[test]
    fn transfer_moves_categories() {
        let t = MemTracker::new();
        t.alloc(Cat::Hash, 80);
        t.transfer(Cat::Hash, Cat::MatC, 80);
        assert_eq!(t.current(Cat::Hash), 0);
        assert_eq!(t.current(Cat::MatC), 80);
    }

    #[test]
    fn tracing_samples_the_timeline_without_perturbing_accounting() {
        let t = MemTracker::new();
        obs::rank_begin(0);
        t.alloc(Cat::Aux, 100);
        t.free(Cat::Aux, 40);
        let buf = obs::rank_take();
        // accounting is identical traced or not — hooks only observe
        assert_eq!(t.current(Cat::Aux), 60);
        assert_eq!(t.peak(Cat::Aux), 100);
        let samples: Vec<u64> = buf
            .events
            .iter()
            .filter_map(|e| match e {
                obs::Ev::Counter { name: "aux", val, .. } => Some(*val),
                _ => None,
            })
            .collect();
        assert_eq!(samples, vec![100, 60], "one sample per change, current bytes");
    }

    #[test]
    fn reset_peaks_isolates_phases() {
        let t = MemTracker::new();
        t.alloc(Cat::Aux, 1000);
        t.free(Cat::Aux, 1000);
        assert_eq!(t.peak_total(), 1000);
        t.reset_peaks();
        assert_eq!(t.peak_total(), 0);
        t.alloc(Cat::Aux, 10);
        assert_eq!(t.peak_total(), 10);
    }
}
