//! The refresher: owns the preconditioner plus the retained symbolic
//! state and replays the numeric halves when the fine operator's values
//! change.

use crate::dist::{Comm, CommStats, DistCsr};
use crate::gen::StencilOperator;
use crate::mem::{Cat, MemTracker};
use crate::mg::{Hierarchy, LevelOp, MgOpts, MgPreconditioner};
use crate::ptap::PtapStats;
use crate::util::timer::BusyTimer;

use super::RetainedLevel;

/// Accounting for one [`HierarchyRefresher::refresh`] call — the numeric
/// side of the paper's symbolic/numeric split, measured across the whole
/// hierarchy instead of one product.
#[derive(Debug, Clone, Copy)]
pub struct RefreshStats {
    /// Busy CPU seconds of the whole refresh on this rank.
    pub time_busy: f64,
    /// Rank-wide traffic of the refresh (all communicators: value
    /// gathers, numeric scatters, boundary redistributions, smoother
    /// collectives, the coarse re-factorization gather).
    pub comm: CommStats,
    /// The slice of `comm` spent resending operator values across
    /// telescope boundaries over the retained fine plans.
    pub redist: CommStats,
    /// Triple-product stats delta over the refresh.  By construction its
    /// symbolic fields are zero — the refresh runs no symbolic phase.
    pub ptap: PtapStats,
    /// Busy time plus the α-β model over the refresh traffic, crediting
    /// the numeric overlap windows.
    pub modeled_secs: f64,
    /// Tracker bytes currently held after the refresh (no growth vs the
    /// build: everything was preallocated).
    pub mem_current: u64,
    /// Halo gathers during the refresh that hit warm persistent buffers
    /// instead of allocating (SpMV, prolongation, and stencil halos).
    pub halo_reuses: u64,
}

/// Hierarchy-wide numeric refresher (`MAT_REUSE_MATRIX` analog): wraps a
/// ready [`MgPreconditioner`] built from a `retain`-mode hierarchy and
/// re-runs only numeric work when the fine operator's values change.
pub struct HierarchyRefresher {
    pc: MgPreconditioner,
    retained: Vec<RetainedLevel>,
    tracker: MemTracker,
    /// One record per completed refresh, in call order.
    pub refreshes: Vec<RefreshStats>,
}

fn ptap_sum(retained: &[RetainedLevel]) -> PtapStats {
    let mut acc = PtapStats::default();
    for op in retained.iter().filter_map(|r| r.op.as_ref()) {
        acc.add(op.stats);
    }
    acc
}

impl HierarchyRefresher {
    /// Take ownership of a `retain`-mode hierarchy, build the solver
    /// state on it (collective), and stand ready to refresh.  Panics if
    /// the hierarchy was built without [`crate::mg::HierarchyConfig::retain`].
    pub fn new(
        comm: &Comm,
        mut hierarchy: Hierarchy,
        opts: MgOpts,
        tracker: &MemTracker,
    ) -> HierarchyRefresher {
        let retained = std::mem::take(&mut hierarchy.retained);
        let n_products = hierarchy.levels.iter().filter(|l| l.p.is_some()).count();
        assert_eq!(
            retained.len(),
            n_products,
            "hierarchy must be built with HierarchyConfig::retain for numeric reuse"
        );
        let pc = MgPreconditioner::new(comm, hierarchy, opts);
        HierarchyRefresher { pc, retained, tracker: tracker.clone(), refreshes: Vec::new() }
    }

    /// The preconditioner (apply it, hand it to the Krylov solvers).
    pub fn pc(&mut self) -> &mut MgPreconditioner {
        &mut self.pc
    }

    pub fn hierarchy(&self) -> &Hierarchy {
        &self.pc.hierarchy
    }

    /// Bytes held by the retained telescoped operator copies.
    pub fn retained_tele_bytes(&self) -> u64 {
        self.retained.iter().map(|r| r.tele_bytes()).sum()
    }

    /// Hierarchy-wide numeric refresh (collective over the finest
    /// communicator): overwrite the finest operator's values from
    /// `new_a0` (same pattern), then walk the levels re-running only the
    /// numeric halves — value redistribution over the retained telescope
    /// plans, `Ptap::numeric` per product, coarse-operator value copies —
    /// and finally re-set-up the value-dependent solver state (smoother
    /// diagonals/ω, coarsest factorization).  No symbolic phase runs and
    /// no plan or cycle scratch is re-allocated; the refreshed hierarchy
    /// is bit-identical to a from-scratch rebuild with the same values.
    pub fn refresh(&mut self, comm: &Comm, new_a0: &DistCsr) -> &RefreshStats {
        self.pc.hierarchy.levels[0].a.csr_mut().copy_values_from(new_a0);
        self.refresh_walk(comm)
    }

    /// Like [`HierarchyRefresher::refresh`] for a hierarchy whose finest
    /// level is matrix-free: copy the stencil coefficients from
    /// `new_fine` (same grid/footprint — an O(stencil) value-only
    /// update), then replay the numeric walk.  The stencil is assembled
    /// into a scratch CSR only for the duration of the level-0 product
    /// and freed immediately after, exactly as during the build.
    pub fn refresh_matrix_free(&mut self, comm: &Comm, new_fine: &StencilOperator) -> &RefreshStats {
        match &mut self.pc.hierarchy.levels[0].a {
            LevelOp::Stencil(s) => s.set_coefs_from(new_fine),
            LevelOp::Csr(_) => panic!("finest level is assembled: use refresh()"),
        }
        self.refresh_walk(comm)
    }

    fn refresh_walk(&mut self, comm: &Comm) -> &RefreshStats {
        let _sp = crate::obs::span(
            crate::obs::Subsys::Refresh,
            "refresh",
            self.refreshes.len() as u64,
        );
        let before_global = comm.stats_global();
        let before_ptap = ptap_sum(&self.retained);
        let before_reuses = self.pc.halo_reuses();
        let mut redist = CommStats::default();
        let mut timer = BusyTimer::new();
        timer.start();

        let h = &mut self.pc.hierarchy;
        let mut cur = comm.clone();
        let nlev = h.levels.len();
        for k in 0..nlev {
            crate::obs::instant(crate::obs::Subsys::Refresh, "refresh.level", k as u64);
            let (head, tail) = h.levels.split_at_mut(k + 1);
            let lvl = &mut head[k];
            let Some(p) = &mut lvl.p else {
                break; // true coarsest level: nothing below to rebuild
            };
            let rl = &mut self.retained[k];
            // A matrix-free level assembles its refreshed coefficients
            // into a scratch CSR for the product, dropped right after.
            let scratch: Option<DistCsr> = match &lvl.a {
                LevelOp::Stencil(s) => {
                    let m = s.assemble();
                    self.tracker.alloc(Cat::Aux, m.bytes());
                    Some(m)
                }
                LevelOp::Csr(_) => None,
            };
            let a_src: &DistCsr = match &scratch {
                Some(m) => m,
                None => lvl.a.csr(),
            };
            // value-only prolongator refresh (smoothed aggregation):
            // rebuild S = I − ωD⁻¹A locally and recompute P = S·tent —
            // zero traffic, the symbolic half is retained
            if let Some(ir) = &rl.interp {
                ir.refresh_values(a_src, p);
            }
            let c_new = if let Some(tel) = &lvl.telescope {
                // value-only scatter of A (and of P when it is
                // value-dependent) over the retained fine plans
                // (collective on the parent scope)
                let before = cur.stats_global();
                tel.fine.refresh_csr(&cur, a_src, rl.tele_ops.as_mut().map(|(a_t, _)| a_t));
                if rl.interp.is_some() {
                    tel.fine.refresh_csr(&cur, p, rl.tele_ops.as_mut().map(|(_, p_t)| p_t));
                }
                redist.merge(cur.stats_global().since(before));
                if tel.subcomm.is_none() {
                    // idle rank: its refresh ends at the boundary
                    if let Some(m) = scratch {
                        self.tracker.free(Cat::Aux, m.bytes());
                    }
                    break;
                }
                let sc = tel.subcomm.as_ref().unwrap();
                let (a_t, p_t) =
                    rl.tele_ops.as_ref().expect("active rank retains its telescoped copies");
                let op = rl.op.as_mut().expect("active rank retains its op");
                op.numeric(sc, a_t, p_t);
                let c = op.extract_c();
                cur = sc.clone();
                c
            } else {
                let op = rl.op.as_mut().expect("non-telescoped level retains its op");
                op.numeric(&cur, a_src, p);
                op.extract_c()
            };
            if let Some(m) = scratch {
                self.tracker.free(Cat::Aux, m.bytes());
            }
            tail[0].a.csr_mut().copy_values_from(&c_new);
        }
        // value-dependent solver state: smoother diagonals/ω bounds and
        // the deepest scope's direct factorization (collective, same
        // sequence as initial setup — the refreshed preconditioner is
        // bit-identical to a fresh one)
        self.pc.refresh_solver_state();
        timer.stop();

        let ptap = ptap_sum(&self.retained).since(before_ptap);
        debug_assert_eq!(ptap.sym_msgs, 0, "refresh must not run a symbolic phase");
        let delta = comm.stats_global().since(before_global);
        let time_busy = timer.total();
        let modeled_secs = time_busy + (delta.modeled_secs() - ptap.overlap_total()).max(0.0);
        self.refreshes.push(RefreshStats {
            time_busy,
            comm: delta,
            redist,
            ptap,
            modeled_secs,
            mem_current: self.tracker.current_total(),
            halo_reuses: self.pc.halo_reuses() - before_reuses,
        });
        self.refreshes.last().unwrap()
    }
}
