//! Hierarchy-wide numeric reuse — the `MAT_REUSE_MATRIX` analog.
//!
//! The paper's premise is "symbolic once, numeric many", and
//! [`crate::ptap::Ptap`] honors it per triple product — but a one-shot
//! [`crate::mg::build_hierarchy`] throws every plan away, so a solver
//! whose operator *values* change (time stepping, lagged nonlinear
//! coefficients) pays the full symbolic cost again at every step.  This
//! subsystem closes that gap the way PETSc's `MAT_REUSE_MATRIX` does for
//! `MatPtAP`/Galerkin rebuilds:
//!
//! - a `retain`-mode build ([`crate::mg::HierarchyConfig::retain`])
//!   collects one [`RetainedLevel`] per triple product — the `Ptap` op
//!   (gather plan, gathered `P̃_r` pattern, preallocated `C`, scratch)
//!   plus, at telescope boundaries, the sub-communicator-side `A`/`P`
//!   copies that the one-shot build used to drop;
//! - [`HierarchyRefresher::refresh`] re-runs *only the numeric halves*
//!   level by level: [`crate::agglomerate::RedistPlan::refresh_csr`]
//!   value scatters across telescope boundaries, [`crate::ptap::Ptap::numeric`]
//!   for each coarse operator, then smoother re-setup (diagonal
//!   extraction, ω power iteration) and the coarsest direct
//!   re-factorization on the deepest scope — no symbolic hash tables, no
//!   pattern traffic, no re-allocation of cycle scratch;
//! - every refresh appends a [`RefreshStats`] record, so the
//!   symbolic-vs-numeric cost split the paper reports per product becomes
//!   measurable end to end across the solver lifecycle.

mod refresher;

pub use refresher::{HierarchyRefresher, RefreshStats};

use crate::dist::DistCsr;
use crate::mg::InterpRefresh;
use crate::ptap::Ptap;

/// Symbolic state retained for one built triple product (one per level
/// that has an interpolation), aligned with the hierarchy's level index.
pub struct RetainedLevel {
    /// The triple-product context whose `numeric` the refresh replays.
    /// `None` only on an idle rank's telescope-boundary slot (it joins
    /// the boundary's value redistribution but runs no product).
    pub op: Option<Ptap>,
    /// The telescoped `A`/`P` copies living in the sub-communicator's
    /// layouts (active ranks of a telescoped level; `None` elsewhere).
    /// `refresh_csr` overwrites values in place: `A` always, and `P` too
    /// when the prolongator is value-dependent (`interp` is `Some`) —
    /// a geometric / tentative `P` is structural and never resent.
    pub tele_ops: Option<(DistCsr, DistCsr)>,
    /// Value-only prolongator refresh context (smoothed aggregation:
    /// `P = (I − ωD⁻¹A)·tent` recomputed locally from `A`'s new values).
    /// `None` when `P` is value-static (geometric, tentative).
    pub interp: Option<InterpRefresh>,
}

impl RetainedLevel {
    /// Heap bytes of the retained copies (the op accounts for itself).
    pub fn tele_bytes(&self) -> u64 {
        self.tele_ops.as_ref().map_or(0, |(a, p)| a.bytes() + p.bytes())
            + self.interp.as_ref().map_or(0, |ir| ir.bytes())
    }
}
