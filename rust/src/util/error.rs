//! Minimal error plumbing (an `anyhow` stand-in — no external crates are
//! available offline): a string-message error with `context` adapters and
//! a [`bail!`](crate::bail) macro, enough for the I/O and runtime layers.

use std::fmt;

/// A plain-message error.
#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(m: impl Into<String>) -> Error {
        Error { msg: m.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e.to_string())
    }
}

impl From<std::num::ParseIntError> for Error {
    fn from(e: std::num::ParseIntError) -> Error {
        Error::msg(e.to_string())
    }
}

impl From<std::num::ParseFloatError> for Error {
    fn from(e: std::num::ParseFloatError) -> Error {
        Error::msg(e.to_string())
    }
}

/// Crate-default result type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (`Result`) or absence (`Option`).
pub trait Context<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T>;
    fn with_context(self, f: impl FnOnce() -> String) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{msg}: {e}")))
    }

    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg.to_string()))
    }

    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Early-return with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($arg)*)).into())
    };
}

/// Construct a formatted [`Error`] value.
#[macro_export]
macro_rules! format_err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn might_fail(ok: bool) -> Result<u32> {
        if !ok {
            bail!("failed with code {}", 7);
        }
        Ok(1)
    }

    #[test]
    fn bail_formats() {
        assert_eq!(might_fail(false).unwrap_err().to_string(), "failed with code 7");
        assert_eq!(might_fail(true).unwrap(), 1);
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<u32, std::num::ParseIntError> = "x".parse::<u32>();
        let e = r.context("parsing x").unwrap_err();
        assert!(e.to_string().starts_with("parsing x: "));
        let o: Option<u32> = None;
        assert_eq!(o.context("missing").unwrap_err().to_string(), "missing");
        let s: Option<u32> = Some(3);
        assert_eq!(s.with_context(|| "nope".to_string()).unwrap(), 3);
    }

    #[test]
    fn io_error_converts() {
        fn read() -> Result<String> {
            Ok(std::fs::read_to_string("/definitely/not/a/file")?)
        }
        assert!(read().is_err());
    }
}
