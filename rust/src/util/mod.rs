//! Small self-contained utilities (no external crates are available offline
//! beyond the xla closure, so PRNG, byte codec, timers and table printing
//! are implemented here).

pub mod bytebuf;
pub mod error;
pub mod log;
pub mod plot;
pub mod prng;
pub mod stats;
pub mod table;
pub mod timer;

/// Format a byte count the way the paper reports memory: whole megabytes
/// ("M") with one decimal below 10 M.
pub fn fmt_mb(bytes: u64) -> String {
    let mb = bytes as f64 / (1024.0 * 1024.0);
    if mb >= 10.0 {
        format!("{:.0}", mb)
    } else {
        format!("{:.1}", mb)
    }
}

/// Bytes -> MiB as f64 (for table math).
pub fn mb(bytes: u64) -> f64 {
    bytes as f64 / (1024.0 * 1024.0)
}

/// Format seconds like the paper's time columns (two significant-ish digits).
pub fn fmt_secs(s: f64) -> String {
    if s >= 100.0 {
        format!("{:.0}", s)
    } else if s >= 1.0 {
        format!("{:.1}", s)
    } else if s >= 0.001 {
        format!("{:.1}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_mb_rounds() {
        assert_eq!(fmt_mb(554 * 1024 * 1024), "554");
        assert_eq!(fmt_mb(3 * 1024 * 1024 + 200 * 1024), "3.2");
    }

    #[test]
    fn fmt_secs_ranges() {
        assert_eq!(fmt_secs(63.0), "63.0");
        assert_eq!(fmt_secs(218.0), "218");
        assert_eq!(fmt_secs(0.0064), "6.4ms");
    }
}
