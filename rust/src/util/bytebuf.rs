//! Little-endian byte codec for simulated-MPI message payloads.
//!
//! Real MPI carries raw bytes; we do the same so pack/unpack costs show up
//! in the per-rank busy time exactly as they would on a cluster.

/// Append-only little-endian writer.
#[derive(Default, Debug)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(cap: usize) -> Self {
        ByteWriter { buf: Vec::with_capacity(cap) }
    }

    #[inline]
    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    #[inline]
    pub fn bytes(&mut self, bs: &[u8]) {
        self.buf.extend_from_slice(bs);
    }

    #[inline]
    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn u32_slice(&mut self, vs: &[u32]) {
        for &v in vs {
            self.u32(v);
        }
    }

    #[inline]
    pub fn u64_slice(&mut self, vs: &[u64]) {
        for &v in vs {
            self.u64(v);
        }
    }

    #[inline]
    pub fn f64_slice(&mut self, vs: &[f64]) {
        for &v in vs {
            self.f64(v);
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }
}

/// Sequential little-endian reader.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        ByteReader { buf, pos: 0 }
    }

    #[inline]
    pub fn u8(&mut self) -> u8 {
        let v = self.buf[self.pos];
        self.pos += 1;
        v
    }

    #[inline]
    pub fn bytes(&mut self, n: usize) -> &'a [u8] {
        let v = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        v
    }

    #[inline]
    pub fn u32(&mut self) -> u32 {
        let v = u32::from_le_bytes(self.buf[self.pos..self.pos + 4].try_into().unwrap());
        self.pos += 4;
        v
    }

    #[inline]
    pub fn u64(&mut self) -> u64 {
        let v = u64::from_le_bytes(self.buf[self.pos..self.pos + 8].try_into().unwrap());
        self.pos += 8;
        v
    }

    #[inline]
    pub fn f64(&mut self) -> f64 {
        let v = f64::from_le_bytes(self.buf[self.pos..self.pos + 8].try_into().unwrap());
        self.pos += 8;
        v
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn done(&self) -> bool {
        self.remaining() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip() {
        let mut w = ByteWriter::new();
        w.u32(7);
        w.u64(1 << 40);
        w.f64(-2.5);
        w.u64_slice(&[1, 2, 3]);
        w.u8(9);
        w.bytes(b"metric.name");
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.u32(), 7);
        assert_eq!(r.u64(), 1 << 40);
        assert_eq!(r.f64(), -2.5);
        assert_eq!([r.u64(), r.u64(), r.u64()], [1, 2, 3]);
        assert_eq!(r.u8(), 9);
        assert_eq!(r.bytes(11), b"metric.name");
        assert!(r.done());
    }
}
