//! Timers.  The key instrument is [`thread_cpu_time`]: per-thread CPU time
//! via `CLOCK_THREAD_CPUTIME_ID`.  Rank threads are scheduled onto however
//! many host cores exist (one, here); blocking in barriers/mailboxes
//! accrues no CPU time, so `max over ranks of busy CPU time` is the
//! simulated parallel compute time (see DESIGN.md §7).

use std::time::Instant;

/// Raw `clock_gettime` binding (no `libc` crate offline; the symbol comes
/// from the C runtime every Rust binary already links on unix).  Only the
/// 64-bit layout is declared, so the binding is gated to 64-bit targets;
/// 32-bit unix (different `timespec` ABI) takes the portable fallback.
#[cfg(all(unix, target_pointer_width = "64"))]
mod sys {
    #[repr(C)]
    pub struct Timespec {
        pub tv_sec: i64,
        pub tv_nsec: i64,
    }

    /// `CLOCK_THREAD_CPUTIME_ID` on Linux; the macOS value differs but the
    /// same symbol exists — gate precisely where it matters.
    #[cfg(target_os = "linux")]
    pub const CLOCK_THREAD_CPUTIME_ID: i32 = 3;
    #[cfg(all(unix, not(target_os = "linux")))]
    pub const CLOCK_THREAD_CPUTIME_ID: i32 = 16;

    extern "C" {
        pub fn clock_gettime(clockid: i32, tp: *mut Timespec) -> i32;
    }
}

/// Seconds of CPU time consumed by the *calling thread*.
#[cfg(all(unix, target_pointer_width = "64"))]
pub fn thread_cpu_time() -> f64 {
    let mut ts = sys::Timespec { tv_sec: 0, tv_nsec: 0 };
    // Safety: plain syscall writing into a local out-param.
    unsafe {
        sys::clock_gettime(sys::CLOCK_THREAD_CPUTIME_ID, &mut ts);
    }
    ts.tv_sec as f64 + ts.tv_nsec as f64 * 1e-9
}

/// Fallback for targets without the raw binding: monotonic wall time
/// (busy-time simulation loses fidelity but everything still runs).
#[cfg(not(all(unix, target_pointer_width = "64")))]
pub fn thread_cpu_time() -> f64 {
    use std::sync::OnceLock;
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_secs_f64()
}

/// Accumulating busy-time stopwatch over thread CPU time.
#[derive(Default, Debug, Clone, Copy)]
pub struct BusyTimer {
    start: Option<f64>,
    total: f64,
}

impl BusyTimer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn start(&mut self) {
        debug_assert!(self.start.is_none(), "timer already running");
        self.start = Some(thread_cpu_time());
    }

    pub fn stop(&mut self) {
        let s = self.start.take().expect("timer not running");
        self.total += thread_cpu_time() - s;
    }

    pub fn total(&self) -> f64 {
        self.total
    }
}

/// Wall-clock stopwatch (for end-to-end numbers where wall time is what a
/// user experiences).
pub struct WallTimer {
    start: Instant,
}

impl WallTimer {
    pub fn start() -> Self {
        WallTimer { start: Instant::now() }
    }

    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_cpu_advances_under_work() {
        let t0 = thread_cpu_time();
        let mut acc = 0u64;
        for i in 0..2_000_000u64 {
            acc = acc.wrapping_add(i * i);
        }
        std::hint::black_box(acc);
        assert!(thread_cpu_time() > t0);
    }

    #[test]
    fn busy_timer_accumulates() {
        let mut t = BusyTimer::new();
        t.start();
        let mut acc = 0u64;
        for i in 0..1_000_000u64 {
            acc = acc.wrapping_add(i);
        }
        std::hint::black_box(acc);
        t.stop();
        let first = t.total();
        assert!(first >= 0.0);
        t.start();
        t.stop();
        assert!(t.total() >= first);
    }

    #[test]
    #[cfg(all(unix, target_pointer_width = "64"))]
    fn sleep_accrues_no_cpu_time() {
        let t0 = thread_cpu_time();
        std::thread::sleep(std::time::Duration::from_millis(50));
        let dt = thread_cpu_time() - t0;
        assert!(dt < 0.02, "sleep consumed {dt}s of CPU time");
    }
}
