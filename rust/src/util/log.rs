//! Tiny leveled logger: rank-prefixed diagnostics on stderr/stdout with a
//! process-wide max level from `GPTAP_LOG` (error/warn/info/debug) or a
//! programmatic override (`--quiet` maps to [`Level::Error`]).
//!
//! Rank threads tag themselves once with [`set_rank`] (done by
//! `dist::World::run`), after which every line carries `r<rank>` so
//! interleaved output from simulated ranks stays attributable.  The
//! coordinator thread logs without a rank prefix.

use std::cell::Cell;
use std::fmt;
use std::sync::atomic::{AtomicU8, Ordering};

/// Severity, most severe first.  A message is emitted when its level is
/// at or above the configured max (`Error` always prints).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
        }
    }

    pub fn parse(s: &str) -> Option<Level> {
        match s.trim().to_ascii_lowercase().as_str() {
            "error" | "e" | "0" => Some(Level::Error),
            "warn" | "warning" | "w" | "1" => Some(Level::Warn),
            "info" | "i" | "2" => Some(Level::Info),
            "debug" | "d" | "3" => Some(Level::Debug),
            _ => None,
        }
    }

    fn from_u8(v: u8) -> Level {
        match v {
            0 => Level::Error,
            1 => Level::Warn,
            2 => Level::Info,
            _ => Level::Debug,
        }
    }
}

/// Sentinel: max level not yet resolved from the environment.
const UNSET: u8 = u8::MAX;

static MAX_LEVEL: AtomicU8 = AtomicU8::new(UNSET);

thread_local! {
    static RANK: Cell<i64> = const { Cell::new(-1) };
}

/// Tag the calling thread as a simulated rank; every subsequent log line
/// from this thread carries an `r<rank>` prefix.
pub fn set_rank(rank: usize) {
    RANK.with(|r| r.set(rank as i64));
}

/// Current max level: resolved lazily from `GPTAP_LOG`, default `Info`.
pub fn max_level() -> Level {
    let v = MAX_LEVEL.load(Ordering::Relaxed);
    if v != UNSET {
        return Level::from_u8(v);
    }
    let lvl = std::env::var("GPTAP_LOG")
        .ok()
        .and_then(|s| Level::parse(&s))
        .unwrap_or(Level::Info);
    MAX_LEVEL.store(lvl as u8, Ordering::Relaxed);
    lvl
}

/// Programmatic override of the max level (`--quiet` → `Level::Error`).
/// Wins over `GPTAP_LOG`.
pub fn set_max_level(lvl: Level) {
    MAX_LEVEL.store(lvl as u8, Ordering::Relaxed);
}

/// Would a message at `lvl` be emitted?  Cheap guard for callers that
/// format expensive diagnostics.
#[inline]
pub fn level_enabled(lvl: Level) -> bool {
    lvl <= max_level()
}

fn render(lvl: Level, rank: i64, args: fmt::Arguments<'_>) -> String {
    if rank >= 0 {
        format!("[{} r{rank}] {args}", lvl.tag())
    } else {
        format!("[{}] {args}", lvl.tag())
    }
}

/// Emit one line at `lvl`.  Errors and warnings go to stderr, info and
/// debug to stdout.  Prefer the `log_error!`/`log_warn!`/`log_info!`/
/// `log_debug!` macros over calling this directly.
pub fn log(lvl: Level, args: fmt::Arguments<'_>) {
    if !level_enabled(lvl) {
        return;
    }
    let line = RANK.with(|r| render(lvl, r.get(), args));
    if lvl <= Level::Warn {
        eprintln!("{line}");
    } else {
        println!("{line}");
    }
}

#[macro_export]
macro_rules! log_error {
    ($($t:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Error, format_args!($($t)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($t:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, format_args!($($t)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($($t:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, format_args!($($t)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($t:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, format_args!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_parse_and_order() {
        assert_eq!(Level::parse("error"), Some(Level::Error));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse(" info "), Some(Level::Info));
        assert_eq!(Level::parse("d"), Some(Level::Debug));
        assert_eq!(Level::parse("verbose"), None);
        assert!(Level::Error < Level::Warn);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn render_prefixes_rank_when_tagged() {
        let plain = render(Level::Warn, -1, format_args!("x = {}", 3));
        assert_eq!(plain, "[WARN] x = 3");
        let ranked = render(Level::Error, 5, format_args!("boom"));
        assert_eq!(ranked, "[ERROR r5] boom");
    }
}
