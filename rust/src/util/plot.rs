//! Minimal ASCII line plots for the paper's figure series (no plotting
//! libraries offline).  Benches render Figs 1/3/7/9-style speedup and
//! efficiency curves into the terminal and results/*.txt.

/// One named series of (x, y) points.
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

/// Render series as a fixed-size ASCII chart with axes and a legend.
/// Distinct markers per series; the ideal-scaling guide can be added as
/// its own series.
pub fn ascii_plot(title: &str, xlabel: &str, ylabel: &str, series: &[Series]) -> String {
    const W: usize = 56;
    const H: usize = 18;
    const MARKS: [char; 6] = ['o', '+', 'x', '*', '#', '@'];
    let all: Vec<(f64, f64)> = series.iter().flat_map(|s| s.points.iter().copied()).collect();
    if all.is_empty() {
        return format!("{title}\n(no data)\n");
    }
    let (mut xmin, mut xmax) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut ymin, mut ymax) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y) in &all {
        xmin = xmin.min(x);
        xmax = xmax.max(x);
        ymin = ymin.min(y);
        ymax = ymax.max(y);
    }
    if (xmax - xmin).abs() < 1e-300 {
        xmax = xmin + 1.0;
    }
    if (ymax - ymin).abs() < 1e-300 {
        ymax = ymin + 1.0;
    }
    // pad y a little
    let ypad = 0.05 * (ymax - ymin);
    ymin -= ypad;
    ymax += ypad;
    let mut grid = vec![vec![' '; W]; H];
    for (si, s) in series.iter().enumerate() {
        let mark = MARKS[si % MARKS.len()];
        // draw line segments by sampling
        for w in s.points.windows(2) {
            let (x0, y0) = w[0];
            let (x1, y1) = w[1];
            let steps = 2 * W;
            for k in 0..=steps {
                let t = k as f64 / steps as f64;
                let x = x0 + t * (x1 - x0);
                let y = y0 + t * (y1 - y0);
                let cx = ((x - xmin) / (xmax - xmin) * (W - 1) as f64).round() as usize;
                let cy = ((y - ymin) / (ymax - ymin) * (H - 1) as f64).round() as usize;
                let row = H - 1 - cy.min(H - 1);
                let col = cx.min(W - 1);
                if grid[row][col] == ' ' || grid[row][col] == '.' {
                    grid[row][col] = '.';
                }
            }
        }
        for &(x, y) in &s.points {
            let cx = ((x - xmin) / (xmax - xmin) * (W - 1) as f64).round() as usize;
            let cy = ((y - ymin) / (ymax - ymin) * (H - 1) as f64).round() as usize;
            grid[H - 1 - cy.min(H - 1)][cx.min(W - 1)] = mark;
        }
    }
    let mut out = String::new();
    out.push_str(&format!("{title}\n"));
    for (r, row) in grid.iter().enumerate() {
        let yv = ymax - (ymax - ymin) * r as f64 / (H - 1) as f64;
        let label = if r % 4 == 0 { format!("{yv:8.2} |") } else { "         |".to_string() };
        out.push_str(&label);
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str("         +");
    out.push_str(&"-".repeat(W));
    out.push('\n');
    out.push_str(&format!(
        "          {:<10}{:^36}{:>10}\n",
        format!("{xmin:.0}"),
        xlabel,
        format!("{xmax:.0}")
    ));
    out.push_str(&format!("  y: {ylabel}   legend: "));
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!("{}={} ", MARKS[si % MARKS.len()], s.name));
    }
    out.push('\n');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plots_contain_markers_and_legend() {
        let s = vec![
            Series { name: "aao".into(), points: vec![(2.0, 1.0), (4.0, 1.9), (8.0, 3.6)] },
            Series { name: "ideal".into(), points: vec![(2.0, 1.0), (8.0, 4.0)] },
        ];
        let out = ascii_plot("speedup", "ranks", "speedup", &s);
        assert!(out.contains('o'));
        assert!(out.contains('+'));
        assert!(out.contains("o=aao"));
        assert!(out.lines().count() > 15);
    }

    #[test]
    fn degenerate_inputs_do_not_panic() {
        let out = ascii_plot("t", "x", "y", &[]);
        assert!(out.contains("no data"));
        let one = vec![Series { name: "p".into(), points: vec![(1.0, 1.0)] }];
        let out = ascii_plot("t", "x", "y", &one);
        assert!(out.contains('o'));
    }
}
