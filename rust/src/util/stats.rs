//! Shared order statistics: the nearest-rank percentile used by the bench
//! latency cells and the streaming bucket percentile used by the live
//! metrics histograms (`obs::metrics`).

/// Nearest-rank percentile of an unsorted sample (p in [0, 100]).
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut s = samples.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((p / 100.0) * (s.len() as f64 - 1.0)).round() as usize;
    s[idx.min(s.len() - 1)]
}

/// Nearest-rank percentile over pre-bucketed counts: walks the cumulative
/// counts (no sort, no per-sample storage) and returns `rep(i)` — the
/// caller's representative value — for the bucket holding the p-th sample.
///
/// This is the streaming-histogram counterpart of [`percentile`]: the
/// rolling-window snapshot in `obs::metrics` keeps only log₂ bucket counts,
/// so percentiles are exact to bucket resolution rather than sample
/// resolution.
pub fn bucket_percentile(counts: &[u64], p: f64, rep: impl Fn(usize) -> f64) -> f64 {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0.0;
    }
    // Nearest-rank index into the (implicitly sorted) sample sequence.
    let idx = ((p / 100.0) * (total as f64 - 1.0)).round() as u64;
    let mut seen = 0u64;
    for (i, &c) in counts.iter().enumerate() {
        seen += c;
        if c > 0 && seen > idx {
            return rep(i);
        }
    }
    // p > 100 or rounding pushed past the end: last non-empty bucket.
    let last = counts.iter().rposition(|&c| c > 0).unwrap();
    rep(last)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_of_empty_is_zero() {
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[], 0.0), 0.0);
        assert_eq!(percentile(&[], 100.0), 0.0);
    }

    #[test]
    fn percentile_of_singleton_is_the_sample() {
        for p in [0.0, 37.5, 50.0, 99.0, 100.0] {
            assert_eq!(percentile(&[4.25], p), 4.25);
        }
    }

    #[test]
    fn percentile_exact_boundaries() {
        // Five samples: index = round(p/100 * 4).
        let s = [10.0, 20.0, 30.0, 40.0, 50.0];
        assert_eq!(percentile(&s, 0.0), 10.0);
        assert_eq!(percentile(&s, 25.0), 20.0);
        assert_eq!(percentile(&s, 50.0), 30.0);
        assert_eq!(percentile(&s, 75.0), 40.0);
        assert_eq!(percentile(&s, 100.0), 50.0);
        // Unsorted input sorts first; p past 100 clamps to the max.
        let shuffled = [40.0, 10.0, 50.0, 30.0, 20.0];
        assert_eq!(percentile(&shuffled, 50.0), 30.0);
        assert_eq!(percentile(&shuffled, 200.0), 50.0);
    }

    #[test]
    fn bucket_percentile_matches_nearest_rank() {
        // Buckets [0..4) with representative = index; counts mimic the
        // sample sequence 0,0,1,2,2,2,3 (seven samples).
        let counts = [2u64, 1, 3, 1];
        let rep = |i: usize| i as f64;
        assert_eq!(bucket_percentile(&counts, 0.0, rep), 0.0);
        assert_eq!(bucket_percentile(&counts, 50.0, rep), 2.0);
        assert_eq!(bucket_percentile(&counts, 100.0, rep), 3.0);
    }

    #[test]
    fn bucket_percentile_empty_and_singleton() {
        assert_eq!(bucket_percentile(&[], 50.0, |i| i as f64), 0.0);
        assert_eq!(bucket_percentile(&[0, 0, 0], 99.0, |i| i as f64), 0.0);
        let one = [0u64, 0, 1, 0];
        for p in [0.0, 50.0, 100.0] {
            assert_eq!(bucket_percentile(&one, p, |i| i as f64), 2.0);
        }
    }
}
