//! Paper-style ASCII table printer + TSV writer for the bench harnesses.

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

/// A simple column-aligned table with a header row.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], widths: &[usize], out: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(out, "{:>width$}  ", c, width = widths[i]);
            }
            out.push('\n');
        };
        line(&self.header, &widths, &mut out);
        let total: usize = widths.iter().sum::<usize>() + 2 * ncol;
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            line(row, &widths, &mut out);
        }
        out
    }

    /// Write as TSV (results/ artifacts consumed by EXPERIMENTS.md).
    pub fn write_tsv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "{}", self.header.join("\t"))?;
        for row in &self.rows {
            writeln!(f, "{}", row.join("\t"))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["np", "Mem", "Time"]);
        t.row(vec!["8192", "68", "69"]);
        t.row(vec!["16384", "35", "37"]);
        let s = t.render();
        assert!(s.contains("np"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["1"]);
    }

    #[test]
    fn tsv_round_trip() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["1", "2"]);
        let p = std::env::temp_dir().join("gptap_table_test.tsv");
        t.write_tsv(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert_eq!(s, "a\tb\n1\t2\n");
        let _ = std::fs::remove_file(&p);
    }
}
