//! Deterministic PRNG (splitmix64 seeding + xoshiro256**), used by the
//! workload generators and the property tests.  No `rand` crate offline.

/// xoshiro256** — fast, high-quality, reproducible across platforms.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut st = seed;
        let s = [
            splitmix64(&mut st),
            splitmix64(&mut st),
            splitmix64(&mut st),
            splitmix64(&mut st),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }
}
