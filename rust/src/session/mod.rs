//! Concurrent solve sessions: amortize every α term across K clients.
//!
//! A solver service sees many requests against operators that share one
//! sparsity pattern (time steps, parameter sweeps, concurrent users of
//! the same mesh).  Two pieces turn that sharing into saved latency:
//!
//! - [`SessionCache`] keys retained hierarchies by
//!   `(pattern hash, eq_limit, algorithm)`.  A client whose operator
//!   matches a cached pattern skips the whole symbolic phase — the cache
//!   hands back the [`HierarchyRefresher`] and replays only the numeric
//!   halves for the client's values ([`HierarchyRefresher::refresh`]),
//!   so concurrent clients share one set of plans, gathered patterns and
//!   preallocated coarse operators.
//! - [`RequestQueue`] accumulates up to K pending right-hand sides (with
//!   a flush deadline so a lone request is never starved) and dispatches
//!   them as ONE blocked solve ([`crate::mg::pcg_multi`]): one K-wide
//!   matvec, one K-wide V-cycle and one K-element reduction per dot
//!   product, instead of K of each.  Column `j` of the batch is bitwise
//!   the solve the client would have gotten alone.
//!
//! The pattern hash is collective: each rank hashes its local structure
//! (diag/offd `rowptr`+`cols`, `garray`, row/col ranges) with FNV-1a,
//! then the per-rank digests are allgathered and folded in rank order,
//! so every rank derives the same key and cache decisions never diverge
//! across the communicator.

use std::collections::{HashMap, HashSet};
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::{Duration, Instant};

use crate::dist::{Comm, DistCsr, DistMultiVec, DistOperator, DistVec};
use crate::mem::{Cat, Charge, MemTracker};
use crate::mg::{
    build_hierarchy, pcg_multi, Coarsening, HierarchyConfig, MgOpts, MgPreconditioner, SolveResult,
};
use crate::obs::health::Verdict;
use crate::ptap::Algo;
use crate::reuse::HierarchyRefresher;

/// FNV-1a 64-bit, streamed a word at a time.
struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    fn u64(&mut self, v: u64) {
        for b in v.to_le_bytes() {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    fn u32s(&mut self, vs: &[u32]) {
        for &v in vs {
            self.u64(v as u64);
        }
    }
}

/// Collective structural digest of a distributed operator: hashes the
/// sparsity pattern and partitioning, NOT the values, so refreshing an
/// operator's coefficients keeps its key.  Every rank returns the same
/// digest (one 8-byte allgather).
pub fn pattern_hash(comm: &Comm, a: &DistCsr) -> u64 {
    let mut h = Fnv::new();
    h.u64(a.row_layout.global_size() as u64);
    h.u64(a.col_layout.global_size() as u64);
    h.u64(a.row_begin() as u64);
    h.u64(a.col_begin() as u64);
    h.u32s(&a.diag.rowptr);
    h.u32s(&a.diag.cols);
    h.u32s(&a.offd.rowptr);
    h.u32s(&a.offd.cols);
    for &g in &a.garray {
        h.u64(g);
    }
    let mut g = Fnv::new();
    for v in comm.all_u64(h.0) {
        g.u64(v);
    }
    g.0
}

/// What a cached hierarchy is keyed by: the operator's structural digest
/// plus the two build knobs that change the retained symbolic state.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SessionKey {
    pub pattern_hash: u64,
    pub eq_limit: Option<usize>,
    pub algo: Algo,
}

/// Hierarchy cache for concurrent solve sessions.  `checkout` is
/// collective; every rank takes the same hit/miss/evict path because the
/// key is derived from the collective [`pattern_hash`].
#[derive(Default)]
pub struct SessionCache {
    entries: HashMap<SessionKey, HierarchyRefresher>,
    /// Keys evicted by [`SessionCache::poison`]: their retained state was
    /// observed mid-panic and can no longer be trusted.  The next
    /// checkout of a poisoned key is a transparent recovery rebuild.
    poisoned: HashSet<SessionKey>,
    /// Checkouts served from a retained hierarchy (symbolic phase skipped).
    pub hits: u64,
    /// Checkouts that had to build from scratch.
    pub misses: u64,
    /// Entries dropped because a client re-presented the same
    /// `(eq_limit, algo)` configuration with a different pattern — the
    /// stale pattern's plans can never be refreshed into the new one.
    pub evictions: u64,
    /// Misses that replaced a poisoned entry (recovery rebuilds).
    pub rebuilds: u64,
}

impl SessionCache {
    pub fn new() -> SessionCache {
        SessionCache::default()
    }

    /// Retained hierarchies currently cached.
    pub fn entry_count(&self) -> usize {
        self.entries.len()
    }

    /// The cache key `checkout` would use for `a0` under `cfg`
    /// (collective — every rank derives the same key).
    pub fn key(comm: &Comm, a0: &DistCsr, cfg: HierarchyConfig) -> SessionKey {
        SessionKey {
            pattern_hash: pattern_hash(comm, a0),
            eq_limit: cfg.eq_limit,
            algo: cfg.algo,
        }
    }

    /// Evict `key` as untrustworthy: a dispatch against its hierarchy
    /// panicked, so any retained state it holds may be torn.  The entry
    /// is dropped now; the next `checkout` of the same pattern silently
    /// rebuilds (and counts a recovery rebuild).  Must be called
    /// symmetrically on every rank — pair it with a collective failure
    /// decision, never a per-rank one.
    pub fn poison(&mut self, key: SessionKey) {
        if self.entries.remove(&key).is_some() {
            self.evictions += 1;
        }
        self.poisoned.insert(key);
    }

    /// True when `key` awaits a recovery rebuild.
    pub fn is_poisoned(&self, key: &SessionKey) -> bool {
        self.poisoned.contains(key)
    }

    /// Hand back a ready-to-apply refresher for `a0` (collective).  On a
    /// hit the cached hierarchy absorbs `a0`'s values through the
    /// numeric-only refresh walk; on a miss a `retain`-mode hierarchy is
    /// built (evicting any entry with the same configuration but a stale
    /// pattern).  Either way the returned preconditioner is bit-identical
    /// to one freshly built on `a0`.  Returns `(refresher, was_hit)`.
    pub fn checkout(
        &mut self,
        comm: &Comm,
        a0: &DistCsr,
        coarsening: &Coarsening,
        cfg: HierarchyConfig,
        opts: MgOpts,
        tracker: &MemTracker,
    ) -> (&mut HierarchyRefresher, bool) {
        let key = SessionCache::key(comm, a0, cfg);
        let hit = self.entries.contains_key(&key);
        if hit {
            self.hits += 1;
            crate::obs::metrics::add(crate::obs::Subsys::Session, "cache.hit", 1);
        } else {
            self.misses += 1;
            crate::obs::metrics::add(crate::obs::Subsys::Session, "cache.miss", 1);
            if self.poisoned.remove(&key) {
                self.rebuilds += 1;
                crate::obs::metrics::add(crate::obs::Subsys::Session, "rebuilds", 1);
            }
            let stale: Vec<SessionKey> = self
                .entries
                .keys()
                .filter(|k| k.algo == key.algo && k.eq_limit == key.eq_limit)
                .copied()
                .collect();
            for s in stale {
                self.entries.remove(&s);
                self.evictions += 1;
            }
            let mut cfg = cfg;
            cfg.retain = true;
            let h = build_hierarchy(comm, a0.clone(), coarsening, cfg, tracker);
            self.entries.insert(key, HierarchyRefresher::new(comm, h, opts, tracker));
        }
        let r = self.entries.get_mut(&key).unwrap();
        if hit {
            r.refresh(comm, a0);
        }
        (r, hit)
    }
}

/// One completed request out of a flushed batch.
#[derive(Debug, Clone)]
pub struct QueuedSolve {
    /// The ticket `submit` returned for this right-hand side.
    pub ticket: u64,
    pub x: DistVec,
    pub result: SolveResult,
    /// Seconds this request sat in the queue before its batch dispatched.
    pub queue_wait: f64,
    /// Seconds from `submit` to batch completion (queue wait + solve).
    pub e2e: f64,
    /// Health verdict from this column's residual history
    /// ([`crate::obs::health::residual_verdict`] under the default
    /// policy).  A `Diverging` ticket should be reported to its client as
    /// an error; the batch's other columns are unaffected.
    pub verdict: crate::obs::health::Verdict,
}

/// One pending right-hand side with its latency bookkeeping.
struct Pending {
    ticket: u64,
    b: DistVec,
    submitted: Instant,
    /// Trace timestamp at submit (0 when tracing was off at submit).
    submit_us: u64,
    /// Per-request deadline: cancel (don't dispatch) if the request is
    /// still queued this long after submit.
    deadline: Option<Duration>,
}

/// Backpressure verdict from [`RequestQueue::try_submit`]: admitting the
/// request would push projected memory past the budget, so it was shed
/// instead of queued.  Byte figures are this rank's local projection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Overloaded {
    /// Tracked bytes projected if the request were admitted.
    pub projected_bytes: u64,
    /// The budget the projection breached.
    pub budget_bytes: u64,
}

impl fmt::Display for Overloaded {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "overloaded: projected {} bytes exceeds budget {} bytes",
            self.projected_bytes, self.budget_bytes
        )
    }
}

/// OR-fold per-rank vote vectors (one byte per ticket, allgathered) into
/// one mask every rank agrees on.
fn or_fold(votes: &[Vec<u8>], n: usize) -> Vec<bool> {
    let mut out = vec![false; n];
    for v in votes {
        debug_assert_eq!(v.len(), n, "every rank must vote on the same tickets");
        for (o, &b) in out.iter_mut().zip(v) {
            *o |= b != 0;
        }
    }
    out
}

/// Accumulates pending right-hand sides and dispatches them as one
/// blocked solve.  A flush fires when the batch is full (`capacity`
/// requests) or when the oldest pending request has waited past the
/// deadline — whichever comes first — so latency stays bounded while
/// every α term in the solve is amortized across the batch.
pub struct RequestQueue {
    capacity: usize,
    deadline: Duration,
    pending: Vec<Pending>,
    next_ticket: u64,
    oldest: Option<Instant>,
    /// Batches dispatched.
    pub flushes: u64,
    /// Batches dispatched below capacity (deadline or forced flush).
    pub partial_flushes: u64,
}

impl RequestQueue {
    pub fn new(capacity: usize, deadline: Duration) -> RequestQueue {
        assert!(capacity >= 1, "batch capacity must be at least 1");
        RequestQueue {
            capacity,
            deadline,
            pending: Vec::new(),
            next_ticket: 0,
            oldest: None,
            flushes: 0,
            partial_flushes: 0,
        }
    }

    /// Enqueue one right-hand side; returns the ticket that identifies
    /// it in the flushed batch.
    pub fn submit(&mut self, b: DistVec) -> u64 {
        self.submit_with_deadline(b, None)
    }

    /// [`RequestQueue::submit`] with a per-request deadline: if the
    /// request is still queued `deadline` after submit when a guarded
    /// flush dispatches, it is cancelled (verdict
    /// [`Verdict::Cancelled`]) instead of solved.
    pub fn submit_with_deadline(&mut self, b: DistVec, deadline: Option<Duration>) -> u64 {
        let ticket = self.next_ticket;
        self.next_ticket += 1;
        if self.pending.is_empty() {
            self.oldest = Some(Instant::now());
        }
        let submit_us = if crate::obs::enabled() {
            crate::obs::instant(crate::obs::Subsys::Session, "enqueue", ticket);
            crate::obs::now_us()
        } else {
            0
        };
        self.pending.push(Pending {
            ticket,
            b,
            submitted: Instant::now(),
            submit_us,
            deadline,
        });
        crate::obs::metrics::add(crate::obs::Subsys::Session, "requests", 1);
        crate::obs::metrics::gauge(
            crate::obs::Subsys::Session,
            "queue.depth",
            self.pending.len() as u64,
        );
        ticket
    }

    /// Admission-controlled submit (collective): project the tracked
    /// memory this request would add — its RHS column plus the matching
    /// solution column, on top of current usage and the columns already
    /// queued — and shed the request with [`Overloaded`] instead of
    /// queueing it when any rank's projection breaches `budget_bytes`
    /// (0 = unlimited).  The shed decision is a one-`u64` reduction so
    /// every rank takes the same branch and the SPMD schedule never
    /// diverges; a shed request consumes no ticket.
    pub fn try_submit(
        &mut self,
        comm: &Comm,
        b: DistVec,
        tracker: &MemTracker,
        budget_bytes: u64,
        deadline: Option<Duration>,
    ) -> Result<u64, Overloaded> {
        let queued: u64 = self.pending.iter().map(|p| p.b.bytes()).sum();
        let projected = tracker.current_total() + 2 * (queued + b.bytes());
        let over = budget_bytes > 0 && projected > budget_bytes;
        let shed = comm.allreduce_sum_u64(u64::from(over)) > 0;
        if shed {
            crate::obs::metrics::add(crate::obs::Subsys::Session, "queue.shed", 1);
            return Err(Overloaded { projected_bytes: projected, budget_bytes });
        }
        Ok(self.submit_with_deadline(b, deadline))
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// True when the batch is full or the oldest request has waited past
    /// the flush deadline.
    pub fn should_flush(&self) -> bool {
        !self.pending.is_empty()
            && (self.pending.len() >= self.capacity
                || self.oldest.is_some_and(|t| t.elapsed() >= self.deadline))
    }

    /// Dispatch every pending request as ONE blocked PCG solve
    /// (collective).  The K stacked right-hand sides pay one K-wide
    /// matvec, one K-wide preconditioner cycle and one K-element
    /// reduction per dot product; each returned column is bitwise the
    /// solve its client would have gotten alone.  The transient K-wide
    /// block is charged to [`Cat::MultiVec`] for the duration of the
    /// solve.
    pub fn flush(
        &mut self,
        comm: &Comm,
        a: &dyn DistOperator,
        pc: Option<&mut MgPreconditioner>,
        rtol: f64,
        max_iters: usize,
        tracker: &MemTracker,
    ) -> Vec<QueuedSolve> {
        if self.pending.is_empty() {
            return Vec::new();
        }
        self.flushes += 1;
        if self.pending.len() < self.capacity {
            self.partial_flushes += 1;
        }
        let pending = std::mem::take(&mut self.pending);
        self.oldest = None;
        crate::obs::instant(
            crate::obs::Subsys::Session,
            "flush.decide",
            pending.len() as u64,
        );
        crate::obs::metrics::gauge(crate::obs::Subsys::Session, "queue.depth", 0);
        let deadline_secs = self.deadline.as_secs_f64();

        let dispatch_start = Instant::now();
        let cols: Vec<&DistVec> = pending.iter().map(|p| &p.b).collect();
        let b = DistMultiVec::from_columns(&cols);
        let mut x = DistMultiVec::zeros(b.layout.clone(), b.rank, b.k);
        let _scratch = Charge::new(tracker, Cat::MultiVec, b.bytes() + x.bytes());
        let results = {
            let _sp = crate::obs::span(crate::obs::Subsys::Session, "dispatch", b.k as u64);
            pcg_multi(comm, a, &b, &mut x, pc, rtol, max_iters)
        };
        let dispatch_end = Instant::now();
        pending
            .into_iter()
            .zip(results)
            .enumerate()
            .map(|(j, (p, result))| {
                if crate::obs::enabled() && p.submit_us != 0 {
                    crate::obs::complete(
                        crate::obs::Subsys::Session,
                        "request",
                        p.ticket,
                        p.submit_us,
                        crate::obs::now_us(),
                    );
                }
                let queue_wait = (dispatch_start - p.submitted).as_secs_f64();
                let e2e = (dispatch_end - p.submitted).as_secs_f64();
                let verdict = crate::obs::health::residual_verdict(
                    &result.residuals,
                    result.converged,
                    &crate::obs::health::HealthPolicy::default(),
                );
                if crate::obs::metrics::enabled() {
                    crate::obs::metrics::observe(
                        crate::obs::Subsys::Session,
                        "queue.wait_us",
                        (queue_wait * 1e6) as u64,
                    );
                    crate::obs::metrics::observe(
                        crate::obs::Subsys::Session,
                        "request.e2e_us",
                        (e2e * 1e6) as u64,
                    );
                    if queue_wait >= deadline_secs {
                        crate::obs::metrics::add(
                            crate::obs::Subsys::Session,
                            "deadline.miss",
                            1,
                        );
                    }
                    if verdict == crate::obs::health::Verdict::Diverging {
                        crate::obs::metrics::add(
                            crate::obs::Subsys::Session,
                            "request.failed",
                            1,
                        );
                    }
                }
                QueuedSolve {
                    ticket: p.ticket,
                    x: x.column(j),
                    result,
                    queue_wait,
                    e2e,
                    verdict,
                }
            })
            .collect()
    }

    /// [`RequestQueue::flush`] hardened for a long-lived server
    /// (collective): expired per-request deadlines are cancelled before
    /// dispatch, and a panicking dispatch is contained to the tickets
    /// that caused it instead of tearing the server down.
    ///
    /// The recovery chain:
    /// 1. Deadline sweep — each rank votes per ticket on whether its
    ///    deadline expired; votes are OR-folded through an allgather so
    ///    every rank cancels the same set even when wall clocks disagree.
    ///    Cancelled tickets get [`Verdict::Cancelled`], a zero solution
    ///    and an empty history, and never reach the solver.
    /// 2. The surviving batch dispatches inside `catch_unwind`.  A panic
    ///    here comes from a malformed request — e.g. an RHS assembled on
    ///    the wrong grid, which [`DistMultiVec::from_columns`] rejects on
    ///    every rank before any message is sent.  (Containment relies on
    ///    panics being SPMD-symmetric; shape mismatches are, because the
    ///    layout object is replicated.)
    /// 3. On panic, each rank flags the columns whose shape disagrees
    ///    with the operator; the flags are OR-folded, flagged tickets
    ///    fail with [`Verdict::Failed`], and the clean remainder
    ///    redispatches as one batch — bitwise what it would have gotten,
    ///    since a block solve's column `j` never depends on the other
    ///    columns.
    /// 4. If the redispatch still panics (a poisoned column the shape
    ///    check could not see), each remaining ticket is retried as a
    ///    guarded single-column solve, failing only the columns that
    ///    actually panic.
    pub fn flush_guarded(
        &mut self,
        comm: &Comm,
        a: &dyn DistOperator,
        pc: Option<&mut MgPreconditioner>,
        rtol: f64,
        max_iters: usize,
        tracker: &MemTracker,
    ) -> Vec<QueuedSolve> {
        if self.pending.is_empty() {
            return Vec::new();
        }
        self.flushes += 1;
        if self.pending.len() < self.capacity {
            self.partial_flushes += 1;
        }
        let pending = std::mem::take(&mut self.pending);
        self.oldest = None;
        crate::obs::instant(
            crate::obs::Subsys::Session,
            "flush.decide",
            pending.len() as u64,
        );
        crate::obs::metrics::gauge(crate::obs::Subsys::Session, "queue.depth", 0);
        let deadline_secs = self.deadline.as_secs_f64();
        let mut pc = pc;
        let dispatch_start = Instant::now();

        let votes: Vec<u8> = pending
            .iter()
            .map(|p| u8::from(p.deadline.is_some_and(|d| p.submitted.elapsed() >= d)))
            .collect();
        let cancelled = or_fold(&comm.allgather_bytes(votes), pending.len());
        let live: Vec<usize> = (0..pending.len()).filter(|&i| !cancelled[i]).collect();

        let dispatch = |idx: &[usize], pc: Option<&mut MgPreconditioner>| {
            let cols: Vec<&DistVec> = idx.iter().map(|&i| &pending[i].b).collect();
            let b = DistMultiVec::from_columns(&cols);
            let mut x = DistMultiVec::zeros(b.layout.clone(), b.rank, b.k);
            let _scratch = Charge::new(tracker, Cat::MultiVec, b.bytes() + x.bytes());
            let results = {
                let _sp = crate::obs::span(crate::obs::Subsys::Session, "dispatch", b.k as u64);
                pcg_multi(comm, a, &b, &mut x, pc, rtol, max_iters)
            };
            (x, results)
        };

        let mut solved: Vec<Option<(DistVec, SolveResult)>> =
            (0..pending.len()).map(|_| None).collect();
        if !live.is_empty() {
            match catch_unwind(AssertUnwindSafe(|| dispatch(&live, pc.as_deref_mut()))) {
                Ok((x, results)) => {
                    for (j, (&i, r)) in live.iter().zip(results).enumerate() {
                        solved[i] = Some((x.column(j), r));
                    }
                }
                Err(_) => {
                    let lay = a.row_layout();
                    let n_local = lay.local_size(comm.rank());
                    let shape_votes: Vec<u8> = live
                        .iter()
                        .map(|&i| {
                            let b = &pending[i].b;
                            u8::from(b.layout != *lay || b.vals.len() != n_local)
                        })
                        .collect();
                    let bad = or_fold(&comm.allgather_bytes(shape_votes), live.len());
                    let survivors: Vec<usize> = live
                        .iter()
                        .enumerate()
                        .filter(|&(j, _)| !bad[j])
                        .map(|(_, &i)| i)
                        .collect();
                    if !survivors.is_empty() {
                        match catch_unwind(AssertUnwindSafe(|| {
                            dispatch(&survivors, pc.as_deref_mut())
                        })) {
                            Ok((x, results)) => {
                                for (j, (&i, r)) in survivors.iter().zip(results).enumerate() {
                                    solved[i] = Some((x.column(j), r));
                                }
                            }
                            Err(_) => {
                                for &i in &survivors {
                                    let one = [i];
                                    if let Ok((x, mut results)) = catch_unwind(
                                        AssertUnwindSafe(|| dispatch(&one, pc.as_deref_mut())),
                                    ) {
                                        let r = results
                                            .pop()
                                            .expect("one column in, one result out");
                                        solved[i] = Some((x.column(0), r));
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        let dispatch_end = Instant::now();

        let policy = crate::obs::health::HealthPolicy::default();
        pending
            .iter()
            .enumerate()
            .map(|(i, p)| {
                if crate::obs::enabled() && p.submit_us != 0 {
                    crate::obs::complete(
                        crate::obs::Subsys::Session,
                        "request",
                        p.ticket,
                        p.submit_us,
                        crate::obs::now_us(),
                    );
                }
                let queue_wait = (dispatch_start - p.submitted).as_secs_f64();
                let e2e = (dispatch_end - p.submitted).as_secs_f64();
                let empty = || SolveResult {
                    iterations: 0,
                    converged: false,
                    residuals: Vec::new(),
                };
                let (x, result, verdict) = if cancelled[i] {
                    (
                        DistVec::zeros(p.b.layout.clone(), p.b.rank),
                        empty(),
                        Verdict::Cancelled,
                    )
                } else if let Some((x, result)) = solved[i].take() {
                    let verdict = crate::obs::health::residual_verdict(
                        &result.residuals,
                        result.converged,
                        &policy,
                    );
                    (x, result, verdict)
                } else {
                    (
                        DistVec::zeros(p.b.layout.clone(), p.b.rank),
                        empty(),
                        Verdict::Failed,
                    )
                };
                if crate::obs::metrics::enabled() {
                    crate::obs::metrics::observe(
                        crate::obs::Subsys::Session,
                        "queue.wait_us",
                        (queue_wait * 1e6) as u64,
                    );
                    crate::obs::metrics::observe(
                        crate::obs::Subsys::Session,
                        "request.e2e_us",
                        (e2e * 1e6) as u64,
                    );
                    if queue_wait >= deadline_secs {
                        crate::obs::metrics::add(
                            crate::obs::Subsys::Session,
                            "deadline.miss",
                            1,
                        );
                    }
                    match verdict {
                        Verdict::Cancelled => crate::obs::metrics::add(
                            crate::obs::Subsys::Session,
                            "request.cancelled",
                            1,
                        ),
                        Verdict::Failed | Verdict::Diverging => crate::obs::metrics::add(
                            crate::obs::Subsys::Session,
                            "request.failed",
                            1,
                        ),
                        _ => {}
                    }
                }
                QueuedSolve { ticket: p.ticket, x, result, queue_wait, e2e, verdict }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{CsrOperator, DistSpmv, World};
    use crate::gen::{grid_laplacian, Grid3};
    use crate::mg::{geometric_chain, pcg};

    fn scaled_values(a: &DistCsr, factor: f64) -> DistCsr {
        let mut m = a.clone();
        for v in m.diag.vals.iter_mut().chain(m.offd.vals.iter_mut()) {
            *v *= factor;
        }
        m
    }

    #[test]
    fn identical_pattern_shares_hierarchy() {
        let w = World::new(2);
        w.run(|c| {
            let grids = geometric_chain(Grid3::cube(3), 3);
            let coarsening = Coarsening::Geometric { grids: grids.clone() };
            let a = grid_laplacian(grids[0], c.rank(), c.size());
            let tracker = MemTracker::new();
            let cfg = HierarchyConfig::default();
            let mut cache = SessionCache::new();

            let (_, hit1) =
                cache.checkout(&c, &a, &coarsening, cfg, MgOpts::default(), &tracker);
            assert!(!hit1, "first client must build");
            // second client: same pattern, different coefficient values
            let a2 = scaled_values(&a, 2.0);
            let (_, hit2) =
                cache.checkout(&c, &a2, &coarsening, cfg, MgOpts::default(), &tracker);
            assert!(hit2, "same pattern must reuse the retained hierarchy");
            assert_eq!(cache.entry_count(), 1);
            assert_eq!((cache.hits, cache.misses, cache.evictions), (1, 1, 0));
        });
    }

    #[test]
    fn pattern_change_evicts_stale_entry() {
        let w = World::new(2);
        w.run(|c| {
            let tracker = MemTracker::new();
            let cfg = HierarchyConfig::default();
            let mut cache = SessionCache::new();

            let grids3 = geometric_chain(Grid3::cube(3), 2);
            let c3 = Coarsening::Geometric { grids: grids3.clone() };
            let a3 = grid_laplacian(grids3[0], c.rank(), c.size());
            cache.checkout(&c, &a3, &c3, cfg, MgOpts::default(), &tracker);

            // same (algo, eq_limit) but a different mesh: the old plans
            // can never be refreshed into this pattern, so it is evicted
            let grids4 = geometric_chain(Grid3::cube(4), 2);
            let c4 = Coarsening::Geometric { grids: grids4.clone() };
            let a4 = grid_laplacian(grids4[0], c.rank(), c.size());
            let (_, hit) = cache.checkout(&c, &a4, &c4, cfg, MgOpts::default(), &tracker);
            assert!(!hit);
            assert_eq!(cache.entry_count(), 1, "stale pattern must be evicted");
            assert_eq!((cache.hits, cache.misses, cache.evictions), (0, 2, 1));
        });
    }

    #[test]
    fn refresh_then_solve_matches_fresh_build() {
        let w = World::new(2);
        w.run(|c| {
            let grids = geometric_chain(Grid3::cube(3), 3);
            let coarsening = Coarsening::Geometric { grids: grids.clone() };
            let a = grid_laplacian(grids[0], c.rank(), c.size());
            let a2 = scaled_values(&a, 1.5);
            let layout = a.row_layout.clone();
            let tracker = MemTracker::new();
            let cfg = HierarchyConfig::default();
            let b = DistVec::from_fn(layout.clone(), c.rank(), |g| ((g * 7 % 5) as f64) - 2.0);

            // cached path: build on a, then hit with a2's values
            let mut cache = SessionCache::new();
            cache.checkout(&c, &a, &coarsening, cfg, MgOpts::default(), &tracker);
            let (r, hit) =
                cache.checkout(&c, &a2, &coarsening, cfg, MgOpts::default(), &tracker);
            assert!(hit);
            let spmv = DistSpmv::new(&c, &a2);
            let op = CsrOperator::new(&a2, &spmv);
            let mut x_cached = DistVec::zeros(layout.clone(), c.rank());
            let res_cached = pcg(&c, &op, &b, &mut x_cached, Some(r.pc()), 1e-8, 60);

            // fresh path: build directly on a2
            let mut cfg_fresh = cfg;
            cfg_fresh.retain = true;
            let h = build_hierarchy(&c, a2.clone(), &coarsening, cfg_fresh, &tracker);
            let mut fresh = HierarchyRefresher::new(&c, h, MgOpts::default(), &tracker);
            let mut x_fresh = DistVec::zeros(layout, c.rank());
            let res_fresh = pcg(&c, &op, &b, &mut x_fresh, Some(fresh.pc()), 1e-8, 60);

            assert!(res_cached.converged && res_fresh.converged);
            assert_eq!(
                res_cached.residuals, res_fresh.residuals,
                "refreshed hierarchy must solve bit-identically to a fresh build"
            );
            assert_eq!(x_cached.vals, x_fresh.vals);
        });
    }

    #[test]
    fn queue_flushes_at_capacity_and_matches_scalar_solves() {
        let w = World::new(2);
        w.run(|c| {
            let a = grid_laplacian(Grid3::cube(4), c.rank(), c.size());
            let spmv = DistSpmv::new(&c, &a);
            let op = CsrOperator::new(&a, &spmv);
            let layout = a.row_layout.clone();
            let tracker = MemTracker::new();
            let rhs = |s: usize| {
                DistVec::from_fn(layout.clone(), c.rank(), |g| {
                    ((g as f64) * 0.1 + s as f64).cos()
                })
            };

            let mut q = RequestQueue::new(3, Duration::from_secs(3600));
            for s in 0..3 {
                assert!(!q.should_flush());
                let t = q.submit(rhs(s));
                assert_eq!(t, s as u64);
            }
            assert!(q.should_flush(), "full batch must flush");
            let done = q.flush(&c, &op, None, 1e-10, 400, &tracker);
            assert_eq!(done.len(), 3);
            assert!(q.is_empty());
            assert_eq!((q.flushes, q.partial_flushes), (1, 0));
            assert_eq!(tracker.current(Cat::MultiVec), 0, "block scratch released");
            assert!(tracker.peak(Cat::MultiVec) > 0, "block scratch was charged");

            // each batched column is bitwise the solo solve
            for (s, d) in done.iter().enumerate() {
                assert_eq!(d.ticket, s as u64);
                assert!(d.queue_wait >= 0.0 && d.e2e >= d.queue_wait, "latency ordering");
                let mut x = DistVec::zeros(layout.clone(), c.rank());
                let res = pcg(&c, &op, &rhs(s), &mut x, None, 1e-10, 400);
                assert_eq!(d.x.vals, x.vals, "column {s} diverged from solo solve");
                assert_eq!(d.result.residuals, res.residuals);
                assert_eq!(d.result.iterations, res.iterations);
            }
        });
    }

    #[test]
    fn queue_deadline_flushes_single_request() {
        let w = World::new(1);
        w.run(|c| {
            let a = grid_laplacian(Grid3::cube(3), c.rank(), c.size());
            let spmv = DistSpmv::new(&c, &a);
            let op = CsrOperator::new(&a, &spmv);
            let layout = a.row_layout.clone();
            let tracker = MemTracker::new();
            let b = DistVec::from_fn(layout.clone(), c.rank(), |g| (g as f64 * 0.37).sin());

            let mut q = RequestQueue::new(8, Duration::ZERO);
            q.submit(b.clone());
            assert!(q.should_flush(), "expired deadline must flush a lone request");
            let done = q.flush(&c, &op, None, 1e-10, 400, &tracker);
            assert_eq!(done.len(), 1);
            assert_eq!((q.flushes, q.partial_flushes), (1, 1));

            let mut x = DistVec::zeros(layout, c.rank());
            let res = pcg(&c, &op, &b, &mut x, None, 1e-10, 400);
            assert_eq!(done[0].x.vals, x.vals, "K=1 batch must equal the scalar path");
            assert_eq!(done[0].result.residuals, res.residuals);
        });
    }

    #[test]
    fn poisoned_entry_rebuilds_transparently() {
        let w = World::new(2);
        w.run(|c| {
            let grids = geometric_chain(Grid3::cube(3), 3);
            let coarsening = Coarsening::Geometric { grids: grids.clone() };
            let a = grid_laplacian(grids[0], c.rank(), c.size());
            let tracker = MemTracker::new();
            let cfg = HierarchyConfig::default();
            let mut cache = SessionCache::new();

            cache.checkout(&c, &a, &coarsening, cfg, MgOpts::default(), &tracker);
            let key = SessionCache::key(&c, &a, cfg);
            cache.poison(key);
            assert!(cache.is_poisoned(&key));
            assert_eq!(cache.entry_count(), 0, "poisoned entry is evicted immediately");

            let (_, hit) = cache.checkout(&c, &a, &coarsening, cfg, MgOpts::default(), &tracker);
            assert!(!hit, "recovery checkout must rebuild");
            assert!(!cache.is_poisoned(&key), "rebuild clears the poison mark");
            assert_eq!(cache.rebuilds, 1);
            assert_eq!((cache.hits, cache.misses, cache.evictions), (0, 2, 1));

            let (_, hit2) = cache.checkout(&c, &a, &coarsening, cfg, MgOpts::default(), &tracker);
            assert!(hit2, "rebuilt entry serves hits again");
        });
    }

    #[test]
    fn try_submit_sheds_over_budget_and_admits_otherwise() {
        let w = World::new(2);
        w.run(|c| {
            let a = grid_laplacian(Grid3::cube(3), c.rank(), c.size());
            let layout = a.row_layout.clone();
            let tracker = MemTracker::new();
            let b = DistVec::from_fn(layout.clone(), c.rank(), |g| g as f64);

            let mut q = RequestQueue::new(4, Duration::from_secs(3600));
            assert_eq!(q.try_submit(&c, b.clone(), &tracker, 1 << 40, None), Ok(0));
            // tiny budget: shed, no ticket consumed, queue untouched
            let err = q.try_submit(&c, b.clone(), &tracker, 1, None).unwrap_err();
            assert!(err.projected_bytes > err.budget_bytes);
            assert_eq!(err.budget_bytes, 1);
            assert_eq!(q.len(), 1);
            // budget 0 means unlimited
            assert_eq!(q.try_submit(&c, b.clone(), &tracker, 0, None), Ok(1));
            assert_eq!(q.len(), 2);
        });
    }

    #[test]
    fn guarded_flush_cancels_expired_and_solves_the_rest() {
        let w = World::new(2);
        w.run(|c| {
            let a = grid_laplacian(Grid3::cube(4), c.rank(), c.size());
            let spmv = DistSpmv::new(&c, &a);
            let op = CsrOperator::new(&a, &spmv);
            let layout = a.row_layout.clone();
            let tracker = MemTracker::new();
            let rhs = |s: usize| {
                DistVec::from_fn(layout.clone(), c.rank(), |g| {
                    ((g as f64) * 0.1 + s as f64).cos()
                })
            };

            let mut q = RequestQueue::new(3, Duration::from_secs(3600));
            q.submit(rhs(0));
            q.submit_with_deadline(rhs(1), Some(Duration::ZERO)); // expired at flush
            q.submit_with_deadline(rhs(2), Some(Duration::from_secs(3600)));
            let done = q.flush_guarded(&c, &op, None, 1e-10, 400, &tracker);
            assert_eq!(done.len(), 3);
            assert!(q.is_empty());
            assert_eq!(done[1].verdict, Verdict::Cancelled);
            assert_eq!(done[1].result.iterations, 0);
            assert!(done[1].x.vals.iter().all(|&v| v == 0.0), "cancelled ticket gets zeros");

            // surviving tickets are bitwise their solo solves
            for &s in &[0usize, 2] {
                let d = &done[s];
                assert_eq!(d.ticket, s as u64);
                assert_eq!(d.verdict, Verdict::Healthy);
                let mut x = DistVec::zeros(layout.clone(), c.rank());
                let res = pcg(&c, &op, &rhs(s), &mut x, None, 1e-10, 400);
                assert_eq!(d.x.vals, x.vals, "column {s} diverged from solo solve");
                assert_eq!(d.result.residuals, res.residuals);
            }
        });
    }

    #[test]
    fn guarded_flush_fails_only_the_malformed_ticket() {
        let w = World::new(2);
        w.run(|c| {
            let a = grid_laplacian(Grid3::cube(4), c.rank(), c.size());
            let spmv = DistSpmv::new(&c, &a);
            let op = CsrOperator::new(&a, &spmv);
            let layout = a.row_layout.clone();
            let tracker = MemTracker::new();
            let rhs = |s: usize| {
                DistVec::from_fn(layout.clone(), c.rank(), |g| {
                    ((g as f64) * 0.1 + s as f64).cos()
                })
            };

            // ticket 1's RHS was assembled on the wrong grid: its layout
            // disagrees with the operator on every rank, so the dispatch
            // panic is SPMD-symmetric and containable
            let wrong = grid_laplacian(Grid3::cube(3), c.rank(), c.size());
            let bad = DistVec::from_fn(wrong.row_layout.clone(), c.rank(), |g| g as f64);

            let mut q = RequestQueue::new(3, Duration::from_secs(3600));
            q.submit(rhs(0));
            q.submit(bad);
            q.submit(rhs(2));
            let done = q.flush_guarded(&c, &op, None, 1e-10, 400, &tracker);
            assert_eq!(done.len(), 3);
            assert_eq!(done[1].verdict, Verdict::Failed);
            assert!(done[1].result.residuals.is_empty());
            assert_eq!(
                tracker.current(Cat::MultiVec),
                0,
                "scratch released even through the panic"
            );
            for &s in &[0usize, 2] {
                let d = &done[s];
                assert_eq!(d.verdict, Verdict::Healthy);
                let mut x = DistVec::zeros(layout.clone(), c.rank());
                let res = pcg(&c, &op, &rhs(s), &mut x, None, 1e-10, 400);
                assert_eq!(d.x.vals, x.vals, "ticket {s} diverged from solo solve");
                assert_eq!(d.result.residuals, res.residuals);
            }
        });
    }
}
