//! Concurrent solve sessions: amortize every α term across K clients.
//!
//! A solver service sees many requests against operators that share one
//! sparsity pattern (time steps, parameter sweeps, concurrent users of
//! the same mesh).  Two pieces turn that sharing into saved latency:
//!
//! - [`SessionCache`] keys retained hierarchies by
//!   `(pattern hash, eq_limit, algorithm)`.  A client whose operator
//!   matches a cached pattern skips the whole symbolic phase — the cache
//!   hands back the [`HierarchyRefresher`] and replays only the numeric
//!   halves for the client's values ([`HierarchyRefresher::refresh`]),
//!   so concurrent clients share one set of plans, gathered patterns and
//!   preallocated coarse operators.
//! - [`RequestQueue`] accumulates up to K pending right-hand sides (with
//!   a flush deadline so a lone request is never starved) and dispatches
//!   them as ONE blocked solve ([`crate::mg::pcg_multi`]): one K-wide
//!   matvec, one K-wide V-cycle and one K-element reduction per dot
//!   product, instead of K of each.  Column `j` of the batch is bitwise
//!   the solve the client would have gotten alone.
//!
//! The pattern hash is collective: each rank hashes its local structure
//! (diag/offd `rowptr`+`cols`, `garray`, row/col ranges) with FNV-1a,
//! then the per-rank digests are allgathered and folded in rank order,
//! so every rank derives the same key and cache decisions never diverge
//! across the communicator.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use crate::dist::{Comm, DistCsr, DistMultiVec, DistOperator, DistVec};
use crate::mem::{Cat, Charge, MemTracker};
use crate::mg::{
    build_hierarchy, pcg_multi, Coarsening, HierarchyConfig, MgOpts, MgPreconditioner, SolveResult,
};
use crate::ptap::Algo;
use crate::reuse::HierarchyRefresher;

/// FNV-1a 64-bit, streamed a word at a time.
struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    fn u64(&mut self, v: u64) {
        for b in v.to_le_bytes() {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    fn u32s(&mut self, vs: &[u32]) {
        for &v in vs {
            self.u64(v as u64);
        }
    }
}

/// Collective structural digest of a distributed operator: hashes the
/// sparsity pattern and partitioning, NOT the values, so refreshing an
/// operator's coefficients keeps its key.  Every rank returns the same
/// digest (one 8-byte allgather).
pub fn pattern_hash(comm: &Comm, a: &DistCsr) -> u64 {
    let mut h = Fnv::new();
    h.u64(a.row_layout.global_size() as u64);
    h.u64(a.col_layout.global_size() as u64);
    h.u64(a.row_begin() as u64);
    h.u64(a.col_begin() as u64);
    h.u32s(&a.diag.rowptr);
    h.u32s(&a.diag.cols);
    h.u32s(&a.offd.rowptr);
    h.u32s(&a.offd.cols);
    for &g in &a.garray {
        h.u64(g);
    }
    let mut g = Fnv::new();
    for v in comm.all_u64(h.0) {
        g.u64(v);
    }
    g.0
}

/// What a cached hierarchy is keyed by: the operator's structural digest
/// plus the two build knobs that change the retained symbolic state.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SessionKey {
    pub pattern_hash: u64,
    pub eq_limit: Option<usize>,
    pub algo: Algo,
}

/// Hierarchy cache for concurrent solve sessions.  `checkout` is
/// collective; every rank takes the same hit/miss/evict path because the
/// key is derived from the collective [`pattern_hash`].
#[derive(Default)]
pub struct SessionCache {
    entries: HashMap<SessionKey, HierarchyRefresher>,
    /// Checkouts served from a retained hierarchy (symbolic phase skipped).
    pub hits: u64,
    /// Checkouts that had to build from scratch.
    pub misses: u64,
    /// Entries dropped because a client re-presented the same
    /// `(eq_limit, algo)` configuration with a different pattern — the
    /// stale pattern's plans can never be refreshed into the new one.
    pub evictions: u64,
}

impl SessionCache {
    pub fn new() -> SessionCache {
        SessionCache::default()
    }

    /// Retained hierarchies currently cached.
    pub fn entry_count(&self) -> usize {
        self.entries.len()
    }

    /// Hand back a ready-to-apply refresher for `a0` (collective).  On a
    /// hit the cached hierarchy absorbs `a0`'s values through the
    /// numeric-only refresh walk; on a miss a `retain`-mode hierarchy is
    /// built (evicting any entry with the same configuration but a stale
    /// pattern).  Either way the returned preconditioner is bit-identical
    /// to one freshly built on `a0`.  Returns `(refresher, was_hit)`.
    pub fn checkout(
        &mut self,
        comm: &Comm,
        a0: &DistCsr,
        coarsening: &Coarsening,
        cfg: HierarchyConfig,
        opts: MgOpts,
        tracker: &MemTracker,
    ) -> (&mut HierarchyRefresher, bool) {
        let key = SessionKey {
            pattern_hash: pattern_hash(comm, a0),
            eq_limit: cfg.eq_limit,
            algo: cfg.algo,
        };
        let hit = self.entries.contains_key(&key);
        if hit {
            self.hits += 1;
            crate::obs::metrics::add(crate::obs::Subsys::Session, "cache.hit", 1);
        } else {
            self.misses += 1;
            crate::obs::metrics::add(crate::obs::Subsys::Session, "cache.miss", 1);
            let stale: Vec<SessionKey> = self
                .entries
                .keys()
                .filter(|k| k.algo == key.algo && k.eq_limit == key.eq_limit)
                .copied()
                .collect();
            for s in stale {
                self.entries.remove(&s);
                self.evictions += 1;
            }
            let mut cfg = cfg;
            cfg.retain = true;
            let h = build_hierarchy(comm, a0.clone(), coarsening, cfg, tracker);
            self.entries.insert(key, HierarchyRefresher::new(comm, h, opts, tracker));
        }
        let r = self.entries.get_mut(&key).unwrap();
        if hit {
            r.refresh(comm, a0);
        }
        (r, hit)
    }
}

/// One completed request out of a flushed batch.
#[derive(Debug, Clone)]
pub struct QueuedSolve {
    /// The ticket `submit` returned for this right-hand side.
    pub ticket: u64,
    pub x: DistVec,
    pub result: SolveResult,
    /// Seconds this request sat in the queue before its batch dispatched.
    pub queue_wait: f64,
    /// Seconds from `submit` to batch completion (queue wait + solve).
    pub e2e: f64,
    /// Health verdict from this column's residual history
    /// ([`crate::obs::health::residual_verdict`] under the default
    /// policy).  A `Diverging` ticket should be reported to its client as
    /// an error; the batch's other columns are unaffected.
    pub verdict: crate::obs::health::Verdict,
}

/// One pending right-hand side with its latency bookkeeping.
struct Pending {
    ticket: u64,
    b: DistVec,
    submitted: Instant,
    /// Trace timestamp at submit (0 when tracing was off at submit).
    submit_us: u64,
}

/// Accumulates pending right-hand sides and dispatches them as one
/// blocked solve.  A flush fires when the batch is full (`capacity`
/// requests) or when the oldest pending request has waited past the
/// deadline — whichever comes first — so latency stays bounded while
/// every α term in the solve is amortized across the batch.
pub struct RequestQueue {
    capacity: usize,
    deadline: Duration,
    pending: Vec<Pending>,
    next_ticket: u64,
    oldest: Option<Instant>,
    /// Batches dispatched.
    pub flushes: u64,
    /// Batches dispatched below capacity (deadline or forced flush).
    pub partial_flushes: u64,
}

impl RequestQueue {
    pub fn new(capacity: usize, deadline: Duration) -> RequestQueue {
        assert!(capacity >= 1, "batch capacity must be at least 1");
        RequestQueue {
            capacity,
            deadline,
            pending: Vec::new(),
            next_ticket: 0,
            oldest: None,
            flushes: 0,
            partial_flushes: 0,
        }
    }

    /// Enqueue one right-hand side; returns the ticket that identifies
    /// it in the flushed batch.
    pub fn submit(&mut self, b: DistVec) -> u64 {
        let ticket = self.next_ticket;
        self.next_ticket += 1;
        if self.pending.is_empty() {
            self.oldest = Some(Instant::now());
        }
        let submit_us = if crate::obs::enabled() {
            crate::obs::instant(crate::obs::Subsys::Session, "enqueue", ticket);
            crate::obs::now_us()
        } else {
            0
        };
        self.pending.push(Pending { ticket, b, submitted: Instant::now(), submit_us });
        crate::obs::metrics::add(crate::obs::Subsys::Session, "requests", 1);
        crate::obs::metrics::gauge(
            crate::obs::Subsys::Session,
            "queue.depth",
            self.pending.len() as u64,
        );
        ticket
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// True when the batch is full or the oldest request has waited past
    /// the flush deadline.
    pub fn should_flush(&self) -> bool {
        !self.pending.is_empty()
            && (self.pending.len() >= self.capacity
                || self.oldest.is_some_and(|t| t.elapsed() >= self.deadline))
    }

    /// Dispatch every pending request as ONE blocked PCG solve
    /// (collective).  The K stacked right-hand sides pay one K-wide
    /// matvec, one K-wide preconditioner cycle and one K-element
    /// reduction per dot product; each returned column is bitwise the
    /// solve its client would have gotten alone.  The transient K-wide
    /// block is charged to [`Cat::MultiVec`] for the duration of the
    /// solve.
    pub fn flush(
        &mut self,
        comm: &Comm,
        a: &dyn DistOperator,
        pc: Option<&mut MgPreconditioner>,
        rtol: f64,
        max_iters: usize,
        tracker: &MemTracker,
    ) -> Vec<QueuedSolve> {
        if self.pending.is_empty() {
            return Vec::new();
        }
        self.flushes += 1;
        if self.pending.len() < self.capacity {
            self.partial_flushes += 1;
        }
        let pending = std::mem::take(&mut self.pending);
        self.oldest = None;
        crate::obs::instant(
            crate::obs::Subsys::Session,
            "flush.decide",
            pending.len() as u64,
        );
        crate::obs::metrics::gauge(crate::obs::Subsys::Session, "queue.depth", 0);
        let deadline_secs = self.deadline.as_secs_f64();

        let dispatch_start = Instant::now();
        let cols: Vec<&DistVec> = pending.iter().map(|p| &p.b).collect();
        let b = DistMultiVec::from_columns(&cols);
        let mut x = DistMultiVec::zeros(b.layout.clone(), b.rank, b.k);
        let _scratch = Charge::new(tracker, Cat::MultiVec, b.bytes() + x.bytes());
        let results = {
            let _sp = crate::obs::span(crate::obs::Subsys::Session, "dispatch", b.k as u64);
            pcg_multi(comm, a, &b, &mut x, pc, rtol, max_iters)
        };
        let dispatch_end = Instant::now();
        pending
            .into_iter()
            .zip(results)
            .enumerate()
            .map(|(j, (p, result))| {
                if crate::obs::enabled() && p.submit_us != 0 {
                    crate::obs::complete(
                        crate::obs::Subsys::Session,
                        "request",
                        p.ticket,
                        p.submit_us,
                        crate::obs::now_us(),
                    );
                }
                let queue_wait = (dispatch_start - p.submitted).as_secs_f64();
                let e2e = (dispatch_end - p.submitted).as_secs_f64();
                let verdict = crate::obs::health::residual_verdict(
                    &result.residuals,
                    result.converged,
                    &crate::obs::health::HealthPolicy::default(),
                );
                if crate::obs::metrics::enabled() {
                    crate::obs::metrics::observe(
                        crate::obs::Subsys::Session,
                        "queue.wait_us",
                        (queue_wait * 1e6) as u64,
                    );
                    crate::obs::metrics::observe(
                        crate::obs::Subsys::Session,
                        "request.e2e_us",
                        (e2e * 1e6) as u64,
                    );
                    if queue_wait >= deadline_secs {
                        crate::obs::metrics::add(
                            crate::obs::Subsys::Session,
                            "deadline.miss",
                            1,
                        );
                    }
                    if verdict == crate::obs::health::Verdict::Diverging {
                        crate::obs::metrics::add(
                            crate::obs::Subsys::Session,
                            "request.failed",
                            1,
                        );
                    }
                }
                QueuedSolve {
                    ticket: p.ticket,
                    x: x.column(j),
                    result,
                    queue_wait,
                    e2e,
                    verdict,
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{CsrOperator, DistSpmv, World};
    use crate::gen::{grid_laplacian, Grid3};
    use crate::mg::{geometric_chain, pcg};

    fn scaled_values(a: &DistCsr, factor: f64) -> DistCsr {
        let mut m = a.clone();
        for v in m.diag.vals.iter_mut().chain(m.offd.vals.iter_mut()) {
            *v *= factor;
        }
        m
    }

    #[test]
    fn identical_pattern_shares_hierarchy() {
        let w = World::new(2);
        w.run(|c| {
            let grids = geometric_chain(Grid3::cube(3), 3);
            let coarsening = Coarsening::Geometric { grids: grids.clone() };
            let a = grid_laplacian(grids[0], c.rank(), c.size());
            let tracker = MemTracker::new();
            let cfg = HierarchyConfig::default();
            let mut cache = SessionCache::new();

            let (_, hit1) =
                cache.checkout(&c, &a, &coarsening, cfg, MgOpts::default(), &tracker);
            assert!(!hit1, "first client must build");
            // second client: same pattern, different coefficient values
            let a2 = scaled_values(&a, 2.0);
            let (_, hit2) =
                cache.checkout(&c, &a2, &coarsening, cfg, MgOpts::default(), &tracker);
            assert!(hit2, "same pattern must reuse the retained hierarchy");
            assert_eq!(cache.entry_count(), 1);
            assert_eq!((cache.hits, cache.misses, cache.evictions), (1, 1, 0));
        });
    }

    #[test]
    fn pattern_change_evicts_stale_entry() {
        let w = World::new(2);
        w.run(|c| {
            let tracker = MemTracker::new();
            let cfg = HierarchyConfig::default();
            let mut cache = SessionCache::new();

            let grids3 = geometric_chain(Grid3::cube(3), 2);
            let c3 = Coarsening::Geometric { grids: grids3.clone() };
            let a3 = grid_laplacian(grids3[0], c.rank(), c.size());
            cache.checkout(&c, &a3, &c3, cfg, MgOpts::default(), &tracker);

            // same (algo, eq_limit) but a different mesh: the old plans
            // can never be refreshed into this pattern, so it is evicted
            let grids4 = geometric_chain(Grid3::cube(4), 2);
            let c4 = Coarsening::Geometric { grids: grids4.clone() };
            let a4 = grid_laplacian(grids4[0], c.rank(), c.size());
            let (_, hit) = cache.checkout(&c, &a4, &c4, cfg, MgOpts::default(), &tracker);
            assert!(!hit);
            assert_eq!(cache.entry_count(), 1, "stale pattern must be evicted");
            assert_eq!((cache.hits, cache.misses, cache.evictions), (0, 2, 1));
        });
    }

    #[test]
    fn refresh_then_solve_matches_fresh_build() {
        let w = World::new(2);
        w.run(|c| {
            let grids = geometric_chain(Grid3::cube(3), 3);
            let coarsening = Coarsening::Geometric { grids: grids.clone() };
            let a = grid_laplacian(grids[0], c.rank(), c.size());
            let a2 = scaled_values(&a, 1.5);
            let layout = a.row_layout.clone();
            let tracker = MemTracker::new();
            let cfg = HierarchyConfig::default();
            let b = DistVec::from_fn(layout.clone(), c.rank(), |g| ((g * 7 % 5) as f64) - 2.0);

            // cached path: build on a, then hit with a2's values
            let mut cache = SessionCache::new();
            cache.checkout(&c, &a, &coarsening, cfg, MgOpts::default(), &tracker);
            let (r, hit) =
                cache.checkout(&c, &a2, &coarsening, cfg, MgOpts::default(), &tracker);
            assert!(hit);
            let spmv = DistSpmv::new(&c, &a2);
            let op = CsrOperator::new(&a2, &spmv);
            let mut x_cached = DistVec::zeros(layout.clone(), c.rank());
            let res_cached = pcg(&c, &op, &b, &mut x_cached, Some(r.pc()), 1e-8, 60);

            // fresh path: build directly on a2
            let mut cfg_fresh = cfg;
            cfg_fresh.retain = true;
            let h = build_hierarchy(&c, a2.clone(), &coarsening, cfg_fresh, &tracker);
            let mut fresh = HierarchyRefresher::new(&c, h, MgOpts::default(), &tracker);
            let mut x_fresh = DistVec::zeros(layout, c.rank());
            let res_fresh = pcg(&c, &op, &b, &mut x_fresh, Some(fresh.pc()), 1e-8, 60);

            assert!(res_cached.converged && res_fresh.converged);
            assert_eq!(
                res_cached.residuals, res_fresh.residuals,
                "refreshed hierarchy must solve bit-identically to a fresh build"
            );
            assert_eq!(x_cached.vals, x_fresh.vals);
        });
    }

    #[test]
    fn queue_flushes_at_capacity_and_matches_scalar_solves() {
        let w = World::new(2);
        w.run(|c| {
            let a = grid_laplacian(Grid3::cube(4), c.rank(), c.size());
            let spmv = DistSpmv::new(&c, &a);
            let op = CsrOperator::new(&a, &spmv);
            let layout = a.row_layout.clone();
            let tracker = MemTracker::new();
            let rhs = |s: usize| {
                DistVec::from_fn(layout.clone(), c.rank(), |g| {
                    ((g as f64) * 0.1 + s as f64).cos()
                })
            };

            let mut q = RequestQueue::new(3, Duration::from_secs(3600));
            for s in 0..3 {
                assert!(!q.should_flush());
                let t = q.submit(rhs(s));
                assert_eq!(t, s as u64);
            }
            assert!(q.should_flush(), "full batch must flush");
            let done = q.flush(&c, &op, None, 1e-10, 400, &tracker);
            assert_eq!(done.len(), 3);
            assert!(q.is_empty());
            assert_eq!((q.flushes, q.partial_flushes), (1, 0));
            assert_eq!(tracker.current(Cat::MultiVec), 0, "block scratch released");
            assert!(tracker.peak(Cat::MultiVec) > 0, "block scratch was charged");

            // each batched column is bitwise the solo solve
            for (s, d) in done.iter().enumerate() {
                assert_eq!(d.ticket, s as u64);
                assert!(d.queue_wait >= 0.0 && d.e2e >= d.queue_wait, "latency ordering");
                let mut x = DistVec::zeros(layout.clone(), c.rank());
                let res = pcg(&c, &op, &rhs(s), &mut x, None, 1e-10, 400);
                assert_eq!(d.x.vals, x.vals, "column {s} diverged from solo solve");
                assert_eq!(d.result.residuals, res.residuals);
                assert_eq!(d.result.iterations, res.iterations);
            }
        });
    }

    #[test]
    fn queue_deadline_flushes_single_request() {
        let w = World::new(1);
        w.run(|c| {
            let a = grid_laplacian(Grid3::cube(3), c.rank(), c.size());
            let spmv = DistSpmv::new(&c, &a);
            let op = CsrOperator::new(&a, &spmv);
            let layout = a.row_layout.clone();
            let tracker = MemTracker::new();
            let b = DistVec::from_fn(layout.clone(), c.rank(), |g| (g as f64 * 0.37).sin());

            let mut q = RequestQueue::new(8, Duration::ZERO);
            q.submit(b.clone());
            assert!(q.should_flush(), "expired deadline must flush a lone request");
            let done = q.flush(&c, &op, None, 1e-10, 400, &tracker);
            assert_eq!(done.len(), 1);
            assert_eq!((q.flushes, q.partial_flushes), (1, 1));

            let mut x = DistVec::zeros(layout, c.rank());
            let res = pcg(&c, &op, &b, &mut x, None, 1e-10, 400);
            assert_eq!(done[0].x.vals, x.vals, "K=1 batch must equal the scalar path");
            assert_eq!(done[0].result.residuals, res.residuals);
        });
    }
}
