//! Batching layer between the block numeric phase and the compiled
//! kernel: collects elementary triple products `plᵀ·a·pr` into fixed-shape
//! chunks, pads the tail with zero blocks (zero in → zero out, harmless
//! for accumulation), executes, and hands each result block back with its
//! caller-supplied tag.

use crate::mat::dense::{block_matvec_add, block_triple_product_add};

use super::KernelRuntime;

/// Which engine evaluates the batched triple products.
#[derive(Clone, Copy)]
pub enum BlockBackend<'rt> {
    /// Pure-rust scalar loop (f64) — fallback and correctness oracle.
    Native,
    /// Compiled Pallas kernel through PJRT (f32 on the wire).
    Pjrt(&'rt KernelRuntime),
}

impl<'rt> BlockBackend<'rt> {
    pub fn name(&self) -> &'static str {
        match self {
            BlockBackend::Native => "native",
            BlockBackend::Pjrt(_) => "pjrt",
        }
    }
}

/// Accumulates (pl, a, pr, tag) quadruples and flushes them through the
/// backend in compiled-batch-size chunks.
pub struct TripleBatcher<'rt> {
    backend: BlockBackend<'rt>,
    b: usize,
    /// chunk capacity (the artifact's compiled batch, or a native tile)
    cap: usize,
    pl: Vec<f32>,
    a: Vec<f32>,
    pr: Vec<f32>,
    // f64 copies for the native path (no precision loss)
    pl64: Vec<f64>,
    a64: Vec<f64>,
    pr64: Vec<f64>,
    tags: Vec<u64>,
    /// Count of kernel invocations (perf accounting).
    pub flushes: u64,
    /// Total triples pushed.
    pub triples: u64,
}

impl<'rt> TripleBatcher<'rt> {
    pub fn new(backend: BlockBackend<'rt>, b: usize) -> Self {
        let cap = match backend {
            BlockBackend::Native => 256,
            BlockBackend::Pjrt(rt) => rt
                .batch_of("block_ptap", b)
                .expect("no block_ptap artifact for this block size"),
        };
        let s = cap * b * b;
        TripleBatcher {
            backend,
            b,
            cap,
            pl: Vec::with_capacity(s),
            a: Vec::with_capacity(s),
            pr: Vec::with_capacity(s),
            pl64: Vec::with_capacity(s),
            a64: Vec::with_capacity(s),
            pr64: Vec::with_capacity(s),
            tags: Vec::with_capacity(cap),
            flushes: 0,
            triples: 0,
        }
    }

    pub fn block_size(&self) -> usize {
        self.b
    }

    pub fn bytes(&self) -> u64 {
        ((self.pl.capacity() + self.a.capacity() + self.pr.capacity()) * 4
            + (self.pl64.capacity() + self.a64.capacity() + self.pr64.capacity()) * 8
            + self.tags.capacity() * 8) as u64
    }

    /// Queue one triple product; flushes into `sink(tag, block)` when the
    /// chunk fills.  `sink` receives the `b*b` result block to accumulate.
    pub fn push<F: FnMut(u64, &[f64]) + ?Sized>(
        &mut self,
        pl: &[f64],
        a: &[f64],
        pr: &[f64],
        tag: u64,
        sink: &mut F,
    ) {
        debug_assert_eq!(a.len(), self.b * self.b);
        match self.backend {
            BlockBackend::Native => {
                self.pl64.extend_from_slice(pl);
                self.a64.extend_from_slice(a);
                self.pr64.extend_from_slice(pr);
            }
            BlockBackend::Pjrt(_) => {
                self.pl.extend(pl.iter().map(|&v| v as f32));
                self.a.extend(a.iter().map(|&v| v as f32));
                self.pr.extend(pr.iter().map(|&v| v as f32));
            }
        }
        self.tags.push(tag);
        self.triples += 1;
        if self.tags.len() == self.cap {
            self.flush(sink);
        }
    }

    /// Evaluate everything queued (padding the tail) and drain results.
    pub fn flush<F: FnMut(u64, &[f64]) + ?Sized>(&mut self, sink: &mut F) {
        if self.tags.is_empty() {
            return;
        }
        let bb = self.b * self.b;
        let n = self.tags.len();
        self.flushes += 1;
        let _sp = crate::obs::span(crate::obs::Subsys::Batch, "triple.flush", n as u64);
        crate::obs::metrics::add(crate::obs::Subsys::Batch, "triple.flushes", 1);
        crate::obs::metrics::add(crate::obs::Subsys::Batch, "triple.products", n as u64);
        match self.backend {
            BlockBackend::Native => {
                let mut out = vec![0.0f64; bb];
                for k in 0..n {
                    out.fill(0.0);
                    block_triple_product_add(
                        self.b,
                        &self.pl64[k * bb..(k + 1) * bb],
                        &self.a64[k * bb..(k + 1) * bb],
                        &self.pr64[k * bb..(k + 1) * bb],
                        &mut out,
                    );
                    sink(self.tags[k], &out);
                }
                self.pl64.clear();
                self.a64.clear();
                self.pr64.clear();
            }
            BlockBackend::Pjrt(rt) => {
                // zero-pad to the compiled batch
                let full = self.cap * bb;
                self.pl.resize(full, 0.0);
                self.a.resize(full, 0.0);
                self.pr.resize(full, 0.0);
                let res = rt
                    .run_block_ptap(self.b, &self.pl, &self.a, &self.pr)
                    .expect("kernel execution failed");
                let mut out = vec![0.0f64; bb];
                for k in 0..n {
                    for (o, &v) in out.iter_mut().zip(&res[k * bb..(k + 1) * bb]) {
                        *o = v as f64;
                    }
                    sink(self.tags[k], &out);
                }
                self.pl.clear();
                self.a.clear();
                self.pr.clear();
            }
        }
        self.tags.clear();
    }
}

/// Batched block mat-vec `y_tag += a·x` — the SpMV twin of
/// [`TripleBatcher`]: block-level multiplies queue into fixed-shape
/// chunks and run as one kernel launch per chunk (native f64 loop, or
/// the compiled `block_spmv` artifact through PJRT).
pub struct SpmvBatcher<'rt> {
    backend: BlockBackend<'rt>,
    b: usize,
    /// chunk capacity (the artifact's compiled batch, or a native tile)
    cap: usize,
    a: Vec<f32>,
    x: Vec<f32>,
    // f64 copies for the native path (no precision loss)
    a64: Vec<f64>,
    x64: Vec<f64>,
    tags: Vec<u64>,
    /// Count of kernel invocations (perf accounting).
    pub flushes: u64,
    /// Total block multiplies pushed.
    pub mults: u64,
}

impl<'rt> SpmvBatcher<'rt> {
    pub fn new(backend: BlockBackend<'rt>, b: usize) -> Self {
        let cap = match backend {
            BlockBackend::Native => 256,
            BlockBackend::Pjrt(rt) => rt
                .batch_of("block_spmv", b)
                .expect("no block_spmv artifact for this block size"),
        };
        SpmvBatcher {
            backend,
            b,
            cap,
            a: Vec::with_capacity(cap * b * b),
            x: Vec::with_capacity(cap * b),
            a64: Vec::with_capacity(cap * b * b),
            x64: Vec::with_capacity(cap * b),
            tags: Vec::with_capacity(cap),
            flushes: 0,
            mults: 0,
        }
    }

    pub fn block_size(&self) -> usize {
        self.b
    }

    /// Chunk capacity: block multiplies folded into one kernel launch.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn bytes(&self) -> u64 {
        ((self.a.capacity() + self.x.capacity()) * 4
            + (self.a64.capacity() + self.x64.capacity()) * 8
            + self.tags.capacity() * 8) as u64
    }

    /// Queue one `b×b · b` multiply; flushes into `sink(tag, y_block)`
    /// when the chunk fills.  `sink` receives the length-`b` product to
    /// accumulate.
    pub fn push<F: FnMut(u64, &[f64]) + ?Sized>(
        &mut self,
        a: &[f64],
        x: &[f64],
        tag: u64,
        sink: &mut F,
    ) {
        debug_assert_eq!(a.len(), self.b * self.b);
        debug_assert_eq!(x.len(), self.b);
        match self.backend {
            BlockBackend::Native => {
                self.a64.extend_from_slice(a);
                self.x64.extend_from_slice(x);
            }
            BlockBackend::Pjrt(_) => {
                self.a.extend(a.iter().map(|&v| v as f32));
                self.x.extend(x.iter().map(|&v| v as f32));
            }
        }
        self.tags.push(tag);
        self.mults += 1;
        if self.tags.len() == self.cap {
            self.flush(sink);
        }
    }

    /// Evaluate everything queued (padding the tail) and drain results.
    pub fn flush<F: FnMut(u64, &[f64]) + ?Sized>(&mut self, sink: &mut F) {
        if self.tags.is_empty() {
            return;
        }
        let b = self.b;
        let bb = b * b;
        let n = self.tags.len();
        self.flushes += 1;
        let _sp = crate::obs::span(crate::obs::Subsys::Batch, "spmv.flush", n as u64);
        crate::obs::metrics::add(crate::obs::Subsys::Batch, "spmv.flushes", 1);
        crate::obs::metrics::add(crate::obs::Subsys::Batch, "spmv.mults", n as u64);
        match self.backend {
            BlockBackend::Native => {
                let mut out = vec![0.0f64; b];
                for k in 0..n {
                    out.fill(0.0);
                    block_matvec_add(
                        b,
                        &self.a64[k * bb..(k + 1) * bb],
                        &self.x64[k * b..(k + 1) * b],
                        &mut out,
                    );
                    sink(self.tags[k], &out);
                }
                self.a64.clear();
                self.x64.clear();
            }
            BlockBackend::Pjrt(rt) => {
                // zero-pad to the compiled batch
                self.a.resize(self.cap * bb, 0.0);
                self.x.resize(self.cap * b, 0.0);
                let res = rt
                    .run_block_spmv(b, &self.a, &self.x)
                    .expect("kernel execution failed");
                let mut out = vec![0.0f64; b];
                for k in 0..n {
                    for (o, &v) in out.iter_mut().zip(&res[k * b..(k + 1) * b]) {
                        *o = v as f64;
                    }
                    sink(self.tags[k], &out);
                }
                self.a.clear();
                self.x.clear();
            }
        }
        self.tags.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn native_batcher_matches_direct_product() {
        let b = 3;
        let mut rng = Rng::new(5);
        let mut batcher = TripleBatcher::new(BlockBackend::Native, b);
        let mk = |rng: &mut Rng| (0..b * b).map(|_| rng.normal()).collect::<Vec<f64>>();
        let mut results: Vec<(u64, Vec<f64>)> = Vec::new();
        let mut want: Vec<Vec<f64>> = Vec::new();
        for tag in 0..700u64 {
            let (pl, a, pr) = (mk(&mut rng), mk(&mut rng), mk(&mut rng));
            let mut w = vec![0.0; b * b];
            block_triple_product_add(b, &pl, &a, &pr, &mut w);
            want.push(w);
            let mut sink = |t: u64, blk: &[f64]| results.push((t, blk.to_vec()));
            batcher.push(&pl, &a, &pr, tag, &mut sink);
        }
        let mut sink = |t: u64, blk: &[f64]| results.push((t, blk.to_vec()));
        batcher.flush(&mut sink);
        assert_eq!(results.len(), 700);
        assert_eq!(batcher.triples, 700);
        assert!(batcher.flushes >= 2, "multi-chunk path must be exercised");
        for (tag, blk) in &results {
            let w = &want[*tag as usize];
            for (x, y) in blk.iter().zip(w) {
                assert!((x - y).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn native_spmv_batcher_matches_direct_matvec() {
        let b = 4;
        let mut rng = Rng::new(9);
        let mut batcher = SpmvBatcher::new(BlockBackend::Native, b);
        let mut results: Vec<(u64, Vec<f64>)> = Vec::new();
        let mut want: Vec<Vec<f64>> = Vec::new();
        for tag in 0..600u64 {
            let a: Vec<f64> = (0..b * b).map(|_| rng.normal()).collect();
            let x: Vec<f64> = (0..b).map(|_| rng.normal()).collect();
            let mut w = vec![0.0; b];
            block_matvec_add(b, &a, &x, &mut w);
            want.push(w);
            let mut sink = |t: u64, blk: &[f64]| results.push((t, blk.to_vec()));
            batcher.push(&a, &x, tag, &mut sink);
        }
        let mut sink = |t: u64, blk: &[f64]| results.push((t, blk.to_vec()));
        batcher.flush(&mut sink);
        assert_eq!(results.len(), 600);
        assert_eq!(batcher.mults, 600);
        assert!(batcher.flushes >= 2, "multi-chunk path must be exercised");
        for (tag, blk) in &results {
            for (x, y) in blk.iter().zip(&want[*tag as usize]) {
                assert!((x - y).abs() < 1e-12);
            }
        }
    }
}
