//! PJRT runtime: loads the AOT-compiled HLO text artifacts produced by
//! `python/compile/aot.py` and serves them to the Layer-3 hot path.
//!
//! Python is build-time only: after `make artifacts` the rust binary is
//! self-contained — this module parses HLO **text** (the 64-bit-id-safe
//! interchange, see DESIGN.md / aot recipe), compiles it once on the PJRT
//! CPU client, and executes batched block kernels from the numeric phase.
//!
//! The PJRT client itself sits behind the off-by-default `pjrt` cargo
//! feature (it needs the `xla` crate, unavailable offline).  Without the
//! feature a stub [`KernelRuntime`] reports the missing feature from its
//! `load*` constructors and [`BlockBackend::Native`] carries the block
//! numeric path, so every consumer compiles and runs unchanged.

mod batcher;
mod manifest;
#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(not(feature = "pjrt"))]
mod stub;

pub use batcher::{BlockBackend, TripleBatcher};
pub use manifest::{Manifest, ManifestEntry};
#[cfg(feature = "pjrt")]
pub use pjrt::KernelRuntime;
#[cfg(not(feature = "pjrt"))]
pub use stub::KernelRuntime;

/// Default artifact directory relative to the repo root.
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";
