//! PJRT runtime: loads the AOT-compiled HLO text artifacts produced by
//! `python/compile/aot.py` and serves them to the Layer-3 hot path.
//!
//! Python is build-time only: after `make artifacts` the rust binary is
//! self-contained — this module parses HLO **text** (the 64-bit-id-safe
//! interchange, see DESIGN.md / aot recipe), compiles it once on the PJRT
//! CPU client, and executes batched block kernels from the numeric phase.

mod batcher;
mod pjrt;

pub use batcher::{BlockBackend, TripleBatcher};
pub use pjrt::{KernelRuntime, Manifest, ManifestEntry};

/// Default artifact directory relative to the repo root.
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";
