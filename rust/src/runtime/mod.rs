//! PJRT runtime: loads the AOT-compiled HLO text artifacts produced by
//! `python/compile/aot.py` and serves them to the Layer-3 hot path.
//!
//! Python is build-time only: after `make artifacts` the rust binary is
//! self-contained — this module parses HLO **text** (the 64-bit-id-safe
//! interchange, see DESIGN.md / aot recipe), compiles it once on the PJRT
//! CPU client, and executes batched block kernels from the numeric phase.
//!
//! Two cargo features stage the accelerator seam:
//!
//! - `pjrt` — the seam itself: batch sizes, artifact manifests, and every
//!   consumer's `BlockBackend::Pjrt` code path compile (CI builds this
//!   offline), but `KernelRuntime::load*` still report the client as
//!   unavailable;
//! - `pjrt-xla` (implies `pjrt`) — additionally compiles the real PJRT
//!   CPU client, which needs the `xla` crate (unavailable offline).
//!
//! Without `pjrt-xla` the stub [`KernelRuntime`] reports the missing
//! client from its `load*` constructors and [`BlockBackend::Native`]
//! carries the block numeric path, so every consumer compiles and runs
//! unchanged.

mod batcher;
mod manifest;
#[cfg(feature = "pjrt-xla")]
mod pjrt;
#[cfg(not(feature = "pjrt-xla"))]
mod stub;

pub use batcher::{BlockBackend, SpmvBatcher, TripleBatcher};
pub use manifest::{Manifest, ManifestEntry};
#[cfg(feature = "pjrt-xla")]
pub use pjrt::KernelRuntime;
#[cfg(not(feature = "pjrt-xla"))]
pub use stub::KernelRuntime;

/// Default artifact directory relative to the repo root.
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";
