//! Artifact manifest parsing and discovery — pure std, compiled with or
//! without the `pjrt` feature so callers can always enumerate artifacts
//! (and skip cleanly when there are none).

use std::path::{Path, PathBuf};

use crate::bail;
use crate::util::error::{Context, Result};

/// One row of `artifacts/manifest.tsv`.
#[derive(Debug, Clone)]
pub struct ManifestEntry {
    pub entry: String,
    pub file: String,
    pub block: usize,
    pub batch: usize,
}

/// Parsed artifact manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let mut entries = Vec::new();
        for line in text.lines() {
            if line.starts_with('#') || line.trim().is_empty() {
                continue;
            }
            let f: Vec<&str> = line.split('\t').collect();
            if f.len() != 4 {
                bail!("malformed manifest line: {line:?}");
            }
            entries.push(ManifestEntry {
                entry: f[0].to_string(),
                file: f[1].to_string(),
                block: f[2].parse()?,
                batch: f[3].parse()?,
            });
        }
        Ok(Manifest { entries })
    }
}

/// Locate the artifact directory, searching upward from the cwd (lets
/// examples/benches run from any directory in the repo).
pub(super) fn find_dir() -> Result<PathBuf> {
    let mut dir = std::env::current_dir()?;
    loop {
        let cand = dir.join(super::DEFAULT_ARTIFACT_DIR);
        if cand.join("manifest.tsv").exists() {
            return Ok(cand);
        }
        if !dir.pop() {
            bail!("no artifacts/manifest.tsv found — run `make artifacts`");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_and_rejects_garbage() {
        let dir = std::env::temp_dir().join("gptap_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.tsv"),
            "# entry\tfile\tblock\tbatch\nblock_ptap\tf.hlo.txt\t8\t256\n",
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 1);
        assert_eq!(m.entries[0].block, 8);
        std::fs::write(dir.join("manifest.tsv"), "bad line\n").unwrap();
        assert!(Manifest::load(&dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
