//! Stub kernel runtime for builds without the `pjrt-xla` feature.
//!
//! The type exists so `BlockBackend::Pjrt` and every call site compile
//! unchanged, but it can never be constructed: `load*` report the missing
//! feature and callers fall back to [`super::BlockBackend::Native`] (or
//! skip, as `tests/integration_runtime.rs` and `selfcheck` do).

use std::convert::Infallible;
use std::path::{Path, PathBuf};

use crate::format_err;
use crate::util::error::Result;

use super::manifest::{self, ManifestEntry};

/// Uninhabited stand-in for the PJRT runtime (see module docs).
pub struct KernelRuntime {
    never: Infallible,
}

impl KernelRuntime {
    fn unavailable<T>(dir: &Path) -> Result<T> {
        Err(format_err!(
            "artifacts found at {} but this binary was built without the `pjrt-xla` feature \
             (rebuild with --features pjrt-xla and the xla dependency); the native backend \
             remains available",
            dir.display()
        ))
    }

    /// Always fails: the PJRT client is not compiled in.
    pub fn load(dir: &Path) -> Result<Self> {
        Self::unavailable(dir)
    }

    /// Always fails: the PJRT client is not compiled in.
    pub fn load_filtered(dir: &Path, pred: impl Fn(&ManifestEntry) -> bool) -> Result<Self> {
        let _ = pred;
        Self::unavailable(dir)
    }

    /// Locate the artifact directory (works without the feature).
    pub fn find_dir() -> Result<PathBuf> {
        manifest::find_dir()
    }

    /// Always fails: the PJRT client is not compiled in.
    pub fn load_default() -> Result<Self> {
        Self::unavailable(&Self::find_dir()?)
    }

    pub fn has(&self, _entry: &str, _block: usize) -> bool {
        match self.never {}
    }

    pub fn block_sizes(&self, _entry: &str) -> Vec<usize> {
        match self.never {}
    }

    pub fn batch_of(&self, _entry: &str, _block: usize) -> Option<usize> {
        match self.never {}
    }

    pub fn run_block_ptap(
        &self,
        _block: usize,
        _pl: &[f32],
        _a: &[f32],
        _pr: &[f32],
    ) -> Result<Vec<f32>> {
        match self.never {}
    }

    pub fn run_block_jacobi(
        &self,
        _block: usize,
        _dinv: &[f32],
        _r: &[f32],
        _x: &[f32],
        _omega: f32,
    ) -> Result<Vec<f32>> {
        match self.never {}
    }

    pub fn run_block_spmv(&self, _block: usize, _a: &[f32], _x: &[f32]) -> Result<Vec<f32>> {
        match self.never {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_reports_missing_feature() {
        let dir = std::env::temp_dir().join("gptap_stub_test");
        std::fs::create_dir_all(&dir).unwrap();
        let err = KernelRuntime::load(&dir).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
