//! PJRT CPU client wrapper: manifest-driven artifact loading + execution.
//! Compiled only with the `pjrt` feature (needs the `xla` crate); without
//! it, [`super::stub`] provides the same API surface.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::format_err;
use crate::util::error::{Context, Result};

use super::manifest::{self, Manifest, ManifestEntry};

/// A compiled kernel executable plus its static shapes.
struct LoadedKernel {
    exe: xla::PjRtLoadedExecutable,
    batch: usize,
}

/// The runtime: one PJRT CPU client, one compiled executable per
/// (entry, block-size) variant discovered in the manifest.
pub struct KernelRuntime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    kernels: HashMap<(String, usize), LoadedKernel>,
    pub dir: PathBuf,
}

impl KernelRuntime {
    /// Load every artifact in `dir` (compiles them on the CPU client).
    pub fn load(dir: &Path) -> Result<Self> {
        Self::load_filtered(dir, |_| true)
    }

    /// Load only the manifest entries matching `pred`.  The runtime is not
    /// `Sync` (one PJRT client per thread, as one per process under real
    /// MPI), so rank closures load their own filtered instance cheaply.
    pub fn load_filtered(dir: &Path, pred: impl Fn(&ManifestEntry) -> bool) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| format_err!("PJRT client: {e}"))?;
        let mut kernels = HashMap::new();
        for m in manifest.entries.iter().filter(|m| pred(m)) {
            let path = dir.join(&m.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .map_err(|e| format_err!("parsing {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| format_err!("compiling {}: {e}", path.display()))?;
            kernels.insert(
                (m.entry.clone(), m.block),
                LoadedKernel { exe, batch: m.batch },
            );
        }
        Ok(KernelRuntime { client, kernels, dir: dir.to_path_buf() })
    }

    /// Locate the artifact directory, searching upward from the cwd
    /// (lets examples/benches run from any directory in the repo).
    pub fn find_dir() -> Result<PathBuf> {
        manifest::find_dir()
    }

    /// Load everything from the default location.
    pub fn load_default() -> Result<Self> {
        Self::load(&Self::find_dir()?)
    }

    pub fn has(&self, entry: &str, block: usize) -> bool {
        self.kernels.contains_key(&(entry.to_string(), block))
    }

    pub fn block_sizes(&self, entry: &str) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .kernels
            .keys()
            .filter(|(e, _)| e == entry)
            .map(|&(_, b)| b)
            .collect();
        v.sort_unstable();
        v
    }

    /// The compiled batch size of a variant.
    pub fn batch_of(&self, entry: &str, block: usize) -> Option<usize> {
        self.kernels.get(&(entry.to_string(), block)).map(|k| k.batch)
    }

    fn literal_3d(data: &[f32], n: usize, b: usize) -> Result<xla::Literal> {
        xla::Literal::vec1(data)
            .reshape(&[n as i64, b as i64, b as i64])
            .map_err(|e| format_err!("reshape: {e}"))
    }

    /// Run the fused triple-product kernel: `out[k] = pl[k]ᵀ a[k] pr[k]`
    /// for one padded chunk of exactly the compiled batch size.
    /// Slices are f32 row-major `[batch, b, b]`.
    pub fn run_block_ptap(
        &self,
        block: usize,
        pl: &[f32],
        a: &[f32],
        pr: &[f32],
    ) -> Result<Vec<f32>> {
        let k = self
            .kernels
            .get(&("block_ptap".to_string(), block))
            .with_context(|| format!("no block_ptap artifact for b={block}"))?;
        let n = k.batch;
        debug_assert_eq!(pl.len(), n * block * block);
        let lits = [
            Self::literal_3d(pl, n, block)?,
            Self::literal_3d(a, n, block)?,
            Self::literal_3d(pr, n, block)?,
        ];
        let result = k
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| format_err!("execute block_ptap: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| format_err!("device->host: {e}"))?;
        let out = result.to_tuple1().map_err(|e| format_err!("untuple: {e}"))?;
        out.to_vec::<f32>().map_err(|e| format_err!("to_vec: {e}"))
    }

    /// Run the batched block-Jacobi smoother update:
    /// `out[k] = x[k] + omega * dinv[k] @ r[k]` for one padded chunk.
    pub fn run_block_jacobi(
        &self,
        block: usize,
        dinv: &[f32],
        r: &[f32],
        x: &[f32],
        omega: f32,
    ) -> Result<Vec<f32>> {
        let k = self
            .kernels
            .get(&("block_jacobi".to_string(), block))
            .with_context(|| format!("no block_jacobi artifact for b={block}"))?;
        let n = k.batch;
        let ld = Self::literal_3d(dinv, n, block)?;
        let lr = xla::Literal::vec1(r)
            .reshape(&[n as i64, block as i64])
            .map_err(|e| format_err!("reshape: {e}"))?;
        let lx = xla::Literal::vec1(x)
            .reshape(&[n as i64, block as i64])
            .map_err(|e| format_err!("reshape: {e}"))?;
        let lw = xla::Literal::vec1(&[omega]);
        let result = k
            .exe
            .execute::<xla::Literal>(&[ld, lr, lx, lw])
            .map_err(|e| format_err!("execute block_jacobi: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| format_err!("device->host: {e}"))?;
        let out = result.to_tuple1().map_err(|e| format_err!("untuple: {e}"))?;
        out.to_vec::<f32>().map_err(|e| format_err!("to_vec: {e}"))
    }

    /// Run the batched block SpMV kernel: `y[k] = a[k] x[k]` for one
    /// padded chunk (`a`: `[batch, b, b]`, `x`: `[batch, b]`).
    pub fn run_block_spmv(&self, block: usize, a: &[f32], x: &[f32]) -> Result<Vec<f32>> {
        let k = self
            .kernels
            .get(&("block_spmv".to_string(), block))
            .with_context(|| format!("no block_spmv artifact for b={block}"))?;
        let n = k.batch;
        let la = Self::literal_3d(a, n, block)?;
        let lx = xla::Literal::vec1(x)
            .reshape(&[n as i64, block as i64])
            .map_err(|e| format_err!("reshape: {e}"))?;
        let result = k
            .exe
            .execute::<xla::Literal>(&[la, lx])
            .map_err(|e| format_err!("execute block_spmv: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| format_err!("device->host: {e}"))?;
        let out = result.to_tuple1().map_err(|e| format_err!("untuple: {e}"))?;
        out.to_vec::<f32>().map_err(|e| format_err!("to_vec: {e}"))
    }
}
