//! Exactly-preallocated CSR being filled by MatSetValues-style insertion.
//!
//! The symbolic phase of every triple-product algorithm ends by computing
//! per-row nonzero counts (`nzd`/`nzo`) and preallocating the output; the
//! numeric phase then inserts values without ever reallocating (paper
//! Alg. 2 line 13, Alg. 7 line 36).  Inserting past the preallocation is a
//! bug in the symbolic phase and panics (PETSc would raise
//! `MAT_NEW_NONZERO_LOCATION_ERR`).

use super::Csr;

/// CSR skeleton with fixed per-row capacity and a fill cursor per row.
#[derive(Debug, Clone)]
pub struct PreallocCsr {
    pub nrows: usize,
    pub ncols: usize,
    rowptr: Vec<u32>,
    rowlen: Vec<u32>,
    cols: Vec<u32>,
    vals: Vec<f64>,
}

impl PreallocCsr {
    /// Allocate from exact per-row nonzero counts.
    pub fn with_row_counts(ncols: usize, counts: &[u32]) -> Self {
        let nrows = counts.len();
        let mut rowptr = vec![0u32; nrows + 1];
        for i in 0..nrows {
            rowptr[i + 1] = rowptr[i] + counts[i];
        }
        let nnz = rowptr[nrows] as usize;
        PreallocCsr {
            nrows,
            ncols,
            rowptr,
            rowlen: vec![0; nrows],
            cols: vec![0; nnz],
            vals: vec![0.0; nnz],
        }
    }

    pub fn capacity(&self) -> usize {
        self.cols.len()
    }

    pub fn bytes(&self) -> u64 {
        (self.rowptr.len() * 4 + self.rowlen.len() * 4 + self.cols.len() * 4
            + self.vals.len() * 8) as u64
    }

    pub fn row_capacity(&self, i: usize) -> usize {
        (self.rowptr[i + 1] - self.rowptr[i]) as usize
    }

    pub fn row_fill(&self, i: usize) -> usize {
        self.rowlen[i] as usize
    }

    /// Add (sorted cols, vals) into row `i`, merging with existing entries
    /// (ADD_VALUES semantics).  New columns shift-insert to keep the row
    /// sorted; exceeding the preallocation panics.
    pub fn add_row(&mut self, i: usize, cols: &[u32], vals: &[f64]) {
        debug_assert_eq!(cols.len(), vals.len());
        debug_assert!(cols.windows(2).all(|w| w[0] < w[1]));
        let base = self.rowptr[i] as usize;
        let cap = self.row_capacity(i);
        let mut len = self.rowlen[i] as usize;
        // Merge: existing row is sorted, incoming is sorted.  Walk from a
        // search cursor to exploit the sortedness of both sides.
        let mut lo = 0usize;
        for (&c, &v) in cols.iter().zip(vals) {
            let slot = {
                let row = &self.cols[base..base + len];
                match row[lo..].binary_search(&c) {
                    Ok(p) => {
                        lo += p;
                        Some(base + lo)
                    }
                    Err(p) => {
                        lo += p;
                        None
                    }
                }
            };
            match slot {
                Some(s) => {
                    self.vals[s] += v;
                    lo += 1;
                }
                None => {
                    assert!(
                        len < cap,
                        "row {i}: insertion past preallocation (cap {cap}) — symbolic phase undercounted"
                    );
                    let pos = base + lo;
                    // shift-insert
                    self.cols.copy_within(pos..base + len, pos + 1);
                    self.vals.copy_within(pos..base + len, pos + 1);
                    self.cols[pos] = c;
                    self.vals[pos] = v;
                    len += 1;
                    lo += 1;
                }
            }
        }
        self.rowlen[i] = len as u32;
    }

    /// Add a single value (c, v) to row i.
    pub fn add_value(&mut self, i: usize, c: u32, v: f64) {
        self.add_row(i, &[c], &[v]);
    }

    /// Add (sorted cols, vals) scaled by `w` into row `i`.
    pub fn add_row_scaled(&mut self, i: usize, cols: &[u32], vals: &[f64], w: f64) {
        let base = self.rowptr[i] as usize;
        let mut len = self.rowlen[i] as usize;
        let cap = self.row_capacity(i);
        let mut lo = 0usize;
        for (&c, &v) in cols.iter().zip(vals) {
            let slot = {
                let row = &self.cols[base..base + len];
                match row[lo..].binary_search(&c) {
                    Ok(p) => {
                        lo += p;
                        Some(base + lo)
                    }
                    Err(p) => {
                        lo += p;
                        None
                    }
                }
            };
            match slot {
                Some(s) => {
                    self.vals[s] += w * v;
                    lo += 1;
                }
                None => {
                    assert!(len < cap, "row {i}: insertion past preallocation");
                    let pos = base + lo;
                    self.cols.copy_within(pos..base + len, pos + 1);
                    self.vals.copy_within(pos..base + len, pos + 1);
                    self.cols[pos] = c;
                    self.vals[pos] = w * v;
                    len += 1;
                    lo += 1;
                }
            }
        }
        self.rowlen[i] = len as u32;
    }

    /// Filled portion of row `i` as (sorted cols, vals).
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let base = self.rowptr[i] as usize;
        let len = self.rowlen[i] as usize;
        (&self.cols[base..base + len], &self.vals[base..base + len])
    }

    /// Zero all values, keeping the pattern — numeric re-products refill
    /// values into the existing structure (PETSc MAT_REUSE_MATRIX analog).
    pub fn zero_values(&mut self) {
        self.vals.fill(0.0);
    }

    /// Fraction of preallocated slots actually used (1.0 = exact symbolic).
    pub fn fill_ratio(&self) -> f64 {
        if self.capacity() == 0 {
            return 1.0;
        }
        self.rowlen.iter().map(|&l| l as u64).sum::<u64>() as f64 / self.capacity() as f64
    }

    /// Compact into an immutable CSR (drops unused slack, if any).
    pub fn finish(self) -> Csr {
        let exact = (0..self.nrows).all(|i| self.rowlen[i] as usize == self.row_capacity(i));
        if exact {
            return Csr {
                nrows: self.nrows,
                ncols: self.ncols,
                rowptr: self.rowptr,
                cols: self.cols,
                vals: self.vals,
            };
        }
        let mut rowptr = vec![0u32; self.nrows + 1];
        for i in 0..self.nrows {
            rowptr[i + 1] = rowptr[i] + self.rowlen[i];
        }
        let nnz = rowptr[self.nrows] as usize;
        let mut cols = Vec::with_capacity(nnz);
        let mut vals = Vec::with_capacity(nnz);
        for i in 0..self.nrows {
            let base = self.rowptr[i] as usize;
            let len = self.rowlen[i] as usize;
            cols.extend_from_slice(&self.cols[base..base + len]);
            vals.extend_from_slice(&self.vals[base..base + len]);
        }
        Csr { nrows: self.nrows, ncols: self.ncols, rowptr, cols, vals }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fill_exact_and_finish() {
        let mut p = PreallocCsr::with_row_counts(4, &[2, 1]);
        p.add_row(0, &[1, 3], &[1.0, 3.0]);
        p.add_row(1, &[2], &[2.0]);
        let m = p.finish();
        m.validate().unwrap();
        assert_eq!(m.nnz(), 3);
        assert_eq!(m.row(0).1, &[1.0, 3.0]);
    }

    #[test]
    fn add_merges_existing() {
        let mut p = PreallocCsr::with_row_counts(6, &[3]);
        p.add_row(0, &[1, 4], &[1.0, 4.0]);
        p.add_row(0, &[1, 2], &[0.5, 2.0]);
        let m = p.finish();
        assert_eq!(m.row_cols(0), &[1, 2, 4]);
        assert_eq!(m.row(0).1, &[1.5, 2.0, 4.0]);
    }

    #[test]
    fn scaled_add() {
        let mut p = PreallocCsr::with_row_counts(4, &[2]);
        p.add_row_scaled(0, &[0, 1], &[1.0, 2.0], 0.5);
        p.add_row_scaled(0, &[1], &[2.0], 2.0);
        let m = p.finish();
        assert_eq!(m.row(0).1, &[0.5, 5.0]);
    }

    #[test]
    #[should_panic(expected = "preallocation")]
    fn overflow_panics() {
        let mut p = PreallocCsr::with_row_counts(8, &[1]);
        p.add_row(0, &[1, 2], &[1.0, 2.0]);
    }

    #[test]
    fn finish_compacts_slack() {
        let mut p = PreallocCsr::with_row_counts(8, &[5, 2]);
        p.add_row(0, &[1], &[1.0]);
        p.add_row(1, &[0, 7], &[1.0, 7.0]);
        assert!(p.fill_ratio() < 1.0);
        let m = p.finish();
        m.validate().unwrap();
        assert_eq!(m.nnz(), 3);
    }

    #[test]
    fn interleaved_inserts_stay_sorted() {
        let mut p = PreallocCsr::with_row_counts(16, &[6]);
        p.add_row(0, &[8, 12], &[8.0, 12.0]);
        p.add_row(0, &[2, 10], &[2.0, 10.0]);
        p.add_row(0, &[0, 15], &[0.1, 15.0]);
        let m = p.finish();
        m.validate().unwrap();
        assert_eq!(m.row_cols(0), &[0, 2, 8, 10, 12, 15]);
    }
}
