//! Matrix Market I/O (coordinate, real, general/symmetric) — lets the
//! library ingest external operators (SuiteSparse etc.) and dump its own
//! for cross-checking against PETSc/SciPy.

use std::io::{BufRead, BufReader, Write};
use std::path::Path;

use crate::bail;
use crate::util::error::{Context, Result};

use super::csr::{Csr, CsrBuilder};
use crate::dist::{DistCsr, DistCsrBuilder, Layout};

/// Write a sequential CSR in Matrix Market coordinate format.
pub fn write_matrix_market(m: &Csr, path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(f, "% written by galerkin-ptap")?;
    writeln!(f, "{} {} {}", m.nrows, m.ncols, m.nnz())?;
    for i in 0..m.nrows {
        let (cols, vals) = m.row(i);
        for (&c, &v) in cols.iter().zip(vals) {
            writeln!(f, "{} {} {:.17e}", i + 1, c + 1, v)?;
        }
    }
    Ok(())
}

/// Read a Matrix Market coordinate file into a sequential CSR.
/// Supports `general` and `symmetric` qualifiers, real/integer fields,
/// and `pattern` (values default to 1.0).
pub fn read_matrix_market(path: &Path) -> Result<Csr> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut lines = BufReader::new(f).lines();
    let header = lines
        .next()
        .context("empty file")??
        .to_lowercase();
    if !header.starts_with("%%matrixmarket matrix coordinate") {
        bail!("unsupported MatrixMarket header: {header}");
    }
    let symmetric = header.contains("symmetric");
    let pattern = header.contains("pattern");
    if header.contains("complex") || header.contains("hermitian") {
        bail!("complex matrices not supported");
    }
    // skip comments, read sizes
    let mut size_line = String::new();
    for line in lines.by_ref() {
        let line = line?;
        if line.trim().is_empty() || line.starts_with('%') {
            continue;
        }
        size_line = line;
        break;
    }
    let dims: Vec<usize> = size_line
        .split_whitespace()
        .map(|t| t.parse().context("size line"))
        .collect::<Result<_>>()?;
    if dims.len() != 3 {
        bail!("bad size line: {size_line}");
    }
    let (nrows, ncols, nnz) = (dims[0], dims[1], dims[2]);
    let mut triplets: Vec<(u32, u32, f64)> = Vec::with_capacity(nnz);
    for line in lines {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let i: usize = it.next().context("row")?.parse()?;
        let j: usize = it.next().context("col")?.parse()?;
        let v: f64 = if pattern {
            1.0
        } else {
            it.next().context("val")?.parse()?
        };
        if i == 0 || j == 0 || i > nrows || j > ncols {
            bail!("entry out of range: {t}");
        }
        triplets.push(((i - 1) as u32, (j - 1) as u32, v));
        if symmetric && i != j {
            triplets.push(((j - 1) as u32, (i - 1) as u32, v));
        }
    }
    triplets.sort_unstable_by_key(|&(i, j, _)| (i, j));
    let mut b = CsrBuilder::with_capacity(ncols, nrows, triplets.len());
    let mut k = 0usize;
    for i in 0..nrows {
        let mut cols: Vec<u32> = Vec::new();
        let mut vals: Vec<f64> = Vec::new();
        while k < triplets.len() && triplets[k].0 as usize == i {
            // accumulate duplicates
            if cols.last() == Some(&triplets[k].1) {
                *vals.last_mut().unwrap() += triplets[k].2;
            } else {
                cols.push(triplets[k].1);
                vals.push(triplets[k].2);
            }
            k += 1;
        }
        b.push_row(&cols, &vals);
    }
    Ok(b.finish())
}

/// Load a Matrix Market file as a distributed matrix: every rank reads the
/// file and keeps its row slice (adequate below ~10M nnz; a streaming
/// split would come with real parallel I/O).
pub fn read_matrix_market_dist(path: &Path, rank: usize, np: usize) -> Result<DistCsr> {
    let seq = read_matrix_market(path)?;
    let row_layout = Layout::new_equal(seq.nrows, np);
    let col_layout = Layout::new_equal(seq.ncols, np);
    let mut b = DistCsrBuilder::new(rank, row_layout.clone(), col_layout);
    for gi in row_layout.range(rank) {
        let (cols, vals) = seq.row(gi);
        let entries: Vec<(u64, f64)> =
            cols.iter().zip(vals).map(|(&c, &v)| (c as u64, v)).collect();
        b.push_row(&entries);
    }
    Ok(b.finish())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{grid_laplacian, Grid3};

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("gptap_{name}_{}.mtx", std::process::id()))
    }

    #[test]
    fn round_trip_general() {
        let a = grid_laplacian(Grid3::cube(4), 0, 1);
        let g = {
            // sequential form of the local (== global at np=1) matrix
            let mut b = CsrBuilder::new(a.diag.ncols);
            for i in 0..a.local_nrows() {
                let (c, v) = a.diag.row(i);
                b.push_row(c, v);
            }
            b.finish()
        };
        let p = tmp("rt");
        write_matrix_market(&g, &p).unwrap();
        let back = read_matrix_market(&p).unwrap();
        assert_eq!(g, back);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn symmetric_expansion() {
        let p = tmp("sym");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate real symmetric\n3 3 4\n1 1 2.0\n2 1 -1.0\n2 2 2.0\n3 3 1.5\n",
        )
        .unwrap();
        let m = read_matrix_market(&p).unwrap();
        assert_eq!(m.nnz(), 5); // the one off-diagonal is mirrored
        assert_eq!(m.row(0).0, &[0, 1]);
        assert_eq!(m.row(1).1, &[-1.0, 2.0]);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn pattern_defaults_to_one() {
        let p = tmp("pat");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate pattern general\n2 2 2\n1 1\n2 2\n",
        )
        .unwrap();
        let m = read_matrix_market(&p).unwrap();
        assert_eq!(m.row(0).1, &[1.0]);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn rejects_garbage() {
        let p = tmp("bad");
        std::fs::write(&p, "not a matrix\n").unwrap();
        assert!(read_matrix_market(&p).is_err());
        std::fs::write(&p, "%%MatrixMarket matrix coordinate real general\n2 2 1\n5 1 1.0\n")
            .unwrap();
        assert!(read_matrix_market(&p).is_err(), "out-of-range entry must fail");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn duplicates_accumulate() {
        let p = tmp("dup");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate real general\n2 2 3\n1 1 1.0\n1 1 2.5\n2 2 1.0\n",
        )
        .unwrap();
        let m = read_matrix_market(&p).unwrap();
        assert_eq!(m.row(0).1, &[3.5]);
        let _ = std::fs::remove_file(&p);
    }
}
