//! Sequential sparse-matrix substrate (PETSc SeqAIJ / SeqBAIJ analogs).
//!
//! A distributed matrix's local part is stored as two of these (diagonal
//! and off-diagonal blocks, see [`crate::dist::DistCsr`]); everything the
//! triple-product algorithms touch row-by-row lives here.

mod bcsr;
mod csr;
pub mod dense;
pub mod io;
mod prealloc;

pub use bcsr::{Bcsr, BcsrBuilder};
pub use csr::{Csr, CsrBuilder};
pub use dense::{
    block_invert, block_matmul_add, block_matmul_t_add, block_matvec_add,
    block_triple_product_add, DenseBlocks,
};
pub use io::{read_matrix_market, read_matrix_market_dist, write_matrix_market};
pub use prealloc::PreallocCsr;
