//! Compressed sparse row matrix (PETSc SeqAIJ analog).
//!
//! 32-bit row pointers and column indices match PETSc's default PetscInt
//! width, so the memory ratios we report are comparable to the paper's.

/// Immutable CSR matrix with f64 values and sorted column indices per row.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Csr {
    pub nrows: usize,
    pub ncols: usize,
    pub rowptr: Vec<u32>,
    pub cols: Vec<u32>,
    pub vals: Vec<f64>,
}

impl Csr {
    /// Empty matrix with the given shape.
    pub fn zeros(nrows: usize, ncols: usize) -> Self {
        Csr { nrows, ncols, rowptr: vec![0; nrows + 1], cols: Vec::new(), vals: Vec::new() }
    }

    pub fn nnz(&self) -> usize {
        self.cols.len()
    }

    /// Heap bytes (rowptr + cols + vals) for memory accounting.
    pub fn bytes(&self) -> u64 {
        (self.rowptr.len() * 4 + self.cols.len() * 4 + self.vals.len() * 8) as u64
    }

    /// Structure-only bytes (a symbolic-phase object: no values array).
    pub fn bytes_symbolic(&self) -> u64 {
        (self.rowptr.len() * 4 + self.cols.len() * 4) as u64
    }

    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let (a, b) = (self.rowptr[i] as usize, self.rowptr[i + 1] as usize);
        (&self.cols[a..b], &self.vals[a..b])
    }

    #[inline]
    pub fn row_cols(&self, i: usize) -> &[u32] {
        let (a, b) = (self.rowptr[i] as usize, self.rowptr[i + 1] as usize);
        &self.cols[a..b]
    }

    pub fn row_len(&self, i: usize) -> usize {
        (self.rowptr[i + 1] - self.rowptr[i]) as usize
    }

    /// y = A x (sequential).
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), self.ncols);
        debug_assert_eq!(y.len(), self.nrows);
        for i in 0..self.nrows {
            let (cols, vals) = self.row(i);
            let mut acc = 0.0;
            for (&c, &v) in cols.iter().zip(vals) {
                acc += v * x[c as usize];
            }
            y[i] = acc;
        }
    }

    /// y += A x.
    pub fn spmv_add(&self, x: &[f64], y: &mut [f64]) {
        for i in 0..self.nrows {
            let (cols, vals) = self.row(i);
            let mut acc = 0.0;
            for (&c, &v) in cols.iter().zip(vals) {
                acc += v * x[c as usize];
            }
            y[i] += acc;
        }
    }

    /// y += Aᵀ x without materializing the transpose (scatter form).
    pub fn spmv_transpose_add(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), self.nrows);
        debug_assert_eq!(y.len(), self.ncols);
        for i in 0..self.nrows {
            let (cols, vals) = self.row(i);
            let xi = x[i];
            for (&c, &v) in cols.iter().zip(vals) {
                y[c as usize] += v * xi;
            }
        }
    }

    /// Explicit transpose (used by the two-step method's `Pᵀ`).
    pub fn transpose(&self) -> Csr {
        let mut counts = vec![0u32; self.ncols + 1];
        for &c in &self.cols {
            counts[c as usize + 1] += 1;
        }
        for i in 0..self.ncols {
            counts[i + 1] += counts[i];
        }
        let rowptr = counts.clone();
        let nnz = self.nnz();
        let mut cols = vec![0u32; nnz];
        let mut vals = vec![0.0f64; nnz];
        let mut cursor = counts;
        for i in 0..self.nrows {
            let (rc, rv) = self.row(i);
            for (&c, &v) in rc.iter().zip(rv) {
                let p = cursor[c as usize] as usize;
                cols[p] = i as u32;
                vals[p] = v;
                cursor[c as usize] += 1;
            }
        }
        Csr { nrows: self.ncols, ncols: self.nrows, rowptr, cols, vals }
    }

    /// Structure-only transpose (two-step symbolic phase).
    pub fn transpose_symbolic(&self) -> Csr {
        let mut t = self.transpose();
        t.vals = Vec::new();
        t
    }

    /// Dense representation (tests only; panics over ~10^7 entries).
    pub fn to_dense(&self) -> Vec<Vec<f64>> {
        assert!(self.nrows * self.ncols <= 10_000_000, "to_dense too large");
        let mut d = vec![vec![0.0; self.ncols]; self.nrows];
        for i in 0..self.nrows {
            let (cols, vals) = self.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                d[i][c as usize] = v;
            }
        }
        d
    }

    /// Max |a - b| over all entries of two equal-shaped matrices.
    pub fn max_abs_diff(&self, other: &Csr) -> f64 {
        assert_eq!((self.nrows, self.ncols), (other.nrows, other.ncols));
        let mut worst = 0.0f64;
        for i in 0..self.nrows {
            let (ca, va) = self.row(i);
            let (cb, vb) = other.row(i);
            let (mut p, mut q) = (0, 0);
            while p < ca.len() || q < cb.len() {
                if q >= cb.len() || (p < ca.len() && ca[p] < cb[q]) {
                    worst = worst.max(va[p].abs());
                    p += 1;
                } else if p >= ca.len() || cb[q] < ca[p] {
                    worst = worst.max(vb[q].abs());
                    q += 1;
                } else {
                    worst = worst.max((va[p] - vb[q]).abs());
                    p += 1;
                    q += 1;
                }
            }
        }
        worst
    }

    /// Check invariants (sorted, in-range columns; monotone rowptr).
    pub fn validate(&self) -> Result<(), String> {
        if self.rowptr.len() != self.nrows + 1 {
            return Err("rowptr length".into());
        }
        if *self.rowptr.last().unwrap() as usize != self.cols.len() {
            return Err("rowptr end != nnz".into());
        }
        if !self.vals.is_empty() && self.vals.len() != self.cols.len() {
            return Err("vals length".into());
        }
        for i in 0..self.nrows {
            if self.rowptr[i] > self.rowptr[i + 1] {
                return Err(format!("rowptr not monotone at {i}"));
            }
            let cols = self.row_cols(i);
            for w in cols.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!("row {i} columns not strictly sorted"));
                }
            }
            if let Some(&c) = cols.last() {
                if c as usize >= self.ncols {
                    return Err(format!("row {i} column out of range"));
                }
            }
        }
        Ok(())
    }
}

/// Row-by-row CSR builder.
#[derive(Debug, Default)]
pub struct CsrBuilder {
    ncols: usize,
    rowptr: Vec<u32>,
    cols: Vec<u32>,
    vals: Vec<f64>,
}

impl CsrBuilder {
    pub fn new(ncols: usize) -> Self {
        CsrBuilder { ncols, rowptr: vec![0], cols: Vec::new(), vals: Vec::new() }
    }

    pub fn with_capacity(ncols: usize, nrows_hint: usize, nnz_hint: usize) -> Self {
        let mut b = Self::new(ncols);
        b.rowptr.reserve(nrows_hint);
        b.cols.reserve(nnz_hint);
        b.vals.reserve(nnz_hint);
        b
    }

    /// Append a row given sorted columns and matching values.
    pub fn push_row(&mut self, cols: &[u32], vals: &[f64]) {
        debug_assert_eq!(cols.len(), vals.len());
        debug_assert!(cols.windows(2).all(|w| w[0] < w[1]), "columns must be sorted");
        self.cols.extend_from_slice(cols);
        self.vals.extend_from_slice(vals);
        self.rowptr.push(self.cols.len() as u32);
    }

    /// Append a row from (col, val) pairs that may be unsorted (sorts them).
    pub fn push_row_unsorted(&mut self, pairs: &mut Vec<(u32, f64)>) {
        pairs.sort_unstable_by_key(|&(c, _)| c);
        for &(c, v) in pairs.iter() {
            self.cols.push(c);
            self.vals.push(v);
        }
        self.rowptr.push(self.cols.len() as u32);
    }

    pub fn nrows(&self) -> usize {
        self.rowptr.len() - 1
    }

    pub fn finish(self) -> Csr {
        Csr {
            nrows: self.rowptr.len() - 1,
            ncols: self.ncols,
            rowptr: self.rowptr,
            cols: self.cols,
            vals: self.vals,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csr {
        // [1 0 2]
        // [0 3 0]
        // [4 0 5]
        let mut b = CsrBuilder::new(3);
        b.push_row(&[0, 2], &[1.0, 2.0]);
        b.push_row(&[1], &[3.0]);
        b.push_row(&[0, 2], &[4.0, 5.0]);
        b.finish()
    }

    #[test]
    fn build_and_validate() {
        let m = sample();
        assert_eq!(m.nnz(), 5);
        m.validate().unwrap();
        assert_eq!(m.row(2).1, &[4.0, 5.0]);
    }

    #[test]
    fn spmv_matches_dense() {
        let m = sample();
        let x = [1.0, 2.0, 3.0];
        let mut y = [0.0; 3];
        m.spmv(&x, &mut y);
        assert_eq!(y, [7.0, 6.0, 19.0]);
    }

    #[test]
    fn transpose_round_trip() {
        let m = sample();
        let t = m.transpose();
        t.validate().unwrap();
        let tt = t.transpose();
        assert_eq!(m, tt);
    }

    #[test]
    fn spmv_transpose_matches_explicit() {
        let m = sample();
        let x = [1.0, -1.0, 0.5];
        let mut y1 = vec![0.0; 3];
        m.spmv_transpose_add(&x, &mut y1);
        let t = m.transpose();
        let mut y2 = vec![0.0; 3];
        t.spmv(&x, &mut y2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn max_abs_diff_detects_mismatch() {
        let a = sample();
        let mut b = sample();
        assert_eq!(a.max_abs_diff(&b), 0.0);
        b.vals[0] += 0.5;
        assert_eq!(a.max_abs_diff(&b), 0.5);
    }

    #[test]
    fn validate_catches_unsorted() {
        let m = Csr {
            nrows: 1,
            ncols: 3,
            rowptr: vec![0, 2],
            cols: vec![2, 1],
            vals: vec![1.0, 2.0],
        };
        assert!(m.validate().is_err());
    }

    #[test]
    fn push_row_unsorted_sorts() {
        let mut b = CsrBuilder::new(5);
        let mut pairs = vec![(4u32, 4.0), (0, 0.5), (2, 2.0)];
        b.push_row_unsorted(&mut pairs);
        let m = b.finish();
        m.validate().unwrap();
        assert_eq!(m.row_cols(0), &[0, 2, 4]);
    }
}
