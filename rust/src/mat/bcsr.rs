//! Block CSR (PETSc SeqBAIJ analog): CSR over b×b dense blocks.
//!
//! The neutron-transport-like workload couples G energy-group variables per
//! mesh vertex; storing the coupling as dense blocks is what makes the
//! numeric triple product MXU-friendly (see python/compile/kernels/).

use super::csr::{Csr, CsrBuilder};

/// Sparse matrix of dense `b x b` blocks, block-row compressed.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Bcsr {
    /// Block size.
    pub b: usize,
    /// Number of block rows / block columns.
    pub nrows: usize,
    pub ncols: usize,
    pub rowptr: Vec<u32>,
    pub cols: Vec<u32>,
    /// Block values, `nnz * b * b` row-major per block.
    pub vals: Vec<f64>,
}

impl Bcsr {
    pub fn zeros(nrows: usize, ncols: usize, b: usize) -> Self {
        Bcsr { b, nrows, ncols, rowptr: vec![0; nrows + 1], cols: Vec::new(), vals: Vec::new() }
    }

    pub fn nnz_blocks(&self) -> usize {
        self.cols.len()
    }

    pub fn nnz_scalar(&self) -> usize {
        self.cols.len() * self.b * self.b
    }

    pub fn bytes(&self) -> u64 {
        (self.rowptr.len() * 4 + self.cols.len() * 4 + self.vals.len() * 8) as u64
    }

    #[inline]
    pub fn row_cols(&self, i: usize) -> &[u32] {
        &self.cols[self.rowptr[i] as usize..self.rowptr[i + 1] as usize]
    }

    #[inline]
    pub fn block(&self, idx: usize) -> &[f64] {
        let s = self.b * self.b;
        &self.vals[idx * s..(idx + 1) * s]
    }

    #[inline]
    pub fn block_mut(&mut self, idx: usize) -> &mut [f64] {
        let s = self.b * self.b;
        &mut self.vals[idx * s..(idx + 1) * s]
    }

    /// Block index range of row `i` (for pairing row_cols with blocks).
    pub fn row_range(&self, i: usize) -> std::ops::Range<usize> {
        self.rowptr[i] as usize..self.rowptr[i + 1] as usize
    }

    /// y = A x over block vectors (x: ncols*b, y: nrows*b).
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        let b = self.b;
        debug_assert_eq!(x.len(), self.ncols * b);
        debug_assert_eq!(y.len(), self.nrows * b);
        y.fill(0.0);
        for i in 0..self.nrows {
            for idx in self.row_range(i) {
                let c = self.cols[idx] as usize;
                super::dense::block_matvec_add(
                    b,
                    self.block(idx),
                    &x[c * b..(c + 1) * b],
                    &mut y[i * b..(i + 1) * b],
                );
            }
        }
    }

    /// Expand to a scalar CSR (cross-checking block vs scalar algorithms).
    pub fn to_scalar_csr(&self) -> Csr {
        let b = self.b;
        let mut builder = CsrBuilder::with_capacity(self.ncols * b, self.nrows * b, self.nnz_scalar());
        for i in 0..self.nrows {
            for r in 0..b {
                let mut pairs: Vec<(u32, f64)> = Vec::new();
                for idx in self.row_range(i) {
                    let c = self.cols[idx] as usize;
                    let blk = self.block(idx);
                    for j in 0..b {
                        let v = blk[r * b + j];
                        if v != 0.0 {
                            pairs.push(((c * b + j) as u32, v));
                        }
                    }
                }
                builder.push_row_unsorted(&mut pairs);
            }
        }
        builder.finish()
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.rowptr.len() != self.nrows + 1 {
            return Err("rowptr length".into());
        }
        if *self.rowptr.last().unwrap() as usize != self.cols.len() {
            return Err("rowptr end != nnz".into());
        }
        if self.vals.len() != self.cols.len() * self.b * self.b {
            return Err("vals length".into());
        }
        for i in 0..self.nrows {
            let cols = self.row_cols(i);
            for w in cols.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!("block row {i} not sorted"));
                }
            }
            if let Some(&c) = cols.last() {
                if c as usize >= self.ncols {
                    return Err(format!("block row {i} col out of range"));
                }
            }
        }
        Ok(())
    }
}

/// Row-by-row block CSR builder.
#[derive(Debug)]
pub struct BcsrBuilder {
    b: usize,
    ncols: usize,
    rowptr: Vec<u32>,
    cols: Vec<u32>,
    vals: Vec<f64>,
}

impl BcsrBuilder {
    pub fn new(ncols: usize, b: usize) -> Self {
        BcsrBuilder { b, ncols, rowptr: vec![0], cols: Vec::new(), vals: Vec::new() }
    }

    /// Append a block row: sorted block columns with their dense blocks
    /// concatenated in `blocks` (len = cols.len()*b*b).
    pub fn push_row(&mut self, cols: &[u32], blocks: &[f64]) {
        debug_assert_eq!(blocks.len(), cols.len() * self.b * self.b);
        debug_assert!(cols.windows(2).all(|w| w[0] < w[1]));
        self.cols.extend_from_slice(cols);
        self.vals.extend_from_slice(blocks);
        self.rowptr.push(self.cols.len() as u32);
    }

    pub fn finish(self) -> Bcsr {
        Bcsr {
            b: self.b,
            nrows: self.rowptr.len() - 1,
            ncols: self.ncols,
            rowptr: self.rowptr,
            cols: self.cols,
            vals: self.vals,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Bcsr {
        // 2x2 block matrix of 2x2 blocks:
        // [ B00  .  ]
        // [ B10 B11 ]
        let mut b = BcsrBuilder::new(2, 2);
        b.push_row(&[0], &[1.0, 2.0, 3.0, 4.0]);
        b.push_row(&[0, 1], &[5.0, 0.0, 0.0, 5.0, 1.0, 0.0, 0.0, 1.0]);
        b.finish()
    }

    #[test]
    fn build_validate() {
        let m = sample();
        m.validate().unwrap();
        assert_eq!(m.nnz_blocks(), 3);
        assert_eq!(m.block(2), &[1.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn scalar_expansion_matches_spmv() {
        let m = sample();
        let s = m.to_scalar_csr();
        s.validate().unwrap();
        let x = [1.0, 2.0, 3.0, 4.0];
        let mut yb = [0.0; 4];
        m.spmv(&x, &mut yb);
        let mut ys = [0.0; 4];
        s.spmv(&x, &mut ys);
        assert_eq!(yb, ys);
    }

    #[test]
    fn scalar_expansion_drops_explicit_zeros() {
        let m = sample();
        let s = m.to_scalar_csr();
        // block (1,0) = [[5,0],[0,5]] has two zero scalars
        assert!(s.nnz() < m.nnz_scalar());
    }
}
