//! Stacks of small dense b×b blocks — the unit of work for the
//! block-structured (neutron-transport-like) path and the operands the
//! PJRT kernel batches ([N, b, b] tensors on the wire).

/// A contiguous stack of `n` dense `b x b` row-major blocks.
#[derive(Debug, Clone, PartialEq)]
pub struct DenseBlocks {
    pub b: usize,
    data: Vec<f64>,
}

impl DenseBlocks {
    pub fn zeros(n: usize, b: usize) -> Self {
        DenseBlocks { b, data: vec![0.0; n * b * b] }
    }

    pub fn from_vec(data: Vec<f64>, b: usize) -> Self {
        assert_eq!(data.len() % (b * b), 0);
        DenseBlocks { b, data }
    }

    pub fn len(&self) -> usize {
        self.data.len() / (self.b * self.b)
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn bytes(&self) -> u64 {
        (self.data.len() * 8) as u64
    }

    #[inline]
    pub fn block(&self, i: usize) -> &[f64] {
        let s = self.b * self.b;
        &self.data[i * s..(i + 1) * s]
    }

    #[inline]
    pub fn block_mut(&mut self, i: usize) -> &mut [f64] {
        let s = self.b * self.b;
        &mut self.data[i * s..(i + 1) * s]
    }

    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    pub fn push_block(&mut self, blk: &[f64]) {
        assert_eq!(blk.len(), self.b * self.b);
        self.data.extend_from_slice(blk);
    }
}

/// c += a @ b for row-major b×b blocks.
#[inline]
pub fn block_matmul_add(bsz: usize, a: &[f64], b: &[f64], c: &mut [f64]) {
    for i in 0..bsz {
        for k in 0..bsz {
            let aik = a[i * bsz + k];
            if aik == 0.0 {
                continue;
            }
            for j in 0..bsz {
                c[i * bsz + j] += aik * b[k * bsz + j];
            }
        }
    }
}

/// c += aᵀ @ b for row-major b×b blocks (left operand transposed).
#[inline]
pub fn block_matmul_t_add(bsz: usize, a: &[f64], b: &[f64], c: &mut [f64]) {
    for k in 0..bsz {
        for i in 0..bsz {
            let aki = a[k * bsz + i];
            if aki == 0.0 {
                continue;
            }
            for j in 0..bsz {
                c[i * bsz + j] += aki * b[k * bsz + j];
            }
        }
    }
}

/// out += plᵀ @ a @ pr — the scalar reference for the PJRT triple-product
/// kernel (and the fallback when no artifact is loaded).
pub fn block_triple_product_add(bsz: usize, pl: &[f64], a: &[f64], pr: &[f64], out: &mut [f64]) {
    // tmp = a @ pr
    let mut tmp = vec![0.0; bsz * bsz];
    block_matmul_add(bsz, a, pr, &mut tmp);
    block_matmul_t_add(bsz, pl, &tmp, out);
}

/// y += a @ x for a row-major b×b block and b-vectors.
#[inline]
pub fn block_matvec_add(bsz: usize, a: &[f64], x: &[f64], y: &mut [f64]) {
    for i in 0..bsz {
        let mut acc = 0.0;
        for j in 0..bsz {
            acc += a[i * bsz + j] * x[j];
        }
        y[i] += acc;
    }
}

/// In-place dense LU inverse of a b×b block (partial pivoting).  Used to
/// invert diagonal blocks for the block-Jacobi smoother.
pub fn block_invert(bsz: usize, a: &[f64]) -> Option<Vec<f64>> {
    let n = bsz;
    let mut m = a.to_vec();
    let mut inv = vec![0.0; n * n];
    for i in 0..n {
        inv[i * n + i] = 1.0;
    }
    for col in 0..n {
        // pivot
        let mut piv = col;
        for r in col + 1..n {
            if m[r * n + col].abs() > m[piv * n + col].abs() {
                piv = r;
            }
        }
        if m[piv * n + col].abs() < 1e-300 {
            return None;
        }
        if piv != col {
            for j in 0..n {
                m.swap(col * n + j, piv * n + j);
                inv.swap(col * n + j, piv * n + j);
            }
        }
        let d = m[col * n + col];
        for j in 0..n {
            m[col * n + j] /= d;
            inv[col * n + j] /= d;
        }
        for r in 0..n {
            if r == col {
                continue;
            }
            let f = m[r * n + col];
            if f == 0.0 {
                continue;
            }
            for j in 0..n {
                m[r * n + j] -= f * m[col * n + j];
                inv[r * n + j] -= f * inv[col * n + j];
            }
        }
    }
    Some(inv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn rand_block(b: usize, rng: &mut Rng) -> Vec<f64> {
        (0..b * b).map(|_| rng.normal()).collect()
    }

    #[test]
    fn matmul_identity() {
        let b = 3;
        let mut eye = vec![0.0; 9];
        for i in 0..3 {
            eye[i * 3 + i] = 1.0;
        }
        let mut rng = Rng::new(1);
        let a = rand_block(b, &mut rng);
        let mut c = vec![0.0; 9];
        block_matmul_add(b, &a, &eye, &mut c);
        for (x, y) in a.iter().zip(&c) {
            assert!((x - y).abs() < 1e-14);
        }
    }

    #[test]
    fn triple_product_vs_naive() {
        let b = 4;
        let mut rng = Rng::new(2);
        let (pl, a, pr) = (rand_block(b, &mut rng), rand_block(b, &mut rng), rand_block(b, &mut rng));
        let mut got = vec![0.0; b * b];
        block_triple_product_add(b, &pl, &a, &pr, &mut got);
        // naive: out[i][j] = sum_k sum_l pl[k][i] a[k][l] pr[l][j]
        let mut want = vec![0.0; b * b];
        for i in 0..b {
            for j in 0..b {
                let mut acc = 0.0;
                for k in 0..b {
                    for l in 0..b {
                        acc += pl[k * b + i] * a[k * b + l] * pr[l * b + j];
                    }
                }
                want[i * b + j] = acc;
            }
        }
        for (x, y) in got.iter().zip(&want) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn invert_round_trip() {
        let b = 5;
        let mut rng = Rng::new(3);
        // diagonally dominant => invertible
        let mut a = rand_block(b, &mut rng);
        for i in 0..b {
            a[i * b + i] += 10.0;
        }
        let inv = block_invert(b, &a).unwrap();
        let mut prod = vec![0.0; b * b];
        block_matmul_add(b, &a, &inv, &mut prod);
        for i in 0..b {
            for j in 0..b {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((prod[i * b + j] - want).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn invert_singular_returns_none() {
        let a = vec![1.0, 2.0, 2.0, 4.0]; // rank 1
        assert!(block_invert(2, &a).is_none());
    }

    #[test]
    fn blocks_indexing() {
        let mut s = DenseBlocks::zeros(3, 2);
        s.block_mut(1)[0] = 5.0;
        assert_eq!(s.block(1)[0], 5.0);
        assert_eq!(s.block(0)[0], 0.0);
        assert_eq!(s.len(), 3);
        assert_eq!(s.bytes(), 3 * 4 * 8);
    }
}
